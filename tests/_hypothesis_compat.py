"""Fallback for the `hypothesis` package on bare environments.

When hypothesis is installed, conftest.py leaves it alone and the property
tests run as real property tests. When it is missing, conftest installs this
module under ``sys.modules["hypothesis"]`` so ``from hypothesis import
given, settings`` and ``from hypothesis import strategies as st`` still
resolve — but ``@given`` degrades to a **fixed-examples** decorator: a
deterministic seeded RNG draws ``max_examples`` example tuples (the first
example is the minimal one: lower bounds, min sizes) and runs the test body
once per tuple. No shrinking, no database — just enough to keep tier-1
collection and coverage alive without the dependency.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib

_FALLBACK_MAX_EXAMPLES = 25


class _Strategy:
    """A draw rule: ``draw(rng)`` for random examples, ``minimal()`` for
    the deterministic first example."""

    def __init__(self, draw, minimal):
        self._draw = draw
        self._minimal = minimal

    def draw(self, rng):
        return self._draw(rng)

    def minimal(self):
        return self._minimal()


def integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value),
                     lambda: min_value)


def floats(min_value=0.0, max_value=1.0, exclude_max=False, **_kw):
    span = max_value - min_value

    def draw(rng):
        v = min_value + rng.random() * span
        if exclude_max and v >= max_value:
            v = min_value + 0.5 * span
        return v

    return _Strategy(draw, lambda: min_value)


def booleans():
    return _Strategy(lambda rng: bool(rng.getrandbits(1)), lambda: False)


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rng: rng.choice(elements), lambda: elements[0])


def lists(elem, min_size=0, max_size=10):
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return [elem.draw(rng) for _ in range(n)]

    return _Strategy(draw,
                     lambda: [elem.minimal() for _ in range(min_size)])


def tuples(*elems):
    return _Strategy(lambda rng: tuple(e.draw(rng) for e in elems),
                     lambda: tuple(e.minimal() for e in elems))


def given(*strategies):
    def decorate(fn):
        # cross-process-stable seed (str hash() is salted; id() is not
        # reproducible): a failing drawn example must be re-drawable.
        seed_base = zlib.crc32(fn.__qualname__.encode())

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples", _FALLBACK_MAX_EXAMPLES)
            n = min(n, _FALLBACK_MAX_EXAMPLES)
            executed = 0
            for i in range(n):
                if i == 0:
                    drawn = tuple(s.minimal() for s in strategies)
                else:
                    rng = random.Random(seed_base * 1000 + i)
                    drawn = tuple(s.draw(rng) for s in strategies)
                try:
                    fn(*args, *drawn, **kwargs)
                    executed += 1
                except _Unsatisfied:
                    continue        # assume() rejected this example
            if executed == 0:
                # mirror real hypothesis' Unsatisfied: a test whose filter
                # rejects every example must not silently pass
                raise AssertionError(
                    f"{fn.__qualname__}: assume() rejected all {n} fallback "
                    f"examples — vacuous property test")

        # pytest must not mistake the drawn parameters for fixtures: drop
        # the wraps()-installed __wrapped__ (inspect.signature follows it)
        # and present a zero-argument signature.
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        wrapper.hypothesis_fallback = True
        return wrapper

    return decorate


def settings(max_examples=None, **_kw):
    def decorate(fn):
        if max_examples is not None:
            fn._max_examples = max_examples
        return fn

    return decorate


def assume(condition):
    if not condition:
        raise _Unsatisfied()


class _Unsatisfied(Exception):
    pass


class HealthCheck:
    all = classmethod(lambda cls: [])
    too_slow = "too_slow"
    data_too_large = "data_too_large"


def install():
    """Register this module as ``hypothesis`` (+ ``.strategies``)."""
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.HealthCheck = HealthCheck
    strat = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from", "lists",
                 "tuples"):
        setattr(strat, name, globals()[name])
    hyp.strategies = strat
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strat
