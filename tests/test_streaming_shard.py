"""Node-partitioned sliding window (repro.distributed.streaming_shard,
DESIGN.md §12).

The multi-shard cases run in a subprocess with 8 forced host devices
(device count must be set before jax initializes); the single-shard case
runs in-process and checks the full exchange/merge/migration path plus
byte-identity against the single-device reference on one real device.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs.base import (
    EngineConfig,
    SamplerConfig,
    SchedulerConfig,
    ShardConfig,
    WalkConfig,
    WindowConfig,
)
from repro.core import streaming as streaming_mod
from repro.core.streaming import StreamingEngine
from repro.data.synthetic import chronological_batches, powerlaw_temporal_graph
from repro.distributed.streaming_shard import DistributedStreamingEngine

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.configs.base import (EngineConfig, SamplerConfig, SchedulerConfig,
                                ShardConfig, WalkConfig, WindowConfig)
from repro.core.streaming import StreamingEngine
from repro.data.synthetic import chronological_batches, powerlaw_temporal_graph
from repro.distributed.streaming_shard import DistributedStreamingEngine

N = 128
g = powerlaw_temporal_graph(N, 3000, seed=7)
cfg = EngineConfig(
    window=WindowConfig(duration=3000, edge_capacity=4096, node_capacity=N),
    sampler=SamplerConfig(bias="exponential", mode="index"),
    scheduler=SchedulerConfig(path="grouped", regroup="bucket"),
    shard=ShardConfig(edge_capacity_per_shard=4096, exchange_capacity=1024,
                      walk_slots=512, walk_bucket_capacity=512),
)
wcfg = WalkConfig(num_walks=256, max_length=8, start_mode="all_nodes")

ref = StreamingEngine(cfg, batch_capacity=1024)
rstats, rwalks, _ = ref.replay_device(chronological_batches(g, 5), wcfg,
                                      return_walks=True)

# --- byte-identity across shard counts {1, 2, 8} -------------------------
for D in (1, 2, 8):
    deng = DistributedStreamingEngine(cfg, batch_capacity=1024, num_shards=D)
    assert deng.num_shards == D
    dstats, dwalks, _ = deng.replay_device(chronological_batches(g, 5), wcfg)
    assert int(dstats.exchange_drops.sum()) == 0, (D, "exchange overflow")
    assert int(dstats.walk_drops.sum()) == 0, (D, "walk overflow")
    assert dstats.exchange_drops.shape == (5, D)
    for f in rstats._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(rstats, f)),
            np.asarray(getattr(dstats.replay, f)), err_msg=f"D={D} {f}")
    np.testing.assert_array_equal(rwalks.nodes, dwalks.nodes)
    np.testing.assert_array_equal(rwalks.times, dwalks.times)
    np.testing.assert_array_equal(rwalks.lengths, dwalks.lengths)

# --- the sharded store partitions the single-device store ----------------
import math
D = 8
rng = math.ceil(N / D)
deng = DistributedStreamingEngine(cfg, batch_capacity=1024, num_shards=D)
deng.replay_device(chronological_batches(g, 5), wcfg)
gstore = ref.state.index.store
n_glob = int(gstore.num_edges)
gsrc = np.asarray(gstore.src)[:n_glob]
gdst = np.asarray(gstore.dst)[:n_glob]
gts = np.asarray(gstore.ts)[:n_glob]
for d in range(D):
    sstore = jax.tree.map(lambda a: np.asarray(a)[d],
                          deng.state.window.index.store)
    n_loc = int(sstore.num_edges)
    sel = (gsrc // rng) == d
    assert n_loc == int(sel.sum()), (d, n_loc, int(sel.sum()))
    np.testing.assert_array_equal(sstore.src[:n_loc], gsrc[sel])
    np.testing.assert_array_equal(sstore.dst[:n_loc], gdst[sel])
    np.testing.assert_array_equal(sstore.ts[:n_loc], gts[sel])

# --- overflow drops are counted, not crashed -----------------------------
tiny = EngineConfig(
    window=cfg.window, sampler=cfg.sampler, scheduler=cfg.scheduler,
    shard=ShardConfig(edge_capacity_per_shard=4096, exchange_capacity=8,
                      walk_slots=512, walk_bucket_capacity=512))
deng = DistributedStreamingEngine(tiny, batch_capacity=1024, num_shards=8)
dstats, _, _ = deng.replay_device(chronological_batches(g, 5), wcfg)
assert int(dstats.exchange_drops.sum()) > 0, "expected exchange overflow"

tiny_w = EngineConfig(
    window=cfg.window, sampler=cfg.sampler, scheduler=cfg.scheduler,
    shard=ShardConfig(edge_capacity_per_shard=4096, exchange_capacity=1024,
                      walk_slots=512, walk_bucket_capacity=2))
deng = DistributedStreamingEngine(tiny_w, batch_capacity=1024, num_shards=8)
dstats, _, _ = deng.replay_device(chronological_batches(g, 5), wcfg)
assert int(dstats.walk_drops.sum()) > 0, "expected walk-bucket overflow"

print("SHARDED_WINDOW_OK")
"""


@pytest.mark.slow      # 8-device subprocess
def test_sharded_window_8_devices():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "SHARDED_WINDOW_OK" in out.stdout, \
        (out.stdout[-1500:], out.stderr[-3000:])


def _cfg(num_nodes=96):
    return EngineConfig(
        window=WindowConfig(duration=2500, edge_capacity=2048,
                            node_capacity=num_nodes),
        sampler=SamplerConfig(bias="exponential", mode="index"),
        scheduler=SchedulerConfig(path="grouped", regroup="bucket"),
        shard=ShardConfig(edge_capacity_per_shard=2048,
                          exchange_capacity=512, walk_slots=256,
                          walk_bucket_capacity=256),
    )


def test_single_shard_matches_replay_device():
    """One-shard sharded replay == single-device replay_device, bit for
    bit: same per-batch stats, same final-batch walks."""
    cfg = _cfg()
    g = powerlaw_temporal_graph(96, 2000, seed=13)
    wcfg = WalkConfig(num_walks=96, max_length=6, start_mode="all_nodes")

    ref = StreamingEngine(cfg, batch_capacity=512)
    rstats, rwalks, _ = ref.replay_device(chronological_batches(g, 4), wcfg,
                                          return_walks=True)

    deng = DistributedStreamingEngine(cfg, batch_capacity=512, num_shards=1)
    dstats, dwalks, elapsed = deng.replay_device(
        chronological_batches(g, 4), wcfg)
    assert elapsed > 0
    assert int(dstats.exchange_drops.sum()) == 0
    assert int(dstats.walk_drops.sum()) == 0
    for f in rstats._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(rstats, f)),
            np.asarray(getattr(dstats.replay, f)), err_msg=f)
    np.testing.assert_array_equal(rwalks.nodes, dwalks.nodes)
    np.testing.assert_array_equal(rwalks.times, dwalks.times)
    np.testing.assert_array_equal(rwalks.lengths, dwalks.lengths)


def test_ingest_batch_matches_single_device_window():
    """The standalone shard_map'd ingest advances the window exactly like
    the single-device merge ingest (1 shard: counters + store identical)."""
    cfg = _cfg()
    g = powerlaw_temporal_graph(96, 1500, seed=3)

    ref = StreamingEngine(cfg, batch_capacity=512)
    deng = DistributedStreamingEngine(cfg, batch_capacity=512, num_shards=1)
    for bs, bd, bt in chronological_batches(g, 3):
        ref.ingest_batch(bs, bd, bt)
        deng.ingest_batch(bs, bd, bt)

    rs = ref.state
    ds = jax.tree.map(lambda a: np.asarray(a)[0], deng.state.window)
    assert int(ds.t_now) == int(rs.t_now)
    assert int(ds.ingested) == int(rs.ingested)
    assert int(ds.late_drops) == int(rs.late_drops)
    assert int(ds.overflow_drops) == int(rs.overflow_drops)
    n = int(rs.index.store.num_edges)
    assert int(ds.index.store.num_edges) == n
    np.testing.assert_array_equal(ds.index.store.src[:n],
                                  np.asarray(rs.index.store.src)[:n])
    np.testing.assert_array_equal(ds.index.store.ts[:n],
                                  np.asarray(rs.index.store.ts)[:n])
    assert int(np.asarray(deng.state.exchange_drops).sum()) == 0


def test_unsupported_modes_raise():
    cfg = _cfg()
    deng = DistributedStreamingEngine(cfg, batch_capacity=512, num_shards=1)
    g = powerlaw_temporal_graph(96, 500, seed=1)
    with pytest.raises(ValueError, match="all_nodes"):
        deng.replay_device(chronological_batches(g, 2),
                           WalkConfig(num_walks=32, max_length=4,
                                      start_mode="nodes"))
    n2v = EngineConfig(
        window=cfg.window, scheduler=cfg.scheduler, shard=cfg.shard,
        sampler=SamplerConfig(bias="exponential", mode="index",
                              node2vec_p=0.5, node2vec_q=2.0))
    deng2 = DistributedStreamingEngine(n2v, batch_capacity=512, num_shards=1)
    with pytest.raises(ValueError, match="node2vec"):
        deng2.replay_device(chronological_batches(g, 2),
                            WalkConfig(num_walks=32, max_length=4,
                                       start_mode="all_nodes"))


def test_replicated_index_warning(monkeypatch):
    """sample_walks_sharded warns once when the replicated index passes the
    size threshold, pointing at the node-partitioned engine."""
    cfg = _cfg()
    eng = StreamingEngine(cfg, batch_capacity=512)
    g = powerlaw_temporal_graph(96, 500, seed=2)
    for bs, bd, bt in chronological_batches(g, 1):
        eng.ingest_batch(bs, bd, bt)
    wcfg = WalkConfig(num_walks=64, max_length=4, start_mode="nodes")
    monkeypatch.setattr(streaming_mod, "REPLICATED_INDEX_WARN_BYTES", 0)
    with pytest.warns(UserWarning, match="DistributedStreamingEngine"):
        eng.sample_walks_sharded(wcfg)
    # one-time: a second call stays silent
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")
        eng.sample_walks_sharded(wcfg)
