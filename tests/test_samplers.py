"""Sampler distribution laws (paper §2.5).

The closed-form inverse CDFs must reproduce their target categorical
distributions exactly (up to Monte-Carlo noise), and the weight-based
samplers must match softmax/linear weights over real timestamps.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.samplers import (
    index_exponential,
    index_linear,
    index_uniform,
    weighted_pick_exp,
    weighted_pick_linear,
)

NDRAWS = 200_000

# upper α=1e-3 standard-normal quantile: the false-positive rate per MC
# test. Seeds are pinned (PRNGKey constants below), so in practice each
# gate is deterministic — α bounds how unlucky a pinned seed can be.
_Z_ALPHA = 3.0902
CHI2_ALPHA = 1e-3


def chi2_crit(dof: int, z: float = _Z_ALPHA) -> float:
    """Upper critical value of χ²(dof) via the Wilson–Hilferty cube
    approximation (accurate to ~1% for dof >= 3; conservative below)."""
    dof = max(dof, 1)
    h = 2.0 / (9.0 * dof)
    return dof * (1.0 - h + z * np.sqrt(h)) ** 3


def _hist(picks, n):
    return np.bincount(np.asarray(picks), minlength=n)[:n] / len(picks)


def _chi2_ok(observed, expected, ndraws):
    """Pearson χ² goodness-of-fit at fixed (dof, α): buckets with an
    expected count <= 5 are pooled out (the standard validity rule),
    dof = kept buckets − 1, gate = Wilson–Hilferty critical value."""
    exp_counts = expected * ndraws
    mask = exp_counts > 5
    chi2 = np.sum((observed[mask] * ndraws - exp_counts[mask]) ** 2
                  / exp_counts[mask])
    dof = int(mask.sum())
    return chi2 < chi2_crit(max(dof - 1, 1))


@pytest.mark.parametrize("n", [1, 2, 7, 64])
@pytest.mark.statistical
def test_index_uniform_law(n):
    u = jax.random.uniform(jax.random.PRNGKey(0), (NDRAWS,))
    picks = index_uniform(u, jnp.full((NDRAWS,), n, jnp.int32))
    h = _hist(picks, n)
    assert _chi2_ok(h, np.full(n, 1.0 / n), NDRAWS)


@pytest.mark.parametrize("n", [1, 2, 7, 64])
@pytest.mark.statistical
def test_index_linear_law(n):
    u = jax.random.uniform(jax.random.PRNGKey(1), (NDRAWS,))
    picks = index_linear(u, jnp.full((NDRAWS,), n, jnp.int32))
    w = np.arange(1, n + 1, dtype=np.float64)
    assert _chi2_ok(_hist(picks, n), w / w.sum(), NDRAWS)


@pytest.mark.parametrize("n", [1, 2, 7, 20])
@pytest.mark.statistical
def test_index_exponential_law(n):
    u = jax.random.uniform(jax.random.PRNGKey(2), (NDRAWS,))
    picks = index_exponential(u, jnp.full((NDRAWS,), n, jnp.int32))
    w = np.exp(np.arange(n, dtype=np.float64) - n)
    assert _chi2_ok(_hist(picks, n), w / w.sum(), NDRAWS)


@pytest.mark.statistical
def test_index_exponential_large_n_asymptotic():
    """Above the float32 e^n threshold the log-domain form takes over and
    must still concentrate on the most recent positions."""
    n = 500
    u = jax.random.uniform(jax.random.PRNGKey(3), (NDRAWS,))
    picks = np.asarray(index_exponential(u, jnp.full((NDRAWS,), n, jnp.int32)))
    assert picks.min() >= 0 and picks.max() <= n - 1
    # P(i >= n-5) = (e^5-1+...)/... ~ 1 - e^-5 ≈ 0.993
    assert (picks >= n - 5).mean() > 0.98


@pytest.mark.statistical
def test_weighted_exp_matches_softmax():
    ts = jnp.asarray([0, 5, 5, 8, 9], jnp.int32)
    tref = int(ts.max())
    w = jnp.exp((ts - tref).astype(jnp.float32))
    pexp = jnp.concatenate([jnp.zeros(1), jnp.cumsum(w)])
    u = jax.random.uniform(jax.random.PRNGKey(4), (NDRAWS,))
    c = jnp.zeros((NDRAWS,), jnp.int32)
    b = jnp.full((NDRAWS,), 5, jnp.int32)
    picks = weighted_pick_exp(pexp, c, b, u)
    target = np.asarray(w / w.sum(), np.float64)
    assert _chi2_ok(_hist(picks, 5), target, NDRAWS)


@pytest.mark.statistical
def test_weighted_exp_suffix_neighborhood():
    """Sampling from a suffix [c, b) uses the same global prefix array."""
    ts = jnp.asarray([0, 5, 5, 8, 9], jnp.int32)
    w = jnp.exp((ts - 9).astype(jnp.float32))
    pexp = jnp.concatenate([jnp.zeros(1), jnp.cumsum(w)])
    u = jax.random.uniform(jax.random.PRNGKey(5), (NDRAWS,))
    c = jnp.full((NDRAWS,), 2, jnp.int32)
    b = jnp.full((NDRAWS,), 5, jnp.int32)
    picks = np.asarray(weighted_pick_exp(pexp, c, b, u)) - 2
    wn = np.asarray(w)[2:]
    assert _chi2_ok(_hist(picks, 3), wn / wn.sum(), NDRAWS)


@pytest.mark.statistical
def test_weighted_linear_matches_weights():
    ts = jnp.asarray([2, 4, 4, 10], jnp.int32)
    tbase = 2
    elem = (ts - tbase + 1).astype(jnp.float32)
    plin = jnp.concatenate([jnp.zeros(1), jnp.cumsum(elem)])
    u = jax.random.uniform(jax.random.PRNGKey(6), (NDRAWS,))
    c = jnp.zeros((NDRAWS,), jnp.int32)
    b = jnp.full((NDRAWS,), 4, jnp.int32)
    tb = jnp.full((NDRAWS,), tbase, jnp.int32)
    picks = weighted_pick_linear(plin, ts, tb, c, b, u)
    # w_i = ts_i - ts_c + 1 with ts_c = 2
    w = np.asarray(ts, np.float64) - 2 + 1
    assert _chi2_ok(_hist(picks, 4), w / w.sum(), NDRAWS)


@settings(max_examples=50, deadline=None)
@given(st.floats(0.0, 1.0, exclude_max=True), st.integers(1, 10_000))
def test_index_samplers_in_range(u, n):
    uu = jnp.asarray([u], jnp.float32)
    nn = jnp.asarray([n], jnp.int32)
    for f in (index_uniform, index_linear, index_exponential):
        i = int(f(uu, nn)[0])
        assert 0 <= i < n


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 100), min_size=1, max_size=30),
       st.floats(0.0, 1.0, exclude_max=True))
def test_weighted_exp_exact_inverse_cdf(ts_list, u):
    """Property: the returned k is the minimal index whose cumulative
    normalized weight reaches u."""
    ts = np.sort(np.asarray(ts_list, np.int32))
    w = np.exp((ts - ts.max()).astype(np.float64))
    pexp = jnp.concatenate([jnp.zeros(1), jnp.cumsum(jnp.asarray(w, jnp.float32))])
    n = len(ts)
    k = int(weighted_pick_exp(pexp, jnp.asarray([0], jnp.int32),
                              jnp.asarray([n], jnp.int32),
                              jnp.asarray([u], jnp.float32))[0])
    cdf = np.cumsum(w) / w.sum()
    expected = int(np.searchsorted(cdf, u, side="right"))
    # float32 rounding at bucket boundaries may move the pick by one bucket
    assert abs(k - min(expected, n - 1)) <= 1
