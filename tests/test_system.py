"""End-to-end system tests: the full Tempest-JAX loop — streaming
ingestion -> dual-index rebuild -> cooperative walk generation ->
downstream consumers (skipgram embeddings, LM batches)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (
    EngineConfig,
    SamplerConfig,
    SchedulerConfig,
    WalkConfig,
    WindowConfig,
)
from repro.core.streaming import StreamingEngine
from repro.core.validation import validate_walks
from repro.data.synthetic import chronological_batches, powerlaw_temporal_graph
from repro.data.walk_dataset import skipgram_pairs, walks_to_lm_batch
from repro.train.embeddings import (
    init_skipgram,
    link_prediction_auc,
    train_on_walks,
)

pytestmark = pytest.mark.slow      # end-to-end streaming system + downstream consumers


def test_streaming_end_to_end():
    g = powerlaw_temporal_graph(256, 20_000, seed=31)
    cfg = EngineConfig(
        window=WindowConfig(duration=4000, edge_capacity=1 << 15,
                            node_capacity=256),
        sampler=SamplerConfig(bias="exponential", mode="weight"),
        scheduler=SchedulerConfig(path="grouped"),
    )
    eng = StreamingEngine(cfg, batch_capacity=4096)
    wcfg = WalkConfig(num_walks=1024, max_length=20, start_mode="nodes")
    seen_valid = []

    def on_batch(e, walks):
        rep = validate_walks(e.state.index, walks)
        seen_valid.append(float(rep.walk_valid_frac))

    stats = eng.replay(chronological_batches(g, 8), wcfg, on_batch=on_batch)
    assert len(stats.ingest_s) == 8
    assert all(v == 1.0 for v in seen_valid)           # paper §3.10
    assert int(eng.state.ingested) == 20_000
    # walks_valid is populated per sampling round (fraction of walks that
    # advanced at least one hop)
    assert len(stats.walks_valid) == 8
    assert all(0.0 <= v <= 1.0 for v in stats.walks_valid)
    assert stats.walks_valid[-1] > 0.0
    # bounded memory: active edges never exceed capacity
    assert max(stats.edges_active) <= 1 << 15


def test_walks_feed_embeddings():
    g = powerlaw_temporal_graph(128, 8000, seed=32)
    cfg = EngineConfig(
        window=WindowConfig(duration=100_000, edge_capacity=1 << 14,
                            node_capacity=128))
    eng = StreamingEngine(cfg, batch_capacity=8192)
    eng.ingest_batch(g.src, g.dst, g.ts)
    walks = eng.sample_walks(WalkConfig(num_walks=2048, max_length=10,
                                        start_mode="nodes"))
    state = init_skipgram(128, 16, jax.random.PRNGKey(0))
    state, loss = train_on_walks(state, walks.nodes, walks.lengths,
                                 jax.random.PRNGKey(1), epochs=2)
    assert np.isfinite(loss)
    auc = link_prediction_auc(state, g.src[-500:], g.dst[-500:], 128)
    # walks encode co-occurrence: better than random
    assert auc > 0.55, auc


def test_walks_feed_lm_batches():
    g = powerlaw_temporal_graph(128, 8000, seed=33)
    cfg = EngineConfig(
        window=WindowConfig(duration=100_000, edge_capacity=1 << 14,
                            node_capacity=128))
    eng = StreamingEngine(cfg, batch_capacity=8192)
    eng.ingest_batch(g.src, g.dst, g.ts)
    walks = eng.sample_walks(WalkConfig(num_walks=512, max_length=12,
                                        start_mode="nodes"))
    toks, labels = walks_to_lm_batch(np.asarray(walks.nodes),
                                     np.asarray(walks.lengths),
                                     seq_len=32, batch=4, vocab=256)
    assert toks.shape == (4, 32) and labels.shape == (4, 32)
    assert toks.max() < 256 and toks.min() >= 0
    # labels are the shifted stream
    np.testing.assert_array_equal(toks[:, 1:], labels[:, :-1])


def test_skipgram_pairs_window():
    nodes = np.asarray([[1, 2, 3, -1]], np.int32)
    lengths = np.asarray([3], np.int32)
    c, x = skipgram_pairs(nodes, lengths, window=1)
    pairs = set(zip(c.tolist(), x.tolist()))
    assert pairs == {(1, 2), (2, 1), (2, 3), (3, 2)}
