import importlib.util
import pathlib

import jax
import numpy as np
import pytest

# Optional-dependency shim: on bare environments the property tests degrade
# to fixed examples instead of failing collection (tests/_hypothesis_compat).
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _spec = importlib.util.spec_from_file_location(
        "_hypothesis_compat",
        pathlib.Path(__file__).parent / "_hypothesis_compat.py")
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    _mod.install()

from repro.configs.base import SamplerConfig, SchedulerConfig, WalkConfig
from repro.core.edge_store import store_from_arrays
from repro.core.temporal_index import build_index
from repro.data.synthetic import powerlaw_temporal_graph

# NOTE: no XLA_FLAGS device-count override here — smoke tests and benches
# must see the single real CPU device. Only launch/dryrun.py forces 512.


@pytest.fixture(scope="session")
def small_graph():
    return powerlaw_temporal_graph(200, 3000, seed=1)


@pytest.fixture(scope="session")
def small_index(small_graph):
    g = small_graph
    store = store_from_arrays(g.src, g.dst, g.ts,
                              edge_capacity=4096, node_capacity=256)
    return build_index(store, 256)


@pytest.fixture(scope="session")
def hub_graph():
    """Heavily hub-skewed graph exercising the mega-hub dispatch column."""
    return powerlaw_temporal_graph(64, 8000, skew=2.0, seed=3)


@pytest.fixture(scope="session")
def hub_index(hub_graph):
    g = hub_graph
    store = store_from_arrays(g.src, g.dst, g.ts,
                              edge_capacity=8192, node_capacity=64)
    return build_index(store, 64)


@pytest.fixture
def walk_cfg():
    return WalkConfig(num_walks=512, max_length=16, start_mode="nodes")


@pytest.fixture
def sampler_cfg():
    return SamplerConfig(bias="exponential", mode="index")


@pytest.fixture
def sched_cfg():
    return SchedulerConfig(path="grouped")


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)
