"""Fast-lane golden test for the dispatch-plane tier distribution.

Promotes benchmarks/tier_distribution.py to a regression gate: on the
fixed seeded graph in ``GOLDEN_DATASET``, ``dispatch_stats`` must report
exactly these tier counts. The values are checked in; any change to the
tier rules (solo/group/mega thresholds, the fused tier-S/tier-L split of
DESIGN.md §14, or the block-sweep count model) shows up here as an
integer diff and must be re-baselined deliberately.
"""
from benchmarks.tier_distribution import golden_counts

EXPECTED = {
    "solo": 93,
    "group_smem": 162,
    "group_global": 4,
    "mega": 0,
    "fused_small": 3064,
    "fused_big": 600,
    "fused_blocks": 2400,
}


def test_tier_distribution_golden():
    got = golden_counts()
    assert got == EXPECTED, f"tier counts drifted: {got} != {EXPECTED}"
