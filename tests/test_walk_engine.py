"""Walk-engine invariants: causality, path equivalence, start modes,
node2vec second-order law."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SamplerConfig, SchedulerConfig, WalkConfig
from repro.core.edge_store import store_from_arrays
from repro.core.temporal_index import build_index
from repro.core.validation import validate_walks, validate_walks_np
from repro.core.walk_engine import NODE_PAD, generate_walks

ALL_PATHS = ("fullwalk", "grouped", "tiled")
BIAS_MODES = [("uniform", "index"), ("linear", "index"),
              ("exponential", "index"), ("uniform", "weight"),
              ("linear", "weight"), ("exponential", "weight")]


@pytest.mark.parametrize("bias,mode", BIAS_MODES)
def test_walks_causal(small_index, bias, mode, key):
    wcfg = WalkConfig(num_walks=512, max_length=16, start_mode="nodes")
    scfg = SamplerConfig(bias=bias, mode=mode)
    res = generate_walks(small_index, key, wcfg, scfg, SchedulerConfig())
    rep = validate_walks(small_index, res)
    assert float(rep.hop_valid_frac) == 1.0
    assert float(rep.walk_valid_frac) == 1.0


@pytest.mark.parametrize("regroup", ("bucket", "lexsort"))
@pytest.mark.parametrize("path", ALL_PATHS[1:])
def test_path_equivalence(small_index, path, regroup, key):
    """Grouped and tiled layouts emit identical walks to fullwalk, under
    both the O(W) bucket regroup (carried permutation) and the lexsort
    reference (DESIGN.md §10)."""
    wcfg = WalkConfig(num_walks=512, max_length=12, start_mode="nodes")
    scfg = SamplerConfig(bias="exponential", mode="weight")
    ref = generate_walks(small_index, key, wcfg, scfg,
                         SchedulerConfig(path="fullwalk"))
    got = generate_walks(small_index, key, wcfg, scfg,
                         SchedulerConfig(path=path, regroup=regroup,
                                         tile_walks=128, tile_edges=512))
    assert jnp.array_equal(ref.nodes, got.nodes)
    assert jnp.array_equal(ref.times, got.times)
    assert jnp.array_equal(ref.lengths, got.lengths)


def test_path_equivalence_hub_graph(hub_index, key):
    """Equivalence must hold under mega-hub skew (oversize fallback path)."""
    wcfg = WalkConfig(num_walks=1024, max_length=10, start_mode="nodes")
    scfg = SamplerConfig(bias="exponential", mode="index")
    ref = generate_walks(hub_index, key, wcfg, scfg,
                         SchedulerConfig(path="fullwalk"))
    for path in ("grouped", "tiled"):
        for regroup in ("bucket", "lexsort"):
            got = generate_walks(hub_index, key, wcfg, scfg,
                                 SchedulerConfig(path=path, regroup=regroup,
                                                 tile_walks=256,
                                                 tile_edges=1024))
            assert jnp.array_equal(ref.nodes, got.nodes), (path, regroup)


def test_regroup_time_subsort_off_equivalence(small_index, key):
    """Node-only bucketing (no time subsort) is still byte-equivalent —
    grouping is purely an execution layout."""
    wcfg = WalkConfig(num_walks=512, max_length=10, start_mode="nodes")
    scfg = SamplerConfig(bias="linear", mode="weight")
    ref = generate_walks(small_index, key, wcfg, scfg,
                         SchedulerConfig(path="fullwalk"))
    got = generate_walks(small_index, key, wcfg, scfg,
                         SchedulerConfig(path="grouped", regroup="bucket",
                                         regroup_time=False))
    assert jnp.array_equal(ref.nodes, got.nodes)
    assert jnp.array_equal(ref.lengths, got.lengths)


def test_generate_walks_donated_matches_and_consumes(small_index, key):
    """Donated entry point: byte-identical results, buffers consumed, and
    chaining the previous result's arrays works (DESIGN.md §10)."""
    from repro.core.walk_engine import (WalkBuffers, alloc_walk_buffers,
                                        generate_walks_donated)
    wcfg = WalkConfig(num_walks=256, max_length=10, start_mode="nodes")
    scfg = SamplerConfig(bias="exponential", mode="weight")
    cfg = SchedulerConfig(path="grouped", regroup="bucket")
    ref = generate_walks(small_index, key, wcfg, scfg, cfg)
    bufs = alloc_walk_buffers(wcfg)
    got = generate_walks_donated(small_index, key, bufs, wcfg, scfg, cfg)
    assert jnp.array_equal(ref.nodes, got.nodes)
    assert jnp.array_equal(ref.times, got.times)
    assert jnp.array_equal(ref.lengths, got.lengths)
    with pytest.raises(Exception):
        np.asarray(bufs.nodes)          # storage was donated
    # round 2 reuses round 1's result arrays as buffers
    key2 = jax.random.PRNGKey(99)
    ref2 = generate_walks(small_index, key2, wcfg, scfg, cfg)
    got2 = generate_walks_donated(small_index, key2,
                                  WalkBuffers(got.nodes, got.times),
                                  wcfg, scfg, cfg)
    assert jnp.array_equal(ref2.nodes, got2.nodes)
    assert jnp.array_equal(ref2.lengths, got2.lengths)


def test_edges_start_mode(small_index, key):
    wcfg = WalkConfig(num_walks=256, max_length=8, start_mode="edges")
    scfg = SamplerConfig(start_bias="linear")
    res = generate_walks(small_index, key, wcfg, scfg, SchedulerConfig())
    rep = validate_walks(small_index, res)
    assert float(rep.hop_valid_frac) == 1.0
    # edges mode records (src, dst) of the start edge
    lengths = np.asarray(res.lengths)
    assert lengths.min() >= 2


def test_all_nodes_start_mode(small_index, key):
    wcfg = WalkConfig(num_walks=512, max_length=8, start_mode="all_nodes")
    res = generate_walks(small_index, key, wcfg, SamplerConfig(),
                         SchedulerConfig())
    nodes0 = np.asarray(res.nodes[:, 0])
    live = nodes0 != NODE_PAD
    # walk w starts at node w % node_capacity when that node is active
    expect = np.arange(512) % 256
    assert np.all(nodes0[live] == expect[live])


def test_walk_buffer_padding(small_index, key):
    res = generate_walks(small_index, key,
                         WalkConfig(num_walks=128, max_length=12,
                                    start_mode="nodes"),
                         SamplerConfig(), SchedulerConfig())
    nodes = np.asarray(res.nodes)
    lengths = np.asarray(res.lengths)
    for w in range(128):
        assert np.all(nodes[w, lengths[w]:] == NODE_PAD)
        assert np.all(nodes[w, :lengths[w]] != NODE_PAD)


def test_validator_detects_corruption(small_index, key):
    res = generate_walks(small_index, key,
                         WalkConfig(num_walks=128, max_length=12,
                                    start_mode="nodes"),
                         SamplerConfig(), SchedulerConfig())
    rep0 = validate_walks(small_index, res)
    assert float(rep0.walk_valid_frac) == 1.0
    # corrupt: swap a hop's timestamps to violate monotonicity
    lengths = np.asarray(res.lengths)
    w = int(np.argmax(lengths >= 3))
    if lengths[w] >= 3:
        times = res.times.at[w, 1].set(res.times[w, 2] + 1)
        bad = res._replace(times=times)
        rep = validate_walks(small_index, bad)
        assert float(rep.walk_valid_frac) < 1.0


def test_node2vec_second_order_law(key):
    """With q -> inf, non-returning non-common hops are suppressed."""
    # triangle u->v at t=1, v->u at t=2, v->w at t=2, u->w edge absent
    src = np.asarray([0, 1, 1], np.int32)
    dst = np.asarray([1, 0, 2], np.int32)
    ts = np.asarray([1, 2, 2], np.int32)
    store = store_from_arrays(src, dst, ts, edge_capacity=8, node_capacity=4)
    idx = build_index(store, 4)
    wcfg = WalkConfig(num_walks=4096, max_length=3, start_mode="all_nodes")
    # p=inf suppresses return (1->0); q=1 keeps out. Start at node 0 only.
    scfg = SamplerConfig(bias="uniform", mode="index",
                         node2vec_p=1e9, node2vec_q=1.0)
    res = generate_walks(idx, key, wcfg, scfg, SchedulerConfig(path="fullwalk"))
    nodes = np.asarray(res.nodes)
    started_at_0 = nodes[:, 0] == 0
    two_hops = np.asarray(res.lengths) >= 3
    sel = started_at_0 & two_hops
    # from 0 -> 1 at t=1 the second hop is 1->0 (return, suppressed by p)
    # or 1->2; returns should be rare (8 rejection rounds each 1/2 proposal:
    # residual fallback keeps a tiny fraction)
    second = nodes[sel, 2]
    frac_return = np.mean(second == 0) if sel.sum() else 0.0
    assert frac_return < 0.02


def test_np_validator_agrees(small_index, small_graph, key):
    res = generate_walks(small_index, key,
                         WalkConfig(num_walks=256, max_length=10,
                                    start_mode="nodes"),
                         SamplerConfig(), SchedulerConfig())
    rep = validate_walks(small_index, res)
    hv, wv = validate_walks_np(
        (small_graph.src, small_graph.dst, small_graph.ts),
        np.asarray(res.nodes), np.asarray(res.times),
        np.asarray(res.lengths))
    assert abs(float(rep.hop_valid_frac) - hv) < 1e-6
    assert abs(float(rep.walk_valid_frac) - wv) < 1e-6


def test_stats_collection(small_index, key):
    from repro.core import scheduler as sched
    res = generate_walks(small_index, key,
                         WalkConfig(num_walks=512, max_length=8,
                                    start_mode="nodes"),
                         SamplerConfig(), SchedulerConfig(),
                         collect_stats=True)
    stats = np.asarray(res.stats)
    assert stats.shape == (8, sched.NUM_STATS)
    # alive counts decrease monotonically
    alive = stats[:, sched.STAT_ALIVE]
    assert np.all(np.diff(alive) <= 0)
    # grouped modeled bytes never exceed fullwalk modeled bytes
    assert np.all(stats[:, sched.STAT_BYTES_GROUPED]
                  <= stats[:, sched.STAT_BYTES_FULLWALK] + 1e-6)
