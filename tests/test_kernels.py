"""Per-kernel allclose vs pure-jnp oracles across shape/dtype sweeps
(interpret mode executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.edge_store import store_from_arrays
from repro.core.temporal_index import build_index, node_range
from repro.data.synthetic import powerlaw_temporal_graph
from repro.kernels import ref as kref
from repro.kernels.walk_step import walk_step_tiled
from repro.kernels.weight_prefix import weight_prefix

MODES = [("index", "uniform"), ("index", "linear"), ("index", "exponential"),
         ("weight", "uniform"), ("weight", "exponential"),
         ("weight", "linear")]


def _setup(E=2048, N=128, W=512, seed=2):
    g = powerlaw_temporal_graph(N, E - 100, seed=seed)
    store = store_from_arrays(g.src % N, g.dst % N, g.ts,
                              edge_capacity=E, node_capacity=N)
    idx = build_index(store, N)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    nodes = jnp.sort(jax.random.randint(k1, (W,), 0, N))
    times = jax.random.randint(k2, (W,), 0, 10_000)
    u = jax.random.uniform(k3, (W,))
    return idx, nodes, times, u


def _tile_inputs(idx, nodes, TW, TE):
    W = nodes.shape[0]
    E = idx.edge_capacity
    a, b = node_range(idx, nodes)
    T = W // TW
    a_t, b_t = a.reshape(T, TW), b.reshape(T, TW)
    base_blocks = jnp.clip(jnp.min(a_t, axis=1) // TE, 0, E // TE - 2)
    base = base_blocks * TE
    lo_raw = (a_t - base[:, None]).reshape(W)
    hi_raw = (b_t - base[:, None]).reshape(W)
    # mirrors kernels/ops.py: hi == 2*TE fits the staged window exactly;
    # lo clips to 2*TE so empty end-of-window regions (lo == hi == 2*TE)
    # stay empty
    oversize = (lo_raw < 0) | (hi_raw > 2 * TE)
    lo = jnp.clip(lo_raw, 0, 2 * TE)
    hi = jnp.clip(hi_raw, 0, 2 * TE)
    tbase = idx.node_tbase[jnp.clip(nodes, 0, idx.node_capacity - 1)]
    return base_blocks.astype(jnp.int32), lo, hi, oversize, tbase


@pytest.mark.parametrize("mode,bias", MODES)
@pytest.mark.parametrize("TW,TE", [(128, 256), (64, 512), (256, 128)])
def test_walk_step_matches_ref(mode, bias, TW, TE):
    idx, nodes, times, u = _setup()
    E = idx.edge_capacity
    base_blocks, lo, hi, oversize, tbase = _tile_inputs(idx, nodes, TW, TE)
    lin = mode == "weight" and bias == "linear"
    pfx = idx.plin[:E] if lin else idx.pexp[:E]
    pfxs = idx.plin[1:E + 1] if lin else idx.pexp[1:E + 1]
    args = (idx.ns_ts[:E], idx.ns_dst[:E], pfx, pfxs, base_blocks,
            times, lo, hi, u, tbase)
    got = walk_step_tiled(*args, mode=mode, bias=bias, tile_walks=TW,
                          tile_edges=TE, interpret=True)
    want = kref.walk_step_ref(*args, mode=mode, bias=bias, tile_walks=TW,
                              tile_edges=TE)
    ok = ~oversize
    for g_, w_ in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g_)[np.asarray(ok)],
                                      np.asarray(w_)[np.asarray(ok)])


def test_walk_step_oracle_matches_engine():
    """The oracle itself agrees with the engine's global-path sampling."""
    from repro.configs.base import SamplerConfig
    from repro.core.samplers import pick_in_neighborhood
    from repro.core.temporal_index import temporal_cutoff
    idx, nodes, times, u = _setup()
    E = idx.edge_capacity
    TW, TE = 128, 512
    base_blocks, lo, hi, oversize, tbase = _tile_inputs(idx, nodes, TW, TE)
    args = (idx.ns_ts[:E], idx.ns_dst[:E], idx.pexp[:E], idx.pexp[1:E + 1],
            base_blocks, times, lo, hi, u, tbase)
    k_loc, n, dst, ts = kref.walk_step_ref(
        *args, mode="weight", bias="exponential", tile_walks=TW, tile_edges=TE)
    a, b = node_range(idx, nodes)
    c = temporal_cutoff(idx, a, b, times)
    scfg = SamplerConfig(bias="exponential", mode="weight")
    k_engine = pick_in_neighborhood(idx, scfg, c, b, u, nodes)
    W = nodes.shape[0]
    tile_of_walk = jnp.arange(W) // TW
    k_global = base_blocks[tile_of_walk] * TE + k_loc
    ok = np.asarray(~oversize & (n > 0))
    np.testing.assert_array_equal(np.asarray(k_global)[ok],
                                  np.asarray(k_engine)[ok])


@pytest.mark.parametrize("E,tile", [(1024, 128), (2048, 256), (4096, 1024)])
@pytest.mark.parametrize("scale", [1.0, 0.1])
def test_weight_prefix_matches_ref(E, tile, scale):
    k = jax.random.PRNGKey(E)
    dt = -jax.random.uniform(k, (E,)) * 50
    valid = jnp.arange(E) < (E * 3 // 4)
    got = weight_prefix(dt, valid, scale=scale, tile=tile, interpret=True)
    want = kref.weight_prefix_ref(dt, valid, scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_weight_prefix_dtype_sweep():
    for dtype in (jnp.float32, jnp.int32):
        dt = -jnp.arange(512, dtype=dtype) % 20
        valid = jnp.ones((512,), bool)
        got = weight_prefix(dt.astype(jnp.float32) * -1.0, valid,
                            scale=0.5, tile=128, interpret=True)
        want = kref.weight_prefix_ref(dt.astype(jnp.float32) * -1.0,
                                      valid, 0.5)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


def test_index_prefix_consistency(small_index):
    """pexp built by build_index equals the fused kernel's output."""
    idx = small_index
    E = idx.edge_capacity
    nc = idx.node_capacity
    dt = (idx.ns_ts - idx.node_tref[jnp.clip(idx.ns_src, 0, nc - 1)])
    valid = idx.ns_src < nc
    got = weight_prefix(dt.astype(jnp.float32), valid, scale=1.0,
                        tile=1024, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(idx.pexp),
                               rtol=1e-5, atol=1e-4)
