"""Merge-based ingest and device-resident replay (DESIGN.md §4).

The merge path must be *byte-identical* to the seed sort path — same store
contents, same counters, same index arrays — and the `lax.scan` replay
driver must reproduce the host-loop driver's window trajectory exactly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (
    EngineConfig,
    SamplerConfig,
    SchedulerConfig,
    WalkConfig,
    WindowConfig,
)
from repro.core.edge_store import make_batch, stack_batches
from repro.core.streaming import (
    ReplayStats,
    StreamingEngine,
    ingest_and_walk,
    ingest_and_walk_donated,
    replay_scan,
)
from repro.core.walk_engine import (
    WalkBuffers,
    alloc_walk_buffers,
    generate_walks,
)
from repro.core.window import ingest, ingest_sort, init_window
from repro.data.synthetic import chronological_batches, powerlaw_temporal_graph


def _assert_states_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# Merge == sort equivalence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_merge_matches_sort_randomized(seed):
    """Randomized streams with ties, late edges, and overflow: the merge
    path and the seed argsort path produce identical WindowStates after
    every batch."""
    rng = np.random.default_rng(seed)
    sm = init_window(edge_capacity=128, node_capacity=16, window=300)
    ss = init_window(edge_capacity=128, node_capacity=16, window=300)
    t = 0
    for _ in range(10):
        n = int(rng.integers(1, 60))
        # heavy timestamp ties + out-of-window stragglers + bursts
        ts = rng.integers(t - 150, t + 200, n).astype(np.int32) // 3 * 3
        t = max(t, int(ts.max()))
        src = rng.integers(0, 16, n)
        dst = rng.integers(0, 16, n)
        batch = make_batch(src, dst, ts, capacity=64)
        sm = ingest(sm, batch, 16)
        ss = ingest_sort(ss, batch, 16)
        _assert_states_equal(sm, ss)


def test_merge_matches_sort_on_graph_stream():
    g = powerlaw_temporal_graph(64, 4000, seed=11)
    sm = init_window(edge_capacity=2048, node_capacity=64, window=2000)
    ss = init_window(edge_capacity=2048, node_capacity=64, window=2000)
    for bs, bd, bt in chronological_batches(g, 8):
        batch = make_batch(bs, bd, bt, capacity=768)
        sm = ingest(sm, batch, 64)
        ss = ingest_sort(ss, batch, 64)
    _assert_states_equal(sm, ss)


def test_merge_empty_batch_and_empty_store():
    """Degenerate runs: empty batch into empty store, then a real batch,
    then another empty batch."""
    sm = init_window(edge_capacity=32, node_capacity=4, window=100)
    ss = init_window(edge_capacity=32, node_capacity=4, window=100)
    empty = make_batch([], [], [], capacity=8)
    full = make_batch([0, 1, 2], [1, 2, 3], [5, 5, 9], capacity=8)
    for batch in (empty, full, empty):
        sm = ingest(sm, batch, 4)
        ss = ingest_sort(ss, batch, 4)
        _assert_states_equal(sm, ss)


# ---------------------------------------------------------------------------
# Device-resident replay
# ---------------------------------------------------------------------------


def _engine(num_nodes=128, edge_capacity=4096, duration=2000, seed=0):
    cfg = EngineConfig(
        window=WindowConfig(duration=duration, edge_capacity=edge_capacity,
                            node_capacity=num_nodes),
        sampler=SamplerConfig(bias="exponential", mode="index"),
        scheduler=SchedulerConfig(path="grouped"),
        seed=seed,
    )
    return StreamingEngine(cfg, batch_capacity=1024)


def test_replay_scan_matches_host_loop():
    """The scan driver's window trajectory == the host loop's, batch for
    batch, and the final states are identical."""
    g = powerlaw_temporal_graph(128, 6000, seed=21)
    wcfg = WalkConfig(num_walks=128, max_length=6, start_mode="nodes")

    host = _engine()
    host.replay(chronological_batches(g, 6), wcfg)

    dev = _engine()
    stats, elapsed = dev.replay_device(chronological_batches(g, 6), wcfg)

    assert isinstance(stats, ReplayStats)
    assert stats.edges_active.shape == (6,)
    assert stats.edges_active.tolist() == host.stats.edges_active
    assert int(stats.ingested[-1]) == 6000
    assert elapsed > 0
    _assert_states_equal(host.state, dev.state)


def test_replay_scan_stats_on_device_until_read():
    """replay_scan itself returns device arrays (no per-batch host sync):
    the single materialization point is the caller's block_until_ready."""
    g = powerlaw_temporal_graph(64, 2000, seed=5)
    eng = _engine(num_nodes=64, edge_capacity=2048)
    stacked = stack_batches(chronological_batches(g, 4), 1024)
    wcfg = WalkConfig(num_walks=64, max_length=4, start_mode="nodes")
    state, stats, walks = replay_scan(
        eng.state, stacked, jax.random.PRNGKey(0),
        eng.cfg.window.node_capacity, wcfg, eng.cfg.sampler,
        eng.cfg.scheduler)
    for leaf in jax.tree_util.tree_leaves((state, stats, walks)):
        assert isinstance(leaf, jax.Array)
    assert walks.nodes.shape == (64, 5)
    jax.block_until_ready(stats)
    assert int(stats.ingested[-1]) == 2000


def test_ingest_and_walk_fused_step_matches_separate_dispatches():
    """The fused (donating) step == ingest followed by generate_walks with
    the same key: identical window state AND identical walks."""
    g = powerlaw_temporal_graph(64, 1000, seed=13)
    scfg = SamplerConfig(bias="exponential", mode="index")
    sched = SchedulerConfig(path="grouped")
    wcfg = WalkConfig(num_walks=64, max_length=4, start_mode="nodes")
    key = jax.random.PRNGKey(7)
    batch = make_batch(g.src, g.dst, g.ts, capacity=1024)

    ref = init_window(edge_capacity=2048, node_capacity=64, window=10_000)
    ref = ingest_sort(ref, batch, 64)
    ref_walks = generate_walks(ref.index, key, wcfg, scfg, sched)

    fused_in = init_window(edge_capacity=2048, node_capacity=64,
                           window=10_000)
    fused, walks = ingest_and_walk(fused_in, batch, key, 64, wcfg, scfg,
                                   sched)
    _assert_states_equal(ref, fused)
    np.testing.assert_array_equal(np.asarray(ref_walks.nodes),
                                  np.asarray(walks.nodes))
    np.testing.assert_array_equal(np.asarray(ref_walks.lengths),
                                  np.asarray(walks.lengths))
    # donation consumed the input state
    with pytest.raises(Exception):
        np.asarray(fused_in.index.store.ts)


def test_ingest_and_walk_donated_chain_matches_separate_dispatches():
    """The fully donated fused step (state + walk buffers consumed) equals
    the non-donating path batch for batch when chained through
    ``WalkBuffers(res.nodes, res.times)`` (DESIGN.md §10)."""
    g = powerlaw_temporal_graph(64, 2000, seed=17)
    scfg = SamplerConfig(bias="exponential", mode="weight")
    sched = SchedulerConfig(path="grouped")
    wcfg = WalkConfig(num_walks=64, max_length=6, start_mode="nodes")
    batches = [make_batch(bs, bd, bt, capacity=1024)
               for bs, bd, bt in chronological_batches(g, 3)]

    ref_state = init_window(edge_capacity=2048, node_capacity=64,
                            window=10_000)
    don_state = init_window(edge_capacity=2048, node_capacity=64,
                            window=10_000)
    bufs = alloc_walk_buffers(wcfg)
    prev_res = None
    for i, batch in enumerate(batches):
        key = jax.random.PRNGKey(100 + i)
        ref_state = ingest_sort(ref_state, batch, 64)
        ref_walks = generate_walks(ref_state.index, key, wcfg, scfg, sched)
        don_state, res = ingest_and_walk_donated(
            don_state, batch, bufs, key, 64, wcfg, scfg, sched)
        np.testing.assert_array_equal(np.asarray(ref_walks.nodes),
                                      np.asarray(res.nodes))
        np.testing.assert_array_equal(np.asarray(ref_walks.lengths),
                                      np.asarray(res.lengths))
        if prev_res is not None:
            with pytest.raises(Exception):       # consumed by this round
                np.asarray(prev_res.nodes)
        bufs = WalkBuffers(res.nodes, res.times)
        prev_res = res
    _assert_states_equal(ref_state, don_state)


def test_engine_sample_walks_donated_pool():
    """StreamingEngine.sample_walks_donated: identical walks to
    sample_walks for the same seed, per-shape buffer reuse (the previous
    same-shape result is consumed), and walks_valid recording."""
    g = powerlaw_temporal_graph(64, 3000, seed=9)
    wcfg = WalkConfig(num_walks=128, max_length=6, start_mode="nodes")
    plain = _engine(num_nodes=64, edge_capacity=4096, duration=100_000)
    pool = _engine(num_nodes=64, edge_capacity=4096, duration=100_000)
    plain.ingest_batch(g.src[:1000], g.dst[:1000], g.ts[:1000])
    pool.ingest_batch(g.src[:1000], g.dst[:1000], g.ts[:1000])

    a1 = plain.sample_walks(wcfg)
    b1 = pool.sample_walks_donated(wcfg)
    np.testing.assert_array_equal(np.asarray(a1.nodes),
                                  np.asarray(b1.nodes))
    a2 = plain.sample_walks(wcfg)
    b2 = pool.sample_walks_donated(wcfg)      # consumes b1's buffers
    np.testing.assert_array_equal(np.asarray(a2.nodes),
                                  np.asarray(b2.nodes))
    with pytest.raises(Exception):
        np.asarray(b1.nodes)
    assert len(pool.stats.walks_valid) == 2
    assert all(0.0 <= v <= 1.0 for v in pool.stats.walks_valid)


def test_engine_sample_walks_sharded():
    from repro.core.validation import validate_walks
    g = powerlaw_temporal_graph(64, 3000, seed=9)
    eng = _engine(num_nodes=64, edge_capacity=4096, duration=100_000)
    eng.ingest_batch(g.src[:1000], g.dst[:1000], g.ts[:1000])
    wcfg = WalkConfig(num_walks=128, max_length=6, start_mode="nodes")
    res = eng.sample_walks_sharded(wcfg)
    assert res.nodes.shape == (128, 7)
    rep = validate_walks(eng.state.index, res)
    assert float(rep.walk_valid_frac) == 1.0
    assert len(eng.stats.walks_valid) == 1


def test_replay_scan_walk_lengths_sane():
    g = powerlaw_temporal_graph(64, 3000, seed=8)
    eng = _engine(num_nodes=64, edge_capacity=4096, duration=10_000)
    wcfg = WalkConfig(num_walks=256, max_length=8, start_mode="nodes")
    stats, _ = eng.replay_device(chronological_batches(g, 5), wcfg)
    # every batch generated walks; mean length in [1, max_length+1]
    assert np.all(stats.mean_len >= 1.0)
    assert np.all(stats.mean_len <= wcfg.max_length + 1)


# ---------------------------------------------------------------------------
# Counter accounting across multi-batch replays (late / overflow / ingested)
# ---------------------------------------------------------------------------


def test_counters_multibatch_accounting():
    """ingested / late_drops / overflow_drops tally exactly across a
    multi-batch replay, including an overflow batch larger than the
    remaining capacity."""
    cap = 16
    st = init_window(edge_capacity=cap, node_capacity=8, window=1000)

    # batch 1: 10 edges, fits
    st = ingest(st, make_batch(np.zeros(10, np.int32), np.ones(10, np.int32),
                               np.arange(10, dtype=np.int32),
                               capacity=32), 8)
    assert int(st.ingested) == 10
    assert int(st.late_drops) == 0
    assert int(st.overflow_drops) == 0
    assert int(st.index.store.num_edges) == 10

    # batch 2: 12 more live edges with only 6 slots free -> 6 oldest drop
    ts2 = np.arange(10, 22, dtype=np.int32)
    st = ingest(st, make_batch(np.zeros(12, np.int32), np.ones(12, np.int32),
                               ts2, capacity=32), 8)
    assert int(st.ingested) == 22
    assert int(st.overflow_drops) == 6
    assert int(st.index.store.num_edges) == cap
    kept = np.asarray(st.index.store.ts)[:cap]
    assert kept.tolist() == list(range(6, 22))   # newest 16 survive

    # batch 3: 2 late edges (t_now=21, window=1000 -> nothing late yet at
    # these times), so push t_now forward first with one fresh edge ...
    st = ingest(st, make_batch([3], [4], [2000], capacity=32), 8)
    # ... then: ts 900 < 2000-1000 is late; ts 1500 is kept
    st = ingest(st, make_batch([1, 2], [2, 3], [900, 1500], capacity=32), 8)
    assert int(st.ingested) == 25
    assert int(st.late_drops) == 1
    # store: everything older than 1000 evicted; only ts 1500 and 2000 left
    n = int(st.index.store.num_edges)
    assert np.asarray(st.index.store.ts)[:n].tolist() == [1500, 2000]
    # overflow counter untouched by eviction/late paths
    assert int(st.overflow_drops) == 6


def test_counters_overflow_exceeds_remaining_capacity_scan_driver():
    """Same accounting via the device-resident driver: cumulative counters
    reported per batch match a brute-force host simulation."""
    cap = 64
    rng = np.random.default_rng(42)
    batches = []
    t = 0
    for _ in range(6):
        n = int(rng.integers(20, 60))        # overflows a 64-slot store fast
        ts = np.sort(rng.integers(t, t + 50, n)).astype(np.int32)
        t = int(ts.max())
        batches.append((rng.integers(0, 8, n).astype(np.int32),
                        rng.integers(0, 8, n).astype(np.int32), ts))

    cfg = EngineConfig(
        window=WindowConfig(duration=10_000, edge_capacity=cap,
                            node_capacity=8),
        sampler=SamplerConfig(bias="uniform", mode="index"),
        scheduler=SchedulerConfig(path="grouped"),
    )
    eng = StreamingEngine(cfg, batch_capacity=64)
    wcfg = WalkConfig(num_walks=32, max_length=4, start_mode="nodes")
    stats, _ = eng.replay_device(batches, wcfg)

    # brute-force per-batch expectation (window never evicts here)
    total, live, overflow = 0, 0, []
    for _, _, ts in batches:
        total += len(ts)
        live = min(live + len(ts), cap)
        overflow.append(total - live)
    assert int(stats.ingested[-1]) == total
    assert stats.overflow_drops.tolist() == overflow
    assert stats.late_drops.tolist() == [0] * len(batches)
    assert stats.edges_active.tolist() == [min(cap, c) for c in
                                           np.cumsum([len(b[2]) for b in
                                                      batches]).tolist()]
