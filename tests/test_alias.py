"""Alias/radix bias factorization: exact laws, oracle equivalence,
incremental maintenance (DESIGN.md §17).

Three layers of evidence, strongest first:

* **exact enumeration** — for every neighborhood size 1..8 and random
  weights, enumerating *all* ``deg·M`` quantized uniforms must hit each
  outcome exactly ``mass_i`` times, and the masses must be the
  largest-remainder apportionment of the weights. No tolerance anywhere:
  a quantized uniform sits at least half a quantile from every bucket
  boundary, while the float path error is orders of magnitude smaller.
* **oracle equivalence** — ``alias_pick`` against the dense O(W·E)
  ``kernels.ref.alias_pick_ref``: law-identical per-outcome counts on the
  tabled branch, per-u identical picks on the exact-fallback branch.
* **incremental == scratch** — property-tested over streamed ingest
  batches with eviction churn: the incrementally maintained tables are
  leaf-identical to a from-scratch build after every advance.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.base import SamplerConfig, SchedulerConfig, WalkConfig
from repro.core.alias import (
    AliasTables,
    TableSpec,
    WEIGHT_FNS,
    alias_pick,
    build_tables,
    quantize_row,
    region_weights,
    row_masses,
    spec_from_sampler,
    vose_row,
    weight_exponential,
    weight_linear,
    weight_uniform,
)
from repro.core.edge_store import make_batch
from repro.core.walk_engine import generate_walks
from repro.core.window import ingest_nodonate, init_window
from repro.kernels.ref import alias_pick_ref
from tests.test_samplers import chi2_crit

M = 64           # small radix: full enumeration stays cheap
R_CAP = 8


def _lr_masses(w, deg, radix):
    """Independent numpy largest-remainder apportionment (float64)."""
    w = np.maximum(np.asarray(w[:deg], np.float64), 0.0)
    target = deg * radix
    if deg == 0:
        return np.zeros(0, np.int64)
    if w.sum() <= 0:
        return np.full(deg, radix, np.int64)
    q = w / w.sum() * target
    fl = np.floor(q).astype(np.int64)
    d = target - fl.sum()
    order = np.lexsort((np.arange(deg), -(q - fl)))  # desc frac, index ties
    m = fl.copy()
    for i in order[:d]:
        m[i] += 1
    return m


# ---------------------------------------------------------------------------
# Row level: quantization + Vose construction, exact
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("deg", list(range(1, R_CAP + 1)))
def test_row_exact_enumeration(deg):
    """All deg·M quantized uniforms hit outcome i exactly mass_i times,
    and the masses are the largest-remainder apportionment."""
    rng = np.random.default_rng(deg)
    w = np.zeros(R_CAP, np.float32)
    w[:deg] = rng.uniform(0.1, 10.0, deg).astype(np.float32)
    if deg >= 3:
        w[1] = 0.0          # a zero-weight entry must be unreachable

    masses = np.asarray(quantize_row(jnp.asarray(w), jnp.asarray(deg), M))
    assert masses[:deg].sum() == deg * M
    assert (masses[deg:] == 0).all()
    if deg >= 3:
        assert masses[1] == 0
    # float32 row agrees with the float64 reference apportionment
    np.testing.assert_array_equal(masses[:deg], _lr_masses(w, deg, M))

    thresh, partner = vose_row(jnp.asarray(masses), jnp.asarray(deg), M)
    th, pa = np.asarray(thresh), np.asarray(partner)
    assert ((pa[:deg] >= 0) & (pa[:deg] < deg)).all()
    assert ((th[:deg] >= 0) & (th[:deg] <= M)).all()
    # mass-recovery identity
    np.testing.assert_array_equal(
        np.asarray(row_masses(thresh, partner, jnp.asarray(deg), M))[:deg],
        masses[:deg])

    # full enumeration through the draw rule itself
    kq = np.arange(deg * M)
    j = kq // M
    r = kq - j * M
    outcome = np.where(r < th[j], j, pa[j])
    counts = np.bincount(outcome, minlength=deg)[:deg]
    np.testing.assert_array_equal(counts, masses[:deg])


def test_row_degenerates():
    # single neighbor: every uniform lands on it
    m1 = np.asarray(quantize_row(jnp.asarray([3.0, 0, 0, 0], jnp.float32),
                                 jnp.asarray(1), M))
    np.testing.assert_array_equal(m1, [M, 0, 0, 0])
    th, pa = map(np.asarray, vose_row(jnp.asarray(m1), jnp.asarray(1), M))
    assert th[0] == M and pa[0] == 0
    # all-zero weights: uniform fallback masses
    mz = np.asarray(quantize_row(jnp.zeros(4, jnp.float32),
                                 jnp.asarray(3), M))
    np.testing.assert_array_equal(mz, [M, M, M, 0])
    # empty region: all-zero masses, sentinel thresholds
    m0 = np.asarray(quantize_row(jnp.ones(4, jnp.float32), jnp.asarray(0), M))
    np.testing.assert_array_equal(m0, 0)
    th0, _ = map(np.asarray, vose_row(jnp.asarray(m0), jnp.asarray(0), M))
    assert (th0 == -1).all()


def test_table_spec_validation():
    with pytest.raises(ValueError, match="power of two"):
        TableSpec(radix=48)
    with pytest.raises(ValueError, match="degree_cap"):
        TableSpec(degree_cap=0)
    with pytest.raises(ValueError, match="2\\^23"):
        TableSpec(radix=4096, degree_cap=1 << 13)
    spec = spec_from_sampler(SamplerConfig(mode="index", bias="table",
                                           table_weight="linear"))
    assert spec is not None and spec.weight is weight_linear
    assert spec_from_sampler(SamplerConfig(mode="index")) is None


# ---------------------------------------------------------------------------
# Window level: alias_pick law + oracle equivalence
# ---------------------------------------------------------------------------


def _window_with(src, dst, ts, spec, ec=256, nc=32):
    state = init_window(ec, nc, 10**6, table=spec)
    return ingest_nodonate(state, make_batch(src, dst, ts, capacity=ec), nc,
                           table=spec)


def _pick_weight(ts, tbase, tref):
    return (ts % 7 + 1).astype(jnp.float32)


def test_window_alias_law_exact_and_oracle_match():
    """Draws over a real window: enumeration of every quantized uniform is
    law-exact vs normalized weights, and matches alias_pick_ref's law."""
    spec = TableSpec(weight=_pick_weight, radix=M, degree_cap=R_CAP)
    # node 3 with 5 edges, consecutive timestamps
    src = [3] * 5 + [7] * 2
    dst = [4, 5, 6, 7, 8, 1, 2]
    ts = [10, 11, 12, 13, 14, 10, 11]
    state = _window_with(src, dst, ts, spec)
    idx, tables = state.index, state.tables
    a0 = int(idx.node_starts[3])
    deg = int(idx.node_starts[4]) - a0
    assert deg == 5

    n_u = deg * M
    u = (np.arange(n_u) + 0.5) / n_u
    W = n_u
    a = jnp.full((W,), a0, jnp.int32)
    b = jnp.full((W,), a0 + deg, jnp.int32)
    k = np.asarray(alias_pick(tables, a, a, b, jnp.asarray(u, jnp.float32),
                              radix=M, degree_cap=R_CAP))
    counts = np.bincount(k - a0, minlength=deg)[:deg]

    w = np.asarray(region_weights(idx, spec))[a0:a0 + deg]
    np.testing.assert_array_equal(counts, _lr_masses(w, deg, M))

    # oracle: same per-outcome law on the tabled branch
    weights = region_weights(idx, spec)
    k_ref, tabled = alias_pick_ref(weights, a, a, b,
                                   jnp.asarray(u, jnp.float32),
                                   radix=M, degree_cap=R_CAP)
    assert bool(jnp.all(tabled))
    ref_counts = np.bincount(np.asarray(k_ref) - a0, minlength=deg)[:deg]
    np.testing.assert_array_equal(counts, ref_counts)


def test_fallback_matches_oracle_per_u():
    """Suffix draws (c > a) use the exact float fallback: per-u identical
    to the dense oracle, not just law-identical."""
    spec = TableSpec(weight=_pick_weight, radix=M, degree_cap=R_CAP)
    src = [3] * 6
    dst = [4, 5, 6, 7, 8, 9]
    ts = [10, 11, 12, 13, 14, 15]
    state = _window_with(src, dst, ts, spec)
    idx, tables = state.index, state.tables
    a0 = int(idx.node_starts[3])

    rng = np.random.default_rng(0)
    u = jnp.asarray(rng.uniform(0, 1, 512), jnp.float32)
    W = u.shape[0]
    a = jnp.full((W,), a0, jnp.int32)
    c = jnp.full((W,), a0 + 2, jnp.int32)   # temporal cutoff dropped 2
    b = jnp.full((W,), a0 + 6, jnp.int32)
    k = alias_pick(tables, a, c, b, u, radix=M, degree_cap=R_CAP)
    weights = region_weights(idx, spec)
    k_ref, tabled = alias_pick_ref(weights, a, c, b, u,
                                   radix=M, degree_cap=R_CAP)
    assert not bool(jnp.any(tabled))
    np.testing.assert_array_equal(np.asarray(k), np.asarray(k_ref))

    # oversize region (> degree_cap) must also take the exact fallback
    k2, tab2 = alias_pick_ref(weights, a, a, b, u, radix=M, degree_cap=3)
    k3 = alias_pick(tables, a, a, b, u, radix=M, degree_cap=3)
    assert not bool(jnp.any(tab2))
    np.testing.assert_array_equal(np.asarray(k3), np.asarray(k2))


@pytest.mark.statistical
@pytest.mark.parametrize("bias", ["uniform", "linear", "exponential"])
def test_closed_form_reproduction(bias):
    """Table-bias with the three closed-form weight functions reproduces
    the corresponding sampler laws (chi-square, tests/test_samplers gate).

    Consecutive integer timestamps make weight_linear == the position
    weights (i+1) and weight_exponential ∝ e^i, i.e. exactly the laws of
    ``index_linear`` / ``index_exponential``.
    """
    deg = 6
    spec = TableSpec(weight=WEIGHT_FNS[bias], radix=4096, degree_cap=64)
    src = [2] * deg
    dst = list(range(3, 3 + deg))
    ts = list(range(100, 100 + deg))
    state = _window_with(src, dst, ts, spec)
    idx, tables = state.index, state.tables
    a0 = int(idx.node_starts[2])

    n = 60_000
    u = jax.random.uniform(jax.random.PRNGKey(9), (n,))
    a = jnp.full((n,), a0, jnp.int32)
    b = jnp.full((n,), a0 + deg, jnp.int32)
    k = np.asarray(alias_pick(tables, a, a, b, u, radix=4096, degree_cap=64))
    counts = np.bincount(k - a0, minlength=deg)[:deg]

    i = np.arange(deg, dtype=np.float64)
    law = {"uniform": np.full(deg, 1.0 / deg),
           "linear": (i + 1) / (i + 1).sum(),
           "exponential": np.exp(i - deg) / np.exp(i - deg).sum()}[bias]
    exp_counts = law * n
    mask = exp_counts > 5
    chi2 = np.sum((counts[mask] - exp_counts[mask]) ** 2 / exp_counts[mask])
    assert chi2 < chi2_crit(max(int(mask.sum()) - 1, 1)), chi2


# ---------------------------------------------------------------------------
# Incremental maintenance == from-scratch build
# ---------------------------------------------------------------------------


def _assert_tables_equal(inc: AliasTables, scr: AliasTables):
    np.testing.assert_array_equal(np.asarray(inc.thresh),
                                  np.asarray(scr.thresh))
    np.testing.assert_array_equal(np.asarray(inc.partner),
                                  np.asarray(scr.partner))
    np.testing.assert_array_equal(np.asarray(inc.ptab), np.asarray(scr.ptab))


def _stream_check(seed, n_batches, batch_n, ec, nc, duration, spec):
    state = init_window(ec, nc, duration, table=spec)
    rng = np.random.default_rng(seed)
    t = 0
    for _ in range(n_batches):
        n = int(rng.integers(1, batch_n + 1))
        src = rng.integers(0, nc, n).astype(np.int32)
        dst = rng.integers(0, nc, n).astype(np.int32)
        ts = np.sort(rng.integers(t, t + duration // 2, n)).astype(np.int32)
        t += int(rng.integers(1, duration // 2))
        state = ingest_nodonate(state, make_batch(src, dst, ts, capacity=ec),
                                nc, table=spec)
        _assert_tables_equal(state.tables, build_tables(state.index, spec))
    assert int(state.tables.rebuilt) > 0


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_incremental_equals_scratch_stream(seed):
    """Leaf-identical tables after every advance of a random edge stream
    (eviction + overflow churn included: tight capacities, small window)."""
    spec = TableSpec(weight=_pick_weight, radix=M, degree_cap=R_CAP,
                     chunk=16)
    _stream_check(seed, n_batches=6, batch_n=48, ec=128, nc=24,
                  duration=300, spec=spec)


@pytest.mark.slow
def test_incremental_equals_scratch_soak():
    """Capacity-scale soak: sustained eviction churn over a long stream."""
    spec = TableSpec(weight=weight_exponential, radix=256, degree_cap=32)
    _stream_check(7, n_batches=25, batch_n=700, ec=2048, nc=128,
                  duration=2000, spec=spec)


# ---------------------------------------------------------------------------
# Engine integration: bias='table' through generate_walks
# ---------------------------------------------------------------------------


def test_engine_table_bias_runs_and_matches_law():
    """bias='table' with weight_uniform draws every neighbor; the walks
    are valid and visit all of a hub's neighbors."""
    spec_cfg = SamplerConfig(mode="index", bias="table",
                             table_weight="uniform", table_radix=M,
                             table_degree_cap=R_CAP)
    spec = spec_from_sampler(spec_cfg)
    deg = 4
    src = [0] * deg + [1, 2, 3, 4]
    dst = [1, 2, 3, 4] + [0, 0, 0, 0]
    ts = [10, 10, 10, 10, 11, 11, 11, 11]
    state = _window_with(src, dst, ts, spec, ec=64, nc=8)
    wcfg = WalkConfig(num_walks=256, max_length=4, start_mode="all_nodes")
    for path in ("fullwalk", "grouped"):
        res = generate_walks(state.index, jax.random.PRNGKey(0), wcfg,
                             spec_cfg, SchedulerConfig(path=path),
                             tables=state.tables)
        nodes = np.asarray(res.nodes)
        lens = np.asarray(res.lengths)
        # every walk that starts on a node with edges makes progress
        # (start node of walk w is w % nc; nodes 5..7 are isolated)
        started = np.arange(len(lens)) % 8 < 5
        assert (lens[started] >= 2).all()
        # walks starting at the hub reach all four neighbors
        first_hops = nodes[nodes[:, 0] == 0, 1]
        assert set(first_hops.tolist()) >= {1, 2, 3, 4}
