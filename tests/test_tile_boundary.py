"""Tile-boundary coverage for the tiled path (regression for the
kernels/ops.py oversize predicate and the walk_step.py P(hi) one-hot read):
regions ending exactly at the staged window's edge (hi == 2·tile_edges),
empty neighborhoods, oversize fallback, and the weight-mode
linear/exponential biases — every lane cross-checked against the engine's
global sampling, and the whole tiled path cross-checked walk-for-walk
against fullwalk."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SamplerConfig, SchedulerConfig, WalkConfig
from repro.core.edge_store import store_from_arrays
from repro.core.samplers import pick_in_neighborhood
from repro.core.temporal_index import build_index, node_range, temporal_cutoff
from repro.core.walk_engine import generate_walks
from repro.kernels import ops as kops
from repro.kernels.walk_step import walk_step_tiled

E, N, TW, TE = 64, 8, 4, 8

# node -> out-degree; regions in the (src, ts)-sorted ns view:
#   node 0: [0, 16)  -> tile base 0,  hi = 16 == 2*TE (exact fit, head)
#   node 1: [16, 16) -> empty region
#   node 2: [16, 20) -> small in-tile region
#   node 3: [20, 40) -> span 20 > 2*TE   (oversize -> global fallback)
#   node 4: [40, 48) -> unused by the crafted lanes
#   node 5: [48, 64) -> tile base 48, hi = 16 == 2*TE (exact fit, tail of
#                       the store: region ends at E exactly)
_DEGS = {0: 16, 2: 4, 3: 20, 4: 8, 5: 16}


def _make_index():
    src, dst, ts = [], [], []
    for j, d in _DEGS.items():
        for i in range(d):
            src.append(j)
            dst.append((j + 1 + i) % N)
            ts.append(j * 100 + 2 * i)     # even ts; odd queries fall between
    store = store_from_arrays(src, dst, ts, edge_capacity=E, node_capacity=N)
    return build_index(store, N)


def _lanes():
    # one tile each: exact-fit head / empty+small / oversize / exact-fit
    # tail + empty region AT the end of the store (node 7: a == b == E,
    # i.e. lo == hi == 2*TE relative to the tile base)
    s_node = jnp.asarray([0, 0, 0, 0, 1, 1, 2, 2,
                          3, 3, 3, 3, 5, 5, 7, 7], jnp.int32)
    # per lane: before-all (full), mid, near-end, at/after-max (empty)
    s_time = jnp.asarray([-1, 15, 29, 30, 0, 1000, 199, 203,
                          299, 305, 321, 400, 499, 515, 0, 999], jnp.int32)
    rng = np.random.default_rng(7)
    u = rng.uniform(size=16).astype(np.float32)
    u[0], u[12] = 0.0, 0.999999           # inverse-CDF endpoints
    return s_node, s_time, jnp.asarray(u)


def _engine_pick(idx, scfg, nodes, times, u):
    a, b = node_range(idx, nodes)
    c = temporal_cutoff(idx, a, b, times)
    return pick_in_neighborhood(idx, scfg, c, b, u, nodes), b - c


MODES = [("weight", "exponential"), ("weight", "linear"),
         ("weight", "uniform"), ("index", "exponential"),
         ("index", "linear"), ("index", "uniform")]


@pytest.mark.parametrize("mode,bias", MODES)
def test_walk_step_boundary_lanes_match_engine(mode, bias):
    """ops.walk_step == global engine sampling on every live lane,
    including exact-fit (hi == 2·TE), empty, and oversize lanes."""
    idx = _make_index()
    s_node, s_time, u = _lanes()
    cfg = SchedulerConfig(path="tiled", tile_walks=TW, tile_edges=TE)
    scfg = SamplerConfig(bias=bias, mode=mode)
    k, n = kops.walk_step(idx, s_node, s_time, u, scfg, cfg)
    k_ref, n_ref = _engine_pick(idx, scfg, s_node, s_time, u)
    np.testing.assert_array_equal(np.asarray(n), np.asarray(n_ref))
    live = np.asarray(n_ref) > 0
    assert live.sum() >= 10          # the crafted lanes are mostly live
    np.testing.assert_array_equal(np.asarray(k)[live],
                                  np.asarray(k_ref)[live])


@pytest.mark.parametrize("mode,bias", [("weight", "exponential"),
                                       ("weight", "linear")])
def test_kernel_serves_exact_fit_regions(mode, bias):
    """The Pallas kernel itself (not the fallback) handles hi == 2·TE:
    feed it tile inputs containing exact-fit regions and compare against
    the engine. Before the P(hi) fix the weight-mode mass read back 0 for
    these lanes and the pick degraded to the uniform fallback."""
    idx = _make_index()
    s_node, s_time, u = _lanes()
    a, b = node_range(idx, s_node)
    T = 16 // TW
    a_t, b_t = a.reshape(T, TW), b.reshape(T, TW)
    base_blocks = jnp.clip(jnp.min(a_t, axis=1) // TE, 0, E // TE - 2)
    base = base_blocks * TE
    lo = (a_t - base[:, None]).reshape(16)
    hi = (b_t - base[:, None]).reshape(16)
    oversize = np.asarray((lo < 0) | (hi > 2 * TE))
    # the predicate regression: exact-fit lanes must be in-tile
    exact_fit = np.asarray(hi) == 2 * TE
    assert exact_fit.sum() == 8 and not oversize[exact_fit].any()

    lin = bias == "linear"
    pfx = idx.plin[:E] if lin else idx.pexp[:E]
    pfxs = idx.plin[1:E + 1] if lin else idx.pexp[1:E + 1]
    tbase = idx.node_tbase[jnp.clip(s_node, 0, N - 1)]
    k_loc, n, _, _ = walk_step_tiled(
        idx.ns_ts[:E], idx.ns_dst[:E], pfx, pfxs,
        base_blocks.astype(jnp.int32), s_time,
        jnp.clip(lo, 0, 2 * TE), jnp.clip(hi, 0, 2 * TE), u, tbase,
        mode=mode, bias=bias, tile_walks=TW, tile_edges=TE, interpret=True)
    tile_of_walk = jnp.arange(16, dtype=jnp.int32) // TW
    k_glob = base_blocks[tile_of_walk] * TE + k_loc

    scfg = SamplerConfig(bias=bias, mode=mode)
    k_ref, n_ref = _engine_pick(idx, scfg, s_node, s_time, u)
    ok = ~oversize & (np.asarray(n_ref) > 0)
    np.testing.assert_array_equal(np.asarray(n)[~oversize],
                                  np.asarray(n_ref)[~oversize])
    np.testing.assert_array_equal(np.asarray(k_glob)[ok],
                                  np.asarray(k_ref)[ok])


@pytest.mark.parametrize("bias", ["exponential", "linear"])
@pytest.mark.parametrize("regroup", ["lexsort", "bucket"])
def test_tiled_boundary_graph_equivalence(bias, regroup, key):
    """Whole-engine regression on the boundary graph: tiled == fullwalk
    byte-for-byte with tiny tiles, both regroup modes, weight biases."""
    idx = _make_index()
    wcfg = WalkConfig(num_walks=64, max_length=8, start_mode="nodes")
    scfg = SamplerConfig(bias=bias, mode="weight")
    ref = generate_walks(idx, key, wcfg, scfg,
                         SchedulerConfig(path="fullwalk"))
    got = generate_walks(idx, key, wcfg, scfg,
                         SchedulerConfig(path="tiled", regroup=regroup,
                                         tile_walks=8, tile_edges=TE))
    assert jnp.array_equal(ref.nodes, got.nodes)
    assert jnp.array_equal(ref.times, got.times)
    assert jnp.array_equal(ref.lengths, got.lengths)
