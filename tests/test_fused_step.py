"""Differential suite for the fused convergence-tiered walk-step kernel
(kernels/fused_step.py, DESIGN.md §14).

Three layers of evidence, all bitwise:

* kernel vs the ``kernels/ref.py`` oracle (``fused_step_ref``) — random
  graphs (hypothesis-driven), mixed per-lane bias codes, all tile shapes,
  and the crafted tile-boundary lanes from tests/test_tile_boundary.py
  (exact-fit ``hi == 2·TE`` regions, empty regions at the window edge,
  oversize tier-L lanes);
* whole-engine ``path="fused"`` vs the ``grouped``-``bucket`` reference
  path across {uniform, linear, exponential} × {index, weight} × both
  start modes (the acceptance criterion), plus lexsort flavor and
  per-lane heterogeneous-bias batches;
* degenerate shapes: empty window, single-walk (W == TW == 1),
  exact-tile-fit and oversize-degree lanes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.base import SamplerConfig, SchedulerConfig, WalkConfig
from repro.core.edge_store import store_from_arrays
from repro.core.temporal_index import build_index, node_range
from repro.core.walk_engine import LaneParams, generate_walk_lanes, generate_walks
from repro.data.synthetic import powerlaw_temporal_graph
from repro.kernels import ref as kref
from repro.kernels.fused_step import fused_walk_step

# the crafted boundary graph (exact-fit / empty / oversize lanes)
from test_tile_boundary import _lanes as _boundary_lanes
from test_tile_boundary import _make_index as _boundary_index
from test_tile_boundary import TE as BTE
from test_tile_boundary import TW as BTW

BIASES = ["uniform", "linear", "exponential"]


def _setup(E=2048, N=128, W=512, seed=2):
    g = powerlaw_temporal_graph(N, E - 100, seed=seed)
    store = store_from_arrays(g.src % N, g.dst % N, g.ts,
                              edge_capacity=E, node_capacity=N)
    idx = build_index(store, N)
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    nodes = jnp.sort(jax.random.randint(k1, (W,), 0, N))
    times = jax.random.randint(k2, (W,), 0, 10_000)
    u = jax.random.uniform(k3, (W,))
    code = jax.random.randint(k4, (W,), 0, 3)
    return idx, nodes, times, u, code


def _assert_matches_oracle(idx, nodes, times, u, code, mode, TW, TE):
    E = idx.edge_capacity
    a, b = node_range(idx, nodes)
    tbase = idx.node_tbase[jnp.clip(nodes, 0, idx.node_capacity - 1)]
    cfg = SchedulerConfig(path="fused", tile_walks=TW, tile_edges=TE)
    got = fused_walk_step(idx, nodes, times, code, u, mode, cfg,
                          interpret=True)
    want = kref.fused_step_ref(idx.ns_ts[:E], idx.ns_dst[:E], idx.pexp,
                               idx.plin, a, b, times, code, u, tbase,
                               mode=mode)
    for name, g_, w_ in zip(("k", "n", "dst", "ts"), got[:4], want):
        np.testing.assert_array_equal(np.asarray(g_), np.asarray(w_),
                                      err_msg=f"{mode}/{name}")
    return got


# ---------------------------------------------------------------------------
# Kernel vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["index", "weight"])
@pytest.mark.parametrize("TW,TE", [(128, 256), (64, 512), (256, 128)])
def test_fused_matches_oracle(mode, TW, TE):
    """Bit-identical to fused_step_ref with mixed per-lane bias codes;
    every tile shape populates both tiers (asserted)."""
    idx, nodes, times, u, code = _setup()
    got = _assert_matches_oracle(idx, nodes, times, u, code, mode, TW, TE)
    tiers = np.asarray(got.tiers)
    assert tiers[0] > 0 and tiers[1] > 0, tiers
    assert tiers[0] + tiers[1] == nodes.shape[0]


@settings(max_examples=15, deadline=None)
@given(st.integers(8, 160), st.integers(50, 1800), st.integers(0, 999),
       st.sampled_from([32, 64, 128]),
       st.sampled_from([128, 256, 1024]),
       st.sampled_from(["index", "weight"]))
def test_fused_matches_oracle_random_graphs(N, num_edges, seed, TW, TE,
                                            mode):
    """Property test: random power-law graphs, query times, bias codes."""
    E, W = 2048, 128
    g = powerlaw_temporal_graph(N, num_edges, seed=seed)
    store = store_from_arrays(g.src % N, g.dst % N, g.ts,
                              edge_capacity=E, node_capacity=N)
    idx = build_index(store, N)
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    nodes = jnp.sort(jax.random.randint(k1, (W,), 0, N))
    times = jax.random.randint(k2, (W,), -100, 10_000)
    u = jax.random.uniform(k3, (W,))
    code = jax.random.randint(k4, (W,), 0, 3)
    _assert_matches_oracle(idx, nodes, times, u, code, mode, TW, TE)


@pytest.mark.parametrize("mode", ["index", "weight"])
def test_fused_boundary_lanes(mode):
    """The crafted tile-boundary lanes: exact-fit (hi == 2·TE) head and
    tail regions, empty regions at the store's end, and the oversize
    node-3 lane (span 20 > 2·8) which the fused kernel serves in-kernel
    via the tier-L sweep — the seed path used a jnp fallback for it."""
    idx = _boundary_index()
    s_node, s_time, u = _boundary_lanes()
    code = jnp.asarray([i % 3 for i in range(16)], jnp.int32)
    got = _assert_matches_oracle(idx, s_node, s_time, u, code, mode,
                                 BTW, BTE)
    tiers = np.asarray(got.tiers)
    assert tiers[1] == 4          # the four node-3 oversize lanes
    assert tiers[2] >= 2          # their regions span >= 2 swept blocks


def test_fused_single_walk():
    """Degenerate W == TW == 1: one lane, one tile."""
    idx = _boundary_index()
    for node, time in ((3, 305), (0, 15), (7, 0)):
        got = _assert_matches_oracle(
            idx, jnp.asarray([node], jnp.int32), jnp.asarray([time], jnp.int32),
            jnp.asarray([0.7], jnp.float32), jnp.asarray([2], jnp.int32),
            "weight", 1, BTE)
        assert got.k.shape == (1,)


def test_fused_empty_window():
    """A window with zero live edges: every lane dead, all outputs zero."""
    store = store_from_arrays([], [], [], edge_capacity=512,
                              node_capacity=8)
    idx = build_index(store, 8)
    W = 8
    nodes = jnp.arange(W, dtype=jnp.int32) % 8
    times = jnp.zeros((W,), jnp.int32)
    u = jnp.full((W,), 0.5, jnp.float32)
    code = jnp.arange(W, dtype=jnp.int32) % 3
    for mode in ("index", "weight"):
        got = _assert_matches_oracle(idx, nodes, times, u, code, mode,
                                     4, 128)
        assert int(jnp.sum(got.n)) == 0
        assert int(jnp.sum(jnp.abs(got.dst))) == 0


# ---------------------------------------------------------------------------
# Whole-engine equivalence (the acceptance criterion)
# ---------------------------------------------------------------------------


def _assert_same_walks(ref, got):
    assert jnp.array_equal(ref.nodes, got.nodes)
    assert jnp.array_equal(ref.times, got.times)
    assert jnp.array_equal(ref.lengths, got.lengths)


@pytest.mark.parametrize("start_mode", ["nodes", "edges"])
@pytest.mark.parametrize("mode", ["index", "weight"])
@pytest.mark.parametrize("bias", BIASES)
def test_fused_path_matches_grouped_bucket(start_mode, mode, bias, key):
    """path='fused' emits bit-identical walks to the grouped-bucket
    reference for all three biases and both start modes."""
    idx, *_ = _setup(seed=7)
    wcfg = WalkConfig(num_walks=256, max_length=8, start_mode=start_mode)
    scfg = SamplerConfig(bias=bias, mode=mode)
    tiles = dict(tile_walks=64, tile_edges=256)
    ref = generate_walks(idx, key, wcfg, scfg,
                         SchedulerConfig(path="grouped", regroup="bucket",
                                         **tiles))
    got = generate_walks(idx, key, wcfg, scfg,
                         SchedulerConfig(path="fused", regroup="bucket",
                                         **tiles))
    _assert_same_walks(ref, got)


@pytest.mark.parametrize("bias", ["exponential", "linear"])
def test_fused_lexsort_boundary_graph_matches_fullwalk(bias, key):
    """Whole-engine regression on the boundary graph: fused == fullwalk
    byte-for-byte with tiny tiles, lexsort flavor, weight biases."""
    idx = _boundary_index()
    wcfg = WalkConfig(num_walks=64, max_length=8, start_mode="nodes")
    scfg = SamplerConfig(bias=bias, mode="weight")
    ref = generate_walks(idx, key, wcfg, scfg,
                         SchedulerConfig(path="fullwalk"))
    got = generate_walks(idx, key, wcfg, scfg,
                         SchedulerConfig(path="fused", regroup="lexsort",
                                         tile_walks=8, tile_edges=BTE))
    _assert_same_walks(ref, got)


def test_fused_lane_batch_matches_grouped(key):
    """Heterogeneous per-lane bias codes through generate_walk_lanes:
    the fused kernel's in-kernel code dispatch == the grouped path's
    jnp per-lane dispatch, including per-lane max_len masking."""
    idx, *_ = _setup(seed=11)
    W = 128
    wcfg = WalkConfig(num_walks=W, max_length=6, start_mode="nodes")
    scfg = SamplerConfig(mode="index")
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    lanes = LaneParams(
        start_node=jax.random.randint(k1, (W,), 0, idx.node_capacity),
        bias=jnp.arange(W, dtype=jnp.int32) % 3,
        start_bias=jnp.zeros((W,), jnp.int32),
        max_len=2 + jnp.arange(W, dtype=jnp.int32) % 5,
        rid=jnp.arange(W, dtype=jnp.int32) // 16,
        wid=jnp.arange(W, dtype=jnp.int32) % 16,
        active=jnp.arange(W) < W - 8,
    )
    tiles = dict(tile_walks=32, tile_edges=256)
    ref = generate_walk_lanes(idx, key, lanes, wcfg, scfg,
                              SchedulerConfig(path="grouped", **tiles))
    got = generate_walk_lanes(idx, key, lanes, wcfg, scfg,
                              SchedulerConfig(path="fused", **tiles))
    _assert_same_walks(ref, got)


def test_fused_rejects_node2vec(key):
    idx, *_ = _setup(seed=7)
    wcfg = WalkConfig(num_walks=64, max_length=4, start_mode="nodes")
    scfg = SamplerConfig(mode="index", node2vec_p=0.5)
    with pytest.raises(ValueError, match="fused"):
        generate_walks(idx, key, wcfg, scfg, SchedulerConfig(path="fused"))


# ---------------------------------------------------------------------------
# interpret-default unification (kernels/runtime.py)
# ---------------------------------------------------------------------------


def test_interpret_defaults_resolve_by_backend(monkeypatch):
    """All kernel entry points default interpret=None -> auto-detect:
    compiled when a TPU backend is present, interpret mode elsewhere."""
    import inspect

    from repro.kernels import runtime
    from repro.kernels.ops import walk_step
    from repro.kernels.walk_step import walk_step_tiled
    from repro.kernels.weight_prefix import weight_prefix

    for fn in (walk_step, walk_step_tiled, weight_prefix, fused_walk_step):
        sig = inspect.signature(fn)
        assert sig.parameters["interpret"].default is None, fn

    assert runtime.resolve_interpret(True) is True
    assert runtime.resolve_interpret(False) is False
    # default backend in this environment is not TPU -> interpret mode
    assert runtime.resolve_interpret(None) is True
    # with a TPU backend present the default resolves to compiled mode
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert runtime.on_tpu()
    assert runtime.resolve_interpret(None) is False
    assert runtime.resolve_interpret(True) is True   # explicit override wins


# ---------------------------------------------------------------------------
# dispatch_stats fused tiers
# ---------------------------------------------------------------------------


def test_dispatch_stats_reports_fused_tiers(key):
    """The new tier stats partition alive lanes and count sweep blocks."""
    from repro.core import scheduler as sched

    idx = _boundary_index()
    wcfg = WalkConfig(num_walks=64, max_length=4, start_mode="nodes")
    res = generate_walks(idx, key, wcfg, SamplerConfig(),
                         SchedulerConfig(path="fused", tile_walks=8,
                                         tile_edges=BTE),
                         collect_stats=True)
    st_ = np.asarray(res.stats)
    alive = st_[:, sched.STAT_ALIVE]
    small = st_[:, sched.STAT_FUSED_SMALL]
    big = st_[:, sched.STAT_FUSED_BIG]
    blocks = st_[:, sched.STAT_FUSED_BLOCKS]
    np.testing.assert_array_equal(small + big, alive)
    # node 3 (degree 20 > 2·TE = 16) carries walks -> tier-L lanes appear
    assert big.sum() > 0
    assert (blocks >= 2 * big).all()   # span > 2·TE models >= 3 blocks
