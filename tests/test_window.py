"""Sliding-window semantics (paper §2.6): eviction invariant, late drops,
overflow behavior, bounded memory."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.base import SamplerConfig, SchedulerConfig, WalkConfig
from repro.core.edge_store import make_batch
from repro.core.validation import validate_walks
from repro.core.walk_engine import generate_walks
from repro.core.window import ingest, init_window
from repro.data.synthetic import chronological_batches, powerlaw_temporal_graph


def test_window_eviction_invariant():
    g = powerlaw_temporal_graph(100, 2000, seed=5)
    st_ = init_window(edge_capacity=2048, node_capacity=128, window=2500)
    for bs, bd, bt in chronological_batches(g, 8):
        st_ = ingest(st_, make_batch(bs, bd, bt, capacity=512), 128)
        n = int(st_.index.store.num_edges)
        if n:
            ts = np.asarray(st_.index.store.ts)[:n]
            assert ts.min() >= int(st_.t_now) - 2500


def test_window_keeps_exact_set():
    """After the full replay, the store holds exactly the edges within Δ of
    the final time."""
    g = powerlaw_temporal_graph(50, 500, seed=6)
    delta = 3000
    st_ = init_window(edge_capacity=1024, node_capacity=64, window=delta)
    for bs, bd, bt in chronological_batches(g, 5):
        st_ = ingest(st_, make_batch(bs, bd, bt, capacity=256), 64)
    t_now = int(st_.t_now)
    expected = sorted(
        (int(s), int(d), int(t))
        for s, d, t in zip(g.src, g.dst, g.ts) if t >= t_now - delta)
    n = int(st_.index.store.num_edges)
    got = sorted(zip(np.asarray(st_.index.store.src)[:n].tolist(),
                     np.asarray(st_.index.store.dst)[:n].tolist(),
                     np.asarray(st_.index.store.ts)[:n].tolist()))
    assert got == expected
    assert int(st_.ingested) == 500


def test_late_edges_dropped():
    st_ = init_window(edge_capacity=64, node_capacity=8, window=10)
    st_ = ingest(st_, make_batch([0], [1], [100], capacity=8), 8)
    # t=50 is older than 100-10=90: dropped without retraction; t=95 kept
    st_ = ingest(st_, make_batch([1, 2], [2, 3], [50, 95], capacity=8), 8)
    assert int(st_.late_drops) == 1
    assert int(st_.index.store.num_edges) == 2


def test_overflow_keeps_newest():
    st_ = init_window(edge_capacity=8, node_capacity=8, window=10_000)
    ts = np.arange(12, dtype=np.int32)
    st_ = ingest(st_, make_batch(np.zeros(12, np.int32),
                                 np.ones(12, np.int32), ts, capacity=16), 8)
    assert int(st_.overflow_drops) == 4
    kept = np.asarray(st_.index.store.ts)[:8]
    assert kept.tolist() == list(range(4, 12))


def test_all_late_batch_leaves_window_byte_identical():
    """Edge case: a batch entirely older than t_now − Δ must (a) leave the
    window byte-identical — store, dual index, t_now — and (b) be fully
    counted as late, with no overflow charged."""
    st_ = init_window(edge_capacity=64, node_capacity=8, window=10)
    st_ = ingest(st_, make_batch([0, 1, 2], [1, 2, 3], [100, 101, 102],
                                 capacity=8), 8)
    before_index = [np.asarray(x).copy()
                    for x in jax.tree.leaves(st_.index)]
    t_before = int(st_.t_now)
    ingested_before = int(st_.ingested)
    overflow_before = int(st_.overflow_drops)
    # cutoff is 102 - 10 = 92: every edge below is "too late"
    st_ = ingest(st_, make_batch([3, 4, 5, 6], [4, 5, 6, 7], [5, 40, 88, 91],
                                 capacity=8), 8)
    after_index = jax.tree.leaves(st_.index)
    assert len(before_index) == len(after_index)
    for got, want in zip(after_index, before_index):
        assert np.array_equal(np.asarray(got), want)
    assert int(st_.t_now) == t_before                 # time does not move
    assert int(st_.late_drops) == 4                   # fully counted late
    assert int(st_.ingested) == ingested_before + 4   # still counted seen
    assert int(st_.overflow_drops) == overflow_before


def test_memory_constant_across_stream():
    """Paper Fig. 11b: device bytes flat across batches."""
    from repro.core.edge_store import store_nbytes
    g = powerlaw_temporal_graph(100, 2000, seed=7)
    st_ = init_window(edge_capacity=1024, node_capacity=128, window=1500)
    sizes = []
    for bs, bd, bt in chronological_batches(g, 10):
        st_ = ingest(st_, make_batch(bs, bd, bt, capacity=256), 128)
        sizes.append(store_nbytes(st_.index.store))
    assert len(set(sizes)) == 1   # exactly constant: static shapes


def test_walks_on_windowed_index_valid(key=jax.random.PRNGKey(0)):
    g = powerlaw_temporal_graph(100, 2000, seed=8)
    st_ = init_window(edge_capacity=2048, node_capacity=128, window=4000)
    for bs, bd, bt in chronological_batches(g, 4):
        st_ = ingest(st_, make_batch(bs, bd, bt, capacity=512), 128)
        res = generate_walks(st_.index, key,
                             WalkConfig(num_walks=256, max_length=8,
                                        start_mode="nodes"),
                             SamplerConfig(), SchedulerConfig())
        rep = validate_walks(st_.index, res)
        assert float(rep.hop_valid_frac) == 1.0


@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 7), st.integers(0, 7),
                          st.integers(0, 1000)),
                min_size=1, max_size=60),
       st.integers(1, 500))
def test_window_matches_bruteforce(edges, delta):
    """Property: streaming ingestion == brute-force window filter."""
    edges = sorted(edges, key=lambda e: e[2])
    n = len(edges)
    st_ = init_window(edge_capacity=128, node_capacity=8, window=delta)
    third = max(n // 3, 1)
    t_now = -1
    consumed = []
    for i in range(0, n, third):
        chunk = edges[i:i + third]
        consumed += chunk
        bs = [e[0] for e in chunk]
        bd = [e[1] for e in chunk]
        bt = [e[2] for e in chunk]
        st_ = ingest(st_, make_batch(bs, bd, bt, capacity=64), 8)
        t_now = max(t_now, max(bt))
        expected = sorted((s, d, t) for s, d, t in consumed
                          if t >= t_now - delta)
        m = int(st_.index.store.num_edges)
        got = sorted(zip(np.asarray(st_.index.store.src)[:m].tolist(),
                         np.asarray(st_.index.store.dst)[:m].tolist(),
                         np.asarray(st_.index.store.ts)[:m].tolist()))
        assert got == expected
