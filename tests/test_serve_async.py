"""Async continuous-batching serving runtime (DESIGN.md §18): overlapped
dispatch bit-identity, snapshot version pinning across publish, linger
late-admission, deadline eviction, EDF admission, in-flight ring bounds."""
import time

import numpy as np
import pytest

from repro.configs.base import (
    EngineConfig,
    SamplerConfig,
    SchedulerConfig,
    ServeConfig,
    WindowConfig,
)
from repro.data.synthetic import chronological_batches, powerlaw_temporal_graph
from repro.obs.registry import DropCounters, MetricsRegistry
from repro.serve import WalkQuery, WalkService

NC = 128
BIASES = ("uniform", "linear", "exponential")


def _engine_cfg():
    return EngineConfig(
        window=WindowConfig(duration=4000, edge_capacity=4096,
                            node_capacity=NC),
        sampler=SamplerConfig(mode="index"),
        scheduler=SchedulerConfig(path="grouped"))


def _serve_cfg(**kw):
    kw.setdefault("lane_buckets", (8, 16, 64))
    kw.setdefault("length_buckets", (4, 8))
    return ServeConfig(**kw)


def _stream():
    g = powerlaw_temporal_graph(100, 3000, seed=11)
    return list(chronological_batches(g, 3))


def _service(batches=None, **serve_kw):
    svc = WalkService(_engine_cfg(), _serve_cfg(**serve_kw))
    for bs, bd, bt in (batches if batches is not None else _stream()):
        svc.ingest(bs, bd, bt)
    return svc


def _queries(n=9, seed0=500):
    qs = []
    for i in range(n):
        if i % 3 == 2:
            qs.append(WalkQuery(num_walks=2 + i % 3, start_mode="edges",
                                bias=BIASES[i % 3],
                                start_bias=BIASES[(i + 1) % 3],
                                max_length=3 + i % 5, seed=seed0 + i))
        else:
            qs.append(WalkQuery(start_nodes=tuple((5 * i + j) % NC
                                                  for j in range(1 + i % 4)),
                                bias=BIASES[i % 3], max_length=3 + i % 5,
                                seed=seed0 + i))
    return qs


def _run_async(svc, queries):
    """Drive the tick/pump event loop to completion; returns tickets."""
    tickets = [svc.submit(q, strict=True) for q in queries]
    spins = 0
    while svc.pending_count or svc.inflight_count:
        svc.tick()
        spins += 1
        if spins > 10_000:            # tick never blocks; bound the spin
            svc.pump(block=True)
    return tickets


def test_async_bit_identical_to_synchronous_baseline():
    """Acceptance: the overlapped tick/pump path returns results
    bit-identical to the historical blocking step() loop (max_inflight=1,
    FIFO) over the same window and queries."""
    batches = _stream()
    svc_sync = _service(batches, max_inflight=1)
    svc_async = _service(batches, max_inflight=4)
    queries = _queries(12)

    t_sync = [svc_sync.submit(q, strict=True) for q in queries]
    while svc_sync.pending_count:
        svc_sync.step()
    t_async = _run_async(svc_async, queries)

    assert svc_async.stats.completed == len(queries)
    for ts_, ta, q in zip(t_sync, t_async, queries):
        rs, ra = svc_sync.poll(ts_), svc_async.poll(ta)
        assert rs is not None and ra is not None
        assert np.array_equal(rs.nodes, ra.nodes), q
        assert np.array_equal(rs.times, ra.times), q
        assert np.array_equal(rs.lengths, ra.lengths), q
        assert rs.snapshot_version == ra.snapshot_version


def test_overlapped_ingest_pins_snapshot_version():
    """Batches launched before publish() compute against the pinned old
    window even when the swap lands while they are in flight — results
    report the pinned version and are bit-identical to a reference
    service that never saw the new batch."""
    batches = _stream()
    svc = _service(batches[:-1], max_inflight=4)
    ref = _service(batches[:-1])
    queries = _queries(6, seed0=900)

    svc.begin_ingest(*batches[-1])        # back buffer building
    v0 = svc.snapshots.version
    tickets = [svc.submit(q, strict=True) for q in queries]
    svc.tick()                            # launch against the pinned v0
    assert svc.inflight_count >= 1
    svc.publish()                         # swap while batches in flight
    assert svc.snapshots.version == v0 + 1
    while svc.pending_count or svc.inflight_count:
        svc.tick()
        svc.pump(block=True)

    for t, q in zip(tickets, queries):
        r = svc.poll(t)
        assert r is not None
        assert r.snapshot_version == v0
        sn, st_, sl = ref.run_query_solo(q)
        assert np.array_equal(r.nodes, sn), q
        assert np.array_equal(r.times, st_), q
        assert np.array_equal(r.lengths, sl), q


@pytest.mark.parametrize("edges_mode", [False, True])
def test_linger_admits_late_queries_bit_identically(edges_mode):
    """Continuous batching: a partially-filled batch lingers up to
    linger_s, late same-group arrivals join it, and every admitted query
    — across all three biases — stays bit-identical to its solo run."""
    svc = _service(max_inflight=4, linger_s=30.0)
    if edges_mode:
        mk = lambda i: WalkQuery(num_walks=2, start_mode="edges",
                                 bias=BIASES[i], max_length=4, seed=700 + i)
    else:
        mk = lambda i: WalkQuery(start_nodes=(10 * i + 1, 10 * i + 2),
                                 bias=BIASES[i], max_length=4, seed=700 + i)
    b0 = svc.stats.batches

    tickets = [svc.submit(mk(0), strict=True)]
    t_head = svc._pending[0].arrival
    svc.tick(now=t_head + 0.001)          # under the linger deadline
    assert svc.inflight_count == 0        # batch can grow: keeps lingering
    tickets.append(svc.submit(mk(1), strict=True))
    svc.tick(now=t_head + 0.002)
    assert svc.inflight_count == 0
    tickets.append(svc.submit(mk(2), strict=True))
    svc.tick(now=t_head + 31.0)           # linger expired: seal + launch
    assert svc.inflight_count == 1 and svc.pending_count == 0
    svc.pump(block=True)

    assert svc.stats.batches == b0 + 1    # ONE coalesced dispatch
    for t, i in zip(tickets, range(3)):
        r = svc.poll(t)
        assert r is not None
        sn, st_, sl = svc.run_query_solo(mk(i))
        assert np.array_equal(r.nodes, sn)
        assert np.array_equal(r.times, st_)
        assert np.array_equal(r.lengths, sl)


def test_linger_seals_when_batch_cannot_grow():
    """A batch that exactly fills the lane budget (or hits a non-fitting
    same-group query) seals immediately — lingering longer could not
    admit anything else."""
    svc = _service(lane_buckets=(4,), linger_s=30.0)
    t_ = svc.submit(WalkQuery(start_nodes=(1, 2, 3, 4), max_length=4,
                              seed=1), strict=True)
    svc.tick(now=svc._pending[0].arrival + 0.001)
    assert svc.inflight_count == 1        # full batch: no linger
    svc.pump(block=True)
    assert svc.poll(t_) is not None


def test_deadline_eviction_accounting():
    """Queued queries past deadline_s are evicted — counted in stats AND
    the canonical drop taxonomy — and never complete; deadline-free
    traffic in the same queue is untouched."""
    reg = MetricsRegistry()
    svc = WalkService(_engine_cfg(), _serve_cfg(), registry=reg)
    g = powerlaw_temporal_graph(100, 500, seed=2)
    svc.ingest(g.src, g.dst, g.ts)
    t_dead = svc.submit(WalkQuery(start_nodes=(1,), max_length=4, seed=1,
                                  deadline_s=1e-4), strict=True)
    t_live = svc.submit(WalkQuery(start_nodes=(2,), max_length=4, seed=2),
                        strict=True)
    time.sleep(0.01)
    drained = svc.drain()
    assert svc.stats.dropped_deadline == 1
    assert DropCounters.from_registry(reg).deadline_expired == 1
    assert {r.ticket for r in drained} == {t_live}   # the dead one never ran
    assert svc.poll(t_dead) is None
    assert svc.stats.completed == 1
    # a batch already sealed+launched always completes: deadlines gate
    # admission, not in-flight device work
    t3 = svc.submit(WalkQuery(start_nodes=(3,), max_length=4, seed=3,
                              deadline_s=1e-4), strict=True)
    svc.tick(now=svc._pending[0].arrival)   # launch before expiry
    time.sleep(0.01)
    svc.pump(block=True)
    assert svc.poll(t3) is not None
    assert svc.stats.dropped_deadline == 1


def test_deadline_validation():
    with pytest.raises(ValueError, match="deadline_s"):
        WalkQuery(start_nodes=(1,), deadline_s=0.0)
    with pytest.raises(ValueError, match="deadline_s"):
        WalkQuery(start_nodes=(1,), deadline_s=-1.0)
    assert WalkQuery(start_nodes=(1,), deadline_s=0.5).deadline_s == 0.5


def test_edf_admission_orders_by_deadline():
    """admission="edf": the queue is served earliest-deadline-first;
    deadline-free queries sort last and keep FIFO order among
    themselves."""
    svc = _service(admission="edf", lane_buckets=(2,))
    qs = [WalkQuery(start_nodes=(1, 2), max_length=4, seed=1,
                    deadline_s=60.0),
          WalkQuery(start_nodes=(3, 4), max_length=4, seed=2,
                    deadline_s=5.0),
          WalkQuery(start_nodes=(5, 6), max_length=4, seed=3),
          WalkQuery(start_nodes=(7, 8), max_length=4, seed=4)]
    tickets = [svc.submit(q, strict=True) for q in qs]
    order = []
    while svc.pending_count:
        _, take, _ = svc._take_batch()
        order.extend(e.ticket for e in take)
    # earliest deadline first; the two deadline-free stay FIFO at the back
    assert order == [tickets[1], tickets[0], tickets[2], tickets[3]]


def test_inflight_ring_bounded_by_max_inflight():
    """tick() never launches past the configured ring depth; pump drains
    it and the remaining queue launches on later ticks."""
    svc = _service(max_inflight=2, lane_buckets=(2,))
    qs = [WalkQuery(start_nodes=(2 * i, 2 * i + 1), max_length=4, seed=i)
          for i in range(6)]
    tickets = [svc.submit(q, strict=True) for q in qs]
    svc.tick()
    assert svc.inflight_count <= 2
    assert svc.pending_count >= len(qs) - 2
    while svc.pending_count or svc.inflight_count:
        assert svc.inflight_count <= 2
        svc.tick()
    svc.pump(block=True)
    assert all(svc.poll(t) is not None for t in tickets)


def test_step_harvests_prior_async_launches():
    """step() is a full sync point: batches launched by earlier tick()
    calls are harvested before it returns, so mixing the async and
    synchronous entry points never strands results."""
    svc = _service(max_inflight=4, lane_buckets=(2,))
    t1 = svc.submit(WalkQuery(start_nodes=(1, 2), max_length=4, seed=1),
                    strict=True)
    svc.tick()
    assert svc.inflight_count == 1
    t2 = svc.submit(WalkQuery(start_nodes=(3, 4), max_length=4, seed=2),
                    strict=True)
    served = svc.step()
    assert served == 1
    assert svc.inflight_count == 0
    assert svc.poll(t1) is not None and svc.poll(t2) is not None
    # step() with an empty queue still drains stragglers
    t3 = svc.submit(WalkQuery(start_nodes=(5, 6), max_length=4, seed=3),
                    strict=True)
    svc.tick()
    assert svc.step() == 0
    assert svc.poll(t3) is not None


def test_serve_config_validation():
    with pytest.raises(ValueError, match="max_inflight"):
        WalkService(_engine_cfg(), _serve_cfg(max_inflight=0))
    with pytest.raises(ValueError, match="linger_s"):
        WalkService(_engine_cfg(), _serve_cfg(linger_s=-0.5))
    with pytest.raises(ValueError, match="admission"):
        WalkService(_engine_cfg(), _serve_cfg(admission="lifo"))


def test_async_drain_scoped_with_inflight():
    """drain() under the async runtime: it harvests in-flight batches
    launched before the drain, yet still returns only what IT completed
    and leaves earlier poll-buffer results alone."""
    svc = _service(max_inflight=4, lane_buckets=(2,))
    ta = svc.submit(WalkQuery(start_nodes=(1, 2), max_length=4, seed=1),
                    strict=True)
    svc.step()                              # ta already in the poll buffer
    tb = svc.submit(WalkQuery(start_nodes=(3, 4), max_length=4, seed=2),
                    strict=True)
    svc.tick()                              # tb in flight
    tc = svc.submit(WalkQuery(start_nodes=(5, 6), max_length=4, seed=3),
                    strict=True)            # tc still queued
    drained = svc.drain()
    assert {r.ticket for r in drained} == {tb, tc}
    assert svc.poll(ta) is not None
