"""Placement layer invariants + 1-shard reshard/checkpoint fast lane.

Every policy must (a) map every node in [0, node_capacity) to exactly one
shard, (b) answer identically on host and device, (c) round-trip through
its checkpoint manifest. The 1-shard engine tests exercise the full
reshard / checkpoint / supervisor machinery on the single real CPU device;
the multi-device bit-identity and elastic-restore suites live in
tests/test_reshard_checkpoint.py (8-device subprocess, slow lane).
"""
import dataclasses
import os
import tempfile

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.base import EngineConfig, WalkConfig
from repro.distributed.placement import (
    HashPlacement,
    Placement,
    RangePlacement,
    SkewPlacement,
    make_placement,
    placement_from_manifest,
)

NC = 128


def _policies(num_shards, nc=NC):
    rp = RangePlacement(num_shards=num_shards, node_capacity=nc)
    hp = HashPlacement.make(num_shards, nc, num_buckets=64)
    sp = SkewPlacement(num_shards=num_shards, node_capacity=nc, base=rp,
                       hot_nodes=(0, 7, 31), hot_owners=(num_shards - 1,) * 3)
    return {"range": rp, "hash": hp, "skew": sp}


# ---------------------------------------------------------------------------
# pure-placement invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("num_shards", [1, 2, 8])
@pytest.mark.parametrize("kind", ["range", "hash", "skew"])
def test_every_node_exactly_one_shard(num_shards, kind):
    p = _policies(num_shards)[kind]
    v = np.arange(NC, dtype=np.int32)
    own = p.owner_np(v)
    assert own.shape == (NC,)
    assert ((own >= 0) & (own < num_shards)).all()
    # shard_nodes is the exact inverse: a partition of [0, NC)
    parts = [p.shard_nodes(d) for d in range(num_shards)]
    joined = np.concatenate(parts) if parts else np.empty(0, np.int32)
    assert sorted(joined.tolist()) == list(range(NC))
    for d, part in enumerate(parts):
        assert (p.owner_np(part) == d).all()


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([1, 2, 8]))
def test_host_device_owner_agree(seed, num_shards):
    """owner() and owner_np() are one rule in two residencies — bit-equal
    for every policy on arbitrary node-id vectors."""
    rng = np.random.default_rng(seed)
    v = rng.integers(0, NC, size=64).astype(np.int32)
    for p in _policies(num_shards).values():
        host = p.owner_np(v)
        dev = np.asarray(jax.jit(p.owner, static_argnums=())(v))
        np.testing.assert_array_equal(host, dev)


def test_range_matches_legacy_formula():
    from repro.core.distributed import owner_range_size
    for d in (1, 2, 3, 8):
        p = RangePlacement(num_shards=d, node_capacity=NC)
        rs = owner_range_size(NC, d)
        v = np.arange(NC, dtype=np.int32)
        np.testing.assert_array_equal(
            p.owner_np(v), np.clip(v // rs, 0, d - 1))


def test_manifest_roundtrip():
    for p in _policies(4).values():
        q = placement_from_manifest(p.describe())
        assert q == p
    # JSON round-trip (the checkpoint path serializes the manifest)
    import json
    sp = _policies(4)["skew"]
    q = placement_from_manifest(json.loads(json.dumps(sp.describe())))
    assert q == sp


def test_make_placement_and_validation():
    assert isinstance(make_placement("range", 2, NC), RangePlacement)
    assert isinstance(make_placement("hash", 2, NC), HashPlacement)
    sp = make_placement("skew", 2, NC)
    assert isinstance(sp, SkewPlacement) and sp.hot_nodes == ()
    with pytest.raises(ValueError, match="unknown placement"):
        make_placement("modulo", 2, NC)
    with pytest.raises(ValueError, match="power of two"):
        HashPlacement(num_shards=2, node_capacity=NC, table=(0, 1, 0))
    with pytest.raises(ValueError, match="out of shard range"):
        HashPlacement(num_shards=2, node_capacity=NC, table=(0, 3))
    rp = RangePlacement(num_shards=2, node_capacity=NC)
    with pytest.raises(ValueError, match="duplicate"):
        SkewPlacement(num_shards=2, node_capacity=NC, base=rp,
                      hot_nodes=(3, 3), hot_owners=(0, 1))
    with pytest.raises(ValueError, match="length mismatch"):
        SkewPlacement(num_shards=2, node_capacity=NC, base=rp,
                      hot_nodes=(3,), hot_owners=())


def test_skew_empty_equals_base():
    rp = RangePlacement(num_shards=4, node_capacity=NC)
    sp = SkewPlacement(num_shards=4, node_capacity=NC, base=rp)
    v = np.arange(NC, dtype=np.int32)
    np.testing.assert_array_equal(sp.owner_np(v), rp.owner_np(v))


def test_skew_from_loads_lpt():
    """Top-k hubs peel off the base assignment onto the least-loaded
    shards; zero-load nodes never become overrides."""
    rp = RangePlacement(num_shards=4, node_capacity=NC)
    loads = np.zeros(NC)
    loads[0] = 100.0          # hub on shard 0
    loads[1] = 90.0           # second hub, also shard 0
    loads[40] = 10.0          # light node on shard 2 (range_size=32)
    sp = SkewPlacement.from_loads(rp, loads, k=3)
    assert sp.hot_nodes == (0, 1, 40)
    # heaviest hub goes to an empty shard, second hub to a different one
    assert sp.hot_owners[0] != sp.hot_owners[1]
    own = sp.owner_np(np.arange(NC, dtype=np.int32))
    shard_load = np.zeros(4)
    np.add.at(shard_load, own, loads)
    base_load = np.zeros(4)
    np.add.at(base_load, rp.owner_np(np.arange(NC, dtype=np.int32)), loads)
    assert shard_load.max() < base_load.max()
    # re-deriving from a skew base unwraps instead of stacking
    sp2 = SkewPlacement.from_loads(sp, loads, k=2)
    assert isinstance(sp2.base, RangePlacement)
    with pytest.raises(ValueError, match="entries"):
        SkewPlacement.from_loads(rp, loads[:-1], k=2)


def test_skew_from_loads_skips_zero_load():
    rp = RangePlacement(num_shards=2, node_capacity=NC)
    loads = np.zeros(NC)
    loads[5] = 1.0
    sp = SkewPlacement.from_loads(rp, loads, k=8)
    assert sp.hot_nodes == (5,)


# ---------------------------------------------------------------------------
# 1-shard engine: placement plumbing, reshard, checkpoint, supervisor
# ---------------------------------------------------------------------------


def _small_cfg():
    cfg = EngineConfig()
    return dataclasses.replace(
        cfg,
        window=dataclasses.replace(cfg.window, node_capacity=NC,
                                   edge_capacity=256, duration=50.0),
        shard=dataclasses.replace(cfg.shard, num_shards=1,
                                  edge_capacity_per_shard=256))


def _batches(n_batches=6, seed=0):
    from repro.data.synthetic import powerlaw_temporal_graph
    g = powerlaw_temporal_graph(NC, 300, t_max=100.0, seed=seed)
    order = np.argsort(g.ts, kind="stable")
    src, dst, ts = g.src[order], g.dst[order], g.ts[order]
    bs = len(src) // n_batches
    return [(src[i * bs:(i + 1) * bs], dst[i * bs:(i + 1) * bs],
             ts[i * bs:(i + 1) * bs]) for i in range(n_batches)], bs


@pytest.fixture(scope="module")
def engine_run():
    from repro.distributed.streaming_shard import DistributedStreamingEngine
    cfg = _small_cfg()
    wcfg = WalkConfig(num_walks=16, max_length=4, start_mode="all_nodes")
    batches, bs = _batches()
    eng = DistributedStreamingEngine(cfg, batch_capacity=bs)
    eng.replay_device(batches, wcfg)
    return cfg, wcfg, batches, bs, eng


def test_one_shard_reshard_identity(engine_run):
    """At D=1 every placement owns everything, so reshard is a pure
    re-sort of the resident edges by timestamp: the ts column (and the
    paired src/dst) must be byte-preserved, and the device reshard must
    agree leaf-for-leaf with the host mirror."""
    from repro.distributed.streaming_shard import reshard, reshard_host
    cfg, wcfg, batches, bs, eng = engine_run
    hp = HashPlacement.make(1, NC)
    rp = RangePlacement(num_shards=1, node_capacity=NC)
    dev_state, _ = reshard(eng.state, rp, hp)
    host_state = reshard_host(eng.state, hp)
    for a, b in zip(jax.tree_util.tree_leaves(dev_state),
                    jax.tree_util.tree_leaves(host_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # resident edge multiset preserved (no drops possible at D=1)
    def edges(state):
        n = int(np.asarray(state.window.index.num_edges)[0])
        s = np.asarray(state.window.index.store.src)[0, :n]
        d = np.asarray(state.window.index.store.dst)[0, :n]
        t = np.asarray(state.window.index.store.ts)[0, :n]
        return sorted(zip(s.tolist(), d.tolist(), t.tolist()))
    assert edges(dev_state) == edges(eng.state)
    assert int(np.asarray(dev_state.exchange_drops).sum()) == \
        int(np.asarray(eng.state.exchange_drops).sum())


def test_engine_rebalance(engine_run):
    from repro.distributed.streaming_shard import DistributedStreamingEngine
    cfg, wcfg, batches, bs, _ = engine_run
    eng = DistributedStreamingEngine(cfg, batch_capacity=bs)
    eng.replay_device(batches[:3], wcfg)
    loads = eng.node_loads()
    assert loads.shape == (NC,)
    assert loads.sum() == int(np.asarray(eng.state.window.index.num_edges
                                         ).sum())
    newp = eng.rebalance(k=4)
    assert isinstance(newp, SkewPlacement)
    assert eng.placement is newp
    # engine keeps replaying after the live reshard
    stats, walks, _ = eng.replay_device(batches[3:], wcfg)
    assert walks is not None


def test_checkpoint_roundtrip_exact(engine_run):
    """Save + restore with no target change is byte-identical, including
    the walk key; the placement manifest round-trips."""
    from repro.train import checkpoint as ckpt
    cfg, wcfg, batches, bs, eng = engine_run
    with tempfile.TemporaryDirectory() as d:
        ckpt.save_sharded_window(d, eng.state, eng.placement, step=6,
                                 walk_key=eng.key)
        meta = ckpt.load_placement_manifest(d)
        assert meta["num_shards"] == 1
        assert meta["node_capacity"] == NC
        assert meta["step"] == 6 and meta["has_walk_key"]
        assert placement_from_manifest(meta["placement"]) == eng.placement
        state, plc, key = ckpt.restore_sharded_window(d)
        assert plc == eng.placement
        np.testing.assert_array_equal(np.asarray(key), np.asarray(eng.key))
        for a, b in zip(jax.tree_util.tree_leaves(eng.state),
                        jax.tree_util.tree_leaves(state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_restore_new_policy(engine_run):
    """Restoring under a different placement re-buckets through the host
    reshard; at D=1 the edge multiset and counters are preserved."""
    from repro.train import checkpoint as ckpt
    cfg, wcfg, batches, bs, eng = engine_run
    hp = HashPlacement.make(1, NC)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save_sharded_window(d, eng.state, eng.placement, step=1)
        state, plc, key = ckpt.restore_sharded_window(d, placement=hp)
        assert plc == hp and key is None
        assert int(np.asarray(state.window.index.num_edges).sum()) == \
            int(np.asarray(eng.state.window.index.num_edges).sum())
        bad = RangePlacement(num_shards=1, node_capacity=NC * 2)
        with pytest.raises(ValueError, match="node_capacity"):
            ckpt.restore_sharded_window(d, placement=bad)


def test_stream_supervisor_crash_resume(engine_run):
    """Kill after 3 batches, restore the step-3 checkpoint, finish: the
    final window AND walk key are bit-identical to the uninterrupted
    run (the checkpoint persists the RNG chain, not just the edges)."""
    from repro.distributed.fault_tolerance import StreamSupervisor
    from repro.distributed.streaming_shard import DistributedStreamingEngine
    cfg, wcfg, batches, bs, _ = engine_run
    # the key splits once per replay_device CALL, so the uninterrupted
    # reference must feed batches one call at a time like the supervisor
    ref = DistributedStreamingEngine(cfg, batch_capacity=bs)
    for b in batches:
        ref.replay_device([b], wcfg)
    with tempfile.TemporaryDirectory() as d:
        sup = StreamSupervisor(d, save_every=3)
        e1 = DistributedStreamingEngine(cfg, batch_capacity=bs)
        stats, step = sup.run(e1, batches[:3], wcfg)
        assert step == 3 and len(stats) == 3
        assert sup.resume_batch() == 3
        e2 = sup.checkpointer.restore_engine(cfg, batch_capacity=bs)
        out2, step2 = sup.run(e2, batches, wcfg,
                              start_batch=sup.resume_batch())
        assert step2 == len(batches)
        for a, b in zip(jax.tree_util.tree_leaves(ref.state),
                        jax.tree_util.tree_leaves(e2.state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(ref.key),
                                      np.asarray(e2.key))


def test_engine_rejects_mismatched_placement():
    from repro.distributed.streaming_shard import DistributedStreamingEngine
    cfg = _small_cfg()
    bad = RangePlacement(num_shards=4, node_capacity=NC)
    with pytest.raises(ValueError):
        DistributedStreamingEngine(cfg, batch_capacity=50, placement=bad)
