"""The capability matrix: every (path, bias, lane-features, sharded,
tables) combination either runs or refuses through the single chokepoint
``walk_engine.check_capabilities`` (DESIGN.md §17).

This used to be four scattered refusal sites (the engine's fused/node2vec
inline checks, the serving constructor, the sharded walker); they now all
delegate here, so this sweep is the one place the support matrix is
pinned. An independent predicate (``_expect_supported``) re-derives what
*should* run; the test asserts behavior matches for the full product
space, and that every refusal carries the uniform message prefix.
"""
import dataclasses
import itertools

import pytest

from repro.configs.base import SamplerConfig
from repro.core.walk_engine import LaneFeatures, check_capabilities

PATHS = ("fullwalk", "grouped", "tiled", "fused")
BIASES = ("uniform", "linear", "exponential", "table")
_CAP_PREFIX = "unsupported sampler capability: "


def _expect_supported(scfg, path, lanes, sharded, have_tables):
    """Independent statement of the support matrix."""
    n2v_cfg = scfg.node2vec_p != 1.0 or scfg.node2vec_q != 1.0
    if scfg.bias == "table":
        if scfg.mode != "index" or sharded or not have_tables:
            return False
        if path in ("tiled", "fused"):
            return False
    if n2v_cfg:
        if sharded or lanes is not None or path in ("tiled", "fused"):
            return False
    if lanes is not None:
        if scfg.mode != "index" or path == "tiled":
            return False
        if lanes.table and (sharded or not have_tables or path == "fused"):
            return False
        if lanes.second_order and (sharded or path == "fused"):
            return False
    return True


def _sweep():
    lane_opts = (None, LaneFeatures(), LaneFeatures(table=True),
                 LaneFeatures(second_order=True),
                 LaneFeatures(table=True, second_order=True))
    for mode in ("index", "weight"):
        for bias, path, lanes, sharded, have_tables in itertools.product(
                BIASES, PATHS, lane_opts, (False, True), (False, True)):
            for n2v in (1.0, 2.0):
                yield (SamplerConfig(mode=mode, bias=bias, node2vec_p=n2v),
                       path, lanes, sharded, have_tables)


def test_capability_matrix_exhaustive():
    checked = 0
    for scfg, path, lanes, sharded, have_tables in _sweep():
        expect = _expect_supported(scfg, path, lanes, sharded, have_tables)
        try:
            check_capabilities(scfg, path, lanes, sharded=sharded,
                               have_tables=have_tables)
            ran = True
            msg = None
        except ValueError as e:
            ran = False
            msg = str(e)
        combo = (scfg.mode, scfg.bias, scfg.node2vec_p, path, lanes,
                 sharded, have_tables)
        assert ran == expect, (combo, msg)
        if not ran:
            assert msg.startswith(_CAP_PREFIX), combo
        checked += 1
    # the product space really was swept
    assert checked == 2 * 4 * 4 * 5 * 2 * 2 * 2


def test_unknown_bias_refused():
    with pytest.raises(ValueError, match="unknown bias"):
        check_capabilities(SamplerConfig(mode="index", bias="nope"),
                           "grouped")
    with pytest.raises(ValueError, match="start-edge bias"):
        check_capabilities(
            SamplerConfig(mode="index", start_bias="table"), "grouped")


def test_pinned_messages():
    """Substrings downstream callers and older tests grep for."""
    with pytest.raises(ValueError, match="fused"):
        check_capabilities(SamplerConfig(mode="index", node2vec_p=2.0),
                           "fused")
    with pytest.raises(ValueError, match="node2vec"):
        check_capabilities(SamplerConfig(mode="index", node2vec_p=2.0),
                           "grouped", sharded=True)
    with pytest.raises(ValueError, match="index"):
        check_capabilities(SamplerConfig(mode="weight"), "grouped",
                           LaneFeatures())
    with pytest.raises(ValueError, match="table"):
        check_capabilities(
            SamplerConfig(mode="index", bias="table"), "grouped",
            have_tables=False)
