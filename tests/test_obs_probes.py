"""Probe instrumentation is free (DESIGN.md §16): the probed replay and
serve programs are bit-identical to the uninstrumented ones — probes are
pure extra arithmetic on values the step already computes, never touching
the RNG chain — and the probe counters agree with the host-side stats.

The multi-shard cases run in a subprocess with 8 forced host devices
(device count must be set before jax initializes, mirroring
tests/test_streaming_shard.py); the fast lane covers the single-device
engine and the 1-shard distributed engine in-process.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.configs.base import (
    EngineConfig,
    SamplerConfig,
    SchedulerConfig,
    ShardConfig,
    WalkConfig,
    WindowConfig,
)
from repro.core.streaming import StreamingEngine
from repro.data.synthetic import chronological_batches, powerlaw_temporal_graph
from repro.distributed.streaming_shard import DistributedStreamingEngine
from repro.obs import new_registry

N = 96


def _cfg():
    return EngineConfig(
        window=WindowConfig(duration=2500, edge_capacity=2048,
                            node_capacity=N),
        sampler=SamplerConfig(bias="exponential", mode="index"),
        scheduler=SchedulerConfig(path="grouped", regroup="bucket"),
        shard=ShardConfig(edge_capacity_per_shard=2048,
                          exchange_capacity=512, walk_slots=256,
                          walk_bucket_capacity=256),
    )


def _replay(eng, g, wcfg):
    return eng.replay_device(chronological_batches(g, 4), wcfg,
                             return_walks=True)


def test_probed_replay_scan_bit_identical():
    """StreamingEngine with probes on == probes off: same stats, same
    walks, same final window — the instrumented program computes nothing
    the walk sees."""
    g = powerlaw_temporal_graph(N, 2000, seed=13)
    wcfg = WalkConfig(num_walks=128, max_length=8, start_mode="nodes")
    base = StreamingEngine(_cfg(), batch_capacity=512,
                           registry=new_registry(), probes=False)
    probed = StreamingEngine(_cfg(), batch_capacity=512,
                             registry=new_registry(), probes=True)
    bstats, bwalks, _ = _replay(base, g, wcfg)
    pstats, pwalks, _ = _replay(probed, g, wcfg)
    for f in bstats._fields:
        np.testing.assert_array_equal(np.asarray(getattr(bstats, f)),
                                      np.asarray(getattr(pstats, f)),
                                      err_msg=f)
    np.testing.assert_array_equal(bwalks.nodes, pwalks.nodes)
    np.testing.assert_array_equal(bwalks.times, pwalks.times)
    np.testing.assert_array_equal(bwalks.lengths, pwalks.lengths)
    np.testing.assert_array_equal(
        np.asarray(base.state.index.store.ts),
        np.asarray(probed.state.index.store.ts))


def test_probe_counters_agree_with_stats():
    """The flushed probe vector reproduces the replay's own cumulative
    accounting — the probes count, they don't estimate."""
    g = powerlaw_temporal_graph(N, 2000, seed=13)
    wcfg = WalkConfig(num_walks=128, max_length=8, start_mode="nodes")
    reg = new_registry()
    eng = StreamingEngine(_cfg(), batch_capacity=512, registry=reg,
                          probes=True)
    stats, walks, _ = _replay(eng, g, wcfg)
    assert reg.value("stream_edges_ingested_total",
                     labels={"driver": "device"}) == int(
        np.asarray(stats.ingested)[-1])
    assert reg.value("drops_total", labels={"kind": "ingest_late"},
                     default=0) == int(np.asarray(stats.late_drops)[-1])
    assert reg.value("drops_total", labels={"kind": "window_overflow"},
                     default=0) == int(np.asarray(stats.overflow_drops)[-1])
    assert reg.value("walks_emitted_total",
                     labels={"driver": "device"}) == 4 * wcfg.num_walks
    # final batch's hop cells are a lower bound on the whole replay's
    final_hops = int(np.sum(np.maximum(
        np.asarray(walks.lengths, dtype=np.int64) - 1, 0)))
    assert reg.value("walk_hops_total",
                     labels={"source": "replay"}) >= final_hops > 0


def test_sharded_probes_single_shard_identity():
    """1-shard distributed replay with probes == without, bit for bit,
    and the per-shard probe flush lands in the registry."""
    g = powerlaw_temporal_graph(N, 2000, seed=13)
    wcfg = WalkConfig(num_walks=128, max_length=8, start_mode="all_nodes")
    base = DistributedStreamingEngine(_cfg(), batch_capacity=512,
                                      num_shards=1,
                                      registry=new_registry(), probes=False)
    reg = new_registry()
    probed = DistributedStreamingEngine(_cfg(), batch_capacity=512,
                                        num_shards=1, registry=reg,
                                        probes=True)
    bstats, bwalks, _ = base.replay_device(chronological_batches(g, 4), wcfg)
    pstats, pwalks, _ = probed.replay_device(chronological_batches(g, 4),
                                             wcfg)
    for f in bstats.replay._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(bstats.replay, f)),
            np.asarray(getattr(pstats.replay, f)), err_msg=f)
    np.testing.assert_array_equal(bwalks.nodes, pwalks.nodes)
    np.testing.assert_array_equal(bwalks.lengths, pwalks.lengths)
    assert reg.value("stream_edges_ingested_total",
                     labels={"driver": "sharded", "shard": "0"}) == int(
        np.asarray(pstats.replay.ingested)[-1])
    assert reg.sum_values("walk_hops_total") > 0
    assert reg.value("shard_edges_active", labels={"shard": "0"}) == int(
        np.asarray(pstats.replay.edges_active)[-1])


SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
from repro.configs.base import (EngineConfig, SamplerConfig, SchedulerConfig,
                                ServeConfig, ShardConfig, WalkConfig,
                                WindowConfig)
from repro.data.synthetic import chronological_batches, powerlaw_temporal_graph
from repro.distributed.streaming_shard import DistributedStreamingEngine
from repro.obs import new_registry
from repro.serve import WalkQuery, WalkService

N = 128
g = powerlaw_temporal_graph(N, 3000, seed=7)
cfg = EngineConfig(
    window=WindowConfig(duration=3000, edge_capacity=4096, node_capacity=N),
    sampler=SamplerConfig(bias="exponential", mode="index"),
    scheduler=SchedulerConfig(path="grouped", regroup="bucket"),
    shard=ShardConfig(edge_capacity_per_shard=4096, exchange_capacity=1024,
                      walk_slots=512, walk_bucket_capacity=512),
)
wcfg = WalkConfig(num_walks=256, max_length=8, start_mode="all_nodes")

# --- probed sharded replay == unprobed, bit for bit, at D in {1,2,8} -----
emitted_by_d = {}
for D in (1, 2, 8):
    base = DistributedStreamingEngine(cfg, batch_capacity=1024, num_shards=D,
                                      registry=new_registry(), probes=False)
    reg = new_registry()
    probed = DistributedStreamingEngine(cfg, batch_capacity=1024,
                                        num_shards=D, registry=reg,
                                        probes=True)
    bstats, bwalks, _ = base.replay_device(chronological_batches(g, 5), wcfg)
    pstats, pwalks, _ = probed.replay_device(chronological_batches(g, 5),
                                             wcfg)
    for f in bstats.replay._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(bstats.replay, f)),
            np.asarray(getattr(pstats.replay, f)), err_msg=f"D={D} {f}")
    np.testing.assert_array_equal(bstats.exchange_drops,
                                  pstats.exchange_drops)
    np.testing.assert_array_equal(bwalks.nodes, pwalks.nodes)
    np.testing.assert_array_equal(bwalks.times, pwalks.times)
    np.testing.assert_array_equal(bwalks.lengths, pwalks.lengths)
    # per-shard edge counters sum to the global cumulative ingest count
    tot = sum(int(reg.value("stream_edges_ingested_total",
                            labels={"driver": "sharded", "shard": str(d)},
                            default=0)) for d in range(D))
    assert tot == int(np.asarray(pstats.replay.ingested)[-1]), (D, tot)
    emitted_by_d[D] = sum(
        int(reg.value("walks_emitted_total",
                      labels={"driver": "sharded", "shard": str(d)},
                      default=0))
        for d in range(D))

# emitted walks are global (recorded once, on shard 0): the probe count
# must agree across shard topologies, like the walks themselves
assert len(set(emitted_by_d.values())) == 1, emitted_by_d
assert min(emitted_by_d.values()) > 0, emitted_by_d

# --- probed sharded serving == unprobed, bit for bit, at D in {1,2,8} ----
scfg = ServeConfig(lane_buckets=(8, 16, 64), length_buckets=(4, 8, 16))
BIASES = ("uniform", "linear", "exponential")
queries = []
for i, b in enumerate(BIASES):
    queries.append(WalkQuery(start_nodes=(1 + i, 30 + i, 60 + i, 99 - i),
                             bias=b, max_length=5 + i, seed=100 + i))
    queries.append(WalkQuery(num_walks=3 + i, start_mode="edges", bias=b,
                             start_bias=BIASES[(i + 1) % 3],
                             max_length=4 + i, seed=200 + i))

for D in (1, 2, 8):
    results = {}
    for probes in (False, True):
        reg = new_registry()
        svc = WalkService(cfg, scfg, num_shards=D, registry=reg,
                          probes=probes)
        for bs, bd, bt in chronological_batches(g, 3):
            svc.ingest(bs, bd, bt)
        tickets = [svc.submit(q, strict=True) for q in queries]
        while svc.pending_count:
            svc.step()
        results[probes] = [svc.poll(t) for t in tickets]
        if probes:
            claims = int(reg.sum_values("serve_lane_claims_total"))
            assert claims == sum(svc.stats.lanes_by_shard.values()), D
    for rb, rp in zip(results[False], results[True]):
        np.testing.assert_array_equal(rb.nodes, rp.nodes, err_msg=str(D))
        np.testing.assert_array_equal(rb.times, rp.times, err_msg=str(D))
        np.testing.assert_array_equal(rb.lengths, rp.lengths,
                                      err_msg=str(D))

print("OBS_PROBES_OK")
"""


@pytest.mark.slow      # 8-device subprocess
def test_probed_paths_8_devices():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "OBS_PROBES_OK" in out.stdout, \
        (out.stdout[-1500:], out.stderr[-3000:])
