"""Observability layer (repro.obs, DESIGN.md §16): registry semantics,
bounded reservoirs, the consolidated drop taxonomy, stage tracing,
exporter schemas, and the no-extra-syncs contract on the instrumented
replay driver."""
import json
import math

import jax
import numpy as np
import pytest

from repro.configs.base import (
    EngineConfig,
    SamplerConfig,
    SchedulerConfig,
    ServeConfig,
    WalkConfig,
    WindowConfig,
)
from repro.core.streaming import StreamingEngine
from repro.data.synthetic import chronological_batches, powerlaw_temporal_graph
from repro.obs import (
    DROP_KINDS,
    DropCounters,
    Reservoir,
    bench_doc,
    count_drop,
    dump_health,
    export_json,
    health_snapshot,
    new_registry,
    span,
    to_prometheus,
    validate_bench,
    validate_health,
    validate_snapshot,
)
from repro.serve import WalkQuery, WalkService
from repro.serve.service import STATS_WINDOW, ServeStats

NC = 128


def _engine_cfg():
    return EngineConfig(
        window=WindowConfig(duration=4000, edge_capacity=4096,
                            node_capacity=NC),
        sampler=SamplerConfig(mode="index"),
        scheduler=SchedulerConfig(path="grouped"))


def _serve_cfg():
    return ServeConfig(lane_buckets=(8, 16, 64), length_buckets=(4, 8))


# ---------------------------------------------------------------------------
# Reservoir + registry primitives
# ---------------------------------------------------------------------------


def test_reservoir_ring_buffer_bounds():
    r = Reservoir(4)
    for v in (1, 2, 3, 4, 5, 6):
        r.add(v)
    assert len(r) == 4
    assert r.count == 6                      # lifetime, not resident
    assert r.total == 21.0
    assert r.values() == [3.0, 4.0, 5.0, 6.0]   # oldest-first after wrap
    np.testing.assert_array_equal(np.asarray(r), [3.0, 4.0, 5.0, 6.0])


def test_reservoir_percentile_contract():
    r = Reservoir(8)
    assert math.isnan(r.percentile(50))      # empty -> nan
    r.add(7.5)
    assert r.percentile(0) == 7.5            # singleton -> the value
    assert r.percentile(50) == 7.5
    assert r.percentile(100) == 7.5
    with pytest.raises(ValueError):
        r.percentile(-1)
    with pytest.raises(ValueError):
        r.percentile(101)
    r2 = Reservoir(256)
    for v in range(101):
        r2.add(float(v))
    assert r2.percentile(50) == 50.0


def test_registry_counters_gauges_histograms():
    reg = new_registry()
    reg.inc("foo_total", 2, help="foo")
    reg.inc("foo_total", 3, labels={"a": "x"})
    assert reg.value("foo_total") == 2
    assert reg.value("foo_total", labels={"a": "x"}) == 3
    assert reg.sum_values("foo_total") == 5
    reg.set_gauge("depth", 7)
    reg.set_gauge("depth", 3)
    assert reg.value("depth") == 3           # gauges overwrite
    for v in (0.1, 0.2, 0.3):
        reg.observe("lat_seconds", v)
    h = reg.histogram("lat_seconds")
    assert h.count == 3
    assert h.sum == pytest.approx(0.6)
    with pytest.raises(ValueError):
        reg.inc("foo_total", -1)             # counters are monotonic
    with pytest.raises(ValueError):
        reg.counter("Bad-Name")              # name charset is enforced
    with pytest.raises(ValueError):
        reg.gauge("foo_total")               # kind conflicts are errors


def test_drop_taxonomy_and_dropcounters():
    reg = new_registry()
    count_drop(reg, "ingest_late", 3)
    count_drop(reg, "oversize", 1)
    count_drop(reg, "exchange_clip", 0)      # zero increments are skipped
    with pytest.raises(ValueError):
        count_drop(reg, "not_a_kind", 1)
    dc = DropCounters.from_registry(reg)
    assert dc.ingest_late == 3 and dc.oversize == 1
    assert dc.total == 4
    d = dc.as_dict()
    assert d["total"] == 4
    for kind in DROP_KINDS:
        assert kind in d                     # every kind always present
    assert d["exchange_clip"] == 0


def test_span_records_even_on_exception():
    reg = new_registry()
    with span("happy", reg):
        pass
    with pytest.raises(RuntimeError):
        with span("sad", reg, labels={"who": "t"}):
            raise RuntimeError("boom")
    assert reg.value("stage_calls_total", labels={"stage": "happy"}) == 1
    assert reg.value("stage_calls_total",
                     labels={"stage": "sad", "who": "t"}) == 1
    h = reg.histogram("stage_seconds", labels={"stage": "sad", "who": "t"})
    assert h.count == 1 and h.sum >= 0


# ---------------------------------------------------------------------------
# Exporters + schemas
# ---------------------------------------------------------------------------


def test_prometheus_and_json_export():
    reg = new_registry()
    reg.inc("walks_total", 5, labels={"path": "a b\"c"}, help="walks done")
    reg.set_gauge("occ", 0.5)
    reg.observe("lat_seconds", 0.25)
    text = to_prometheus(reg)
    assert "# HELP walks_total walks done" in text
    assert "# TYPE walks_total counter" in text
    assert 'path="a b\\"c"' in text          # label escaping
    assert "# TYPE lat_seconds summary" in text
    assert 'lat_seconds{quantile="0.5"} 0.25' in text
    assert "lat_seconds_count 1" in text

    doc = export_json(reg)                   # self-validating
    assert doc["schema"] == "tempest-obs/v1"
    assert doc["metrics"]["walks_total"]["series"][0]["value"] == 5
    hist = doc["metrics"]["lat_seconds"]["series"][0]
    assert hist["count"] == 1 and hist["p50"] == 0.25
    json.dumps(doc)                          # round-trippable
    bad = dict(doc, schema="nope/v9")
    with pytest.raises(ValueError):
        validate_snapshot(bad)


def test_bench_schema():
    doc = bench_doc("suite_x", [{"name": "r0", "us_per_call": 1.5,
                                 "derived": "k=v"}],
                    results={"extra": {"n": 1}})
    assert validate_bench(doc) is doc
    with pytest.raises(ValueError):
        validate_bench(dict(doc, rows=[{"name": "r0",
                                        "us_per_call": float("nan")}]))
    with pytest.raises(ValueError):
        validate_bench(dict(doc, rows=[{"us_per_call": 1.0}]))
    with pytest.raises(ValueError):
        validate_bench(dict(doc, suite=""))


def test_serve_stats_latency_contract():
    st = ServeStats()
    assert math.isnan(st.latency_percentile(50))   # empty -> nan
    st.latencies_s.append(0.040)
    assert st.latency_percentile(50) == 0.040      # singleton -> the value
    assert st.p50_ms == pytest.approx(40.0)
    with pytest.raises(ValueError):
        st.latency_percentile(150)
    # bounded: the reservoir never grows past STATS_WINDOW entries
    assert st.latencies_s.capacity == STATS_WINDOW
    for _ in range(STATS_WINDOW + 10):
        st.sample_s.append(0.001)
    assert len(st.sample_s) == STATS_WINDOW


# ---------------------------------------------------------------------------
# Instrumented engines: metrics smoke + the no-extra-syncs contract
# ---------------------------------------------------------------------------


def test_streaming_engine_metrics_smoke():
    reg = new_registry()
    g = powerlaw_temporal_graph(100, 2000, seed=5)
    eng = StreamingEngine(_engine_cfg(), batch_capacity=1024, registry=reg)
    wcfg = WalkConfig(num_walks=128, max_length=8, start_mode="nodes")
    stats, _ = eng.replay_device(chronological_batches(g, 3), wcfg)

    doc = export_json(reg)
    for name in ("stream_batches_total", "stream_edges_ingested_total",
                 "walk_hops_total", "walks_emitted_total", "replay_seconds",
                 "window_edges_active", "window_occupancy", "window_t_now"):
        assert name in doc["metrics"], name
    ingested = reg.value("stream_edges_ingested_total",
                         labels={"driver": "device"})
    assert ingested == int(np.asarray(stats.ingested)[-1])
    assert reg.value("stream_batches_total",
                     labels={"driver": "device"}) == 3
    assert reg.value("window_edges_active") == int(
        np.asarray(stats.edges_active)[-1])
    assert reg.value("walk_hops_total", labels={"source": "replay"}) > 0


def test_replay_device_single_sync_per_batch(monkeypatch):
    """The probe flush rides the replay's one existing host sync: the
    instrumented driver makes exactly as many explicit
    ``block_until_ready`` calls as the uninstrumented one (one per
    ``replay_device``), regardless of ``probes``."""
    g = powerlaw_temporal_graph(100, 2000, seed=5)
    wcfg = WalkConfig(num_walks=128, max_length=8, start_mode="nodes")
    counts = {}
    orig = jax.block_until_ready

    for probes in (False, True):
        eng = StreamingEngine(_engine_cfg(), batch_capacity=1024,
                              registry=new_registry(), probes=probes)
        calls = []
        monkeypatch.setattr(jax, "block_until_ready",
                            lambda x: calls.append(1) or orig(x))
        try:
            eng.replay_device(chronological_batches(g, 3), wcfg)
        finally:
            monkeypatch.setattr(jax, "block_until_ready", orig)
        counts[probes] = len(calls)

    assert counts[True] == counts[False] == 1, counts


def test_unified_export_after_replay_and_serve(tmp_path):
    """Acceptance check: one registry, one ``export_json`` after a device
    replay AND a serve drain yields ingest/window/dispatch/latency metrics
    in a single schema-validated document, plus a valid health dump."""
    reg = new_registry()
    g = powerlaw_temporal_graph(100, 3000, seed=11)

    eng = StreamingEngine(_engine_cfg(), batch_capacity=1024, registry=reg)
    batches = list(chronological_batches(g, 4))
    eng.replay_device(batches[:3],
                      WalkConfig(num_walks=64, max_length=8,
                                 start_mode="nodes"))
    eng.ingest_batch(*batches[3])            # host-driver ingest path

    svc = WalkService(_engine_cfg(), _serve_cfg(), registry=reg)
    for bs, bd, bt in chronological_batches(g, 3):
        svc.ingest(bs, bd, bt)
    tickets = [svc.submit(WalkQuery(start_nodes=(1, 30, 60), max_length=8,
                                    seed=i), strict=True) for i in range(2)]
    # an oversize query is dropped (not queued) and lands in drops_total
    assert svc.submit(WalkQuery(start_nodes=tuple(range(100)),
                                max_length=8, seed=9)) is None
    while svc.pending_count:
        svc.step()
    assert all(svc.poll(t) is not None for t in tickets)

    doc = export_json(reg)
    for name in ("stream_batches_total", "stream_edges_ingested_total",
                 "window_occupancy", "walks_dispatched_total",
                 "serve_submitted_total", "serve_completed_total",
                 "serve_latency_seconds", "stage_seconds", "drops_total"):
        assert name in doc["metrics"], name
    # both producers landed in the same families, split by label
    drivers = {s["labels"].get("driver")
               for s in doc["metrics"]["stream_batches_total"]["series"]}
    assert {"device", "host"} <= drivers

    health = health_snapshot(reg, service=svc)
    assert validate_health(health) is health
    assert health["serving"]["completed"] == 2
    assert health["ingest"]["batches"] == 4   # 3 replayed + 1 host ingest
    assert health["dispatch"]["walks_by_path"].get("serve", 0) > 0

    path = tmp_path / "health.json"
    dump_health(str(path), reg, service=svc)
    validate_health(json.loads(path.read_text()))
