"""Pipeline parallelism (pipeline == sequential oracle, subprocess with
forced devices) and fault-tolerance supervisor behavior."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.fault_tolerance import StragglerPolicy, TrainSupervisor
from repro.train.optimizer import AdamWConfig, init_opt_state

PIPE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import gpipe_forward, sequential_reference

mesh = jax.make_mesh((4,), ("pod",))
P_stages, M, mb, d = 4, 6, 3, 8
key = jax.random.PRNGKey(0)
params = {"w": 0.3 * jax.random.normal(key, (P_stages, d, d)),
          "b": 0.1 * jnp.ones((P_stages, d))}

def stage_fn(p, x):
    return jnp.tanh(x @ p["w"] + p["b"])

x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d))
got = gpipe_forward(mesh, "pod", stage_fn, params, x)
want = sequential_reference(stage_fn, params, x)
np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                           rtol=1e-5, atol=1e-5)
print("PIPELINE_OK")
"""

pytestmark = pytest.mark.slow      # multi-device subprocess pipeline + FT supervisor


def test_gpipe_matches_sequential():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", PIPE_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "PIPELINE_OK" in out.stdout, \
        (out.stdout[-1000:], out.stderr[-3000:])


def test_straggler_policy():
    pol = StragglerPolicy(threshold=3.0, max_flags=2)
    for _ in range(10):
        assert pol.observe(1.0) == "ok"
    assert pol.observe(10.0) == "straggler"
    assert pol.observe(10.0) == "remesh"
    assert pol.observe(1.0) == "ok"        # flags reset


def test_supervisor_checkpoint_resume(tmp_path):
    """Crash -> resume from the latest checkpoint, bit-exact state."""
    opt_cfg = AdamWConfig(lr=0.1, warmup_steps=0, weight_decay=0.0)
    params = {"w": jnp.ones((4,))}
    opt = init_opt_state(params, opt_cfg)

    def step_fn(p, o, batch):
        from repro.train.optimizer import apply_updates
        grads = {"w": p["w"] - batch}
        p, o, m = apply_updates(p, grads, o, opt_cfg)
        return p, o, m

    sup = TrainSupervisor(str(tmp_path), save_every=5)
    batches = [jnp.full((4,), float(i)) for i in range(12)]
    p1, o1, step = sup.run(step_fn, params, opt, batches, max_steps=12)
    assert step == 12
    assert sup.resume_step() == 10          # last multiple of save_every

    # "crash": restart from checkpoint and replay the tail
    p2, o2 = sup.restore(params, opt)
    p2, o2, step2 = sup.run(step_fn, p2, o2, batches[10:],
                            start_step=10, max_steps=12)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(o1.mu["w"]),
                               np.asarray(o2.mu["w"]), rtol=1e-6)
