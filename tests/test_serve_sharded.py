"""Sharded serving (DESIGN.md §13): coalesced multi-tenant lane batches
over the node-partitioned window.

The acceptance invariant extends PR 3's: a coalesced batch served against
the **sharded** window is bit-identical to each query run **solo on the
single-device engine** — at any shard count. The multi-shard cases run in
a subprocess with 8 forced host devices (device count must be set before
jax initializes, mirroring test_streaming_shard.py); the fast lane covers
1-shard identity, the sharded snapshot double-buffer, and the
unsupported-config refusals in-process.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.configs.base import (
    EngineConfig,
    SamplerConfig,
    SchedulerConfig,
    ServeConfig,
    ShardConfig,
    WalkConfig,
    WindowConfig,
)
from repro.core.edge_store import make_batch
from repro.data.synthetic import chronological_batches, powerlaw_temporal_graph
from repro.serve import ShardedSnapshotManager, WalkQuery, WalkService

NC = 128
BIASES = ("uniform", "linear", "exponential")


def _cfg():
    return EngineConfig(
        window=WindowConfig(duration=4000, edge_capacity=4096,
                            node_capacity=NC),
        sampler=SamplerConfig(mode="index"),
        scheduler=SchedulerConfig(path="grouped"),
        shard=ShardConfig(edge_capacity_per_shard=4096,
                          exchange_capacity=4096, walk_slots=256,
                          walk_bucket_capacity=256))


def _serve_cfg():
    return ServeConfig(lane_buckets=(8, 16, 64), length_buckets=(4, 8, 16))


def _query_grid():
    """3 bias codes × 2 start modes, varied lengths/fan-outs/seeds."""
    queries = []
    for i, b in enumerate(BIASES):
        queries.append(WalkQuery(start_nodes=(1 + i, 30 + i, 60 + i, 99 - i),
                                 bias=b, max_length=5 + i, seed=100 + i))
        queries.append(WalkQuery(num_walks=3 + i, start_mode="edges", bias=b,
                                 start_bias=BIASES[(i + 1) % 3],
                                 max_length=4 + i, seed=200 + i))
    return queries


SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
from repro.configs.base import (EngineConfig, SamplerConfig, SchedulerConfig,
                                ServeConfig, ShardConfig, WindowConfig)
from repro.data.synthetic import chronological_batches, powerlaw_temporal_graph
from repro.serve import WalkQuery, WalkService

NC = 128
BIASES = ("uniform", "linear", "exponential")
g = powerlaw_temporal_graph(100, 3000, seed=11)
cfg = EngineConfig(
    window=WindowConfig(duration=4000, edge_capacity=4096, node_capacity=NC),
    sampler=SamplerConfig(mode="index"),
    scheduler=SchedulerConfig(path="grouped"),
    shard=ShardConfig(edge_capacity_per_shard=4096, exchange_capacity=4096,
                      walk_slots=256, walk_bucket_capacity=256))
scfg = ServeConfig(lane_buckets=(8, 16, 64), length_buckets=(4, 8, 16))

# solo reference: the single-device service over the replicated window
ref = WalkService(cfg, scfg)
for bs, bd, bt in chronological_batches(g, 3):
    ref.ingest(bs, bd, bt)

queries = []
for i, b in enumerate(BIASES):
    queries.append(WalkQuery(start_nodes=(1 + i, 30 + i, 60 + i, 99 - i),
                             bias=b, max_length=5 + i, seed=100 + i))
    queries.append(WalkQuery(num_walks=3 + i, start_mode="edges", bias=b,
                             start_bias=BIASES[(i + 1) % 3],
                             max_length=4 + i, seed=200 + i))

# --- coalesced-sharded == solo-single-device at shard counts {1, 2, 8} ---
for D in (1, 2, 8):
    svc = WalkService(cfg, scfg, num_shards=D)
    assert svc.num_shards == D
    for bs, bd, bt in chronological_batches(g, 3):
        svc.ingest(bs, bd, bt)
    # the replicated ts-view is byte-identical to the single-device store
    rs = ref.snapshots.current.index.store
    vs = svc.snapshots.view.store
    assert int(rs.num_edges) == int(vs.num_edges)
    for f in ("src", "dst", "ts"):
        np.testing.assert_array_equal(np.asarray(getattr(rs, f)),
                                      np.asarray(getattr(vs, f)),
                                      err_msg=f"D={D} view.{f}")
    tickets = [svc.submit(q, strict=True) for q in queries]
    while svc.pending_count:
        svc.step()
    for t, q in zip(tickets, queries):
        r = svc.poll(t)
        assert r is not None
        sn, st_, sl = ref.run_query_solo(q)
        np.testing.assert_array_equal(r.nodes, sn, err_msg=f"D={D} {q}")
        np.testing.assert_array_equal(r.times, st_, err_msg=f"D={D} {q}")
        np.testing.assert_array_equal(r.lengths, sl, err_msg=f"D={D} {q}")
    assert svc.stats.shard_walk_drops == 0, (D, "walk overflow")
    assert svc.stats.exchange_drops == 0, (D, "ingest exchange overflow")
    assert svc.stats.completed == len(queries)

# --- nodes-mode start lanes spread across owner shards at D=8 ------------
svc = WalkService(cfg, scfg, num_shards=8)
for bs, bd, bt in chronological_batches(g, 3):
    svc.ingest(bs, bd, bt)
starts = tuple(range(0, 96, 2))
t = svc.submit(WalkQuery(start_nodes=starts, max_length=4, seed=5),
               strict=True)
svc.step()
assert svc.poll(t) is not None
assert len(svc.stats.lanes_by_shard) > 1, svc.stats.lanes_by_shard
# nodes-mode claims are device-counted: one claim per admitted start
# lane (a zero-degree start node is claimed by no shard)
assert 0 < sum(svc.stats.lanes_by_shard.values()) <= len(starts)

# --- edges-mode lanes are claim-counted on device too --------------------
before = sum(svc.stats.lanes_by_shard.values())
t = svc.submit(WalkQuery(num_walks=24, start_mode="edges", max_length=4,
                         seed=9), strict=True)
svc.step()
assert svc.poll(t) is not None
after = sum(svc.stats.lanes_by_shard.values())
assert after == before + 24, (before, after)

# --- walk-slot overflow is counted, not crashed --------------------------
tiny = EngineConfig(
    window=cfg.window, sampler=cfg.sampler, scheduler=cfg.scheduler,
    shard=ShardConfig(edge_capacity_per_shard=4096, exchange_capacity=1024,
                      walk_slots=2, walk_bucket_capacity=256))
svc = WalkService(tiny, scfg, num_shards=8)
for bs, bd, bt in chronological_batches(g, 3):
    svc.ingest(bs, bd, bt)
t = svc.submit(WalkQuery(start_nodes=tuple(range(32)), max_length=4,
                         seed=1), strict=True)
svc.step()
assert svc.poll(t) is not None
assert svc.stats.shard_walk_drops > 0, "expected walk-slot overflow"

print("SHARDED_SERVE_OK")
"""


@pytest.mark.slow      # 8-device subprocess
def test_sharded_serving_8_devices():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "SHARDED_SERVE_OK" in out.stdout, \
        (out.stdout[-1500:], out.stderr[-3000:])


# ---------------------------------------------------------------------------
# Fast lane: 1-shard identity + snapshot protocol + refusals (in-process)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def services():
    """(graph, single-device reference service, 1-shard sharded service),
    both fed the same batch stream."""
    g = powerlaw_temporal_graph(100, 3000, seed=11)
    ref = WalkService(_cfg(), _serve_cfg())
    svc = WalkService(_cfg(), _serve_cfg(), num_shards=1)
    for bs, bd, bt in chronological_batches(g, 3):
        ref.ingest(bs, bd, bt)
        svc.ingest(bs, bd, bt)
    return g, ref, svc


def test_single_shard_coalesced_matches_single_device_solo(services):
    """Acceptance (fast lane): coalesced batches on the 1-shard
    node-partitioned window == per-query solo runs on the single-device
    engine, all three biases × both start modes."""
    _, ref, svc = services
    queries = _query_grid()
    tickets = [svc.submit(q, strict=True) for q in queries]
    while svc.pending_count:
        svc.step()
    for t, q in zip(tickets, queries):
        r = svc.poll(t)
        assert r is not None
        sn, st_, sl = ref.run_query_solo(q)
        assert np.array_equal(r.nodes, sn), q
        assert np.array_equal(r.times, st_), q
        assert np.array_equal(r.lengths, sl), q
    assert svc.stats.shard_walk_drops == 0


def test_sharded_solo_matches_single_device_solo(services):
    """The sharded service's own solo path agrees with the single-device
    solo path bit for bit (same exact-shape dispatch, different engine)."""
    _, ref, svc = services
    for q in (_query_grid()[0], _query_grid()[-1]):
        for a, b in zip(svc.run_query_solo(q), ref.run_query_solo(q)):
            assert np.array_equal(a, b), q


def test_sharded_view_matches_single_device_store(services):
    """The replicated ts-view (start directory) is byte-identical to the
    single-device window store after the same batch stream."""
    _, ref, svc = services
    rs = ref.snapshots.current.index.store
    vs = svc.snapshots.view.store
    assert int(rs.num_edges) == int(vs.num_edges)
    for f in ("src", "dst", "ts"):
        np.testing.assert_array_equal(np.asarray(getattr(rs, f)),
                                      np.asarray(getattr(vs, f)), err_msg=f)


def test_sharded_snapshot_double_buffer():
    """begin_ingest keeps the current (state, view) pair serveable;
    publish swaps both and bumps the version; protocol errors raise."""
    g = powerlaw_temporal_graph(100, 1500, seed=3)
    batches = list(chronological_batches(g, 3))
    svc = WalkService(_cfg(), _serve_cfg(), num_shards=1)
    for bs, bd, bt in batches[:-1]:
        svc.ingest(bs, bd, bt)
    bs, bd, bt = batches[-1]
    v0 = svc.snapshots.version
    old_n = int(svc.snapshots.view.store.num_edges)
    svc.begin_ingest(bs, bd, bt)
    assert svc.snapshots.ingest_in_flight
    with pytest.raises(RuntimeError, match="already in flight"):
        svc.begin_ingest(bs, bd, bt)
    # the front buffer still serves while the back buffer builds
    t = svc.submit(WalkQuery(start_nodes=(1, 2, 3), max_length=4, seed=1),
                   strict=True)
    svc.step()
    r = svc.poll(t)
    assert r is not None and r.snapshot_version == v0
    assert int(svc.snapshots.view.store.num_edges) == old_n
    svc.publish()
    assert svc.snapshots.version == v0 + 1
    assert not svc.snapshots.ingest_in_flight
    assert int(svc.snapshots.view.store.num_edges) != old_n
    with pytest.raises(RuntimeError, match="no ingest in flight"):
        svc.publish()
    svc.begin_ingest(bs, bd, bt)
    svc.snapshots.discard()
    assert not svc.snapshots.ingest_in_flight


def test_sharded_serving_refusals():
    """Unsupported configs are refused up front, not mid-batch."""
    import dataclasses
    with pytest.raises(ValueError, match="index"):
        WalkService(dataclasses.replace(
            _cfg(), sampler=SamplerConfig(mode="weight")), num_shards=1)
    with pytest.raises(ValueError, match="node2vec"):
        WalkService(dataclasses.replace(
            _cfg(), sampler=SamplerConfig(mode="index", node2vec_p=2.0)),
            num_shards=1)
    # the state= override belongs to the single-device path
    from repro.core.window import init_window
    with pytest.raises(ValueError, match="single-device"):
        WalkService(_cfg(), _serve_cfg(),
                    state=init_window(4096, NC, 4000), num_shards=1)
    # more shards than devices
    import jax
    with pytest.raises(ValueError, match="devices"):
        WalkService(_cfg(), _serve_cfg(),
                    num_shards=len(jax.devices()) + 1)
    # the engine-level check refuses non-lane start modes for lane batches
    from repro.distributed.streaming_shard import _check_supported
    with pytest.raises(ValueError, match="nodes"):
        _check_supported(WalkConfig(start_mode="all_nodes"),
                         SamplerConfig(mode="index"), lanes=True)
    with pytest.raises(ValueError, match="index"):
        _check_supported(WalkConfig(start_mode="nodes"),
                         SamplerConfig(mode="weight"), lanes=True)
    # sharded snapshot manager rejects a wrong-capacity batch
    snaps = ShardedSnapshotManager(_cfg(), batch_capacity=1024, num_shards=1)
    with pytest.raises(ValueError, match="capacity"):
        snaps.begin_ingest(make_batch([1], [2], [3], capacity=16))


def test_ingest_exchange_drops_surface_in_stats():
    """Under-provisioned ingest exchange buckets lose window edges; the
    service surfaces them (bit-identity needs BOTH drop counters zero)."""
    import dataclasses
    tiny = dataclasses.replace(
        _cfg(), shard=ShardConfig(edge_capacity_per_shard=4096,
                                  exchange_capacity=8, walk_slots=256,
                                  walk_bucket_capacity=256))
    g = powerlaw_temporal_graph(100, 1500, seed=7)
    svc = WalkService(tiny, _serve_cfg(), num_shards=1)
    svc.ingest(g.src, g.dst, g.ts)
    assert svc.stats.exchange_drops > 0
    # a healthy service stays at zero
    svc2 = WalkService(_cfg(), _serve_cfg(), num_shards=1)
    svc2.ingest(g.src, g.dst, g.ts)
    assert svc2.stats.exchange_drops == 0


def test_serve_config_num_shards_switch():
    """ServeConfig.num_shards flips the service into sharded mode."""
    scfg = ServeConfig(lane_buckets=(8, 16), length_buckets=(4, 8),
                       num_shards=1)
    svc = WalkService(_cfg(), scfg)
    assert svc.sharded and svc.num_shards == 1
    g = powerlaw_temporal_graph(100, 800, seed=9)
    svc.ingest(g.src, g.dst, g.ts)
    t = svc.submit(WalkQuery(start_nodes=(3, 4), max_length=4, seed=7),
                   strict=True)
    svc.step()
    assert svc.poll(t) is not None


# ---------------------------------------------------------------------------
# Snapshot-consistency soak: no result mixes two window versions
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_snapshot_consistency_soak():
    """Interleave begin_ingest/publish with live queries and verify every
    QueryResult against the window bounds of the version it reports: all
    hop timestamps within [t_now - Δ, t_now] of that version, nodes-mode
    start rows pinned to that version's t_floor. An edge from a later
    publish would exceed the pinned version's t_now; an evicted one would
    fall below its cutoff — either way, version mixing is caught.
    """
    g = powerlaw_temporal_graph(100, 6000, seed=21)
    svc = WalkService(_cfg(), _serve_cfg(), num_shards=1)
    batches = list(chronological_batches(g, 12))
    rng = np.random.default_rng(5)

    # bounds[v] = (t_floor, cutoff, t_now) of published version v
    def bounds():
        view = svc.snapshots.view
        n = int(view.store.num_edges)
        ts0 = int(np.asarray(view.store.ts[0])) if n else 0
        t_now = int(np.asarray(view.t_now))
        return (ts0 - 1 if n else 0, t_now - int(np.asarray(view.window)),
                t_now)

    version_bounds = {}
    tickets = []
    pending_ingest = False
    bi = 0
    svc.ingest(*batches[bi]); bi += 1
    version_bounds[svc.snapshots.version] = bounds()
    for step in range(60):
        op = rng.integers(4)
        if op == 0 and not pending_ingest and bi < len(batches):
            svc.begin_ingest(*batches[bi]); bi += 1
            pending_ingest = True
        elif op == 1 and pending_ingest:
            svc.publish()
            pending_ingest = False
            version_bounds[svc.snapshots.version] = bounds()
        elif op == 2:
            n = int(rng.integers(1, 5))
            starts = tuple(int(s) for s in rng.integers(0, NC, n))
            if rng.random() < 0.5:
                q = WalkQuery(start_nodes=starts,
                              bias=BIASES[int(rng.integers(3))],
                              max_length=int(rng.integers(2, 9)),
                              seed=int(rng.integers(1 << 16)))
            else:
                q = WalkQuery(num_walks=n, start_mode="edges",
                              bias=BIASES[int(rng.integers(3))],
                              max_length=int(rng.integers(2, 9)),
                              seed=int(rng.integers(1 << 16)))
            t = svc.submit(q)
            if t is not None:
                tickets.append(t)
        elif svc.pending_count:
            svc.step()
    # drain is scoped to the queries it completes; earlier step()
    # completions stay poll-able (the poll-after-drain contract, here
    # exercised on the sharded path)
    results = svc.drain()
    drained = {r.ticket for r in results}
    for t in tickets:
        if t not in drained:
            r = svc.poll(t)
            assert r is not None, f"ticket {t} lost across drain()"
            results.append(r)
    assert len(results) == len(tickets)

    assert results
    checked_hops = 0
    for r in results:
        t_floor, cutoff, t_now = version_bounds[r.snapshot_version]
        first_hop = 1 if r.query.start_mode == "nodes" else 0
        for w in range(r.nodes.shape[0]):
            L = int(r.lengths[w])
            if L == 0:
                continue
            if r.query.start_mode == "nodes":
                assert int(r.times[w, 0]) == t_floor, r.snapshot_version
            hop_ts = r.times[w, first_hop:L]
            assert np.all(hop_ts >= cutoff), (r.snapshot_version, hop_ts)
            assert np.all(hop_ts <= t_now), (r.snapshot_version, hop_ts)
            checked_hops += len(hop_ts)
    assert checked_hops > 0
