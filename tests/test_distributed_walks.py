"""Distributed walk engine == single-device engine, bit-exact.

Runs in a subprocess with 8 forced host devices (device count must be set
before jax initializes)."""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import SamplerConfig
from repro.core.distributed import (
    gather_walks, init_sharded_walks, make_distributed_walker,
    partition_edges)
from repro.core.edge_store import store_from_arrays
from repro.core.temporal_index import build_index, node_range, temporal_cutoff
from repro.core.samplers import pick_in_neighborhood
from repro.data.synthetic import powerlaw_temporal_graph

N, E, D, L = 256, 4000, 8, 12
g = powerlaw_temporal_graph(N, E, seed=4)
scfg = SamplerConfig(bias="exponential", mode="index")

# ---- single-device reference with the SAME (walk_id, step) RNG ----------
store = store_from_arrays(g.src, g.dst, g.ts, edge_capacity=8192,
                          node_capacity=N)
idx = build_index(store, N)
W = 128
rng = np.random.default_rng(0)
start_nodes = rng.integers(0, N, W).astype(np.int32)
start_times = np.full(W, -1, np.int32)

def ref_walks():
    nodes = np.full((W, L + 1), -1, np.int32)
    times = np.full((W, L + 1), -1, np.int32)
    lengths = np.ones(W, np.int32)
    nodes[:, 0] = start_nodes
    times[:, 0] = start_times
    cur_n = jnp.asarray(start_nodes)
    cur_t = jnp.asarray(start_times)
    alive = jnp.ones(W, bool)
    base = jax.random.PRNGKey(0)
    wid = jnp.arange(W)
    for step in range(L):
        a, b = node_range(idx, cur_n)
        c = temporal_cutoff(idx, a, b, cur_t)
        n = b - c
        has = alive & (n > 0)
        sk = jax.vmap(lambda w: jax.random.fold_in(
            jax.random.fold_in(base, step), w))(wid)
        u = jax.vmap(lambda k: jax.random.uniform(k, ()))(sk)
        k = jnp.clip(pick_in_neighborhood(idx, scfg, c, b, u, cur_n),
                     0, idx.edge_capacity - 1)
        nn = jnp.where(has, idx.ns_dst[k], cur_n)
        nt = jnp.where(has, idx.ns_ts[k], cur_t)
        hnp = np.asarray(has)
        nodes[hnp, int(1 + step) if False else 0] = nodes[hnp, 0]  # noop
        for w in range(W):
            if hnp[w]:
                nodes[w, lengths[w]] = int(nn[w])
                times[w, lengths[w]] = int(nt[w])
                lengths[w] += 1
        cur_n, cur_t, alive = nn, nt, has
    return nodes, times, lengths

ref_n, ref_t, ref_l = ref_walks()

# ---- distributed --------------------------------------------------------
mesh = jax.make_mesh((D,), ("data",))
idx_stacked, placement = partition_edges(g.src, g.dst, g.ts, N, D,
                                         edge_capacity_per_shard=4096)
# provision for the worst case: every walk converging on one shard
state = init_sharded_walks(D, 160, L, start_nodes, start_times, placement)
runner = make_distributed_walker(mesh, "data", idx_stacked, scfg,
                                 placement=placement, max_length=L,
                                 bucket_capacity=128)
out = runner(state)
got_n, got_t, got_l = gather_walks(out, W)
assert int(np.asarray(out.dropped).sum()) == 0, "bucket overflow"
np.testing.assert_array_equal(got_l, ref_l)
np.testing.assert_array_equal(got_n, ref_n)
np.testing.assert_array_equal(got_t, ref_t)
print("DISTRIBUTED_OK")
"""

pytestmark = pytest.mark.slow      # 8-device subprocess walk migration


def test_distributed_equals_single_device():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "DISTRIBUTED_OK" in out.stdout, \
        (out.stdout[-1500:], out.stderr[-3000:])
