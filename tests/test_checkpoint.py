"""Checkpoint save/restore: roundtrip, atomicity contract, elastic remesh
(the remesh path itself runs in a subprocess with a forced device count)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt


def _tree():
    return {"layer": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                      "b": jnp.ones((4,), jnp.float32)},
            "emb": {"table": jnp.full((8, 2), 3.0)}}


def test_roundtrip(tmp_path):
    tree = _tree()
    ckpt.save(str(tmp_path), tree, step=7)
    assert ckpt.latest_step(str(tmp_path)) == 7
    zero = jax.tree.map(jnp.zeros_like, tree)
    back = ckpt.restore(str(tmp_path), zero)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_shape_mismatch_fails(tmp_path):
    ckpt.save(str(tmp_path), _tree(), step=1)
    bad = _tree()
    bad["layer"]["w"] = jnp.zeros((5, 5))
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), bad)


def test_restore_missing_leaf_fails(tmp_path):
    ckpt.save(str(tmp_path), _tree(), step=1)
    target = _tree()
    target["extra"] = jnp.zeros((2,))
    with pytest.raises(KeyError):
        ckpt.restore(str(tmp_path), target)


def test_overwrite_is_atomic(tmp_path):
    """A later save fully replaces the manifest (no torn state)."""
    ckpt.save(str(tmp_path), _tree(), step=1)
    t2 = jax.tree.map(lambda x: x + 1, _tree())
    ckpt.save(str(tmp_path), t2, step=2)
    assert ckpt.latest_step(str(tmp_path)) == 2
    back = ckpt.restore(str(tmp_path), jax.tree.map(jnp.zeros_like, t2))
    np.testing.assert_array_equal(np.asarray(back["layer"]["w"]),
                                  np.asarray(t2["layer"]["w"]))


ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.train import checkpoint as ckpt

path = sys.argv[1]
tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
# save from a 4x2 mesh
mesh1 = jax.make_mesh((4, 2), ("data", "model"))
sh1 = {"w": NamedSharding(mesh1, P("data", "model"))}
placed = jax.device_put(tree, sh1)
ckpt.save(path, placed, step=3)
# elastic restore onto a DIFFERENT mesh shape (2x4)
mesh2 = jax.make_mesh((2, 4), ("data", "model"))
sh2 = {"w": NamedSharding(mesh2, P("data", "model"))}
back = ckpt.restore(path, jax.tree.map(jnp.zeros_like, tree), shardings=sh2)
assert back["w"].sharding == sh2["w"]
np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(tree["w"]))
print("ELASTIC_OK")
"""


def test_elastic_remesh_restore(tmp_path):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", ELASTIC_SCRIPT,
                          str(tmp_path)], env=env, capture_output=True,
                         text=True, timeout=300)
    assert "ELASTIC_OK" in out.stdout, out.stderr[-2000:]
