"""Walk-axis sharding (repro.distributed.walks, DESIGN.md §10).

The 8-device case runs in a subprocess (device count must be forced before
jax initializes); the single-device case checks the engine wiring and
determinism in-process."""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs.base import SamplerConfig, SchedulerConfig, WalkConfig
from repro.core.validation import validate_walks
from repro.distributed.walks import generate_walks_sharded, walk_mesh

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import SamplerConfig, SchedulerConfig, WalkConfig
from repro.core.edge_store import store_from_arrays
from repro.core.temporal_index import build_index
from repro.core.validation import validate_walks
from repro.data.synthetic import powerlaw_temporal_graph
from repro.distributed.walks import generate_walks_sharded, walk_mesh

N = 256
g = powerlaw_temporal_graph(N, 6000, seed=4)
store = store_from_arrays(g.src, g.dst, g.ts, edge_capacity=8192,
                          node_capacity=N)
idx = build_index(store, N)
mesh = walk_mesh()
assert mesh.devices.size == 8
wcfg = WalkConfig(num_walks=512, max_length=10, start_mode="all_nodes")
scfg = SamplerConfig(bias="exponential", mode="weight")
cfg = SchedulerConfig(path="grouped", regroup="bucket")
res = generate_walks_sharded(idx, jax.random.PRNGKey(3), wcfg, scfg, cfg,
                             mesh=mesh)
assert res.nodes.shape == (512, 11)
# walk_offset keeps the global all_nodes assignment: walk w starts at
# node w % N when that node is active
nodes0 = np.asarray(res.nodes[:, 0])
live = nodes0 != -1
expect = np.arange(512) % N
assert live.sum() > 0 and np.all(nodes0[live] == expect[live])
# every hop is a causally valid window edge
rep = validate_walks(idx, res)
assert float(rep.walk_valid_frac) == 1.0
# deterministic for a fixed (key, device count)
res2 = generate_walks_sharded(idx, jax.random.PRNGKey(3), wcfg, scfg, cfg,
                              mesh=mesh)
assert jnp.array_equal(res.nodes, res2.nodes)
# walk count must divide the device count
try:
    generate_walks_sharded(idx, jax.random.PRNGKey(0),
                           WalkConfig(num_walks=510, max_length=4,
                                      start_mode="nodes"),
                           scfg, cfg, mesh=mesh)
    raise SystemExit("expected ValueError for 510 walks on 8 devices")
except ValueError:
    pass
print("SHARDED_OK")
"""


@pytest.mark.slow      # 8-device subprocess
def test_sharded_walks_8_devices():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "SHARDED_OK" in out.stdout, \
        (out.stdout[-1500:], out.stderr[-3000:])


def test_sharded_single_device_valid(small_index, key):
    wcfg = WalkConfig(num_walks=128, max_length=8, start_mode="nodes")
    scfg = SamplerConfig(bias="exponential", mode="index")
    cfg = SchedulerConfig(path="grouped")
    res = generate_walks_sharded(small_index, key, wcfg, scfg, cfg)
    assert res.nodes.shape == (128, 9)
    rep = validate_walks(small_index, res)
    assert float(rep.walk_valid_frac) == 1.0


def test_sharded_matches_walk_mesh_default(small_index, key):
    """Default mesh == explicit mesh over the same devices."""
    wcfg = WalkConfig(num_walks=64, max_length=6, start_mode="nodes")
    scfg = SamplerConfig(bias="uniform", mode="index")
    cfg = SchedulerConfig(path="grouped")
    a = generate_walks_sharded(small_index, key, wcfg, scfg, cfg)
    b = generate_walks_sharded(
        small_index, key, wcfg, scfg, cfg,
        mesh=walk_mesh(devices=np.asarray(jax.devices())))
    np.testing.assert_array_equal(np.asarray(a.nodes), np.asarray(b.nodes))
