"""Second-order (node2vec) sampling law + oracle differentials (paper §2.5).

The engine implements temporal node2vec as N2V_ROUNDS rounds of rejection
over the first-order proposal stream, falling back to the round-0 proposal
when every round rejects. That procedure has a *closed-form* law: with
first-order proposal probabilities π_w, acceptance β_w/β_max and
A = Σ_w π_w·β_w/β_max,

    P(w) = α_w·Σ_{r=0}^{R-1}(1-A)^r + π_w·(1-β_w/β_max)·(1-A)^{R-1}

where α_w = π_w·β_w/β_max. The first term is "accepted in some round", the
second is "all R rounds rejected and the round-0 proposal was w" (round 0's
rejection is correlated with the fallback, hence the exponent R-1).

Evidence layers:

* **exact law** — a small graph whose hop-2 neighborhood has one return,
  one common and one far candidate; sampled frequencies on both the
  fullwalk and grouped paths must match the closed form (chi-square gate
  from tests/test_samplers), and the two paths must agree bit-for-bit.
* **oracle differential** — the per-lane rejection scan
  (``walk_engine._lane_second_order``) against the dense O(W·E)
  ``kernels.ref.node2vec_step_ref``, fed the same uniform streams through
  an independent numpy proposal picker: bitwise-equal accepted picks.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SamplerConfig, SchedulerConfig, WalkConfig
from repro.core.edge_store import make_batch
from repro.core.samplers import BIAS_UNIFORM, node2vec_max_beta
from repro.core.temporal_index import node_range, temporal_cutoff
from repro.core.walk_engine import N2V_ROUNDS, _lane_second_order, generate_walks
from repro.core.window import ingest_nodonate, init_window
from repro.kernels.ref import node2vec_step_ref
from tests.test_samplers import chi2_crit


def _window(src, dst, ts, ec=128, nc=16):
    state = init_window(ec, nc, 10**6)
    return ingest_nodonate(state, make_batch(src, dst, ts, capacity=ec), nc)


def _rejection_law(pi, beta, p, q):
    """Closed-form law of the R-round rejection sampler (module docstring)."""
    beta_max = node2vec_max_beta(p, q)
    alpha = pi * beta / beta_max
    A = alpha.sum()
    r = 1.0 - A
    return alpha * (1.0 - r**N2V_ROUNDS) / A + pi * (1.0 - beta / beta_max) \
        * r ** (N2V_ROUNDS - 1)


@pytest.mark.statistical
def test_second_order_law_exact():
    """Hop-2 frequencies from a controlled graph match the closed-form
    rejection law on both walk paths, and the paths agree bitwise.

    Node 1's hop-2 neighborhood (prev = 0) has exactly one candidate per
    β class: node 0 (return, β = 1/p), node 2 (adjacent to prev via the
    0→2 edge, β = 1), node 3 (far, β = 1/q).
    """
    src = [0, 0, 1, 1, 1]
    dst = [1, 2, 0, 2, 3]
    ts = [10, 5, 11, 12, 13]
    state = _window(src, dst, ts, ec=64, nc=4)

    p, q = 0.5, 2.0
    scfg = SamplerConfig(mode="index", bias="uniform",
                         node2vec_p=p, node2vec_q=q)
    wcfg = WalkConfig(num_walks=32_768, max_length=3, start_mode="all_nodes")

    per_path = {}
    for path in ("fullwalk", "grouped"):
        res = generate_walks(state.index, jax.random.PRNGKey(11), wcfg,
                             scfg, SchedulerConfig(path=path))
        per_path[path] = (np.asarray(res.nodes), np.asarray(res.lengths))
    # layout invariance holds for the second-order path too
    np.testing.assert_array_equal(per_path["fullwalk"][0],
                                  per_path["grouped"][0])
    np.testing.assert_array_equal(per_path["fullwalk"][1],
                                  per_path["grouped"][1])

    nodes, lens = per_path["fullwalk"]
    cond = (nodes[:, 0] == 0) & (lens >= 3) & (nodes[:, 1] == 1)
    hops = nodes[cond, 2]
    n_cond = int(cond.sum())
    assert n_cond > 2000
    # only the three temporal candidates of node 1 after ts 10 can appear
    assert set(np.unique(hops).tolist()) <= {0, 2, 3}

    cands = np.array([0, 2, 3])
    beta = np.array([1.0 / p, 1.0, 1.0 / q])
    law = _rejection_law(np.full(3, 1.0 / 3.0), beta, p, q)
    np.testing.assert_allclose(law.sum(), 1.0, atol=1e-12)

    counts = np.array([(hops == w).sum() for w in cands], np.float64)
    exp_counts = law * n_cond
    assert (exp_counts > 5).all()
    chi2 = np.sum((counts - exp_counts) ** 2 / exp_counts)
    assert chi2 < chi2_crit(len(cands) - 1), (chi2, counts, exp_counts)


@pytest.mark.statistical
def test_second_order_law_no_history_is_first_order():
    """Hops with no previous node accept unconditionally (round 0), so the
    first hop follows the plain first-order law even under (p, q) != 1."""
    deg = 4
    src = [0] * deg
    dst = [1, 2, 3, 4]
    ts = [10, 11, 12, 13]
    state = _window(src, dst, ts, ec=64, nc=8)
    scfg = SamplerConfig(mode="index", bias="uniform",
                         node2vec_p=0.25, node2vec_q=4.0)
    wcfg = WalkConfig(num_walks=65_536, max_length=2, start_mode="all_nodes")
    res = generate_walks(state.index, jax.random.PRNGKey(12), wcfg, scfg,
                         SchedulerConfig(path="fullwalk"))
    nodes = np.asarray(res.nodes)
    hops = nodes[nodes[:, 0] == 0, 1]
    counts = np.array([(hops == w).sum() for w in (1, 2, 3, 4)], np.float64)
    exp_counts = np.full(deg, len(hops) / deg)
    chi2 = np.sum((counts - exp_counts) ** 2 / exp_counts)
    assert chi2 < chi2_crit(deg - 1), (chi2, counts)


# ---------------------------------------------------------------------------
# Per-u differential: engine rejection scan vs kernels.ref oracle
# ---------------------------------------------------------------------------


def _np_index_uniform(u, n):
    """Bitwise replica of samplers.index_uniform in numpy float32."""
    i = np.floor(u.astype(np.float32) * n.astype(np.float32)).astype(np.int32)
    return np.clip(i, 0, np.maximum(n - 1, 0))


def test_lane_second_order_matches_oracle_per_u():
    """The per-lane rejection scan is bitwise-equal to the dense oracle
    when both consume the same proposal/accept uniform streams, across
    mixed (p, q) lanes, no-history lanes, and empty neighborhoods; lanes
    with p == q == 1 pass the plain first-order pick through untouched."""
    nc, ec, W = 16, 128, 256
    rng = np.random.default_rng(42)
    n_e = 100
    src = rng.integers(0, nc, n_e).astype(np.int32)
    dst = rng.integers(0, nc, n_e).astype(np.int32)
    ts = np.sort(rng.integers(0, 1000, n_e)).astype(np.int32)
    state = _window(src.tolist(), dst.tolist(), ts.tolist(), ec=ec, nc=nc)
    index = state.index

    cur = jnp.asarray(rng.integers(0, nc, W), jnp.int32)
    cur_t = jnp.asarray(rng.integers(0, 1000, W), jnp.int32)
    a, b = node_range(index, cur)
    c = temporal_cutoff(index, a, b, cur_t)

    prev = rng.integers(0, nc, W).astype(np.int32)
    prev[rng.uniform(size=W) < 0.3] = -1        # no-history lanes
    pq_menu = np.array([[1.0, 1.0], [0.5, 2.0], [4.0, 0.25], [1.0, 3.0]],
                       np.float32)
    pq = pq_menu[rng.integers(0, len(pq_menu), W)]
    p, q = jnp.asarray(pq[:, 0]), jnp.asarray(pq[:, 1])

    us2 = jnp.asarray(rng.uniform(size=(N2V_ROUNDS, 2, W)), jnp.float32)
    u_plain = jnp.asarray(rng.uniform(size=W), jnp.float32)

    lane_bias = jnp.zeros((W,), jnp.int32) + BIAS_UNIFORM
    scfg = SamplerConfig(mode="index", bias="uniform")
    n = np.asarray(b - c)
    k_plain = jnp.asarray(np.asarray(c) +
                          _np_index_uniform(np.asarray(u_plain), n))
    k_eng = np.asarray(_lane_second_order(
        index, scfg, None, lane_bias, a, c, b, jnp.asarray(prev), k_plain,
        (p, q, us2)))

    # independent numpy proposal picker over the same uniform stream
    ks = np.stack([np.asarray(c) +
                   _np_index_uniform(np.asarray(us2[r, 0]), n)
                   for r in range(N2V_ROUNDS)])
    vs = np.asarray(us2[:, 1])
    valid = jnp.arange(ec, dtype=jnp.int32) < index.num_edges
    k_ref = np.asarray(node2vec_step_ref(
        index.ns_src, index.ns_dst, valid, jnp.asarray(prev),
        jnp.asarray(ks), jnp.asarray(vs), p, q))

    is_n2v = (pq[:, 0] != 1.0) | (pq[:, 1] != 1.0)
    assert is_n2v.any() and (~is_n2v).any()
    np.testing.assert_array_equal(k_eng[is_n2v], k_ref[is_n2v])
    # plain lanes keep the first-order pick bit-for-bit
    np.testing.assert_array_equal(k_eng[~is_n2v], np.asarray(k_plain)[~is_n2v])

    # no-history n2v lanes accept round 0 unconditionally
    nohist = is_n2v & (prev < 0)
    assert nohist.any()
    np.testing.assert_array_equal(k_eng[nohist], ks[0][nohist])
