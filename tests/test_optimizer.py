"""Optimizer unit tests: descent, clipping, schedule, int8 error-feedback
compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.optimizer import (
    AdamWConfig,
    apply_updates,
    compress_int8,
    global_norm,
    init_opt_state,
    lr_at,
)


def test_adamw_descends_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=1000, clip_norm=100.0)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = init_opt_state(params, cfg)
    for _ in range(200):
        grads = {"x": 2 * params["x"]}
        params, state, _ = apply_updates(params, grads, state, cfg)
    assert float(jnp.abs(params["x"]).max()) < 0.2


def test_grad_clipping():
    cfg = AdamWConfig(clip_norm=1.0, warmup_steps=0)
    params = {"x": jnp.zeros(4)}
    state = init_opt_state(params, cfg)
    big = {"x": jnp.full((4,), 1e6)}
    _, _, metrics = apply_updates(params, big, state, cfg)
    assert float(metrics["grad_norm"]) > 1e5   # raw norm reported


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(lr_at(cfg, jnp.asarray(s))) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 1.0) < 1e-6        # end of warmup
    assert lrs[-1] <= lrs[1]
    assert lrs[-1] >= 0.1 - 1e-6           # min ratio floor


def test_int8_compression_error_feedback():
    """Error feedback makes compression unbiased over repeated steps."""
    g = jnp.asarray([0.001, 0.5, -0.3, 1.0])
    err = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    for _ in range(100):
        deq, err = compress_int8(g, err)
        acc = acc + deq
    np.testing.assert_allclose(np.asarray(acc / 100), np.asarray(g),
                               atol=2e-3)


def test_compressed_training_matches_uncompressed_coarsely():
    k = jax.random.PRNGKey(0)
    w_true = jax.random.normal(k, (8,))

    def loss_grad(w):
        return 2 * (w - w_true)

    out = {}
    for comp in ("none", "int8"):
        cfg = AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0,
                          compression=comp)
        params = {"w": jnp.zeros(8)}
        state = init_opt_state(params, cfg)
        for _ in range(300):
            params, state, _ = apply_updates(
                params, {"w": loss_grad(params["w"])}, state, cfg)
        out[comp] = params["w"]
    err = float(jnp.max(jnp.abs(out["int8"] - w_true)))
    assert err < 0.05, err


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert abs(float(global_norm(t)) - 5.0) < 1e-6
