"""Dual-index invariants (paper §2.3): both views index the same edge
multiset; node regions and temporal cutoffs match a numpy oracle."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.edge_store import TS_PAD, store_from_arrays
from repro.core.temporal_index import (
    adjacency_contains,
    build_index,
    node_range,
    ranged_search,
    temporal_cutoff,
)


def test_views_same_multiset(small_index, small_graph):
    idx = small_index
    n = int(idx.num_edges)
    store_triples = sorted(zip(np.asarray(idx.store.src)[:n].tolist(),
                               np.asarray(idx.store.dst)[:n].tolist(),
                               np.asarray(idx.store.ts)[:n].tolist()))
    ns_triples = sorted(zip(np.asarray(idx.ns_src)[:n].tolist(),
                            np.asarray(idx.ns_dst)[:n].tolist(),
                            np.asarray(idx.ns_ts)[:n].tolist()))
    raw = sorted(zip(small_graph.src.tolist(), small_graph.dst.tolist(),
                     small_graph.ts.tolist()))
    assert store_triples == raw == ns_triples


def test_store_is_ts_sorted(small_index):
    ts = np.asarray(small_index.store.ts)
    assert np.all(np.diff(ts.astype(np.int64)) >= 0)


def test_ns_view_sorted_by_node_then_ts(small_index):
    idx = small_index
    n = int(idx.num_edges)
    src = np.asarray(idx.ns_src)[:n].astype(np.int64)
    ts = np.asarray(idx.ns_ts)[:n].astype(np.int64)
    key = src * (1 << 32) + ts
    assert np.all(np.diff(key) >= 0)


def test_node_ranges_match_numpy(small_index, small_graph):
    idx = small_index
    g = small_graph
    for v in [0, 1, 5, 50, 199, 255]:
        a, b = node_range(idx, jnp.asarray(v))
        expected = int(np.sum(g.src == v))
        assert int(b) - int(a) == expected


def test_temporal_cutoff_matches_numpy(small_index, small_graph):
    idx = small_index
    g = small_graph
    rng = np.random.default_rng(0)
    nodes = rng.integers(0, 200, 64)
    times = rng.integers(0, 10_000, 64)
    a, b = node_range(idx, jnp.asarray(nodes, jnp.int32))
    c = temporal_cutoff(idx, a, b, jnp.asarray(times, jnp.int32))
    for i, (v, t) in enumerate(zip(nodes, times)):
        mask = g.src == v
        expected = int(np.sum(g.ts[mask] > t))
        assert int(b[i]) - int(c[i]) == expected, (v, t)


def test_group_counts_match_numpy(small_index, small_graph):
    idx = small_index
    g = small_graph
    counts = np.asarray(idx.node_group_counts)
    for v in [0, 1, 2, 10, 100, 199]:
        expected = len(np.unique(g.ts[g.src == v]))
        assert counts[v] == expected


def test_adjacency_contains(small_index, small_graph):
    idx = small_index
    g = small_graph
    u0, w0 = int(g.src[0]), int(g.dst[0])
    assert bool(adjacency_contains(idx, jnp.asarray(u0), jnp.asarray(w0)))
    # a non-edge: find a pair not present
    pairs = set(zip(g.src.tolist(), g.dst.tolist()))
    for w in range(200):
        if (u0, w) not in pairs:
            assert not bool(adjacency_contains(idx, jnp.asarray(u0),
                                               jnp.asarray(w)))
            break


def test_prefix_arrays_monotone(small_index):
    pexp = np.asarray(small_index.pexp)
    plin = np.asarray(small_index.plin)
    assert np.all(np.diff(pexp) >= 0)
    assert np.all(np.diff(plin) >= 0)
    assert pexp[0] == 0 and plin[0] == 0


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 1000), min_size=1, max_size=200),
       st.integers(-5, 1005))
def test_ranged_search_is_searchsorted(values, target):
    arr = np.sort(np.asarray(values, np.int32))
    pad = np.full(256 - len(arr), TS_PAD, np.int32)
    arr_p = jnp.asarray(np.concatenate([arr, pad]))
    lo = jnp.asarray([0], jnp.int32)
    hi = jnp.asarray([len(arr)], jnp.int32)
    t = jnp.asarray([target], jnp.int32)
    got_strict = int(ranged_search(arr_p, lo, hi, t, strict=True)[0])
    got_ge = int(ranged_search(arr_p, lo, hi, t, strict=False)[0])
    assert got_strict == int(np.searchsorted(arr, target, side="right"))
    assert got_ge == int(np.searchsorted(arr, target, side="left"))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 2), st.integers(1, 100))
def test_build_index_arbitrary_ts(base_ts, n):
    """Index build is robust to arbitrary timestamp magnitudes."""
    rng = np.random.default_rng(n)
    src = rng.integers(0, 8, n).astype(np.int32)
    dst = rng.integers(0, 8, n).astype(np.int32)
    span = min(1000, 2**31 - 2 - base_ts)
    ts = (base_ts + rng.integers(0, span + 1, n)).astype(np.int32)
    store = store_from_arrays(src, dst, ts, edge_capacity=128,
                              node_capacity=8)
    idx = build_index(store, 8)
    assert int(idx.num_edges) == n
    assert np.all(np.isfinite(np.asarray(idx.pexp)))
