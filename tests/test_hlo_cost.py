"""HLO static analyzer regression tests — the roofline's foundation.

The key property: scan == unroll (XLA's builtin cost_analysis fails this
by counting while bodies once)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import analyze_hlo_text, shape_elems_bytes
from repro.launch.roofline import collective_bytes_from_hlo

pytestmark = pytest.mark.slow      # HLO lowering / static-analyzer regressions


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_scan_equals_unroll_flops():
    def scanned(a, ws):
        def body(x, w):
            return x @ w, None
        y, _ = jax.lax.scan(body, a, ws)
        return y

    def unrolled(a, ws):
        for i in range(10):
            a = a @ ws[i]
        return a

    a = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
    fs = analyze_hlo_text(_compile(scanned, a, ws).as_text()).flops
    fu = analyze_hlo_text(_compile(unrolled, a, ws).as_text()).flops
    expected = 2 * 128 ** 3 * 10
    assert fs == pytest.approx(expected, rel=0.01)
    assert fu == pytest.approx(expected, rel=0.01)


def test_nested_scan_flops():
    def nested(a, ws):
        def outer(x, w3):
            def inner(y, w):
                return y @ w, None
            y, _ = jax.lax.scan(inner, x, w3)
            return y, None
        y, _ = jax.lax.scan(outer, a, ws.reshape(2, 5, 128, 128))
        return y

    a = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
    f = analyze_hlo_text(_compile(nested, a, ws).as_text()).flops
    assert f == pytest.approx(2 * 128 ** 3 * 10, rel=0.01)


def test_builtin_cost_analysis_undercounts_scans():
    """Documents WHY we use the custom analyzer (if this ever starts
    passing with ratio 1, XLA fixed it and we can reconsider)."""
    def scanned(a, ws):
        def body(x, w):
            return x @ w, None
        y, _ = jax.lax.scan(body, a, ws)
        return y
    a = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
    c = _compile(scanned, a, ws)
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    builtin = float(ca.get("flops", 0))
    ours = analyze_hlo_text(c.as_text()).flops
    assert builtin < 0.5 * ours


def test_dot_general_batched_flops():
    def f(q, k):
        return jnp.einsum("bqhd,bkhd->bhqk", q, k)
    q = jax.ShapeDtypeStruct((2, 64, 4, 32), jnp.float32)
    k = jax.ShapeDtypeStruct((2, 64, 4, 32), jnp.float32)
    flops = analyze_hlo_text(_compile(f, q, k).as_text()).flops
    assert flops == pytest.approx(2 * 2 * 4 * 64 * 64 * 32, rel=0.05)


def test_shape_parse():
    assert shape_elems_bytes("bf16[128,4096]{1,0}") == (128 * 4096,
                                                        128 * 4096 * 2)
    e, b = shape_elems_bytes("(f32[8], s32[4])")
    assert e == 12 and b == 48


def test_collective_parser_result_shapes():
    text = """
  %ar = f32[65536,16384]{1,0} all-reduce(%dot.119), channel_id=17
  %ag = bf16[32,1024]{1,0} all-gather(%p), dims={0}
  %done = f32[8] all-reduce-done(%start)
"""
    out = collective_bytes_from_hlo(text)
    assert out["all-reduce"] == 65536 * 16384 * 4
    assert out["all-gather"] == 32 * 1024 * 2
