"""Serving subsystem (DESIGN.md §11): coalesced == solo bit-identity,
shape buckets, queue backpressure, snapshot double-buffer."""
import dataclasses

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.base import (
    EngineConfig,
    SamplerConfig,
    SchedulerConfig,
    ServeConfig,
    WalkConfig,
    WindowConfig,
)
from repro.core.edge_store import make_batch
from repro.core.validation import validate_walks_np
from repro.core.walk_engine import NODE_PAD, generate_walk_lanes
from repro.core.window import ingest, init_window
from repro.data.synthetic import chronological_batches, powerlaw_temporal_graph
from repro.serve import (
    WalkQuery,
    WalkService,
    bucketize,
    pack_queries,
    slice_result,
)

NC = 128


def _engine_cfg(**sched_kw):
    return EngineConfig(
        window=WindowConfig(duration=4000, edge_capacity=4096,
                            node_capacity=NC),
        sampler=SamplerConfig(mode="index"),
        scheduler=SchedulerConfig(path="grouped", **sched_kw))


def _serve_cfg(**kw):
    kw.setdefault("lane_buckets", (8, 16, 64))
    kw.setdefault("length_buckets", (4, 8))
    return ServeConfig(**kw)


# module-level cache rather than a fixture: the property test below must
# not take fixture arguments (the hypothesis fallback shim presents a
# zero-argument signature), so both share this helper.
_SERVICE_CACHE = {}


def _loaded_service():
    if not _SERVICE_CACHE:
        g = powerlaw_temporal_graph(100, 3000, seed=11)
        svc = WalkService(_engine_cfg(), _serve_cfg())
        for bs, bd, bt in chronological_batches(g, 3):
            svc.ingest(bs, bd, bt)
        _SERVICE_CACHE["svc"] = (g, svc)
    return _SERVICE_CACHE["svc"]


@pytest.fixture(scope="module")
def loaded_service():
    return _loaded_service()


BIASES = ("uniform", "linear", "exponential")


def _query(bias_i, edges_mode, n_lanes, max_length, seed, node0):
    if edges_mode:
        return WalkQuery(num_walks=n_lanes, start_mode="edges",
                         bias=BIASES[bias_i],
                         start_bias=BIASES[(bias_i + 1) % 3],
                         max_length=max_length, seed=seed)
    starts = tuple((node0 + 7 * i) % NC for i in range(n_lanes))
    return WalkQuery(start_nodes=starts, bias=BIASES[bias_i],
                     max_length=max_length, seed=seed)


def _assert_solo_equals_coalesced(svc, queries):
    tickets = [svc.submit(q, strict=True) for q in queries]
    while svc.pending_count:
        svc.step()
    for t, q in zip(tickets, queries):
        r = svc.poll(t)
        assert r is not None
        sn, st_, sl = svc.run_query_solo(q)
        assert np.array_equal(r.nodes, sn), q
        assert np.array_equal(r.times, st_), q
        assert np.array_equal(r.lengths, sl), q


def test_mixed_bias_equivalence_full_grid(loaded_service):
    """Acceptance: a coalesced heterogeneous batch is bit-identical to
    per-query solo runs — all three biases × both start modes."""
    _, svc = loaded_service
    queries = []
    for i, bias in enumerate(BIASES):
        queries.append(WalkQuery(start_nodes=(1 + i, 30 + i, 60 + i),
                                 bias=bias, max_length=5 + i,
                                 seed=100 + i))
        queries.append(WalkQuery(num_walks=3, start_mode="edges", bias=bias,
                                 start_bias=BIASES[(i + 1) % 3],
                                 max_length=4 + i, seed=200 + i))
    _assert_solo_equals_coalesced(svc, queries)


@settings(max_examples=12, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2), st.booleans(),
                          st.integers(1, 4), st.integers(2, 8),
                          st.integers(0, 10_000), st.integers(0, NC - 1)),
                min_size=1, max_size=6))
def test_mixed_bias_equivalence_property(descriptors):
    """Property: any mix of (bias, start mode, lanes, length, seed) packs
    into coalesced batches bit-identical to each query alone."""
    _, svc = _loaded_service()
    queries = [_query(*d) for d in descriptors]
    _assert_solo_equals_coalesced(svc, queries)


def test_served_walks_are_causal(loaded_service):
    """Coalesced answers are real temporal walks (hop-valid on the graph)."""
    g, svc = loaded_service
    queries = [WalkQuery(start_nodes=tuple(range(40)), bias=b, max_length=8,
                         seed=i) for i, b in enumerate(BIASES)]
    tickets = [svc.submit(q, strict=True) for q in queries]
    while svc.pending_count:
        svc.step()
    for t in tickets:
        r = svc.poll(t)
        hv, _ = validate_walks_np((g.src, g.dst, g.ts), r.nodes, r.times,
                                  r.lengths)
        assert hv == 1.0
        # rows past a lane's length are PAD; lengths respect max_length+1
        assert r.lengths.max() <= r.query.max_length + 1
        for w in range(r.nodes.shape[0]):
            assert np.all(r.nodes[w, r.lengths[w]:] == NODE_PAD)


def test_lane_paths_equivalent(loaded_service):
    """fullwalk / grouped-bucket / grouped-lexsort serve identical walks."""
    _, svc = loaded_service
    q = WalkQuery(start_nodes=tuple(range(24)), bias="exponential",
                  max_length=8, seed=9)
    ref = None
    for path, regroup in (("fullwalk", "bucket"), ("grouped", "bucket"),
                          ("grouped", "lexsort")):
        svc2 = WalkService(_engine_cfg(regroup=regroup), _serve_cfg(),
                           state=svc.snapshots.current)
        svc2.sched_cfg = dataclasses.replace(svc2.sched_cfg, path=path,
                                             regroup=regroup)
        got = svc2.run_query_solo(q)
        if ref is None:
            ref = got
        else:
            for a, b in zip(ref, got):
                assert np.array_equal(a, b), (path, regroup)


def test_queue_backpressure_and_drop_accounting():
    svc = WalkService(_engine_cfg(), _serve_cfg(queue_capacity=3))
    g = powerlaw_temporal_graph(100, 500, seed=2)
    svc.ingest(g.src, g.dst, g.ts)
    qs = [WalkQuery(start_nodes=(i % NC,), max_length=4, seed=i)
          for i in range(5)]
    tickets = [svc.submit(q) for q in qs]
    assert tickets[:3] == [0, 1, 2] and tickets[3:] == [None, None]
    assert svc.stats.dropped_backpressure == 2
    assert svc.stats.submitted == 3
    with pytest.raises(Exception):
        svc.submit(qs[0], strict=True)
    served = svc.drain()
    assert len(served) == 3
    # queue drained: submits accepted again
    assert svc.submit(qs[3]) is not None


def test_queue_full_strict_raises_queuefull():
    """strict=True backpressure raises the typed QueueFull, and the queue
    recovers exactly: drop accounting never double-counts strict raises."""
    from repro.serve import QueueFull
    svc = WalkService(_engine_cfg(), _serve_cfg(queue_capacity=2))
    g = powerlaw_temporal_graph(100, 400, seed=4)
    svc.ingest(g.src, g.dst, g.ts)
    q = WalkQuery(start_nodes=(1,), max_length=4, seed=0)
    assert svc.submit(q) is not None and svc.submit(q) is not None
    with pytest.raises(QueueFull, match="capacity 2"):
        svc.submit(q, strict=True)
    # a strict raise is not a drop; a non-strict overflow is
    assert svc.stats.dropped_backpressure == 0
    assert svc.submit(q) is None
    assert svc.stats.dropped_backpressure == 1
    svc.drain()
    assert svc.submit(q, strict=True) is not None


def test_latency_percentile_degenerate_histories():
    """Empty history -> NaN (not a crash); one sample -> that sample at
    every percentile; counters stay zero-safe."""
    import math
    from repro.serve import ServeStats
    s = ServeStats()
    assert math.isnan(s.latency_percentile(50))
    assert math.isnan(s.p50_ms) and math.isnan(s.p99_ms)
    assert s.walks_per_s == 0.0 and s.lane_occupancy == 0.0
    s.latencies_s.append(0.25)
    for q in (0, 50, 99, 100):
        assert s.latency_percentile(q) == pytest.approx(0.25)
    assert s.p99_ms == pytest.approx(250.0)


def test_oversize_query_dropped_or_rejected():
    svc = WalkService(_engine_cfg(), _serve_cfg())
    big = WalkQuery(start_nodes=tuple(range(65)), max_length=4)   # > 64 lanes
    long = WalkQuery(start_nodes=(1,), max_length=9)              # > 8 hops
    assert svc.submit(big) is None and svc.submit(long) is None
    assert svc.stats.dropped_oversize == 2
    with pytest.raises(ValueError):
        svc.submit(big, strict=True)


def test_oversize_contract_matrix():
    """All four strict × drop_oversize cells of the submit contract:
    silent drop / typed refusal / strict raise — and exactly the first
    two count as shed work (stats + the canonical drop taxonomy)."""
    from repro.obs.registry import DropCounters, MetricsRegistry
    from repro.serve import OversizeQuery
    big = WalkQuery(start_nodes=tuple(range(65)), max_length=4)
    assert issubclass(OversizeQuery, ValueError)   # older callers' catches

    # drop_oversize=True: non-strict drops silently (counted) ...
    reg = MetricsRegistry()
    svc = WalkService(_engine_cfg(), _serve_cfg(drop_oversize=True),
                      registry=reg)
    assert svc.submit(big) is None
    assert svc.stats.dropped_oversize == 1
    assert DropCounters.from_registry(reg).oversize == 1
    # ... strict raises, NOT counted (the raise is the caller's handling)
    with pytest.raises(OversizeQuery, match="largest bucket"):
        svc.submit(big, strict=True)
    assert svc.stats.dropped_oversize == 1
    assert DropCounters.from_registry(reg).oversize == 1

    # drop_oversize=False: non-strict raises the typed refusal (counted —
    # the service shed traffic mid-stream) ...
    reg2 = MetricsRegistry()
    svc2 = WalkService(_engine_cfg(), _serve_cfg(drop_oversize=False),
                       registry=reg2)
    with pytest.raises(OversizeQuery, match="refusing"):
        svc2.submit(big)
    assert svc2.stats.dropped_oversize == 1
    assert DropCounters.from_registry(reg2).oversize == 1
    # ... strict raises identically but stays uncounted
    with pytest.raises(OversizeQuery):
        svc2.submit(big, strict=True)
    assert svc2.stats.dropped_oversize == 1
    assert DropCounters.from_registry(reg2).oversize == 1
    # rightsized traffic is unaffected in both configs
    assert svc2.submit(WalkQuery(start_nodes=(1,), max_length=4)) is not None


def test_drain_scoped_poll_after_drain(loaded_service):
    """drain() returns exactly the queries it completed; results from
    earlier step()/tick() calls stay poll-able afterwards (the regression:
    drain used to destroy them), and drained tickets are delivered —
    popped, not double-pollable."""
    _, svc = loaded_service
    ta = svc.submit(WalkQuery(start_nodes=(1, 2), max_length=4, seed=77),
                    strict=True)
    svc.step()                     # completes ta into the poll buffer
    tb = svc.submit(WalkQuery(start_nodes=(3,), max_length=4, seed=78),
                    strict=True)
    tc = svc.submit(WalkQuery(num_walks=2, start_mode="edges", max_length=4,
                              seed=79), strict=True)
    drained = svc.drain()
    assert {r.ticket for r in drained} == {tb, tc}
    ra = svc.poll(ta)
    assert ra is not None and ra.ticket == ta
    assert svc.poll(tb) is None and svc.poll(tc) is None
    assert svc.drain() == []       # empty drain is a no-op


def test_solo_runs_are_accounted():
    """run_query_solo participates in throughput accounting: walks, hops,
    device busy time, and the path="solo" dispatch counter — without
    touching the queue/latency stats (nothing was queued)."""
    from repro.obs.registry import MetricsRegistry
    g = powerlaw_temporal_graph(100, 500, seed=3)
    reg = MetricsRegistry()
    svc = WalkService(_engine_cfg(), _serve_cfg(), registry=reg)
    svc.ingest(g.src, g.dst, g.ts)
    q = WalkQuery(start_nodes=(1, 2, 3), max_length=4, seed=5)
    _, _, lengths = svc.run_query_solo(q)
    assert svc.stats.solo_queries == 1
    assert svc.stats.walks == 3
    assert svc.stats.hops == int(np.sum(np.clip(lengths - 1, 0, None)))
    assert svc.stats.busy_s > 0.0
    assert len(svc.stats.sample_s) == 1
    assert reg.value("walks_dispatched_total", labels={"path": "solo"}) == 3
    # not "served" traffic: no ticket, no completion, no latency sample
    assert svc.stats.completed == 0 and svc.stats.submitted == 0
    assert len(svc.stats.latencies_s) == 0


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.booleans(), st.integers(1, 24),
                          st.integers(1, 8), st.integers(0, 999)),
                min_size=1, max_size=10))
def test_take_batch_fairness_property(descs):
    """Property (the docstring's no-overtaking claim): every sealed batch
    is single-group, fits the lane budget, starts at the oldest pending
    query, and takes exactly a PREFIX of its group in admission order —
    so no query is ever overtaken by a younger same-group query."""
    from repro.serve import group_key
    _, svc = _loaded_service()
    assert svc.pending_count == 0 and svc.inflight_count == 0
    for edges_mode, lanes, length, seed in descs:
        if edges_mode:
            q = WalkQuery(num_walks=lanes, start_mode="edges",
                          max_length=length, seed=seed)
        else:
            q = WalkQuery(start_nodes=tuple(range(lanes)),
                          max_length=length, seed=seed)
        assert svc.submit(q, strict=True) is not None
    budget = svc.serve_cfg.lane_buckets[-1]
    lb = svc.serve_cfg.length_buckets
    while svc.pending_count:
        before = list(svc._pending)
        key, take, lanes = svc._take_batch()
        assert take and lanes == sum(e.query.num_lanes for e in take)
        assert lanes <= budget
        assert all(group_key(e.query, lb) == key for e in take)
        # head-of-line: the batch's group is the oldest query's group,
        # and that query leads the batch
        assert take[0].ticket == before[0].ticket
        assert group_key(before[0].query, lb) == key
        # prefix rule == zero same-group overtaking: the taken tickets
        # are exactly the first len(take) same-group tickets
        same = [e.ticket for e in before if group_key(e.query, lb) == key]
        assert [e.ticket for e in take] == same[:len(take)]
        # progress: taken queries actually left the queue
        assert len(svc._pending) == len(before) - len(take)


PACK_BUCKETS = (8, 16, 64)


@settings(max_examples=40, deadline=None)
@given(st.booleans(),
       st.lists(st.tuples(st.integers(1, 24), st.integers(1, 8),
                          st.integers(0, 10_000)),
                min_size=0, max_size=6))
def test_pack_queries_roundtrip_property(edges_mode, descs):
    """Property: any same-mode query mix either exceeds every lane bucket
    (refused) or packs back-to-back with exact per-lane params — and every
    admitted query's result slice round-trips losslessly."""
    queries = []
    for lanes, length, seed in descs:
        if edges_mode:
            queries.append(WalkQuery(num_walks=lanes, start_mode="edges",
                                     max_length=length, seed=seed))
        else:
            queries.append(WalkQuery(start_nodes=tuple(range(lanes)),
                                     max_length=length, seed=seed))
    total = sum(q.num_lanes for q in queries)
    len_bucket = bucketize(max((q.max_length for q in queries), default=1),
                           (4, 8))
    bucket = bucketize(total, PACK_BUCKETS)
    if bucket is None:
        assert total > PACK_BUCKETS[-1]
        with pytest.raises(ValueError, match="exceed"):
            pack_queries(queries, PACK_BUCKETS[-1], len_bucket)
        return
    # smallest-bucket property, incl. the exact-boundary case
    assert total <= bucket
    assert all(b < total for b in PACK_BUCKETS if b < bucket)
    params, slices = pack_queries(queries, bucket, len_bucket)

    off = 0
    rid = np.asarray(params.rid)
    wid = np.asarray(params.wid)
    ml = np.asarray(params.max_len)
    active = np.asarray(params.active)
    for q, sl in zip(queries, slices):
        assert sl.offset == off and sl.count == q.num_lanes
        rows = slice(sl.offset, sl.offset + sl.count)
        assert (rid[rows] == np.int32(q.seed)).all()
        assert (wid[rows] == np.arange(q.num_lanes)).all()
        assert (ml[rows] == q.max_length).all()
        if not edges_mode:
            assert tuple(np.asarray(params.start_node)[rows]) == q.start_nodes
        off += q.num_lanes
    assert off == total
    assert active[:total].all() and not active[total:].any()

    # lossless slice round-trip: every batch cell is unique, so equality
    # proves each query got exactly its own rows/columns back
    L1 = len_bucket + 1
    nodes = np.arange(bucket * L1, dtype=np.int32).reshape(bucket, L1)
    times = nodes + 1_000_000
    lengths = np.arange(bucket, dtype=np.int32)
    for q, sl in zip(queries, slices):
        qn, qt, ql = slice_result(nodes, times, lengths, sl, q)
        rows = slice(sl.offset, sl.offset + sl.count)
        assert qn.shape == (q.num_lanes, q.max_length + 1)
        np.testing.assert_array_equal(qn, nodes[rows, :q.max_length + 1])
        np.testing.assert_array_equal(qt, times[rows, :q.max_length + 1])
        np.testing.assert_array_equal(ql, lengths[rows])


def test_pack_queries_edge_cases():
    """Zero-walk batches, exact-boundary full-capacity packs, one-over
    refusals, and over-length refusals."""
    params, slices = pack_queries([], 8, 4)
    assert slices == []
    assert not np.asarray(params.active).any()
    qs = [WalkQuery(start_nodes=tuple(range(5)), max_length=4),
          WalkQuery(start_nodes=tuple(range(3)), max_length=4)]
    params, slices = pack_queries(qs, 8, 4)       # full capacity: 5 + 3 == 8
    assert np.asarray(params.active).all()
    assert [(s.offset, s.count) for s in slices] == [(0, 5), (5, 3)]
    with pytest.raises(ValueError, match="exceed"):
        pack_queries(qs + [WalkQuery(start_nodes=(1,), max_length=4)], 8, 4)
    with pytest.raises(ValueError, match="length"):
        pack_queries([WalkQuery(start_nodes=(1,), max_length=5)], 8, 4)


def test_lane_owners_routing():
    """Host-side owner routing matches the device claim rule; padding
    lanes map to -1."""
    from repro.distributed.placement import make_placement
    from repro.serve import lane_owners
    params, _ = pack_queries(
        [WalkQuery(start_nodes=(0, 63, 64, 127), max_length=4)], 8, 4)
    own = lane_owners(params, make_placement("range", 2, 128))
    assert own.tolist() == [0, 0, 1, 1, -1, -1, -1, -1]
    own1 = lane_owners(params, make_placement("range", 1, 128))
    assert own1.tolist() == [0, 0, 0, 0] + [-1] * 4
    # hash policy routes through the same host mirror; still -1 on padding
    hown = lane_owners(params, make_placement("hash", 2, 128))
    assert (hown[:4] >= 0).all() and (hown[:4] <= 1).all()
    assert hown[4:].tolist() == [-1] * 4


def test_shape_buckets():
    assert bucketize(1, (8, 16)) == 8
    assert bucketize(8, (8, 16)) == 8
    assert bucketize(9, (8, 16)) == 16
    assert bucketize(17, (8, 16)) is None
    params, slices = pack_queries(
        [WalkQuery(start_nodes=(1, 2), max_length=3),
         WalkQuery(num_walks=3, start_mode="edges", max_length=4)], 8, 4)
    assert params.start_node.shape == (8,)
    assert [(s.offset, s.count) for s in slices] == [(0, 2), (2, 3)]
    assert np.asarray(params.active).tolist() == [True] * 5 + [False] * 3
    with pytest.raises(ValueError):
        pack_queries([WalkQuery(start_nodes=tuple(range(9)))], 8, 16)


def test_snapshot_double_buffer_consistency():
    """begin_ingest keeps the current snapshot serveable; publish swaps in
    a window byte-identical to the donating ingest path."""
    g = powerlaw_temporal_graph(100, 2000, seed=5)
    batches = list(chronological_batches(g, 4))
    svc = WalkService(_engine_cfg(), _serve_cfg())
    ref = init_window(4096, NC, 4000)
    for bs, bd, bt in batches[:-1]:
        svc.ingest(bs, bd, bt)
        ref = ingest(ref, make_batch(bs, bd, bt, capacity=svc.batch_capacity),
                     NC)
    bs, bd, bt = batches[-1]
    svc.begin_ingest(bs, bd, bt)
    assert svc.snapshots.ingest_in_flight
    v0 = svc.snapshots.version
    # the front buffer still serves while the back buffer builds
    t = svc.submit(WalkQuery(start_nodes=(1, 2, 3), max_length=4, seed=1),
                   strict=True)
    svc.step()
    r_old = svc.poll(t)
    assert r_old is not None
    before = [np.asarray(x) for x in jax.tree.leaves(svc.snapshots.current)]
    svc.publish()
    assert svc.snapshots.version == v0 + 1
    ref = ingest(ref, make_batch(bs, bd, bt, capacity=svc.batch_capacity), NC)
    after = jax.tree.leaves(svc.snapshots.current)
    for got, want in zip(after, jax.tree.leaves(ref)):
        assert np.array_equal(np.asarray(got), np.asarray(want))
    # the published window is a different state than the served snapshot
    changed = any(not np.array_equal(a, np.asarray(b))
                  for a, b in zip(before, after))
    assert changed
    with pytest.raises(RuntimeError):
        svc.publish()                      # nothing in flight anymore


def test_serving_rejects_unsupported_configs():
    with pytest.raises(ValueError):
        WalkService(dataclasses.replace(
            _engine_cfg(), sampler=SamplerConfig(mode="weight")))
    with pytest.raises(ValueError):
        WalkService(dataclasses.replace(
            _engine_cfg(), sampler=SamplerConfig(mode="index",
                                                 node2vec_p=2.0)))
    # tiled scheduler silently falls back to the (equivalent) grouped path
    svc = WalkService(dataclasses.replace(
        _engine_cfg(), scheduler=SchedulerConfig(path="tiled")))
    assert svc.sched_cfg.path == "grouped"
    # the engine itself refuses a tiled lane batch
    g = powerlaw_temporal_graph(50, 500, seed=1)
    svc2 = WalkService(_engine_cfg(), _serve_cfg())
    svc2.ingest(g.src, g.dst, g.ts)
    params, _ = pack_queries([WalkQuery(start_nodes=(1,), max_length=4)],
                             8, 4)
    with pytest.raises(ValueError):
        generate_walk_lanes(
            svc2.snapshots.current.index, svc2.base_key, params,
            WalkConfig(num_walks=8, max_length=4, start_mode="nodes"),
            SamplerConfig(mode="index"), SchedulerConfig(path="tiled"))


def test_query_validation():
    with pytest.raises(ValueError):
        WalkQuery(start_nodes=(), start_mode="nodes")
    with pytest.raises(ValueError):
        WalkQuery(start_nodes=(1,), bias="gaussian")
    with pytest.raises(ValueError):
        WalkQuery(start_nodes=(1,), max_length=0)
    with pytest.raises(ValueError):
        WalkQuery(start_mode="edges", num_walks=0)
    with pytest.raises(ValueError):
        WalkQuery(start_nodes=(1,), seed=1 << 31)        # int32 round-trip
    with pytest.raises(ValueError):
        WalkQuery(start_nodes=(1 << 31,))
    assert WalkQuery(start_nodes=(1, 2)).num_lanes == 2
    assert WalkQuery(start_mode="edges", num_walks=5).num_lanes == 5


# ---------------------------------------------------------------------------
# Alias-table and second-order (node2vec) query lanes (DESIGN.md §17)
# ---------------------------------------------------------------------------


def _loaded_table_service():
    """Service whose window carries alias tables (table_weight set) but
    whose config bias stays a closed form — queries opt into the table."""
    if "tsvc" not in _SERVICE_CACHE:
        g, _ = _loaded_service()
        cfg = dataclasses.replace(
            _engine_cfg(),
            sampler=SamplerConfig(mode="index", table_weight="exponential"))
        tsvc = WalkService(cfg, _serve_cfg())
        for bs, bd, bt in chronological_batches(g, 3):
            tsvc.ingest(bs, bd, bt)
        _SERVICE_CACHE["tsvc"] = tsvc
    return _SERVICE_CACHE["svc"][0], _SERVICE_CACHE["tsvc"]


def test_table_and_node2vec_mixed_equivalence():
    """Acceptance: coalesced lanes with table-bias and node2vec codes are
    bit-identical to solo runs, mixed with plain closed-form queries."""
    _, tsvc = _loaded_table_service()
    queries = [
        WalkQuery(start_nodes=(2, 31, 63), bias="table", max_length=5,
                  seed=301),
        WalkQuery(start_nodes=(4, 40), bias="uniform", n2v_p=0.5,
                  n2v_q=2.0, max_length=6, seed=302),
        WalkQuery(start_nodes=(5, 50, 77), bias="table", n2v_p=2.0,
                  n2v_q=0.25, max_length=4, seed=303),
        WalkQuery(num_walks=3, start_mode="edges", bias="table",
                  start_bias="linear", max_length=5, seed=304),
        WalkQuery(start_nodes=(8, 16), bias="linear", max_length=7,
                  seed=305),
    ]
    _assert_solo_equals_coalesced(tsvc, queries)


def test_plain_queries_unaffected_by_tables():
    """Queries not coded table/second-order are bit-identical between a
    table-carrying service and a plain one over the same stream."""
    _, svc = _loaded_service()
    _, tsvc = _loaded_table_service()
    for q in (WalkQuery(start_nodes=(3, 33, 93), bias="exponential",
                        max_length=6, seed=400),
              WalkQuery(num_walks=4, start_mode="edges", bias="uniform",
                        start_bias="exponential", max_length=5, seed=401)):
        n0, t0, l0 = svc.run_query_solo(q)
        n1, t1, l1 = tsvc.run_query_solo(q)
        assert np.array_equal(n0, n1) and np.array_equal(t0, t1)
        assert np.array_equal(l0, l1)


def test_submit_refuses_table_queries_without_tables():
    """A service whose window has no alias tables refuses table-coded
    queries at submit time through the capability chokepoint."""
    _, svc = _loaded_service()
    with pytest.raises(ValueError, match="table"):
        svc.submit(WalkQuery(start_nodes=(1,), bias="table", max_length=4),
                   strict=True)
    # second-order queries need no tables; grouped serving accepts them
    t = svc.submit(WalkQuery(start_nodes=(1,), n2v_p=2.0, max_length=4),
                   strict=True)
    while svc.pending_count:
        svc.step()
    assert svc.poll(t) is not None


def test_second_order_query_validation():
    with pytest.raises(ValueError, match="positive"):
        WalkQuery(start_nodes=(1,), n2v_p=0.0)
    with pytest.raises(ValueError, match="positive"):
        WalkQuery(start_nodes=(1,), n2v_q=-1.0)
    with pytest.raises(ValueError, match="start_bias"):
        WalkQuery(start_nodes=(1,), start_bias="table")
    assert WalkQuery(start_nodes=(1,)).second_order is False
    assert WalkQuery(start_nodes=(1,), n2v_q=2.0).second_order is True
