"""Model-layer unit tests: chunked attention vs naive, chunked xent vs
dense, MoE dispatch properties, RoPE invariants."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig
from repro.models import attention as A
from repro.models import moe as MOE
from repro.models.layers import apply_mrope, apply_rope
from repro.models.model import cross_entropy_chunked


def _naive_attention(q, k, v, causal, window=0):
    B, Sq, H, D = q.shape
    _, Skv, Hkv, Dv = v.shape
    rep = H // Hkv
    kk = jnp.repeat(k, rep, axis=2)
    vv = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / math.sqrt(D)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vv)


@pytest.mark.parametrize("Sq,Skv,H,Hkv,causal,window", [
    (64, 64, 4, 4, True, 0),
    (64, 64, 4, 2, True, 0),
    (33, 33, 4, 1, True, 0),       # ragged (pad path)
    (16, 48, 4, 4, False, 0),      # cross-attention shape
    (64, 64, 4, 2, True, 16),      # sliding window
])
def test_chunked_attention_matches_naive(Sq, Skv, H, Hkv, causal, window,
                                         monkeypatch):
    monkeypatch.setattr(A, "Q_CHUNK", 16)
    monkeypatch.setattr(A, "KV_CHUNK", 16)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    D = 8
    q = jax.random.normal(k1, (2, Sq, H, D))
    k = jax.random.normal(k2, (2, Skv, Hkv, D))
    v = jax.random.normal(k3, (2, Skv, Hkv, D))
    got = A._chunked_attention(q, k, v, causal=causal, window=window)
    want = _naive_attention(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("V,chunk", [(1000, 16), (1000, 64),
                                     (257, 7), (64, 128)])
def test_chunked_xent_matches_dense(V, chunk):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    B, S, d = 2, 33, 32
    x = jax.random.normal(k1, (B, S, d))
    table = jax.random.normal(k2, (V, d)) / math.sqrt(d)
    tgt = jax.random.randint(k3, (B, S), 0, V)
    got = cross_entropy_chunked(x, table, tgt, chunk=chunk)
    logits = x @ table.T
    want = jnp.mean(jax.nn.logsumexp(logits, -1)
                    - jnp.take_along_axis(logits, tgt[..., None], 2)[..., 0])
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_chunked_xent_grad_matches_dense():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
    B, S, d, V = 2, 16, 16, 300
    x = jax.random.normal(k1, (B, S, d))
    table = jax.random.normal(k2, (V, d)) / math.sqrt(d)
    tgt = jax.random.randint(k3, (B, S), 0, V)
    g1 = jax.grad(lambda xx: cross_entropy_chunked(xx, table, tgt,
                                                   chunk=5))(x)
    def dense(xx):
        logits = xx @ table.T
        return jnp.mean(jax.nn.logsumexp(logits, -1)
                        - jnp.take_along_axis(logits, tgt[..., None],
                                              2)[..., 0])
    g2 = jax.grad(dense)(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=1e-4, atol=1e-5)


def test_rope_relative_property():
    """RoPE: <R(p)q, R(p+k)v> depends only on k (shift invariance)."""
    D = 16
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, D))
    v = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, D))
    def dot_at(p):
        pq = jnp.asarray([[p]], jnp.int32)
        pk = jnp.asarray([[p + 3]], jnp.int32)
        return float(jnp.sum(apply_rope(q, pq, 10000.0)
                             * apply_rope(v, pk, 10000.0)))
    assert abs(dot_at(0) - dot_at(17)) < 1e-4


def test_mrope_sections_rotate_independently():
    D = 16
    sections = (4, 2, 2)
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, D))
    # varying only the h component must not change the t-section bands
    p1 = jnp.asarray([[[2, 0, 0]]], jnp.int32)
    p2 = jnp.asarray([[[2, 5, 0]]], jnp.int32)
    y1 = apply_mrope(x, p1, 10000.0, sections)
    y2 = apply_mrope(x, p2, 10000.0, sections)
    # first 4 bands (t-section) identical, h-section differs
    np.testing.assert_allclose(np.asarray(y1[..., :4]),
                               np.asarray(y2[..., :4]), rtol=1e-6)
    assert float(jnp.max(jnp.abs(y1[..., 4:6] - y2[..., 4:6]))) > 1e-4


def _tiny_moe_cfg(E=4, k=2, cf=2.0):
    return (ModelConfig(d_model=16, activation="swiglu"),
            MoEConfig(num_experts=E, top_k=k, expert_d_ff=32,
                      capacity_factor=cf))


def test_moe_output_shape_and_aux():
    cfg, m = _tiny_moe_cfg()
    params = MOE.init_moe(jax.random.PRNGKey(0), cfg, m)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    y, aux = MOE.apply_moe(params, x, cfg, m)
    assert y.shape == x.shape
    assert float(aux) >= 0


def test_moe_high_capacity_keeps_all_tokens():
    """With capacity >= T*k/E ... every token routes; combine weights sum
    to ~1, so output magnitude tracks expert outputs (no silent drops)."""
    cfg, m = _tiny_moe_cfg(E=2, k=2, cf=4.0)
    params = MOE.init_moe(jax.random.PRNGKey(0), cfg, m)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 16))
    # with top_k == num_experts the result must equal the dense mixture
    y, _ = MOE.apply_moe(params, x, cfg, m)
    logits = jnp.einsum("bsd,de->bse", x, params["router"])
    p = jax.nn.softmax(logits, -1)
    def expert(e, xx):
        h = xx @ params["w_gate"][e]
        u = xx @ params["w_up"][e]
        return (jax.nn.silu(h) * u) @ params["w_down"][e]
    dense = sum(p[..., e:e + 1] * expert(e, x) for e in range(2))
    np.testing.assert_allclose(np.asarray(y), np.asarray(dense),
                               rtol=2e-2, atol=2e-3)


def test_moe_grouping_invariance():
    """Dispatch groups change execution layout, not results (when capacity
    is not binding)."""
    cfg, m = _tiny_moe_cfg(E=4, k=1, cf=4.0)
    params = MOE.init_moe(jax.random.PRNGKey(0), cfg, m)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16))
    y1, _ = MOE.apply_moe(params, x, cfg, m, num_groups=1)
    y2, _ = MOE.apply_moe(params, x, cfg, m, num_groups=4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)
