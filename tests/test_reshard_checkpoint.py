"""Live resharding + elastic sharded-window checkpoints (DESIGN.md §15).

Runs in a subprocess with 8 forced host devices. Covers the placement
layer end to end at real shard counts:

* hash / skew placements replay **bit-identical** to the single-device
  engine at D in {2, 8} (walk RNG is placement-independent);
* mid-stream live reshard (range -> hash) loses no edges and leaves the
  walk stream bit-identical to an engine that never resharded;
* range -> hash -> range round-trips the window byte-identically (the
  canonical ts merge is a stable sort; timestamps are distinct);
* the device reshard and its host numpy mirror agree leaf-for-leaf;
* a checkpoint written at 8 shards restores at 2 (and 2 -> 8), preserving
  the window edge multiset, and the continued replay is bit-identical to
  an uninterrupted engine at the target shard count;
* engine.rebalance() (measured-load skew overrides + live reshard)
  keeps the replay running with zero drops.
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.configs.base import (EngineConfig, SamplerConfig, SchedulerConfig,
                                ShardConfig, WalkConfig, WindowConfig)
from repro.core.streaming import StreamingEngine
from repro.data.synthetic import powerlaw_temporal_graph
from repro.distributed.fault_tolerance import StreamSupervisor
from repro.distributed.placement import (HashPlacement, RangePlacement,
                                         SkewPlacement)
from repro.distributed.streaming_shard import (DistributedStreamingEngine,
                                               reshard, reshard_host)

N, E = 128, 2000
g = powerlaw_temporal_graph(N, E, seed=7)
# distinct timestamps: the canonical reshard merge sorts stably by ts, so
# unique ts make every per-shard ordering fully deterministic
ts = np.arange(E, dtype=g.ts.dtype)
cfg = EngineConfig(
    window=WindowConfig(duration=5000, edge_capacity=4096, node_capacity=N),
    sampler=SamplerConfig(bias="exponential", mode="index"),
    scheduler=SchedulerConfig(path="grouped", regroup="bucket"),
    shard=ShardConfig(edge_capacity_per_shard=4096, exchange_capacity=1024,
                      walk_slots=512, walk_bucket_capacity=512),
)
wcfg = WalkConfig(num_walks=256, max_length=8, start_mode="all_nodes")
nb, bs = 5, E // 5
batches = [(g.src[i*bs:(i+1)*bs], g.dst[i*bs:(i+1)*bs], ts[i*bs:(i+1)*bs])
           for i in range(nb)]

def edge_multiset(state):
    ne = np.asarray(state.window.index.num_edges)
    S = np.asarray(state.window.index.store.src)
    Dd = np.asarray(state.window.index.store.dst)
    T = np.asarray(state.window.index.store.ts)
    out = []
    for d in range(ne.shape[0]):
        n = int(ne[d])
        out += list(zip(S[d, :n].tolist(), Dd[d, :n].tolist(),
                        T[d, :n].tolist()))
    return sorted(out)

def counters(state):
    out = {f: int(np.asarray(getattr(state.window, f)).sum())
           for f in ("ingested", "late_drops", "overflow_drops")}
    out["exchange_drops"] = int(np.asarray(state.exchange_drops).sum())
    return out

ref = StreamingEngine(cfg, batch_capacity=bs)
rstats, rwalks, _ = ref.replay_device(batches, wcfg, return_walks=True)
n_ref = int(ref.state.index.store.num_edges)
ref_edges = sorted(zip(
    np.asarray(ref.state.index.store.src)[:n_ref].tolist(),
    np.asarray(ref.state.index.store.dst)[:n_ref].tolist(),
    np.asarray(ref.state.index.store.ts)[:n_ref].tolist()))

# --- hash + skew placements bit-identical to single-device at D {2, 8} ---
for D in (2, 8):
    rp = RangePlacement(num_shards=D, node_capacity=N)
    for plc in (HashPlacement.make(D, N),
                SkewPlacement(num_shards=D, node_capacity=N, base=rp,
                              hot_nodes=(0, 1, 2, 3),
                              hot_owners=(D - 1,) * 4)):
        deng = DistributedStreamingEngine(cfg, batch_capacity=bs,
                                          num_shards=D, placement=plc)
        dstats, dwalks, _ = deng.replay_device(batches, wcfg)
        assert int(dstats.exchange_drops.sum()) == 0, (D, plc.kind)
        assert int(dstats.walk_drops.sum()) == 0, (D, plc.kind)
        for f in rstats._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(rstats, f)),
                np.asarray(getattr(dstats.replay, f)),
                err_msg=f"D={D} {plc.kind} {f}")
        np.testing.assert_array_equal(rwalks.nodes, dwalks.nodes,
                                      err_msg=f"D={D} {plc.kind}")
        np.testing.assert_array_equal(rwalks.times, dwalks.times)
        np.testing.assert_array_equal(rwalks.lengths, dwalks.lengths)
        assert edge_multiset(deng.state) == ref_edges, (D, plc.kind)
        # every shard's resident edges are the ones the placement assigns
        S_ = np.asarray(deng.state.window.index.store.src)
        ne = np.asarray(deng.state.window.index.num_edges)
        for d in range(D):
            own = plc.owner_np(S_[d, :int(ne[d])])
            assert (own == d).all(), (D, plc.kind, d)
print("POLICY_IDENTITY_OK")

# --- mid-stream live reshard range -> hash at D=8 ------------------------
D = 8
rp = RangePlacement(num_shards=D, node_capacity=N)
hp = HashPlacement.make(D, N)
eng = DistributedStreamingEngine(cfg, batch_capacity=bs, num_shards=D)
eng.replay_device(batches[:3], wcfg)
pre = counters(eng.state)
eng.reshard_to(hp)
assert eng.placement is hp
post = counters(eng.state)
assert post == pre, (pre, post)     # reshard moves edges, not counters
s2, w2, _ = eng.replay_device(batches[3:], wcfg)
assert int(s2.exchange_drops.sum()) == 0 and int(s2.walk_drops.sum()) == 0

base = DistributedStreamingEngine(cfg, batch_capacity=bs, num_shards=D)
base.replay_device(batches[:3], wcfg)     # same call pattern -> same keys
b2, bw2, _ = base.replay_device(batches[3:], wcfg)
np.testing.assert_array_equal(w2.nodes, bw2.nodes)
np.testing.assert_array_equal(w2.times, bw2.times)
np.testing.assert_array_equal(w2.lengths, bw2.lengths)
for f in b2.replay._fields:
    np.testing.assert_array_equal(np.asarray(getattr(s2.replay, f)),
                                  np.asarray(getattr(b2.replay, f)),
                                  err_msg=f"live-reshard {f}")
assert edge_multiset(eng.state) == edge_multiset(base.state) == ref_edges
print("LIVE_RESHARD_OK")

# --- range -> hash -> range round-trip is byte-identical -----------------
state0 = base.state
s_hash, _ = reshard(state0, rp, hp)
s_back, _ = reshard(s_hash, hp, rp)
for name in ("t_now", "window"):
    np.testing.assert_array_equal(
        np.asarray(getattr(state0.window, name)),
        np.asarray(getattr(s_back.window, name)), err_msg=name)
idx0 = state0.window.index
idxb = s_back.window.index
np.testing.assert_array_equal(np.asarray(idx0.num_edges),
                              np.asarray(idxb.num_edges))
ne = np.asarray(idx0.num_edges)
for fld in ("src", "dst", "ts"):
    a = np.asarray(getattr(idx0.store, fld))
    b = np.asarray(getattr(idxb.store, fld))
    for d in range(D):
        np.testing.assert_array_equal(a[d, :int(ne[d])], b[d, :int(ne[d])],
                                      err_msg=f"roundtrip {fld} shard {d}")
np.testing.assert_array_equal(np.asarray(idx0.node_starts),
                              np.asarray(idxb.node_starts))
assert counters(s_back) == counters(state0)

# --- device reshard == host mirror, leaf for leaf, at D=8 ----------------
h_hash = reshard_host(state0, hp)
for (pa, a), (pb, b) in zip(
        jax.tree_util.tree_flatten_with_path(s_hash)[0],
        jax.tree_util.tree_flatten_with_path(h_hash)[0]):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                  err_msg=str(pa))
print("ROUNDTRIP_OK")

# --- elastic checkpoint: 8 -> 2 and 2 -> 8 -------------------------------
for D_save, D_load in ((8, 2), (2, 8)):
    with tempfile.TemporaryDirectory() as tmp:
        sup = StreamSupervisor(tmp, save_every=3)
        e1 = DistributedStreamingEngine(cfg, batch_capacity=bs,
                                        num_shards=D_save)
        sup.run(e1, batches[:3], wcfg)
        assert sup.resume_batch() == 3
        e2 = sup.checkpointer.restore_engine(cfg, batch_capacity=bs,
                                             num_shards=D_load)
        assert e2.num_shards == D_load
        assert edge_multiset(e2.state) == edge_multiset(e1.state)
        assert counters(e2.state) == counters(e1.state)
        out, step = sup.run(e2, batches, wcfg, start_batch=3)
        assert step == nb

        # uninterrupted reference at the TARGET shard count, same
        # per-batch call pattern (the walk key splits once per call)
        r2 = DistributedStreamingEngine(cfg, batch_capacity=bs,
                                        num_shards=D_load)
        for b in batches[:-1]:
            r2.replay_device([b], wcfg)
        rs_, rw_, _ = r2.replay_device([batches[-1]], wcfg)
        np.testing.assert_array_equal(np.asarray(e2.key), np.asarray(r2.key))
        assert edge_multiset(e2.state) == edge_multiset(r2.state) == ref_edges
        assert counters(e2.state) == counters(r2.state)
        for f in rs_.replay._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(out[-1].replay, f)),
                np.asarray(getattr(rs_.replay, f)),
                err_msg=f"elastic {D_save}->{D_load} {f}")
print("ELASTIC_CKPT_OK")

# --- rebalance: measured-load skew overrides + live reshard --------------
eng = DistributedStreamingEngine(cfg, batch_capacity=bs, num_shards=8)
eng.replay_device(batches[:3], wcfg)
loads = eng.node_loads()
assert loads.shape == (N,) and loads.sum() > 0
before = edge_multiset(eng.state)
newp = eng.rebalance(k=8)
assert isinstance(newp, SkewPlacement) and len(newp.hot_nodes) > 0
assert edge_multiset(eng.state) == before
s3, _, _ = eng.replay_device(batches[3:], wcfg)
assert int(s3.exchange_drops.sum()) == 0 and int(s3.walk_drops.sum()) == 0
# the hot overrides actually moved hub load off the heaviest shard
sl = eng.shard_loads()
assert sl.sum() == len(ref_edges)
print("REBALANCE_OK")
"""

pytestmark = pytest.mark.slow      # 8-device subprocess


def test_reshard_and_elastic_checkpoint_8_devices():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    for sentinel in ("POLICY_IDENTITY_OK", "LIVE_RESHARD_OK", "ROUNDTRIP_OK",
                     "ELASTIC_CKPT_OK", "REBALANCE_OK"):
        assert sentinel in out.stdout, \
            (sentinel, out.stdout[-1500:], out.stderr[-3000:])
