"""Per-architecture smoke tests (assignment deliverable f).

Each assigned arch is instantiated at a REDUCED config of the same family
(small width/layers/experts) and runs one forward + one train-style grad +
one decode step on CPU, asserting output shapes and finiteness.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, reduced
from repro.models import model as M

ARCHS = list_archs()

pytestmark = pytest.mark.slow      # per-arch model-zoo smoke (forward/grad/decode for every assigned arch)


def _batch(cfg, key, B=2, S=32):
    k1, k2, k3 = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(k2, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        npatch = S // 2
        batch["tokens"] = batch["tokens"][:, :S - npatch]
        batch["labels"] = batch["labels"][:, :S - npatch]
        batch["patches"] = 0.02 * jax.random.normal(
            k3, (B, npatch, cfg.d_model))
    if cfg.family == "enc_dec":
        batch["frames"] = 0.1 * jax.random.normal(k3, (B, 16, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch):
    cfg = reduced(get_config(arch))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    x, pos, aux = M.forward(params, cfg, batch)
    assert x.shape[0] == 2 and x.shape[-1] == cfg.d_model
    assert bool(jnp.all(jnp.isfinite(x)))
    loss = M.loss_fn(params, cfg, batch)
    assert np.isfinite(float(loss))
    assert float(loss) < 3 * np.log(cfg.vocab_size) + 5


@pytest.mark.parametrize("arch", ARCHS)
def test_train_grad_finite(arch):
    cfg = reduced(get_config(arch))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1), S=16)
    g = jax.grad(lambda p: M.loss_fn(p, cfg, batch))(params)
    leaves = jax.tree.leaves(g)
    assert leaves
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in leaves)
    # at least the embedding gets gradient signal
    gn = sum(float(jnp.sum(jnp.abs(l))) for l in leaves)
    assert gn > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = reduced(get_config(arch))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    state = M.init_decode_state(cfg, 2, 16)
    tok = jnp.ones((2, 1), jnp.int32)
    logits, state = M.decode_step(params, cfg, tok, state)
    logits, state = M.decode_step(params, cfg, tok, state)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ["olmo-1b", "qwen2-0.5b",
                                  "deepseek-v2-236b", "jamba-v0.1-52b",
                                  "xlstm-125m", "arctic-480b",
                                  "phi3-medium-14b", "qwen2-vl-72b",
                                  "deepseek-coder-33b"])
def test_prefill_decode_consistency(arch):
    """Forward logits == token-by-token decode logits (cache correctness,
    incl. the MLA latent absorb trick and SSM state carry)."""
    cfg = reduced(get_config(arch))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, S), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.family == "vlm":
        batch = {"tokens": toks, "patches": jnp.zeros((B, 0, cfg.d_model))}
        x, _, _ = M.forward(params, cfg, batch)
    else:
        x, _, _ = M.forward(params, cfg, batch)
    ref = M.logits_from_hidden(params, cfg, x)
    state = M.init_decode_state(cfg, B, 16)
    outs = []
    for t in range(S):
        lg, state = M.decode_step(params, cfg, toks[:, t:t + 1], state)
        outs.append(lg[:, 0])
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_enc_dec_decode_consistency():
    from repro.models.model import _run_encoder
    cfg = reduced(get_config("seamless-m4t-medium"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, S), 0,
                              cfg.vocab_size)
    frames = 0.1 * jax.random.normal(jax.random.PRNGKey(6),
                                     (B, 16, cfg.d_model))
    x, _, _ = M.forward(params, cfg, {"tokens": toks, "frames": frames})
    ref = M.logits_from_hidden(params, cfg, x)
    enc_out, enc_pos = _run_encoder(params, cfg, frames, x.dtype)
    state = M.init_decode_state(cfg, B, 16)
    state["enc_out"], state["enc_pos"] = enc_out, enc_pos
    outs = []
    for t in range(S):
        lg, state = M.decode_step(params, cfg, toks[:, t:t + 1], state)
        outs.append(lg[:, 0])
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_analytic_param_counts_full_configs():
    """Analytic counts for the FULL configs are in the advertised ballpark
    (names encode the rough scale)."""
    expect = {
        "phi3-medium-14b": (10e9, 20e9),
        "olmo-1b": (0.9e9, 1.6e9),
        "deepseek-coder-33b": (28e9, 40e9),
        "qwen2-0.5b": (0.3e9, 0.8e9),
        "qwen2-vl-72b": (60e9, 85e9),
        "xlstm-125m": (0.08e9, 0.25e9),
        "jamba-v0.1-52b": (40e9, 65e9),
        "deepseek-v2-236b": (180e9, 280e9),
        "arctic-480b": (380e9, 560e9),
        "seamless-m4t-medium": (0.7e9, 1.8e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).approx_params()
        assert lo <= n <= hi, (arch, n)


def test_analytic_matches_actual_reduced():
    """Analytic formula agrees with the real parameter count on reduced
    configs (within the bits the formula intentionally ignores: norms,
    small biases)."""
    for arch in ARCHS:
        cfg = reduced(get_config(arch))
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        analytic = M.count_params_analytic(cfg)
        assert abs(actual - analytic) / actual < 0.1, \
            (arch, actual, analytic)
