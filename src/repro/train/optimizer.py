"""AdamW with global-norm clipping, cosine schedule, and optional int8
error-feedback gradient compression (a distributed-optimization feature:
the all-reduce payload shrinks 4x; the quantization residual is carried
forward so the compression is unbiased over time).

Pure-pytree implementation (no optax dependency): states shard exactly
like their parameters, which keeps checkpoint resharding trivial.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    # gradient compression: "none" | "int8"
    compression: str = "none"


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any
    error: Any          # error-feedback residual (compression only)


def init_opt_state(params, cfg: AdamWConfig) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    err = zeros if cfg.compression != "none" else None
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros), error=err)


def lr_at(cfg: AdamWConfig, step) -> jax.Array:
    stepf = step.astype(jnp.float32)
    warm = stepf / max(cfg.warmup_steps, 1)
    prog = jnp.clip((stepf - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) \
        * 0.5 * (1 + jnp.cos(math.pi * prog))
    return cfg.lr * jnp.minimum(warm, cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def compress_int8(g, error):
    """Error-feedback int8 quantization: returns (q, scale, new_error).

    Applied BEFORE the gradient all-reduce when compression is enabled —
    the reduce then moves 1 byte/element instead of 4.
    """
    g_ef = g + error
    scale = jnp.maximum(jnp.max(jnp.abs(g_ef)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g_ef / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, g_ef - deq


def apply_updates(params, grads, state: OptState,
                  cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state.step + 1

    if cfg.compression == "int8":
        pairs = jax.tree.map(compress_int8, grads, state.error)
        grads = jax.tree.map(lambda pr: pr[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_error = jax.tree.map(lambda pr: pr[1], pairs,
                                 is_leaf=lambda x: isinstance(x, tuple))
    else:
        new_error = state.error

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                      state.nu, grads)
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)
    lr = lr_at(cfg, step)

    def upd(p, m, v):
        u = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        return (p.astype(jnp.float32)
                - lr * (u + cfg.weight_decay * p.astype(jnp.float32))
                ).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, OptState(step=step, mu=mu, nu=nu, error=new_error), \
        metrics
