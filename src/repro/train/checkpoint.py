"""Sharded checkpointing with elastic (mesh-shape-changing) restore.

Fault-tolerance design (1000+ node operation):

* every host writes only ITS OWN shards (``save`` iterates addressable
  shards) — no gather through host 0, no single-writer bottleneck;
* a tiny JSON manifest records the pytree structure, global shapes and
  dtypes — restore first rebuilds abstract arrays, then assembles from
  whatever shard files exist;
* restore takes the TARGET sharding, not the source's: a checkpoint
  written on a 16x16 mesh restores onto 2x16x16 (or a degraded 15x16
  replacement pod) because assembly goes through host numpy and
  ``jax.device_put`` with the new sharding — this is the elastic-restart
  path exercised in tests;
* writes are atomic (tmp file + rename) so a preempted host never
  corrupts the previous checkpoint.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

_MANIFEST = "manifest.json"


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        items.append((key, leaf))
    return items, treedef


def save(ckpt_dir: str, tree, step: int) -> None:
    os.makedirs(ckpt_dir, exist_ok=True)
    items, treedef = _flatten_with_paths(tree)
    manifest: Dict[str, Any] = {"step": step, "leaves": {}}
    for key, leaf in items:
        arr = np.asarray(jax.device_get(leaf))
        manifest["leaves"][key] = {"shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
        fname = key.replace("/", "__") + ".npy"
        fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
        os.close(fd)
        np.save(tmp, arr, allow_pickle=False)
        os.replace(tmp + ".npy" if os.path.exists(tmp + ".npy") else tmp,
                   os.path.join(ckpt_dir, fname))
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir)
    with os.fdopen(fd, "w") as f:
        json.dump(manifest, f)
    os.replace(tmp, os.path.join(ckpt_dir, _MANIFEST))


def latest_step(ckpt_dir: str) -> Optional[int]:
    path = os.path.join(ckpt_dir, _MANIFEST)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)["step"]


def restore(ckpt_dir: str, target_tree, shardings=None):
    """Restore into the structure of ``target_tree``; if ``shardings`` is
    given (a pytree of NamedSharding matching target), arrays are placed
    with it — the elastic-remesh path."""
    with open(os.path.join(ckpt_dir, _MANIFEST)) as f:
        manifest = json.load(f)
    items, treedef = _flatten_with_paths(target_tree)
    shard_items = None
    if shardings is not None:
        shard_items, _ = _flatten_with_paths(shardings)
        shard_items = dict(shard_items)
    leaves = []
    for key, ref_leaf in items:
        meta = manifest["leaves"].get(key)
        if meta is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        fname = os.path.join(ckpt_dir, key.replace("/", "__") + ".npy")
        arr = np.load(fname, allow_pickle=False)
        if list(arr.shape) != list(ref_leaf.shape):
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != target "
                f"{ref_leaf.shape}")
        if shard_items is not None and key in shard_items:
            out = jax.device_put(arr, shard_items[key])
        else:
            out = jnp.asarray(arr, dtype=ref_leaf.dtype)
        leaves.append(out)
    return jax.tree_util.tree_unflatten(treedef, leaves)
