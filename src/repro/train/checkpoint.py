"""Sharded checkpointing with elastic (mesh-shape-changing) restore.

Fault-tolerance design (1000+ node operation):

* every host writes only ITS OWN shards (``save`` iterates addressable
  shards) — no gather through host 0, no single-writer bottleneck;
* a tiny JSON manifest records the pytree structure, global shapes and
  dtypes — restore first rebuilds abstract arrays, then assembles from
  whatever shard files exist;
* restore takes the TARGET sharding, not the source's: a checkpoint
  written on a 16x16 mesh restores onto 2x16x16 (or a degraded 15x16
  replacement pod) because assembly goes through host numpy and
  ``jax.device_put`` with the new sharding — this is the elastic-restart
  path exercised in tests;
* writes are atomic (tmp file + rename) so a preempted host never
  corrupts the previous checkpoint.

Beyond params/opt trees, this module checkpoints the **sharded sliding
window itself** (``save_sharded_window`` / ``restore_sharded_window``,
DESIGN.md §15): the per-leaf writer persists the full
``ShardedWindowState`` plus the walk RNG key, and a ``placement.json``
manifest records the node-placement policy (its ``describe()`` descriptor
round-trips through ``placement_from_manifest``) and the window geometry.
Restore is **elastic over shard count and policy**: a window saved at 8
shards under range placement restores at 2 shards under a hash table by
re-bucketing through the host reshard mirror
(``streaming_shard.reshard_host`` — the same canonical merge as the
device reshard), preserving the resident edge multiset.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

_MANIFEST = "manifest.json"


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        items.append((key, leaf))
    return items, treedef


def save(ckpt_dir: str, tree, step: int) -> None:
    os.makedirs(ckpt_dir, exist_ok=True)
    items, treedef = _flatten_with_paths(tree)
    manifest: Dict[str, Any] = {"step": step, "leaves": {}}
    for key, leaf in items:
        arr = np.asarray(jax.device_get(leaf))
        manifest["leaves"][key] = {"shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
        fname = key.replace("/", "__") + ".npy"
        fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
        os.close(fd)
        np.save(tmp, arr, allow_pickle=False)
        os.replace(tmp + ".npy" if os.path.exists(tmp + ".npy") else tmp,
                   os.path.join(ckpt_dir, fname))
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir)
    with os.fdopen(fd, "w") as f:
        json.dump(manifest, f)
    os.replace(tmp, os.path.join(ckpt_dir, _MANIFEST))


def latest_step(ckpt_dir: str) -> Optional[int]:
    path = os.path.join(ckpt_dir, _MANIFEST)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)["step"]


def restore(ckpt_dir: str, target_tree, shardings=None):
    """Restore into the structure of ``target_tree``; if ``shardings`` is
    given (a pytree of NamedSharding matching target), arrays are placed
    with it — the elastic-remesh path."""
    with open(os.path.join(ckpt_dir, _MANIFEST)) as f:
        manifest = json.load(f)
    items, treedef = _flatten_with_paths(target_tree)
    shard_items = None
    if shardings is not None:
        shard_items, _ = _flatten_with_paths(shardings)
        shard_items = dict(shard_items)
    leaves = []
    for key, ref_leaf in items:
        meta = manifest["leaves"].get(key)
        if meta is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        fname = os.path.join(ckpt_dir, key.replace("/", "__") + ".npy")
        arr = np.load(fname, allow_pickle=False)
        if list(arr.shape) != list(ref_leaf.shape):
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != target "
                f"{ref_leaf.shape}")
        if shard_items is not None and key in shard_items:
            out = jax.device_put(arr, shard_items[key])
        else:
            out = jnp.asarray(arr, dtype=ref_leaf.dtype)
        leaves.append(out)
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# Sharded-window checkpoints: ShardedWindowState + placement manifest,
# with elastic (shard-count / policy-changing) restore (DESIGN.md §15)
# ---------------------------------------------------------------------------

_PLACEMENT = "placement.json"


def save_sharded_window(ckpt_dir: str, state, placement, step: int,
                        walk_key=None) -> None:
    """Persist a ``ShardedWindowState`` + its placement + the walk key.

    ``state`` is the engine's sharded window (leaves [D, ...]);
    ``placement`` the ``Placement`` that produced its layout (saved as its
    ``describe()`` descriptor so the exact routing/override tables ride
    along); ``walk_key`` the engine's PRNG key — without it a restored
    replay could not continue the bit-exact walk stream
    (``DistributedStreamingEngine.replay_device`` splits the key per
    call). Leaf arrays go through the same atomic per-leaf writer as
    params checkpoints.
    """
    tree = {"state": state}
    if walk_key is not None:
        tree["walk_key"] = walk_key
    save(ckpt_dir, tree, step)
    w = state.window
    meta = {
        "placement": placement.describe(),
        "num_shards": int(state.exchange_drops.shape[0]),
        "edge_capacity_per_shard": int(w.index.store.src.shape[1]),
        # node_starts spans nc real nodes + the virtual padding node, with
        # one extra boundary entry: [D, nc + 2]
        "node_capacity": int(w.index.node_starts.shape[1]) - 2,
        "window": int(np.asarray(w.window).max()),
        "step": step,
        "has_walk_key": walk_key is not None,
    }
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir)
    with os.fdopen(fd, "w") as f:
        json.dump(meta, f)
    os.replace(tmp, os.path.join(ckpt_dir, _PLACEMENT))


def load_placement_manifest(ckpt_dir: str) -> Dict[str, Any]:
    with open(os.path.join(ckpt_dir, _PLACEMENT)) as f:
        return json.load(f)


def restore_sharded_window(ckpt_dir: str, *, placement=None,
                           num_shards: Optional[int] = None,
                           bias_scale: float = 1.0):
    """Restore a sharded window; optionally onto a DIFFERENT layout.

    With no target arguments the window comes back exactly as saved
    (same shard count, same placement — byte-identical leaves). Passing
    ``placement`` (a ``Placement``) or ``num_shards`` (re-derives the
    saved policy kind at the new count; skew hub overrides are dropped
    since they index the old shard space) re-buckets the restored edges
    through ``reshard_host`` — the elastic path: an 8-shard checkpoint
    restores on a 2-device host and vice versa, window edge multiset
    preserved (up to the counted per-shard capacity clip).

    Returns ``(state, placement, walk_key)`` with host-resident leaves;
    callers place them onto their mesh (``NamedSharding``) — see
    ``fault_tolerance.WindowCheckpointer.restore_engine``.
    """
    from repro.distributed.placement import (
        make_placement,
        placement_from_manifest,
    )
    from repro.distributed.streaming_shard import (
        init_sharded_window,
        reshard_host,
    )

    meta = load_placement_manifest(ckpt_dir)
    old_placement = placement_from_manifest(meta["placement"])
    D_old = meta["num_shards"]
    target = {"state": init_sharded_window(
        D_old, meta["edge_capacity_per_shard"], meta["node_capacity"],
        meta["window"])}
    if meta["has_walk_key"]:
        target["walk_key"] = jax.random.PRNGKey(0)
    tree = restore(ckpt_dir, target)
    state = tree["state"]
    walk_key = tree.get("walk_key")

    if placement is None:
        if num_shards is None or num_shards == D_old:
            return state, old_placement, walk_key
        kind = meta["placement"]["kind"]
        placement = make_placement(
            kind if kind in ("range", "hash") else "range",
            num_shards, meta["node_capacity"])
    if placement.node_capacity != meta["node_capacity"]:
        raise ValueError(
            f"target placement node_capacity {placement.node_capacity} != "
            f"checkpoint {meta['node_capacity']}")
    if placement != old_placement:
        state = reshard_host(state, placement, bias_scale=bias_scale)
    return state, placement, walk_key
