"""Incremental skipgram embedding training on temporal walks (paper §3.9).

Streaming regime: after each ingested batch, walks are generated from the
active window and the embeddings are updated incrementally [Mikolov'13;
CTDNE]. Link prediction supervises against negative edges built by
replacing each positive edge's target with a non-co-occurring node.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class SkipgramState(NamedTuple):
    emb_in: jax.Array      # [N, D]
    emb_out: jax.Array     # [N, D]


def init_skipgram(num_nodes: int, dim: int, key) -> SkipgramState:
    k1, k2 = jax.random.split(key)
    scale = 1.0 / np.sqrt(dim)
    return SkipgramState(
        emb_in=scale * jax.random.normal(k1, (num_nodes, dim)),
        emb_out=jnp.zeros((num_nodes, dim)),
    )


@partial(jax.jit, static_argnames=("n_neg", "lr"))
def skipgram_step(state: SkipgramState, centers, contexts, key,
                  n_neg: int = 5, lr: float = 0.025):
    """One SGD step of skipgram with negative sampling."""
    N = state.emb_in.shape[0]
    negs = jax.random.randint(key, (centers.shape[0], n_neg), 0, N)

    def loss_fn(st: SkipgramState):
        u = st.emb_in[centers]                    # [P, D]
        v = st.emb_out[contexts]                  # [P, D]
        vn = st.emb_out[negs]                     # [P, K, D]
        pos = jax.nn.log_sigmoid(jnp.sum(u * v, -1))
        neg = jnp.sum(jax.nn.log_sigmoid(-jnp.einsum("pd,pkd->pk", u, vn)),
                      -1)
        return -jnp.mean(pos + neg)

    loss, g = jax.value_and_grad(loss_fn)(state)
    new = SkipgramState(emb_in=state.emb_in - lr * g.emb_in,
                        emb_out=state.emb_out - lr * g.emb_out)
    return new, loss


def train_on_walks(state: SkipgramState, nodes, lengths, key, *,
                   window: int = 2, epochs: int = 1, batch_pairs: int = 8192,
                   n_neg: int = 5, lr: float = 0.025):
    """Incremental update from one walk batch (host-side pair extraction)."""
    from repro.data.walk_dataset import skipgram_pairs
    c, x = skipgram_pairs(np.asarray(nodes), np.asarray(lengths),
                          window=window)
    if len(c) == 0:
        return state, 0.0
    losses = []
    for ep in range(epochs):
        perm = np.random.default_rng(ep).permutation(len(c))
        for i in range(0, len(c), batch_pairs):
            sel = perm[i:i + batch_pairs]
            key, sub = jax.random.split(key)
            state, loss = skipgram_step(
                state, jnp.asarray(c[sel]), jnp.asarray(x[sel]), sub,
                n_neg=n_neg, lr=lr)
            losses.append(float(loss))
    return state, float(np.mean(losses))


def link_prediction_auc(state: SkipgramState, pos_src, pos_dst,
                        num_nodes: int, seed: int = 0) -> float:
    """AUC of dot-product scores, negatives = corrupted targets."""
    rng = np.random.default_rng(seed)
    neg_dst = rng.integers(0, num_nodes, len(pos_dst))
    emb_in = np.asarray(state.emb_in)
    emb_out = np.asarray(state.emb_out)
    pos_s = np.sum(emb_in[pos_src] * emb_out[pos_dst], -1)
    neg_s = np.sum(emb_in[pos_src] * emb_out[neg_dst], -1)
    # AUC = P(pos > neg) via rank statistic
    scores = np.concatenate([pos_s, neg_s])
    labels = np.concatenate([np.ones_like(pos_s), np.zeros_like(neg_s)])
    order = np.argsort(scores)
    ranks = np.empty_like(order, dtype=np.float64)
    ranks[order] = np.arange(1, len(scores) + 1)
    n_pos = len(pos_s)
    n_neg = len(neg_s)
    auc = (ranks[labels == 1].sum() - n_pos * (n_pos + 1) / 2) \
        / (n_pos * n_neg)
    return float(auc)
