"""Jitted train/serve step factories.

``make_train_step(cfg, opt_cfg, num_groups)`` returns a pure
``(params, opt_state, batch) -> (params, opt_state, metrics)`` suitable for
``jax.jit`` with in/out shardings; ``make_serve_step(cfg)`` returns the
one-token decode step. These are the functions the multi-pod dry-run
lowers (launch/dryrun.py).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.train.optimizer import AdamWConfig, OptState, apply_updates


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    num_groups: int = 1):
    def train_step(params, opt_state: OptState, batch: Dict[str, Any]):
        def loss(p):
            return M.loss_fn(p, cfg, batch, num_groups=num_groups)

        loss_val, grads = jax.value_and_grad(loss)(params)
        params, opt_state, metrics = apply_updates(params, grads,
                                                   opt_state, opt_cfg)
        metrics = dict(metrics, loss=loss_val)
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, num_groups: int = 1):
    def eval_step(params, batch):
        return M.loss_fn(params, cfg, batch, num_groups=num_groups)
    return eval_step


def make_serve_step(cfg: ModelConfig, num_groups: int = 1):
    def serve_step(params, tokens, state):
        logits, new_state = M.decode_step(params, cfg, tokens, state,
                                          num_groups=num_groups)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok[:, None], new_state
    return serve_step


def make_prefill_step(cfg: ModelConfig, num_groups: int = 1):
    """Prefill: full-sequence forward returning last-position logits.
    (Cache population during prefill is served by running decode_step over
    chunks in production; for the dry-run the compute shape is what
    matters and is dominated by this forward.)"""
    def prefill_step(params, batch):
        x, _, _ = M.forward(params, cfg, batch, num_groups=num_groups)
        return M.logits_from_hidden(params, cfg, x[:, -1:, :])
    return prefill_step
