"""Synthetic temporal graph generation.

Power-law (hub-skewed) degree distributions model the paper's datasets
(§2.4.1: "on hub-skewed temporal graphs this redundancy dominates");
the ``skew`` knob moves mass onto hubs to exercise the dispatch plane's
mega-hub column.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class TemporalGraph(NamedTuple):
    src: np.ndarray
    dst: np.ndarray
    ts: np.ndarray
    num_nodes: int


def powerlaw_temporal_graph(num_nodes: int, num_edges: int, *,
                            skew: float = 1.2, t_max: int = 10_000,
                            seed: int = 0, ts_groups: int | None = None,
                            self_loops: bool = False) -> TemporalGraph:
    """Edges with Zipf-ish endpoints and uniform timestamps in [0, t_max].

    ``ts_groups`` quantizes timestamps onto that many distinct values,
    reproducing the paper's high-frequency regime where "many events
    concentrate into each millisecond timestamp" (§3.3).
    """
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, num_nodes + 1, dtype=np.float64)
    probs = ranks ** (-skew)
    probs /= probs.sum()
    src = rng.choice(num_nodes, size=num_edges, p=probs).astype(np.int32)
    dst = rng.choice(num_nodes, size=num_edges, p=probs).astype(np.int32)
    if not self_loops:
        loops = src == dst
        dst[loops] = (dst[loops] + 1) % num_nodes
    ts = rng.integers(0, t_max + 1, size=num_edges).astype(np.int32)
    if ts_groups is not None:
        step = max(t_max // ts_groups, 1)
        ts = (ts // step) * step
    order = np.argsort(ts, kind="stable")
    return TemporalGraph(src[order], dst[order], ts[order].astype(np.int32),
                         num_nodes)


def chronological_batches(g: TemporalGraph, num_batches: int):
    """Split a temporal graph into chronological batches (paper §3.3)."""
    n = g.src.shape[0]
    bounds = np.linspace(0, n, num_batches + 1).astype(np.int64)
    for i in range(num_batches):
        s, e = bounds[i], bounds[i + 1]
        yield g.src[s:e], g.dst[s:e], g.ts[s:e]
