"""Walks → training data: skipgram pairs (CTDNE-style) and LM token
sequences (walk-native training, paper conclusion)."""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np

from repro.core.walk_engine import NODE_PAD


def skipgram_pairs(nodes: np.ndarray, lengths: np.ndarray,
                   window: int = 2, max_pairs: int | None = None,
                   seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """(center, context) pairs from walk node sequences (numpy, host)."""
    W, L = nodes.shape
    centers, contexts = [], []
    for w in range(W):
        n = int(lengths[w])
        seq = nodes[w, :n]
        for i in range(n):
            for j in range(max(0, i - window), min(n, i + window + 1)):
                if i != j:
                    centers.append(seq[i])
                    contexts.append(seq[j])
    c = np.asarray(centers, np.int32)
    x = np.asarray(contexts, np.int32)
    if max_pairs is not None and len(c) > max_pairs:
        rng = np.random.default_rng(seed)
        idx = rng.choice(len(c), max_pairs, replace=False)
        c, x = c[idx], x[idx]
    return c, x


def walks_to_lm_batch(nodes: np.ndarray, lengths: np.ndarray,
                      seq_len: int, batch: int, vocab: int,
                      pad_id: int = 0, seed: int = 0):
    """Pack walks into fixed [batch, seq_len] token/label arrays.

    Node ids are the token ids (walk-native LM training); walks shorter
    than seq_len are concatenated with a separator (vocab-1)."""
    rng = np.random.default_rng(seed)
    sep = vocab - 1
    stream = []
    order = rng.permutation(nodes.shape[0])
    for w in order:
        n = int(lengths[w])
        if n > 1:
            stream.extend(int(t) % (vocab - 1) for t in nodes[w, :n])
            stream.append(sep)
    need = batch * (seq_len + 1)
    while len(stream) < need:
        stream.append(pad_id)
    arr = np.asarray(stream[:need], np.int32).reshape(batch, seq_len + 1)
    return arr[:, :-1], arr[:, 1:]
