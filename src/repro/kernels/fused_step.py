"""Pallas TPU kernel: fused convergence-tiered walk step (paper §2.4.3-§2.4.4).

One kernel dispatch per hop fuses the three stages the seed-era tiled path
ran as separate ops — the prefix-weight lookup, the inverse-CDF draw, and
the neighbor ``dst``/``ts`` gather — and dispatches all three closed-form
biases **branchlessly by int32 code** (samplers.BIAS_CODES, matching
``LaneParams``), so one compiled kernel serves heterogeneous per-lane
bias batches.

Degree-tiered program lanes (the TPU analogue of the paper's Fig. 5
thread/warp/block terminal kernels, selected by the same convergence and
degree statistics ``core/scheduler.py::dispatch_stats`` reports):

* **tier S (staged)** — lanes whose neighborhood fits the tile's staged
  ``2·tile_edges`` VMEM window (the smem-panel analog, §2.4.3) resolve in
  one pass over the staged rows: dense compare-and-reduce cutoff, per-lane
  branchless pick, one-hot gather. This is the common case the paper's
  shared-memory tiers serve.
* **tier L (swept)** — oversize lanes (region span > 2·tile_edges — the
  paper's G-axis "global" tier) are tiled over the edge window: the grid's
  second axis walks ``tile_edges`` blocks of the node-ts view sequentially
  while per-lane VMEM scratch carries the running cutoff count, the
  one-hot-captured prefix values at the cutoff, and the monotone pick
  count. One sweep suffices because the cutoff finalizes in the block that
  contains it — until then the candidate ``c = a + cnt`` sits at the end
  of the seen range, which self-masks every downstream one-hot (details in
  ``_big_kernel_weight``). The seed path served these lanes through a
  pure-jnp gather fallback (kernels/ops.py); the sweep retires that.

Bit-identity contract: both tiers evaluate exactly the engine's sampler
expressions (samplers.py) over exactly the prefix values the engine reads
— the staged rows are slices of the same global ``pexp``/``plin`` arrays,
so weight-mode counting reproduces the binary search bit-for-bit
(DESIGN.md §14). ``path="fused"`` therefore emits walks byte-identical to
the ``grouped``/``tiled`` paths (tested in tests/test_fused_step.py).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.configs.base import SchedulerConfig
from repro.core.samplers import (
    BIAS_LINEAR,
    BIAS_UNIFORM,
    index_pick_lanes,
    index_uniform,
)
from repro.core.temporal_index import TemporalIndex, node_range
from repro.kernels.runtime import resolve_interpret


class FusedStepResult(NamedTuple):
    """Per-lane hop outputs plus the actual tier split of this dispatch."""

    k: jax.Array       # int32[W] global pick position (0 where n <= 0)
    n: jax.Array       # int32[W] neighborhood size |Γ_t(v)|
    dst: jax.Array     # int32[W] picked neighbor (0 where n <= 0)
    ts: jax.Array      # int32[W] picked edge timestamp (0 where n <= 0)
    tiers: jax.Array   # int32[3]: (tier-S lanes, tier-L lanes, swept blocks)


def _count_true(mask: jax.Array) -> jax.Array:
    return jnp.sum(mask.astype(jnp.int32), axis=1)


def _onehot_i32(values_row: jax.Array, pos: jax.Array,
                k: jax.Array) -> jax.Array:
    """Exact int32 gather-by-one-hot: sum(where(pos == k, values, 0))."""
    sel = jnp.where(pos == k[:, None], values_row[None, :], 0)
    return jnp.sum(sel, axis=1)


def _onehot_f32(values_row: jax.Array, pos: jax.Array,
                k: jax.Array) -> jax.Array:
    sel = jnp.where(pos == k[:, None], values_row[None, :], 0.0)
    return jnp.sum(sel, axis=1)


# ---------------------------------------------------------------------------
# Tier S: one staged pass over the tile's 2·TE VMEM window
# ---------------------------------------------------------------------------


def _finalize(k, n, pos, dst, ts, kmax, k_ref, n_ref, dst_out_ref,
              ts_out_ref):
    k = jnp.clip(k, 0, kmax)
    has = n > 0
    k_ref[...] = jnp.where(has, k, 0)
    n_ref[...] = n
    dst_out_ref[...] = jnp.where(has, _onehot_i32(dst, pos, k), 0)
    ts_out_ref[...] = jnp.where(has, _onehot_i32(ts, pos, k), 0)


def _cutoff(time_ref, lo_ref, hi_ref, ts):
    """Dense compare-and-reduce temporal cutoff (DESIGN.md §2)."""
    t = time_ref[...][:, None]
    lo = lo_ref[...][:, None]
    hi = hi_ref[...][:, None]
    pos = jax.lax.broadcasted_iota(jnp.int32, (1, ts.shape[0]), 1)
    in_region = (pos >= lo) & (pos < hi)
    c = lo[:, 0] + _count_true(in_region & (ts[None, :] <= t))
    n = hi[:, 0] - c
    return pos, hi, c, n


def _small_kernel_index(
        # scalar prefetch
        base_ref,
        # per-walk tile inputs [TW]
        time_ref, lo_ref, hi_ref, u_ref, code_ref,
        # staged edge-view windows, two consecutive blocks each [TE]
        ts0_ref, ts1_ref, dst0_ref, dst1_ref,
        # outputs [TW]
        k_ref, n_ref, dst_out_ref, ts_out_ref):
    te = ts0_ref.shape[0]
    ts = jnp.concatenate([ts0_ref[...], ts1_ref[...]])        # [2TE]
    dst = jnp.concatenate([dst0_ref[...], dst1_ref[...]])
    pos, _, c, n = _cutoff(time_ref, lo_ref, hi_ref, ts)
    # branchless per-lane closed-form dispatch (paper eqs 1-3, §2.5)
    k = c + index_pick_lanes(code_ref[...], u_ref[...], n)
    _finalize(k, n, pos, dst, ts, 2 * te - 1, k_ref, n_ref, dst_out_ref,
              ts_out_ref)


def _small_kernel_weight(
        base_ref,
        time_ref, lo_ref, hi_ref, u_ref, code_ref, tbase_ref,
        ts0_ref, ts1_ref, dst0_ref, dst1_ref,
        # staged exp and linear prefix rows P(base+j) and P(base+j+1)
        pe0_ref, pe1_ref, pes0_ref, pes1_ref,
        pl0_ref, pl1_ref, pls0_ref, pls1_ref,
        k_ref, n_ref, dst_out_ref, ts_out_ref):
    te = ts0_ref.shape[0]
    ts = jnp.concatenate([ts0_ref[...], ts1_ref[...]])
    dst = jnp.concatenate([dst0_ref[...], dst1_ref[...]])
    pe = jnp.concatenate([pe0_ref[...], pe1_ref[...]])
    pes = jnp.concatenate([pes0_ref[...], pes1_ref[...]])
    pl_ = jnp.concatenate([pl0_ref[...], pl1_ref[...]])
    pls = jnp.concatenate([pls0_ref[...], pls1_ref[...]])

    pos, hi, c, n = _cutoff(time_ref, lo_ref, hi_ref, ts)
    u = u_ref[...]
    fb = c + index_uniform(u, n)          # uniform bias == weight fallback

    # exponential: smallest j in [c, hi) with P(j+1) >= target, by counting
    # over the shifted row. P(hi) must come from the shifted row (ps[hi-1]):
    # reading pe[hi] yields 0 when hi == 2·TE (exact-fit region, §2.4.3).
    pe_c = _onehot_f32(pe, pos, c)
    pe_hi = jnp.sum(jnp.where(pos == hi - 1, pes[None, :], 0.0), axis=1)
    total_e = pe_hi - pe_c
    target_e = pe_c + u * total_e
    below_e = (pos >= c[:, None]) & (pos < hi) \
        & (pes[None, :] < target_e[:, None])
    k_exp = jnp.where(total_e > 0, c + _count_true(below_e), fb)

    # linear: S(j) = (PL(j+1) − PL(c)) − (j+1−c)·δ, δ = ts_c − t_base(v)
    ts_c = _onehot_i32(ts, pos, c)
    delta = (ts_c - tbase_ref[...]).astype(jnp.float32)[:, None]
    pl_c = _onehot_f32(pl_, pos, c)[:, None]
    pl_hi = jnp.sum(jnp.where(pos == hi - 1, pls[None, :], 0.0), axis=1)
    s = (pls[None, :] - pl_c) \
        - (pos + 1 - c[:, None]).astype(jnp.float32) * delta
    s_hi = (pl_hi[:, None] - pl_c) \
        - (hi - c[:, None]).astype(jnp.float32) * delta
    total_l = s_hi[:, 0]
    below_l = (pos >= c[:, None]) & (pos < hi) \
        & (s < (u * total_l)[:, None])
    k_lin = jnp.where(total_l > 0, c + _count_true(below_l), fb)

    code = code_ref[...]
    k = jnp.where(code == BIAS_UNIFORM, fb,
                  jnp.where(code == BIAS_LINEAR, k_lin, k_exp))
    _finalize(k, n, pos, dst, ts, 2 * te - 1, k_ref, n_ref, dst_out_ref,
              ts_out_ref)


# ---------------------------------------------------------------------------
# Tier L: sweep the edge window, carrying per-lane state in VMEM scratch
# ---------------------------------------------------------------------------
#
# Grid (T, MAXB): for tile t the second axis stages blocks blo[t]..bhi[t]
# of the node-ts view (index map min(blo+j, bhi); steps past the span are
# pl.when-skipped). All positions are *global*. One sweep suffices:
#
#   * cnt accumulates the cutoff count; the candidate c = a + cnt equals
#     the seen-range end until the true cutoff's block is staged, where it
#     finalizes. Every one-hot keyed on c (prefix/ts capture) and every
#     mask (pos >= c) is therefore empty in earlier blocks — the candidate
#     self-masks — and correct from the finalizing block on.
#   * the weight-mode pick count is monotone (prefix rows are
#     nondecreasing), so k = c + count stabilizes in the block containing
#     the pick; the gather one-hot keyed on the current k fires exactly
#     once, in that block (before it, clip(k, c, ·) >= c >= seen end).
#   * P(b) is a per-lane O(1) gather from the global prefix arrays done
#     outside the kernel (pb_e/pb_l inputs) — the same values the engine's
#     binary search reads, preserving bit-identity.


def _big_prologue(blo_ref, bhi_ref, te):
    t_id = pl.program_id(0)
    j = pl.program_id(1)
    blk = jnp.minimum(blo_ref[t_id] + j, bhi_ref[t_id])
    live = (blo_ref[t_id] + j) <= bhi_ref[t_id]
    pos = blk * te + jax.lax.broadcasted_iota(jnp.int32, (1, te), 1)
    return j, live, pos


def _zero_refs(*refs):
    for r in refs:
        r[...] = jnp.zeros_like(r[...])


def _big_kernel_index(
        blo_ref, bhi_ref,
        # per-walk inputs [TW]; a/b are global region bounds (0 for tier-S
        # lanes sharing the tile — their garbage is merged out)
        a_ref, b_ref, time_ref, u_ref, code_ref,
        # one staged edge block [TE]
        ts_ref, dst_ref,
        # outputs [TW]
        k_ref, n_ref, dst_out_ref, ts_out_ref,
        # scratch [TW]
        cnt_ref):
    te = ts_ref.shape[0]
    j, live, pos = _big_prologue(blo_ref, bhi_ref, te)

    @pl.when(j == 0)
    def _init():
        _zero_refs(cnt_ref, k_ref, n_ref, dst_out_ref, ts_out_ref)

    @pl.when(live)
    def _step():
        a = a_ref[...]
        b = b_ref[...]
        ts = ts_ref[...][None, :]
        in_region = (pos >= a[:, None]) & (pos < b[:, None])
        cnt_ref[...] = cnt_ref[...] + _count_true(
            in_region & (ts <= time_ref[...][:, None]))
        c = a + cnt_ref[...]
        n = b - c
        k = c + index_pick_lanes(code_ref[...], u_ref[...], n)
        hit = pos == k[:, None]
        dst_out_ref[...] = dst_out_ref[...] + jnp.sum(
            jnp.where(hit, dst_ref[...][None, :], 0), axis=1)
        ts_out_ref[...] = ts_out_ref[...] + jnp.sum(
            jnp.where(hit, ts, 0), axis=1)
        k_ref[...] = k
        n_ref[...] = n


def _big_kernel_weight(
        blo_ref, bhi_ref,
        a_ref, b_ref, time_ref, u_ref, code_ref, tbase_ref,
        pbe_ref, pbl_ref,                 # P(b): pexp[b], plin[b] per lane
        ts_ref, dst_ref, pe_ref, pes_ref, pl_ref, pls_ref,
        k_ref, n_ref, dst_out_ref, ts_out_ref,
        # scratch [TW]: cutoff count, P(c) captures, ts_c, pick counts
        cnt_ref, pce_ref, pcl_ref, tsc_ref, pke_ref, pkl_ref):
    te = ts_ref.shape[0]
    j, live, pos = _big_prologue(blo_ref, bhi_ref, te)

    @pl.when(j == 0)
    def _init():
        _zero_refs(cnt_ref, pce_ref, pcl_ref, tsc_ref, pke_ref, pkl_ref,
                   k_ref, n_ref, dst_out_ref, ts_out_ref)

    @pl.when(live)
    def _step():
        a = a_ref[...]
        b = b_ref[...]
        u = u_ref[...]
        ts = ts_ref[...][None, :]
        in_region = (pos >= a[:, None]) & (pos < b[:, None])
        cnt_ref[...] = cnt_ref[...] + _count_true(
            in_region & (ts <= time_ref[...][:, None]))
        c = a + cnt_ref[...]
        n = b - c

        # capture P(c)/ts_c in the block where c finalizes (self-masking:
        # until then c sits at/past the end of the seen range)
        hit_c = pos == c[:, None]
        pce_ref[...] = pce_ref[...] + jnp.sum(
            jnp.where(hit_c, pe_ref[...][None, :], 0.0), axis=1)
        pcl_ref[...] = pcl_ref[...] + jnp.sum(
            jnp.where(hit_c, pl_ref[...][None, :], 0.0), axis=1)
        tsc_ref[...] = tsc_ref[...] + jnp.sum(jnp.where(hit_c, ts, 0),
                                              axis=1)

        pick_region = (pos >= c[:, None]) & (pos < b[:, None])
        # exponential: count P(j+1) < target over [c, b)
        total_e = pbe_ref[...] - pce_ref[...]
        target_e = pce_ref[...] + u * total_e
        pke_ref[...] = pke_ref[...] + _count_true(
            pick_region & (pes_ref[...][None, :] < target_e[:, None]))
        # linear: count S(j) < u·total over [c, b)
        delta = (tsc_ref[...] - tbase_ref[...]).astype(jnp.float32)
        s = (pls_ref[...][None, :] - pcl_ref[...][:, None]) \
            - (pos + 1 - c[:, None]).astype(jnp.float32) * delta[:, None]
        total_l = (pbl_ref[...] - pcl_ref[...]) \
            - n.astype(jnp.float32) * delta
        pkl_ref[...] = pkl_ref[...] + _count_true(
            pick_region & (s < (u * total_l)[:, None]))

        # per-lane k, matching samplers.py expression order + clip exactly
        fb = c + index_uniform(u, n)
        k_exp = jnp.where(total_e > 0, c + pke_ref[...], fb)
        k_lin = jnp.where(total_l > 0, c + pkl_ref[...], fb)
        code = code_ref[...]
        k = jnp.where(code == BIAS_UNIFORM, fb,
                      jnp.where(code == BIAS_LINEAR, k_lin, k_exp))
        k = jnp.clip(k, c, jnp.maximum(b - 1, c))

        hit_k = pos == k[:, None]
        dst_out_ref[...] = dst_out_ref[...] + jnp.sum(
            jnp.where(hit_k, dst_ref[...][None, :], 0), axis=1)
        ts_out_ref[...] = ts_out_ref[...] + jnp.sum(
            jnp.where(hit_k, ts, 0), axis=1)
        k_ref[...] = k
        n_ref[...] = n


# ---------------------------------------------------------------------------
# Dispatch wrapper: tier split, both kernels, merge
# ---------------------------------------------------------------------------


def fused_walk_step(index: TemporalIndex, s_node: jax.Array,
                    s_time: jax.Array, code: jax.Array, u: jax.Array,
                    mode: str, cfg: SchedulerConfig,
                    *, interpret: bool | None = None) -> FusedStepResult:
    """Fused hop for walks sorted by node, with per-lane int32 bias codes.

    Splits lanes by the same degree statistic ``dispatch_stats`` reports
    (region span vs the staged 2·tile_edges window, evaluated against the
    tile's actual anchor), runs tier S in one staged pass and tier L as an
    edge-window sweep, and merges by mask. Returns global pick positions,
    neighborhood sizes, and the gathered ``dst``/``ts`` — no jnp fallback.
    """
    interpret = resolve_interpret(interpret)
    if mode not in ("index", "weight"):
        raise ValueError(f"unknown sampler mode {mode!r}")
    W = s_node.shape[0]
    E = index.edge_capacity
    TW, TE = cfg.tile_walks, cfg.tile_edges
    if W % TW or E % TE:
        raise ValueError(f"walks {W} / edges {E} not multiples of tile "
                         f"({TW}, {TE})")
    if E // TE < 2:
        raise ValueError(f"edge capacity {E} must span >= 2 tiles of {TE}")
    T = W // TW
    MAXB = E // TE

    from jax.experimental.pallas import tpu as pltpu

    a, b = node_range(index, s_node)
    # --- tier split: same task table as the seed tiled path --------------
    a_t = a.reshape(T, TW)
    b_t = b.reshape(T, TW)
    base_blocks = jnp.clip(jnp.min(a_t, axis=1) // TE, 0, MAXB - 2)
    base = base_blocks * TE
    lo = (a_t - base[:, None]).reshape(W)
    hi = (b_t - base[:, None]).reshape(W)
    # hi == 2·TE is an exact-fit in-tile region; the clips only bound the
    # garbage of tier-L lanes, whose tier-S output is merged out below
    big = (lo < 0) | (hi > 2 * TE)
    lo_k = jnp.clip(lo, 0, 2 * TE)
    hi_k = jnp.clip(hi, 0, 2 * TE)
    nc = index.node_capacity
    tbase = index.node_tbase[jnp.clip(s_node, 0, nc - 1)]
    base_blocks = base_blocks.astype(jnp.int32)

    walk_spec = pl.BlockSpec((TW,), lambda i, base_: (i,))
    edge_spec0 = pl.BlockSpec((TE,), lambda i, base_: (base_[i],))
    edge_spec1 = pl.BlockSpec((TE,), lambda i, base_: (base_[i] + 1,))
    out_shape = [jax.ShapeDtypeStruct((W,), jnp.int32) for _ in range(4)]

    # --- tier S: one staged pass ----------------------------------------
    if mode == "index":
        kernel_s = _small_kernel_index
        walk_in_s = (s_time, lo_k, hi_k, u, code)
        edge_in_s = (index.ns_ts[:E], index.ns_ts[:E],
                     index.ns_dst[:E], index.ns_dst[:E])
        n_edge_s = 2
    else:
        kernel_s = _small_kernel_weight
        walk_in_s = (s_time, lo_k, hi_k, u, code, tbase)
        edge_in_s = (index.ns_ts[:E], index.ns_ts[:E],
                     index.ns_dst[:E], index.ns_dst[:E],
                     index.pexp[:E], index.pexp[:E],
                     index.pexp[1:E + 1], index.pexp[1:E + 1],
                     index.plin[:E], index.plin[:E],
                     index.plin[1:E + 1], index.plin[1:E + 1])
        n_edge_s = 6
    grid_s = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(T,),
        in_specs=[walk_spec] * len(walk_in_s)
        + [edge_spec0, edge_spec1] * n_edge_s,
        out_specs=[walk_spec] * 4,
    )
    k_s, n_s, dst_s, ts_s = pl.pallas_call(
        kernel_s, grid_spec=grid_s, out_shape=out_shape,
        interpret=interpret)(base_blocks, *walk_in_s, *edge_in_s)

    # --- tier L: edge-window sweep ---------------------------------------
    ab_blk = (a // TE).reshape(T, TW)
    bb_blk = (jnp.maximum(b - 1, a) // TE).reshape(T, TW)
    big_t = big.reshape(T, TW)
    has_big = jnp.any(big_t, axis=1)
    blo = jnp.where(has_big,
                    jnp.min(jnp.where(big_t, ab_blk, MAXB - 1), axis=1), 0)
    bhi = jnp.where(has_big, jnp.max(jnp.where(big_t, bb_blk, 0), axis=1), 0)
    bhi = jnp.maximum(bhi, blo).astype(jnp.int32)
    blo = blo.astype(jnp.int32)
    a_big = jnp.where(big, a, 0)
    b_big = jnp.where(big, b, 0)

    walk_spec_l = pl.BlockSpec((TW,), lambda t, j, blo_, bhi_: (t,))
    edge_spec_l = pl.BlockSpec(
        (TE,), lambda t, j, blo_, bhi_: (jnp.minimum(blo_[t] + j, bhi_[t]),))
    scratch_i32 = pltpu.VMEM((TW,), jnp.int32)
    scratch_f32 = pltpu.VMEM((TW,), jnp.float32)
    if mode == "index":
        kernel_l = _big_kernel_index
        walk_in_l = (a_big, b_big, s_time, u, code)
        edge_in_l = (index.ns_ts[:E], index.ns_dst[:E])
        scratch_l = [scratch_i32]
    else:
        kernel_l = _big_kernel_weight
        walk_in_l = (a_big, b_big, s_time, u, code, tbase,
                     index.pexp[b_big], index.plin[b_big])
        edge_in_l = (index.ns_ts[:E], index.ns_dst[:E],
                     index.pexp[:E], index.pexp[1:E + 1],
                     index.plin[:E], index.plin[1:E + 1])
        scratch_l = [scratch_i32, scratch_f32, scratch_f32, scratch_i32,
                     scratch_i32, scratch_i32]
    grid_l = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(T, MAXB),
        in_specs=[walk_spec_l] * len(walk_in_l)
        + [edge_spec_l] * len(edge_in_l),
        out_specs=[walk_spec_l] * 4,
        scratch_shapes=scratch_l,
    )
    k_l, n_l, dst_l, ts_l = pl.pallas_call(
        kernel_l, grid_spec=grid_l, out_shape=out_shape,
        interpret=interpret)(blo, bhi, *walk_in_l, *edge_in_l)

    # --- merge ------------------------------------------------------------
    tile_of_walk = jnp.arange(W, dtype=jnp.int32) // TW
    k_sg = jnp.where(n_s > 0, base_blocks[tile_of_walk] * TE + k_s, 0)
    has_l = n_l > 0
    k = jnp.where(big, jnp.where(has_l, k_l, 0), k_sg)
    n = jnp.where(big, n_l, n_s)
    dst = jnp.where(big, jnp.where(has_l, dst_l, 0), dst_s)
    ts = jnp.where(big, jnp.where(has_l, ts_l, 0), ts_s)
    tiers = jnp.stack([
        jnp.sum((~big).astype(jnp.int32)),
        jnp.sum(big.astype(jnp.int32)),
        jnp.sum(jnp.where(has_big, bhi - blo + 1, 0)),
    ])
    return FusedStepResult(k=k, n=n, dst=dst, ts=ts, tiers=tiers)
