"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each oracle implements exactly the tile-level semantics of its kernel on
full arrays, so ``assert_allclose(kernel(...), ref(...))`` across
shape/dtype sweeps is meaningful. The oracles themselves are cross-checked
against the engine's own samplers in tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.samplers import (
    BIAS_LINEAR,
    BIAS_UNIFORM,
    index_exponential,
    index_linear,
    index_pick_lanes,
    index_uniform,
)


def walk_step_ref(ns_ts, ns_dst, pfx, pfx_shift,
                  base_blocks, time, lo, hi, u, tbase,
                  *, mode: str, bias: str, tile_walks: int, tile_edges: int):
    """Oracle for kernels/walk_step.py with identical inputs/outputs."""
    W = time.shape[0]
    E = ns_ts.shape[0]
    TW, TE = tile_walks, tile_edges
    T = W // TW
    tile_of_walk = jnp.arange(W, dtype=jnp.int32) // TW
    base = base_blocks[tile_of_walk] * TE            # element offset per walk

    glo = base + lo
    ghi = base + hi

    # temporal cutoff by global dense count (same math as the kernel)
    pos = jnp.arange(E, dtype=jnp.int32)
    # counting per walk over the full array is O(W*E) — fine as an oracle.
    in_region = (pos[None, :] >= glo[:, None]) & (pos[None, :] < ghi[:, None])
    cnt = jnp.sum(in_region & (ns_ts[None, :] <= time[:, None]), axis=1)
    c = glo + cnt.astype(jnp.int32)
    n = ghi - c

    if mode == "index":
        picker = {"uniform": index_uniform, "linear": index_linear,
                  "exponential": index_exponential}[bias]
        k = c + picker(u, n)
    elif mode == "weight":
        p_c = pfx[jnp.clip(c, 0, E - 1)]
        # P(ghi) via the shifted row (pfx_shift[j] = P(j+1)): pfx[ghi]
        # would clamp-misread when a region ends at the array edge
        # (ghi == E), mirroring the kernel's hi == 2·TE case.
        p_hi = jnp.where(ghi > 0, pfx_shift[jnp.clip(ghi - 1, 0, E - 1)], 0.0)
        if bias == "exponential":
            total = p_hi - p_c
            target = p_c + u * total
            below = (pos[None, :] >= c[:, None]) & (pos[None, :] < ghi[:, None]) \
                & (pfx_shift[None, :] < target[:, None])
            k = c + jnp.sum(below, axis=1).astype(jnp.int32)
            k = jnp.where(total > 0, k, c + index_uniform(u, n))
        elif bias == "linear":
            ts_c = ns_ts[jnp.clip(c, 0, E - 1)]
            delta = (ts_c - tbase).astype(jnp.float32)
            pl_c = pfx[jnp.clip(c, 0, E - 1)]
            s = (pfx_shift[None, :] - pl_c[:, None]) \
                - (pos[None, :] + 1 - c[:, None]).astype(jnp.float32) * delta[:, None]
            total = (p_hi - pl_c) - (ghi - c).astype(jnp.float32) * delta
            below = (pos[None, :] >= c[:, None]) & (pos[None, :] < ghi[:, None]) \
                & (s < (u * total)[:, None])
            k = c + jnp.sum(below, axis=1).astype(jnp.int32)
            k = jnp.where(total > 0, k, c + index_uniform(u, n))
        elif bias == "uniform":
            k = c + index_uniform(u, n)
        else:
            raise ValueError(bias)
    else:
        raise ValueError(mode)

    k = jnp.clip(k, 0, E - 1)
    has = n > 0
    k_local = jnp.where(has, k - base, 0)
    dst_pick = jnp.where(has, ns_dst[k], 0)
    ts_pick = jnp.where(has, ns_ts[k], 0)
    return k_local, n, dst_pick, ts_pick


def fused_step_ref(ns_ts, ns_dst, pexp, plin, a, b, time, code, u, tbase,
                   *, mode: str):
    """Oracle for kernels/fused_step.py — tier-free global semantics.

    ``pexp``/``plin`` are the full exclusive prefix arrays (length E+1);
    ``a``/``b`` are global region bounds; ``code`` carries per-lane bias
    codes (samplers.BIAS_CODES). Returns (k_global, n, dst, ts) with the
    same dead-lane zeroing as the fused kernel, so equality is bitwise.
    O(W·E) dense counting — fine as an oracle.
    """
    E = ns_ts.shape[0]
    pos = jnp.arange(E, dtype=jnp.int32)
    in_region = (pos[None, :] >= a[:, None]) & (pos[None, :] < b[:, None])
    cnt = jnp.sum(in_region & (ns_ts[None, :] <= time[:, None]), axis=1)
    c = a + cnt.astype(jnp.int32)
    n = b - c

    if mode == "index":
        k = c + index_pick_lanes(code, u, n)
    elif mode == "weight":
        fb = c + index_uniform(u, n)
        pes = pexp[1:E + 1]
        pick_region = (pos[None, :] >= c[:, None]) \
            & (pos[None, :] < b[:, None])
        # exponential (samplers.weighted_pick_exp expression order)
        total_e = pexp[b] - pexp[c]
        target_e = pexp[c] + u * total_e
        k_exp = c + jnp.sum(
            pick_region & (pes[None, :] < target_e[:, None]),
            axis=1).astype(jnp.int32)
        k_exp = jnp.where(total_e > 0, k_exp, fb)
        # linear (samplers.weighted_pick_linear dual-prefix form)
        ts_c = ns_ts[jnp.clip(c, 0, E - 1)]
        delta = (ts_c - tbase).astype(jnp.float32)
        pls = plin[1:E + 1]
        s = (pls[None, :] - plin[c][:, None]) \
            - (pos[None, :] + 1 - c[:, None]).astype(jnp.float32) \
            * delta[:, None]
        total_l = (plin[b] - plin[c]) - n.astype(jnp.float32) * delta
        k_lin = c + jnp.sum(
            pick_region & (s < (u * total_l)[:, None]),
            axis=1).astype(jnp.int32)
        k_lin = jnp.where(total_l > 0, k_lin, fb)
        k = jnp.where(code == BIAS_UNIFORM, fb,
                      jnp.where(code == BIAS_LINEAR, k_lin, k_exp))
        k = jnp.clip(k, c, jnp.maximum(b - 1, c))
    else:
        raise ValueError(mode)

    k = jnp.clip(k, 0, E - 1)
    has = n > 0
    k = jnp.where(has, k, 0)
    return (k, n, jnp.where(has, ns_dst[k], 0),
            jnp.where(has, ns_ts[k], 0))


def weight_prefix_ref(dt: jax.Array, valid: jax.Array,
                      scale: float = 1.0) -> jax.Array:
    """Oracle for kernels/weight_prefix.py: fused exp + masked cumsum.

    dt[i] = ts_i − t_ref[src_i] (≤ 0 for real edges). Returns the exclusive
    prefix array P of length E+1 with P[0] = 0.
    """
    w = jnp.where(valid, jnp.exp(scale * dt.astype(jnp.float32)), 0.0)
    return jnp.concatenate([jnp.zeros((1,), jnp.float32), jnp.cumsum(w)])
