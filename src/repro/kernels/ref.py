"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each oracle implements exactly the tile-level semantics of its kernel on
full arrays, so ``assert_allclose(kernel(...), ref(...))`` across
shape/dtype sweeps is meaningful. The oracles themselves are cross-checked
against the engine's own samplers in tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.samplers import (
    BIAS_LINEAR,
    BIAS_UNIFORM,
    index_exponential,
    index_linear,
    index_pick_lanes,
    index_uniform,
)


def walk_step_ref(ns_ts, ns_dst, pfx, pfx_shift,
                  base_blocks, time, lo, hi, u, tbase,
                  *, mode: str, bias: str, tile_walks: int, tile_edges: int):
    """Oracle for kernels/walk_step.py with identical inputs/outputs."""
    W = time.shape[0]
    E = ns_ts.shape[0]
    TW, TE = tile_walks, tile_edges
    T = W // TW
    tile_of_walk = jnp.arange(W, dtype=jnp.int32) // TW
    base = base_blocks[tile_of_walk] * TE            # element offset per walk

    glo = base + lo
    ghi = base + hi

    # temporal cutoff by global dense count (same math as the kernel)
    pos = jnp.arange(E, dtype=jnp.int32)
    # counting per walk over the full array is O(W*E) — fine as an oracle.
    in_region = (pos[None, :] >= glo[:, None]) & (pos[None, :] < ghi[:, None])
    cnt = jnp.sum(in_region & (ns_ts[None, :] <= time[:, None]), axis=1)
    c = glo + cnt.astype(jnp.int32)
    n = ghi - c

    if mode == "index":
        picker = {"uniform": index_uniform, "linear": index_linear,
                  "exponential": index_exponential}[bias]
        k = c + picker(u, n)
    elif mode == "weight":
        p_c = pfx[jnp.clip(c, 0, E - 1)]
        # P(ghi) via the shifted row (pfx_shift[j] = P(j+1)): pfx[ghi]
        # would clamp-misread when a region ends at the array edge
        # (ghi == E), mirroring the kernel's hi == 2·TE case.
        p_hi = jnp.where(ghi > 0, pfx_shift[jnp.clip(ghi - 1, 0, E - 1)], 0.0)
        if bias == "exponential":
            total = p_hi - p_c
            target = p_c + u * total
            below = (pos[None, :] >= c[:, None]) & (pos[None, :] < ghi[:, None]) \
                & (pfx_shift[None, :] < target[:, None])
            k = c + jnp.sum(below, axis=1).astype(jnp.int32)
            k = jnp.where(total > 0, k, c + index_uniform(u, n))
        elif bias == "linear":
            ts_c = ns_ts[jnp.clip(c, 0, E - 1)]
            delta = (ts_c - tbase).astype(jnp.float32)
            pl_c = pfx[jnp.clip(c, 0, E - 1)]
            s = (pfx_shift[None, :] - pl_c[:, None]) \
                - (pos[None, :] + 1 - c[:, None]).astype(jnp.float32) * delta[:, None]
            total = (p_hi - pl_c) - (ghi - c).astype(jnp.float32) * delta
            below = (pos[None, :] >= c[:, None]) & (pos[None, :] < ghi[:, None]) \
                & (s < (u * total)[:, None])
            k = c + jnp.sum(below, axis=1).astype(jnp.int32)
            k = jnp.where(total > 0, k, c + index_uniform(u, n))
        elif bias == "uniform":
            k = c + index_uniform(u, n)
        else:
            raise ValueError(bias)
    else:
        raise ValueError(mode)

    k = jnp.clip(k, 0, E - 1)
    has = n > 0
    k_local = jnp.where(has, k - base, 0)
    dst_pick = jnp.where(has, ns_dst[k], 0)
    ts_pick = jnp.where(has, ns_ts[k], 0)
    return k_local, n, dst_pick, ts_pick


def fused_step_ref(ns_ts, ns_dst, pexp, plin, a, b, time, code, u, tbase,
                   *, mode: str):
    """Oracle for kernels/fused_step.py — tier-free global semantics.

    ``pexp``/``plin`` are the full exclusive prefix arrays (length E+1);
    ``a``/``b`` are global region bounds; ``code`` carries per-lane bias
    codes (samplers.BIAS_CODES). Returns (k_global, n, dst, ts) with the
    same dead-lane zeroing as the fused kernel, so equality is bitwise.
    O(W·E) dense counting — fine as an oracle.
    """
    E = ns_ts.shape[0]
    pos = jnp.arange(E, dtype=jnp.int32)
    in_region = (pos[None, :] >= a[:, None]) & (pos[None, :] < b[:, None])
    cnt = jnp.sum(in_region & (ns_ts[None, :] <= time[:, None]), axis=1)
    c = a + cnt.astype(jnp.int32)
    n = b - c

    if mode == "index":
        k = c + index_pick_lanes(code, u, n)
    elif mode == "weight":
        fb = c + index_uniform(u, n)
        pes = pexp[1:E + 1]
        pick_region = (pos[None, :] >= c[:, None]) \
            & (pos[None, :] < b[:, None])
        # exponential (samplers.weighted_pick_exp expression order)
        total_e = pexp[b] - pexp[c]
        target_e = pexp[c] + u * total_e
        k_exp = c + jnp.sum(
            pick_region & (pes[None, :] < target_e[:, None]),
            axis=1).astype(jnp.int32)
        k_exp = jnp.where(total_e > 0, k_exp, fb)
        # linear (samplers.weighted_pick_linear dual-prefix form)
        ts_c = ns_ts[jnp.clip(c, 0, E - 1)]
        delta = (ts_c - tbase).astype(jnp.float32)
        pls = plin[1:E + 1]
        s = (pls[None, :] - plin[c][:, None]) \
            - (pos[None, :] + 1 - c[:, None]).astype(jnp.float32) \
            * delta[:, None]
        total_l = (plin[b] - plin[c]) - n.astype(jnp.float32) * delta
        k_lin = c + jnp.sum(
            pick_region & (s < (u * total_l)[:, None]),
            axis=1).astype(jnp.int32)
        k_lin = jnp.where(total_l > 0, k_lin, fb)
        k = jnp.where(code == BIAS_UNIFORM, fb,
                      jnp.where(code == BIAS_LINEAR, k_lin, k_exp))
        k = jnp.clip(k, c, jnp.maximum(b - 1, c))
    else:
        raise ValueError(mode)

    k = jnp.clip(k, 0, E - 1)
    has = n > 0
    k = jnp.where(has, k, 0)
    return (k, n, jnp.where(has, ns_dst[k], 0),
            jnp.where(has, ns_ts[k], 0))


def alias_pick_ref(weights: jax.Array, a, c, b, u, *, radix: int,
                   degree_cap: int):
    """Brute-force oracle for ``core.alias.alias_pick`` (DESIGN.md §17).

    ``weights`` float32[E]: raw per-position weights over the ns view
    (what ``alias.region_weights`` produces). O(W·E) dense per lane:

    * **tabled branch** (``c == a`` and ``0 < deg <= degree_cap``):
      recompute the largest-remainder masses densely and inverse-CDF the
      quantized uniform ``⌊u·deg·M⌋`` through the mass prefix. Same *law*
      as the alias draw — under full enumeration of the ``deg·M``
      quantized uniforms each outcome appears exactly ``mass_i`` times on
      both sides — but not the same per-u mapping (the two-stack
      construction permutes which uniform lands where), so tests compare
      per-outcome counts, not per-u picks.
    * **fallback branch**: per-u exact — a dense count below the target
      over the same full-array weight prefix ``alias_pick`` binary-
      searches, so every float compares identically.

    Returns (k, tabled): the pick and which branch produced it.
    """
    E = weights.shape[0]
    M = radix
    pos = jnp.arange(E, dtype=jnp.int32)
    ptab = jnp.concatenate([jnp.zeros((1,), jnp.float32),
                            jnp.cumsum(weights)])
    deg = b - a
    n = b - c
    tabled = (c == a) & (deg > 0) & (deg <= degree_cap)

    # --- dense largest-remainder masses, one row per lane ---------------
    in_reg = (pos[None, :] >= a[:, None]) & (pos[None, :] < b[:, None])
    w = jnp.where(in_reg, jnp.maximum(weights[None, :], 0.0), 0.0)
    total_w = jnp.sum(w, axis=1)
    target = (deg * M).astype(jnp.int32)
    targetf = target.astype(jnp.float32)
    q = jnp.where((total_w > 0)[:, None],
                  w * (targetf / jnp.maximum(total_w, 1e-30))[:, None], 0.0)
    fl = jnp.minimum(jnp.floor(q).astype(jnp.int32), target[:, None])
    frac = q - fl.astype(jnp.float32)
    d = target - jnp.sum(fl, axis=1)
    order_desc = jnp.argsort(jnp.where(in_reg & (frac > 0), -frac, 2.0),
                             axis=1, stable=True)
    rank_desc = jnp.argsort(order_desc, axis=1, stable=True).astype(
        jnp.int32)
    add = (rank_desc < d[:, None]) & (frac > 0)
    order_asc = jnp.argsort(jnp.where(in_reg & (fl >= 1), frac, 2.0),
                            axis=1, stable=True)
    rank_asc = jnp.argsort(order_asc, axis=1, stable=True).astype(jnp.int32)
    sub = (rank_asc < -d[:, None]) & (fl >= 1)
    m = fl + add.astype(jnp.int32) - sub.astype(jnp.int32)
    resid = target - jnp.sum(m, axis=1)
    imax = jnp.argmax(jnp.where(in_reg, m, -1), axis=1)
    m = m.at[jnp.arange(m.shape[0]), imax].add(resid)
    uniform = jnp.where(in_reg, M, 0).astype(jnp.int32)
    m = jnp.where((total_w > 0)[:, None], m, uniform)
    m = jnp.where(in_reg, m, 0)

    # inverse CDF over the quantized masses
    kq = jnp.floor(u * targetf).astype(jnp.int32)
    kq = jnp.clip(kq, 0, jnp.maximum(deg * M - 1, 0))
    cum = jnp.cumsum(m, axis=1)
    k_tab = a + jnp.sum(in_reg & (cum <= kq[:, None]), axis=1).astype(
        jnp.int32)

    # --- fallback: dense count over the shared float prefix -------------
    total = ptab[b] - ptab[c]
    tgt = ptab[c] + u * total
    pes = ptab[1:E + 1]
    in_sfx = (pos[None, :] >= c[:, None]) & (pos[None, :] < b[:, None])
    k_w = c + jnp.sum(in_sfx & (pes[None, :] < tgt[:, None]),
                      axis=1).astype(jnp.int32)
    k_w = jnp.where(total > 0, k_w, c + index_uniform(u, n))

    k = jnp.where(tabled, k_tab, k_w)
    return jnp.clip(k, c, jnp.maximum(b - 1, c)), tabled


def node2vec_step_ref(ns_src, ns_dst, valid, prev, ks, vs, p, q):
    """Oracle for the engine's second-order rejection loop (paper §2.5).

    ``ks`` int32[ROUNDS, W] are the per-round first-order proposals (the
    differential tests produce them through an independent picker fed the
    same uniform stream), ``vs`` float32[ROUNDS, W] the accept uniforms,
    ``prev`` int32[W] the previous node (< 0 = no history), ``p``/``q``
    float32[W] per-lane node2vec parameters. The adjacency probe is the
    dense O(W·E) ``any(src == prev & dst == cand)`` over ``valid``
    positions — independent of the engine's O(log E) ranged search.
    Returns the accepted pick per lane (round-0 proposal when every
    round rejects), matching the engine's scan bit-for-bit.
    """
    beta_max = jnp.maximum(jnp.maximum(1.0 / p, 1.0), 1.0 / q).astype(
        jnp.float32)
    rounds = ks.shape[0]
    k_acc = ks[0]
    accepted = jnp.zeros(prev.shape, bool)
    for r in range(rounds):
        cand = ns_dst[jnp.clip(ks[r], 0, ns_dst.shape[0] - 1)]
        is_return = cand == prev
        is_common = jnp.any(valid[None, :] & (ns_src[None, :] ==
                                              prev[:, None])
                            & (ns_dst[None, :] == cand[:, None]), axis=1)
        beta = jnp.where(is_return, 1.0 / p,
                         jnp.where(is_common, 1.0, 1.0 / q)).astype(
            jnp.float32)
        ok = (vs[r] * beta_max <= beta) | (prev < 0)
        take = ok & ~accepted
        k_acc = jnp.where(take, ks[r], k_acc)
        accepted = accepted | ok
    return k_acc


def weight_prefix_ref(dt: jax.Array, valid: jax.Array,
                      scale: float = 1.0) -> jax.Array:
    """Oracle for kernels/weight_prefix.py: fused exp + masked cumsum.

    dt[i] = ts_i − t_ref[src_i] (≤ 0 for real edges). Returns the exclusive
    prefix array P of length E+1 with P[0] = 0.
    """
    w = jnp.where(valid, jnp.exp(scale * dt.astype(jnp.float32)), 0.0)
    return jnp.concatenate([jnp.zeros((1,), jnp.float32), jnp.cumsum(w)])
