"""Shared backend detection for the Pallas kernel entry points.

Every kernel wrapper takes ``interpret: bool | None = None`` and resolves
it here: ``None`` auto-detects (compiled on a TPU backend, interpret mode
everywhere else), an explicit bool always wins. Keeping the resolver in
one leaf module lets ``ops``, ``walk_step``, ``weight_prefix``, and
``fused_step`` share it without import cycles.
"""
from __future__ import annotations

import jax


def on_tpu() -> bool:
    """True when the default JAX backend is a TPU."""
    return jax.default_backend() == "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve an ``interpret`` kwarg: None → auto-detect by backend."""
    if interpret is None:
        return not on_tpu()
    return bool(interpret)
