"""Pallas TPU kernels for the paper's compute hot spots.

walk_step.py     — cooperative walk step (smem-panel analog, §2.4.3)
fused_step.py    — fused convergence-tiered hop: prefix lookup + draw +
                   gather in one dispatch, degree-tiered lanes (§2.4.3-4)
weight_prefix.py — fused exp + blocked scan (ingestion "weight" stage)
ops.py           — jit'd dispatch wrappers (kernel vs fallback)
ref.py           — pure-jnp oracles
runtime.py       — shared interpret/backend auto-detect
"""
