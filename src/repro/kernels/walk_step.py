"""Pallas TPU kernel: cooperative walk step (paper §2.4.3 smem panel).

One *task* = one tile of ``tile_walks`` walk lanes (sorted by current node)
plus a window of ``2 * tile_edges`` consecutive rows of the node-ts view
staged HBM→VMEM once per task via scalar-prefetched, data-dependent
BlockSpec index maps — the TPU analogue of the paper's "preload the node's
adjacency metadata into shared memory once per task".

TPU-native adaptation (recorded in DESIGN.md §2): the paper's per-walk
binary search over smem becomes a **dense compare-and-reduce** over the
staged tile. Each lane's temporal cutoff is

    c = lo + |{ j ∈ [lo, hi) : ts[j] ≤ t }|

computed as a [tile_walks, 2·tile_edges] vectorized compare + row-sum —
pure VPU/MXU work with zero per-lane gathers, which TPUs strongly prefer
over latency-bound pointer chasing. The weight-mode inverse CDF uses the
same counting trick over the staged prefix-sum rows, and the final edge
fetch is a one-hot select over the staged ``dst``/``ts`` rows.

Grid iteration on TPU is sequential per core; tasks are independent, so
the grid parallelizes across cores/megacore without interaction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.samplers import index_exponential, index_linear, index_uniform
from repro.kernels.runtime import resolve_interpret


def _count_true(mask: jax.Array) -> jax.Array:
    return jnp.sum(mask.astype(jnp.int32), axis=1)


def _onehot_pick_i32(values_row: jax.Array, pos: jax.Array,
                     k: jax.Array) -> jax.Array:
    """Exact int32 gather-by-one-hot: sum(where(pos == k, values, 0))."""
    sel = jnp.where(pos == k[:, None], values_row[None, :], 0)
    return jnp.sum(sel, axis=1)


def _kernel(mode: str, bias: str,
            # scalar prefetch
            base_ref,
            # per-walk tile inputs [TW]
            time_ref, lo_ref, hi_ref, u_ref, tbase_ref,
            # staged edge-view windows, two consecutive blocks each [TE]
            ts0_ref, ts1_ref, dst0_ref, dst1_ref,
            px0_ref, px1_ref, ps0_ref, ps1_ref,
            # outputs [TW]
            k_ref, n_ref, dst_out_ref, ts_out_ref):
    te = ts0_ref.shape[0]
    ts = jnp.concatenate([ts0_ref[...], ts1_ref[...]])        # [2TE]
    dst = jnp.concatenate([dst0_ref[...], dst1_ref[...]])

    t = time_ref[...][:, None]                                # [TW, 1]
    lo = lo_ref[...][:, None]
    hi = hi_ref[...][:, None]
    pos = jax.lax.broadcasted_iota(jnp.int32, (1, 2 * te), 1)  # [1, 2TE]
    in_region = (pos >= lo) & (pos < hi)

    # temporal cutoff by dense count (ts ascending within [lo, hi))
    c = lo[:, 0] + _count_true(in_region & (ts[None, :] <= t))
    n = hi[:, 0] - c
    u = u_ref[...]

    if mode == "index":
        if bias == "uniform":
            i = index_uniform(u, n)
        elif bias == "linear":
            i = index_linear(u, n)
        elif bias == "exponential":
            i = index_exponential(u, n)
        else:
            raise ValueError(bias)
        k = c + i
    elif mode == "weight":
        px = jnp.concatenate([px0_ref[...], px1_ref[...]])    # P(base+j)
        ps = jnp.concatenate([ps0_ref[...], ps1_ref[...]])    # P(base+j+1)
        p_c = jnp.sum(jnp.where(pos == c[:, None], px[None, :], 0.0), axis=1)
        # P(hi) comes from the shifted row: ps[j] = P(base+j+1), so
        # P(hi) = ps[hi-1]. Reading px[hi] silently yields 0 when hi == 2·TE
        # (a region ending exactly at the staged window's edge — a legal
        # in-tile task), which would zero the neighborhood's weight mass.
        p_hi = jnp.sum(jnp.where(pos == hi - 1, ps[None, :], 0.0), axis=1)
        if bias == "exponential":
            total = p_hi - p_c
            target = p_c + u * total
            # smallest j in [c, hi) with P(j+1) >= target, via counting
            below = (pos >= c[:, None]) & (pos < hi) \
                & (ps[None, :] < target[:, None])
            k = c + _count_true(below)
            # underflowed mass -> uniform fallback (matches samplers.py)
            k = jnp.where(total > 0, k, c + index_uniform(u, n))
        elif bias == "linear":
            # S(j) = (PL(j+1) - PL(c)) - (j+1-c)·δ, δ = ts_c − t_base(v);
            # px/ps here carry the *linear* prefix rows; t_base(v) arrives
            # per walk in tbase_ref (a cheap node-level gather done outside).
            ts_c = _onehot_pick_i32(ts, pos, c)
            delta = (ts_c - tbase_ref[...]).astype(jnp.float32)[:, None]
            pl_c = jnp.sum(jnp.where(pos == c[:, None], px[None, :], 0.0),
                           axis=1)[:, None]
            s = (ps[None, :] - pl_c) \
                - (pos + 1 - c[:, None]).astype(jnp.float32) * delta
            s_hi = (p_hi[:, None] - pl_c) \
                - (hi - c[:, None]).astype(jnp.float32) * delta
            total = s_hi[:, 0]
            target = u * total
            below = (pos >= c[:, None]) & (pos < hi) & (s < target[:, None])
            k = c + _count_true(below)
            k = jnp.where(total > 0, k, c + index_uniform(u, n))
        elif bias == "uniform":
            k = c + index_uniform(u, n)
        else:
            raise ValueError(bias)
    else:
        raise ValueError(mode)

    k = jnp.clip(k, 0, 2 * te - 1)
    has = n > 0
    k_ref[...] = jnp.where(has, k, 0)
    n_ref[...] = n
    dst_out_ref[...] = jnp.where(has, _onehot_pick_i32(dst, pos, k), 0)
    ts_out_ref[...] = jnp.where(has, _onehot_pick_i32(ts, pos, k), 0)


@functools.partial(jax.jit, static_argnames=(
    "mode", "bias", "tile_walks", "tile_edges", "interpret"))
def walk_step_tiled(ns_ts, ns_dst, pfx, pfx_shift,
                    base_blocks, time, lo, hi, u, tbase,
                    *, mode: str, bias: str, tile_walks: int,
                    tile_edges: int, interpret: bool | None = None):
    """Run the cooperative walk-step kernel over all tiles.

    Args:
      ns_ts / ns_dst: node-ts view rows, length E (multiple of tile_edges).
      pfx / pfx_shift: P(j) and P(j+1) prefix rows for the active weight
        bias (exp or linear), length E. Ignored for index mode (pass any
        array of the right shape).
      base_blocks: int32[T] block index (units of tile_edges) staged per task.
      time/lo/hi/u/tbase: per-walk arrays, length W = T * tile_walks,
        sorted by node; lo/hi are tile-local row offsets; tbase is the
        per-walk node t_base gather (used by the linear bias only).

    ``interpret=None`` auto-detects (compiled on TPU, interpret elsewhere).

    Returns (k_local, n, dst_pick, ts_pick) — k_local is tile-local.
    """
    interpret = resolve_interpret(interpret)
    W = time.shape[0]
    E = ns_ts.shape[0]
    TW, TE = tile_walks, tile_edges
    assert W % TW == 0 and E % TE == 0, (W, TW, E, TE)
    T = W // TW

    from jax.experimental.pallas import tpu as pltpu

    walk_spec = pl.BlockSpec((TW,), lambda i, base: (i,))
    edge_spec0 = pl.BlockSpec((TE,), lambda i, base: (base[i],))
    edge_spec1 = pl.BlockSpec((TE,), lambda i, base: (base[i] + 1,))

    kernel = functools.partial(_kernel, mode, bias)
    out_shape = [jax.ShapeDtypeStruct((W,), jnp.int32) for _ in range(4)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(T,),
        in_specs=[walk_spec] * 5 + [edge_spec0, edge_spec1] * 4,
        out_specs=[walk_spec] * 4,
    )
    fn = pl.pallas_call(kernel, grid_spec=grid_spec, out_shape=out_shape,
                        interpret=interpret)
    k, n, dpick, tpick = fn(base_blocks, time, lo, hi, u, tbase,
                            ns_ts, ns_ts, ns_dst, ns_dst,
                            pfx, pfx, pfx_shift, pfx_shift)
    return k, n, dpick, tpick
