"""Pallas TPU kernel: fused exp-weight + prefix-sum (paper Table 4 "weight").

The cumulative-weight precomputation is one of the paper's four ingestion
stages (up to 26% of per-batch time on Delicious). On TPU we fuse the
elementwise exp with the scan: the grid walks edge blocks **sequentially**
(TPU grids are sequential per core), carrying the running sum in an SMEM
scratch cell — a classic carry-propagating blocked scan with one HBM read
and one HBM write per element.

Block shape: (1, tile) over a (1, E) view — TPU wants ≥2-D refs with the
lane dim last; ``tile`` is a multiple of 128 lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret


def _kernel(scale, dt_ref, valid_ref, out_ref, carry_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[0] = 0.0

    w = jnp.where(valid_ref[...],
                  jnp.exp(scale * dt_ref[...].astype(jnp.float32)), 0.0)
    c = jnp.cumsum(w, axis=-1)
    out_ref[...] = c + carry_ref[0]
    carry_ref[0] = carry_ref[0] + c[0, -1]


@functools.partial(jax.jit,
                   static_argnames=("scale", "tile", "interpret"))
def weight_prefix(dt: jax.Array, valid: jax.Array, *, scale: float = 1.0,
                  tile: int = 1024,
                  interpret: bool | None = None) -> jax.Array:
    """Fused exp+scan. Returns exclusive prefix P of length E+1, P[0]=0.

    ``interpret=None`` auto-detects (compiled on TPU, interpret elsewhere).
    """
    from jax.experimental.pallas import tpu as pltpu

    interpret = resolve_interpret(interpret)

    E = dt.shape[0]
    assert E % tile == 0, (E, tile)
    grid = (E // tile,)
    inc = pl.pallas_call(
        functools.partial(_kernel, scale),
        grid=grid,
        in_specs=[pl.BlockSpec((1, tile), lambda i: (0, i)),
                  pl.BlockSpec((1, tile), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, E), jnp.float32),
        scratch_shapes=[pltpu.SMEM((1,), jnp.float32)],
        interpret=interpret,
    )(dt[None, :], valid[None, :])
    return jnp.concatenate([jnp.zeros((1,), jnp.float32), inc[0]])
