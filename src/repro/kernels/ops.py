"""Jit'd dispatch wrappers for the Pallas kernels.

``walk_step`` is the tiled-path hop primitive used by the walk engine
(SchedulerConfig.path == "tiled"): it builds the fixed-shape task table,
runs the kernel for in-tile lanes, and serves oversize lanes (neighborhood
wider than the staged window — the paper's G-axis "global" fallback tier)
through the pure-jnp path, merging by mask.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import SamplerConfig, SchedulerConfig
from repro.core import scheduler as sched
from repro.core.samplers import pick_in_neighborhood
from repro.core.temporal_index import (
    TemporalIndex,
    node_range,
    temporal_cutoff,
)
from repro.kernels.runtime import on_tpu, resolve_interpret  # noqa: F401
from repro.kernels.walk_step import walk_step_tiled


def walk_step(index: TemporalIndex, s_node: jax.Array, s_time: jax.Array,
              u: jax.Array, scfg: SamplerConfig, cfg: SchedulerConfig,
              *, interpret: bool | None = None):
    """Hop search+sample for walks sorted by node. Returns (k_global, n)."""
    interpret = resolve_interpret(interpret)
    W = s_node.shape[0]
    E = index.edge_capacity
    TW, TE = cfg.tile_walks, cfg.tile_edges
    if W % TW or E % TE:
        raise ValueError(f"walks {W} / edges {E} not multiples of tile "
                         f"({TW}, {TE})")

    a, b = node_range(index, s_node)
    # --- task table: align each tile's window to a TE block --------------
    T = W // TW
    a_t = a.reshape(T, TW)
    b_t = b.reshape(T, TW)
    base_blocks = jnp.min(a_t, axis=1) // TE
    base_blocks = jnp.clip(base_blocks, 0, E // TE - 2)
    base = base_blocks * TE
    lo = (a_t - base[:, None]).reshape(W)
    hi = (b_t - base[:, None]).reshape(W)
    # a region ending exactly at the staged window's edge (hi == 2·TE) fits
    # [base, base + 2·TE) and is served in-tile; only hi > 2·TE overflows.
    # In-tile lanes satisfy 0 <= lo <= hi <= 2·TE (including empty regions
    # with lo == hi == 2·TE), so the clips below pass them through
    # unchanged and only bound the garbage of oversize lanes (whose kernel
    # output is masked out below). A tighter 2·TE - 1 clip on lo would turn
    # an empty end-of-window region into a phantom 1-edge region.
    oversize = (lo < 0) | (hi > 2 * TE)
    lo_k = jnp.clip(lo, 0, 2 * TE)
    hi_k = jnp.clip(hi, 0, 2 * TE)

    if scfg.mode == "weight" and scfg.bias == "linear":
        pfx = index.plin[:E]
        pfx_shift = index.plin[1:E + 1]
    else:
        pfx = index.pexp[:E]
        pfx_shift = index.pexp[1:E + 1]
    nc = index.node_capacity
    tbase = index.node_tbase[jnp.clip(s_node, 0, nc - 1)]

    k_loc, n_k, _, _ = walk_step_tiled(
        index.ns_ts[:E], index.ns_dst[:E], pfx, pfx_shift,
        base_blocks.astype(jnp.int32), s_time, lo_k, hi_k, u, tbase,
        mode=scfg.mode, bias=scfg.bias, tile_walks=TW, tile_edges=TE,
        interpret=interpret)
    tile_of_walk = jnp.arange(W, dtype=jnp.int32) // TW
    k_kernel = base_blocks[tile_of_walk] * TE + k_loc

    # --- global fallback for oversize lanes (paper's G-cap fallback) -----
    c = temporal_cutoff(index, a, b, s_time)
    n_fb = b - c
    k_fb = pick_in_neighborhood(index, scfg, c, b, u, s_node)

    k = jnp.where(oversize, k_fb, k_kernel)
    n = jnp.where(oversize, n_fb, n_k)
    return k, n
