"""Dispatch plane (paper §2.4.4, Fig. 5) — TPU adaptation.

The paper partitions per-step work into five terminal kernels keyed on
(W = walks co-located at a node, G = the node's timestamp-group count).
On TPU there are no per-task kernel launches; the same two axes instead
select between three execution layouts (SchedulerConfig.path) and, inside
the tiled path, whether a task's metadata slice fits a VMEM tile (the smem
analog) or must fall back to global-memory-style gathers.

This module computes:
* per-step tier statistics (the paper's Table 3 / launch-count analog),
* the modeled HBM traffic of the fullwalk vs grouped layouts (the paper's
  structural metric "global-memory traffic amortized across co-located
  walks" — measurable on real TPU, modeled here on CPU),
* fixed-shape task tables for the Pallas tiled kernel.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import SchedulerConfig
from repro.core.temporal_index import TemporalIndex

# stats vector layout (per step)
STAT_ALIVE = 0            # alive walks
STAT_UNIQUE_NODES = 1     # distinct nodes carrying walks
STAT_SOLO = 2             # tasks dispatched solo (W <= solo_threshold)
STAT_GROUP_SMEM = 3       # grouped tasks whose G fits the VMEM tile
STAT_GROUP_GLOBAL = 4     # grouped tasks needing global fallback
STAT_MEGA = 5             # mega-hub sub-tasks (ceil(W / max_task_walks))
STAT_BYTES_FULLWALK = 6   # modeled HBM bytes, per-walk layout
STAT_BYTES_GROUPED = 7    # modeled HBM bytes, grouped layout
NUM_STATS = 8

_BYTES_PER_EDGE_ROW = 8   # (dst, ts) int32 pair
_BYTES_PER_OFFSET = 4


def dispatch_stats(index: TemporalIndex, cur_node: jax.Array,
                   alive: jax.Array, cfg: SchedulerConfig) -> jax.Array:
    """Per-step dispatch-plane statistics (paper Alg. 1 lines 4-9 analog)."""
    nc = index.node_capacity
    node = jnp.clip(cur_node, 0, nc - 1)
    w_per_node = jax.ops.segment_sum(alive.astype(jnp.int32), node,
                                     num_segments=nc)
    occupied = w_per_node > 0
    g = index.node_group_counts

    solo = occupied & (w_per_node <= cfg.solo_threshold)
    grouped = occupied & (w_per_node > cfg.solo_threshold) \
        & (w_per_node <= cfg.max_task_walks)
    mega_tasks = jnp.where(
        occupied & (w_per_node > cfg.max_task_walks),
        -(-w_per_node // cfg.max_task_walks), 0)
    fits_tile = g <= cfg.tile_edges

    deg = index.node_starts[1:nc + 1] - index.node_starts[:nc]
    # modeled bytes: the search touches ~log2(deg) edge rows + 2 offsets.
    probes = jnp.ceil(jnp.log2(jnp.maximum(deg, 2).astype(jnp.float32)))
    per_lookup = probes * _BYTES_PER_EDGE_ROW + 2 * _BYTES_PER_OFFSET
    wf = w_per_node.astype(jnp.float32)
    # fullwalk: every walk pays the lookup + one edge-row read.
    bytes_full = jnp.sum(wf * (per_lookup + _BYTES_PER_EDGE_ROW))
    # grouped: the lookup is paid once per occupied node (time-dedup is
    # strictly better; this is the conservative node-level bound), each walk
    # still pays its sampled edge-row read.
    bytes_grp = jnp.sum(jnp.where(occupied, per_lookup, 0.0)
                        + wf * _BYTES_PER_EDGE_ROW)

    return jnp.stack([
        jnp.sum(alive.astype(jnp.float32)),
        jnp.sum(occupied.astype(jnp.float32)),
        jnp.sum(solo.astype(jnp.float32)),
        jnp.sum((grouped & fits_tile).astype(jnp.float32)),
        jnp.sum((grouped & ~fits_tile).astype(jnp.float32)),
        jnp.sum(mega_tasks.astype(jnp.float32)),
        bytes_full,
        bytes_grp,
    ])


class TaskTable(NamedTuple):
    """Fixed-shape task table for the Pallas tiled kernel.

    Each *task* covers one tile of ``tile_walks`` sorted walk lanes plus the
    edge-array window [edge_base, edge_base + tile_edges) that contains the
    neighborhoods of every walk in the tile (tasks are split so this holds;
    the split mirrors the paper's mega-hub expansion).
    """

    edge_base: jax.Array    # int32[T] base offset into the ns view
    walk_lo: jax.Array      # int32[W] per-walk tile-local region start
    walk_hi: jax.Array      # int32[W] per-walk tile-local region end
    oversize: jax.Array     # bool[W] neighborhood exceeds the tile => fallback


def build_task_table(index: TemporalIndex, s_node: jax.Array,
                     a: jax.Array, b: jax.Array,
                     cfg: SchedulerConfig) -> TaskTable:
    """Build the tile table for walks already sorted by node.

    Tiles are aligned windows of the ns view: a walk whose node region fits
    entirely inside the tile anchored at its own region start participates;
    walks whose regions span more than ``tile_edges`` are flagged oversize
    and served by the global-fallback path (paper's G-axis fallback).
    """
    W = s_node.shape[0]
    tw = cfg.tile_walks
    T = W // tw
    # anchor each tile at the smallest region start among its walks
    a_tiles = a.reshape(T, tw)
    b_tiles = b.reshape(T, tw)
    base = jnp.min(a_tiles, axis=1)
    span_ok = (b_tiles - base[:, None]) <= cfg.tile_edges
    walk_lo = (a_tiles - base[:, None]).reshape(W)
    walk_hi = (b_tiles - base[:, None]).reshape(W)
    oversize = ~span_ok.reshape(W)
    walk_lo = jnp.clip(walk_lo, 0, cfg.tile_edges)
    walk_hi = jnp.clip(walk_hi, 0, cfg.tile_edges)
    base = jnp.clip(base, 0, jnp.maximum(index.edge_capacity - cfg.tile_edges, 0))
    return TaskTable(edge_base=base.astype(jnp.int32),
                     walk_lo=walk_lo.astype(jnp.int32),
                     walk_hi=walk_hi.astype(jnp.int32),
                     oversize=oversize)
