"""Dispatch plane (paper §2.4.4, Fig. 5) — TPU adaptation.

The paper partitions per-step work into five terminal kernels keyed on
(W = walks co-located at a node, G = the node's timestamp-group count).
On TPU there are no per-task kernel launches; the same two axes instead
select between three execution layouts (SchedulerConfig.path) and, inside
the tiled path, whether a task's metadata slice fits a VMEM tile (the smem
analog) or must fall back to global-memory-style gathers.

This module computes:
* per-step tier statistics (the paper's Table 3 / launch-count analog),
* the modeled HBM traffic of the fullwalk vs grouped layouts (the paper's
  structural metric "global-memory traffic amortized across co-located
  walks" — measurable on real TPU, modeled here on CPU),
* fixed-shape task tables for the Pallas tiled kernel.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SchedulerConfig
from repro.core.temporal_index import TemporalIndex

# stats vector layout (per step)
STAT_ALIVE = 0            # alive walks
STAT_UNIQUE_NODES = 1     # distinct nodes carrying walks
STAT_SOLO = 2             # tasks dispatched solo (W <= solo_threshold)
STAT_GROUP_SMEM = 3       # grouped tasks whose G fits the VMEM tile
STAT_GROUP_GLOBAL = 4     # grouped tasks needing global fallback
STAT_MEGA = 5             # mega-hub sub-tasks (ceil(W / max_task_walks))
STAT_BYTES_FULLWALK = 6   # modeled HBM bytes, per-walk layout
STAT_BYTES_GROUPED = 7    # modeled HBM bytes, grouped layout
STAT_FUSED_SMALL = 8      # fused tier-S lanes (span fits the staged window)
STAT_FUSED_BIG = 9        # fused tier-L lanes (edge-window sweep)
STAT_FUSED_BLOCKS = 10    # modeled tier-L swept edge blocks
NUM_STATS = 11

_BYTES_PER_EDGE_ROW = 8   # (dst, ts) int32 pair
_BYTES_PER_OFFSET = 4


def dispatch_stats(index: TemporalIndex, cur_node: jax.Array,
                   alive: jax.Array, cfg: SchedulerConfig) -> jax.Array:
    """Per-step dispatch-plane statistics (paper Alg. 1 lines 4-9 analog)."""
    nc = index.node_capacity
    node = jnp.clip(cur_node, 0, nc - 1)
    w_per_node = jax.ops.segment_sum(alive.astype(jnp.int32), node,
                                     num_segments=nc)
    occupied = w_per_node > 0
    g = index.node_group_counts

    solo = occupied & (w_per_node <= cfg.solo_threshold)
    grouped = occupied & (w_per_node > cfg.solo_threshold) \
        & (w_per_node <= cfg.max_task_walks)
    mega_tasks = jnp.where(
        occupied & (w_per_node > cfg.max_task_walks),
        -(-w_per_node // cfg.max_task_walks), 0)
    fits_tile = g <= cfg.tile_edges

    deg = index.node_starts[1:nc + 1] - index.node_starts[:nc]
    # modeled bytes: the search touches ~log2(deg) edge rows + 2 offsets.
    probes = jnp.ceil(jnp.log2(jnp.maximum(deg, 2).astype(jnp.float32)))
    per_lookup = probes * _BYTES_PER_EDGE_ROW + 2 * _BYTES_PER_OFFSET
    wf = w_per_node.astype(jnp.float32)
    # fullwalk: every walk pays the lookup + one edge-row read.
    bytes_full = jnp.sum(wf * (per_lookup + _BYTES_PER_EDGE_ROW))
    # grouped: the lookup is paid once per occupied node (time-dedup is
    # strictly better; this is the conservative node-level bound), each walk
    # still pays its sampled edge-row read.
    bytes_grp = jnp.sum(jnp.where(occupied, per_lookup, 0.0)
                        + wf * _BYTES_PER_EDGE_ROW)

    # fused-kernel tier split (kernels/fused_step.py): a lane whose whole
    # region span fits the staged 2·tile_edges window is tier S, else tier
    # L. This is the idealized per-lane rule — the kernel's split is
    # tile-anchored and can only demote additional lanes — and the block
    # count models one sweep block per tile_edges of span plus the
    # alignment slop, per tier-L lane (per-tile dedup not modeled).
    fused_small = alive & (deg[node] <= 2 * cfg.tile_edges)
    fused_big = alive & (deg[node] > 2 * cfg.tile_edges)
    fused_blocks = jnp.where(fused_big,
                             -(-deg[node] // cfg.tile_edges) + 1, 0)

    return jnp.stack([
        jnp.sum(alive.astype(jnp.float32)),
        jnp.sum(occupied.astype(jnp.float32)),
        jnp.sum(solo.astype(jnp.float32)),
        jnp.sum((grouped & fits_tile).astype(jnp.float32)),
        jnp.sum((grouped & ~fits_tile).astype(jnp.float32)),
        jnp.sum(mega_tasks.astype(jnp.float32)),
        bytes_full,
        bytes_grp,
        jnp.sum(fused_small.astype(jnp.float32)),
        jnp.sum(fused_big.astype(jnp.float32)),
        jnp.sum(fused_blocks.astype(jnp.float32)),
    ])


# ---------------------------------------------------------------------------
# O(W) bucketed per-hop regrouping (DESIGN.md §10)
# ---------------------------------------------------------------------------

_RADIX_BITS = 4           # bucket bits per counting pass
_RADIX = 1 << _RADIX_BITS
_TIME_SUBSORT_BITS = 16   # quantized relative-time subsort resolution


def _counting_pass(digit: jax.Array) -> jax.Array:
    """One stable counting-sort pass over ``_RADIX``-valued keys.

    Returns the permutation ``perm`` (output position -> input lane) that
    groups lanes by ``digit`` while preserving input order inside each
    bucket. Segment offsets come from the bucket counts (a segment-sum in
    one-hot form) + an exclusive cumsum; the within-bucket rank is the
    running occurrence count — a dense [W, _RADIX] compare + cumsum, which
    is the same VPU-friendly shape as the tiled kernel's cutoff trick
    (DESIGN.md §9) and costs O(W) for the fixed radix, vs the O(W log W)
    lexsort it replaces. The narrow radix keeps the one-hot panel cheap;
    more (but much smaller) passes win on both VPU and CPU.
    """
    W = digit.shape[0]
    buckets = jnp.arange(_RADIX, dtype=jnp.int32)
    onehot = (digit[:, None] == buckets[None, :]).astype(jnp.int32)
    running = jnp.cumsum(onehot, axis=0)                 # inclusive per bucket
    rank = jnp.take_along_axis(running, digit[:, None], axis=1)[:, 0] - 1
    counts = running[-1]
    starts = jnp.cumsum(counts) - counts                 # exclusive offsets
    pos = starts[digit] + rank
    return jnp.zeros((W,), jnp.int32).at[pos].set(
        jnp.arange(W, dtype=jnp.int32))


def _radix_passes(perm: jax.Array, key: jax.Array, num_bits: int):
    """Compose LSD counting passes until ``num_bits`` of ``key`` are sorted."""
    k = key[perm]
    for shift in range(0, num_bits, _RADIX_BITS):
        pp = _counting_pass((k >> shift) & (_RADIX - 1))
        perm = perm[pp]
        k = k[pp]
    return perm


def bucket_regroup(node_key: jax.Array, time_key: jax.Array,
                   node_capacity: int, *, time_subsort: bool = True
                   ) -> jax.Array:
    """O(W) replacement for the per-hop ``jnp.lexsort`` (DESIGN.md §10).

    Returns a permutation (output position -> input lane) grouping lanes by
    ``node_key`` (exact LSD counting sort over the node-id digits; dead
    lanes keyed ``node_capacity + 1`` land in the trailing bucket). When
    ``time_subsort`` is set, lanes are first ordered by a span-scaled
    16-bit quantized relative time (equal times always share a key, so
    grouping coarsens with the window span instead of saturating away)
    so equal-(node, time) lanes coalesce into single segments
    — but only when some occupied node actually carries mixed times; the
    check is a segment min/max and the passes sit behind a ``lax.cond``, so
    the common near-sorted steady state pays nothing. The permutation is
    purely an execution layout: any grouping is correct (segment heads are
    re-derived from the materialized order), so the quantization never
    affects emitted walks.
    """
    W = node_key.shape[0]
    perm = jnp.arange(W, dtype=jnp.int32)

    if time_subsort:
        nseg = node_capacity + 2
        seg = jnp.clip(node_key, 0, nseg - 1)
        occupied = seg <= node_capacity - 1
        big = jnp.int32(np.iinfo(np.int32).max)
        tmin = jax.ops.segment_min(jnp.where(occupied, time_key, big), seg,
                                   num_segments=nseg)
        tmax = jax.ops.segment_max(jnp.where(occupied, time_key, -big), seg,
                                   num_segments=nseg)
        mixed = jnp.any(tmin[:node_capacity] < tmax[:node_capacity])

        def with_time(p):
            # span-scaled 16-bit quantization: shift the relative time so
            # the whole observed span fits the subsort bits — a hard clip
            # would saturate (and stop grouping anything) once the window
            # spans more than 2^16 ticks. The shift is monotone and maps
            # equal times to equal keys, so grouping only coarsens.
            tlo = jnp.min(time_key)
            span = jnp.maximum(jnp.max(time_key) - tlo, 1)
            shift = jnp.maximum(
                jnp.floor(jnp.log2(span.astype(jnp.float32))).astype(
                    jnp.int32) - (_TIME_SUBSORT_BITS - 1), 0)
            rel = jnp.clip((time_key - tlo) >> shift, 0,
                           (1 << _TIME_SUBSORT_BITS) - 1).astype(jnp.int32)
            return _radix_passes(p, rel, _TIME_SUBSORT_BITS)

        perm = jax.lax.cond(mixed, with_time, lambda p: p, perm)

    node_bits = max(_RADIX_BITS,
                    int(np.ceil(np.log2(node_capacity + 2) / _RADIX_BITS))
                    * _RADIX_BITS)
    return _radix_passes(perm, node_key, node_bits)


class TaskTable(NamedTuple):
    """Fixed-shape task table for the Pallas tiled kernel.

    Each *task* covers one tile of ``tile_walks`` sorted walk lanes plus the
    edge-array window [edge_base, edge_base + tile_edges) that contains the
    neighborhoods of every walk in the tile (tasks are split so this holds;
    the split mirrors the paper's mega-hub expansion).
    """

    edge_base: jax.Array    # int32[T] base offset into the ns view
    walk_lo: jax.Array      # int32[W] per-walk tile-local region start
    walk_hi: jax.Array      # int32[W] per-walk tile-local region end
    oversize: jax.Array     # bool[W] neighborhood exceeds the tile => fallback


def build_task_table(index: TemporalIndex, s_node: jax.Array,
                     a: jax.Array, b: jax.Array,
                     cfg: SchedulerConfig) -> TaskTable:
    """Build the tile table for walks already sorted by node.

    Tiles are aligned windows of the ns view: a walk whose node region fits
    entirely inside the tile anchored at its own region start participates;
    walks whose regions span more than ``tile_edges`` are flagged oversize
    and served by the global-fallback path (paper's G-axis fallback).
    """
    W = s_node.shape[0]
    tw = cfg.tile_walks
    T = W // tw
    # anchor each tile at the smallest region start among its walks
    a_tiles = a.reshape(T, tw)
    b_tiles = b.reshape(T, tw)
    base = jnp.min(a_tiles, axis=1)
    span_ok = (b_tiles - base[:, None]) <= cfg.tile_edges
    walk_lo = (a_tiles - base[:, None]).reshape(W)
    walk_hi = (b_tiles - base[:, None]).reshape(W)
    oversize = ~span_ok.reshape(W)
    walk_lo = jnp.clip(walk_lo, 0, cfg.tile_edges)
    walk_hi = jnp.clip(walk_hi, 0, cfg.tile_edges)
    base = jnp.clip(base, 0, jnp.maximum(index.edge_capacity - cfg.tile_edges, 0))
    return TaskTable(edge_base=base.astype(jnp.int32),
                     walk_lo=walk_lo.astype(jnp.int32),
                     walk_hi=walk_hi.astype(jnp.int32),
                     oversize=oversize)
