"""Alias/radix bias factorization with incremental maintenance (DESIGN.md §17).

Tempest (paper §2.5) ships three closed-form inverse-CDF samplers.
Arbitrary bias functions need either an O(log n) binary search over a
cumulative-weight array per hop, or — the Bingo factorization this module
implements — a per-node **alias table** over the window's neighborhood
regions: weights are quantized radix-wise into integer masses summing
``deg · M`` (M = ``TableSpec.radix``), the classic two-stack Vose
construction turns the masses into (threshold, partner) bucket pairs, and
a draw is O(1): one uniform → bucket ``j = ⌊u·deg·M⌋ div M`` → biased
coin ``r = ⌊u·deg·M⌋ mod M`` → ``j`` if ``r < thresh[j]`` else
``partner[j]``.

Layout — three flat arrays carried in the window state beside pexp/plin:

* ``thresh``  int32[E]: per ns-view position, the bucket threshold in
  [0, M]; ``-1`` where no table exists (padding, or regions larger than
  ``degree_cap``).
* ``partner`` int32[E]: the alias partner as a **region-local offset** —
  position-independent content, which is what lets a node whose region
  merely *shifted* (other nodes' edges moved around it) copy its old
  table bytes instead of rebuilding.
* ``ptab``    float32[E+1]: exclusive prefix of the raw weights in
  ns-view order. The exact fallback for draws the table cannot serve —
  temporal-suffix neighborhoods Γ_t(v) ⊊ [a, b) and oversize regions —
  via the same O(log E) shifted binary search the weight-mode samplers
  use.

**Incremental maintenance rule** (the Bingo dynamic-update analog): an
ingest advance dirties exactly the nodes whose region content changed —
sources of kept batch edges, sources of the evicted store prefix, and
sources of overflow-clipped rows. Dirty nodes are compacted and rebuilt
in fixed-size chunks under a ``lax.while_loop`` (work ∝ dirty count, not
window size); clean nodes positionally copy their old table content
through the old→new ``node_starts`` offset. A from-scratch build is the
same code path with an all-dirty mask, so incremental-vs-scratch
leaf-identity (property-tested) is a real check of the dirty rule, not a
tautology of shared arithmetic.

**Quantization** is largest-remainder apportionment: ``m_i =
⌊w_i/W · deg·M⌋`` plus one unit to the ``deficit`` largest fractional
remainders (index tie-break). Zero-weight entries provably get zero mass
(surplus only lands on positions with a positive remainder), and the
total is exactly ``deg·M`` — the invariant the two-stack construction
and the exact-enumeration law tests rely on. ``deg·M ≤ 64·4096 = 2^18``
keeps every quantized quantity exact in float32/int32.

The module is import-light on purpose: samplers.py does not import it
(walk_engine dispatches table-coded lanes), so there is no cycle.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.samplers import _shifted_lower_bound, index_uniform
from repro.core.temporal_index import TemporalIndex

DEFAULT_RADIX = 4096        # M: coin resolution per bucket (2^12)
DEFAULT_DEGREE_CAP = 64     # R: largest region served by the O(1) path
DEFAULT_CHUNK = 128         # dirty nodes rebuilt per while_loop iteration


# ---------------------------------------------------------------------------
# Spec + state
# ---------------------------------------------------------------------------


def weight_uniform(ts, tbase, tref):
    """w ≡ 1 — table-bias reproduction of the uniform sampler."""
    return jnp.ones_like(ts, dtype=jnp.float32)


def weight_linear(ts, tbase, tref):
    """w = ts − t_base(v) + 1 — the weight-mode linear element weights."""
    return (ts - tbase + 1).astype(jnp.float32)


def weight_exponential(ts, tbase, tref):
    """w = exp(ts − t_ref(v)) — the weight-mode exponential weights."""
    return jnp.exp((ts - tref).astype(jnp.float32))


WEIGHT_FNS = {
    "uniform": weight_uniform,
    "linear": weight_linear,
    "exponential": weight_exponential,
}


@dataclass(frozen=True)
class TableSpec:
    """Static alias-table parameters (hashable; keys jit caches).

    ``weight(ts, tbase, tref) -> float32`` is the user bias: elementwise
    and **node-local** (it may read only the edge's timestamp and its
    source node's min/max timestamp). Node-locality is what makes the
    incremental clean-node copy sound: a node whose edge set did not
    change cannot see its weights change. Non-negative by contract;
    negative outputs are clamped to 0.
    """

    weight: Callable = weight_exponential
    radix: int = DEFAULT_RADIX
    degree_cap: int = DEFAULT_DEGREE_CAP
    chunk: int = DEFAULT_CHUNK

    def __post_init__(self):
        if self.radix < 2 or self.radix & (self.radix - 1):
            raise ValueError(f"radix must be a power of two >= 2, got "
                             f"{self.radix}")
        if self.degree_cap < 1:
            raise ValueError("degree_cap must be >= 1")
        if self.degree_cap * self.radix > 1 << 23:
            # deg·M must stay exactly representable in float32
            raise ValueError("degree_cap * radix must be <= 2^23")
        if self.chunk < 1:
            raise ValueError("chunk must be >= 1")


class AliasTables(NamedTuple):
    """Per-node alias tables over the ns-view regions (see module doc)."""

    thresh: jax.Array    # int32[E]   bucket threshold in [0, M]; -1 = none
    partner: jax.Array   # int32[E]   region-local alias partner offset
    ptab: jax.Array      # float32[E+1] exclusive raw-weight prefix (fallback)
    rebuilt: jax.Array   # int32[]    cumulative node rebuilds (obs counter)


def spec_from_sampler(scfg) -> Optional[TableSpec]:
    """The TableSpec a SamplerConfig implies, or None when tables are off."""
    if scfg.bias != "table" and scfg.table_weight is None:
        return None
    weight = scfg.table_weight
    if weight is None:
        weight = weight_exponential
    elif isinstance(weight, str):
        weight = WEIGHT_FNS[weight]
    return TableSpec(weight=weight, radix=scfg.table_radix,
                     degree_cap=scfg.table_degree_cap)


# ---------------------------------------------------------------------------
# Row-level construction (vmapped over a chunk of dirty nodes)
# ---------------------------------------------------------------------------


def quantize_row(w: jax.Array, deg: jax.Array, radix: int) -> jax.Array:
    """Integer masses m[R] with Σm = deg·M exactly, m_i ∝ w_i.

    Largest-remainder apportionment with index tie-break; positions with
    zero weight get zero mass; an all-zero row falls back to uniform
    masses (M each). ``deg == 0`` yields the all-zero row.
    """
    R = w.shape[0]
    M = radix
    pos = jnp.arange(R, dtype=jnp.int32)
    inrow = pos < deg
    w = jnp.where(inrow, jnp.maximum(w.astype(jnp.float32), 0.0), 0.0)
    total_w = jnp.sum(w)
    target = (deg * M).astype(jnp.int32)
    targetf = target.astype(jnp.float32)

    q = jnp.where(total_w > 0, w * (targetf / jnp.maximum(total_w, 1e-30)),
                  0.0)
    fl = jnp.minimum(jnp.floor(q).astype(jnp.int32), target)
    frac = q - fl.astype(jnp.float32)
    d = target - jnp.sum(fl)

    # d > 0: +1 to the d largest remainders (stable argsort => index ties)
    order_desc = jnp.argsort(jnp.where(inrow & (frac > 0), -frac, 2.0),
                             stable=True)
    rank_desc = jnp.argsort(order_desc, stable=True).astype(jnp.int32)
    add = (rank_desc < d) & (frac > 0)
    # d < 0 (float-rounding edge): -1 from the |d| smallest remainders
    # among positions that have a unit to give
    order_asc = jnp.argsort(jnp.where(inrow & (fl >= 1), frac, 2.0),
                            stable=True)
    rank_asc = jnp.argsort(order_asc, stable=True).astype(jnp.int32)
    sub = (rank_asc < -d) & (fl >= 1)

    m = fl + add.astype(jnp.int32) - sub.astype(jnp.int32)
    # belt-and-braces: fold any residual into the heaviest slot (never a
    # zero-weight one: it holds >= target/deg >= M units when total_w > 0)
    resid = target - jnp.sum(m)
    imax = jnp.argmax(m)
    m = m.at[imax].add(resid)

    uniform = jnp.where(inrow, M, 0).astype(jnp.int32)
    m = jnp.where(total_w > 0, m, uniform)
    return jnp.where(inrow, m, 0)


def vose_row(masses: jax.Array, deg: jax.Array, radix: int):
    """Two-stack Vose construction as a fixed-trip jnp scan.

    ``masses`` int32[R] with Σ = deg·M (see ``quantize_row``). Returns
    (thresh[R], partner[R]): bucket i resolves to i when the coin
    ``r < thresh[i]`` and to ``partner[i]`` otherwise. Each scan step pops
    one small (m < M) and one large (m ≥ M) bucket, finalizes the small
    one at its current mass and donates the shortfall from the large one;
    the exact-integer invariant (remaining mass = pending·M) means the
    large stack can never empty first, and whatever remains when the
    small stack empties sits at exactly M — finalized self-referential in
    the post-pass.
    """
    R = masses.shape[0]
    M = radix
    pos = jnp.arange(R, dtype=jnp.int32)
    inrow = pos < deg

    is_small = inrow & (masses < M)
    is_large = inrow & (masses >= M)
    # compacted ascending index stacks; top = entry count-1
    small = jnp.argsort(jnp.where(is_small, 0, 1), stable=True).astype(
        jnp.int32)
    large = jnp.argsort(jnp.where(is_large, 0, 1), stable=True).astype(
        jnp.int32)
    sn = jnp.sum(is_small.astype(jnp.int32))
    ln = jnp.sum(is_large.astype(jnp.int32))

    thresh0 = jnp.full((R,), -1, jnp.int32)
    partner0 = pos

    def step(carry, _):
        m, ss, sn_, ls, ln_, th, pa = carry
        can = (sn_ > 0) & (ln_ > 0)
        si = ss[jnp.maximum(sn_ - 1, 0)]
        li = ls[jnp.maximum(ln_ - 1, 0)]
        ms = m[si]
        th2 = th.at[si].set(ms)
        pa2 = pa.at[si].set(li)
        ml = m[li] - (M - ms)
        m2 = m.at[li].set(ml)
        sn2 = sn_ - 1
        ln2 = ln_ - 1
        now_small = ml < M
        ss2 = jnp.where(now_small, ss.at[sn2].set(li), ss)
        sn3 = sn2 + now_small.astype(jnp.int32)
        ls2 = jnp.where(now_small, ls, ls.at[ln2].set(li))
        ln3 = ln2 + (1 - now_small.astype(jnp.int32))
        new = (m2, ss2, sn3, ls2, ln3, th2, pa2)
        old = (m, ss, sn_, ls, ln_, th, pa)
        out = jax.tree.map(lambda a, b: jnp.where(can, a, b), new, old)
        return out, None

    carry0 = (masses, small, sn, large, ln, thresh0, partner0)
    (m, _, _, _, _, thresh, partner), _ = jax.lax.scan(
        step, carry0, None, length=max(R - 1, 1))

    pending = inrow & (thresh < 0)
    thresh = jnp.where(pending, M, thresh)
    partner = jnp.where(pending, pos, partner)
    return jnp.where(inrow, thresh, -1), jnp.where(inrow, partner, 0)


def row_masses(thresh: jax.Array, partner: jax.Array, deg, radix: int):
    """Recover the quantized masses a (thresh, partner) row encodes.

    m_i = thresh_i + Σ_j [partner_j == i]·(M − thresh_j) — the accounting
    identity the exact-enumeration law tests assert against.
    """
    R = thresh.shape[0]
    M = radix
    pos = jnp.arange(R, dtype=jnp.int32)
    inrow = pos < deg
    own = jnp.where(inrow, thresh, 0)
    donated = jnp.where(inrow, M - thresh, 0)
    recv = jnp.zeros((R,), jnp.int32).at[
        jnp.where(inrow, partner, R)].add(donated, mode="drop")
    return own + recv


# ---------------------------------------------------------------------------
# Flat build / incremental update
# ---------------------------------------------------------------------------


def region_weights(index: TemporalIndex, spec: TableSpec) -> jax.Array:
    """Raw per-position weights over the ns view (0 beyond the valid part)."""
    nc = index.node_capacity
    srcc = jnp.clip(index.ns_src, 0, nc - 1)
    w = spec.weight(index.ns_ts, index.node_tbase[srcc],
                    index.node_tref[srcc])
    valid = index.ns_src < nc
    return jnp.where(valid, jnp.maximum(w.astype(jnp.float32), 0.0), 0.0)


def update_tables(index: TemporalIndex, spec: TableSpec, *,
                  old_starts: Optional[jax.Array] = None,
                  old_tables: Optional[AliasTables] = None,
                  dirty: Optional[jax.Array] = None) -> AliasTables:
    """(Re)build alias tables for ``index``.

    With ``old_starts``/``old_tables``/``dirty`` (bool[N]) this is the
    incremental advance: clean nodes copy their old region content
    through the old→new offset, dirty ones rebuild in chunks. Without
    them (or with an all-True mask) it is the from-scratch build — the
    same code path, so the two are leaf-identical by construction *iff*
    the dirty rule catches every changed node (property-tested).
    """
    E = index.edge_capacity
    nc = index.node_capacity
    M, R, K = spec.radix, spec.degree_cap, spec.chunk

    w = region_weights(index, spec)
    ptab = jnp.concatenate([jnp.zeros((1,), jnp.float32), jnp.cumsum(w)])

    starts = index.node_starts
    if dirty is None:
        dirty = jnp.ones((nc,), bool)
    dirty = dirty.astype(bool)

    thresh = jnp.full((E,), -1, jnp.int32)
    partner = jnp.zeros((E,), jnp.int32)

    if old_tables is not None:
        # clean-node positional copy: position p of node v's new region
        # holds what old position old_starts[v] + (p − starts[v]) held
        pos = jnp.arange(E, dtype=jnp.int32)
        v = jnp.clip(index.ns_src, 0, nc - 1)
        clean = (index.ns_src < nc) & ~dirty[v]
        old_pos = jnp.clip(old_starts[v] + (pos - starts[v]), 0, E - 1)
        thresh = jnp.where(clean, old_tables.thresh[old_pos], thresh)
        partner = jnp.where(clean, old_tables.partner[old_pos], partner)
        prev_rebuilt = old_tables.rebuilt
    else:
        prev_rebuilt = jnp.asarray(0, jnp.int32)

    # compact dirty node ids to the front; sentinel nc beyond
    ids = jnp.argsort(jnp.where(dirty, 0, 1), stable=True).astype(jnp.int32)
    n_dirty = jnp.sum(dirty.astype(jnp.int32))
    ids = jnp.where(jnp.arange(nc, dtype=jnp.int32) < n_dirty, ids, nc)
    ids = jnp.concatenate([ids, jnp.full((K,), nc, jnp.int32)])

    off = jnp.arange(R, dtype=jnp.int32)

    def rebuild_chunk(state):
        i, th, pa = state
        vs = jax.lax.dynamic_slice(ids, (i * K,), (K,))
        vc = jnp.clip(vs, 0, nc)
        A = starts[vc]
        B = starts[vc + 1]
        deg = jnp.where(vs < nc, B - A, 0)
        small = (deg > 0) & (deg <= R)
        degr = jnp.where(small, deg, 0)          # oversize rows: no-op
        gpos = A[:, None] + off[None, :]
        gvalid = off[None, :] < degr[:, None]
        wrow = jnp.where(gvalid, w[jnp.clip(gpos, 0, E - 1)], 0.0)
        masses = jax.vmap(quantize_row, in_axes=(0, 0, None))(wrow, degr, M)
        throw, parow = jax.vmap(vose_row, in_axes=(0, 0, None))(
            masses, degr, M)
        spos = jnp.where(gvalid, gpos, E).reshape(-1)   # E -> dropped
        th = th.at[spos].set(throw.reshape(-1), mode="drop")
        pa = pa.at[spos].set(parow.reshape(-1), mode="drop")
        return i + 1, th, pa

    def cond(state):
        return state[0] * K < n_dirty

    _, thresh, partner = jax.lax.while_loop(
        cond, rebuild_chunk, (jnp.asarray(0, jnp.int32), thresh, partner))

    deg_all = starts[1:nc + 1] - starts[:nc]
    rebuilt = prev_rebuilt + jnp.sum((dirty & (deg_all > 0)).astype(
        jnp.int32))
    return AliasTables(thresh=thresh, partner=partner, ptab=ptab,
                       rebuilt=rebuilt)


def build_tables(index: TemporalIndex, spec: TableSpec) -> AliasTables:
    """From-scratch build: ``update_tables`` with an all-dirty mask."""
    return update_tables(index, spec)


# ---------------------------------------------------------------------------
# Draws
# ---------------------------------------------------------------------------


def alias_pick(tables: AliasTables, a: jax.Array, c: jax.Array,
               b: jax.Array, u: jax.Array, *, radix: int,
               degree_cap: int) -> jax.Array:
    """Pick k ∈ [c, b) under the table bias; valid only when b > c.

    O(1) alias path when the temporal cutoff keeps the whole region
    (c == a) and the region fits the table (deg ≤ degree_cap) — true for
    every hop launched at the window floor, and for any node whose edges
    all postdate the walker's clock. Otherwise the draw falls back to the
    exact float-weight inverse CDF over ``ptab`` restricted to [c, b)
    (O(log E) — the binary-search comparator the benchmarks race the
    table against).
    """
    M = radix
    E = tables.thresh.shape[0]
    deg = b - a
    n = b - c
    tabled = (c == a) & (deg > 0) & (deg <= degree_cap)

    # O(1) path: bucket + biased coin, all exact in float32 (deg·M ≤ 2^23)
    kq = jnp.floor(u * (deg * M).astype(jnp.float32)).astype(jnp.int32)
    kq = jnp.clip(kq, 0, jnp.maximum(deg * M - 1, 0))
    j = kq // M
    r = kq - j * M
    pa = jnp.clip(a + j, 0, E - 1)
    take_own = r < tables.thresh[pa]
    k_tab = a + jnp.where(take_own, j, tables.partner[pa])

    # exact fallback over the raw-weight prefix, suffix-restricted
    total = tables.ptab[b] - tables.ptab[c]
    target = tables.ptab[c] + u * total
    k_w = _shifted_lower_bound(tables.ptab, c, b, target)
    k_w = jnp.where(total > 0, k_w, c + index_uniform(u, n))

    k = jnp.where(tabled, k_tab, k_w)
    return jnp.clip(k, c, jnp.maximum(b - 1, c))
