"""Baselines the paper compares against, implemented here (not stubbed):

* ``TeaStyleSampler`` — a CPU temporal-walk engine in the style of
  TEA/TEA+ [EuroSys'23, TACO'24]: per-node alias tables over exponential
  edge weights built at ingest, with per-hop *rejection* against the
  temporal cutoff and an exact-method fallback (their "hybrid" sampling).
  Single-threaded numpy — the comparison isolates algorithmic structure,
  mirroring the paper's Table 5 caveat about differing execution models.

* ``StaticWalker`` — a time-agnostic random walk engine in the style of
  FlowWalker/ThunderRW used for Table 6: timestamps are discarded, hops
  sample uniformly from the full static adjacency, so causal validity of
  its output measures exactly what the paper's §3.10 measures.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def build_alias(probs: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Vose alias table."""
    n = len(probs)
    scaled = probs * n / probs.sum()
    small = [i for i, p in enumerate(scaled) if p < 1.0]
    large = [i for i, p in enumerate(scaled) if p >= 1.0]
    prob = np.zeros(n)
    alias = np.zeros(n, np.int64)
    while small and large:
        s = small.pop()
        l = large.pop()
        prob[s] = scaled[s]
        alias[s] = l
        scaled[l] = scaled[l] - (1.0 - scaled[s])
        (small if scaled[l] < 1.0 else large).append(l)
    for i in large + small:
        prob[i] = 1.0
    return prob, alias


def alias_draw(prob, alias, rng) -> int:
    i = rng.integers(0, len(prob))
    return i if rng.random() < prob[i] else alias[i]


class TeaStyleSampler:
    def __init__(self, src, dst, ts, num_nodes: int, bias: str = "exponential"):
        order = np.lexsort((ts, src))
        self.src = src[order]
        self.dst = dst[order]
        self.ts = ts[order]
        self.starts = np.searchsorted(self.src, np.arange(num_nodes + 1))
        self.num_nodes = num_nodes
        self.bias = bias
        self.alias = {}
        for v in range(num_nodes):
            a, b = self.starts[v], self.starts[v + 1]
            if b > a:
                t = self.ts[a:b].astype(np.float64)
                if bias == "exponential":
                    w = np.exp(t - t.max())
                elif bias == "linear":
                    w = t - t.min() + 1.0
                else:
                    w = np.ones_like(t)
                w = np.maximum(w, 1e-30)
                self.alias[v] = build_alias(w)

    def _exact_pick(self, v, t, rng):
        a, b = self.starts[v], self.starts[v + 1]
        c = a + np.searchsorted(self.ts[a:b], t, side="right")
        if c >= b:
            return -1
        tt = self.ts[c:b].astype(np.float64)
        if self.bias == "exponential":
            w = np.exp(tt - tt.max())
        elif self.bias == "linear":
            w = tt - tt.min() + 1.0
        else:
            w = np.ones_like(tt)
        p = w / w.sum()
        return c + rng.choice(len(p), p=p)

    def walk(self, start: int, t0: int, length: int, rng,
             p: float = 1.0, q: float = 1.0):
        """Hybrid alias+rejection temporal walk; optional node2vec β."""
        nodes = [start]
        times = [t0]
        v, t = start, t0
        prev = -1
        for _ in range(length):
            if v not in self.alias:
                break
            a, b = self.starts[v], self.starts[v + 1]
            prob, alias = self.alias[v]
            k = -1
            for _try in range(8):            # rejection rounds
                cand = a + alias_draw(prob, alias, rng)
                if self.ts[cand] > t:
                    if p != 1.0 or q != 1.0:
                        w = self.dst[cand]
                        if w == prev:
                            beta = 1.0 / p
                        else:
                            lo = np.searchsorted(self.dst[self.starts[prev]:
                                                          self.starts[prev + 1]]
                                                 if prev >= 0 else
                                                 np.empty(0), w)
                            # adjacency probe (unsorted dst -> linear scan)
                            adj = (prev >= 0 and w in
                                   self.dst[self.starts[prev]:
                                            self.starts[prev + 1]])
                            beta = 1.0 if adj else 1.0 / q
                        bmax = max(1.0 / p, 1.0, 1.0 / q)
                        if rng.random() * bmax > beta:
                            continue
                    k = cand
                    break
            if k < 0:
                k = self._exact_pick(v, t, rng)   # exact fallback
            if k < 0:
                break
            prev = v
            v = int(self.dst[k])
            t = int(self.ts[k])
            nodes.append(v)
            times.append(t)
        return nodes, times


class StaticWalker:
    """Time-agnostic walker (FlowWalker/ThunderRW abstraction level)."""

    def __init__(self, src, dst, ts, num_nodes: int):
        order = np.argsort(src)
        self.src = src[order]
        self.dst = dst[order]
        self.ts = ts[order]                 # kept only for post-hoc validity
        self.starts = np.searchsorted(self.src, np.arange(num_nodes + 1))
        self.num_nodes = num_nodes

    def walk(self, start: int, length: int, rng):
        nodes = [start]
        times = []
        v = start
        for _ in range(length):
            a, b = self.starts[v], self.starts[v + 1]
            if b <= a:
                break
            k = rng.integers(a, b)
            v = int(self.dst[k])
            nodes.append(v)
            times.append(int(self.ts[k]))   # timestamp it happens to carry
        return nodes, times


def temporal_validity(nodes, times) -> Tuple[int, int, bool]:
    """(valid_hops, total_hops, walk_valid) under strict monotonicity.

    Mirrors the paper's §3.10 post-processing: a greedy earliest-feasible
    timestamp assignment — since each hop carries the timestamp of the
    edge actually traversed, strict increase is the feasibility test.
    """
    total = len(times)
    if total == 0:
        return 0, 0, False
    valid = 0
    prev = -np.inf
    ok = True
    for t in times:
        if t > prev:
            valid += 1
        else:
            ok = False
        prev = t
    return valid, total, ok
