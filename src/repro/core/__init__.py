"""Tempest-JAX core: the paper's contribution as composable JAX modules."""
from repro.core.edge_store import (
    EdgeBatch,
    EdgeStore,
    empty_store,
    make_batch,
    stack_batches,
    store_from_arrays,
)
from repro.core.temporal_index import (
    TemporalIndex,
    build_index,
    build_index_donated,
)
from repro.core.walk_engine import (
    LaneParams,
    WalkBuffers,
    WalkResult,
    alloc_walk_buffers,
    generate_walk_lanes,
    generate_walks,
    generate_walks_donated,
)
from repro.core.window import (
    WindowState,
    ingest,
    ingest_nodonate,
    ingest_sort,
    init_window,
)

__all__ = [
    "EdgeBatch", "EdgeStore", "empty_store", "make_batch", "stack_batches",
    "store_from_arrays", "TemporalIndex", "build_index",
    "build_index_donated", "LaneParams", "WalkBuffers", "WalkResult",
    "alloc_walk_buffers", "generate_walk_lanes", "generate_walks",
    "generate_walks_donated", "WindowState", "ingest", "ingest_nodonate",
    "ingest_sort", "init_window",
]
