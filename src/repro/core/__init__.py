"""Tempest-JAX core: the paper's contribution as composable JAX modules."""
from repro.core.edge_store import (
    EdgeBatch,
    EdgeStore,
    empty_store,
    make_batch,
    stack_batches,
    store_from_arrays,
)
from repro.core.temporal_index import (
    TemporalIndex,
    build_index,
    build_index_donated,
)
from repro.core.walk_engine import WalkResult, generate_walks
from repro.core.window import WindowState, ingest, ingest_sort, init_window

__all__ = [
    "EdgeBatch", "EdgeStore", "empty_store", "make_batch", "stack_batches",
    "store_from_arrays", "TemporalIndex", "build_index",
    "build_index_donated", "WalkResult", "generate_walks", "WindowState",
    "ingest", "ingest_sort", "init_window",
]
