"""Distributed walk engine: node-partitioned edge store + per-step
walk migration over ``all_to_all`` (shard_map).

Scale-out design (KnightKing-style walk migration, recast as collectives):

* nodes are partitioned across devices by a pluggable ``Placement``
  policy (repro/distributed/placement.py, DESIGN.md §15; default: range,
  ``owner(v) = v // range``); each device holds the dual-index of exactly
  its nodes' out-edges, so a resident walk's Γ_t(v) is always served
  locally;
* each step: (1) local hop via the same sampler stack as the single-device
  engine, (2) walks bucketed by destination owner, (3) one ``all_to_all``
  moves walk payloads (id, node, time + trace) to their new owners,
  (4) received walks compact into resident slots;
* RNG is keyed by (walk_id, step) via fold_in, so results are
  **bit-identical to the single-device engine** regardless of placement
  (tested in tests/test_distributed_walks.py);
* buckets are fixed-capacity (static shapes); overflow drops are counted
  and surface in the result — at production scale bucket capacity is a
  provisioning knob exactly like the paper's walk-array capacity.

This is a beyond-paper feature: Tempest is single-GPU; pod-scale walk
generation needs the store sharded (81B-edge windows exceed one chip's
HBM) and this module supplies the mechanism.

The owner-bucketed exchange (``exchange_by_owner``) and the resident-walk
hop (``hop_resident``) are shared with the *streaming* side of the same
partition: repro/distributed/streaming_shard.py keeps a node-partitioned
sliding window per shard (DESIGN.md §12) and advances walks over the
freshly ingested shard-local indexes with the exact same migration
machinery — there the per-(walk, step) RNG is the streaming engine's
(``uniform(fold_in(walk_key, step), (W,))[walk_id]``), which makes the
sharded replay bit-identical to the single-device
``StreamingEngine.replay_device``.
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import SamplerConfig
from repro.core.edge_store import TS_PAD, EdgeStore
from repro.core.temporal_index import (
    TemporalIndex,
    build_index,
    node_range,
    temporal_cutoff,
)
from repro.core.samplers import (
    pick_in_neighborhood,
    pick_in_neighborhood_lanes,
)
from repro.core.walk_engine import NODE_PAD


class ShardedWalkState(NamedTuple):
    walk_id: jax.Array    # int32[D, Wd]  (-1 = empty slot)
    cur_node: jax.Array   # int32[D, Wd]
    cur_time: jax.Array   # int32[D, Wd]
    alive: jax.Array      # bool[D, Wd]
    trace_n: jax.Array    # int32[D, Wd, L+1]
    trace_t: jax.Array    # int32[D, Wd, L+1]
    length: jax.Array     # int32[D, Wd]
    dropped: jax.Array    # int32[D] bucket-overflow counter


def partition_edges(src, dst, ts, num_nodes: int, num_shards: int,
                    edge_capacity_per_shard: int, placement=None):
    """Host-side: partition edges by source-node owner (``placement``,
    default range policy); build one TemporalIndex per shard, stacked on a
    leading device axis. Returns (stacked index, placement)."""
    if placement is None:
        from repro.distributed.placement import RangePlacement
        placement = RangePlacement(num_shards=num_shards,
                                   node_capacity=num_nodes)
    owners = placement.owner_np(np.asarray(src))
    stores = []
    for d in range(num_shards):
        sel = owners == d
        from repro.core.edge_store import store_from_arrays
        stores.append(store_from_arrays(
            np.asarray(src)[sel], np.asarray(dst)[sel], np.asarray(ts)[sel],
            edge_capacity=edge_capacity_per_shard,
            node_capacity=num_nodes))
    indexes = [build_index(s, num_nodes) for s in stores]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *indexes)
    return stacked, placement


def init_sharded_walks(num_shards: int, walks_per_shard: int,
                       max_length: int, start_nodes, start_times,
                       placement) -> ShardedWalkState:
    """Place walks on their start node's owner (host-side)."""
    D, Wd, L = num_shards, walks_per_shard, max_length
    wid = np.full((D, Wd), -1, np.int32)
    node = np.zeros((D, Wd), np.int32)
    tme = np.zeros((D, Wd), np.int32)
    alive = np.zeros((D, Wd), bool)
    tn = np.full((D, Wd, L + 1), NODE_PAD, np.int32)
    tt = np.full((D, Wd, L + 1), NODE_PAD, np.int32)
    ln = np.zeros((D, Wd), np.int32)
    fill = np.zeros(D, np.int32)
    start_owner = placement.owner_np(np.asarray(start_nodes))
    for i, (v, t) in enumerate(zip(np.asarray(start_nodes),
                                   np.asarray(start_times))):
        d = int(start_owner[i])
        s = fill[d]
        if s >= Wd:
            raise ValueError(f"shard {d} start overflow")
        wid[d, s] = i
        node[d, s] = v
        tme[d, s] = t
        alive[d, s] = True
        tn[d, s, 0] = v
        tt[d, s, 0] = t
        ln[d, s] = 1
        fill[d] += 1
    return ShardedWalkState(
        walk_id=jnp.asarray(wid), cur_node=jnp.asarray(node),
        cur_time=jnp.asarray(tme), alive=jnp.asarray(alive),
        trace_n=jnp.asarray(tn), trace_t=jnp.asarray(tt),
        length=jnp.asarray(ln), dropped=jnp.zeros((D,), jnp.int32))


def owner_range_size(num_nodes: int, num_shards: int) -> int:
    """Node-range width per shard: owner(v) = v // owner_range_size(...)."""
    return math.ceil(num_nodes / num_shards)


def hop_resident(idx: TemporalIndex, scfg: SamplerConfig, node, time, alive,
                 u):
    """One local hop for resident rows given per-row uniforms.

    The pure sampling half of a migration step, shared by the static walker
    (legacy per-(walk, step) fold_in keying) and the distributed streaming
    engine (engine keying, DESIGN.md §12): Γ_t(v) lives entirely on v's
    owner, so (cutoff, pick, gather) are all shard-local. Returns
    (next_node, next_time, has_next); rows without a next hop keep their
    (node, time).
    """
    a, b = node_range(idx, node)
    c = temporal_cutoff(idx, a, b, time)
    n = b - c
    has = alive & (n > 0)
    k = pick_in_neighborhood(idx, scfg, c, b, u, node)
    k = jnp.clip(k, 0, idx.edge_capacity - 1)
    return (jnp.where(has, idx.ns_dst[k], node),
            jnp.where(has, idx.ns_ts[k], time), has)


def hop_resident_lanes(idx: TemporalIndex, code, node, time, alive, u):
    """``hop_resident`` with a per-row bias *code* instead of a config bias.

    The migrating half of sharded lane serving (DESIGN.md §13): each
    resident row is one coalesced-query lane, whose bias dispatches
    branchlessly over the three closed-form inverse CDFs
    (``samplers.index_pick_lanes``) exactly as in the single-device lane
    engine — so the pick is a pure function of (code, u, |Γ_t(v)|) and the
    migrated walk stays bit-identical to its solo single-device run.
    """
    a, b = node_range(idx, node)
    c = temporal_cutoff(idx, a, b, time)
    has = alive & (b - c > 0)
    k = pick_in_neighborhood_lanes(idx, code, c, b, u)
    k = jnp.clip(k, 0, idx.edge_capacity - 1)
    return (jnp.where(has, idx.ns_dst[k], node),
            jnp.where(has, idx.ns_ts[k], time), has)


def exchange_by_owner(axis: str, num_shards: int, capacity: int,
                      owner, valid, payloads, fills):
    """Bucket rows by destination shard and move them with one all_to_all.

    ``owner``/``valid`` are [n] (destination shard id / live-row mask);
    ``payloads`` is a tuple of [n, ...] arrays and ``fills`` their padding
    values. Each destination bucket holds ``capacity`` rows; a valid row
    ranked past capacity in its bucket is **not sent** (static shapes make
    overflow a provisioning event, exactly like the paper's walk-array
    capacity) and counted in the returned scalar. Returns
    (received leaves [num_shards * capacity, ...], fits, n_dropped) —
    ``fits`` marks the rows that were actually sent, so callers can keep
    or retire the overflow locally.

    Rank within a bucket preserves row order, so receivers see each
    sender's rows contiguously in sender-position order — the property the
    sharded window ingest (DESIGN.md §12) relies on for stable timestamp
    tie-breaking.
    """
    n = owner.shape[0]
    owner = jnp.where(valid, owner, num_shards)
    # rank within destination bucket: stable sort by owner (distinct keys)
    sort_key = owner * n + jnp.arange(n, dtype=jnp.int32)
    order = jnp.argsort(sort_key).astype(jnp.int32)
    owner_sorted = owner[order]
    first = jnp.searchsorted(owner_sorted, owner_sorted,
                             side="left").astype(jnp.int32)
    rank_sorted = jnp.arange(n, dtype=jnp.int32) - first
    rank = jnp.zeros((n,), jnp.int32).at[order].set(rank_sorted)
    fits = (rank < capacity) & valid
    n_drop = jnp.sum(valid & ~fits)

    o = jnp.where(fits, owner, num_shards - 1)
    r = jnp.where(fits, rank, capacity)

    def move(payload, fillv):
        buf = jnp.full((num_shards, capacity) + payload.shape[1:], fillv,
                       payload.dtype)
        buf = buf.at[o, r].set(payload, mode="drop")
        res = jax.lax.all_to_all(buf, axis, split_axis=0, concat_axis=0,
                                 tiled=True)
        return res.reshape((num_shards * capacity,) + payload.shape[1:])

    received = tuple(move(p, f) for p, f in zip(payloads, fills))
    return received, fits, n_drop


def make_distributed_walker(mesh: Mesh, axis: str, index_stacked,
                            scfg: SamplerConfig, *, placement,
                            max_length: int, bucket_capacity: int):
    """Returns a jitted function advancing all walks ``max_length`` steps."""
    D = mesh.devices.size

    def local_hop(idx: TemporalIndex, node, time, alive, wid, step):
        # per-(walk, step) RNG: placement-independent
        base = jax.random.PRNGKey(0)
        sk = jax.vmap(lambda w: jax.random.fold_in(
            jax.random.fold_in(base, step), w))(wid)
        u = jax.vmap(lambda k: jax.random.uniform(k, ()))(sk)
        return hop_resident(idx, scfg, node, time, alive, u)

    def step_fn(idx, state_leaf_tuple, step):
        (wid, node, time, alive, tn, tt, ln, dropped) = state_leaf_tuple
        Wd = wid.shape[0]
        nn, nt, has = local_hop(idx, node, time, alive, wid, step)
        # record hop locally before migration
        tn = jnp.where(has[:, None] & (jnp.arange(tn.shape[1]) == ln[:, None]),
                       nn[:, None], tn)
        tt = jnp.where(has[:, None] & (jnp.arange(tt.shape[1]) == ln[:, None]),
                       nt[:, None], tt)
        ln = ln + has.astype(jnp.int32)
        occupied = wid >= 0
        alive = has

        # dead-but-occupied walks stay put (their trace lives here); only
        # ALIVE walks migrate to their destination's owner.
        owner = placement.owner(nn)
        ((r_wid, r_node, r_time, r_tn, r_tt, r_ln), fits,
         n_drop) = exchange_by_owner(
            axis, D, bucket_capacity, owner, alive & occupied,
            (wid, nn, nt, tn, tt, ln),
            (-1, 0, 0, NODE_PAD, NODE_PAD, 0))

        # keep: dead walks stay resident (their trace is gathered here);
        # bucket-overflow walks also stay but STOP (counted as dropped).
        keep = occupied & (~alive | ~fits)
        wid = jnp.where(keep, wid, -1)
        alive_keep = jnp.zeros_like(alive)
        # compact: place received walks into free slots
        free = wid < 0
        free_rank = jnp.cumsum(free.astype(jnp.int32)) - 1
        slot_of_free_rank = jnp.full((Wd,), Wd, jnp.int32).at[
            jnp.where(free, free_rank, Wd)].set(jnp.arange(Wd, dtype=jnp.int32),
                                                mode="drop")
        inc_valid = r_wid >= 0
        inc_rank = jnp.cumsum(inc_valid.astype(jnp.int32)) - 1
        dest = jnp.where(inc_valid,
                         slot_of_free_rank[jnp.clip(inc_rank, 0, Wd - 1)],
                         Wd)
        recv_drop = jnp.sum(inc_valid & (dest >= Wd))

        def place(cur, payload):
            return cur.at[dest].set(payload, mode="drop")

        wid = place(wid, r_wid)
        node = place(jnp.where(keep, node, 0), r_node)
        time = place(jnp.where(keep, time, 0), r_time)
        tn = place(jnp.where(keep[:, None], tn, NODE_PAD), r_tn)
        tt = place(jnp.where(keep[:, None], tt, NODE_PAD), r_tt)
        ln = place(jnp.where(keep, ln, 0), r_ln)
        alive = place(alive_keep, inc_valid)
        dropped = dropped + n_drop + recv_drop
        return (wid, node, time, alive, tn, tt, ln, dropped)

    def walker(index_st, state: ShardedWalkState):
        # strip the size-1 sharded leading axis shard_map leaves in place
        idx_local = jax.tree.map(lambda a: a[0], index_st)
        leaves = tuple(l[0] for l in
                       (state.walk_id, state.cur_node, state.cur_time,
                        state.alive, state.trace_n, state.trace_t,
                        state.length))
        leaves = leaves + (state.dropped[0],)

        def body(carry, step):
            return step_fn(idx_local, carry, step), None

        out, _ = jax.lax.scan(body, leaves,
                              jnp.arange(max_length, dtype=jnp.int32))
        return ShardedWalkState(*(o[None] for o in out))

    pspec_idx = jax.tree.map(lambda _: P(axis), index_stacked)
    pspec_state = ShardedWalkState(
        walk_id=P(axis), cur_node=P(axis), cur_time=P(axis), alive=P(axis),
        trace_n=P(axis), trace_t=P(axis), length=P(axis), dropped=P(axis))

    fn = shard_map(walker, mesh=mesh,
                   in_specs=(pspec_idx, pspec_state),
                   out_specs=pspec_state, check_rep=False)

    def run(state: ShardedWalkState) -> ShardedWalkState:
        return jax.jit(fn)(index_stacked, state)

    return run


def gather_walks(state: ShardedWalkState, num_walks: int):
    """Assemble (nodes, times, lengths) in walk-id order (host-side)."""
    wid = np.asarray(state.walk_id).reshape(-1)
    tn = np.asarray(state.trace_n).reshape(-1, state.trace_n.shape[-1])
    tt = np.asarray(state.trace_t).reshape(-1, state.trace_t.shape[-1])
    ln = np.asarray(state.length).reshape(-1)
    L1 = tn.shape[-1]
    nodes = np.full((num_walks, L1), NODE_PAD, np.int32)
    times = np.full((num_walks, L1), NODE_PAD, np.int32)
    lengths = np.zeros((num_walks,), np.int32)
    for i, w in enumerate(wid):
        if w >= 0:
            nodes[w] = tn[i]
            times[w] = tt[i]
            lengths[w] = ln[i]
    return nodes, times, lengths
