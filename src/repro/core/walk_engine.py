"""Temporal random-walk engine (paper §2.4).

Execution paths (the TPU mapping of the paper's dispatch plane):

* ``fullwalk`` — the paper's §2.4.1 baseline: every walk advances
  independently; per-hop gathers and binary searches are issued per walk in
  whatever order walks happen to sit in memory.

* ``grouped`` — the hierarchical-cooperative-scheduling adaptation (§2.4.3):
  each hop, walks are regrouped by (current node, current time); identical
  (node, time) pairs form *segments* whose temporal cutoff is computed once
  at the segment head and broadcast to members, and whose gathers touch
  contiguous index regions (the TPU analog of coalesced, smem-amortized
  access). Only the random draw and the picked edge differ per walk —
  exactly the paper's observation.

* ``tiled`` — the grouped path with the hop search+sample executed by the
  Pallas kernel (kernels/walk_step.py), which stages each task's edge slice
  in VMEM (the smem-panel analog). Selected via SchedulerConfig.path.

* ``fused`` — the grouped path with the whole hop (prefix-weight lookup,
  branchless per-lane inverse-CDF draw, and the dst/ts gather) executed by
  the fused convergence-tiered kernel (kernels/fused_step.py, DESIGN.md
  §14): small-degree lanes resolve in one staged tile pass, oversize lanes
  sweep the edge window in-kernel — no jnp fallback. Because the bias
  dispatches by int32 code per lane, ``fused`` also serves heterogeneous
  ``LaneParams`` batches (unlike ``tiled``, which compiles one bias).

The per-hop regrouping itself comes in two flavors
(``SchedulerConfig.regroup``, DESIGN.md §10): ``bucket`` (default) is an
O(W) counting regroup (core/scheduler.py::bucket_regroup) whose permutation
is **carried across hops** in the walk state — lanes stay in grouped order
and only the lane→walk map is tracked, so neither a fresh O(W log W) sort
nor a scatter-built inverse permutation is paid per hop. ``lexsort`` keeps
the seed's per-hop ``jnp.lexsort`` + inverse scatter as the
equivalence/benchmark reference.

All paths and regroup modes produce **identical walks for identical keys**
(tested): random draws are generated in original walk order and indexed
through the lane→walk map, so grouping is purely an execution-layout
decision — the paper makes the same claim for its tiers.

Steady-state callers reuse the output buffers via
``generate_walks_donated`` (walk arrays donated back into the jit,
DESIGN.md §10), and ``repro.distributed.walks.generate_walks_sharded``
shards the walk axis across devices (walks are embarrassingly parallel;
the index is replicated). When the window itself no longer fits one
device, ``repro.distributed.streaming_shard`` shards the window and
migrates walks between owners instead (DESIGN.md §12).

**Per-lane sampler parameters** (``LaneParams`` / ``generate_walk_lanes``,
DESIGN.md §11): the serving coalescer packs many heterogeneous queries
into one fixed-shape batch, so bias, max length, and RNG seed become
per-lane *arrays* instead of compile-time config. Bias dispatches
branchlessly over the three closed-form inverse CDFs
(samplers.index_pick_lanes), per-lane max length masks ``has_next`` once a
lane's own budget is spent, and every lane draws from an RNG stream folded
by (request seed, walk-within-request, step) — independent of batch shape
and of which other lanes are present, which makes a coalesced batch
bit-identical to running each query solo. The same lane batches run over
the node-partitioned window via
``repro.distributed.streaming_shard.serve_lanes_sharded`` (DESIGN.md §13),
with the identical bit-identity guarantee.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SamplerConfig, SchedulerConfig, WalkConfig
from repro.core import scheduler as sched
from repro.core.alias import AliasTables, alias_pick
from repro.core.samplers import (
    BIAS_CODES,
    BIAS_TABLE,
    node2vec_beta,
    node2vec_beta_lanes,
    node2vec_max_beta,
    node2vec_max_beta_lanes,
    pick_in_neighborhood,
    pick_in_neighborhood_lanes,
    pick_start_edges,
    pick_start_edges_lanes,
)
from repro.core.temporal_index import (
    TemporalIndex,
    node_range,
    temporal_cutoff,
)

NODE_PAD = -1          # sentinel in emitted walks beyond walk length
N2V_ROUNDS = 8         # rejection-sampling rounds per hop (vectorized)
# Second-order lanes draw their rejection uniforms from dedicated RNG tags
# N2V_TAG_BASE + step·(2·N2V_ROUNDS) + 2r + j, far above any per-step tag
# (tag s+1 for scan step s) a first-order lane ever uses — so enabling
# second-order lanes leaves every existing draw stream bit-identical.
N2V_TAG_BASE = 1 << 20


# ---------------------------------------------------------------------------
# Capability chokepoint: every bias/path/lane refusal goes through here
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LaneFeatures:
    """Static summary of what a coalesced lane batch needs from the engine.

    ``table``: the batch may carry lanes with bias code "table" (alias
    tables are threaded into the dispatch). ``second_order``: the batch
    carries per-lane node2vec (p, q) arrays with at least one lane ≠ 1.
    Both are compile-time facts (the service derives them from the query
    set), so refusals stay trace-time errors.
    """

    table: bool = False
    second_order: bool = False


_CAP = "unsupported sampler capability: "


def check_capabilities(scfg: SamplerConfig, path: str,
                       lanes: Optional[LaneFeatures] = None, *,
                       sharded: bool = False,
                       have_tables: bool = False) -> None:
    """Validate a (sampler config, path, lane features) combination.

    The single chokepoint behind every refusal the engine, the serving
    layer, and the sharded streaming walker used to issue separately —
    one place to read what runs where, one set of error messages, and
    one matrix for tests to sweep (tests/test_capabilities.py). Raises
    ``ValueError``; returns ``None`` when the combination is supported.
    """
    if scfg.bias not in BIAS_CODES:
        raise ValueError(
            _CAP + f"unknown bias {scfg.bias!r} "
            f"(expected one of {sorted(BIAS_CODES)})")
    if scfg.start_bias == "table" or scfg.start_bias not in BIAS_CODES:
        raise ValueError(
            _CAP + f"start-edge bias {scfg.start_bias!r} is not supported; "
            "start draws use the closed forms 'uniform'|'linear'|"
            "'exponential' (alias tables cover neighborhood regions, not "
            "the timestamp view)")
    use_n2v = scfg.node2vec_p != 1.0 or scfg.node2vec_q != 1.0

    if scfg.bias == "table":
        if scfg.mode != "index":
            raise ValueError(
                _CAP + "bias='table' requires SamplerConfig.mode='index' "
                f"(the alias draw replaces the mode dispatch; got "
                f"mode={scfg.mode!r})")
        if sharded:
            raise ValueError(
                _CAP + "sharded streaming walks do not support bias="
                "'table' (per-shard alias tables cover resident regions "
                "only; a migrating walk's draw would need its owner's "
                "table)")
        if not have_tables:
            raise ValueError(
                _CAP + "bias='table' requires alias tables: build the "
                "window with a TableSpec (init_window(..., table=spec) / "
                "ingest(..., table=spec)) and pass state.tables into the "
                "walk entry point")
        if path in ("tiled", "fused"):
            raise ValueError(
                _CAP + f"path={path!r} does not support bias='table' (the "
                "Pallas kernels dispatch the closed-form inverse CDFs "
                "only); use 'fullwalk'|'grouped'")

    if use_n2v:
        if sharded:
            raise ValueError(
                _CAP + "sharded streaming walks do not support node2vec "
                "second-order bias (the β probe needs the previous node's "
                "adjacency, which lives on a different shard)")
        if lanes is not None:
            raise ValueError(
                _CAP + "per-lane batches do not support config-level "
                "node2vec second-order bias; second-order lanes carry "
                "their own (n2v_p, n2v_q) arrays (set node2vec_p="
                "node2vec_q=1.0)")
        if path == "fused":
            raise ValueError(
                _CAP + "path='fused' does not support node2vec "
                "second-order bias (the rejection loop re-draws outside "
                "the kernel); use 'fullwalk'|'grouped'")
        if path == "tiled":
            raise ValueError(
                _CAP + "path='tiled' does not support node2vec "
                "second-order bias (the walk-step kernel draws first-"
                "order only); use 'fullwalk'|'grouped'")

    if lanes is not None:
        if scfg.mode != "index":
            raise ValueError(
                _CAP + "per-lane batches require SamplerConfig.mode="
                "'index': the per-lane dispatch selects over the closed-"
                f"form inverse CDFs (got mode={scfg.mode!r})")
        if path == "tiled":
            raise ValueError(
                _CAP + "per-lane batches support paths 'fullwalk'|"
                "'grouped'|'fused'; the tiled Pallas kernel compiles a "
                "single bias per dispatch (the fused kernel dispatches "
                "per-lane bias codes)")
        if lanes.table:
            if sharded:
                raise ValueError(
                    _CAP + "sharded lane serving does not support bias "
                    "code 'table' (per-shard alias tables cover resident "
                    "regions only; a migrating lane's draw would need its "
                    "owner's table)")
            if not have_tables:
                raise ValueError(
                    _CAP + "lane bias code 'table' requires alias tables: "
                    "ingest with a TableSpec and pass state.tables into "
                    "generate_walk_lanes")
            if path == "fused":
                raise ValueError(
                    _CAP + "path='fused' does not serve lane bias code "
                    "'table' (the fused kernel dispatches the closed-form "
                    "codes only); use 'fullwalk'|'grouped'")
        if lanes.second_order:
            if sharded:
                raise ValueError(
                    _CAP + "sharded lane serving does not support "
                    "node2vec second-order lanes (the β probe needs the "
                    "previous node's adjacency, which lives on a "
                    "different shard)")
            if path == "fused":
                raise ValueError(
                    _CAP + "path='fused' does not support node2vec "
                    "second-order lanes (the rejection loop re-draws "
                    "outside the kernel); use 'fullwalk'|'grouped'")


class WalkResult(NamedTuple):
    nodes: jax.Array     # int32[W, L+1], NODE_PAD beyond length
    times: jax.Array     # int32[W, L+1]
    lengths: jax.Array   # int32[W] number of nodes recorded (>=1)
    stats: Optional[jax.Array]   # float32[L, sched.NUM_STATS] or None


class WalkBuffers(NamedTuple):
    """Reusable walk output buffers (donated through the jit boundary).

    Holds the two O(W·L) arrays of a WalkResult. The walk loop overwrites
    *every* cell (the start writes column 0, and each hop writes its column
    for all W lanes, PAD for non-advancing walks), so the previous round's
    contents are dead on entry: the donated storage flows straight into the
    scan carry and XLA updates it in place — steady-state walk generation
    allocates only the [W] lengths vector (DESIGN.md §10).
    """

    nodes: jax.Array     # int32[W, L+1]
    times: jax.Array     # int32[W, L+1]


def alloc_walk_buffers(wcfg: WalkConfig) -> WalkBuffers:
    """Allocate walk buffers for ``generate_walks_donated`` round-trips."""
    W, L = wcfg.num_walks, wcfg.max_length
    return WalkBuffers(
        nodes=jnp.full((W, L + 1), NODE_PAD, jnp.int32),
        times=jnp.full((W, L + 1), NODE_PAD, jnp.int32),
    )


class LaneParams(NamedTuple):
    """Per-lane sampler parameters for a coalesced walk batch (DESIGN.md §11).

    All arrays are [W] in walk order. ``rid``/``wid`` drive the per-lane
    RNG: lane draws come from ``fold_in(fold_in(fold_in(base, rid), wid),
    tag)`` with tag 0 for the start draw and tag s+1 for scan step s — a
    pure function of (request seed, walk-within-request, step). A lane's
    stream therefore does not depend on the batch shape or on which other
    lanes share the batch: the bit-identity guarantee the serving
    coalescer relies on.
    """

    start_node: jax.Array   # int32[W] start node (start_mode="nodes")
    bias: jax.Array         # int32[W] hop-bias code (samplers.BIAS_CODES)
    start_bias: jax.Array   # int32[W] start-edge bias code (start_mode="edges")
    max_len: jax.Array      # int32[W] per-lane hop budget (edges emitted <= max_len)
    rid: jax.Array          # int32[W] request seed folded into the RNG
    wid: jax.Array          # int32[W] walk index within the request
    active: jax.Array       # bool[W] real lane vs bucket padding
    # second-order node2vec lane parameters (DESIGN.md §17): float32[W],
    # 1.0 disables the second-order bias for that lane. None (the default,
    # an empty pytree subtree) on batches packed before this field existed
    # — equivalent to all-ones. Only read when the entry point is called
    # with second_order=True.
    n2v_p: Optional[jax.Array] = None
    n2v_q: Optional[jax.Array] = None


def _lane_keys(key: jax.Array, lanes: LaneParams) -> jax.Array:
    """Per-lane PRNG keys: base key folded by request seed then walk id."""
    ks = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(key, lanes.rid)
    return jax.vmap(jax.random.fold_in)(ks, lanes.wid)


def _lane_uniform(lane_keys: jax.Array, tag) -> jax.Array:
    """One U[0,1) draw per lane from the step-``tag`` substream."""
    ks = jax.vmap(jax.random.fold_in, in_axes=(0, None))(lane_keys, tag)
    return jax.vmap(lambda k: jax.random.uniform(k, ()))(ks)


class _Carry(NamedTuple):
    # cur_node/cur_time/prev_node/alive are in *lane* order; ``lane`` maps
    # lane -> original walk id (identity for fullwalk/lexsort, the carried
    # bucket-regroup permutation otherwise). nodes/times/lengths stay in
    # walk order throughout.
    cur_node: jax.Array
    cur_time: jax.Array
    prev_node: jax.Array
    alive: jax.Array
    lane: jax.Array
    nodes: jax.Array
    times: jax.Array
    lengths: jax.Array


# ---------------------------------------------------------------------------
# Walk starts
# ---------------------------------------------------------------------------


def start_walks(index: TemporalIndex, wcfg: WalkConfig, scfg: SamplerConfig,
                key: jax.Array, walk_offset=0,
                buffers: Optional[WalkBuffers] = None,
                lanes: Optional[LaneParams] = None,
                lane_keys: Optional[jax.Array] = None) -> _Carry:
    W = wcfg.num_walks
    L = wcfg.max_length
    if buffers is None:
        nodes = jnp.full((W, L + 1), NODE_PAD, jnp.int32)
        times = jnp.full((W, L + 1), NODE_PAD, jnp.int32)
    else:
        # every cell is overwritten before the result is read (see
        # WalkBuffers), so the stale contents pass through untouched and
        # the donated storage is updated in place
        nodes = buffers.nodes
        times = buffers.times
    lane = jnp.arange(W, dtype=jnp.int32)

    t_floor = jnp.where(index.num_edges > 0, index.store.ts[0] - 1, 0)

    if lanes is not None:
        # Per-lane starts (DESIGN.md §11). Padding lanes (active=False)
        # stay dead: all-PAD rows with length 0.
        nc = index.node_capacity
        if wcfg.start_mode == "nodes":
            # explicit per-lane start nodes; mirrors all_nodes aliveness
            # (a start node with no in-window edges yields an empty walk)
            cur = jnp.clip(lanes.start_node, 0, nc - 1)
            deg = index.node_starts[cur + 1] - index.node_starts[cur]
            alive = (lanes.active & (deg > 0) & (lanes.start_node >= 0)
                     & (lanes.start_node < nc))
            cur_time = jnp.full((W,), 1, jnp.int32) * t_floor
            nodes = nodes.at[:, 0].set(jnp.where(alive, cur, NODE_PAD))
            times = times.at[:, 0].set(jnp.where(alive, cur_time, NODE_PAD))
            return _Carry(cur_node=cur, cur_time=cur_time,
                          prev_node=jnp.full((W,), -1, jnp.int32),
                          alive=alive, lane=lane, nodes=nodes, times=times,
                          lengths=alive.astype(jnp.int32))
        if wcfg.start_mode == "edges":
            # per-lane biased start-edge selection over the timestamp view
            u = _lane_uniform(lane_keys, 0)
            e = pick_start_edges_lanes(index, lanes.start_bias, u)
            e = jnp.clip(e, 0, index.edge_capacity - 1)
            src = index.store.src[e]
            cur = index.store.dst[e]
            cur_time = index.store.ts[e]
            alive = lanes.active & (index.num_edges > 0)
            nodes = nodes.at[:, 0].set(jnp.where(alive, src, NODE_PAD))
            times = times.at[:, 0].set(jnp.where(alive, cur_time, NODE_PAD))
            nodes = nodes.at[:, 1].set(jnp.where(alive, cur, NODE_PAD))
            times = times.at[:, 1].set(jnp.where(alive, cur_time, NODE_PAD))
            return _Carry(cur_node=cur, cur_time=cur_time, prev_node=src,
                          alive=alive, lane=lane, nodes=nodes, times=times,
                          lengths=jnp.where(alive, 2, 0).astype(jnp.int32))
        raise ValueError(
            f"lane batches support start_mode 'nodes'|'edges', "
            f"got {wcfg.start_mode!r}")

    if wcfg.start_mode == "all_nodes":
        # paper §3.3: k walks from every active source node; walk_offset
        # shifts the assignment for sharded generation (walk w on shard s
        # starts where global walk s·Wd + w would)
        nc = index.node_capacity
        cur = ((walk_offset + jnp.arange(W, dtype=jnp.int32)) % nc).astype(
            jnp.int32)
        deg = index.node_starts[cur + 1] - index.node_starts[cur]
        alive = deg > 0
        cur_time = jnp.full((W,), 1, jnp.int32) * t_floor
    elif wcfg.start_mode == "nodes":
        # uniform over active nodes via cumulative-count inversion
        nc = index.node_capacity
        deg = index.node_starts[1:nc + 1] - index.node_starts[:nc]
        active = (deg > 0).astype(jnp.int32)
        cum = jnp.cumsum(active)
        num_active = cum[-1]
        u = jax.random.uniform(key, (W,))
        j = jnp.floor(u * num_active.astype(jnp.float32)).astype(jnp.int32)
        j = jnp.clip(j, 0, jnp.maximum(num_active - 1, 0))
        cur = jnp.searchsorted(cum, j, side="right").astype(jnp.int32)
        alive = jnp.broadcast_to(num_active > 0, (W,))
        cur_time = jnp.full((W,), 1, jnp.int32) * t_floor
    elif wcfg.start_mode == "edges":
        # start-edge selection over the timestamp-grouped view (paper §2.3)
        u = jax.random.uniform(key, (W,))
        e = pick_start_edges(index, scfg, u)
        e = jnp.clip(e, 0, index.edge_capacity - 1)
        src = index.store.src[e]
        cur = index.store.dst[e]
        cur_time = index.store.ts[e]
        alive = jnp.broadcast_to(index.num_edges > 0, (W,))
        nodes = nodes.at[:, 0].set(jnp.where(alive, src, NODE_PAD))
        times = times.at[:, 0].set(jnp.where(alive, cur_time, NODE_PAD))
        nodes = nodes.at[:, 1].set(jnp.where(alive, cur, NODE_PAD))
        times = times.at[:, 1].set(jnp.where(alive, cur_time, NODE_PAD))
        return _Carry(cur_node=cur, cur_time=cur_time, prev_node=src,
                      alive=alive, lane=lane, nodes=nodes, times=times,
                      lengths=jnp.where(alive, 2, 0).astype(jnp.int32))
    else:
        raise ValueError(f"unknown start_mode {wcfg.start_mode!r}")

    nodes = nodes.at[:, 0].set(jnp.where(alive, cur, NODE_PAD))
    times = times.at[:, 0].set(jnp.where(alive, cur_time, NODE_PAD))
    return _Carry(cur_node=cur, cur_time=cur_time,
                  prev_node=jnp.full((W,), -1, jnp.int32),
                  alive=alive, lane=lane, nodes=nodes, times=times,
                  lengths=alive.astype(jnp.int32))


# ---------------------------------------------------------------------------
# One hop, full-walk layout
# ---------------------------------------------------------------------------


def _pick_config(index, scfg, tables, a, c, b, u, node):
    """First-order pick under the *config* bias (non-lane paths)."""
    if scfg.bias == "table":
        return alias_pick(tables, a, c, b, u, radix=scfg.table_radix,
                          degree_cap=scfg.table_degree_cap)
    return pick_in_neighborhood(index, scfg, c, b, u, node)


def _pick_lane_codes(index, scfg, tables, code, a, c, b, u):
    """First-order pick under per-lane bias codes.

    The closed forms dispatch branchlessly as before; when alias tables
    are threaded in, lanes coded BIAS_TABLE overlay the alias draw —
    still elementwise in (code, u, region), preserving the coalesced↔solo
    bit-identity guarantee.
    """
    k = pick_in_neighborhood_lanes(index, code, c, b, u)
    if tables is not None:
        k_tab = alias_pick(tables, a, c, b, u, radix=scfg.table_radix,
                           degree_cap=scfg.table_degree_cap)
        k = jnp.where(code == BIAS_TABLE, k_tab, k)
    return k


def _lane_second_order(index, scfg, tables, lane_bias, a, c, b, prev,
                       k_plain, n2v):
    """Per-lane node2vec rejection over the first-order proposal stream.

    ``n2v = (p, q, us2)`` with us2[N2V_ROUNDS, 2, W] from the dedicated
    N2V_TAG_BASE substreams, all in the caller's lane layout. Lanes with
    p == q == 1 keep ``k_plain`` (the ordinary first-order draw), so a
    mixed batch is bit-identical to running each lane solo either way.
    """
    p, q, us2 = n2v
    beta_max = node2vec_max_beta_lanes(p, q)

    def round_(carry_, uv):
        k_acc, accepted = carry_
        u_r, v_r = uv[0], uv[1]
        k_r = _pick_lane_codes(index, scfg, tables, lane_bias, a, c, b, u_r)
        cand = index.ns_dst[jnp.clip(k_r, 0, index.edge_capacity - 1)]
        beta = node2vec_beta_lanes(index, prev, cand, p, q)
        # hops with no previous node accept unconditionally
        ok = (v_r * beta_max <= beta) | (prev < 0)
        take = ok & ~accepted
        return (jnp.where(take, k_r, k_acc), accepted | ok), None

    k0 = _pick_lane_codes(index, scfg, tables, lane_bias, a, c, b,
                          us2[0, 0])
    W = k0.shape[0]
    (k_rej, _), _ = jax.lax.scan(round_, (k0, jnp.zeros((W,), bool)), us2)
    is_n2v = (p != 1.0) | (q != 1.0)
    return jnp.where(is_n2v, k_rej, k_plain)


def _sample_hop(index: TemporalIndex, scfg: SamplerConfig,
                cur_node, cur_time, prev_node, alive, hop_key,
                lane_bias=None, lane_u=None, tables=None, lane_n2v=None):
    """Given per-walk (node, time), returns (next_node, next_time, has_next).

    Pure sampling logic shared by every path; callers control the layout.
    With ``lane_bias``/``lane_u`` (walk-order arrays, DESIGN.md §11) the
    draw is the caller-supplied per-lane uniform and the bias dispatches
    per lane; ``tables`` threads the alias tables for table-coded lanes
    (or config bias='table'); ``lane_n2v`` carries per-lane second-order
    parameters (see ``_lane_second_order``).
    """
    W = cur_node.shape[0]
    a, b = node_range(index, cur_node)
    c = temporal_cutoff(index, a, b, cur_time)
    n = b - c
    has_next = alive & (n > 0)

    use_n2v = (scfg.node2vec_p != 1.0) or (scfg.node2vec_q != 1.0)
    if lane_u is not None:
        k = _pick_lane_codes(index, scfg, tables, lane_bias, a, c, b,
                             lane_u)
        if lane_n2v is not None:
            k = _lane_second_order(index, scfg, tables, lane_bias, a, c, b,
                                   prev_node, k, lane_n2v)
    elif not use_n2v:
        u = jax.random.uniform(hop_key, (W,))
        k = _pick_config(index, scfg, tables, a, c, b, u, cur_node)
    else:
        # rejection sampling on the first-order proposal (paper §2.5)
        beta_max = node2vec_max_beta(scfg.node2vec_p, scfg.node2vec_q)
        us = jax.random.uniform(hop_key, (N2V_ROUNDS, 2, W))

        def round_(carry, uv):
            k_acc, accepted = carry
            u_r, v_r = uv[0], uv[1]
            k_r = _pick_config(index, scfg, tables, a, c, b, u_r, cur_node)
            cand = index.ns_dst[jnp.clip(k_r, 0, index.edge_capacity - 1)]
            beta = node2vec_beta(index, prev_node, cand,
                                 scfg.node2vec_p, scfg.node2vec_q)
            # hops with no previous node accept unconditionally
            ok = (v_r * beta_max <= beta) | (prev_node < 0)
            take = ok & ~accepted
            return (jnp.where(take, k_r, k_acc), accepted | ok), None

        u0 = us[0, 0]
        k0 = _pick_config(index, scfg, tables, a, c, b, u0, cur_node)
        (k, _), _ = jax.lax.scan(round_, (k0, jnp.zeros((W,), bool)), us)

    k = jnp.clip(k, 0, index.edge_capacity - 1)
    next_node = index.ns_dst[k]
    next_time = index.ns_ts[k]
    return next_node, next_time, has_next, (a, b, c)


def _hop_fullwalk(index, scfg, carry: _Carry, step: jax.Array,
                  hop_key, lane_bias=None, lane_u=None,
                  lane_limit=None, tables=None, lane_n2v=None) -> _Carry:
    nn, nt, has_next, _ = _sample_hop(
        index, scfg, carry.cur_node, carry.cur_time, carry.prev_node,
        carry.alive, hop_key, lane_bias=lane_bias, lane_u=lane_u,
        tables=tables, lane_n2v=lane_n2v)
    if lane_limit is not None:
        has_next = has_next & lane_limit
    return _advance(carry, step, nn, nt, has_next)


# ---------------------------------------------------------------------------
# Grouped layouts: shared segment cutoff + draw/pick helpers
# ---------------------------------------------------------------------------


def _segment_cutoff(index: TemporalIndex, s_node, s_time):
    """(b, c) for lanes grouped by (node, time): Γ_t(v) = [c, b) per lane.

    Segment heads are re-derived from the materialized order — contiguous
    equal (node, time) runs share one cutoff — so *any* lane permutation is
    correct; better grouping only improves dedup and gather locality.
    """
    W = s_node.shape[0]
    p_node = jnp.concatenate([jnp.full((1,), -2, jnp.int32), s_node[:-1]])
    p_time = jnp.concatenate([jnp.full((1,), -2, jnp.int32), s_time[:-1]])
    head = (s_node != p_node) | (s_time != p_time)
    seg_id = jnp.cumsum(head.astype(jnp.int32)) - 1

    a, b = node_range(index, s_node)
    # cutoff computed once per segment head, broadcast to members.
    c_head = temporal_cutoff(index, a, b, s_time)
    c = jax.ops.segment_max(jnp.where(head, c_head, 0), seg_id,
                            num_segments=W)[seg_id]
    return b, c


def _bucket_prologue(index: TemporalIndex, sched_cfg, carry: _Carry):
    """Regroup lanes by current node (DESIGN.md §10) and permute the walk
    state; shared by the grouped and tiled bucket hops. Returns the
    composed lane→walk map plus the permuted per-lane state."""
    nc = index.node_capacity
    node_key = jnp.where(carry.alive, carry.cur_node, nc + 1)
    pp = sched.bucket_regroup(node_key, carry.cur_time, nc,
                              time_subsort=sched_cfg.regroup_time)
    return (carry.lane[pp], carry.cur_node[pp], carry.cur_time[pp],
            carry.prev_node[pp], carry.alive[pp])


def _draw_pick(index, scfg, hop_key, c, b, s_node, s_prev, order,
               lane_bias=None, lane_u=None, tables=None, lane_n2v=None):
    """Sample positions k ∈ [c, b) for grouped lanes.

    ``order`` maps lane -> original walk id; draws are generated in walk-id
    order and indexed through it, which is what makes every layout emit
    identical walks for identical keys. ``lane_bias``/``lane_u`` and the
    ``lane_n2v`` arrays are walk-order per-lane arrays (DESIGN.md §11),
    indexed through ``order`` the same way.
    """
    W = s_node.shape[0]
    use_n2v = (scfg.node2vec_p != 1.0) or (scfg.node2vec_q != 1.0)
    if tables is not None or lane_n2v is not None:
        a, _ = node_range(index, s_node)
    else:
        a = None
    if lane_u is not None:
        k = _pick_lane_codes(index, scfg, tables, lane_bias[order], a, c, b,
                             lane_u[order])
        if lane_n2v is not None:
            p, q, us2 = lane_n2v
            k = _lane_second_order(index, scfg, tables, lane_bias[order],
                                   a, c, b, s_prev, k,
                                   (p[order], q[order], us2[:, :, order]))
    elif not use_n2v:
        u = jax.random.uniform(hop_key, (W,))[order]
        k = _pick_config(index, scfg, tables, a, c, b, u, s_node)
    else:
        beta_max = node2vec_max_beta(scfg.node2vec_p, scfg.node2vec_q)
        us = jax.random.uniform(hop_key, (N2V_ROUNDS, 2, W))[:, :, order]

        def round_(carry_, uv):
            k_acc, accepted = carry_
            u_r, v_r = uv[0], uv[1]
            k_r = _pick_config(index, scfg, tables, a, c, b, u_r, s_node)
            cand = index.ns_dst[jnp.clip(k_r, 0, index.edge_capacity - 1)]
            beta = node2vec_beta(index, s_prev, cand,
                                 scfg.node2vec_p, scfg.node2vec_q)
            ok = (v_r * beta_max <= beta) | (s_prev < 0)
            take = ok & ~accepted
            return (jnp.where(take, k_r, k_acc), accepted | ok), None

        k0 = _pick_config(index, scfg, tables, a, c, b, us[0, 0], s_node)
        (k, _), _ = jax.lax.scan(round_, (k0, jnp.zeros((W,), bool)), us)

    return jnp.clip(k, 0, index.edge_capacity - 1)


def _hop_grouped(index, scfg, carry: _Carry, step: jax.Array,
                 hop_key, lane_bias=None, lane_u=None,
                 lane_limit=None, tables=None, lane_n2v=None) -> _Carry:
    """Reference regroup: fresh lexsort by (node, time) + inverse scatter."""
    W = carry.cur_node.shape[0]
    nc = index.node_capacity
    node_key = jnp.where(carry.alive, carry.cur_node, nc + 1)
    perm = jnp.lexsort((carry.cur_time, node_key)).astype(jnp.int32)

    s_node = carry.cur_node[perm]
    s_time = carry.cur_time[perm]
    s_prev = carry.prev_node[perm]
    s_alive = carry.alive[perm]

    b, c = _segment_cutoff(index, s_node, s_time)
    has_next_s = s_alive & (b - c > 0)
    if lane_limit is not None:
        has_next_s = has_next_s & lane_limit[perm]

    k = _draw_pick(index, scfg, hop_key, c, b, s_node, s_prev, perm,
                   lane_bias=lane_bias, lane_u=lane_u, tables=tables,
                   lane_n2v=lane_n2v)
    nn_s = index.ns_dst[k]
    nt_s = index.ns_ts[k]

    # unsort back to original walk order
    inv = jnp.zeros((W,), jnp.int32).at[perm].set(
        jnp.arange(W, dtype=jnp.int32))
    return _advance(carry, step, nn_s[inv], nt_s[inv], has_next_s[inv])


def _hop_grouped_bucket(index, scfg, sched_cfg, carry: _Carry,
                        step: jax.Array, hop_key, lane_bias=None,
                        lane_u=None, lane_limit=None, tables=None,
                        lane_n2v=None) -> _Carry:
    """O(W) counting regroup with carried permutation (DESIGN.md §10).

    Lanes stay in grouped order across hops — the regroup permutes the
    *previous* lane layout (walks keep near-sorted order naturally, since a
    segment's members scatter over one node's neighbor list) and composes
    into ``carry.lane``; no inverse permutation is ever built.
    """
    lane, s_node, s_time, s_prev, s_alive = _bucket_prologue(
        index, sched_cfg, carry)

    b, c = _segment_cutoff(index, s_node, s_time)
    has_next_s = s_alive & (b - c > 0)
    if lane_limit is not None:
        has_next_s = has_next_s & lane_limit[lane]

    k = _draw_pick(index, scfg, hop_key, c, b, s_node, s_prev, lane,
                   lane_bias=lane_bias, lane_u=lane_u, tables=tables,
                   lane_n2v=lane_n2v)
    return _advance_lanes(carry, lane, step, s_node, s_time, s_prev,
                          index.ns_dst[k], index.ns_ts[k], has_next_s)


def _hop_tiled(index, scfg, sched_cfg, carry: _Carry, step, hop_key) -> _Carry:
    """Lexsort layout with the Pallas kernel executing search+sample."""
    from repro.kernels import ops as kops
    W = carry.cur_node.shape[0]
    node_key = jnp.where(carry.alive, carry.cur_node, index.node_capacity + 1)
    perm = jnp.lexsort((carry.cur_time, node_key)).astype(jnp.int32)
    s_node = carry.cur_node[perm]
    s_time = carry.cur_time[perm]
    s_alive = carry.alive[perm]
    u = jax.random.uniform(hop_key, (W,))[perm]

    k, n = kops.walk_step(index, s_node, s_time, u, scfg, sched_cfg)
    has_next_s = s_alive & (n > 0)
    k = jnp.clip(k, 0, index.edge_capacity - 1)
    nn_s = index.ns_dst[k]
    nt_s = index.ns_ts[k]
    inv = jnp.zeros((W,), jnp.int32).at[perm].set(jnp.arange(W, dtype=jnp.int32))
    return _advance(carry, step, nn_s[inv], nt_s[inv], has_next_s[inv])


def _hop_tiled_bucket(index, scfg, sched_cfg, carry: _Carry, step,
                      hop_key) -> _Carry:
    """Bucket-regrouped layout feeding the Pallas kernel (DESIGN.md §10).

    The counting regroup yields an exact node sort (LSD passes over the
    full node id), which is all the tile/task-table construction needs.
    """
    from repro.kernels import ops as kops
    lane, s_node, s_time, s_prev, s_alive = _bucket_prologue(
        index, sched_cfg, carry)
    u = jax.random.uniform(hop_key, (carry.cur_node.shape[0],))[lane]

    k, n = kops.walk_step(index, s_node, s_time, u, scfg, sched_cfg)
    has_next_s = s_alive & (n > 0)
    k = jnp.clip(k, 0, index.edge_capacity - 1)
    return _advance_lanes(carry, lane, step, s_node, s_time, s_prev,
                          index.ns_dst[k], index.ns_ts[k], has_next_s)


def _fused_draws(index, scfg, hop_key, order, lane_bias, lane_u):
    """Per-lane (bias code, uniform) for the fused kernel, in lane order.

    Draws are generated in walk order and indexed through ``order`` —
    the same layout-independence rule as ``_draw_pick``.
    """
    from repro.core.samplers import bias_code
    W = order.shape[0]
    if lane_u is not None:
        return lane_bias[order], lane_u[order]
    code = jnp.full((W,), bias_code(scfg.bias), jnp.int32)
    return code, jax.random.uniform(hop_key, (W,))[order]


def _hop_fused(index, scfg, sched_cfg, carry: _Carry, step, hop_key,
               lane_bias=None, lane_u=None, lane_limit=None, tables=None,
               lane_n2v=None) -> _Carry:
    """Lexsort layout feeding the fused convergence-tiered kernel.

    ``tables``/``lane_n2v`` are always None here — check_capabilities
    refuses table-bias and second-order batches on the fused path.
    """
    from repro.kernels import fused_step as kfused
    W = carry.cur_node.shape[0]
    node_key = jnp.where(carry.alive, carry.cur_node, index.node_capacity + 1)
    perm = jnp.lexsort((carry.cur_time, node_key)).astype(jnp.int32)
    s_node = carry.cur_node[perm]
    s_time = carry.cur_time[perm]
    s_alive = carry.alive[perm]
    code, u = _fused_draws(index, scfg, hop_key, perm, lane_bias, lane_u)

    out = kfused.fused_walk_step(index, s_node, s_time, code, u,
                                 scfg.mode, sched_cfg)
    has_next_s = s_alive & (out.n > 0)
    if lane_limit is not None:
        has_next_s = has_next_s & lane_limit[perm]
    inv = jnp.zeros((W,), jnp.int32).at[perm].set(
        jnp.arange(W, dtype=jnp.int32))
    return _advance(carry, step, out.dst[inv], out.ts[inv], has_next_s[inv])


def _hop_fused_bucket(index, scfg, sched_cfg, carry: _Carry, step, hop_key,
                      lane_bias=None, lane_u=None, lane_limit=None,
                      tables=None, lane_n2v=None) -> _Carry:
    """Bucket-regrouped layout feeding the fused kernel (DESIGN.md §14).

    The kernel returns the gathered dst/ts directly — the hop issues no
    edge-array gathers at all, unlike ``_hop_tiled_bucket``.
    ``tables``/``lane_n2v`` are always None here (see ``_hop_fused``).
    """
    from repro.kernels import fused_step as kfused
    lane, s_node, s_time, s_prev, s_alive = _bucket_prologue(
        index, sched_cfg, carry)
    code, u = _fused_draws(index, scfg, hop_key, lane, lane_bias, lane_u)

    out = kfused.fused_walk_step(index, s_node, s_time, code, u,
                                 scfg.mode, sched_cfg)
    has_next_s = s_alive & (out.n > 0)
    if lane_limit is not None:
        has_next_s = has_next_s & lane_limit[lane]
    return _advance_lanes(carry, lane, step, s_node, s_time, s_prev,
                          out.dst, out.ts, has_next_s)


def _advance(carry: _Carry, step, next_node, next_time, has_next) -> _Carry:
    """Advance with lanes in walk order (fullwalk / lexsort paths)."""
    nodes = carry.nodes.at[:, step + 1].set(
        jnp.where(has_next, next_node, NODE_PAD).astype(jnp.int32),
        mode="drop")
    times = carry.times.at[:, step + 1].set(
        jnp.where(has_next, next_time, NODE_PAD).astype(jnp.int32),
        mode="drop")
    return _Carry(
        cur_node=jnp.where(has_next, next_node, carry.cur_node),
        cur_time=jnp.where(has_next, next_time, carry.cur_time),
        prev_node=jnp.where(has_next, carry.cur_node, carry.prev_node),
        alive=has_next,
        lane=carry.lane,
        nodes=nodes, times=times,
        lengths=carry.lengths + has_next.astype(jnp.int32),
    )


def _advance_lanes(carry: _Carry, lane, step, s_node, s_time, s_prev,
                   next_node, next_time, has_next) -> _Carry:
    """Advance with lanes in grouped order; walk buffers scatter via lane."""
    nodes = carry.nodes.at[lane, step + 1].set(
        jnp.where(has_next, next_node, NODE_PAD).astype(jnp.int32),
        mode="drop")
    times = carry.times.at[lane, step + 1].set(
        jnp.where(has_next, next_time, NODE_PAD).astype(jnp.int32),
        mode="drop")
    return _Carry(
        cur_node=jnp.where(has_next, next_node, s_node),
        cur_time=jnp.where(has_next, next_time, s_time),
        prev_node=jnp.where(has_next, s_node, s_prev),
        alive=has_next,
        lane=lane,
        nodes=nodes, times=times,
        lengths=carry.lengths.at[lane].add(has_next.astype(jnp.int32),
                                           mode="drop"),
    )


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def _generate_walks_impl(index: TemporalIndex, key: jax.Array,
                         wcfg: WalkConfig, scfg: SamplerConfig,
                         sched_cfg: SchedulerConfig,
                         collect_stats: bool = False,
                         buffers: Optional[WalkBuffers] = None,
                         walk_offset=0,
                         lanes: Optional[LaneParams] = None,
                         tables: Optional[AliasTables] = None,
                         second_order: bool = False) -> WalkResult:
    """Shared walk-generation body behind every jit entry point.

    ``tables`` threads the window's alias tables (bias='table' configs or
    table-coded lanes, DESIGN.md §17); ``second_order`` (static) compiles
    the per-lane node2vec rejection machinery into the lane dispatch.
    """
    path = sched_cfg.path
    if lanes is not None:
        _check_lane_support(wcfg, scfg, sched_cfg, lanes,
                            tables=tables, second_order=second_order)
        # one base key; lane streams are derived by fold_in, no split —
        # the split would make draws depend on batch composition
        lane_keys = _lane_keys(key, lanes)
        start_key = walk_key = key
    else:
        check_capabilities(scfg, path, have_tables=tables is not None)
        lane_keys = None
        start_key, walk_key = jax.random.split(key)
    carry0 = start_walks(index, wcfg, scfg, start_key,
                         walk_offset=walk_offset, buffers=buffers,
                         lanes=lanes, lane_keys=lane_keys)
    L = wcfg.max_length
    # number of remaining hops: start already consumed 1 edge in edges-mode
    hops = L - 1 if wcfg.start_mode == "edges" else L

    bucket = sched_cfg.regroup == "bucket"
    if sched_cfg.regroup not in ("bucket", "lexsort"):
        raise ValueError(f"unknown regroup {sched_cfg.regroup!r}")
    pass_tables = tables if scfg.bias == "table" or lanes is not None \
        else None

    def body(carry, step):
        hop_key = jax.random.fold_in(walk_key, step)
        write_pos = step + (1 if wcfg.start_mode == "edges" else 0)
        if lanes is not None:
            # per-lane draw for this hop (tag s+1; tag 0 is the start draw)
            # and the per-lane budget: column write_pos+1 is written only
            # while it stays within the lane's own max_len
            lane_kw = dict(
                lane_bias=lanes.bias,
                lane_u=_lane_uniform(lane_keys, step + 1),
                lane_limit=(write_pos + 1) <= lanes.max_len,
                tables=pass_tables,
            )
            if second_order:
                # second-order rejection uniforms from the dedicated tag
                # block (see N2V_TAG_BASE): 2 per round per lane
                base = N2V_TAG_BASE + step * (2 * N2V_ROUNDS)
                us2 = jnp.stack([
                    jnp.stack([_lane_uniform(lane_keys, base + 2 * r),
                               _lane_uniform(lane_keys, base + 2 * r + 1)])
                    for r in range(N2V_ROUNDS)])
                lane_kw["lane_n2v"] = (lanes.n2v_p, lanes.n2v_q, us2)
        elif scfg.bias == "table":
            lane_kw = dict(tables=pass_tables)
        else:
            lane_kw = {}
        if collect_stats:
            st = sched.dispatch_stats(index, carry.cur_node, carry.alive,
                                      sched_cfg)
        else:
            st = jnp.zeros((sched.NUM_STATS,), jnp.float32)
        if path == "fullwalk":
            carry = _hop_fullwalk(index, scfg, carry, write_pos, hop_key,
                                  **lane_kw)
        elif path == "grouped":
            if bucket:
                carry = _hop_grouped_bucket(index, scfg, sched_cfg, carry,
                                            write_pos, hop_key, **lane_kw)
            else:
                carry = _hop_grouped(index, scfg, carry, write_pos, hop_key,
                                     **lane_kw)
        elif path == "tiled":
            if bucket:
                carry = _hop_tiled_bucket(index, scfg, sched_cfg, carry,
                                          write_pos, hop_key)
            else:
                carry = _hop_tiled(index, scfg, sched_cfg, carry, write_pos,
                                   hop_key)
        elif path == "fused":
            if bucket:
                carry = _hop_fused_bucket(index, scfg, sched_cfg, carry,
                                          write_pos, hop_key, **lane_kw)
            else:
                carry = _hop_fused(index, scfg, sched_cfg, carry, write_pos,
                                   hop_key, **lane_kw)
        else:
            raise ValueError(f"unknown scheduler path {path!r}")
        return carry, st

    carry, stats = jax.lax.scan(body, carry0,
                                jnp.arange(hops, dtype=jnp.int32))
    return WalkResult(nodes=carry.nodes, times=carry.times,
                      lengths=carry.lengths,
                      stats=stats if collect_stats else None)


def _check_lane_support(wcfg: WalkConfig, scfg: SamplerConfig,
                        sched_cfg: SchedulerConfig, lanes: LaneParams,
                        tables: Optional[AliasTables] = None,
                        second_order: bool = False) -> None:
    """Static (trace-time) validation of a per-lane batch (DESIGN.md §11).

    Shape checks live here; everything capability-shaped delegates to
    ``check_capabilities``.
    """
    check_capabilities(
        scfg, sched_cfg.path,
        LaneFeatures(table=tables is not None, second_order=second_order),
        have_tables=tables is not None)
    if lanes.start_node.shape[0] != wcfg.num_walks:
        raise ValueError(
            f"lane arrays have {lanes.start_node.shape[0]} lanes but "
            f"wcfg.num_walks={wcfg.num_walks}")
    if second_order and (lanes.n2v_p is None or lanes.n2v_q is None):
        raise ValueError(
            "second_order=True requires LaneParams.n2v_p/n2v_q arrays "
            "(the coalescer packs them; see serve/coalescer.py)")


# Generate ``wcfg.num_walks`` temporal walks of ≤ ``max_length`` hops.
# ``tables`` (trailing, optional) threads the window's alias tables for
# bias='table' configs.
generate_walks = partial(
    jax.jit,
    static_argnames=("wcfg", "scfg", "sched_cfg", "collect_stats"),
)(_generate_walks_impl)


def _generate_walk_lanes_impl(index: TemporalIndex, key: jax.Array,
                              lanes: LaneParams, wcfg: WalkConfig,
                              scfg: SamplerConfig,
                              sched_cfg: SchedulerConfig,
                              buffers: Optional[WalkBuffers] = None,
                              tables: Optional[AliasTables] = None,
                              second_order: bool = False) -> WalkResult:
    return _generate_walks_impl(index, key, wcfg, scfg, sched_cfg,
                                buffers=buffers, lanes=lanes,
                                tables=tables, second_order=second_order)


# Coalesced heterogeneous batch (DESIGN.md §11): one fixed-shape dispatch
# serving many queries, with bias / max_length / RNG seed per lane (plus
# alias tables and per-lane node2vec (p, q) when the batch needs them,
# DESIGN.md §17). The jit cache keys on (wcfg, scfg, sched_cfg,
# second_order) — the serving coalescer keeps that set small by bucketing
# batch shapes.
generate_walk_lanes = partial(
    jax.jit,
    static_argnames=("wcfg", "scfg", "sched_cfg", "second_order"),
)(_generate_walk_lanes_impl)


def _generate_walks_donated_impl(index: TemporalIndex, key: jax.Array,
                                 buffers: WalkBuffers, wcfg: WalkConfig,
                                 scfg: SamplerConfig,
                                 sched_cfg: SchedulerConfig,
                                 tables: Optional[AliasTables] = None
                                 ) -> WalkResult:
    return _generate_walks_impl(index, key, wcfg, scfg, sched_cfg,
                                collect_stats=False, buffers=buffers,
                                tables=tables)


# Donating entry point for steady-state loops (DESIGN.md §10): pass the
# previous round's WalkResult arrays (or alloc_walk_buffers once) as
# ``buffers`` and XLA reuses their storage for the new result instead of
# allocating ~2·W·(L+1) ints per call. The passed-in buffers are consumed.
generate_walks_donated = partial(
    jax.jit,
    static_argnames=("wcfg", "scfg", "sched_cfg"),
    donate_argnums=(2,),   # buffers only; tables trail after and are read-only
)(_generate_walks_donated_impl)
