"""Temporal random-walk engine (paper §2.4).

Execution paths (the TPU mapping of the paper's dispatch plane):

* ``fullwalk`` — the paper's §2.4.1 baseline: every walk advances
  independently; per-hop gathers and binary searches are issued per walk in
  whatever order walks happen to sit in memory.

* ``grouped`` — the hierarchical-cooperative-scheduling adaptation (§2.4.3):
  each hop, walks are sorted by (current node, current time); identical
  (node, time) pairs form *segments* whose temporal cutoff is computed once
  at the segment head and broadcast to members, and whose gathers touch
  contiguous index regions (the TPU analog of coalesced, smem-amortized
  access). Only the random draw and the picked edge differ per walk —
  exactly the paper's observation.

* ``tiled`` — the grouped path with the hop search+sample executed by the
  Pallas kernel (kernels/walk_step.py), which stages each task's edge slice
  in VMEM (the smem-panel analog). Selected via SchedulerConfig.path.

All paths produce **identical walks for identical keys** (tested): random
draws are generated in original walk order and permuted alongside the state,
so grouping is purely an execution-layout decision — the paper makes the
same claim for its tiers.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SamplerConfig, SchedulerConfig, WalkConfig
from repro.core import scheduler as sched
from repro.core.samplers import (
    node2vec_beta,
    node2vec_max_beta,
    pick_in_neighborhood,
    pick_start_edges,
)
from repro.core.temporal_index import (
    TemporalIndex,
    node_range,
    temporal_cutoff,
)

NODE_PAD = -1          # sentinel in emitted walks beyond walk length
N2V_ROUNDS = 8         # rejection-sampling rounds per hop (vectorized)


class WalkResult(NamedTuple):
    nodes: jax.Array     # int32[W, L+1], NODE_PAD beyond length
    times: jax.Array     # int32[W, L+1]
    lengths: jax.Array   # int32[W] number of nodes recorded (>=1)
    stats: Optional[jax.Array]   # float32[L, sched.NUM_STATS] or None


class _Carry(NamedTuple):
    cur_node: jax.Array
    cur_time: jax.Array
    prev_node: jax.Array
    alive: jax.Array
    nodes: jax.Array
    times: jax.Array
    lengths: jax.Array


# ---------------------------------------------------------------------------
# Walk starts
# ---------------------------------------------------------------------------


def start_walks(index: TemporalIndex, wcfg: WalkConfig, scfg: SamplerConfig,
                key: jax.Array) -> _Carry:
    W = wcfg.num_walks
    L = wcfg.max_length
    nodes = jnp.full((W, L + 1), NODE_PAD, jnp.int32)
    times = jnp.full((W, L + 1), NODE_PAD, jnp.int32)

    t_floor = jnp.where(index.num_edges > 0, index.store.ts[0] - 1, 0)

    if wcfg.start_mode == "all_nodes":
        # paper §3.3: k walks from every active source node
        nc = index.node_capacity
        cur = (jnp.arange(W, dtype=jnp.int32) % nc)
        deg = index.node_starts[cur + 1] - index.node_starts[cur]
        alive = deg > 0
        cur_time = jnp.full((W,), 1, jnp.int32) * t_floor
    elif wcfg.start_mode == "nodes":
        # uniform over active nodes via cumulative-count inversion
        nc = index.node_capacity
        deg = index.node_starts[1:nc + 1] - index.node_starts[:nc]
        active = (deg > 0).astype(jnp.int32)
        cum = jnp.cumsum(active)
        num_active = cum[-1]
        u = jax.random.uniform(key, (W,))
        j = jnp.floor(u * num_active.astype(jnp.float32)).astype(jnp.int32)
        j = jnp.clip(j, 0, jnp.maximum(num_active - 1, 0))
        cur = jnp.searchsorted(cum, j, side="right").astype(jnp.int32)
        alive = jnp.broadcast_to(num_active > 0, (W,))
        cur_time = jnp.full((W,), 1, jnp.int32) * t_floor
    elif wcfg.start_mode == "edges":
        # start-edge selection over the timestamp-grouped view (paper §2.3)
        u = jax.random.uniform(key, (W,))
        e = pick_start_edges(index, scfg, u)
        e = jnp.clip(e, 0, index.edge_capacity - 1)
        src = index.store.src[e]
        cur = index.store.dst[e]
        cur_time = index.store.ts[e]
        alive = jnp.broadcast_to(index.num_edges > 0, (W,))
        nodes = nodes.at[:, 0].set(jnp.where(alive, src, NODE_PAD))
        times = times.at[:, 0].set(jnp.where(alive, cur_time, NODE_PAD))
        nodes = nodes.at[:, 1].set(jnp.where(alive, cur, NODE_PAD))
        times = times.at[:, 1].set(jnp.where(alive, cur_time, NODE_PAD))
        return _Carry(cur_node=cur, cur_time=cur_time, prev_node=src,
                      alive=alive, nodes=nodes, times=times,
                      lengths=jnp.where(alive, 2, 0).astype(jnp.int32))
    else:
        raise ValueError(f"unknown start_mode {wcfg.start_mode!r}")

    nodes = nodes.at[:, 0].set(jnp.where(alive, cur, NODE_PAD))
    times = times.at[:, 0].set(jnp.where(alive, cur_time, NODE_PAD))
    return _Carry(cur_node=cur, cur_time=cur_time,
                  prev_node=jnp.full((W,), -1, jnp.int32),
                  alive=alive, nodes=nodes, times=times,
                  lengths=alive.astype(jnp.int32))


# ---------------------------------------------------------------------------
# One hop, full-walk layout
# ---------------------------------------------------------------------------


def _sample_hop(index: TemporalIndex, scfg: SamplerConfig,
                cur_node, cur_time, prev_node, alive, hop_key):
    """Given per-walk (node, time), returns (next_node, next_time, has_next).

    Pure sampling logic shared by every path; callers control the layout.
    """
    W = cur_node.shape[0]
    a, b = node_range(index, cur_node)
    c = temporal_cutoff(index, a, b, cur_time)
    n = b - c
    has_next = alive & (n > 0)

    use_n2v = (scfg.node2vec_p != 1.0) or (scfg.node2vec_q != 1.0)
    if not use_n2v:
        u = jax.random.uniform(hop_key, (W,))
        k = pick_in_neighborhood(index, scfg, c, b, u, cur_node)
    else:
        # rejection sampling on the first-order proposal (paper §2.5)
        beta_max = node2vec_max_beta(scfg.node2vec_p, scfg.node2vec_q)
        us = jax.random.uniform(hop_key, (N2V_ROUNDS, 2, W))

        def round_(carry, uv):
            k_acc, accepted = carry
            u_r, v_r = uv[0], uv[1]
            k_r = pick_in_neighborhood(index, scfg, c, b, u_r, cur_node)
            cand = index.ns_dst[jnp.clip(k_r, 0, index.edge_capacity - 1)]
            beta = node2vec_beta(index, prev_node, cand,
                                 scfg.node2vec_p, scfg.node2vec_q)
            # hops with no previous node accept unconditionally
            ok = (v_r * beta_max <= beta) | (prev_node < 0)
            take = ok & ~accepted
            return (jnp.where(take, k_r, k_acc), accepted | ok), None

        u0 = us[0, 0]
        k0 = pick_in_neighborhood(index, scfg, c, b, u0, cur_node)
        (k, _), _ = jax.lax.scan(round_, (k0, jnp.zeros((W,), bool)), us)

    k = jnp.clip(k, 0, index.edge_capacity - 1)
    next_node = index.ns_dst[k]
    next_time = index.ns_ts[k]
    return next_node, next_time, has_next, (a, b, c)


def _hop_fullwalk(index, scfg, carry: _Carry, step: jax.Array,
                  hop_key) -> _Carry:
    nn, nt, has_next, _ = _sample_hop(
        index, scfg, carry.cur_node, carry.cur_time, carry.prev_node,
        carry.alive, hop_key)
    return _advance(carry, step, nn, nt, has_next)


def _hop_grouped(index, scfg, carry: _Carry, step: jax.Array,
                 hop_key) -> _Carry:
    """Sort by (node, time); dedup the cutoff search per segment head."""
    W = carry.cur_node.shape[0]
    nc = index.node_capacity
    node_key = jnp.where(carry.alive, carry.cur_node, nc + 1)
    perm = jnp.lexsort((carry.cur_time, node_key)).astype(jnp.int32)

    s_node = carry.cur_node[perm]
    s_time = carry.cur_time[perm]
    s_prev = carry.prev_node[perm]
    s_alive = carry.alive[perm]

    # segment heads: first lane of each unique (node, time) pair
    p_node = jnp.concatenate([jnp.full((1,), -2, jnp.int32), s_node[:-1]])
    p_time = jnp.concatenate([jnp.full((1,), -2, jnp.int32), s_time[:-1]])
    head = (s_node != p_node) | (s_time != p_time)
    seg_id = jnp.cumsum(head.astype(jnp.int32)) - 1

    a, b = node_range(index, s_node)
    # cutoff computed once per segment head, broadcast to members.
    c_head = temporal_cutoff(index, a, b, s_time)
    c = jax.ops.segment_max(jnp.where(head, c_head, 0), seg_id,
                            num_segments=W)[seg_id]
    n = b - c
    has_next_s = s_alive & (n > 0)

    # draws follow original walk order for path-equivalence; permute them
    use_n2v = (scfg.node2vec_p != 1.0) or (scfg.node2vec_q != 1.0)
    if not use_n2v:
        u = jax.random.uniform(hop_key, (W,))[perm]
        k = pick_in_neighborhood(index, scfg, c, b, u, s_node)
    else:
        beta_max = node2vec_max_beta(scfg.node2vec_p, scfg.node2vec_q)
        us = jax.random.uniform(hop_key, (N2V_ROUNDS, 2, W))[:, :, perm]

        def round_(carry_, uv):
            k_acc, accepted = carry_
            u_r, v_r = uv[0], uv[1]
            k_r = pick_in_neighborhood(index, scfg, c, b, u_r, s_node)
            cand = index.ns_dst[jnp.clip(k_r, 0, index.edge_capacity - 1)]
            beta = node2vec_beta(index, s_prev, cand,
                                 scfg.node2vec_p, scfg.node2vec_q)
            ok = (v_r * beta_max <= beta) | (s_prev < 0)
            take = ok & ~accepted
            return (jnp.where(take, k_r, k_acc), accepted | ok), None

        k0 = pick_in_neighborhood(index, scfg, c, b, us[0, 0], s_node)
        (k, _), _ = jax.lax.scan(round_, (k0, jnp.zeros((W,), bool)), us)

    k = jnp.clip(k, 0, index.edge_capacity - 1)
    nn_s = index.ns_dst[k]
    nt_s = index.ns_ts[k]

    # unsort back to original walk order
    inv = jnp.zeros((W,), jnp.int32).at[perm].set(
        jnp.arange(W, dtype=jnp.int32))
    nn = nn_s[inv]
    nt = nt_s[inv]
    has_next = has_next_s[inv]
    return _advance(carry, step, nn, nt, has_next)


def _hop_tiled(index, scfg, sched_cfg, carry: _Carry, step, hop_key) -> _Carry:
    """Grouped layout with the Pallas kernel executing search+sample."""
    from repro.kernels import ops as kops
    W = carry.cur_node.shape[0]
    node_key = jnp.where(carry.alive, carry.cur_node, index.node_capacity + 1)
    perm = jnp.lexsort((carry.cur_time, node_key)).astype(jnp.int32)
    s_node = carry.cur_node[perm]
    s_time = carry.cur_time[perm]
    s_alive = carry.alive[perm]
    u = jax.random.uniform(hop_key, (W,))[perm]

    k, n = kops.walk_step(index, s_node, s_time, u, scfg, sched_cfg)
    has_next_s = s_alive & (n > 0)
    k = jnp.clip(k, 0, index.edge_capacity - 1)
    nn_s = index.ns_dst[k]
    nt_s = index.ns_ts[k]
    inv = jnp.zeros((W,), jnp.int32).at[perm].set(jnp.arange(W, dtype=jnp.int32))
    return _advance(carry, step, nn_s[inv], nt_s[inv], has_next_s[inv])


def _advance(carry: _Carry, step, next_node, next_time, has_next) -> _Carry:
    nodes = carry.nodes.at[:, step + 1].set(
        jnp.where(has_next, next_node, NODE_PAD).astype(jnp.int32),
        mode="drop")
    times = carry.times.at[:, step + 1].set(
        jnp.where(has_next, next_time, NODE_PAD).astype(jnp.int32),
        mode="drop")
    return _Carry(
        cur_node=jnp.where(has_next, next_node, carry.cur_node),
        cur_time=jnp.where(has_next, next_time, carry.cur_time),
        prev_node=jnp.where(has_next, carry.cur_node, carry.prev_node),
        alive=has_next,
        nodes=nodes, times=times,
        lengths=carry.lengths + has_next.astype(jnp.int32),
    )


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("wcfg", "scfg", "sched_cfg",
                                   "collect_stats"))
def generate_walks(index: TemporalIndex, key: jax.Array,
                   wcfg: WalkConfig, scfg: SamplerConfig,
                   sched_cfg: SchedulerConfig,
                   collect_stats: bool = False) -> WalkResult:
    """Generate ``wcfg.num_walks`` temporal walks of ≤ ``max_length`` hops."""
    start_key, walk_key = jax.random.split(key)
    carry0 = start_walks(index, wcfg, scfg, start_key)
    L = wcfg.max_length
    first_hop = carry0.lengths.max() if wcfg.start_mode == "edges" else None
    # number of remaining hops: start already consumed 1 edge in edges-mode
    hops = L - 1 if wcfg.start_mode == "edges" else L

    path = sched_cfg.path

    def body(carry, step):
        hop_key = jax.random.fold_in(walk_key, step)
        write_pos = step + (1 if wcfg.start_mode == "edges" else 0)
        if collect_stats:
            st = sched.dispatch_stats(index, carry.cur_node, carry.alive,
                                      sched_cfg)
        else:
            st = jnp.zeros((sched.NUM_STATS,), jnp.float32)
        if path == "fullwalk":
            carry = _hop_fullwalk(index, scfg, carry, write_pos, hop_key)
        elif path == "grouped":
            carry = _hop_grouped(index, scfg, carry, write_pos, hop_key)
        elif path == "tiled":
            carry = _hop_tiled(index, scfg, sched_cfg, carry, write_pos,
                               hop_key)
        else:
            raise ValueError(f"unknown scheduler path {path!r}")
        return carry, st

    carry, stats = jax.lax.scan(body, carry0,
                                jnp.arange(hops, dtype=jnp.int32))
    return WalkResult(nodes=carry.nodes, times=carry.times,
                      lengths=carry.lengths,
                      stats=stats if collect_stats else None)
