"""Streaming driver: chronological batch replay with per-batch walk
generation (the paper's §3.3 operating regime) and stage timings."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Iterable, List, Optional

import jax
import numpy as np

from repro.configs.base import (
    EngineConfig,
    SamplerConfig,
    SchedulerConfig,
    WalkConfig,
)
from repro.core.edge_store import make_batch
from repro.core.walk_engine import generate_walks
from repro.core.window import WindowState, ingest, init_window


@dataclass
class StreamStats:
    ingest_s: List[float] = field(default_factory=list)
    sample_s: List[float] = field(default_factory=list)
    edges_active: List[int] = field(default_factory=list)
    walks_valid: List[float] = field(default_factory=list)

    @property
    def cumulative_ingest(self):
        return np.cumsum(self.ingest_s)

    @property
    def cumulative_sample(self):
        return np.cumsum(self.sample_s)


class StreamingEngine:
    """Tempest's end-to-end loop: ingest -> rebuild -> walk."""

    def __init__(self, cfg: EngineConfig, batch_capacity: int):
        self.cfg = cfg
        self.batch_capacity = batch_capacity
        self.state: WindowState = init_window(
            cfg.window.edge_capacity, cfg.window.node_capacity,
            int(cfg.window.duration))
        self.key = jax.random.PRNGKey(cfg.seed)
        self.stats = StreamStats()

    def ingest_batch(self, src, dst, ts) -> None:
        batch = make_batch(src, dst, ts, capacity=self.batch_capacity)
        t0 = time.perf_counter()
        self.state = ingest(self.state, batch,
                            self.cfg.window.node_capacity)
        jax.block_until_ready(self.state.index.ns_order)
        self.stats.ingest_s.append(time.perf_counter() - t0)
        self.stats.edges_active.append(int(self.state.index.num_edges))

    def sample_walks(self, wcfg: WalkConfig,
                     collect_stats: bool = False):
        self.key, sub = jax.random.split(self.key)
        t0 = time.perf_counter()
        res = generate_walks(self.state.index, sub, wcfg,
                             self.cfg.sampler, self.cfg.scheduler,
                             collect_stats=collect_stats)
        jax.block_until_ready(res.nodes)
        self.stats.sample_s.append(time.perf_counter() - t0)
        return res

    def replay(self, batches: Iterable, wcfg: WalkConfig,
               on_batch: Optional[Callable] = None):
        for bs, bd, bt in batches:
            self.ingest_batch(bs, bd, bt)
            res = self.sample_walks(wcfg)
            if on_batch is not None:
                on_batch(self, res)
        return self.stats
