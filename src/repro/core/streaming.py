"""Streaming drivers: chronological batch replay (paper §3.3 regime).

Two drivers over the same merge-based ingest (DESIGN.md §4):

* **Host loop** (`StreamingEngine.replay`) — one ingest dispatch + one walk
  dispatch per batch, with a `block_until_ready` after each so per-stage
  wall-clock timings can be recorded. This is the measurement driver
  (benchmarks Table 4 / Fig. 6 need per-batch stage latencies).

* **Device-resident scan** (`replay_scan` / `StreamingEngine.replay_device`)
  — all K batches are stacked into one device array and the whole
  ingest→rebuild→walk loop runs under a single `jax.lax.scan` with the
  window state donated into the jit. Per-batch statistics (active edges,
  drop counters, walk lengths) are accumulated on-device as scan outputs
  and materialized **once** at the end — zero host round-trips between
  batches. This is the throughput driver: dispatch overhead and host
  synchronization are off the critical path, so sustained ingest bandwidth
  is what the hardware allows.

`ingest_and_walk` is the shared fused step: one jitted program covering
merge-ingest + index rebuild + walk generation, donating the old state.
`ingest_and_walk_donated` additionally consumes the previous round's walk
buffers, and `replay_scan` carries them through the scan, so steady-state
replay reallocates nothing on the walk side either (DESIGN.md §10).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Iterable, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (
    EngineConfig,
    SamplerConfig,
    SchedulerConfig,
    WalkConfig,
)
from repro.core.edge_store import EdgeBatch, make_batch, stack_batches
from repro.core.walk_engine import (
    WalkBuffers,
    WalkResult,
    _generate_walks_impl,
    alloc_walk_buffers,
    generate_walks,
    generate_walks_donated,
)
from repro.core.window import (
    WindowState,
    ingest,
    ingest_impl,
    ingest_sort,
    init_window,
)
from repro.obs.probes import (
    flush_replay_probes,
    replay_probe_update,
    replay_probe_zeros,
)
from repro.obs.registry import MetricsRegistry, get_registry
from repro.obs.tracing import span


# sample_walks_sharded replicates the index per device; past this size a
# one-time warning points at the node-partitioned engine (DESIGN.md §12).
REPLICATED_INDEX_WARN_BYTES = 256 << 20


@dataclass
class StreamStats:
    ingest_s: List[float] = field(default_factory=list)
    sample_s: List[float] = field(default_factory=list)
    edges_active: List[int] = field(default_factory=list)
    walks_valid: List[float] = field(default_factory=list)

    @property
    def cumulative_ingest(self):
        return np.cumsum(self.ingest_s)

    @property
    def cumulative_sample(self):
        return np.cumsum(self.sample_s)


class ReplayStats(NamedTuple):
    """Per-batch statistics of a device-resident replay ([K] arrays).

    Gathered as `lax.scan` outputs — reading them costs one device->host
    transfer for the whole replay, not one per batch.
    """

    edges_active: jax.Array     # int32[K] store population after each batch
    t_now: jax.Array            # int32[K]
    ingested: jax.Array         # int32[K] cumulative counters after each batch
    late_drops: jax.Array       # int32[K]
    overflow_drops: jax.Array   # int32[K]
    mean_len: jax.Array         # float32[K] mean walk length per batch


def _ingest_and_walk_impl(state: WindowState, batch: EdgeBatch,
                          key: jax.Array, node_capacity: int,
                          wcfg: WalkConfig, scfg: SamplerConfig,
                          sched_cfg: SchedulerConfig,
                          bias_scale: float = 1.0,
                          walk_bufs: Optional[WalkBuffers] = None,
                          table=None):
    state = ingest_impl(state, batch, node_capacity, bias_scale,
                        table=table)
    res = _generate_walks_impl(state.index, key, wcfg, scfg, sched_cfg,
                               buffers=walk_bufs, tables=state.tables)
    return state, res


# Fused step: ingest + rebuild + walk in ONE jitted program, old state
# donated. One dispatch per batch instead of two, and XLA may overlap the
# index rebuild with the first hops of the walk scan. ``table`` (static
# TableSpec) switches on incremental alias-table maintenance + table-bias
# walks (DESIGN.md §17).
ingest_and_walk = partial(
    jax.jit,
    static_argnames=("node_capacity", "wcfg", "scfg", "sched_cfg",
                     "bias_scale", "table"),
    donate_argnums=(0,),
)(_ingest_and_walk_impl)


def _ingest_and_walk_donated_impl(state: WindowState, batch: EdgeBatch,
                                  walk_bufs: WalkBuffers, key: jax.Array,
                                  node_capacity: int, wcfg: WalkConfig,
                                  scfg: SamplerConfig,
                                  sched_cfg: SchedulerConfig,
                                  bias_scale: float = 1.0, table=None):
    return _ingest_and_walk_impl(state, batch, key, node_capacity, wcfg,
                                 scfg, sched_cfg, bias_scale,
                                 walk_bufs=walk_bufs, table=table)


# Fully donated fused step (DESIGN.md §10): both the window state AND the
# previous round's walk buffers are consumed, so a steady-state host loop
# reallocates nothing per batch — chain with
# ``bufs = WalkBuffers(res.nodes, res.times)`` between calls.
ingest_and_walk_donated = partial(
    jax.jit,
    static_argnames=("node_capacity", "wcfg", "scfg", "sched_cfg",
                     "bias_scale", "table"),
    donate_argnums=(0, 2),
)(_ingest_and_walk_donated_impl)


def _replay_scan_impl(state: WindowState, batches: EdgeBatch, key: jax.Array,
                      node_capacity: int, wcfg: WalkConfig,
                      scfg: SamplerConfig, sched_cfg: SchedulerConfig,
                      bias_scale: float = 1.0, with_probes: bool = False,
                      table=None):
    """Shared body of ``replay_scan`` / ``replay_scan_probed``.

    ``with_probes`` threads an obs probe vector (obs/probes.py) through
    the scan carry as an *extra* leaf: the walk/RNG dataflow is untouched
    (probe updates are pure ``at[].add`` on counters the stats already
    compute), and when False the traced program is exactly the historical
    one — no probe leaf exists to be DCE'd.
    """

    def step(carry, batch):
        if with_probes:
            st, k, bufs, _, pv = carry
        else:
            st, k, bufs, _ = carry
        k, sub = jax.random.split(k)
        st2, res = _ingest_and_walk_impl(st, batch, sub, node_capacity,
                                         wcfg, scfg, sched_cfg, bias_scale,
                                         walk_bufs=bufs, table=table)
        stats = ReplayStats(
            edges_active=st2.index.num_edges,
            t_now=st2.t_now,
            ingested=st2.ingested,
            late_drops=st2.late_drops,
            overflow_drops=st2.overflow_drops,
            mean_len=jnp.mean(res.lengths.astype(jnp.float32)),
        )
        # walk buffers ride the scan carry: batch k+1's walks are written
        # into batch k's storage (DESIGN.md §10)
        nbufs = WalkBuffers(res.nodes, res.times)
        if with_probes:
            pv = replay_probe_update(
                pv,
                ingested_delta=st2.ingested - st.ingested,
                late_delta=st2.late_drops - st.late_drops,
                overflow_delta=st2.overflow_drops - st.overflow_drops,
                lengths=res.lengths)
            return (st2, k, nbufs, res.lengths, pv), stats
        return (st2, k, nbufs, res.lengths), stats

    lengths0 = jnp.zeros((wcfg.num_walks,), jnp.int32)
    carry0 = [state, key, alloc_walk_buffers(wcfg), lengths0]
    if with_probes:
        carry0.append(replay_probe_zeros())
    carry, stats = jax.lax.scan(step, tuple(carry0), batches)
    walks = WalkResult(nodes=carry[2].nodes, times=carry[2].times,
                       lengths=carry[3], stats=None)
    if with_probes:
        return carry[0], stats, walks, carry[4]
    return carry[0], stats, walks


@partial(jax.jit,
         static_argnames=("node_capacity", "wcfg", "scfg", "sched_cfg",
                          "bias_scale", "table"),
         donate_argnums=(0,))
def replay_scan(state: WindowState, batches: EdgeBatch, key: jax.Array,
                node_capacity: int, wcfg: WalkConfig, scfg: SamplerConfig,
                sched_cfg: SchedulerConfig, bias_scale: float = 1.0,
                table=None):
    """Replay K stacked batches fully on device under `jax.lax.scan`.

    ``batches`` holds [K, B_cap] arrays (see edge_store.stack_batches).
    Returns ``(final_state, ReplayStats, final_walks)`` — all still on
    device; the caller decides when to synchronize (a single
    block_until_ready at the end of the replay is the intended pattern).
    ``final_walks`` is the last batch's WalkResult, read straight out of
    the carried walk buffers — it is what the distributed replay
    (repro/distributed/streaming_shard.py, DESIGN.md §12) must reproduce
    bit-for-bit, and costs nothing to expose.
    """
    return _replay_scan_impl(state, batches, key, node_capacity, wcfg,
                             scfg, sched_cfg, bias_scale, with_probes=False,
                             table=table)


@partial(jax.jit,
         static_argnames=("node_capacity", "wcfg", "scfg", "sched_cfg",
                          "bias_scale", "table"),
         donate_argnums=(0,))
def replay_scan_probed(state: WindowState, batches: EdgeBatch,
                       key: jax.Array, node_capacity: int, wcfg: WalkConfig,
                       scfg: SamplerConfig, sched_cfg: SchedulerConfig,
                       bias_scale: float = 1.0, table=None):
    """``replay_scan`` plus an obs probe vector (DESIGN.md §16).

    Returns ``(final_state, ReplayStats, final_walks, probes)`` with
    ``probes`` an int32[NUM_REPLAY_PROBES] device vector accumulated
    across the scan — flush it with ``obs.flush_replay_probes`` at the
    same host sync that reads ``stats``. Walks and stats are bit-identical
    to ``replay_scan`` (pinned by tests/test_obs_probes.py); keeping this
    a separate jit entry point leaves the uninstrumented program
    byte-unchanged.
    """
    return _replay_scan_impl(state, batches, key, node_capacity, wcfg,
                             scfg, sched_cfg, bias_scale, with_probes=True,
                             table=table)


class StreamingEngine:
    """Tempest's end-to-end loop: ingest -> rebuild -> walk.

    ``ingest_impl`` selects the window-advance algorithm: ``"merge"`` (the
    rank-based two-run merge, default) or ``"sort"`` (the seed's global
    argsort, kept as the equivalence/benchmark reference).
    """

    def __init__(self, cfg: EngineConfig, batch_capacity: int,
                 ingest_impl: str = "merge",
                 registry: Optional[MetricsRegistry] = None,
                 probes: bool = True):
        if ingest_impl not in ("merge", "sort"):
            raise ValueError(f"unknown ingest_impl {ingest_impl!r}")
        self.cfg = cfg
        self.batch_capacity = batch_capacity
        self._ingest = ingest if ingest_impl == "merge" else ingest_sort
        # alias-table spec (DESIGN.md §17): bias='table' configs maintain
        # per-node alias tables incrementally through every ingest
        from repro.core.alias import spec_from_sampler
        self._table = spec_from_sampler(cfg.sampler)
        if self._table is not None and ingest_impl == "sort":
            raise ValueError(
                "alias-table maintenance (bias='table') requires the merge "
                "ingest path; the 'sort' reference path does not thread "
                "table state")
        self.state: WindowState = init_window(
            cfg.window.edge_capacity, cfg.window.node_capacity,
            int(cfg.window.duration), table=self._table)
        self.key = jax.random.PRNGKey(cfg.seed)
        self.stats = StreamStats()
        # obs integration (DESIGN.md §16): every driver publishes into the
        # registry; ``probes=False`` pins replay_device to the historical
        # uninstrumented program (used by the byte-identity tests).
        self.registry = registry if registry is not None else get_registry()
        self.probes = probes
        # window-counter baselines: state counters are cumulative, the
        # registry wants monotonic deltas
        self._ingested_seen = 0
        self._late_seen = 0
        self._overflow_seen = 0
        self._rebuilt_seen = 0
        # walk-buffer pool for sample_walks_donated, keyed by (W, L)
        self._walk_bufs: dict = {}
        self._warned_replicated_index = False

    def _publish_window(self) -> None:
        """Refresh window gauges + drop deltas from the synced state."""
        from repro.obs.registry import count_drop
        reg = self.registry
        num_edges = int(self.state.index.num_edges)
        reg.set_gauge("window_edges_active", num_edges,
                      help="edges resident in the temporal window")
        reg.set_gauge("window_t_now", int(self.state.t_now),
                      help="watermark timestamp of the window")
        reg.set_gauge("window_occupancy",
                      num_edges / self.cfg.window.edge_capacity,
                      help="window fill fraction (edges_active / capacity)")
        ingested = int(self.state.ingested)
        late = int(self.state.late_drops)
        overflow = int(self.state.overflow_drops)
        reg.inc("stream_edges_ingested_total",
                max(0, ingested - self._ingested_seen),
                labels={"driver": "host"},
                help="edges delivered into the window")
        count_drop(reg, "ingest_late", max(0, late - self._late_seen))
        count_drop(reg, "window_overflow",
                   max(0, overflow - self._overflow_seen))
        self._ingested_seen = ingested
        self._late_seen = late
        self._overflow_seen = overflow
        self._publish_tables()

    def _publish_tables(self) -> None:
        """Alias-table maintenance counters (DESIGN.md §17): how many node
        rebuilds the incremental update actually performed — the work a
        full per-batch rebuild would multiply by the window's node count."""
        if self.state.tables is None:
            return
        rebuilt = int(self.state.tables.rebuilt)
        self.registry.inc("alias_nodes_rebuilt_total",
                          max(0, rebuilt - self._rebuilt_seen),
                          help="alias-table node rebuilds performed by "
                               "incremental window maintenance")
        self._rebuilt_seen = rebuilt

    def _publish_window_from_replay(self, stats: ReplayStats) -> None:
        """Window gauges after a device replay; drop/ingest counters were
        already published from the probe vector, so only the cumulative
        baselines advance here."""
        last = np.asarray(stats.edges_active)
        if last.size == 0:
            return
        reg = self.registry
        edges = int(last[-1])
        reg.set_gauge("window_edges_active", edges,
                      help="edges resident in the temporal window")
        reg.set_gauge("window_t_now", int(np.asarray(stats.t_now)[-1]),
                      help="watermark timestamp of the window")
        reg.set_gauge("window_occupancy",
                      edges / self.cfg.window.edge_capacity,
                      help="window fill fraction (edges_active / capacity)")
        self._ingested_seen = int(np.asarray(stats.ingested)[-1])
        self._late_seen = int(np.asarray(stats.late_drops)[-1])
        self._overflow_seen = int(np.asarray(stats.overflow_drops)[-1])
        self._publish_tables()

    def ingest_batch(self, src, dst, ts) -> None:
        batch = make_batch(src, dst, ts, capacity=self.batch_capacity)
        t0 = time.perf_counter()
        with span("ingest_merge", self.registry):
            if self._table is not None:
                self.state = self._ingest(self.state, batch,
                                          self.cfg.window.node_capacity,
                                          table=self._table)
            else:
                self.state = self._ingest(self.state, batch,
                                          self.cfg.window.node_capacity)
            jax.block_until_ready(self.state.index.ns_order)
        self.stats.ingest_s.append(time.perf_counter() - t0)
        self.stats.edges_active.append(int(self.state.index.num_edges))
        self.registry.inc("stream_batches_total", 1,
                          labels={"driver": "host"},
                          help="batches replayed through the streaming "
                               "drivers")
        self._publish_window()

    def sample_walks(self, wcfg: WalkConfig,
                     collect_stats: bool = False):
        self.key, sub = jax.random.split(self.key)
        t0 = time.perf_counter()
        res = generate_walks(self.state.index, sub, wcfg,
                             self.cfg.sampler, self.cfg.scheduler,
                             collect_stats=collect_stats,
                             tables=self.state.tables)
        self._finish_sample(res, t0, path="host")
        return res

    def sample_walks_donated(self, wcfg: WalkConfig):
        """Like ``sample_walks`` but reuses a per-shape walk-buffer pool
        through ``generate_walks_donated`` (DESIGN.md §10): steady-state
        sampling allocates nothing on the walk side.

        Caveat: the *previous* WalkResult returned for the same
        (num_walks, max_length) shape is consumed by this call — copy it
        (``np.asarray``) first if it must outlive the next round.
        """
        shape_key = (wcfg.num_walks, wcfg.max_length)
        bufs = self._walk_bufs.pop(shape_key, None)
        if bufs is None:
            bufs = alloc_walk_buffers(wcfg)
        self.key, sub = jax.random.split(self.key)
        t0 = time.perf_counter()
        res = generate_walks_donated(self.state.index, sub, bufs, wcfg,
                                     self.cfg.sampler, self.cfg.scheduler,
                                     tables=self.state.tables)
        self._finish_sample(res, t0, path="donated")
        self._walk_bufs[shape_key] = WalkBuffers(res.nodes, res.times)
        return res

    def sample_walks_sharded(self, wcfg: WalkConfig, mesh=None):
        """Device-parallel sampling: the walk axis sharded over the mesh
        (defaults to all devices) against the replicated window index —
        see repro.distributed.walks (DESIGN.md §10).

        Memory cost: the **full dual index is replicated onto every
        device** of the mesh — a D-device mesh holds D copies of the
        store + index arrays (~10 arrays of edge capacity each), so total
        index memory is D× the single-device footprint and the window must
        still fit on ONE chip. That is the right trade only while it does;
        once the index passes ``REPLICATED_INDEX_WARN_BYTES`` a one-time
        warning points at the node-partitioned alternative
        (``repro.distributed.streaming_shard.DistributedStreamingEngine``,
        DESIGN.md §12), which shards the window itself so per-device memory
        *falls* with device count instead of staying flat.
        """
        from repro.distributed.walks import generate_walks_sharded
        self._warn_replicated_index()
        self.key, sub = jax.random.split(self.key)
        t0 = time.perf_counter()
        res = generate_walks_sharded(self.state.index, sub, wcfg,
                                     self.cfg.sampler, self.cfg.scheduler,
                                     mesh=mesh)
        self._finish_sample(res, t0, path="sharded")
        return res

    def _warn_replicated_index(self) -> None:
        """One-time warning when the replicated-index sharding strategy is
        used with an index too large to replicate comfortably."""
        if self._warned_replicated_index:
            return
        nbytes = sum(
            int(np.prod(a.shape)) * a.dtype.itemsize
            for a in jax.tree_util.tree_leaves(self.state.index))
        if nbytes > REPLICATED_INDEX_WARN_BYTES:
            import warnings
            warnings.warn(
                f"sample_walks_sharded replicates the full window index "
                f"(~{nbytes / 2**20:.0f} MiB) onto every device of the "
                f"mesh; for windows of this size consider the "
                f"node-partitioned "
                f"repro.distributed.streaming_shard.DistributedStreaming"
                f"Engine (DESIGN.md §12), which shards the window itself.",
                stacklevel=3)
            self._warned_replicated_index = True

    def _finish_sample(self, res, t0: float, path: str = "host") -> float:
        """Shared stats tail of every sample_walks* entry point: sync,
        record wall time + valid-walk fraction, publish into the registry,
        return the elapsed seconds."""
        jax.block_until_ready(res.nodes)
        elapsed = time.perf_counter() - t0
        self.stats.sample_s.append(elapsed)
        lengths = np.asarray(res.lengths)
        frac = float(np.mean(lengths >= 2)) if lengths.size else 0.0
        self.stats.walks_valid.append(frac)
        reg = self.registry
        reg.inc("walks_dispatched_total", int(lengths.size),
                labels={"path": path},
                help="walk slots dispatched, by sampling path")
        reg.inc("walks_emitted_total", int(np.sum(lengths >= 2)),
                labels={"driver": "host"},
                help="walks with at least one hop")
        reg.inc("walk_hops_total",
                int(np.sum(np.maximum(lengths.astype(np.int64) - 1, 0))),
                labels={"source": "replay"}, help="hop cells executed")
        reg.observe("walk_sample_seconds", elapsed, labels={"path": path},
                    help="wall time per sample_walks dispatch")
        return elapsed

    def replay(self, batches: Iterable, wcfg: WalkConfig,
               on_batch: Optional[Callable] = None):
        """Host-loop driver: per-batch dispatch + sync (stage timings)."""
        for bs, bd, bt in batches:
            self.ingest_batch(bs, bd, bt)
            res = self.sample_walks(wcfg)
            if on_batch is not None:
                on_batch(self, res)
        return self.stats

    def replay_device(self, batches: Iterable, wcfg: WalkConfig,
                      return_walks: bool = False):
        """Device-resident driver: one `lax.scan` over all batches, one
        host sync at the end. Returns (ReplayStats on host, wall seconds),
        or (stats, final-batch WalkResult, seconds) with ``return_walks``
        — the reference trajectory the sharded replay
        (DistributedStreamingEngine) is tested bit-identical against.
        """
        stacked = stack_batches(batches, self.batch_capacity)
        self.key, sub = jax.random.split(self.key)
        t0 = time.perf_counter()
        if self.probes:
            self.state, stats, walks, pv = replay_scan_probed(
                self.state, stacked, sub, self.cfg.window.node_capacity,
                wcfg, self.cfg.sampler, self.cfg.scheduler,
                table=self._table)
            # the single sync point — probes ride the same materialization
            jax.block_until_ready((stats, pv))
        else:
            self.state, stats, walks = replay_scan(
                self.state, stacked, sub, self.cfg.window.node_capacity,
                wcfg, self.cfg.sampler, self.cfg.scheduler,
                table=self._table)
            jax.block_until_ready(stats)       # the single sync point
        elapsed = time.perf_counter() - t0
        if self.probes:
            flush_replay_probes(self.registry, pv, driver="device")
            self.registry.observe("replay_seconds", elapsed,
                                  labels={"driver": "device"},
                                  help="wall time per replay_device call")
            self._publish_window_from_replay(stats)
        # NOTE: self.stats is left untouched — StreamStats' lists are
        # parallel per host-loop batch, and this driver has no per-batch
        # host timings to pair with. Everything lives in the return value.
        host_stats = ReplayStats(*(np.asarray(a) for a in stats))
        if return_walks:
            host_walks = WalkResult(nodes=np.asarray(walks.nodes),
                                    times=np.asarray(walks.times),
                                    lengths=np.asarray(walks.lengths),
                                    stats=None)
            return host_stats, host_walks, elapsed
        return host_stats, elapsed
