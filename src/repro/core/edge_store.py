"""Shared edge store (paper §2.3).

One physical edge array, kept **timestamp-sorted** — the timestamp-grouped
view IS the physical layout, so window eviction is a prefix drop and
start-edge selection is a range sample (paper: "Window eviction then reduces
to discarding the prefix of the edge array up to the temporal cutoff").

Static-shape discipline (TPU/XLA): the store is padded to ``edge_capacity``.
Padding edges carry ``ts = TS_PAD`` (int32 max) so every timestamp sort puts
them last, and ``src = node_capacity`` so they land in a virtual trailing
node bucket that no real query ever touches.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

TS_PAD = np.iinfo(np.int32).max


class EdgeBatch(NamedTuple):
    """An incoming (possibly unsorted) batch of temporal edges.

    Fixed-capacity arrays + a count, so ingestion jits once per capacity.
    """

    src: jax.Array      # int32[B_cap]
    dst: jax.Array      # int32[B_cap]
    ts: jax.Array       # int32[B_cap]
    count: jax.Array    # int32 scalar — valid prefix length


class EdgeStore(NamedTuple):
    """Timestamp-sorted shared edge store."""

    src: jax.Array        # int32[E_cap]
    dst: jax.Array        # int32[E_cap]
    ts: jax.Array         # int32[E_cap]  (ascending; TS_PAD beyond num_edges)
    num_edges: jax.Array  # int32 scalar

    @property
    def capacity(self) -> int:
        return self.src.shape[0]


def _pad_host(src, dst, ts, capacity: int):
    """Shared host-side batch padding: zeros for src/dst, TS_PAD for ts.
    Returns (src, dst, ts, n) numpy arrays of length ``capacity``."""
    src = np.asarray(src, np.int32)
    dst = np.asarray(dst, np.int32)
    ts = np.asarray(ts, np.int32)
    n = src.shape[0]
    if n > capacity:
        raise ValueError(f"batch of {n} exceeds capacity {capacity}")
    pad = capacity - n
    return (np.concatenate([src, np.zeros(pad, np.int32)]),
            np.concatenate([dst, np.zeros(pad, np.int32)]),
            np.concatenate([ts, np.full(pad, TS_PAD, np.int32)]),
            n)


def make_batch(src, dst, ts, capacity: int | None = None) -> EdgeBatch:
    """Build an EdgeBatch from host arrays, padding to capacity."""
    n = np.asarray(src).shape[0]
    src, dst, ts, n = _pad_host(src, dst, ts, capacity or max(n, 1))
    return EdgeBatch(
        src=jnp.asarray(src),
        dst=jnp.asarray(dst),
        ts=jnp.asarray(ts),
        count=jnp.asarray(n, jnp.int32),
    )


def stack_batches(batches, capacity: int) -> EdgeBatch:
    """Stack K host batches into one device-resident EdgeBatch of shape
    [K, capacity] (+ count[K]) with a single host->device transfer.

    The result is scan-able: ``jax.lax.scan`` over the leading axis yields
    one per-batch EdgeBatch per step (used by streaming.replay_scan).
    """
    srcs, dsts, tss, counts = [], [], [], []
    for s, d, t in batches:
        s, d, t, n = _pad_host(s, d, t, capacity)
        srcs.append(s)
        dsts.append(d)
        tss.append(t)
        counts.append(n)
    if not srcs:
        raise ValueError("stack_batches needs at least one batch")
    return EdgeBatch(
        src=jnp.asarray(np.stack(srcs)),
        dst=jnp.asarray(np.stack(dsts)),
        ts=jnp.asarray(np.stack(tss)),
        count=jnp.asarray(np.asarray(counts, np.int32)),
    )


def empty_store(edge_capacity: int, node_capacity: int) -> EdgeStore:
    return EdgeStore(
        src=jnp.full((edge_capacity,), node_capacity, jnp.int32),
        dst=jnp.zeros((edge_capacity,), jnp.int32),
        ts=jnp.full((edge_capacity,), TS_PAD, jnp.int32),
        num_edges=jnp.asarray(0, jnp.int32),
    )


def store_from_arrays(src, dst, ts, edge_capacity: int,
                      node_capacity: int) -> EdgeStore:
    """Host-side constructor: sort by timestamp, pad to capacity."""
    src = np.asarray(src, np.int32)
    dst = np.asarray(dst, np.int32)
    ts = np.asarray(ts, np.int32)
    order = np.argsort(ts, kind="stable")
    src, dst, ts = src[order], dst[order], ts[order]
    n = src.shape[0]
    if n > edge_capacity:
        raise ValueError(f"{n} edges exceed capacity {edge_capacity}")
    pad = edge_capacity - n
    return EdgeStore(
        src=jnp.asarray(np.concatenate([src, np.full(pad, node_capacity, np.int32)])),
        dst=jnp.asarray(np.concatenate([dst, np.zeros(pad, np.int32)])),
        ts=jnp.asarray(np.concatenate([ts, np.full(pad, TS_PAD, np.int32)])),
        num_edges=jnp.asarray(n, jnp.int32),
    )


def store_nbytes(store: EdgeStore) -> int:
    """Device bytes held by the store (paper Fig. 11 memory accounting)."""
    return sum(int(np.prod(a.shape)) * a.dtype.itemsize
               for a in (store.src, store.dst, store.ts))
