"""Causality validation (paper §3.10).

Checks, for every emitted walk:
* **hop validity** — each hop (u -> v at time t) corresponds to a real edge
  (u, v, t) of the active window, and timestamps are strictly increasing;
* **walk validity** — all hops of the walk are valid.

The paper uses this metric to show static engines produce 0% valid walks
while Tempest produces 100%. A numpy reference implementation is provided
alongside the jnp one so the validator itself is cross-checked in tests.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.temporal_index import TemporalIndex, ranged_search
from repro.core.walk_engine import NODE_PAD, WalkResult


class ValidityReport(NamedTuple):
    hop_valid_frac: jax.Array
    walk_valid_frac: jax.Array
    num_hops: jax.Array
    num_walks: jax.Array


def _edge_exists(index: TemporalIndex, u, v, t):
    """Membership probe for the exact triple (u, v, t) via the adjacency view.

    The adjacency view is sorted by (src, dst, ts); within node u's region
    we binary-search for dst >= v, then scan the (v, *) run boundaries by a
    second search on ts.
    """
    E = index.edge_capacity
    a = index.node_starts[jnp.clip(u, 0, index.node_capacity)]
    b = index.node_starts[jnp.clip(u, 0, index.node_capacity) + 1]
    lo = ranged_search(index.adj_dst, a, b, v, strict=False)
    hi = ranged_search(index.adj_dst, a, b, v, strict=True)
    adj_ts = index.store.ts[index.adj_order]
    k = ranged_search(adj_ts, lo, hi, t, strict=False)
    k = jnp.clip(k, 0, E - 1)
    return (k < hi) & (adj_ts[k] == t) \
        & (index.adj_dst[jnp.clip(k, 0, E - 1)] == v)


@jax.jit
def validate_walks(index: TemporalIndex, result: WalkResult) -> ValidityReport:
    nodes, times, lengths = result.nodes, result.times, result.lengths
    W, Lp1 = nodes.shape
    pos = jnp.arange(Lp1 - 1)
    u = nodes[:, :-1]
    v = nodes[:, 1:]
    t_prev = times[:, :-1]
    t = times[:, 1:]
    is_hop = (pos[None, :] + 1) < lengths[:, None]

    exists = _edge_exists(index, u, v, t)
    # strictly increasing except the first hop in edges-start mode, where
    # position 0 records the start edge's own timestamp on both endpoints.
    increasing = (t > t_prev) | (pos[None, :] == 0) & (t == t_prev)
    hop_ok = jnp.where(is_hop, exists & increasing, True)

    n_hops = jnp.sum(is_hop)
    hop_valid = jnp.sum(hop_ok & is_hop)
    has_hops = lengths > 1
    walk_ok = jnp.all(hop_ok, axis=1) & has_hops
    n_walks = jnp.sum(has_hops)
    return ValidityReport(
        hop_valid_frac=hop_valid / jnp.maximum(n_hops, 1),
        walk_valid_frac=jnp.sum(walk_ok) / jnp.maximum(n_walks, 1),
        num_hops=n_hops, num_walks=n_walks,
    )


def validate_walks_np(edges: Tuple[np.ndarray, np.ndarray, np.ndarray],
                      nodes: np.ndarray, times: np.ndarray,
                      lengths: np.ndarray) -> Tuple[float, float]:
    """Reference validator over raw (src, dst, ts) arrays (host)."""
    src, dst, ts = edges
    edge_set = set(zip(src.tolist(), dst.tolist(), ts.tolist()))
    hop_total = hop_ok = 0
    walk_total = walk_ok = 0
    for w in range(nodes.shape[0]):
        L = int(lengths[w])
        if L <= 1:
            continue
        walk_total += 1
        ok = True
        for i in range(L - 1):
            hop_total += 1
            u, v, t = int(nodes[w, i]), int(nodes[w, i + 1]), int(times[w, i + 1])
            t_prev = int(times[w, i])
            valid = (u, v, t) in edge_set and (t > t_prev or (i == 0 and t == t_prev))
            hop_ok += valid
            ok &= valid
        walk_ok += ok
    return (hop_ok / max(hop_total, 1), walk_ok / max(walk_total, 1))
