"""Dual-index organization (paper §2.3) — bulk reconstruction, O(m).

Two logical views over the shared edge store, plus one auxiliary view:

* **Timestamp-grouped view** — the physical store itself (timestamp-sorted).
  The paper materializes a per-timestamp-group offset array; because ties are
  contiguous runs of a sorted array, group boundaries are implicit and every
  operation the paper performs on the offset array (bias -> group -> slice)
  is a binary search over the sorted ``ts`` array here. Same asymptotics
  (O(log E) vs O(log G)); zero extra memory. Recorded as an adaptation in
  DESIGN.md §9.

* **Node-and-timestamp-grouped view** — permutation ``ns_order`` sorting
  edges by (src, ts); ``node_starts[v]`` locates node v's edge region
  [a, b) in O(1); a ranged binary search inside [a, b) locates the temporal
  cutoff c so that Γ_t(v) = [c, b). ``ns_ts`` / ``ns_dst`` are gathered
  copies so hop lookups touch contiguous memory (the GPU version reads
  through the permutation; on TPU a materialized gather at build time buys
  sequential HBM access per node region — build is O(m), amortized over K
  walks, paper §2.7).

* **Adjacency view** (addition) — permutation sorting edges by
  (src, dst, ts). Used by (a) temporal node2vec's β(u,w) rejection test
  (the paper needs the same adjacency probe; mechanism unspecified there)
  and (b) the causality validator (paper §3.10).

Weight-based sampling support (paper §2.5 + Table 4 "weight" stage):
per-element weights are accumulated into **global prefix-sum arrays** whose
per-node-segment differences give neighborhood cumulative weights for *any*
hop suffix [c, b):

* exponential: w_i = exp(s · (ts_i − t_ref[src_i])), t_ref = node's max ts
  so exponents ≤ 0 (numerically safe). exp(t_i − t_min) of the paper equals
  this up to a positive factor that cancels in the normalized CDF.
* linear: elem_i = ts_i − t_base[src_i] + 1, t_base = node's min ts. The
  neighborhood weight w_i = ts_i − ts_c + 1 = elem_i − δ with
  δ = ts_c − t_base[v]; cumulative S[k] = (P[k+1] − P[c]) − (k+1−c)·δ is
  O(1) per probe, so inverse-CDF stays a binary search.
"""
from __future__ import annotations

import math

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.edge_store import TS_PAD, EdgeStore


class TemporalIndex(NamedTuple):
    # shared edge store (timestamp-grouped view == physical layout)
    store: EdgeStore
    # ---- node-and-timestamp-grouped view ----
    ns_order: jax.Array      # int32[E] permutation: position -> store index
    ns_src: jax.Array        # int32[E] src gathered through ns_order
    ns_dst: jax.Array        # int32[E]
    ns_ts: jax.Array         # int32[E]
    node_starts: jax.Array   # int32[N+2] region of node v = [ns[v], ns[v+1])
    node_group_counts: jax.Array  # int32[N] distinct-timestamp count (the G axis)
    # weight-sampler prefix arrays (exclusive; length E+1)
    pexp: jax.Array          # float32[E+1]
    plin: jax.Array          # float32[E+1]
    node_tref: jax.Array     # int32[N] max ts per node (exp reference)
    node_tbase: jax.Array    # int32[N] min ts per node (linear reference)
    # store-level prefixes for start-edge selection over the timestamp view
    pexp_store: jax.Array    # float32[E+1]
    plin_store: jax.Array    # float32[E+1]
    # ---- adjacency view (node2vec β probe + validation) ----
    adj_order: jax.Array     # int32[E] permutation sorted by (src, dst, ts)
    adj_dst: jax.Array       # int32[E]

    @property
    def num_edges(self) -> jax.Array:
        return self.store.num_edges

    @property
    def node_capacity(self) -> int:
        return self.node_starts.shape[0] - 2

    @property
    def edge_capacity(self) -> int:
        return self.ns_order.shape[0]


def _build_index_impl(store: EdgeStore, node_capacity: int,
                      bias_scale: float = 1.0) -> TemporalIndex:
    """Bulk dual-index reconstruction (paper §2.6: two sorts + linear passes)."""
    E = store.capacity
    n_valid = store.num_edges
    valid = jnp.arange(E, dtype=jnp.int32) < n_valid

    # ---- sort 1: (src, ts) — the node-and-timestamp-grouped view --------
    # Padding edges have src == node_capacity, ts == TS_PAD -> sort last.
    ns_order = jnp.lexsort((store.ts, store.src)).astype(jnp.int32)
    ns_src = store.src[ns_order]
    ns_dst = store.dst[ns_order]
    ns_ts = store.ts[ns_order]

    # node regions: node_starts[v] = first position with ns_src >= v.
    # one extra bucket (node_capacity) holds the padding edges.
    node_starts = jnp.searchsorted(
        ns_src, jnp.arange(node_capacity + 2, dtype=jnp.int32), side="left"
    ).astype(jnp.int32)

    # G axis: distinct timestamps per node region. A timestamp group starts
    # wherever either the src or the ts changes in the (src, ts)-sorted order.
    prev_src = jnp.concatenate([jnp.full((1,), -1, jnp.int32), ns_src[:-1]])
    prev_ts = jnp.concatenate([jnp.full((1,), -1, jnp.int32), ns_ts[:-1]])
    group_start = (ns_src != prev_src) | (ns_ts != prev_ts)
    node_group_counts = jax.ops.segment_sum(
        (group_start & (ns_src < node_capacity)).astype(jnp.int32),
        jnp.clip(ns_src, 0, node_capacity - 1),
        num_segments=node_capacity,
    ).astype(jnp.int32)

    # per-node ts extrema (references for stable weights)
    big = jnp.int32(TS_PAD)
    ns_ts_masked_min = jnp.where(ns_src < node_capacity, ns_ts, big)
    ns_ts_masked_max = jnp.where(ns_src < node_capacity, ns_ts, -big)
    node_tbase = jax.ops.segment_min(
        ns_ts_masked_min, jnp.clip(ns_src, 0, node_capacity - 1),
        num_segments=node_capacity).astype(jnp.int32)
    node_tref = jax.ops.segment_max(
        ns_ts_masked_max, jnp.clip(ns_src, 0, node_capacity - 1),
        num_segments=node_capacity).astype(jnp.int32)
    node_tbase = jnp.where(node_tbase == big, 0, node_tbase)
    node_tref = jnp.where(node_tref == -big, 0, node_tref)

    # ---- weight prefix arrays (linear passes) ----------------------------
    in_range = ns_src < node_capacity
    dt_exp = (ns_ts - node_tref[jnp.clip(ns_src, 0, node_capacity - 1)]).astype(jnp.float32)
    w_exp = jnp.where(in_range, jnp.exp(bias_scale * dt_exp), 0.0)
    elem_lin = (ns_ts - node_tbase[jnp.clip(ns_src, 0, node_capacity - 1)] + 1).astype(jnp.float32)
    w_lin = jnp.where(in_range, elem_lin, 0.0)
    zero = jnp.zeros((1,), jnp.float32)
    pexp = jnp.concatenate([zero, jnp.cumsum(w_exp)])
    plin = jnp.concatenate([zero, jnp.cumsum(w_lin)])

    # store-level prefixes (start-edge selection over the whole window)
    t_hi = jnp.where(n_valid > 0, store.ts[jnp.maximum(n_valid - 1, 0)], 0)
    t_lo = store.ts[0]
    w_exp_s = jnp.where(valid, jnp.exp(bias_scale * (store.ts - t_hi).astype(jnp.float32)), 0.0)
    w_lin_s = jnp.where(valid, (store.ts - t_lo + 1).astype(jnp.float32), 0.0)
    pexp_store = jnp.concatenate([zero, jnp.cumsum(w_exp_s)])
    plin_store = jnp.concatenate([zero, jnp.cumsum(w_lin_s)])

    # ---- sort 2: (src, dst, ts) — adjacency view -------------------------
    adj_order = jnp.lexsort((store.ts, store.dst, store.src)).astype(jnp.int32)
    adj_dst = store.dst[adj_order]

    return TemporalIndex(
        store=store,
        ns_order=ns_order, ns_src=ns_src, ns_dst=ns_dst, ns_ts=ns_ts,
        node_starts=node_starts, node_group_counts=node_group_counts,
        pexp=pexp, plin=plin,
        node_tref=node_tref, node_tbase=node_tbase,
        pexp_store=pexp_store, plin_store=plin_store,
        adj_order=adj_order, adj_dst=adj_dst,
    )


# ``build_index`` leaves the caller's store valid (tests and static pipelines
# read the raw store after indexing). ``build_index_donated`` donates the
# store buffers for standalone rebuild-in-place callers (init_window; any
# re-index of a store the caller is done with). Inside the already-jitted
# window advance the inner jit's donation annotation is inert — there, buffer
# reuse comes from ``ingest``'s own donate_argnums (DESIGN.md §4).
build_index = partial(jax.jit, static_argnames=("node_capacity",
                                                "bias_scale"))(
    _build_index_impl)
build_index_donated = partial(jax.jit,
                              static_argnames=("node_capacity", "bias_scale"),
                              donate_argnums=(0,))(_build_index_impl)


# ---------------------------------------------------------------------------
# Ranged binary searches (branch-free, fixed trip count — TPU friendly)
# ---------------------------------------------------------------------------


def ranged_search(arr: jax.Array, lo: jax.Array, hi: jax.Array,
                  target: jax.Array, *, strict: bool) -> jax.Array:
    """First index k in [lo, hi) with arr[k] > target (strict) or >= target.

    Vectorized over lo/hi/target (same shape); ``arr`` is 1-D. Returns hi if
    no such k. Fixed ceil(log2(len(arr)))+1 iterations.
    """
    n = arr.shape[0]
    steps = max(1, math.ceil(math.log2(max(n, 2))) + 1)
    lo = lo.astype(jnp.int32)
    hi = hi.astype(jnp.int32)

    def body(_, state):
        lo_, hi_ = state
        mid = (lo_ + hi_) >> 1
        v = arr[jnp.clip(mid, 0, n - 1)]
        pred = (v > target) if strict else (v >= target)
        open_ = lo_ < hi_
        hi2 = jnp.where(pred, mid, hi_)
        lo2 = jnp.where(pred, lo_, mid + 1)
        return (jnp.where(open_, lo2, lo_), jnp.where(open_, hi2, hi_))

    lo_f, _ = jax.lax.fori_loop(0, steps, body, (lo, hi))
    return lo_f


def node_range(index: TemporalIndex, node: jax.Array):
    """[a, b) edge region of ``node`` in the node-ts view — O(1)."""
    v = jnp.clip(node, 0, index.node_capacity)
    return index.node_starts[v], index.node_starts[v + 1]


def temporal_cutoff(index: TemporalIndex, a: jax.Array, b: jax.Array,
                    t: jax.Array) -> jax.Array:
    """c = first position in [a, b) with ns_ts > t, so Γ_t(v) = [c, b)."""
    return ranged_search(index.ns_ts, a, b, t, strict=True)


def adjacency_contains(index: TemporalIndex, u: jax.Array,
                       w: jax.Array) -> jax.Array:
    """Whether edge (u -> w, any ts) exists in the window — O(log E)."""
    a, b = node_range_adj(index, u)
    k = ranged_search(index.adj_dst, a, b, w, strict=False)
    return (k < b) & (index.adj_dst[jnp.clip(k, 0, index.edge_capacity - 1)] == w)


def node_range_adj(index: TemporalIndex, node: jax.Array):
    # adjacency view shares node regions with the ns view (both sort by src
    # first and the sorts are over the same multiset)
    return node_range(index, node)
