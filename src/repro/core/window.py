"""Streaming ingestion and sliding-window management (paper §2.6).

The active window W(t) = {e : t − Δ ≤ t_e ≤ t}. Each incoming batch:

1. is sorted by timestamp (GPU radix sort in the paper; XLA sort here),
2. advances t to max(t, batch max ts),
3. drops batch edges older than t − Δ ("too late", no retraction),
4. evicts the store prefix older than t − Δ (prefix drop — the payoff of the
   timestamp-sorted shared store),
5. merges the two sorted runs and **bulk-rebuilds** the dual index
   (paper: reconstruction over incremental mutation).

Everything is static-shape: the store is capacity-padded; on overflow the
*oldest* edges are dropped (the window semantics make this the only
reasonable degradation) and the event is counted in ``overflow_drops``.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.edge_store import TS_PAD, EdgeBatch, EdgeStore
from repro.core.temporal_index import TemporalIndex, build_index


class WindowState(NamedTuple):
    index: TemporalIndex
    t_now: jax.Array          # int32: max timestamp seen
    window: jax.Array         # int32: Δ
    ingested: jax.Array       # int64-ish running counters (int32 here)
    late_drops: jax.Array
    overflow_drops: jax.Array


def init_window(edge_capacity: int, node_capacity: int, window: int,
                bias_scale: float = 1.0) -> WindowState:
    from repro.core.edge_store import empty_store
    store = empty_store(edge_capacity, node_capacity)
    index = build_index(store, node_capacity, bias_scale)
    z = jnp.asarray(0, jnp.int32)
    return WindowState(index=index, t_now=z,
                       window=jnp.asarray(window, jnp.int32),
                       ingested=z, late_drops=z, overflow_drops=z)


@partial(jax.jit, static_argnames=("node_capacity", "bias_scale"))
def ingest(state: WindowState, batch: EdgeBatch, node_capacity: int,
           bias_scale: float = 1.0) -> WindowState:
    """Advance the window by one batch and rebuild the dual index."""
    store = state.index.store
    E = store.capacity
    B = batch.src.shape[0]

    # (1) sort the batch by timestamp; mark invalid slots with TS_PAD
    bvalid = jnp.arange(B, dtype=jnp.int32) < batch.count
    bts = jnp.where(bvalid, batch.ts, TS_PAD)
    border = jnp.argsort(bts).astype(jnp.int32)
    bsrc = batch.src[border]
    bdst = batch.dst[border]
    bts = bts[border]

    # (2) advance time
    last = jnp.where(batch.count > 0,
                     bts[jnp.clip(batch.count - 1, 0, B - 1)], -TS_PAD)
    t_now = jnp.maximum(state.t_now, last)
    cutoff = t_now - state.window

    # (3) late drops in the batch
    blate = bvalid & (bts < cutoff)
    bkeep = bvalid & ~blate
    late = jnp.sum(blate.astype(jnp.int32))
    # compact kept batch edges to the front (stable sort by drop flag)
    bperm = jnp.argsort(jnp.where(bkeep, 0, 1), stable=True).astype(jnp.int32)
    bsrc, bdst, bts = bsrc[bperm], bdst[bperm], bts[bperm]
    bts = jnp.where(jnp.arange(B) < jnp.sum(bkeep), bts, TS_PAD)
    bn = jnp.sum(bkeep.astype(jnp.int32))

    # (4) evict the store prefix older than the cutoff (prefix drop)
    evict_to = jnp.searchsorted(store.ts, cutoff, side="left").astype(jnp.int32)
    evict_to = jnp.minimum(evict_to, store.num_edges)
    keep_n = store.num_edges - evict_to
    idx = jnp.arange(E, dtype=jnp.int32) + evict_to
    live = jnp.arange(E, dtype=jnp.int32) < keep_n
    ssrc = jnp.where(live, store.src[jnp.clip(idx, 0, E - 1)], node_capacity)
    sdst = jnp.where(live, store.dst[jnp.clip(idx, 0, E - 1)], 0)
    sts = jnp.where(live, store.ts[jnp.clip(idx, 0, E - 1)], TS_PAD)

    # (5) merge two ts-sorted runs: concat + sort (XLA sort is the TPU
    # analog of the paper's radix sort; O((m+b) log) vs O(m+b), recorded
    # as a hardware adaptation).
    msrc = jnp.concatenate([ssrc, bsrc])
    mdst = jnp.concatenate([sdst, bdst])
    mts = jnp.concatenate([sts, bts])
    morder = jnp.argsort(mts).astype(jnp.int32)
    msrc, mdst, mts = msrc[morder], mdst[morder], mts[morder]

    total = keep_n + bn
    overflow = jnp.maximum(total - E, 0)
    # on overflow keep the NEWEST E edges: shift window right by `overflow`
    shift = overflow
    idx2 = jnp.arange(E, dtype=jnp.int32) + shift
    n_after = jnp.minimum(total, E)
    live2 = jnp.arange(E, dtype=jnp.int32) < n_after
    EM = msrc.shape[0]
    new_store = EdgeStore(
        src=jnp.where(live2, msrc[jnp.clip(idx2, 0, EM - 1)], node_capacity),
        dst=jnp.where(live2, mdst[jnp.clip(idx2, 0, EM - 1)], 0),
        ts=jnp.where(live2, mts[jnp.clip(idx2, 0, EM - 1)], TS_PAD),
        num_edges=n_after.astype(jnp.int32),
    )

    index = build_index(new_store, node_capacity, bias_scale)
    return WindowState(
        index=index, t_now=t_now, window=state.window,
        ingested=state.ingested + batch.count,
        late_drops=state.late_drops + late,
        overflow_drops=state.overflow_drops + overflow,
    )
