"""Streaming ingestion and sliding-window management (paper §2.6).

The active window W(t) = {e : t − Δ ≤ t_e ≤ t}. Each incoming batch:

1. is sorted by timestamp (GPU radix sort in the paper; XLA sort here —
   the batch is small, so this is the O(b log b) part),
2. advances t to max(t, batch max ts),
3. drops batch edges older than t − Δ ("too late", no retraction),
4. evicts the store prefix older than t − Δ (prefix drop — the payoff of the
   timestamp-sorted shared store),
5. merges the two **already-sorted runs** into the new store and
   bulk-rebuilds the dual index (paper: reconstruction over incremental
   mutation).

Step (5) is merge-based (DESIGN.md §4): the surviving store suffix and the
sorted batch are two sorted runs, so each element's output position is its
own index plus a ``searchsorted`` rank into the *other* run — O(m·log b +
b·log m) vectorized searches and one scatter, replacing the seed's global
concat+argsort (O((m+b)·log(m+b))). The seed path is kept as
``ingest_sort`` as the equivalence reference; both produce byte-identical
``WindowState``s (tested in tests/test_streaming_merge.py).

The public ``ingest`` donates the incoming ``WindowState`` (``jax.jit``
``donate_argnums``), so the window advances in place: XLA aliases the old
store/index buffers into the new ones instead of reallocating ~10 arrays of
edge capacity per batch. Callers must treat the passed-in state as consumed
(every in-repo caller already reassigns ``state = ingest(state, ...)``).

Everything is static-shape: the store is capacity-padded; on overflow the
*oldest* edges are dropped (the window semantics make this the only
reasonable degradation) and the event is counted in ``overflow_drops``.

The unjitted ``ingest_impl`` body is shard-reusable: the node-partitioned
sliding window (repro/distributed/streaming_shard.py, DESIGN.md §12) runs
it per shard under ``shard_map`` against each shard's slice of the store,
passing the globally agreed ``watermark`` so eviction stays causally
consistent across shards.

The pipeline is factored into store-level stages (``_prepare_runs`` →
``_merge_runs`` → ``_clip_to_capacity``) so the same math can advance a
**bare store without a dual index**: ``TsView`` / ``advance_view`` keep a
replicated timestamp-view of the *global* window — just the (src, dst, ts)
columns, byte-identical to the single-device store — which the sharded
serving layer (DESIGN.md §13) uses as its start directory for global
start-edge draws while the dual indexes stay node-partitioned.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.alias import AliasTables, TableSpec, build_tables, update_tables
from repro.core.edge_store import TS_PAD, EdgeBatch, EdgeStore
from repro.core.temporal_index import TemporalIndex, build_index, build_index_donated


class WindowState(NamedTuple):
    index: TemporalIndex
    t_now: jax.Array          # int32: max timestamp seen
    window: jax.Array         # int32: Δ
    ingested: jax.Array       # int64-ish running counters (int32 here)
    late_drops: jax.Array
    overflow_drops: jax.Array
    # alias/radix bias tables (DESIGN.md §17), carried beside pexp/plin and
    # maintained incrementally by ingest when a TableSpec is passed; None
    # (an empty pytree subtree) when table bias is off.
    tables: Optional[AliasTables] = None


def init_window(edge_capacity: int, node_capacity: int, window: int,
                bias_scale: float = 1.0,
                table: Optional[TableSpec] = None) -> WindowState:
    from repro.core.edge_store import empty_store
    store = empty_store(edge_capacity, node_capacity)
    index = build_index_donated(store, node_capacity, bias_scale)
    tables = build_tables(index, table) if table is not None else None
    # distinct scalar buffers: donation (ingest donate_argnums) rejects a
    # state whose fields alias one another
    def z():
        return jnp.asarray(0, jnp.int32)
    return WindowState(index=index, t_now=z(),
                       window=jnp.asarray(window, jnp.int32),
                       ingested=z(), late_drops=z(), overflow_drops=z(),
                       tables=tables)


# ---------------------------------------------------------------------------
# Shared pipeline stages (steps 1-4): batch sort, time advance, late drop,
# prefix eviction. Both the merge and the reference sort path run these.
# ---------------------------------------------------------------------------


def _prepare_runs(store: EdgeStore, t_prev, window, batch: EdgeBatch,
                  node_capacity: int, watermark=None):
    """Return the two ts-sorted runs to merge plus bookkeeping scalars.

    Run S: the surviving store suffix, compacted to the front of length-E
    arrays (TS_PAD / virtual-node padding beyond ``keep_n``).
    Run B: the kept batch edges, ts-sorted and compacted to the front of
    length-B arrays (TS_PAD padding beyond ``bn``).

    ``watermark`` (optional int32 scalar) is an externally agreed lower
    bound on the new ``t_now``. A node-partitioned window (DESIGN.md §12)
    passes the max batch timestamp *across all shards* here so every shard
    evicts against the same cutoff t − Δ even when the locally received
    batch slice is older than the global maximum — the eviction watermark
    protocol that keeps sharded windows causally consistent.

    Store-level on purpose (no ``WindowState``): the replicated ts-view
    advance (``advance_view``) runs the same stages with no dual index.
    """
    E = store.capacity
    B = batch.src.shape[0]

    # (1) sort the batch by timestamp; mark invalid slots with TS_PAD
    bvalid = jnp.arange(B, dtype=jnp.int32) < batch.count
    bts = jnp.where(bvalid, batch.ts, TS_PAD)
    border = jnp.argsort(bts).astype(jnp.int32)
    bsrc = batch.src[border]
    bdst = batch.dst[border]
    bts = bts[border]

    # (2) advance time
    last = jnp.where(batch.count > 0,
                     bts[jnp.clip(batch.count - 1, 0, B - 1)], -TS_PAD)
    t_now = jnp.maximum(t_prev, last)
    if watermark is not None:
        t_now = jnp.maximum(t_now, watermark)
    cutoff = t_now - window

    # (3) late drops in the batch
    blate = bvalid & (bts < cutoff)
    bkeep = bvalid & ~blate
    late = jnp.sum(blate.astype(jnp.int32))
    # compact kept batch edges to the front (stable sort by drop flag)
    bperm = jnp.argsort(jnp.where(bkeep, 0, 1), stable=True).astype(jnp.int32)
    bsrc, bdst, bts = bsrc[bperm], bdst[bperm], bts[bperm]
    bts = jnp.where(jnp.arange(B) < jnp.sum(bkeep), bts, TS_PAD)
    bn = jnp.sum(bkeep.astype(jnp.int32))

    # (4) evict the store prefix older than the cutoff (prefix drop)
    evict_to = jnp.searchsorted(store.ts, cutoff, side="left").astype(jnp.int32)
    evict_to = jnp.minimum(evict_to, store.num_edges)
    keep_n = store.num_edges - evict_to
    idx = jnp.arange(E, dtype=jnp.int32) + evict_to
    live = jnp.arange(E, dtype=jnp.int32) < keep_n
    ssrc = jnp.where(live, store.src[jnp.clip(idx, 0, E - 1)], node_capacity)
    sdst = jnp.where(live, store.dst[jnp.clip(idx, 0, E - 1)], 0)
    sts = jnp.where(live, store.ts[jnp.clip(idx, 0, E - 1)], TS_PAD)

    # evict_to rides along for the alias-table dirty rule: the sources of
    # the evicted prefix store.src[:evict_to] lose edges this advance
    return ((ssrc, sdst, sts, keep_n), (bsrc, bdst, bts, bn), t_now, late,
            evict_to)


def _clip_to_capacity(merged, keep_n, bn, E: int, node_capacity: int):
    """Overflow-clip the merged run to an E-capacity ts-sorted store."""
    msrc, mdst, mts = merged
    EM = msrc.shape[0]

    total = keep_n + bn
    overflow = jnp.maximum(total - E, 0)
    # on overflow keep the NEWEST E edges: shift window right by `overflow`
    idx2 = jnp.arange(E, dtype=jnp.int32) + overflow
    n_after = jnp.minimum(total, E)
    live2 = jnp.arange(E, dtype=jnp.int32) < n_after
    new_store = EdgeStore(
        src=jnp.where(live2, msrc[jnp.clip(idx2, 0, EM - 1)], node_capacity),
        dst=jnp.where(live2, mdst[jnp.clip(idx2, 0, EM - 1)], 0),
        ts=jnp.where(live2, mts[jnp.clip(idx2, 0, EM - 1)], TS_PAD),
        num_edges=n_after.astype(jnp.int32),
    )
    return new_store, overflow


def _finalize(state: WindowState, merged, keep_n, bn, t_now, late,
              batch_count, node_capacity: int, bias_scale: float):
    """Overflow-clip the merged run to capacity and rebuild the dual index."""
    new_store, overflow = _clip_to_capacity(
        merged, keep_n, bn, state.index.store.capacity, node_capacity)
    index = build_index(new_store, node_capacity, bias_scale)
    return WindowState(
        index=index, t_now=t_now, window=state.window,
        ingested=state.ingested + batch_count,
        late_drops=state.late_drops + late,
        overflow_drops=state.overflow_drops + overflow,
    )


# ---------------------------------------------------------------------------
# Step 5, merge path (default): rank-based two-run merge, O(m+b) data
# movement + O(m log b + b log m) vectorized binary searches. No global sort.
# ---------------------------------------------------------------------------


def _merge_runs(run_s, run_b):
    """Stable two-run merge by rank: an element's output position is its own
    run index plus the count of other-run elements that precede it. Ties
    break store-first (side="left" for store elems, side="right" for batch
    elems), exactly matching a stable argsort over [store ++ batch] — which
    is what the reference path computes — so the two paths are bit-equal.
    """
    ssrc, sdst, sts, _ = run_s
    bsrc, bdst, bts, _ = run_b
    E = sts.shape[0]
    B = bts.shape[0]

    rank_s = jnp.searchsorted(bts, sts, side="left").astype(jnp.int32)
    rank_b = jnp.searchsorted(sts, bts, side="right").astype(jnp.int32)
    pos_s = jnp.arange(E, dtype=jnp.int32) + rank_s
    pos_b = jnp.arange(B, dtype=jnp.int32) + rank_b

    EM = E + B
    msrc = jnp.zeros((EM,), jnp.int32).at[pos_s].set(ssrc).at[pos_b].set(bsrc)
    mdst = jnp.zeros((EM,), jnp.int32).at[pos_s].set(sdst).at[pos_b].set(bdst)
    mts = jnp.full((EM,), TS_PAD, jnp.int32).at[pos_s].set(sts).at[pos_b].set(bts)
    return msrc, mdst, mts


def _dirty_nodes(state: WindowState, run_b, merged, keep_n, bn, evict_to,
                 node_capacity: int) -> jax.Array:
    """bool[N] mask of nodes whose neighborhood region changed this advance.

    Exactly three ways a node's region content can change (the stable
    merge + stable lexsort keep every untouched node's region sequence
    identical, merely shifted): it gained a kept batch edge, it lost an
    edge to prefix eviction, or it lost an edge to the overflow clip of
    the merged run. The alias-table incremental update rebuilds precisely
    these nodes; tests/test_alias.py property-checks the rule against
    from-scratch rebuilds.
    """
    nc = node_capacity
    E = state.index.store.capacity
    dirty = jnp.zeros((nc,), bool)

    bsrc = run_b[0]
    B = bsrc.shape[0]
    bkept = jnp.arange(B, dtype=jnp.int32) < bn
    dirty = dirty.at[jnp.where(bkept, bsrc, nc)].set(True, mode="drop")

    old_src = state.index.store.src
    evicted = jnp.arange(E, dtype=jnp.int32) < evict_to
    dirty = dirty.at[jnp.where(evicted, old_src, nc)].set(True, mode="drop")

    msrc = merged[0]
    EM = msrc.shape[0]
    overflow = jnp.maximum(keep_n + bn - E, 0)
    clipped = jnp.arange(EM, dtype=jnp.int32) < overflow
    dirty = dirty.at[jnp.where(clipped, msrc, nc)].set(True, mode="drop")
    return dirty


def ingest_impl(state: WindowState, batch: EdgeBatch, node_capacity: int,
                bias_scale: float = 1.0, watermark=None,
                table: Optional[TableSpec] = None) -> WindowState:
    """Merge-based window advance (unjitted body; see ``ingest``).

    ``watermark`` is the sharded-window eviction hook (see
    ``_prepare_runs``); single-device callers leave it ``None``.

    ``table`` (static TableSpec) switches on alias-table maintenance:
    only the dirty nodes (see ``_dirty_nodes``) are rebuilt against the
    new index; clean nodes copy their old table content positionally.
    The spec must be passed on *every* ingest of a table-carrying state —
    omitting it drops the tables from the returned state.
    """
    run_s, run_b, t_now, late, evict_to = _prepare_runs(
        state.index.store, state.t_now, state.window, batch, node_capacity,
        watermark=watermark)
    merged = _merge_runs(run_s, run_b)
    new = _finalize(state, merged, run_s[3], run_b[3], t_now, late,
                    batch.count, node_capacity, bias_scale)
    if table is None:
        return new
    if state.tables is None:
        tables = build_tables(new.index, table)
    else:
        dirty = _dirty_nodes(state, run_b, merged, run_s[3], run_b[3],
                             evict_to, node_capacity)
        tables = update_tables(new.index, table,
                               old_starts=state.index.node_starts,
                               old_tables=state.tables, dirty=dirty)
    return new._replace(tables=tables)


def _ingest_sort_impl(state: WindowState, batch: EdgeBatch, node_capacity: int,
                      bias_scale: float = 1.0) -> WindowState:
    """Seed reference path: concat + global stable argsort (O((m+b) log))."""
    run_s, run_b, t_now, late, _ = _prepare_runs(
        state.index.store, state.t_now, state.window, batch, node_capacity)
    ssrc, sdst, sts, keep_n = run_s
    bsrc, bdst, bts, bn = run_b

    msrc = jnp.concatenate([ssrc, bsrc])
    mdst = jnp.concatenate([sdst, bdst])
    mts = jnp.concatenate([sts, bts])
    morder = jnp.argsort(mts).astype(jnp.int32)
    msrc, mdst, mts = msrc[morder], mdst[morder], mts[morder]

    return _finalize(state, (msrc, mdst, mts), keep_n, bn, t_now, late,
                     batch.count, node_capacity, bias_scale)


# Public entry points. ``ingest`` (merge path) donates the old WindowState so
# XLA advances the window without reallocating the edge store + index arrays;
# ``ingest_sort`` is the non-donating seed reference kept for equivalence
# tests and old-vs-new benchmarking.
ingest = partial(jax.jit,
                 static_argnames=("node_capacity", "bias_scale", "table"),
                 donate_argnums=(0,))(ingest_impl)
ingest_merge = ingest
ingest_sort = partial(jax.jit,
                      static_argnames=("node_capacity", "bias_scale"))(
    _ingest_sort_impl)

# Non-donating merge ingest for the serving snapshot double-buffer
# (serve/snapshot.py, DESIGN.md §11): the *old* WindowState must stay
# readable while walk queries run against it and the next window builds
# concurrently, so the input cannot be donated. Same math as ``ingest``,
# byte-identical output; costs one fresh store+index allocation per call.
ingest_nodonate = partial(
    jax.jit, static_argnames=("node_capacity", "bias_scale", "table"))(
    ingest_impl)


# ---------------------------------------------------------------------------
# Replicated timestamp-view: the global window's (src, dst, ts) columns
# without a dual index (sharded serving's start directory, DESIGN.md §13)
# ---------------------------------------------------------------------------


class TsView(NamedTuple):
    """A bare timestamp-sorted store plus the window clock — no dual index.

    Advanced through the exact single-device merge stages, so ``store`` is
    **byte-identical to the single-device window's store** for the same
    batch stream. The sharded serving layer replicates one of these next to
    the node-partitioned window: global start-edge draws (positions in the
    global ts view) resolve locally on every shard, while the ~10-array
    dual indexes — the expensive part — stay sharded. Memory cost is 3
    int32 columns of global edge capacity per replica.
    """

    store: EdgeStore
    t_now: jax.Array          # int32: max timestamp seen
    window: jax.Array         # int32: Δ


def init_view(edge_capacity: int, node_capacity: int, window: int) -> TsView:
    from repro.core.edge_store import empty_store
    return TsView(store=empty_store(edge_capacity, node_capacity),
                  t_now=jnp.asarray(0, jnp.int32),
                  window=jnp.asarray(window, jnp.int32))


def advance_view_impl(view: TsView, batch: EdgeBatch, node_capacity: int,
                      watermark=None) -> TsView:
    """Advance a ts-view by one batch: the window pipeline minus the index
    build. Bit-identical store/t_now trajectory to ``ingest_impl``."""
    run_s, run_b, t_now, _, _ = _prepare_runs(
        view.store, view.t_now, view.window, batch, node_capacity,
        watermark=watermark)
    merged = _merge_runs(run_s, run_b)
    new_store, _ = _clip_to_capacity(merged, run_s[3], run_b[3],
                                     view.store.capacity, node_capacity)
    return TsView(store=new_store, t_now=t_now, window=view.window)


# Non-donating on purpose: the serving snapshot double-buffer keeps the old
# view readable while the next one builds (same reasoning as
# ``ingest_nodonate``).
advance_view = partial(jax.jit, static_argnames=("node_capacity",))(
    advance_view_impl)
