"""Temporal bias sampling (paper §2.5).

Two sampler modes over a neighborhood Γ_t(v) = positions [c, b) of the
node-ts view (or [0, n) of the timestamp view for start-edge selection):

* ``index`` — closed-form constant-time inverse CDFs over the ordinal
  position i ∈ [0, n), exact when timestamp gaps are uniform (paper eqs 1-3):

    uniform      i = ⌊u·n⌋
    linear       weights w_i ∝ (i+1);   CDF(k) = (k+1)(k+2)/2 / (n(n+1)/2)
                 i = ⌊(−1 + sqrt(1 + 4·u·n·(n+1)))/2⌋
    exponential  weights w_i ∝ e^i;     CDF(k) = (e^{k+1}−1)/(e^n−1)
                 exact inverse: i = ⌈log(u·(e^n−1) + 1)⌉ − 1
                 stable form for large n (e^n overflows):
                 log(u·(e^n−1)+1) = n + log(u) + log1p((1−u)·e^{−n}/u·…) ≈ n + log(u)
                 giving the paper's approximation i ≈ ⌊n + ln u − 1⌋… we use
                 the exact form below a threshold and the log-domain
                 asymptotic above it; both clamp into [0, n).

* ``weight`` — exact inverse-transform over cumulative true-timestamp
  weights, served from the prefix arrays built at index time
  (paper Table 4 "weight" stage), O(log n) binary search per hop.

Temporal node2vec (paper §2.5): second-order bias β(u,w) applied by
rejection on the first-order proposal with acceptance β(u,w)/β_max,
β_max = max(1/p, 1, 1/q) — keeping the inner CDF prev-independent so the
second-order picker runs through the same dispatch path.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import SamplerConfig
from repro.core.temporal_index import (
    TemporalIndex,
    adjacency_contains,
    ranged_search,
)

_EXP_EXACT_MAX_N = 80.0   # e^n fits float32 comfortably up to ~88


# ---------------------------------------------------------------------------
# Closed-form index samplers (O(1) per hop)
# ---------------------------------------------------------------------------


def index_uniform(u: jax.Array, n: jax.Array) -> jax.Array:
    nf = n.astype(jnp.float32)
    i = jnp.floor(u * nf).astype(jnp.int32)
    return jnp.clip(i, 0, jnp.maximum(n - 1, 0))


def index_linear(u: jax.Array, n: jax.Array) -> jax.Array:
    """Inverse CDF for w_i ∝ (i+1): smallest k with (k+1)(k+2) ≥ u·n(n+1)·…

    Paper eq. (2). Solve the quadratic in float32; a one-step correction
    fixes boundary rounding so the result is an exact inverse CDF.
    """
    nf = n.astype(jnp.float32)
    i = jnp.floor((-1.0 + jnp.sqrt(1.0 + 4.0 * u * nf * (nf + 1.0))) / 2.0)
    i = i.astype(jnp.int32)
    # correction: the exact condition is (i)(i+1)/2 < u·n(n+1)/2 ≤ (i+1)(i+2)/2
    target = u * nf * (nf + 1.0)
    if_ = i.astype(jnp.float32)
    too_high = if_ * (if_ + 1.0) >= target
    i = jnp.where(too_high, i - 1, i)
    if2 = i.astype(jnp.float32)
    too_low = (if2 + 1.0) * (if2 + 2.0) < target
    i = jnp.where(too_low, i + 1, i)
    return jnp.clip(i, 0, jnp.maximum(n - 1, 0))


def index_exponential(u: jax.Array, n: jax.Array) -> jax.Array:
    """Inverse CDF for w_i ∝ e^i (most-recent position gets highest weight).

    Exact: smallest k with (e^{k+1}−1)/(e^n−1) ≥ u  ⇒  k = ⌈log(u(e^n−1)+1)⌉−1.
    For n above the float32 overflow threshold, e^n−1 → e^n and
    log(u·e^n + 1) → n + log(u) (since u·e^n ≫ 1 for any representable u>0),
    recovering the paper's eq. (3) asymptotic ⌊n + ln u − 1⌋ up to rounding.
    """
    nf = n.astype(jnp.float32)
    u = jnp.clip(u, 1e-30, 1.0)
    exact = jnp.ceil(jnp.log(u * jnp.expm1(nf) + 1.0)) - 1.0
    asymptotic = jnp.ceil(nf + jnp.log(u)) - 1.0
    i = jnp.where(nf <= _EXP_EXACT_MAX_N, exact, asymptotic).astype(jnp.int32)
    return jnp.clip(i, 0, jnp.maximum(n - 1, 0))


_INDEX_SAMPLERS = {
    "uniform": index_uniform,
    "linear": index_linear,
    "exponential": index_exponential,
}


def index_pick(bias: str, u: jax.Array, n: jax.Array) -> jax.Array:
    return _INDEX_SAMPLERS[bias](u, n)


# ---------------------------------------------------------------------------
# Per-lane bias dispatch (serving subsystem, DESIGN.md §11)
#
# The three closed-form inverse CDFs are elementwise in (u, n), so a
# heterogeneous batch dispatches them branchlessly: every lane evaluates
# all three O(1) formulas and a two-level select keeps the one named by its
# int8/int32 bias code. This is the vectorized analog of `lax.switch` —
# identical results, no cross-lane divergence, and each lane's pick is a
# pure function of (bias_code, u, n), which is what makes a coalesced
# mega-batch bit-identical to running each query solo.
# ---------------------------------------------------------------------------

BIAS_UNIFORM = 0
BIAS_LINEAR = 1
BIAS_EXPONENTIAL = 2
BIAS_TABLE = 3        # alias/radix tables (core/alias.py, DESIGN.md §17);
                      # dispatched by walk_engine, not index_pick_lanes

BIAS_CODES = {
    "uniform": BIAS_UNIFORM,
    "linear": BIAS_LINEAR,
    "exponential": BIAS_EXPONENTIAL,
    "table": BIAS_TABLE,
}


def bias_code(bias: str) -> int:
    """Map a bias name to its per-lane dispatch code."""
    try:
        return BIAS_CODES[bias]
    except KeyError:
        raise ValueError(f"unknown bias {bias!r} "
                         f"(expected one of {sorted(BIAS_CODES)})") from None


def index_pick_lanes(code: jax.Array, u: jax.Array, n: jax.Array) -> jax.Array:
    """Per-lane index sampling: ``code[i]`` selects the inverse CDF of lane i."""
    i_uni = index_uniform(u, n)
    i_lin = index_linear(u, n)
    i_exp = index_exponential(u, n)
    return jnp.where(code == BIAS_UNIFORM, i_uni,
                     jnp.where(code == BIAS_LINEAR, i_lin, i_exp))


def pick_in_neighborhood_lanes(index: TemporalIndex, code: jax.Array,
                               c: jax.Array, b: jax.Array,
                               u: jax.Array) -> jax.Array:
    """Per-lane-bias pick of k ∈ [c, b); index-mode closed forms only.

    Valid only when b > c (caller masks empty neighborhoods).
    """
    return c + index_pick_lanes(code, u, b - c)


def pick_start_edges_lanes(index: TemporalIndex, code: jax.Array,
                           u: jax.Array) -> jax.Array:
    """Per-lane-bias start-edge sampling over the timestamp view."""
    n = jnp.broadcast_to(index.num_edges, u.shape).astype(jnp.int32)
    return index_pick_lanes(code, u, n)


# ---------------------------------------------------------------------------
# Weight-based samplers (exact, O(log n) over prefix arrays)
# ---------------------------------------------------------------------------


def weighted_pick_exp(pexp: jax.Array, c: jax.Array, b: jax.Array,
                      u: jax.Array) -> jax.Array:
    """Smallest k in [c, b) with pexp[k+1] − pexp[c] ≥ u·(pexp[b] − pexp[c]).

    Falls back to uniform position when the neighborhood's weight mass
    underflows to zero (all edges far older than the node's newest edge).
    """
    total = pexp[b] - pexp[c]
    r = u * total
    target = pexp[c] + r
    # search over the shifted array pexp[k+1]
    k = _shifted_lower_bound(pexp, c, b, target)
    n = b - c
    fallback = c + index_uniform(u, n)
    k = jnp.where(total > 0, k, fallback)
    return jnp.clip(k, c, jnp.maximum(b - 1, c))


def weighted_pick_linear(plin: jax.Array, ns_ts: jax.Array,
                         node_tbase_at: jax.Array, c: jax.Array,
                         b: jax.Array, u: jax.Array) -> jax.Array:
    """Inverse CDF over w_k = ts_k − ts_c + 1 via the dual-prefix trick.

    S(k) = (plin[k+1] − plin[c]) − (k+1−c)·δ,  δ = ts_c − t_base(v).
    S is strictly increasing (w_k ≥ 1), so binary search applies with each
    probe computed in O(1) from the prefix array.
    """
    E = ns_ts.shape[0]
    ts_c = ns_ts[jnp.clip(c, 0, E - 1)]
    delta = (ts_c - node_tbase_at).astype(jnp.float32)
    total = (plin[b] - plin[c]) - (b - c).astype(jnp.float32) * delta
    r = u * total

    steps = max(1, math.ceil(math.log2(max(E + 1, 2))) + 1)

    def body(_, state):
        lo, hi = state
        mid = (lo + hi) >> 1
        s_mid = (plin[jnp.clip(mid + 1, 0, E)] - plin[c]) \
            - (mid + 1 - c).astype(jnp.float32) * delta
        pred = s_mid >= r
        open_ = lo < hi
        hi2 = jnp.where(pred, mid, hi)
        lo2 = jnp.where(pred, lo, mid + 1)
        return (jnp.where(open_, lo2, lo), jnp.where(open_, hi2, hi))

    k, _ = jax.lax.fori_loop(0, steps, body, (c, b))
    n = b - c
    fallback = c + index_uniform(u, n)
    k = jnp.where(total > 0, k, fallback)
    return jnp.clip(k, c, jnp.maximum(b - 1, c))


def _shifted_lower_bound(prefix: jax.Array, lo: jax.Array, hi: jax.Array,
                         target: jax.Array) -> jax.Array:
    """Smallest k in [lo, hi) with prefix[k+1] >= target."""
    E = prefix.shape[0] - 1
    steps = max(1, math.ceil(math.log2(max(E + 1, 2))) + 1)

    def body(_, state):
        lo_, hi_ = state
        mid = (lo_ + hi_) >> 1
        v = prefix[jnp.clip(mid + 1, 0, E)]
        pred = v >= target
        open_ = lo_ < hi_
        hi2 = jnp.where(pred, mid, hi_)
        lo2 = jnp.where(pred, lo_, mid + 1)
        return (jnp.where(open_, lo2, lo_), jnp.where(open_, hi2, hi_))

    k, _ = jax.lax.fori_loop(0, steps, body, (lo, hi))
    return k


# ---------------------------------------------------------------------------
# Hop-level API
# ---------------------------------------------------------------------------


def pick_in_neighborhood(index: TemporalIndex, cfg: SamplerConfig,
                         c: jax.Array, b: jax.Array, u: jax.Array,
                         node: jax.Array) -> jax.Array:
    """Pick a position k ∈ [c, b) under the configured bias; returns k.

    Valid only when b > c (caller masks empty neighborhoods).
    """
    n = b - c
    if cfg.mode == "index":
        return c + index_pick(cfg.bias, u, n)
    if cfg.mode == "weight":
        if cfg.bias == "uniform":
            return c + index_uniform(u, n)
        if cfg.bias == "exponential":
            return weighted_pick_exp(index.pexp, c, b, u)
        if cfg.bias == "linear":
            nc = index.node_capacity
            tbase = index.node_tbase[jnp.clip(node, 0, nc - 1)]
            return weighted_pick_linear(index.plin, index.ns_ts, tbase, c, b, u)
        raise ValueError(f"unknown bias {cfg.bias!r}")
    raise ValueError(f"unknown sampler mode {cfg.mode!r}")


def pick_start_edges(index: TemporalIndex, cfg: SamplerConfig,
                     u: jax.Array) -> jax.Array:
    """Sample start edges from the timestamp-grouped view (store order)."""
    zero = jnp.zeros_like(u, dtype=jnp.int32)
    b = jnp.broadcast_to(index.num_edges, u.shape).astype(jnp.int32)
    n = b
    if cfg.start_bias == "uniform":
        return index_uniform(u, n)
    if cfg.mode == "index":
        return index_pick(cfg.start_bias, u, n)
    if cfg.start_bias == "exponential":
        return weighted_pick_exp(index.pexp_store, zero, b, u)
    if cfg.start_bias == "linear":
        # store-level linear uses t_base = global min ts => delta = 0
        total = index.plin_store[b]
        r = u * total
        k = _shifted_lower_bound(index.plin_store, zero, b, r)
        return jnp.where(total > 0, k, index_uniform(u, n))
    return index_uniform(u, n)


# ---------------------------------------------------------------------------
# Temporal node2vec (second-order bias via rejection, paper §2.5)
# ---------------------------------------------------------------------------


def node2vec_beta(index: TemporalIndex, prev: jax.Array, cand: jax.Array,
                  p: float, q: float) -> jax.Array:
    """β(u,w): 1/p if w == prev (return), 1 if w adjacent to prev, 1/q else."""
    is_return = cand == prev
    is_common = adjacency_contains(index, prev, cand)
    return jnp.where(is_return, 1.0 / p,
                     jnp.where(is_common, 1.0, 1.0 / q)).astype(jnp.float32)


def node2vec_max_beta(p: float, q: float) -> float:
    return max(1.0 / p, 1.0, 1.0 / q)


def node2vec_beta_lanes(index: TemporalIndex, prev: jax.Array,
                        cand: jax.Array, p: jax.Array,
                        q: jax.Array) -> jax.Array:
    """Per-lane β(u,w): like ``node2vec_beta`` but with array (p, q)."""
    is_return = cand == prev
    is_common = adjacency_contains(index, prev, cand)
    return jnp.where(is_return, 1.0 / p,
                     jnp.where(is_common, 1.0, 1.0 / q)).astype(jnp.float32)


def node2vec_max_beta_lanes(p: jax.Array, q: jax.Array) -> jax.Array:
    return jnp.maximum(jnp.maximum(1.0 / p, 1.0), 1.0 / q).astype(
        jnp.float32)
