"""Unified metrics registry (DESIGN.md §16).

One process-wide home for every counter the system used to scatter across
ad-hoc structs (``StreamStats``, ``ReplayStats``, ``ServeStats``,
``scheduler.dispatch_stats``): named **counters** (monotonic totals),
**gauges** (last-written level) and **histograms** (bounded ring-buffer
reservoirs, see ``Reservoir``) with label support, e.g.::

    reg = get_registry()
    reg.inc("walks_dispatched_total", 2048, labels={"path": "fused"})
    reg.set_gauge("window_edges_active", 53_241)
    reg.observe("serve_latency_seconds", 0.0031)

Naming scheme (validated): ``snake_case`` matching ``[a-z][a-z0-9_]*``;
counters end in ``_total``, time histograms in ``_seconds``. A metric
name owns ONE kind for the registry's lifetime — re-registering it as a
different kind raises, so the exposition formats (obs/export.py) never
see a name flip types.

The registry is host-side and cheap (dict + lock); on-device accounting
stays in the jit-safe probe vectors (obs/probes.py) and is flushed here
only at existing host sync points.

``DropCounters`` is the consolidated drop taxonomy: every place the
system sheds work (serving queue backpressure, oversize queries, sharded
ingest exchange clips, walk-slot overflow, reshard clips, window
late/capacity drops) publishes into the single ``drops_total{kind=...}``
family, and ``DropCounters.from_registry`` reads them back as one view.
"""
from __future__ import annotations

import re
import threading
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

# Shared reservoir bound: the latency/batch histograms (and the
# ``ServeStats`` views on top of them) keep at most this many recent
# observations, so a long-running service neither grows without bound nor
# pays O(history) per percentile read.
RESERVOIR_SIZE = 65536

_NAME_RE = re.compile(r"^[a-z][a-z0-9_]*$")

LabelDict = Optional[Dict[str, object]]
LabelKey = Tuple[Tuple[str, str], ...]


def _check_name(name: str) -> str:
    if not _NAME_RE.match(name or ""):
        raise ValueError(
            f"metric name {name!r} violates the naming scheme "
            f"(snake_case, [a-z][a-z0-9_]*; DESIGN.md §16)")
    return name


def _label_key(labels: LabelDict) -> LabelKey:
    if not labels:
        return ()
    for k in labels:
        _check_name(k)
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class Reservoir:
    """Bounded ring-buffer sample reservoir (the histogram backing store).

    Keeps the most recent ``capacity`` observations in insertion order
    (oldest first once wrapped); ``count``/``total`` are lifetime
    accumulators, unaffected by eviction. Deque-compatible surface
    (``append``/``__len__``/``__iter__``/``__array__``) so it can sit
    behind existing stats fields like ``ServeStats.latencies_s``.

    Percentile contract (tested in tests/test_obs.py):
    * empty reservoir  -> ``nan`` for every q
    * single sample    -> that sample for every q
    * q outside [0, 100] -> ``ValueError``
    """

    __slots__ = ("capacity", "_buf", "_idx", "count", "total")

    def __init__(self, capacity: int = RESERVOIR_SIZE):
        if capacity <= 0:
            raise ValueError(f"reservoir capacity must be > 0 (got {capacity})")
        self.capacity = int(capacity)
        self._buf: List[float] = []
        self._idx = 0
        self.count = 0          # lifetime observations
        self.total = 0.0        # lifetime sum

    def add(self, value: float) -> None:
        v = float(value)
        if len(self._buf) < self.capacity:
            self._buf.append(v)
        else:
            self._buf[self._idx] = v
            self._idx = (self._idx + 1) % self.capacity
        self.count += 1
        self.total += v

    # deque-compatible alias: existing call sites do ``.append(x)``
    append = add

    def values(self) -> List[float]:
        """Retained samples, oldest first."""
        if len(self._buf) < self.capacity:
            return list(self._buf)
        return self._buf[self._idx:] + self._buf[:self._idx]

    def percentile(self, q: float) -> float:
        if not 0.0 <= q <= 100.0:
            raise ValueError(f"percentile q must be in [0, 100] (got {q})")
        if not self._buf:
            return float("nan")
        return float(np.percentile(np.asarray(self._buf, dtype=np.float64), q))

    def __len__(self) -> int:
        return len(self._buf)

    def __iter__(self):
        return iter(self.values())

    def __array__(self, dtype=None, copy=None):
        return np.asarray(self.values(), dtype=dtype or np.float64)

    def __repr__(self) -> str:
        return (f"Reservoir(capacity={self.capacity}, retained={len(self)}, "
                f"count={self.count})")


class Counter:
    """Monotonic counter. ``inc`` rejects negative increments."""

    kind = "counter"
    __slots__ = ("value", "written")

    def __init__(self):
        self.value = 0
        self.written = False

    def inc(self, n: float = 1) -> None:
        if n < 0:
            raise ValueError(f"counter increment must be >= 0 (got {n})")
        self.value += n
        self.written = True


class Gauge:
    """Last-written level (can move both ways)."""

    kind = "gauge"
    __slots__ = ("value", "written")

    def __init__(self):
        self.value = 0.0
        self.written = False

    def set(self, v: float) -> None:
        self.value = v
        self.written = True

    def inc(self, n: float = 1) -> None:
        self.set(self.value + n)

    def dec(self, n: float = 1) -> None:
        self.set(self.value - n)


class Histogram:
    """Reservoir-backed distribution (p50/p99 reads, lifetime count/sum)."""

    kind = "histogram"
    __slots__ = ("reservoir", "written")

    def __init__(self, reservoir_size: int = RESERVOIR_SIZE):
        self.reservoir = Reservoir(reservoir_size)
        self.written = False

    def observe(self, v: float) -> None:
        self.reservoir.add(v)
        self.written = True

    @property
    def count(self) -> int:
        return self.reservoir.count

    @property
    def sum(self) -> float:
        return self.reservoir.total

    def percentile(self, q: float) -> float:
        return self.reservoir.percentile(q)


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class Family:
    """All label-series of one metric name (one kind, one help string)."""

    __slots__ = ("name", "kind", "help", "series")

    def __init__(self, name: str, kind: str, help: str = ""):
        self.name = name
        self.kind = kind
        self.help = help
        self.series: Dict[LabelKey, object] = {}

    @property
    def written(self) -> bool:
        return any(s.written for s in self.series.values())


class MetricsRegistry:
    """Named metric families with label support (thread-safe).

    ``counter``/``gauge``/``histogram`` return the instrument for a
    (name, labels) pair, creating it on first use; ``inc``/``set_gauge``/
    ``observe`` are the one-line conveniences the instrumented call sites
    use. ``families()`` snapshots everything for the exporters.
    """

    def __init__(self):
        self._families: Dict[str, Family] = {}
        self._lock = threading.Lock()

    # -- instrument access -------------------------------------------------

    def _get(self, name: str, kind: str, labels: LabelDict, help: str,
             **kwargs):
        _check_name(name)
        key = _label_key(labels)
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = Family(name, kind, help)
                self._families[name] = fam
            elif fam.kind != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {fam.kind}, "
                    f"requested {kind}")
            elif help and not fam.help:
                fam.help = help
            inst = fam.series.get(key)
            if inst is None:
                inst = _KINDS[kind](**kwargs)
                fam.series[key] = inst
            return inst

    def counter(self, name: str, labels: LabelDict = None,
                help: str = "") -> Counter:
        return self._get(name, "counter", labels, help)

    def gauge(self, name: str, labels: LabelDict = None,
              help: str = "") -> Gauge:
        return self._get(name, "gauge", labels, help)

    def histogram(self, name: str, labels: LabelDict = None, help: str = "",
                  reservoir_size: int = RESERVOIR_SIZE) -> Histogram:
        return self._get(name, "histogram", labels, help,
                         reservoir_size=reservoir_size)

    # -- one-line write conveniences ---------------------------------------

    def inc(self, name: str, n: float = 1, labels: LabelDict = None,
            help: str = "") -> None:
        self.counter(name, labels, help).inc(n)

    def set_gauge(self, name: str, v: float, labels: LabelDict = None,
                  help: str = "") -> None:
        self.gauge(name, labels, help).set(v)

    def observe(self, name: str, v: float, labels: LabelDict = None,
                help: str = "") -> None:
        self.histogram(name, labels, help).observe(v)

    # -- read side ---------------------------------------------------------

    def families(self) -> List[Family]:
        with self._lock:
            return sorted(self._families.values(), key=lambda f: f.name)

    def get_family(self, name: str) -> Optional[Family]:
        with self._lock:
            return self._families.get(name)

    def value(self, name: str, labels: LabelDict = None, default=None):
        """Current value of a counter/gauge series (None when absent)."""
        fam = self.get_family(name)
        if fam is None:
            return default
        inst = fam.series.get(_label_key(labels))
        if inst is None:
            return default
        return inst.value

    def sum_values(self, name: str) -> float:
        """Sum of a counter/gauge family over all label series (0 absent)."""
        fam = self.get_family(name)
        if fam is None:
            return 0
        return sum(s.value for s in fam.series.values())

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._families)

    def written_names(self) -> set:
        """Family names with at least one written (non-default) series."""
        return {f.name for f in self.families() if f.written}

    def reset(self) -> None:
        with self._lock:
            self._families.clear()


# ---------------------------------------------------------------------------
# Default process registry
# ---------------------------------------------------------------------------

_DEFAULT = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-default registry (engines/services fall back to it)."""
    return _DEFAULT


def new_registry() -> MetricsRegistry:
    """A fresh isolated registry (tests, per-tenant sandboxes)."""
    return MetricsRegistry()


# ---------------------------------------------------------------------------
# Consolidated drop taxonomy (ISSUE 8 satellite; DESIGN.md §16)
# ---------------------------------------------------------------------------

# Every loss path in the system, one canonical kind each. Publishers use
# ``count_drop``; the single ``drops_total{kind=...}`` family replaces the
# three incompatible homes drops used to live in (`exchange_drops`,
# `shard_walk_drops`, `dropped_backpressure`).
DROP_KINDS = (
    "queue_backpressure",    # serve: submit queue at capacity
    "oversize",              # serve: query exceeds largest shape bucket
    "deadline_expired",      # serve: queued query evicted past its deadline
    "exchange_clip",         # sharded ingest: all_to_all bucket overflow
    "walk_slot_overflow",    # sharded walks/lanes: slot or bucket overflow
    "reshard_clip",          # live reshard: per-shard capacity clip
    "ingest_late",           # window: edge older than the eviction cutoff
    "window_overflow",       # window: capacity eviction of in-window edges
)

DROPS_METRIC = "drops_total"


def count_drop(registry: MetricsRegistry, kind: str, n: float = 1) -> None:
    """Publish ``n`` drops of ``kind`` into the canonical taxonomy."""
    if kind not in DROP_KINDS:
        raise ValueError(f"unknown drop kind {kind!r}; known: {DROP_KINDS}")
    if n:
        registry.inc(DROPS_METRIC, n, labels={"kind": kind},
                     help="work shed, by canonical drop kind")


@dataclass(frozen=True)
class DropCounters:
    """One read-side view over the whole drop taxonomy."""

    queue_backpressure: int = 0
    oversize: int = 0
    deadline_expired: int = 0
    exchange_clip: int = 0
    walk_slot_overflow: int = 0
    reshard_clip: int = 0
    ingest_late: int = 0
    window_overflow: int = 0

    @classmethod
    def from_registry(cls, registry: MetricsRegistry) -> "DropCounters":
        vals = {}
        for kind in DROP_KINDS:
            vals[kind] = int(registry.value(
                DROPS_METRIC, labels={"kind": kind}, default=0))
        return cls(**vals)

    @property
    def total(self) -> int:
        return sum(getattr(self, k) for k in DROP_KINDS)

    def as_dict(self) -> Dict[str, int]:
        d = {k: getattr(self, k) for k in DROP_KINDS}
        d["total"] = self.total
        return d
