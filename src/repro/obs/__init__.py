"""Unified observability layer (DESIGN.md §16).

Four pieces, one substrate:

* ``registry`` — host-side metrics registry: counters / gauges /
  bounded-reservoir histograms with labels, plus the consolidated
  ``drops_total{kind=...}`` taxonomy (``DropCounters``).
* ``probes`` — jit-safe fixed-slot int32 stat vectors threaded through
  scan carries and ``shard_map`` bodies; flushed to the registry only at
  existing host sync points (zero extra device→host transfers).
* ``tracing`` — ``span(stage)`` context managers around host pipeline
  stages, mirrored into XLA profiles via ``TraceAnnotation``.
* ``export`` — Prometheus text exposition, ``tempest-obs/v1`` JSON
  snapshots, ``tempest-health/v1`` streaming-health dumps, and the
  ``tempest-bench/v1`` schema every ``BENCH_*.json`` artifact shares.
"""
from repro.obs.registry import (  # noqa: F401
    DROP_KINDS,
    DROPS_METRIC,
    RESERVOIR_SIZE,
    Counter,
    DropCounters,
    Family,
    Gauge,
    Histogram,
    MetricsRegistry,
    Reservoir,
    count_drop,
    get_registry,
    new_registry,
)
from repro.obs.probes import (  # noqa: F401
    NUM_REPLAY_PROBES,
    NUM_SERVE_PROBES,
    RP_BATCHES,
    RP_EDGES_INGESTED,
    RP_EXCHANGE_DROPS,
    RP_HOPS,
    RP_LATE_DROPS,
    RP_OVERFLOW_DROPS,
    RP_WALK_DROPS,
    RP_WALKS_EMITTED,
    SP_HOPS,
    SP_LANES_CLAIMED,
    SP_WALK_DROPS,
    flush_replay_probes,
    flush_serve_probes,
    replay_probe_update,
    replay_probe_zeros,
    serve_probe_zeros,
)
from repro.obs.tracing import Span, named_scope, span  # noqa: F401
from repro.obs.export import (  # noqa: F401
    BENCH_SCHEMA,
    HEALTH_SCHEMA,
    OBS_SCHEMA,
    bench_doc,
    dump_health,
    export_json,
    health_snapshot,
    to_prometheus,
    validate_bench,
    validate_health,
    validate_snapshot,
)
