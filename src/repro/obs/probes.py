"""Jit-safe on-device probes: fixed-slot stat vectors (DESIGN.md §16).

The registry (obs/registry.py) is host-side; the replay/serve hot paths
run entirely on device under ``lax.scan`` / ``shard_map`` with exactly
one host sync per call. Probes bridge the two without adding transfers:
a fixed-slot ``int32`` stat vector — the same pattern as the
``STAT_*`` dispatch-stats layout in ``core/scheduler.py``, generalized
to streaming counters — is threaded through the scan carry (one vector
per replay) or assembled in the ``shard_map`` body (one vector per
shard), returned alongside the existing outputs, and **flushed to the
registry only at the call's existing host sync point**. Instrumented
runs are bit-identical to uninstrumented ones (the probe arithmetic
never touches the RNG chain or any walk value) and add zero extra
device→host syncs per batch — both properties are pinned by
tests/test_obs_probes.py.

Slot layouts are append-only: exporters and flushers index by the
``RP_*`` / ``SP_*`` constants, never by position literals.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.obs.registry import MetricsRegistry, count_drop

# ---------------------------------------------------------------------------
# Replay probes: one int32[NUM_REPLAY_PROBES] vector per replay (or per
# shard of a sharded replay), accumulated across the scanned batches.
# ---------------------------------------------------------------------------

RP_BATCHES = 0           # batches replayed
RP_EDGES_INGESTED = 1    # edges delivered into the window (post-exchange)
RP_LATE_DROPS = 2        # edges older than the eviction cutoff
RP_OVERFLOW_DROPS = 3    # capacity evictions of in-window edges
RP_EXCHANGE_DROPS = 4    # sharded only: ingest all_to_all bucket overflow
RP_WALK_DROPS = 5        # sharded only: walk slot/bucket overflow
RP_HOPS = 6              # hop cells executed (this shard's, when sharded)
RP_WALKS_EMITTED = 7     # walks with >= 1 hop (single-device driver)
NUM_REPLAY_PROBES = 8

# Serve probes: one int32[NUM_SERVE_PROBES] vector per shard of a
# ``serve_lanes_sharded`` dispatch.
SP_LANES_CLAIMED = 0     # start lanes claimed by this shard
SP_WALK_DROPS = 1        # start-slot + migration overflow on this shard
SP_HOPS = 2              # hop cells executed by this shard
NUM_SERVE_PROBES = 3


def replay_probe_zeros() -> jnp.ndarray:
    return jnp.zeros((NUM_REPLAY_PROBES,), jnp.int32)


def serve_probe_zeros() -> jnp.ndarray:
    return jnp.zeros((NUM_SERVE_PROBES,), jnp.int32)


def replay_probe_update(vec, *, ingested_delta=None, late_delta=None,
                        overflow_delta=None, exchange_drops=None,
                        walk_drops=None, hops=None, lengths=None):
    """One batch's accumulation into a replay probe vector (device-side).

    All arguments are optional scalars (int32); ``lengths`` is the
    batch's [W] walk-length vector, from which the hop and emitted-walk
    counts derive when the caller doesn't track them separately. Pure
    ``at[].add`` arithmetic — no RNG, no data-dependent control flow —
    so threading it through a scan carry cannot perturb the walk math.
    """
    vec = vec.at[RP_BATCHES].add(1)
    if ingested_delta is not None:
        vec = vec.at[RP_EDGES_INGESTED].add(ingested_delta.astype(jnp.int32))
    if late_delta is not None:
        vec = vec.at[RP_LATE_DROPS].add(late_delta.astype(jnp.int32))
    if overflow_delta is not None:
        vec = vec.at[RP_OVERFLOW_DROPS].add(overflow_delta.astype(jnp.int32))
    if exchange_drops is not None:
        vec = vec.at[RP_EXCHANGE_DROPS].add(exchange_drops.astype(jnp.int32))
    if walk_drops is not None:
        vec = vec.at[RP_WALK_DROPS].add(walk_drops.astype(jnp.int32))
    if hops is not None:
        vec = vec.at[RP_HOPS].add(hops.astype(jnp.int32))
    if lengths is not None:
        if hops is None:
            vec = vec.at[RP_HOPS].add(
                jnp.sum(jnp.maximum(lengths - 1, 0)).astype(jnp.int32))
        vec = vec.at[RP_WALKS_EMITTED].add(
            jnp.sum((lengths >= 2).astype(jnp.int32)))
    return vec


# ---------------------------------------------------------------------------
# Host-side flush (at the caller's existing sync point)
# ---------------------------------------------------------------------------


def _shard_labels(shard: Optional[int], **extra) -> dict:
    labels = dict(extra)
    if shard is not None:
        labels["shard"] = str(shard)
    return labels


def flush_replay_probes(registry: MetricsRegistry, vec, *,
                        driver: str, shard: Optional[int] = None) -> None:
    """Publish one replay probe vector into the registry.

    ``driver`` labels the producing loop ("device" for the single-device
    scan, "sharded" for the node-partitioned one); ``shard`` adds the
    per-shard label for sharded flushes. Drop slots land in the
    consolidated ``drops_total{kind=...}`` taxonomy.
    """
    v = np.asarray(vec, dtype=np.int64)
    if v.shape != (NUM_REPLAY_PROBES,):
        raise ValueError(
            f"replay probe vector must be [{NUM_REPLAY_PROBES}] "
            f"(got shape {v.shape})")
    lab = _shard_labels(shard, driver=driver)
    registry.inc("stream_batches_total", int(v[RP_BATCHES]), labels=lab,
                 help="batches replayed through the streaming drivers")
    registry.inc("stream_edges_ingested_total", int(v[RP_EDGES_INGESTED]),
                 labels=lab, help="edges delivered into the window")
    registry.inc("walk_hops_total", int(v[RP_HOPS]),
                 labels=_shard_labels(shard, source="replay"),
                 help="hop cells executed")
    registry.inc("walks_emitted_total", int(v[RP_WALKS_EMITTED]), labels=lab,
                 help="walks with at least one hop")
    count_drop(registry, "ingest_late", int(v[RP_LATE_DROPS]))
    count_drop(registry, "window_overflow", int(v[RP_OVERFLOW_DROPS]))
    count_drop(registry, "exchange_clip", int(v[RP_EXCHANGE_DROPS]))
    count_drop(registry, "walk_slot_overflow", int(v[RP_WALK_DROPS]))


def flush_serve_probes(registry: MetricsRegistry, vecs) -> None:
    """Publish a [D, NUM_SERVE_PROBES] serve probe matrix (one dispatch)."""
    v = np.asarray(vecs, dtype=np.int64)
    if v.ndim != 2 or v.shape[1] != NUM_SERVE_PROBES:
        raise ValueError(
            f"serve probe matrix must be [D, {NUM_SERVE_PROBES}] "
            f"(got shape {v.shape})")
    for d in range(v.shape[0]):
        if v[d, SP_LANES_CLAIMED]:
            registry.inc("serve_lane_claims_total",
                         int(v[d, SP_LANES_CLAIMED]),
                         labels={"shard": str(d)},
                         help="start lanes claimed per owner shard")
        if v[d, SP_HOPS]:
            registry.inc("walk_hops_total", int(v[d, SP_HOPS]),
                         labels={"source": "serve", "shard": str(d)})
    count_drop(registry, "walk_slot_overflow", int(v[:, SP_WALK_DROPS].sum()))
