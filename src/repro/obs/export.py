"""Exporters: Prometheus text exposition, JSON snapshots, health dumps,
and the shared benchmark schema (DESIGN.md §16).

Three schema-tagged document shapes, each with a hand-rolled validator
(no external jsonschema dependency — the container ships none):

* ``tempest-obs/v1`` (``export_json``/``validate_snapshot``) — the whole
  registry: every family, every label series; histograms export count /
  sum / min / max / p50 / p90 / p99 over their bounded reservoirs.
* ``tempest-health/v1`` (``health_snapshot``/``validate_health``) — the
  live streaming-health view assembled from registry metrics (plus an
  optional engine/service for fresh per-shard loads): ingest progress,
  window occupancy + eviction rate, per-shard load/drift, dispatch-tier
  mix, serve p50/p99, and the consolidated drop taxonomy.
* ``tempest-bench/v1`` (``bench_doc``/``validate_bench``) — one schema
  for every ``BENCH_*.json`` artifact benchmarks/run.py emits: the
  suite's CSV rows (name, us_per_call, derived) plus optional
  suite-specific ``results``.

``to_prometheus`` renders the registry in Prometheus text exposition
format (counters/gauges as-is; histograms as summaries with p50/p99
quantile lines), so a scrape endpoint or a file-based textfile collector
can lift the whole system's telemetry without bespoke glue.
"""
from __future__ import annotations

import json
import math
import time
from typing import Dict, List, Optional

import numpy as np

from repro.obs.registry import (
    DROP_KINDS,
    DropCounters,
    MetricsRegistry,
    get_registry,
)

OBS_SCHEMA = "tempest-obs/v1"
HEALTH_SCHEMA = "tempest-health/v1"
BENCH_SCHEMA = "tempest-bench/v1"

_HIST_QUANTILES = (50.0, 90.0, 99.0)


# ---------------------------------------------------------------------------
# Prometheus text exposition
# ---------------------------------------------------------------------------


def _fmt_value(v) -> str:
    f = float(v)
    if math.isnan(f):
        return "NaN"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _fmt_labels(key) -> str:
    if not key:
        return ""
    parts = []
    for k, v in key:
        esc = str(v).replace("\\", r"\\").replace('"', r'\"').replace(
            "\n", r"\n")
        parts.append(f'{k}="{esc}"')
    return "{" + ",".join(parts) + "}"


def to_prometheus(registry: Optional[MetricsRegistry] = None) -> str:
    """Render the registry in Prometheus text exposition format."""
    reg = registry if registry is not None else get_registry()
    out: List[str] = []
    for fam in reg.families():
        ptype = "summary" if fam.kind == "histogram" else fam.kind
        if fam.help:
            out.append(f"# HELP {fam.name} {fam.help}")
        out.append(f"# TYPE {fam.name} {ptype}")
        for key, inst in sorted(fam.series.items()):
            if fam.kind == "histogram":
                for q in _HIST_QUANTILES:
                    qkey = key + (("quantile", str(q / 100.0)),)
                    out.append(f"{fam.name}{_fmt_labels(qkey)} "
                               f"{_fmt_value(inst.percentile(q))}")
                out.append(f"{fam.name}_count{_fmt_labels(key)} "
                           f"{_fmt_value(inst.count)}")
                out.append(f"{fam.name}_sum{_fmt_labels(key)} "
                           f"{_fmt_value(inst.sum)}")
            else:
                out.append(f"{fam.name}{_fmt_labels(key)} "
                           f"{_fmt_value(inst.value)}")
    return "\n".join(out) + "\n"


# ---------------------------------------------------------------------------
# JSON snapshot of the whole registry
# ---------------------------------------------------------------------------


def export_json(registry: Optional[MetricsRegistry] = None) -> dict:
    """Snapshot every registered metric as one schema-tagged document."""
    reg = registry if registry is not None else get_registry()
    metrics: Dict[str, dict] = {}
    for fam in reg.families():
        series = []
        for key, inst in sorted(fam.series.items()):
            entry: dict = {"labels": dict(key)}
            if fam.kind == "histogram":
                vals = np.asarray(inst.reservoir)
                entry.update(
                    count=int(inst.count),
                    sum=float(inst.sum),
                    min=float(vals.min()) if vals.size else None,
                    max=float(vals.max()) if vals.size else None,
                )
                for q in _HIST_QUANTILES:
                    p = inst.percentile(q)
                    entry[f"p{int(q)}"] = None if math.isnan(p) else float(p)
            else:
                entry["value"] = (int(inst.value)
                                  if float(inst.value) == int(inst.value)
                                  else float(inst.value))
            series.append(entry)
        metrics[fam.name] = {"kind": fam.kind, "help": fam.help,
                             "series": series}
    doc = {"schema": OBS_SCHEMA, "generated_unix_s": time.time(),
           "metrics": metrics}
    validate_snapshot(doc)
    return doc


def _fail(msg: str):
    raise ValueError(f"schema validation failed: {msg}")


def validate_snapshot(doc: dict) -> dict:
    """Validate a ``tempest-obs/v1`` document; returns it on success."""
    if not isinstance(doc, dict):
        _fail("document is not an object")
    if doc.get("schema") != OBS_SCHEMA:
        _fail(f"schema tag {doc.get('schema')!r} != {OBS_SCHEMA!r}")
    if not isinstance(doc.get("generated_unix_s"), (int, float)):
        _fail("generated_unix_s missing or not a number")
    metrics = doc.get("metrics")
    if not isinstance(metrics, dict):
        _fail("metrics missing or not an object")
    for name, fam in metrics.items():
        if not isinstance(fam, dict):
            _fail(f"{name}: family is not an object")
        kind = fam.get("kind")
        if kind not in ("counter", "gauge", "histogram"):
            _fail(f"{name}: unknown kind {kind!r}")
        series = fam.get("series")
        if not isinstance(series, list):
            _fail(f"{name}: series is not a list")
        for entry in series:
            if not isinstance(entry.get("labels"), dict):
                _fail(f"{name}: series entry lacks labels object")
            if kind == "histogram":
                if not isinstance(entry.get("count"), int):
                    _fail(f"{name}: histogram entry lacks integer count")
                if not isinstance(entry.get("sum"), (int, float)):
                    _fail(f"{name}: histogram entry lacks numeric sum")
            elif not isinstance(entry.get("value"), (int, float)):
                _fail(f"{name}: {kind} entry lacks numeric value")
    return doc


# ---------------------------------------------------------------------------
# Streaming-health view
# ---------------------------------------------------------------------------


def _series_by_label(registry, name: str, label: str) -> Dict[str, float]:
    fam = registry.get_family(name)
    out: Dict[str, float] = {}
    if fam is None:
        return out
    for key, inst in fam.series.items():
        labels = dict(key)
        if label in labels:
            out[labels[label]] = out.get(labels[label], 0) + inst.value
    return out


def _hist_summary(registry, name: str) -> dict:
    fam = registry.get_family(name)
    if fam is None or not fam.series:
        return {"count": 0, "p50_s": None, "p99_s": None}
    # merge all label series of the family into one summary view
    count, vals = 0, []
    for inst in fam.series.values():
        count += inst.count
        vals.extend(inst.reservoir.values())
    if not vals:
        return {"count": count, "p50_s": None, "p99_s": None}
    a = np.asarray(vals, dtype=np.float64)
    return {"count": count,
            "p50_s": float(np.percentile(a, 50)),
            "p99_s": float(np.percentile(a, 99))}


def health_snapshot(registry: Optional[MetricsRegistry] = None, *,
                    engine=None, service=None) -> dict:
    """Assemble the live streaming-health document (``tempest-health/v1``).

    Reads the registry only; ``engine`` (a ``DistributedStreamingEngine``
    or anything exposing ``shard_loads()``) refreshes per-shard resident
    loads at snapshot time, and ``service`` (a ``WalkService``) refreshes
    queue depth and latency percentiles from its live stats view.
    """
    reg = registry if registry is not None else get_registry()

    ingested = int(reg.sum_values("stream_edges_ingested_total"))
    late = int(reg.value("drops_total", labels={"kind": "ingest_late"},
                         default=0))
    overflow = int(reg.value("drops_total",
                             labels={"kind": "window_overflow"}, default=0))
    evicted = late + overflow
    ingest = {
        "batches": int(reg.sum_values("stream_batches_total")),
        "edges_ingested": ingested,
        "edges_active": int(reg.value("window_edges_active", default=0)),
        "stage_seconds": _hist_summary(reg, "stage_seconds"),
    }
    window = {
        "occupancy": float(reg.value("window_occupancy", default=0.0)),
        "t_now": int(reg.value("window_t_now", default=0)),
        "eviction_rate": (evicted / ingested) if ingested else 0.0,
    }

    if engine is not None and hasattr(engine, "shard_loads"):
        loads = np.asarray(engine.shard_loads(), dtype=np.int64)
        per_shard = {str(d): int(v) for d, v in enumerate(loads)}
    else:
        per_shard = {k: int(v) for k, v in sorted(
            _series_by_label(reg, "shard_edges_active", "shard").items())}
    if per_shard:
        vals = np.asarray(list(per_shard.values()), dtype=np.float64)
        mean = float(vals.mean())
        drift = float((vals.max() - mean) / mean) if mean else 0.0
    else:
        drift = 0.0
    shards = {"edges_active": per_shard, "load_drift": drift}

    dispatch = {
        "walks_by_path": {k: int(v) for k, v in sorted(_series_by_label(
            reg, "walks_dispatched_total", "path").items())},
        "lane_claims_by_shard": {k: int(v) for k, v in sorted(
            _series_by_label(reg, "serve_lane_claims_total",
                             "shard").items())},
    }

    lat = _hist_summary(reg, "serve_latency_seconds")
    serving = {
        "submitted": int(reg.sum_values("serve_submitted_total")),
        "completed": int(reg.sum_values("serve_completed_total")),
        "batches": int(reg.sum_values("serve_batches_total")),
        "queue_depth": int(reg.value("serve_queue_depth", default=0)),
        "latency": lat,
    }
    if service is not None:
        serving["queue_depth"] = int(service.pending_count)
        if len(service.stats.latencies_s):
            serving["latency"] = {
                "count": service.stats.latencies_s.count,
                "p50_s": service.stats.latency_percentile(50),
                "p99_s": service.stats.latency_percentile(99),
            }

    doc = {
        "schema": HEALTH_SCHEMA,
        "generated_unix_s": time.time(),
        "ingest": ingest,
        "window": window,
        "shards": shards,
        "dispatch": dispatch,
        "serving": serving,
        "drops": DropCounters.from_registry(reg).as_dict(),
    }
    validate_health(doc)
    return doc


def validate_health(doc: dict) -> dict:
    """Validate a ``tempest-health/v1`` document; returns it on success."""
    if not isinstance(doc, dict):
        _fail("document is not an object")
    if doc.get("schema") != HEALTH_SCHEMA:
        _fail(f"schema tag {doc.get('schema')!r} != {HEALTH_SCHEMA!r}")
    for section in ("ingest", "window", "shards", "dispatch", "serving",
                    "drops"):
        if not isinstance(doc.get(section), dict):
            _fail(f"section {section!r} missing or not an object")
    for field in ("batches", "edges_ingested", "edges_active"):
        if not isinstance(doc["ingest"].get(field), int):
            _fail(f"ingest.{field} missing or not an integer")
    for field in ("occupancy", "eviction_rate"):
        if not isinstance(doc["window"].get(field), (int, float)):
            _fail(f"window.{field} missing or not a number")
    if not isinstance(doc["shards"].get("edges_active"), dict):
        _fail("shards.edges_active missing or not an object")
    drops = doc["drops"]
    for kind in DROP_KINDS + ("total",):
        if not isinstance(drops.get(kind), int):
            _fail(f"drops.{kind} missing or not an integer")
    return doc


def dump_health(path: str, registry: Optional[MetricsRegistry] = None, *,
                engine=None, service=None) -> dict:
    """Write a validated health snapshot to ``path``; returns the doc."""
    doc = health_snapshot(registry, engine=engine, service=service)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return doc


# ---------------------------------------------------------------------------
# Benchmark artifact schema (one shape for every BENCH_*.json)
# ---------------------------------------------------------------------------


def bench_doc(suite: str, rows: Optional[List[dict]] = None, *,
              config: Optional[dict] = None,
              results: Optional[dict] = None) -> dict:
    """Build a ``tempest-bench/v1`` document from a suite's emitted rows."""
    doc: dict = {
        "schema": BENCH_SCHEMA,
        "suite": suite,
        "rows": list(rows or []),
    }
    if config is not None:
        doc["config"] = config
    if results is not None:
        doc["results"] = results
    validate_bench(doc)
    return doc


def validate_bench(doc: dict) -> dict:
    """Validate a ``tempest-bench/v1`` document; returns it on success."""
    if not isinstance(doc, dict):
        _fail("document is not an object")
    if doc.get("schema") != BENCH_SCHEMA:
        _fail(f"schema tag {doc.get('schema')!r} != {BENCH_SCHEMA!r}")
    if not isinstance(doc.get("suite"), str) or not doc["suite"]:
        _fail("suite missing or not a non-empty string")
    rows = doc.get("rows")
    if not isinstance(rows, list):
        _fail("rows missing or not a list")
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            _fail(f"rows[{i}] is not an object")
        if not isinstance(row.get("name"), str):
            _fail(f"rows[{i}].name missing or not a string")
        us = row.get("us_per_call")
        if not isinstance(us, (int, float)) or (
                isinstance(us, float) and math.isnan(us)):
            _fail(f"rows[{i}].us_per_call missing or not a finite number")
        if not isinstance(row.get("derived", ""), str):
            _fail(f"rows[{i}].derived is not a string")
    for opt in ("config", "results"):
        if opt in doc and not isinstance(doc[opt], dict):
            _fail(f"{opt} is not an object")
    return doc
