"""Span-based stage tracing (DESIGN.md §16).

``span(stage)`` is a context manager around one host-observable pipeline
stage — ingest merge, snapshot publish, coalesce, dispatch, result
slicing — that records the stage's wall time into the registry
(``stage_seconds{stage=...}`` histogram + ``stage_calls_total`` counter)
and, when the JAX profiler is active, mirrors the span as a
``jax.profiler.TraceAnnotation`` so host stages line up with XLA device
lanes in the trace viewer::

    with span("ingest_merge", registry=reg):
        state = ingest(state, batch, nc)
        jax.block_until_ready(state.index.ns_order)

Spans nest freely (each records its own wall time; no parent/child
bookkeeping — the profiler timeline shows nesting already). For
device-side (traced, inside-jit) scopes use ``named_scope`` — a
re-export of ``jax.named_scope`` — which names the emitted HLO instead.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Iterator, Optional

from repro.obs.registry import MetricsRegistry, get_registry

try:                                    # profiler import is best-effort:
    from jax.profiler import TraceAnnotation as _TraceAnnotation
except ImportError:                     # pragma: no cover - old jaxlib
    _TraceAnnotation = None

try:
    from jax import named_scope         # noqa: F401  (re-export)
except ImportError:                     # pragma: no cover - old jax
    from contextlib import nullcontext

    def named_scope(name):              # type: ignore[misc]
        return nullcontext()

STAGE_METRIC = "stage_seconds"
STAGE_CALLS_METRIC = "stage_calls_total"


class Span:
    """Handle yielded by ``span``; ``elapsed_s`` is set on exit."""

    __slots__ = ("stage", "elapsed_s")

    def __init__(self, stage: str):
        self.stage = stage
        self.elapsed_s: float = 0.0


@contextmanager
def span(stage: str, registry: Optional[MetricsRegistry] = None,
         labels: Optional[dict] = None,
         annotate: bool = True) -> Iterator[Span]:
    """Time one pipeline stage into the registry (and the XLA profile).

    ``labels`` merge into the ``stage_seconds`` series key beside the
    stage name (e.g. ``{"path": "fused"}``); ``annotate=False`` skips the
    profiler pass-through for spans inside profiler-hostile loops.
    The stage time is recorded even when the body raises — a failing
    dispatch still shows up in the stage histogram.
    """
    reg = registry if registry is not None else get_registry()
    handle = Span(stage)
    lab = {"stage": stage}
    if labels:
        lab.update(labels)
    ann = (_TraceAnnotation(f"obs:{stage}")
           if annotate and _TraceAnnotation is not None else None)
    t0 = time.perf_counter()
    try:
        if ann is not None:
            with ann:
                yield handle
        else:
            yield handle
    finally:
        handle.elapsed_s = time.perf_counter() - t0
        reg.observe(STAGE_METRIC, handle.elapsed_s, labels=lab,
                    help="host wall time per pipeline stage")
        reg.inc(STAGE_CALLS_METRIC, 1, labels=lab,
                help="invocations per pipeline stage")
