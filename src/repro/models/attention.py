"""Attention: GQA (optional QKV bias, RoPE/M-RoPE), MLA (DeepSeek-V2),
cross-attention, with three execution regimes:

* ``train/prefill`` — memory-efficient chunked attention (flash-style
  running softmax over KV blocks, scanned over Q blocks) so 32k contexts
  lower without materializing [S, S] scores;
* ``decode`` — one-token query against a functional KV cache
  (dynamic_update_slice); MLA decodes in latent space via the absorb trick
  (the production-grade path — scores against the compressed cache);
* ``windowed decode`` — fixed-size ring cache for sliding-window layers
  (Jamba long-context): memory O(window), not O(seq).
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionConfig
from repro.models.layers import apply_positional, apply_rope, truncated_normal

Q_CHUNK = 1024
KV_CHUNK = 1024


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def init_attention(key, att: AttentionConfig, d_model: int):
    ks = jax.random.split(key, 8)
    s = 1.0 / math.sqrt(d_model)
    if att.kind == "gqa":
        p = {
            "wq": truncated_normal(ks[0], (d_model, att.n_heads, att.head_dim), s),
            "wk": truncated_normal(ks[1], (d_model, att.n_kv_heads, att.head_dim), s),
            "wv": truncated_normal(ks[2], (d_model, att.n_kv_heads, att.head_dim), s),
            "wo": truncated_normal(ks[3], (att.n_heads, att.head_dim, d_model),
                                   1.0 / math.sqrt(att.n_heads * att.head_dim)),
        }
        if att.qkv_bias:
            p["bq"] = jnp.zeros((att.n_heads, att.head_dim), jnp.float32)
            p["bk"] = jnp.zeros((att.n_kv_heads, att.head_dim), jnp.float32)
            p["bv"] = jnp.zeros((att.n_kv_heads, att.head_dim), jnp.float32)
        return p
    if att.kind == "mla":
        qk_dim = att.qk_nope_head_dim + att.qk_rope_head_dim
        p = {
            # query path (optionally low-rank)
            "wq_a": truncated_normal(ks[0], (d_model, att.q_lora_rank), s),
            "q_norm": jnp.ones((att.q_lora_rank,), jnp.float32),
            "wq_b": truncated_normal(
                ks[1], (att.q_lora_rank, att.n_heads, qk_dim),
                1.0 / math.sqrt(att.q_lora_rank)),
            # kv latent path
            "wkv_a": truncated_normal(
                ks[2], (d_model, att.kv_lora_rank + att.qk_rope_head_dim), s),
            "kv_norm": jnp.ones((att.kv_lora_rank,), jnp.float32),
            "wk_b": truncated_normal(
                ks[3], (att.kv_lora_rank, att.n_heads, att.qk_nope_head_dim),
                1.0 / math.sqrt(att.kv_lora_rank)),
            "wv_b": truncated_normal(
                ks[4], (att.kv_lora_rank, att.n_heads, att.v_head_dim),
                1.0 / math.sqrt(att.kv_lora_rank)),
            "wo": truncated_normal(
                ks[5], (att.n_heads, att.v_head_dim, d_model),
                1.0 / math.sqrt(att.n_heads * att.v_head_dim)),
        }
        return p
    raise ValueError(att.kind)


# ---------------------------------------------------------------------------
# Chunked (flash-style) attention — pure JAX reference implementation
# ---------------------------------------------------------------------------


def _chunked_attention(q, k, v, *, causal: bool, q_offset: int = 0,
                       window: int = 0):
    """q: [B, Sq, H, D]; k/v: [B, Skv, Hkv, D(v)]. Running-softmax over KV
    chunks, scanned over Q chunks. GQA expands via head grouping."""
    B, Sq, H, D = q.shape
    _, Skv, Hkv, Dv = v.shape
    rep = H // Hkv
    scale = 1.0 / math.sqrt(D)

    qc = Q_CHUNK if Sq > Q_CHUNK else Sq
    kc = KV_CHUNK if Skv > KV_CHUNK else Skv
    nq = (Sq + qc - 1) // qc
    nk = (Skv + kc - 1) // kc
    # pad to multiples
    q = jnp.pad(q, ((0, 0), (0, nq * qc - Sq), (0, 0), (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, nk * kc - Skv), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, nk * kc - Skv), (0, 0), (0, 0)))

    kq = k.reshape(B, nk, kc, Hkv, D)
    vq = v.reshape(B, nk, kc, Hkv, Dv)
    qq = q.reshape(B, nq, qc, H, D)

    kv_pos = jnp.arange(nk * kc).reshape(nk, kc)
    kv_valid = kv_pos < Skv

    def q_block(carry, qi):
        from repro.distributed.sharding import hint
        qb = hint(qq[:, qi], "batch", None, "model", None)  # [B, qc, H, D]
        q_pos = q_offset + qi * qc + jnp.arange(qc)

        def kv_block(acc, ki):
            m, l, o = acc
            kb = kq[:, ki]                    # [B, kc, Hkv, D]
            vb = vq[:, ki]
            kb_r = jnp.repeat(kb, rep, axis=2)
            vb_r = jnp.repeat(vb, rep, axis=2)
            s = jnp.einsum("bqhd,bkhd->bhqk", qb, kb_r) * scale
            mask = kv_valid[ki][None, None, None, :]
            if causal:
                mask = mask & (kv_pos[ki][None, None, None, :]
                               <= q_pos[None, None, :, None])
            if window:
                mask = mask & (kv_pos[ki][None, None, None, :]
                               > q_pos[None, None, :, None] - window)
            s = jnp.where(mask, s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            o_new = o * corr[..., None] \
                + jnp.einsum("bhqk,bkhd->bhqd", p.astype(vb_r.dtype), vb_r)
            return (m_new, l_new, o_new), None

        m0 = hint(jnp.full((B, H, qc), -1e30, jnp.float32),
                  "batch", "model", None)
        l0 = hint(jnp.zeros((B, H, qc), jnp.float32), "batch", "model", None)
        o0 = hint(jnp.zeros((B, H, qc, Dv), jnp.float32),
                  "batch", "model", None, None)
        (m, l, o), _ = jax.lax.scan(kv_block, (m0, l0, o0),
                                    jnp.arange(nk))
        out = o / jnp.maximum(l, 1e-30)[..., None]
        return carry, out.astype(q.dtype)     # [B, H, qc, Dv]

    _, outs = jax.lax.scan(q_block, None, jnp.arange(nq))
    # outs: [nq, B, H, qc, Dv] -> [B, Sq, H, Dv]
    out = jnp.moveaxis(outs, 0, 1)            # [B, nq, H, qc, Dv]
    out = out.transpose(0, 2, 1, 3, 4)        # [B, H, nq, qc, Dv]
    out = out.reshape(B, H, nq * qc, Dv).transpose(0, 2, 1, 3)
    return out[:, :Sq]


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    k: jax.Array        # [B, S_max, Hkv, D] (ring buffer when windowed)
    v: jax.Array
    pos: jax.Array      # int32 scalar: tokens already written


def gqa_forward(params, att: AttentionConfig, x, positions, *,
                causal: bool = True, window: int = 0,
                kv: Optional[tuple] = None):
    """Full-sequence forward (train / prefill).

    kv: optional externally-provided (k_input, v_input, kv_positions) for
    cross-attention (encoder memory); when given, causal must be False.
    """
    dtype = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dtype))
    src = x if kv is None else kv[0]
    k = jnp.einsum("bsd,dhk->bshk", src, params["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", src if kv is None else kv[1],
                   params["wv"].astype(dtype))
    if att.qkv_bias:
        q = q + params["bq"].astype(dtype)
        k = k + params["bk"].astype(dtype)
        v = v + params["bv"].astype(dtype)
    q = apply_positional(att, q, positions)
    kpos = positions if kv is None else kv[2]
    k = apply_positional(att, k, kpos)
    out = _chunked_attention(q, k, v, causal=causal, window=window)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dtype))


def gqa_init_cache(att: AttentionConfig, batch: int, max_seq: int,
                   dtype) -> KVCache:
    size = att.window if att.window else max_seq
    shape = (batch, size, att.n_kv_heads, att.head_dim)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   pos=jnp.zeros((), jnp.int32))


def gqa_decode(params, att: AttentionConfig, x, cache: KVCache, *,
               window: int = 0):
    """One-token decode: x [B, 1, d]. Returns (out, new_cache)."""
    dtype = x.dtype
    B = x.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dtype))
    if att.qkv_bias:
        q = q + params["bq"].astype(dtype)
        k = k + params["bk"].astype(dtype)
        v = v + params["bv"].astype(dtype)
    pos = cache.pos
    posf = jnp.broadcast_to(pos, (B, 1))
    if att.rope == "mrope":
        pos3 = jnp.broadcast_to(pos, (B, 1, 3))
        q = apply_positional(att, q, pos3)
        k = apply_positional(att, k, pos3)
    else:
        q = apply_positional(att, q, posf)
        k = apply_positional(att, k, posf)

    size = cache.k.shape[1]
    slot = jnp.where(window > 0, pos % size, jnp.minimum(pos, size - 1))
    k_cache = jax.lax.dynamic_update_slice(cache.k, k, (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache.v, v, (0, slot, 0, 0))

    H, Hkv = att.n_heads, att.n_kv_heads
    rep = H // Hkv
    kk = jnp.repeat(k_cache, rep, axis=2)
    vv = jnp.repeat(v_cache, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / math.sqrt(att.head_dim)
    idx = jnp.arange(size)
    if window > 0:
        # ring buffer: every slot written so far is in-window by
        # construction (K entries carry their absolute rotary positions)
        written = jnp.minimum(pos + 1, size)
        valid = idx[None, :] < written
    else:
        valid = idx[None, :] <= pos
    s = jnp.where(valid[None, None, :, :], s, -1e30)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vv)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dtype))
    return y, KVCache(k=k_cache, v=v_cache, pos=pos + 1)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------


class MLACache(NamedTuple):
    c_kv: jax.Array      # [B, S_max, kv_lora] compressed latent
    k_rope: jax.Array    # [B, S_max, qk_rope]
    pos: jax.Array


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (y * scale).astype(x.dtype)


def mla_forward(params, att: AttentionConfig, x, positions, *,
                causal: bool = True):
    """Train / prefill: materialize per-head K/V from the latent (standard),
    then run chunked attention."""
    dtype = x.dtype
    q_lat = _rms(x @ params["wq_a"].astype(dtype), params["q_norm"])
    q = jnp.einsum("bsr,rhk->bshk", q_lat, params["wq_b"].astype(dtype))
    q_nope = q[..., :att.qk_nope_head_dim]
    q_rope = apply_rope(q[..., att.qk_nope_head_dim:], positions,
                        att.rope_theta)

    kv_a = x @ params["wkv_a"].astype(dtype)
    c_kv = _rms(kv_a[..., :att.kv_lora_rank], params["kv_norm"])
    k_rope = apply_rope(kv_a[..., None, att.kv_lora_rank:], positions,
                        att.rope_theta)                       # [B,S,1,rope]

    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["wk_b"].astype(dtype))
    v = jnp.einsum("bsr,rhk->bshk", c_kv, params["wv_b"].astype(dtype))
    k_rope_exp = jnp.broadcast_to(
        k_rope, k_rope.shape[:2] + (att.n_heads, att.qk_rope_head_dim))
    qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
    kfull = jnp.concatenate([k_nope, k_rope_exp], axis=-1)
    out = _chunked_attention(qfull, kfull, v, causal=causal)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dtype))


def mla_init_cache(att: AttentionConfig, batch: int, max_seq: int,
                   dtype) -> MLACache:
    return MLACache(
        c_kv=jnp.zeros((batch, max_seq, att.kv_lora_rank), dtype),
        k_rope=jnp.zeros((batch, max_seq, att.qk_rope_head_dim), dtype),
        pos=jnp.zeros((), jnp.int32))


def mla_decode(params, att: AttentionConfig, x, cache: MLACache):
    """Latent-space decode (absorb trick): the per-head key up-projection is
    folded into the query, so attention scores hit the compressed cache
    directly — O(kv_lora + rope) per cached token instead of O(H·D)."""
    dtype = x.dtype
    B = x.shape[0]
    pos = cache.pos
    posf = jnp.broadcast_to(pos, (B, 1))

    q_lat = _rms(x @ params["wq_a"].astype(dtype), params["q_norm"])
    q = jnp.einsum("bsr,rhk->bshk", q_lat, params["wq_b"].astype(dtype))
    q_nope = q[..., :att.qk_nope_head_dim]
    q_rope = apply_rope(q[..., att.qk_nope_head_dim:], posf, att.rope_theta)
    # absorb W_UK into the query: q_eff [B,1,H,kv_lora]
    q_eff = jnp.einsum("bshk,rhk->bshr", q_nope, params["wk_b"].astype(dtype))

    kv_a = x @ params["wkv_a"].astype(dtype)
    c_new = _rms(kv_a[..., :att.kv_lora_rank], params["kv_norm"])
    k_rope_new = apply_rope(kv_a[..., None, att.kv_lora_rank:], posf,
                            att.rope_theta)[:, :, 0, :]

    c_kv = jax.lax.dynamic_update_slice(cache.c_kv, c_new, (0, pos, 0))
    k_rope = jax.lax.dynamic_update_slice(cache.k_rope, k_rope_new,
                                          (0, pos, 0))

    scale = 1.0 / math.sqrt(att.qk_nope_head_dim + att.qk_rope_head_dim)
    s = (jnp.einsum("bshr,bkr->bshk", q_eff, c_kv)
         + jnp.einsum("bshr,bkr->bshk", q_rope, k_rope)) * scale
    S_max = c_kv.shape[1]
    valid = jnp.arange(S_max)[None, :] <= pos
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(dtype)
    # attend in latent space, then up-project with W_UV
    o_lat = jnp.einsum("bshk,bkr->bshr", p, c_kv)
    out = jnp.einsum("bshr,rhk->bshk", o_lat, params["wv_b"].astype(dtype))
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dtype))
    return y, MLACache(c_kv=c_kv, k_rope=k_rope, pos=pos + 1)
