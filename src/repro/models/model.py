"""Top-level model API for the assigned architectures.

* ``init_params(cfg, key)``             — parameter pytree (fp32 masters)
* ``forward(params, cfg, batch, ...)``  — logits for train/prefill
* ``loss_fn(params, cfg, batch, ...)``  — vocab-chunked cross-entropy + MoE aux
* ``init_decode_state(cfg, B, S, ...)`` — KV/recurrent state pytree
* ``decode_step(params, cfg, tok, st)`` — one-token serve step

Batch dict keys by family:
  dense/moe/hybrid/ssm: tokens [B,S] (+ labels for train)
  vlm:   tokens [B, S-N_PATCHES], patches [B, N_PATCHES, d_model]
  audio (enc_dec): frames [B, ENC_FRAMES, d_model], tokens [B, S]
The modality frontends are stubs per the assignment: ``input_specs()``
provides precomputed frame/patch embeddings.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm
from repro.models.layers import (
    apply_norm,
    embed,
    init_embedding,
    init_norm,
    sinusoidal_positions,
    truncated_normal,
)

N_PATCHES = 1024        # VLM stub: patch tokens prepended to text
ENC_FRAMES = 1536       # audio stub: encoder frame count
VOCAB_CHUNK = 16384     # vocab-chunked cross-entropy block


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    ks = jax.random.split(key, 8)
    segments = tfm.build_segments(cfg)
    p: Dict[str, Any] = {
        "embed": init_embedding(ks[0], cfg.vocab_size, cfg.d_model),
        "final_norm": init_norm(cfg),
        "layers": tfm.init_stack(ks[1], cfg, segments,
                                 cross_attention=cfg.family == "enc_dec"),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = {"table": truncated_normal(
            ks[2], (cfg.vocab_size, cfg.d_model),
            1.0 / math.sqrt(cfg.d_model))}
    if cfg.family == "enc_dec":
        p["enc_layers"] = tfm.init_stack(
            ks[3], cfg, _encoder_segments(cfg), cross_attention=False)
        p["enc_norm"] = init_norm(cfg)
    return p


def _encoder_segments(cfg: ModelConfig):
    return [tfm.Segment(cfg.encoder_layers,
                        (tfm.LayerSpec("attn", "dense"),))]


# ---------------------------------------------------------------------------
# Positions
# ---------------------------------------------------------------------------


def _mrope_positions(B: int, S: int, n_patches: int) -> jax.Array:
    """(t, h, w) positions: patches on a grid at t=0, text sequential."""
    side = max(int(math.sqrt(max(n_patches, 1))), 1)
    i = jnp.arange(n_patches)
    patch_pos = jnp.stack([jnp.zeros_like(i), i // side, i % side], -1)
    # text continues sequentially after the vision block (matches the
    # decode path, whose position counter is the cache write index)
    t = n_patches + jnp.arange(S - n_patches)
    text_pos = jnp.stack([t, t, t], -1)
    pos = jnp.concatenate([patch_pos, text_pos], 0)
    return jnp.broadcast_to(pos[None], (B, S, 3)).astype(jnp.int32)


def _positions(cfg: ModelConfig, B: int, S: int,
               n_patches: int = 0) -> jax.Array:
    if cfg.attention.rope == "mrope":
        return _mrope_positions(B, S, n_patches)
    return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _input_embedding(params, cfg: ModelConfig, batch, dtype):
    """Token / multimodal input embedding. Returns (x [B,S,d], positions)."""
    tokens = batch["tokens"]
    B = tokens.shape[0]
    x = embed(params["embed"], tokens, dtype)
    n_patches = 0
    if cfg.family == "vlm":
        patches = batch["patches"].astype(dtype)
        n_patches = patches.shape[1]
        x = jnp.concatenate([patches, x], axis=1)
    S = x.shape[1]
    pos = _positions(cfg, B, S, n_patches)
    if cfg.attention.rope == "sinusoidal":
        x = x + sinusoidal_positions(pos, cfg.d_model).astype(dtype)
    return x, pos


def _run_encoder(params, cfg: ModelConfig, frames, dtype):
    B, S_enc, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(S_enc, dtype=jnp.int32)[None],
                           (B, S_enc))
    x = frames.astype(dtype) + sinusoidal_positions(pos, cfg.d_model) \
        .astype(dtype)
    x, _, _ = tfm.apply_stack(params["enc_layers"], cfg,
                              _encoder_segments(cfg), x, pos,
                              mode="forward", causal=False)
    return apply_norm(params["enc_norm"], x, cfg.norm), pos


def forward(params, cfg: ModelConfig, batch, *, num_groups: int = 1):
    """Full-sequence forward. Returns (pre-logits x, positions, aux)."""
    from repro.distributed.sharding import hint
    dtype = jnp.dtype(cfg.dtype)
    segments = tfm.build_segments(cfg)
    x, pos = _input_embedding(params, cfg, batch, dtype)
    x = hint(x, "batch", None, None)
    enc_out = enc_pos = None
    if cfg.family == "enc_dec":
        enc_out, enc_pos = _run_encoder(params, cfg, batch["frames"], dtype)
    x, _, aux = tfm.apply_stack(params["layers"], cfg, segments, x, pos,
                                mode="forward", enc_out=enc_out,
                                enc_positions=enc_pos, causal=True,
                                num_groups=num_groups)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return x, pos, aux


def logits_from_hidden(params, cfg: ModelConfig, x):
    table = params["embed"]["table"] if cfg.tie_embeddings \
        else params["unembed"]["table"]
    return x @ table.astype(x.dtype).T


# ---------------------------------------------------------------------------
# Sequence-chunked cross-entropy: never materializes [B, S, V] logits
# ---------------------------------------------------------------------------

SEQ_CHUNK = 256


def cross_entropy_chunked(x, table, targets, *, chunk: int = SEQ_CHUNK):
    """x: [B, S, d]; table: [V, d]; targets: [B, S]. Mean NLL in fp32.

    Scans SEQUENCE chunks: each body materializes only [B, chunk, V]
    logits (rematerialized in backward). Chunking over the unsharded
    sequence axis composes cleanly with SPMD: batch stays on the fsdp
    axes, vocab on the model axis — no giant cross-axis all-reduces
    (the vocab-chunked alternative all-reduced full logit chunks over
    the fsdp axis because the contraction dim was fsdp-sharded).
    """
    from repro.distributed.sharding import hint

    B, S, d = x.shape
    V = table.shape[0]
    chunk = min(chunk, S)
    n_chunks = (S + chunk - 1) // chunk
    Sp = n_chunks * chunk
    x = jnp.pad(x, ((0, 0), (0, Sp - S), (0, 0)))
    targets = jnp.pad(targets, ((0, 0), (0, Sp - S)))
    tab = hint(table, "model", None).astype(x.dtype)

    def body(carry, ci):
        xs = jax.lax.dynamic_slice_in_dim(x, ci * chunk, chunk, 1)
        tg = jax.lax.dynamic_slice_in_dim(targets, ci * chunk, chunk, 1)
        logits = jnp.einsum("bsd,vd->bsv", xs, tab).astype(jnp.float32)
        logits = hint(logits, "batch", None, "model")
        lse = jax.nn.logsumexp(logits, axis=-1)
        tl = jnp.take_along_axis(logits, tg[..., None], 2)[..., 0]
        spos = ci * chunk + jnp.arange(chunk)
        valid = (spos < S)[None, :]
        return carry + jnp.sum(jnp.where(valid, lse - tl, 0.0)), None

    loss_sum, _ = jax.lax.scan(jax.checkpoint(body), jnp.zeros((), jnp.float32),
                               jnp.arange(n_chunks))
    return loss_sum / (B * S)


def loss_fn(params, cfg: ModelConfig, batch, *, num_groups: int = 1):
    x, _, aux = forward(params, cfg, batch, num_groups=num_groups)
    labels = batch["labels"]
    B, S_l = labels.shape
    # vlm: loss only over the text positions (the last S_l of the sequence)
    x_txt = x[:, -S_l:, :]
    table = params["embed"]["table"] if cfg.tie_embeddings \
        else params["unembed"]["table"]
    loss = cross_entropy_chunked(x_txt, table, labels)
    return loss + aux


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def init_decode_state(cfg: ModelConfig, batch: int, max_seq: int,
                      key=None) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.dtype)
    segments = tfm.build_segments(cfg)
    state: Dict[str, Any] = {
        "caches": tfm.init_stack_cache(cfg, segments, batch, max_seq, dtype),
    }
    if cfg.family == "enc_dec":
        # encoder memory computed at prefill; carried as decode state
        state["enc_out"] = jnp.zeros((batch, ENC_FRAMES, cfg.d_model), dtype)
        state["enc_pos"] = jnp.broadcast_to(
            jnp.arange(ENC_FRAMES, dtype=jnp.int32)[None],
            (batch, ENC_FRAMES))
    return state


def decode_step(params, cfg: ModelConfig, tokens, state, *,
                num_groups: int = 1):
    """tokens: [B, 1]. Returns (logits [B, 1, V], new_state)."""
    dtype = jnp.dtype(cfg.dtype)
    segments = tfm.build_segments(cfg)
    x = embed(params["embed"], tokens, dtype)
    pos = None  # decode positions come from per-layer cache.pos
    enc_out = state.get("enc_out")
    enc_pos = state.get("enc_pos")
    if cfg.attention.rope == "sinusoidal":
        # position index lives in the first attn cache; use 0-d broadcast
        p0 = _first_cache_pos(state["caches"])
        x = x + sinusoidal_positions(
            jnp.broadcast_to(p0, tokens.shape), cfg.d_model).astype(dtype)
    x, new_caches, _ = tfm.apply_stack(
        params["layers"], cfg, segments, x, pos, mode="decode",
        caches=state["caches"], enc_out=enc_out, enc_positions=enc_pos,
        causal=True, num_groups=num_groups)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = logits_from_hidden(params, cfg, x)
    new_state = dict(state)
    new_state["caches"] = new_caches
    return logits, new_state


def _first_cache_pos(caches):
    for seg in caches:
        for v in seg.values():
            if hasattr(v, "pos"):
                return v.pos[0] if v.pos.ndim else v.pos
    return jnp.zeros((), jnp.int32)


# ---------------------------------------------------------------------------
# Analytic parameter counts (roofline 6ND)
# ---------------------------------------------------------------------------


def count_params_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    att = cfg.attention
    d = cfg.d_model
    total = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)

    def attn_params() -> int:
        if att.kind == "mla":
            qk = att.qk_nope_head_dim + att.qk_rope_head_dim
            return (d * att.q_lora_rank
                    + att.q_lora_rank * att.n_heads * qk
                    + d * (att.kv_lora_rank + att.qk_rope_head_dim)
                    + att.kv_lora_rank * att.n_heads
                    * (att.qk_nope_head_dim + att.v_head_dim)
                    + att.n_heads * att.v_head_dim * d)
        return (d * att.n_heads * att.head_dim
                + 2 * d * att.n_kv_heads * att.head_dim
                + att.n_heads * att.head_dim * d)

    def mlp_params(ff: int) -> int:
        mult = 3 if cfg.activation in ("swiglu", "geglu") else 2
        return mult * d * ff

    def moe_params(active: bool) -> int:
        m = cfg.moe
        n_e = m.top_k if active else m.num_experts
        n = d * m.num_experts            # router
        n += n_e * 3 * d * m.expert_d_ff
        if m.num_shared_experts:
            n += mlp_params(m.shared_d_ff * m.num_shared_experts)
        if m.dense_residual:
            n += mlp_params(m.dense_residual_d_ff)
        return n

    def ssm_params(kind: str) -> int:
        s = cfg.ssm
        if kind == "mamba":
            di = s.expand * d
            dt_rank = max(1, math.ceil(d / 16))
            return (2 * d * di + s.d_conv * di + di * (dt_rank + 2 * s.d_state)
                    + dt_rank * di + di * s.d_state + 2 * di + di * d)
        if kind == "mlstm":
            di = int(s.proj_factor * d)
            dh = di // s.num_heads
            return (2 * d * di + 3 * di * s.num_heads * dh
                    + 2 * di * s.num_heads + di * d + di)
        if kind == "slstm":
            di = d
            dh = di // s.num_heads
            return (4 * d * di + s.num_heads * dh * 4 * dh
                    + 2 * di * (4 * di // 3) + 5 * di)
        raise ValueError(kind)

    for spec in tfm.layer_specs(cfg):
        if spec.kind == "attn":
            total += attn_params()
            if cfg.family == "enc_dec":
                total += attn_params()     # cross-attention
        else:
            total += ssm_params(spec.kind)
        if spec.ffn == "dense":
            total += mlp_params(cfg.d_ff)
        elif spec.ffn == "moe":
            total += moe_params(active_only)
    if cfg.family == "enc_dec":
        total += cfg.encoder_layers * (attn_params() + mlp_params(cfg.d_ff))
    return int(total)
