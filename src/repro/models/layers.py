"""Shared model layers: norms, rotary embeddings (RoPE / M-RoPE /
sinusoidal), MLPs, embeddings.

Everything is pure functions over param pytrees (nested dicts); params are
created fp32 and cast to the compute dtype at apply time (MaxText-style
mixed precision).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionConfig, ModelConfig


def truncated_normal(key, shape, scale, dtype=jnp.float32):
    return scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(cfg: ModelConfig, dim: Optional[int] = None):
    d = dim or cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}
    if cfg.norm == "nonparametric_ln":      # OLMo: no learned affine
        return {}
    raise ValueError(cfg.norm)


def apply_norm(params, x, kind: str, eps: float = 1e-5):
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    elif kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] \
            + params["bias"]
    elif kind == "nonparametric_ln":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    else:
        raise ValueError(kind)
    return y.astype(dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    half = x.shape[-1] // 2
    freqs = rope_frequencies(x.shape[-1], theta)          # [half]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(angles)[..., None, :]                   # [..., S, 1, half]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections: tuple) -> jax.Array:
    """M-RoPE (Qwen2-VL): positions [..., S, 3] = (t, h, w); the half-dim
    frequency bands are split into ``sections`` (sum == head_dim // 2), each
    rotated by its own position component."""
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_frequencies(x.shape[-1], theta)          # [half]
    # select position component per frequency band
    comp = jnp.concatenate([
        jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)])
    pos_per_band = jnp.take_along_axis(
        positions.astype(jnp.float32),
        jnp.broadcast_to(comp, positions.shape[:-1] + (half,)).astype(jnp.int32),
        axis=-1)                                          # [..., S, half]
    angles = pos_per_band * freqs
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def sinusoidal_positions(positions: jax.Array, d_model: int) -> jax.Array:
    half = d_model // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(angles), jnp.cos(angles)], axis=-1)


def apply_positional(att: AttentionConfig, x: jax.Array,
                     positions: jax.Array) -> jax.Array:
    if att.rope == "rope":
        return apply_rope(x, positions, att.rope_theta)
    if att.rope == "mrope":
        return apply_mrope(x, positions, att.rope_theta, att.mrope_sections)
    return x   # "none" / "sinusoidal" (added at the embedding, not in attn)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, activation: str):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(d_ff)
    if activation in ("swiglu", "geglu"):
        return {
            "w_gate": truncated_normal(k1, (d_model, d_ff), s_in),
            "w_up": truncated_normal(k2, (d_model, d_ff), s_in),
            "w_down": truncated_normal(k3, (d_ff, d_model), s_out),
        }
    return {
        "w_up": truncated_normal(k1, (d_model, d_ff), s_in),
        "w_down": truncated_normal(k2, (d_ff, d_model), s_out),
    }


def apply_mlp(params, x, activation: str):
    dtype = x.dtype
    if activation == "swiglu":
        g = x @ params["w_gate"].astype(dtype)
        u = x @ params["w_up"].astype(dtype)
        h = jax.nn.silu(g) * u
    elif activation == "geglu":
        g = x @ params["w_gate"].astype(dtype)
        u = x @ params["w_up"].astype(dtype)
        h = jax.nn.gelu(g) * u
    elif activation == "gelu":
        h = jax.nn.gelu(x @ params["w_up"].astype(dtype))
    else:
        raise ValueError(activation)
    return h @ params["w_down"].astype(dtype)


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------


def init_embedding(key, vocab: int, d_model: int):
    # 1/sqrt(d) keeps tied-unembedding logits O(1) at init
    return {"table": truncated_normal(key, (vocab, d_model),
                                      1.0 / math.sqrt(d_model))}


def embed(params, tokens, dtype):
    return params["table"].astype(dtype)[tokens]


def unembed(params, x, table=None):
    t = (table if table is not None else params["table"]).astype(x.dtype)
    return x @ t.T
