"""Layer assembly: per-layer specs, segment grouping, scanned stacks.

A ``LayerSpec`` is (kind, ffn) with kind ∈ {attn, mamba, mlstm, slstm} and
ffn ∈ {dense, moe, none}. Consecutive layers are grouped into *segments* of
repeating periods (e.g. Jamba's 8-layer mamba/attn pattern × 4, or
DeepSeek-V2's 1 dense-FFN prefix + 59 MoE layers); each segment's params
are stacked over periods and applied with ``lax.scan`` so the compiled HLO
contains one period body per segment regardless of depth.
"""
from __future__ import annotations

import math
from typing import Any, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import apply_mlp, apply_norm, init_mlp, init_norm


class LayerSpec(NamedTuple):
    kind: str     # attn | mamba | mlstm | slstm
    ffn: str      # dense | moe | none


class Segment(NamedTuple):
    n_periods: int
    period: Tuple[LayerSpec, ...]


def layer_specs(cfg: ModelConfig) -> List[LayerSpec]:
    specs = []
    m = cfg.moe
    for i in range(cfg.num_layers):
        kind = cfg.layer_pattern[i % len(cfg.layer_pattern)]
        if kind in ("mlstm", "slstm") or cfg.d_ff == 0:
            ffn = "none"
        elif m is None:
            ffn = "dense"
        elif i < m.first_dense_layers:
            ffn = "dense"
        elif m.every_k_layers > 1 and (i % m.every_k_layers) != m.every_k_layers - 1:
            ffn = "dense"
        else:
            ffn = "moe"
        specs.append(LayerSpec(kind, ffn))
    return specs


def build_segments(cfg: ModelConfig) -> List[Segment]:
    specs = layer_specs(cfg)
    segments: List[Segment] = []
    prefix = cfg.moe.first_dense_layers if cfg.moe else 0
    if prefix:
        segments.append(Segment(1, tuple(specs[:prefix])))
        specs = specs[prefix:]
    if not specs:
        return segments
    period_len = len(cfg.layer_pattern)
    if cfg.moe and cfg.moe.every_k_layers > 1:
        period_len = math.lcm(period_len, cfg.moe.every_k_layers)
    if len(specs) % period_len:
        period_len = len(specs)
    segments.append(Segment(len(specs) // period_len,
                            tuple(specs[:period_len])))
    return segments


# ---------------------------------------------------------------------------
# Per-layer init / apply
# ---------------------------------------------------------------------------


def init_layer(key, cfg: ModelConfig, spec: LayerSpec,
               cross_attention: bool = False):
    ks = jax.random.split(key, 6)
    p: dict = {"norm1": init_norm(cfg)}
    if spec.kind == "attn":
        p["attn"] = attn_mod.init_attention(ks[0], cfg.attention, cfg.d_model)
        if cross_attention:
            p["norm_x"] = init_norm(cfg)
            p["cross"] = attn_mod.init_attention(ks[1], cfg.attention,
                                                 cfg.d_model)
    elif spec.kind == "mamba":
        p["mamba"] = ssm_mod.init_mamba(ks[0], cfg, cfg.ssm)
    elif spec.kind == "mlstm":
        p["mlstm"] = ssm_mod.init_mlstm(ks[0], cfg, cfg.ssm)
    elif spec.kind == "slstm":
        p["slstm"] = ssm_mod.init_slstm(ks[0], cfg, cfg.ssm)
    else:
        raise ValueError(spec.kind)
    if spec.ffn == "dense":
        p["norm2"] = init_norm(cfg)
        d_ff = cfg.d_ff
        p["mlp"] = init_mlp(ks[2], cfg.d_model, d_ff, cfg.activation)
    elif spec.ffn == "moe":
        p["norm2"] = init_norm(cfg)
        p["moe"] = moe_mod.init_moe(ks[2], cfg, cfg.moe)
    return p


def init_layer_cache(cfg: ModelConfig, spec: LayerSpec, batch: int,
                     max_seq: int, dtype):
    """Decode-state slot for one layer (None-free so pytrees stack)."""
    att = cfg.attention
    if spec.kind == "attn":
        if att.kind == "mla":
            return attn_mod.mla_init_cache(att, batch, max_seq, dtype)
        return attn_mod.gqa_init_cache(att, batch, max_seq, dtype)
    if spec.kind == "mamba":
        return ssm_mod.mamba_init_state(cfg, cfg.ssm, batch, dtype)
    if spec.kind == "mlstm":
        return ssm_mod.mlstm_init_state(cfg, cfg.ssm, batch, dtype)
    if spec.kind == "slstm":
        return ssm_mod.slstm_init_state(cfg, cfg.ssm, batch, dtype)
    raise ValueError(spec.kind)


def apply_layer(params, cfg: ModelConfig, spec: LayerSpec, x, positions, *,
                mode: str, cache=None, enc_out=None, enc_positions=None,
                causal: bool = True, num_groups: int = 1):
    """Returns (x, new_cache, aux_loss)."""
    att = cfg.attention
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(params["norm1"], x, cfg.norm)
    new_cache = cache

    if spec.kind == "attn":
        if mode == "decode":
            if att.kind == "mla":
                y, new_cache = attn_mod.mla_decode(params["attn"], att, h,
                                                   cache)
            else:
                y, new_cache = attn_mod.gqa_decode(params["attn"], att, h,
                                                   cache, window=att.window)
        else:
            if att.kind == "mla":
                y = attn_mod.mla_forward(params["attn"], att, h, positions,
                                         causal=causal)
            else:
                y = attn_mod.gqa_forward(params["attn"], att, h, positions,
                                         causal=causal, window=att.window)
    elif spec.kind == "mamba":
        y, new_cache = ssm_mod.mamba_forward(params["mamba"], cfg, cfg.ssm,
                                             h, cache)
    elif spec.kind == "mlstm":
        fwd = ssm_mod.mlstm_forward_chunked \
            if (mode != "decode" and cfg.ssm.chunked) \
            else ssm_mod.mlstm_forward
        y, new_cache = fwd(params["mlstm"], cfg, cfg.ssm, h, cache)
    elif spec.kind == "slstm":
        y, new_cache = ssm_mod.slstm_forward(params["slstm"], cfg, cfg.ssm,
                                             h, cache)
    else:
        raise ValueError(spec.kind)
    x = x + y

    if "cross" in params and enc_out is not None:
        hx = apply_norm(params["norm_x"], x, cfg.norm)
        y = attn_mod.gqa_forward(params["cross"], att, hx, positions,
                                 causal=False,
                                 kv=(enc_out, enc_out, enc_positions))
        x = x + y

    if spec.ffn == "dense":
        h2 = apply_norm(params["norm2"], x, cfg.norm)
        x = x + apply_mlp(params["mlp"], h2, cfg.activation)
    elif spec.ffn == "moe":
        h2 = apply_norm(params["norm2"], x, cfg.norm)
        y, aux = moe_mod.apply_moe(params["moe"], h2, cfg, cfg.moe,
                                   num_groups=num_groups)
        x = x + y
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Segment stacks (scan over periods)
# ---------------------------------------------------------------------------


def init_stack(key, cfg: ModelConfig, segments: List[Segment],
               cross_attention: bool = False):
    stacks = []
    for si, seg in enumerate(segments):
        kseg = jax.random.fold_in(key, si)

        def one_period(k):
            return {f"pos{j}": init_layer(jax.random.fold_in(k, j), cfg,
                                          spec, cross_attention)
                    for j, spec in enumerate(seg.period)}

        keys = jax.random.split(kseg, seg.n_periods)
        stacks.append(jax.vmap(one_period)(keys))
    return stacks


def init_stack_cache(cfg: ModelConfig, segments: List[Segment], batch: int,
                     max_seq: int, dtype):
    caches = []
    for seg in segments:
        one = {f"pos{j}": init_layer_cache(cfg, spec, batch, max_seq, dtype)
               for j, spec in enumerate(seg.period)}
        caches.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a, (seg.n_periods,) + a.shape).copy()
            if seg.n_periods > 1 else a[None], one))
    return caches


def apply_stack(stacks, cfg: ModelConfig, segments: List[Segment], x,
                positions, *, mode: str, caches=None, enc_out=None,
                enc_positions=None, causal: bool = True,
                num_groups: int = 1):
    """Returns (x, new_caches, total_aux)."""
    total_aux = jnp.zeros((), jnp.float32)
    new_caches = []
    for si, seg in enumerate(segments):
        stack = stacks[si]
        cache = caches[si] if caches is not None else None

        def run_period(xc, auxc, pparams, pcache, seg=seg):
            # pin the scan carry's layout: XLA SPMD does not reliably
            # propagate shardings into while bodies and silently
            # replicates the carry otherwise (16x flops per chip).
            from repro.distributed.sharding import hint
            xc = hint(xc, "batch", None, None)
            new_pcache = {}
            for j, spec in enumerate(seg.period):
                c_j = pcache[f"pos{j}"] if pcache is not None else None
                xc, nc, a = apply_layer(
                    pparams[f"pos{j}"], cfg, spec, xc, positions, mode=mode,
                    cache=c_j, enc_out=enc_out, enc_positions=enc_positions,
                    causal=causal, num_groups=num_groups)
                new_pcache[f"pos{j}"] = nc
                auxc = auxc + a
            return xc, auxc, new_pcache

        if cache is None:
            def body(carry, pparams):
                xc, auxc, _ = run_period(carry[0], carry[1], pparams, None)
                return (xc, auxc), None
        else:
            def body(carry, xs):
                pparams, pcache = xs
                xc, auxc, npc = run_period(carry[0], carry[1], pparams,
                                           pcache)
                return (xc, auxc), npc

        if cfg.remat == "block":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        xs = stack if cache is None else (stack, cache)
        (x, total_aux), cache_out = jax.lax.scan(body, (x, total_aux), xs)
        new_caches.append(cache_out)
    return x, new_caches, total_aux
