"""State-space / recurrent blocks: Mamba (selective SSM), xLSTM's mLSTM
(matrix memory) and sLSTM (scalar memory with exponential gating).

All three expose the same triple of entry points:
  * ``*_forward``  — full sequence (train / prefill), lax.scan over time
    (state stays O(d·N), nothing [B,S,d,N]-sized is materialized);
  * ``*_init_state`` — decode state;
  * ``*_decode``   — one-token step carrying the state.

The sequential scan keeps HLO small and memory bounded; the chunked
parallel (SSD-style) form is a recorded §Perf candidate, not a baseline
requirement.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.models.layers import apply_norm, truncated_normal


# ---------------------------------------------------------------------------
# Mamba
# ---------------------------------------------------------------------------


class MambaState(NamedTuple):
    h: jax.Array          # [B, d_inner, N]
    conv: jax.Array       # [B, d_conv-1, d_inner] trailing inputs


def _dinner(cfg: ModelConfig, s: SSMConfig) -> int:
    return s.expand * cfg.d_model


def init_mamba(key, cfg: ModelConfig, s: SSMConfig):
    d = cfg.d_model
    di = _dinner(cfg, s)
    N = s.d_state
    dt_rank = max(1, math.ceil(d / 16))
    ks = jax.random.split(key, 8)
    sc = 1.0 / math.sqrt(d)
    return {
        "w_in": truncated_normal(ks[0], (d, 2 * di), sc),
        "conv_w": truncated_normal(ks[1], (s.d_conv, di), 1.0 / math.sqrt(s.d_conv)),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "w_x": truncated_normal(ks[2], (di, dt_rank + 2 * N), 1.0 / math.sqrt(di)),
        "w_dt": truncated_normal(ks[3], (dt_rank, di), 1.0 / math.sqrt(dt_rank)),
        "dt_bias": jnp.log(jnp.exp(
            jnp.linspace(1e-3, 1e-1, di)) - 1.0).astype(jnp.float32),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, N + 1, dtype=jnp.float32), (di, N))),
        "D": jnp.ones((di,), jnp.float32),
        "w_out": truncated_normal(ks[4], (di, d), 1.0 / math.sqrt(di)),
    }


def _mamba_scan(params, xz, s: SSMConfig, h0, conv0):
    """xz: [B, S, 2*di]. Returns (y [B,S,di->d projected outside], state)."""
    B, S, _ = xz.shape
    di = xz.shape[-1] // 2
    N = s.d_state
    dtype = xz.dtype
    x_part, z = jnp.split(xz, 2, axis=-1)

    # causal depthwise conv over time (width d_conv)
    conv_w = params["conv_w"].astype(dtype)                 # [K, di]
    K = conv_w.shape[0]
    x_hist = jnp.concatenate([conv0.astype(dtype), x_part], axis=1)
    x_conv = sum(x_hist[:, i:i + S] * conv_w[i] for i in range(K))
    x_conv = jax.nn.silu(x_conv + params["conv_b"].astype(dtype))
    new_conv = x_hist[:, S:]                                # trailing K-1

    proj = jnp.einsum("bsi,ip->bsp", x_conv, params["w_x"].astype(dtype))
    dt_rank = params["w_dt"].shape[0]
    dt_in, Bmat, Cmat = jnp.split(proj, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt_in, params["w_dt"].astype(dtype))
        + params["dt_bias"].astype(dtype))                  # [B,S,di]
    A = -jnp.exp(params["A_log"]).astype(jnp.float32)       # [di,N]

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp                           # [B,di],[B,di],[B,N],[B,N]
        dA = jnp.exp(dt_t[..., None].astype(jnp.float32) * A)   # [B,di,N]
        dBx = (dt_t * x_t)[..., None].astype(jnp.float32) \
            * b_t[:, None, :].astype(jnp.float32)
        h = h * dA + dBx
        y = jnp.einsum("bin,bn->bi", h, c_t.astype(jnp.float32))
        return h, y.astype(dtype)

    xs = (jnp.moveaxis(x_conv, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bmat, 1, 0), jnp.moveaxis(Cmat, 1, 0))
    h, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + x_conv * params["D"].astype(dtype)
    y = y * jax.nn.silu(z)
    return y, MambaState(h=h, conv=new_conv)


def mamba_init_state(cfg: ModelConfig, s: SSMConfig, batch: int,
                     dtype) -> MambaState:
    di = _dinner(cfg, s)
    return MambaState(h=jnp.zeros((batch, di, s.d_state), jnp.float32),
                      conv=jnp.zeros((batch, s.d_conv - 1, di), dtype))


def mamba_forward(params, cfg: ModelConfig, s: SSMConfig, x,
                  state: MambaState | None = None):
    """x: [B, S, d] -> (y [B, S, d], state)."""
    B = x.shape[0]
    dtype = x.dtype
    if state is None:
        state = mamba_init_state(cfg, s, B, dtype)
    xz = x @ params["w_in"].astype(dtype)
    y, st = _mamba_scan(params, xz, s, state.h, state.conv)
    return y @ params["w_out"].astype(dtype), st


def mamba_decode(params, cfg: ModelConfig, s: SSMConfig, x,
                 state: MambaState):
    return mamba_forward(params, cfg, s, x, state)


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix memory)
# ---------------------------------------------------------------------------


class MLSTMState(NamedTuple):
    C: jax.Array          # [B, H, dk, dv]
    n: jax.Array          # [B, H, dk]
    m: jax.Array          # [B, H] log-domain gate normalizer


def init_mlstm(key, cfg: ModelConfig, s: SSMConfig):
    d = cfg.d_model
    di = int(s.proj_factor * d)
    H = s.num_heads
    dh = di // H
    ks = jax.random.split(key, 8)
    sc = 1.0 / math.sqrt(d)
    si = 1.0 / math.sqrt(di)
    return {
        "w_up": truncated_normal(ks[0], (d, 2 * di), sc),
        "wq": truncated_normal(ks[1], (di, H, dh), si),
        "wk": truncated_normal(ks[2], (di, H, dh), si),
        "wv": truncated_normal(ks[3], (di, H, dh), si),
        "w_if": truncated_normal(ks[4], (di, 2 * H), si),
        "b_if": jnp.concatenate([jnp.zeros((H,)),
                                 3.0 * jnp.ones((H,))]).astype(jnp.float32),
        "gn_scale": jnp.ones((di,), jnp.float32),
        "w_down": truncated_normal(ks[5], (di, d), si),
    }


def mlstm_init_state(cfg: ModelConfig, s: SSMConfig, batch: int,
                     dtype) -> MLSTMState:
    di = int(s.proj_factor * cfg.d_model)
    H = s.num_heads
    dh = di // H
    return MLSTMState(C=jnp.zeros((batch, H, dh, dh), jnp.float32),
                      n=jnp.zeros((batch, H, dh), jnp.float32),
                      m=jnp.full((batch, H), -1e30, jnp.float32))


def mlstm_forward_chunked(params, cfg: ModelConfig, s: SSMConfig, x,
                          state: MLSTMState | None = None):
    """Chunkwise-parallel mLSTM (§Perf optimization, beyond-paper).

    The sequential scan writes the O(dk·dv) matrix state to HBM every
    timestep; the chunkwise form (linear-attention chunking, as in
    GLA/Mamba-2/xLSTM kernels) carries state only across chunk boundaries:

      intra-chunk: masked attention-style score matrix with cumulative
        log-forget weights (MXU matmuls over [c, dk] tiles);
      inter-chunk: each chunk reads the boundary state once.

    HBM state traffic drops ~chunk_size x and the work becomes matmuls.
    Gate stabilization follows the same running-max trick as the scan
    form; equivalence vs the sequential form is tested to bf16-ish rtol.

    Decode (S == 1) and cross-chunk state carry use the same state layout
    as the sequential form, so serve paths are unchanged.
    """
    B, S, d = x.shape
    dtype = x.dtype
    if state is None:
        state = mlstm_init_state(cfg, s, B, dtype)
    H = s.num_heads
    c = min(s.chunk_size, S)
    if S % c:
        # fall back for ragged tails (decode handled by sequential form)
        return mlstm_forward(params, cfg, s, x, state)
    n_chunks = S // c

    up = x @ params["w_up"].astype(dtype)
    u, z = jnp.split(up, 2, axis=-1)
    q = jnp.einsum("bsi,ihk->bshk", u, params["wq"].astype(dtype))
    k = jnp.einsum("bsi,ihk->bshk", u, params["wk"].astype(dtype))
    v = jnp.einsum("bsi,ihk->bshk", u, params["wv"].astype(dtype))
    gates = u @ params["w_if"].astype(dtype) + params["b_if"].astype(dtype)
    i_pre, f_pre = jnp.split(gates, 2, axis=-1)             # [B,S,H]
    dh = q.shape[-1]
    scale = 1.0 / math.sqrt(dh)

    # reshape to chunks [B, n, c, ...] then scan over n
    def chunked(a):
        return a.reshape(B, n_chunks, c, *a.shape[2:])
    qc_, kc_, vc_ = chunked(q), chunked(k), chunked(v)
    ic_, fc_ = chunked(i_pre), chunked(f_pre)

    def chunk_step(carry, inp):
        # Derivation (per head; F_t = Σ_{s<=t} log σ(f_s), m = carry
        # stabilizer, stored state = true state · e^{-m}):
        #   m_loc_t = F_t + max(m, max_{j<=t}(i_j − F_j))   (== seq. m_t)
        #   w_tj    = e^{F_t − F_j + i_j − m_loc_t}         (j <= t)
        #   num_t   = Σ_j (q·k_j) scale w_tj v_j + e^{F_t + m − m_loc_t} q·C
        #   den_t   = max(|Σ_j w_tj (q·k_j scale)… analog on n|, e^{−m_loc_t})
        #   C'      = e^{F_c + m − m'} C + Σ_j e^{F_c − F_j + i_j − m'} k v^T
        C, n, m = carry                     # [B,H,dk,dv], [B,H,dk], [B,H]
        qt, kt, vt, it, ft = inp            # [B,c,H,*]
        qf = qt.astype(jnp.float32)
        kf = kt.astype(jnp.float32) * scale
        vf = vt.astype(jnp.float32)
        i_f = it.astype(jnp.float32)
        logf = jax.nn.log_sigmoid(ft.astype(jnp.float32))   # [B,c,H]
        csum = jnp.cumsum(logf, axis=1)                     # F_t, inclusive
        total = csum[:, -1]                                 # F_c  [B,H]

        iw = i_f - csum                                     # i_j − F_j
        run_max = jax.lax.associative_scan(jnp.maximum, iw, axis=1)
        m_loc = csum + jnp.maximum(run_max, m[:, None, :])  # [B,c,H]
        m_new = m_loc[:, -1]                                # chunk-end m

        # --- intra-chunk (attention-style, causal; MXU matmuls) ---
        sc = jnp.einsum("bthk,bjhk->bhtj", qf, kf)
        cs_h = csum.transpose(0, 2, 1)                      # [B,H,c]
        logw = (cs_h[:, :, :, None] - cs_h[:, :, None, :]
                + i_f.transpose(0, 2, 1)[:, :, None, :]
                - m_loc.transpose(0, 2, 1)[:, :, :, None])
        causal = jnp.tril(jnp.ones((c, c), bool))
        w = jnp.where(causal[None, None], jnp.exp(logw), 0.0)
        intra = jnp.einsum("bhtj,bhtj,bjhv->bthv", sc, w, vf)
        nrm = jnp.einsum("bhtj,bjhk->bthk", w, kf)
        n_intra = jnp.einsum("bthk,bthk->bth", qf, nrm)

        # --- inter-chunk (boundary state, read once) ---
        carry_w = jnp.exp(csum + m[:, None, :] - m_loc)     # [B,c,H]
        inter = jnp.einsum("bthk,bhkv->bthv", qf, C) * carry_w[..., None]
        n_inter = jnp.einsum("bthk,bhk->bth", qf, n) * carry_w
        num = intra + inter
        den = jnp.maximum(jnp.abs(n_intra + n_inter),
                          jnp.exp(-m_loc))[..., None]
        y = (num / den).astype(dtype)

        # --- boundary state update (written once per chunk) ---
        kv_w = jnp.exp(i_f + (total[:, None] - csum) - m_new[:, None, :])
        fgate = jnp.exp(total + m - m_new)[:, :, None, None]
        C_new = fgate * C + jnp.einsum("bjhk,bjh,bjhv->bhkv", kf, kv_w, vf)
        n_new = fgate[..., 0] * n + jnp.einsum("bjhk,bjh->bhk", kf, kv_w)
        return (C_new, n_new, m_new), y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (qc_, kc_, vc_, ic_, fc_))
    (C, n, m), ys = jax.lax.scan(chunk_step, (state.C, state.n, state.m), xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, -1)
    y = apply_norm({"scale": params["gn_scale"]}, y, "rmsnorm")
    y = y * jax.nn.silu(z)
    return y @ params["w_down"].astype(dtype), MLSTMState(C=C, n=n, m=m)


def mlstm_forward(params, cfg: ModelConfig, s: SSMConfig, x,
                  state: MLSTMState | None = None):
    """Stabilized mLSTM recurrence (xLSTM eqs. 19-27), scanned over time."""
    B, S, d = x.shape
    dtype = x.dtype
    if state is None:
        state = mlstm_init_state(cfg, s, B, dtype)
    H = s.num_heads
    up = x @ params["w_up"].astype(dtype)
    u, z = jnp.split(up, 2, axis=-1)                        # [B,S,di]
    q = jnp.einsum("bsi,ihk->bshk", u, params["wq"].astype(dtype))
    k = jnp.einsum("bsi,ihk->bshk", u, params["wk"].astype(dtype))
    v = jnp.einsum("bsi,ihk->bshk", u, params["wv"].astype(dtype))
    gates = u @ params["w_if"].astype(dtype) + params["b_if"].astype(dtype)
    i_pre, f_pre = jnp.split(gates, 2, axis=-1)             # [B,S,H]
    dh = q.shape[-1]
    scale = 1.0 / math.sqrt(dh)

    def step(carry, inp):
        C, n, m = carry
        q_t, k_t, v_t, i_t, f_t = [a.astype(jnp.float32) for a in inp]
        logf = jax.nn.log_sigmoid(f_t)                      # [B,H]
        m_new = jnp.maximum(logf + m, i_t)
        fg = jnp.exp(logf + m - m_new)[..., None, None]
        ig = jnp.exp(i_t - m_new)[..., None, None]
        C = fg * C + ig * (k_t[..., :, None] * v_t[..., None, :]) * scale
        n = fg[..., 0] * n + ig[..., 0] * k_t * scale
        num = jnp.einsum("bhkv,bhk->bhv", C, q_t)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q_t)),
                          jnp.exp(-m_new))[..., None]
        y = num / den
        return (C, n, m_new), y.astype(dtype)

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (q, k, v, i_pre, f_pre))
    (C, n, m), ys = jax.lax.scan(step, (state.C, state.n, state.m), xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, -1)            # [B,S,di]
    # group-norm per head approximated by RMS over di + learned scale
    y = apply_norm({"scale": params["gn_scale"]}, y, "rmsnorm")
    y = y * jax.nn.silu(z)
    return y @ params["w_down"].astype(dtype), MLSTMState(C=C, n=n, m=m)


# ---------------------------------------------------------------------------
# sLSTM (xLSTM scalar memory)
# ---------------------------------------------------------------------------


class SLSTMState(NamedTuple):
    c: jax.Array          # [B, di]
    n: jax.Array
    h: jax.Array
    m: jax.Array


def init_slstm(key, cfg: ModelConfig, s: SSMConfig):
    d = cfg.d_model
    di = d                      # sLSTM keeps model width
    H = s.num_heads
    dh = di // H
    ks = jax.random.split(key, 6)
    sc = 1.0 / math.sqrt(d)
    return {
        "w_gates": truncated_normal(ks[0], (d, 4 * di), sc),
        # block-diagonal recurrent mixing per head: [H, dh, 4*dh]
        "r_gates": truncated_normal(ks[1], (H, dh, 4 * dh),
                                    1.0 / math.sqrt(dh)),
        "b_gates": jnp.concatenate([
            jnp.zeros((di,)), 3.0 * jnp.ones((di,)),
            jnp.zeros((2 * di,))]).astype(jnp.float32),
        "gn_scale": jnp.ones((di,), jnp.float32),
        # post-FFN (proj factor 4/3, xLSTM paper)
        "w_ff1": truncated_normal(ks[2], (di, 4 * di // 3), sc),
        "w_ff2": truncated_normal(ks[3], (4 * di // 3, di),
                                  1.0 / math.sqrt(4 * di // 3)),
    }


def slstm_init_state(cfg: ModelConfig, s: SSMConfig, batch: int,
                     dtype) -> SLSTMState:
    di = cfg.d_model
    z = jnp.zeros((batch, di), jnp.float32)
    return SLSTMState(c=z, n=z, h=z, m=z - 1e30)


def slstm_forward(params, cfg: ModelConfig, s: SSMConfig, x,
                  state: SLSTMState | None = None):
    B, S, d = x.shape
    dtype = x.dtype
    if state is None:
        state = slstm_init_state(cfg, s, B, dtype)
    H = s.num_heads
    di = d
    dh = di // H
    wx = x @ params["w_gates"].astype(dtype) + params["b_gates"].astype(dtype)

    def step(carry, wx_t):
        c, n, h, m = carry
        hh = h.reshape(B, H, dh)
        rec = jnp.einsum("bhk,hkp->bhp", hh.astype(dtype),
                         params["r_gates"].astype(dtype)).reshape(B, 4 * di)
        zi, fi, ii, oi = jnp.split((wx_t + rec).astype(jnp.float32), 4, -1)
        logf = jax.nn.log_sigmoid(fi)
        m_new = jnp.maximum(logf + m, ii)
        fg = jnp.exp(logf + m - m_new)
        ig = jnp.exp(ii - m_new)
        c = fg * c + ig * jnp.tanh(zi)
        n = fg * n + ig
        h_new = jax.nn.sigmoid(oi) * c / jnp.maximum(n, 1.0)
        return (c, n, h_new, m_new), h_new.astype(dtype)

    (c, n, h, m), ys = jax.lax.scan(step, (state.c, state.n, state.h,
                                           state.m),
                                    jnp.moveaxis(wx, 1, 0))
    y = jnp.moveaxis(ys, 0, 1)
    y = apply_norm({"scale": params["gn_scale"]}, y, "rmsnorm")
    y = y + jax.nn.gelu(y @ params["w_ff1"].astype(dtype)) \
        @ params["w_ff2"].astype(dtype)
    return y, SLSTMState(c=c, n=n, h=h, m=m)
