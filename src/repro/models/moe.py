"""Mixture-of-Experts with static-shape sort-based dispatch.

Dispatch (TPU/XLA friendly, no dynamic shapes):
  router -> top-k -> flatten (token, slot) assignments -> argsort by expert
  -> per-assignment rank within its expert (vectorized searchsorted)
  -> scatter into a capacity-bounded [E, C, d] buffer (capacity drops)
  -> per-expert SwiGLU einsum -> gather back, weighted combine.

Token grouping: dispatch runs vmapped over ``num_groups`` groups (set to the
number of data shards at scale) so the argsort stays shard-local — experts
are sharded over the ``model`` axis (EP), the buffer's group axis over
``data``.

Supports: shared experts (DeepSeek-V2), dense-residual FFN in parallel
(Arctic), first-k-dense layers, load-balancing auxiliary loss (GShard).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.layers import apply_mlp, init_mlp, truncated_normal


def init_moe(key, cfg: ModelConfig, m: MoEConfig):
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    f = m.expert_d_ff
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(f)
    p = {
        "router": truncated_normal(ks[0], (d, m.num_experts), s_in),
        "w_gate": truncated_normal(ks[1], (m.num_experts, d, f), s_in),
        "w_up": truncated_normal(ks[2], (m.num_experts, d, f), s_in),
        "w_down": truncated_normal(ks[3], (m.num_experts, f, d), s_out),
    }
    if m.num_shared_experts:
        p["shared"] = init_mlp(ks[4], d,
                               m.shared_d_ff * m.num_shared_experts,
                               cfg.activation)
    if m.dense_residual:
        p["dense"] = init_mlp(ks[5], d, m.dense_residual_d_ff,
                              cfg.activation)
    return p


def _capacity(tokens_per_group: int, m: MoEConfig) -> int:
    c = int(math.ceil(tokens_per_group * m.top_k * m.capacity_factor
                      / m.num_experts))
    # keep MXU-aligned and nonzero
    c = max(8, ((c + 7) // 8) * 8)
    return min(c, tokens_per_group)


def _dispatch_one_group(x, logits, m: MoEConfig, capacity: int):
    """x: [T, d]; logits: [T, E]. Returns (buffer [E, C, d], combine info)."""
    T, d = x.shape
    E, k = m.num_experts, m.top_k
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                  # [T, k]
    top_p = (top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9))

    flat_e = top_e.reshape(-1)                              # [T*k]
    order = jnp.argsort(flat_e, stable=True).astype(jnp.int32)
    sorted_e = flat_e[order]
    # rank within expert = position - first position of that expert value
    first = jnp.searchsorted(sorted_e, sorted_e, side="left").astype(jnp.int32)
    ranks_sorted = jnp.arange(T * k, dtype=jnp.int32) - first
    ranks = jnp.zeros((T * k,), jnp.int32).at[order].set(ranks_sorted)
    ranks = ranks.reshape(T, k)
    keep = ranks < capacity                                  # capacity drop

    token_of = jnp.arange(T, dtype=jnp.int32)[:, None]
    buf = jnp.zeros((E, capacity, d), x.dtype)
    e_idx = jnp.where(keep, top_e, E - 1)
    r_idx = jnp.where(keep, ranks, capacity)                 # OOB -> dropped
    buf = buf.at[e_idx.reshape(-1), r_idx.reshape(-1)].set(
        jnp.repeat(x, k, axis=0) if k > 1 else x, mode="drop")
    return buf, (e_idx, r_idx, top_p, keep, probs)


def _combine_one_group(out_buf, info, T: int, capacity: int):
    e_idx, r_idx, top_p, keep, _ = info
    # gather each (token, slot)'s expert output; dropped slots give zeros
    g = out_buf[e_idx.reshape(-1),
                jnp.clip(r_idx.reshape(-1), 0, capacity - 1)]
    g = g.reshape(T, top_p.shape[1], -1)
    w = jnp.where(keep, top_p, 0.0).astype(g.dtype)
    return jnp.einsum("tkd,tk->td", g, w)


def apply_moe(params, x, cfg: ModelConfig, m: MoEConfig, *,
              num_groups: int = 1):
    """x: [B, S, d] -> (y, aux_loss).

    Explicit-group formulation: every large intermediate carries the
    group axis G so sharding hints pin it to the fsdp axes (G = data
    shards at scale) and the expert axis to ``model`` (EP). vmap is used
    only for the small per-group integer index computation — XLA's
    propagation replicated the big dispatch buffers when the whole
    dispatch was vmapped.
    """
    from repro.distributed.sharding import hint

    B, S, d = x.shape
    T = B * S
    G = math.gcd(T, num_groups)          # decode batches may be tiny
    tg = T // G
    capacity = _capacity(tg, m)
    xg = hint(x.reshape(G, tg, d), "batch", None, None)
    dtype = x.dtype
    E, k = m.num_experts, m.top_k

    logits = jnp.einsum("gtd,de->gte", xg, params["router"].astype(dtype))
    logits = hint(logits, "batch", None, None)

    def group_indices(la):
        probs = jax.nn.softmax(la.astype(jnp.float32), axis=-1)
        top_p, top_e = jax.lax.top_k(probs, k)                 # [Tg, k]
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
        flat_e = top_e.reshape(-1)
        order = jnp.argsort(flat_e, stable=True).astype(jnp.int32)
        sorted_e = flat_e[order]
        first = jnp.searchsorted(sorted_e, sorted_e,
                                 side="left").astype(jnp.int32)
        ranks_sorted = jnp.arange(tg * k, dtype=jnp.int32) - first
        ranks = jnp.zeros((tg * k,), jnp.int32).at[order].set(ranks_sorted)
        ranks = ranks.reshape(tg, k)
        keep = ranks < capacity
        e_idx = jnp.where(keep, top_e, E - 1)
        r_idx = jnp.where(keep, ranks, capacity)               # OOB drops
        # aux loss ingredients
        top1 = jnp.argmax(la, axis=-1)
        f_e = jnp.mean(jax.nn.one_hot(top1, E, dtype=jnp.float32), axis=0)
        p_e = jnp.mean(probs, axis=0)
        aux = E * jnp.sum(f_e * p_e)
        return e_idx, r_idx, top_p, keep, aux

    e_idx, r_idx, top_p, keep, aux = jax.vmap(group_indices)(logits)

    # scatter tokens into the [G, E, C, d] dispatch buffer
    xk = jnp.repeat(xg, k, axis=1) if k > 1 else xg            # [G, Tg*k, d]
    xk = hint(xk, "batch", None, None)
    g_ids = jnp.repeat(jnp.arange(G, dtype=jnp.int32)[:, None], tg * k, 1)
    buf = jnp.zeros((G, E, capacity, d), dtype)
    buf = buf.at[g_ids.reshape(-1),
                 e_idx.reshape(-1),
                 r_idx.reshape(-1)].set(xk.reshape(-1, d), mode="drop")
    buf = hint(buf, "batch", "model", None, None)

    h = jnp.einsum("gecd,edf->gecf", buf, params["w_gate"].astype(dtype))
    u = jnp.einsum("gecd,edf->gecf", buf, params["w_up"].astype(dtype))
    act = jax.nn.silu(h) * u if cfg.activation in ("swiglu", "silu") \
        else jax.nn.gelu(h) * u
    out_buf = jnp.einsum("gecf,efd->gecd", act,
                         params["w_down"].astype(dtype))
    out_buf = hint(out_buf, "batch", "model", None, None)

    # combine: gather each (token, slot)'s expert output
    gather = out_buf[g_ids.reshape(-1),
                     e_idx.reshape(-1),
                     jnp.clip(r_idx, 0, capacity - 1).reshape(-1)]
    gather = hint(gather.reshape(G, tg, k, d), "batch", None, None, None)
    w = jnp.where(keep, top_p, 0.0).astype(dtype)
    yg = jnp.einsum("gtkd,gtk->gtd", gather, w)
    y = hint(yg, "batch", None, None).reshape(B, S, d)

    if m.num_shared_experts:
        y = y + apply_mlp(params["shared"], x, cfg.activation)
    if m.dense_residual:
        y = y + apply_mlp(params["dense"], x, cfg.activation)
    return y, jnp.mean(aux) * m.router_aux_loss
