"""Production mesh construction (multi-pod dry-run deliverable).

A FUNCTION, not a module constant — importing this module never touches
jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod adds a leading pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_dev_mesh(data: int = 1, model: int = 1):
    """Small mesh for CPU tests (requires forced host device count)."""
    return jax.make_mesh((data, model), ("data", "model"))
