"""ShapeDtypeStruct stand-ins for every model input (no allocation).

``input_specs(cfg, shape)`` returns the abstract batch for train/prefill;
``decode_specs`` additionally returns the abstract decode state. Modality
frontends are stubs: precomputed frame/patch embeddings appear directly as
inputs (assignment note for [audio]/[vlm] archs).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model as M

Spec = jax.ShapeDtypeStruct


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    toks = Spec((B, S), jnp.int32)
    batch: Dict[str, Any] = {"tokens": toks}
    if cfg.family == "vlm":
        n_p = min(M.N_PATCHES, S // 2)
        batch["tokens"] = Spec((B, S - n_p), jnp.int32)
        batch["patches"] = Spec((B, n_p, cfg.d_model), dt)
    if cfg.family == "enc_dec":
        batch["frames"] = Spec((B, M.ENC_FRAMES, cfg.d_model), dt)
    if shape.kind == "train":
        batch["labels"] = Spec(batch["tokens"].shape, jnp.int32)
    return batch


def decode_specs(cfg: ModelConfig, shape: ShapeConfig):
    """(tokens, state) abstract values for serve_step."""
    B, S = shape.global_batch, shape.seq_len
    tokens = Spec((B, 1), jnp.int32)
    state = jax.eval_shape(
        lambda: M.init_decode_state(cfg, B, S))
    return tokens, state


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0)))


def abstract_opt_state(cfg: ModelConfig, opt_cfg):
    from repro.train.optimizer import init_opt_state
    return jax.eval_shape(lambda p: init_opt_state(p, opt_cfg),
                          abstract_params(cfg))
