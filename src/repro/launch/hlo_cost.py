"""Static cost analysis over optimized (post-SPMD) HLO text.

Why not ``compiled.cost_analysis()``: XLA's HLO cost analysis counts a
``while`` body ONCE, ignoring the trip count — and this framework lowers
every layer stack, attention chunk loop and xent chunk loop as scans, so
the builtin numbers undercount flops/bytes/collectives by ~depth x.
(Verified: a 10-iteration scanned matmul reports 1/10 the flops of its
unrolled twin.)

This analyzer parses the optimized HLO text into computations, builds a
per-computation symbol table (op -> shape), and computes:

* **flops** — 2·(output elems)·(contraction elems) for every ``dot``
  (recursing into fusions/calls), multiplied through nested while-loop
  trip counts (extracted from each loop condition's comparison constant);
* **bytes** — an HBM-traffic model: for each op at computation level,
  operand + result bytes; fusions count only their operands/results
  (internal intermediates live in registers/VMEM — closer to real traffic
  than XLA's "bytes accessed", which double-counts fusion internals);
* **collective payload bytes** per kind (all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute), trip-multiplied.

Everything here operates on per-partition HLO, so results are per-chip.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u2": 1, "u4": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*(?P<type>.+?)\s+"
    r"(?P<opcode>[\w\-]+)\((?P<rest>.*)$")

_COMP_HEAD_RE = re.compile(r"^(?:ENTRY\s+)?%?(?P<name>[\w.\-]+)\s*\(.*\)\s*->")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    """(total elements, total bytes) of a possibly-tuple HLO type string."""
    elems = 0
    nbytes = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    # scalar like "f32[]" has empty dims -> n = 1 (handled above: no digits
    # means the loop over "" leaves n = 1)
    return elems, nbytes


@dataclass
class Op:
    name: str
    opcode: str
    type_str: str
    operands: List[str]
    attrs: str


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)   # name -> type


@dataclass
class CostTotals:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    collectives: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "CostTotals", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.transcendentals += other.transcendentals * mult
        for k, v in other.collectives.items():
            self.collectives[k] = self.collectives.get(k, 0.0) + v * mult


def _split_operands(rest: str) -> Tuple[List[str], str]:
    """Split 'a, b, c), attrs...' at the closing paren of the call."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                inner = rest[:i]
                attrs = rest[i + 1:]
                ops = [o.strip() for o in _split_top_commas(inner)]
                return ops, attrs
    return [o.strip() for o in _split_top_commas(rest)], ""


def _split_top_commas(s: str) -> List[str]:
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "({[":
            depth += 1
        elif ch in ")}]":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return [x for x in out if x.strip()]


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s or s.startswith("//"):
            continue
        if s.endswith("{") and ("->" in s or s.startswith("ENTRY")):
            m = _COMP_HEAD_RE.match(s)
            if m:
                cur = Computation(m.group("name"))
                comps[cur.name] = cur
                if s.startswith("ENTRY"):
                    entry = cur.name
                # parameters appear in the header: bind their types
                for pm in re.finditer(r"%?([\w.\-]+):\s*((?:\([^)]*\)|[\w\[\],{}\s/]+?))(?:,|\)\s*->)", s):
                    cur.symbols[pm.group(1)] = pm.group(2)
                continue
        if s == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(s)
        if not m:
            continue
        operands, attrs = _split_operands(m.group("rest"))
        op = Op(name=m.group("name"), opcode=m.group("opcode"),
                type_str=m.group("type"), operands=operands, attrs=attrs)
        cur.ops.append(op)
        cur.symbols[op.name] = op.type_str
    return comps, entry


def _operand_type(comp: Computation, operand: str) -> str:
    # operands look like "%name", "%name.1", "s32[] constant(5)", etc.
    name = operand.strip().lstrip("%").split(" ")[0]
    return comp.symbols.get(name, operand)


def _called(attrs: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w.\-]+)", attrs)
    return m.group(1) if m else None


def _dot_flops(comp: Computation, op: Op) -> float:
    out_elems, _ = shape_elems_bytes(op.type_str)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    cdims = [int(x) for x in m.group(1).split(",")] if m and m.group(1) else []
    lhs_type = _operand_type(comp, op.operands[0]) if op.operands else ""
    mm = _SHAPE_RE.search(lhs_type)
    k = 1
    if mm:
        dims = [int(x) for x in mm.group(2).split(",") if x]
        for c in cdims:
            if c < len(dims):
                k *= dims[c]
    return 2.0 * out_elems * max(k, 1)


def _while_trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    """Trip count of a scan-style while: the loop bound is the comparison
    constant in the condition. XLA may wrap the compare in a fusion, so we
    take the largest integer constant present in the condition computation
    (iteration counters contribute only 0/1)."""
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    best = 0
    stack = [cond]
    seen = set()
    while stack:
        c = stack.pop()
        if c.name in seen:
            continue
        seen.add(c.name)
        for op in c.ops:
            if op.opcode == "constant" and op.operands:
                mv = re.match(r"^\s*(\d+)", op.operands[0])
                if mv:
                    best = max(best, int(mv.group(1)))
            for key in ("calls", "to_apply"):
                called = _called(op.attrs, key)
                if called and called in comps:
                    stack.append(comps[called])
    return max(best, 1)


_ELEMENTWISE_FLOP_OPS = {"add", "subtract", "multiply", "divide", "maximum",
                         "minimum", "compare", "select", "and", "or", "xor"}
_TRANSCENDENTAL_OPS = {"exponential", "log", "rsqrt", "sqrt", "tanh",
                       "logistic", "power", "sine", "cosine", "expm1",
                       "log1p", "erf"}


def _comp_cost(comps: Dict[str, Computation], name: str,
               memo: Dict[str, CostTotals], *, inside_fusion: bool,
               ) -> CostTotals:
    key = f"{name}|{inside_fusion}"
    if key in memo:
        return memo[key]
    comp = comps.get(name)
    total = CostTotals()
    if comp is None:
        memo[key] = total
        return total
    for op in comp.ops:
        oc = op.opcode
        if oc == "while":
            body = _called(op.attrs, "body")
            cond = _called(op.attrs, "condition")
            trips = _while_trip_count(comps, cond) if cond else 1
            if body:
                total.add(_comp_cost(comps, body, memo,
                                     inside_fusion=False), trips)
            continue
        if oc in ("fusion",):
            called = _called(op.attrs, "calls")
            if called:
                sub = _comp_cost(comps, called, memo, inside_fusion=True)
                # flops recurse; bytes = fusion I/O only
                total.flops += sub.flops
                total.transcendentals += sub.transcendentals
                for k, v in sub.collectives.items():
                    total.collectives[k] = total.collectives.get(k, 0) + v
            if not inside_fusion:
                _, ob = shape_elems_bytes(op.type_str)
                ib = sum(shape_elems_bytes(_operand_type(comp, o))[1]
                         for o in op.operands)
                total.bytes += ob + ib
            continue
        if oc in ("call", "conditional", "sort", "reduce", "reduce-window",
                  "scatter", "map", "select-and-scatter", "custom-call"):
            for k in ("to_apply", "called_computations", "calls",
                      "branch_computations"):
                called = _called(op.attrs, k)
                if called:
                    sub = _comp_cost(comps, called, memo,
                                     inside_fusion=inside_fusion)
                    total.flops += sub.flops
                    total.transcendentals += sub.transcendentals
        if oc == "dot":
            total.flops += _dot_flops(comp, op)
        elif oc == "convolution":
            # rough: 2 * out_elems * (kernel elems) — models here use no
            # big convs; keep conservative
            out_e, _ = shape_elems_bytes(op.type_str)
            k_e = 1
            if len(op.operands) > 1:
                k_e, _ = shape_elems_bytes(_operand_type(comp,
                                                         op.operands[1]))
            total.flops += 2.0 * out_e * max(k_e, 1) ** 0.5
        elif oc in _ELEMENTWISE_FLOP_OPS:
            out_e, _ = shape_elems_bytes(op.type_str)
            total.flops += out_e
        elif oc in _TRANSCENDENTAL_OPS:
            out_e, _ = shape_elems_bytes(op.type_str)
            total.transcendentals += out_e

        base = oc.replace("-start", "")
        if base in COLLECTIVES and not oc.endswith("-done"):
            _, ob = shape_elems_bytes(op.type_str)
            total.collectives[base] = total.collectives.get(base, 0.0) + ob

        if not inside_fusion and oc not in ("parameter", "constant",
                                            "get-tuple-element", "tuple",
                                            "bitcast"):
            _, ob = shape_elems_bytes(op.type_str)
            ib = sum(shape_elems_bytes(_operand_type(comp, o))[1]
                     for o in op.operands)
            total.bytes += ob + ib
    memo[key] = total
    return total


def analyze_hlo_text(text: str) -> CostTotals:
    comps, entry = parse_hlo(text)
    if entry is None:
        return CostTotals()
    memo: Dict[str, CostTotals] = {}
    return _comp_cost(comps, entry, memo, inside_fusion=False)
