"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), TPU v5e constants:
    compute    = HLO_FLOPs / (chips × 197e12 bf16 FLOP/s)
    memory     = HLO_bytes / (chips × 819e9 B/s HBM)
    collective = collective_bytes / (chips × 50e9 B/s ICI per link)

``cost_analysis()`` reports the per-device program, so per-chip terms are
direct. Collective bytes are NOT in cost_analysis: we parse the optimized
(post-SPMD) HLO text and sum operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVE_RE = re.compile(
    r"^\s*[%\w.\-]+\s*=\s*(?:\([^)]*\)|[\w\[\]{},:#\s*]+?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind payload bytes (per partition) from HLO text.

    The payload is the RESULT shape, which in HLO text sits between ``=``
    and the op name:  ``%ar.1 = bf16[128,4096]{1,0} all-reduce(%x), ...``.
    ``*-done`` ops are skipped (their ``*-start`` counterpart already
    carried the shape).
    """
    out: Dict[str, int] = {}
    kinds = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.startswith("//") or "=" not in s:
            continue
        m = re.search(
            r"=\s*(?P<shape>[^=]*?)\s*"
            r"\b(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)(?P<phase>-start|-done)?\(", s)
        if not m or m.group("phase") == "-done":
            continue
        out[m.group("kind")] = out.get(m.group("kind"), 0) \
            + _shape_bytes(m.group("shape"))
    return out


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    collective_bytes_per_chip: float
    collective_breakdown: Dict[str, int] = field(default_factory=dict)
    peak_memory_per_chip: float = 0.0
    model_flops: float = 0.0          # 6·N·D (global)

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops_per_chip * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful-FLOPs MFU bound implied by the dominant term."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        if t <= 0:
            return 0.0
        return (self.model_flops / (self.chips * PEAK_FLOPS)) / t

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "hlo_flops_total": self.flops_per_chip * self.chips,
            "useful_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "peak_mem_gib": self.peak_memory_per_chip / 2**30,
            "collectives": self.collective_breakdown,
        }


def analyze(arch: str, shape_name: str, mesh_name: str, chips: int,
            compiled, model_flops: float) -> RooflineReport:
    """Derive per-chip roofline terms from the compiled artifact.

    Uses the trip-count-aware HLO static analyzer (hlo_cost.py) rather
    than ``compiled.cost_analysis()``: XLA's builtin counts while bodies
    once, undercounting every scanned layer stack by ~depth x (verified
    in tests/test_roofline.py).
    """
    from repro.launch.hlo_cost import analyze_hlo_text

    hlo = compiled.as_text()
    totals = analyze_hlo_text(hlo)
    flops = totals.flops
    bytes_ = totals.bytes
    coll = {k: int(v) for k, v in totals.collectives.items()}
    mem = compiled.memory_analysis()
    peak = 0.0
    if mem is not None:
        peak = float(getattr(mem, "temp_size_in_bytes", 0)
                     + getattr(mem, "argument_size_in_bytes", 0)
                     + getattr(mem, "output_size_in_bytes", 0)
                     - getattr(mem, "alias_size_in_bytes", 0))
    return RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        flops_per_chip=flops, bytes_per_chip=bytes_,
        collective_bytes_per_chip=float(sum(coll.values())),
        collective_breakdown=coll,
        peak_memory_per_chip=peak, model_flops=model_flops,
    )


def model_flops_for(cfg, shape) -> float:
    """6·N·D for train; 2·N·D for inference forward (per step/batch)."""
    n_active = cfg.approx_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
