"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dryrun JSON."""
from __future__ import annotations

import json
import sys


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def render(rows, mesh_filter=None):
    out = []
    out.append("| arch | shape | mesh | t_compute | t_memory | t_collective"
               " | bottleneck | 6ND/HLO | roofline-frac | mem/chip |")
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"FAILED: {r['status']} |||||||")
            continue
        if mesh_filter and r["mesh"] != mesh_filter:
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {fmt_s(r['t_compute_s'])} | {fmt_s(r['t_memory_s'])} "
            f"| {fmt_s(r['t_collective_s'])} | {r['bottleneck']} "
            f"| {r['useful_ratio']:.3f} | {r['roofline_fraction']:.2e} "
            f"| {r['peak_mem_gib']:.1f}GiB |")
    return "\n".join(out)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    rows = json.load(open(path))
    print("## Single-pod (16x16 = 256 chips)\n")
    print(render(rows, "16x16"))
    print("\n## Multi-pod (2x16x16 = 512 chips)\n")
    print(render(rows, "2x16x16"))


if __name__ == "__main__":
    main()
