import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
    " --xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape × mesh) combination:
  * ``train_4k``                    lowers train_step,
  * ``prefill_32k``                 lowers prefill_step,
  * ``decode_32k`` / ``long_500k``  lower serve_step,
with production shardings, then ``.lower().compile()`` — proving the
distribution config is coherent: sharding mismatches, compile-time OOMs
and unsupported collectives all surface here.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only]
  PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.json
"""
import argparse
import json
import sys
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES_BY_NAME, get_config, list_archs, shapes_for
from repro.distributed import sharding as shd
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (
    abstract_opt_state,
    abstract_params,
    decode_specs,
    input_specs,
)
from repro.train.optimizer import AdamWConfig
from repro.train.train_loop import (
    make_prefill_step,
    make_serve_step,
    make_train_step,
)


def num_token_groups(mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    g = sizes.get("data", 1) * sizes.get("pod", 1)
    if os.environ.get("REPRO_SHARDING_MODE") == "fsdp":
        g *= sizes.get("model", 1)    # batch spans every axis
    return g


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               compile_: bool = True, opt_overrides=None):
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    groups = num_token_groups(mesh)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)

    params_abs = abstract_params(cfg)
    param_shard = shd.tree_shardings(params_abs, mesh)

    with mesh:
        if shape.kind == "train":
            opt_cfg = AdamWConfig(**(opt_overrides or {}))
            step = make_train_step(cfg, opt_cfg, num_groups=groups)
            opt_abs = abstract_opt_state(cfg, opt_cfg)
            opt_shard = shd.tree_shardings(opt_abs, mesh)
            batch = input_specs(cfg, shape)
            bshard = shd.batch_shardings(cfg, mesh, batch)
            lowered = jax.jit(
                step,
                in_shardings=(param_shard, opt_shard, bshard),
                out_shardings=(param_shard, opt_shard, None),
                donate_argnums=(0, 1),
            ).lower(params_abs, opt_abs, batch)
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg, num_groups=groups)
            batch = input_specs(cfg, shape)
            bshard = shd.batch_shardings(cfg, mesh, batch)
            lowered = jax.jit(
                step, in_shardings=(param_shard, bshard),
            ).lower(params_abs, batch)
        else:  # decode
            step = make_serve_step(cfg, num_groups=groups)
            tokens, state = decode_specs(cfg, shape)
            tshard = NamedSharding(
                mesh, shd.batch_pspec(mesh, shape.global_batch))
            sshard = shd.state_shardings(mesh, state, shape.global_batch)
            lowered = jax.jit(
                step,
                in_shardings=(param_shard, tshard, sshard),
                out_shardings=(None, sshard),
                donate_argnums=(2,),
            ).lower(params_abs, tokens, state)

        if not compile_:
            return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                    "status": "lowered"}
        compiled = lowered.compile()

    report = rl.analyze(arch, shape_name, mesh_name, chips, compiled,
                        rl.model_flops_for(cfg, shape))
    row = report.row()
    row["status"] = "ok"
    return row


def run_all(multi_pod_only=False, single_pod_only=False, archs=None,
            out_path=None):
    rows = []
    meshes = [False, True]
    if multi_pod_only:
        meshes = [True]
    if single_pod_only:
        meshes = [False]
    for arch in (archs or list_archs()):
        cfg = get_config(arch)
        for shape in shapes_for(cfg):
            for mp in meshes:
                t0 = time.time()
                try:
                    row = lower_cell(arch, shape.name, multi_pod=mp)
                    row["compile_s"] = round(time.time() - t0, 1)
                    print(f"[OK] {arch:22s} {shape.name:12s} "
                          f"mesh={'2x16x16' if mp else '16x16':8s} "
                          f"compile={row['compile_s']:7.1f}s "
                          f"bottleneck={row.get('bottleneck', '?'):10s} "
                          f"mem={row.get('peak_mem_gib', 0):.2f}GiB",
                          flush=True)
                except Exception as e:
                    traceback.print_exc()
                    row = {"arch": arch, "shape": shape.name,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": f"FAIL: {type(e).__name__}: {e}"}
                    print(f"[FAIL] {arch} {shape.name} mp={mp}: {e}",
                          flush=True)
                rows.append(row)
                if out_path:
                    with open(out_path, "w") as f:
                        json.dump(rows, f, indent=1, default=str)
    n_ok = sum(r.get("status") == "ok" for r in rows)
    print(f"\n{n_ok}/{len(rows)} cells compiled OK")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.all:
        rows = run_all(multi_pod_only=args.multi_pod_only,
                       single_pod_only=args.single_pod_only,
                       archs=[args.arch] if args.arch else None,
                       out_path=args.out)
        sys.exit(0 if all(r.get("status") == "ok" for r in rows) else 1)

    row = lower_cell(args.arch, args.shape, multi_pod=args.multi_pod)
    print(json.dumps(row, indent=2, default=str))


if __name__ == "__main__":
    main()
