"""Request coalescing: heterogeneous queries -> one fixed-shape lane batch.

The packing problem (DESIGN.md §11): the walk engine dispatches
fixed-shape programs (jit caches key on ``(num_walks, max_length,
start_mode)``), while traffic arrives as many small heterogeneous
requests. The coalescer bridges the two:

* **shape buckets** — a batch always runs at a bucketed (lane count,
  max length) from ``ServeConfig``, never at the exact request shape, so
  arbitrary traffic compiles at most ``len(lane_buckets) ×
  len(length_buckets) × 2`` programs;
* **lane packing** — queries are laid out back-to-back along the walk
  axis; surplus bucket lanes are marked inactive (``LaneParams.active``)
  and cost only VPU lanes, not correctness;
* **result slicing** — each query's rows are sliced back out and trimmed
  to its own ``max_length + 1`` columns (everything beyond is PAD by the
  per-lane termination in the engine);
* **owner routing** (sharded serving, DESIGN.md §13/§15) — over a
  node-partitioned window each start lane belongs to exactly one shard;
  ``lane_owners`` resolves that routing host-side for nodes-mode batches
  through the placement policy's host mirror (``Placement.owner_np`` —
  the same object the device claim rule consults, so host and device
  owners agree bitwise for every policy; property-tested in
  tests/test_placement.py). Edges-mode start owners are data-dependent
  (the picked edge's destination) and resolve on device; both modes'
  per-shard claim counts come back from ``serve_lanes_sharded`` and feed
  ``ServeStats.lanes_by_shard`` (the provisioning signal for
  ``ShardConfig.walk_slots``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.samplers import bias_code
from repro.core.walk_engine import LaneParams, WalkResult
from repro.serve.query import WalkQuery


def bucketize(n: int, buckets: Sequence[int]) -> Optional[int]:
    """Smallest bucket >= n, or None when n exceeds every bucket."""
    for b in buckets:
        if n <= b:
            return b
    return None


def group_key(query: WalkQuery, length_buckets: Sequence[int]):
    """Coalescing group of a query: ``(start_mode, length bucket)``.

    Two queries may share a batch iff their group keys match — the start
    mode fixes the compiled program's shape family and the length bucket
    fixes its column count. This is THE compatibility rule; the service's
    batch formation, the linger/seal decision, and the fairness property
    in tests/test_serve.py all consult it through this one helper.
    """
    return (query.start_mode, bucketize(query.max_length, length_buckets))


@dataclass(frozen=True)
class LaneSlice:
    """Where one query's lanes live inside a coalesced batch."""

    offset: int
    count: int


def pack_queries(queries: Sequence[WalkQuery], num_lanes: int,
                 max_length: int) -> Tuple[LaneParams, List[LaneSlice]]:
    """Lay queries out back-to-back along the walk axis.

    Returns the engine-ready ``LaneParams`` (device arrays, ``num_lanes``
    wide, padding lanes inactive) and one ``LaneSlice`` per query. All
    queries must share a start mode and fit the bucket shape; the service
    guarantees both.
    """
    total = sum(q.num_lanes for q in queries)
    if total > num_lanes:
        raise ValueError(f"{total} lanes exceed the {num_lanes}-lane bucket")
    if any(q.max_length > max_length for q in queries):
        raise ValueError("query max_length exceeds the length bucket")
    start_node = np.zeros(num_lanes, np.int32)
    bias = np.zeros(num_lanes, np.int32)
    start_bias = np.zeros(num_lanes, np.int32)
    max_len = np.zeros(num_lanes, np.int32)
    rid = np.zeros(num_lanes, np.int32)
    wid = np.zeros(num_lanes, np.int32)
    active = np.zeros(num_lanes, bool)
    # second-order lanes: (1, 1) = first-order draw, the padding default
    n2v_p = np.ones(num_lanes, np.float32)
    n2v_q = np.ones(num_lanes, np.float32)

    slices: List[LaneSlice] = []
    off = 0
    for q in queries:
        n = q.num_lanes
        sl = slice(off, off + n)
        if q.start_mode == "nodes":
            start_node[sl] = np.asarray(q.start_nodes, np.int32)
        bias[sl] = bias_code(q.bias)
        start_bias[sl] = bias_code(q.start_bias)
        max_len[sl] = q.max_length
        rid[sl] = np.int32(q.seed)
        wid[sl] = np.arange(n, dtype=np.int32)
        active[sl] = True
        n2v_p[sl] = np.float32(q.n2v_p)
        n2v_q[sl] = np.float32(q.n2v_q)
        slices.append(LaneSlice(offset=off, count=n))
        off += n

    return LaneParams(
        start_node=jnp.asarray(start_node),
        bias=jnp.asarray(bias),
        start_bias=jnp.asarray(start_bias),
        max_len=jnp.asarray(max_len),
        rid=jnp.asarray(rid),
        wid=jnp.asarray(wid),
        active=jnp.asarray(active),
        n2v_p=jnp.asarray(n2v_p),
        n2v_q=jnp.asarray(n2v_q),
    ), slices


def slice_result(nodes: np.ndarray, times: np.ndarray, lengths: np.ndarray,
                 sl: LaneSlice, query: WalkQuery):
    """One query's rows out of the batch result, trimmed to its columns."""
    cols = query.max_length + 1
    rows = slice(sl.offset, sl.offset + sl.count)
    return (nodes[rows, :cols].copy(), times[rows, :cols].copy(),
            lengths[rows].copy())


def lane_owners(params: LaneParams, placement) -> np.ndarray:
    """Owner shard of each start lane in a packed nodes-mode batch.

    The device-side claim rule's host mirror: ``placement.owner_np`` over
    the clipped start node (repro.distributed.placement, DESIGN.md §15) —
    one rule, two residencies, bit-equal by construction for every
    policy. Padding / inactive lanes map to -1. Host-side on purpose:
    callers get per-shard routing without touching device state.
    """
    v = np.clip(np.asarray(params.start_node), 0,
                placement.node_capacity - 1)
    own = placement.owner_np(v)
    return np.where(np.asarray(params.active), own, -1)


def result_arrays(res: WalkResult):
    """Materialize a batch result on host once (single device->host copy
    per array; per-query slicing then stays in numpy)."""
    return (np.asarray(res.nodes), np.asarray(res.times),
            np.asarray(res.lengths))
