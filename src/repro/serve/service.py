"""Multi-tenant walk-query service over the streaming engine (DESIGN.md §11).

``WalkService`` is the front door the ROADMAP's "serve heavy traffic"
goal needs: many callers submit small heterogeneous ``WalkQuery``s; the
service queues them (fixed capacity, backpressure by drop + accounting),
coalesces compatible queries into one fixed-shape ``generate_walk_lanes``
dispatch per ``step()``, slices each tenant's rows back out, and tracks
p50/p99 submit→complete latency plus walks/s throughput.

Coalescing policy: strict FIFO head-of-line — ``step()`` takes the oldest
pending query, then greedily folds in every other pending query with the
same (start mode, length bucket) group key, in arrival order, until the
largest lane bucket is full. Older traffic is never overtaken by more
than one batch formation, and a lone query still rides a right-sized
(small) bucket instead of the mega-batch shape.

Determinism: results are bit-identical to running each query solo
(``run_query_solo``) because lane RNG folds by (query seed, walk id,
step) and the per-lane bias/length dispatch is pure per lane — the
coalescer only decides *where* a lane sits, never *what* it computes.

**Sharded serving** (DESIGN.md §13): with ``ServeConfig.num_shards > 0``
(or an explicit ``mesh``/``num_shards``), the same service runs against a
node-partitioned window: snapshots double-buffer a
``ShardedWindowState`` + replicated ts-view pair, and each coalesced
batch dispatches through ``serve_lanes_sharded`` — start lanes claimed by
their owner shards, per-hop owner migration, one psum trace reassembly —
with the *same* bit-identity guarantee against single-device solo runs.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.configs.base import EngineConfig, ServeConfig, WalkConfig
from repro.core.alias import spec_from_sampler
from repro.core.edge_store import make_batch
from repro.core.walk_engine import (
    LaneFeatures,
    LaneParams,
    check_capabilities,
    generate_walk_lanes,
)
from repro.core.window import WindowState, init_window
from repro.serve.coalescer import (
    bucketize,
    pack_queries,
    result_arrays,
    slice_result,
)
from repro.obs.probes import flush_serve_probes
from repro.obs.registry import (
    RESERVOIR_SIZE,
    MetricsRegistry,
    Reservoir,
    count_drop,
    get_registry,
)
from repro.obs.tracing import span
from repro.serve.query import QueryResult, WalkQuery
from repro.serve.snapshot import ShardedSnapshotManager, SnapshotManager


class QueueFull(RuntimeError):
    """Raised by ``submit(..., strict=True)`` when the queue is at capacity."""


# percentile window: counters are lifetime totals, but the latency/batch
# samples backing p50/p99 are a bounded ring-buffer reservoir (the obs
# histogram backing store, obs/registry.py) so a long-running service
# neither grows without bound nor pays O(history) per stat read
STATS_WINDOW = RESERVOIR_SIZE


@dataclass
class ServeStats:
    """Serving counters + latency/throughput accounting."""

    submitted: int = 0
    completed: int = 0
    dropped_backpressure: int = 0   # queue at capacity
    dropped_oversize: int = 0       # exceeds the largest shape bucket
    batches: int = 0                # coalesced dispatches
    lanes_dispatched: int = 0       # incl. bucket padding
    lanes_live: int = 0             # real query lanes
    walks: int = 0                  # walks returned to callers
    hops: int = 0                   # edges traversed in returned walks
    busy_s: float = 0.0             # total wall time inside dispatches
    shard_walk_drops: int = 0       # sharded serving: capacity-overflow lanes
    exchange_drops: int = 0         # sharded serving: ingest-exchange drops
    # ^ cumulative over the service lifetime; BOTH refresh per dispatch
    #   (and exchange_drops additionally at publish()), so they advance in
    #   lockstep — the old asymmetry where exchange_drops lagged until the
    #   next snapshot publish is gone. The §13 bit-identity guarantee
    #   needs BOTH at zero: walk drops lose lanes, exchange drops lose
    #   window edges.
    lanes_by_shard: Dict[int, int] = field(default_factory=dict)
    # ^ sharded batches, BOTH start modes: start lanes claimed per owner
    #   shard, counted on device inside ``serve_lanes_sharded`` (the
    #   walk_slots provisioning signal and the placement-imbalance gauge
    #   that ``SkewPlacement.from_loads`` consumes, DESIGN.md §15)
    latencies_s: Reservoir = field(
        default_factory=lambda: Reservoir(STATS_WINDOW))
    sample_s: Reservoir = field(
        default_factory=lambda: Reservoir(STATS_WINDOW))

    @property
    def dropped(self) -> int:
        return self.dropped_backpressure + self.dropped_oversize

    def latency_percentile(self, q: float) -> float:
        """q-th percentile of submit→complete latency over the bounded
        reservoir, in seconds. Contract (tested in tests/test_obs.py):
        empty reservoir -> nan for every q; a single sample -> that sample
        for every q; q outside [0, 100] -> ValueError."""
        return self.latencies_s.percentile(q)

    @property
    def p50_ms(self) -> float:
        return 1e3 * self.latency_percentile(50)

    @property
    def p99_ms(self) -> float:
        return 1e3 * self.latency_percentile(99)

    @property
    def walks_per_s(self) -> float:
        return self.walks / self.busy_s if self.busy_s > 0 else 0.0

    @property
    def steps_per_s(self) -> float:
        return self.hops / self.busy_s if self.busy_s > 0 else 0.0

    @property
    def lane_occupancy(self) -> float:
        """Live fraction of dispatched lanes (bucket-padding overhead)."""
        return (self.lanes_live / self.lanes_dispatched
                if self.lanes_dispatched else 0.0)


class WalkService:
    """Walk-query serving over a snapshot double-buffered window.

    The service owns a ``SnapshotManager`` (feed it edges via ``ingest`` /
    ``begin_ingest`` + ``publish``) and a fixed-capacity FIFO of pending
    queries. ``submit`` enqueues (or drops, under backpressure);
    ``step`` serves one coalesced batch; ``drain`` loops until empty.
    """

    def __init__(self, cfg: EngineConfig,
                 serve_cfg: ServeConfig = ServeConfig(),
                 state: Optional[WindowState] = None,
                 batch_capacity: int = 8192, *,
                 mesh=None, num_shards: int = 0, placement=None,
                 registry: Optional[MetricsRegistry] = None,
                 probes: bool = True):
        if list(serve_cfg.lane_buckets) != sorted(serve_cfg.lane_buckets) \
                or list(serve_cfg.length_buckets) != sorted(
                    serve_cfg.length_buckets):
            raise ValueError("ServeConfig buckets must be sorted ascending")
        self.cfg = cfg
        self.serve_cfg = serve_cfg
        # the tiled kernel compiles one bias per dispatch; serve on the
        # grouped path instead (same walks — tested path equivalence).
        # The fused kernel dispatches per-lane bias codes, so path="fused"
        # passes through and serves heterogeneous batches in-kernel.
        self.sched_cfg = (dataclasses.replace(cfg.scheduler, path="grouped")
                         if cfg.scheduler.path == "tiled" else cfg.scheduler)
        # bias='table' (or an explicit table_weight) opts the snapshot
        # buffers into alias-table maintenance (core/alias.py, §17)
        self._table = spec_from_sampler(cfg.sampler)
        self._rebuilt_seen = 0
        # every serving dispatch is a per-lane batch, so validate the
        # config against lane capabilities up front — the single
        # chokepoint (walk_engine.check_capabilities) refuses mode !=
        # 'index', config-level node2vec, and sharded table bias here
        # instead of mid-batch
        check_capabilities(
            cfg.sampler, self.sched_cfg.path, LaneFeatures(),
            sharded=mesh is not None or (num_shards
                                         or serve_cfg.num_shards) > 0,
            have_tables=self._table is not None)
        # obs integration (DESIGN.md §16); ``probes=False`` pins the
        # sharded dispatch to the historical uninstrumented program
        self.registry = registry if registry is not None else get_registry()
        self.probes = probes
        ns = num_shards or serve_cfg.num_shards
        self.sharded = mesh is not None or ns > 0
        if self.sharded:
            if state is not None:
                raise ValueError(
                    "sharded serving builds its own node-partitioned "
                    "window; the state= override is single-device only")
            self.snapshots = ShardedSnapshotManager(
                cfg, batch_capacity, mesh=mesh, num_shards=ns,
                placement=placement, registry=self.registry)
            self.batch_capacity = self.snapshots.batch_capacity
            self.num_shards = self.snapshots.num_shards
        else:
            if placement is not None:
                raise ValueError("placement= requires sharded serving "
                                 "(num_shards > 0 or mesh=)")
            self.batch_capacity = batch_capacity
            self.num_shards = 0
            self.snapshots = SnapshotManager(
                state if state is not None else init_window(
                    cfg.window.edge_capacity, cfg.window.node_capacity,
                    int(cfg.window.duration), table=self._table),
                cfg.window.node_capacity, registry=self.registry,
                table=self._table)
        # NOT split per call: lane RNG identity lives in (seed, walk, step)
        # folds, and solo/coalesced bit-equality needs a stable base.
        self.base_key = jax.random.PRNGKey(cfg.seed)
        self.stats = ServeStats()
        # drop-delta baseline: stats.exchange_drops is cumulative and may
        # be reset by callers, the registry needs monotonic deltas
        self._exchange_drops_seen = 0
        self._last_shard_claims: Optional[np.ndarray] = None
        self.placement = (self.snapshots.placement if self.sharded
                          else None)
        self._pending: Deque[Tuple[int, float, WalkQuery]] = deque()
        self._results: Dict[int, QueryResult] = {}
        self._next_ticket = 0

    # ------------------------------------------------------------------
    # Ingest side (snapshot double-buffer)
    # ------------------------------------------------------------------

    def ingest(self, src, dst, ts) -> None:
        """Advance the window synchronously (begin + publish)."""
        self.begin_ingest(src, dst, ts)
        self.publish()

    def begin_ingest(self, src, dst, ts) -> None:
        """Start building the next window; serving continues against the
        current snapshot until ``publish``."""
        batch = make_batch(src, dst, ts, capacity=self.batch_capacity)
        with span("ingest_merge", self.registry):
            self.snapshots.begin_ingest(batch)

    def publish(self) -> None:
        with span("snapshot_publish", self.registry):
            self.snapshots.publish()
        self.registry.set_gauge("snapshot_version", self.snapshots.version,
                                help="published serving snapshot version")
        if self.sharded:
            self._refresh_exchange_drops()
        elif self.snapshots.current.tables is not None:
            # same counter the streaming engine publishes (§17): incremental
            # maintenance work per advance, against a full-rebuild baseline
            rebuilt = int(self.snapshots.current.tables.rebuilt)
            self.registry.inc("alias_nodes_rebuilt_total",
                              max(0, rebuilt - self._rebuilt_seen),
                              help="alias-table node rebuilds performed by "
                                   "incremental window maintenance")
            self._rebuilt_seen = rebuilt

    def _refresh_exchange_drops(self) -> None:
        """Pull the sharded ingest's cumulative exchange-drop counter into
        the stats view + registry. Called per dispatch AND per publish, so
        ``exchange_drops`` advances in lockstep with ``shard_walk_drops``
        (sharded ingest drops edges — not lanes — on exchange overflow;
        they break bit-identity just like walk drops)."""
        total = int(np.asarray(self.snapshots.state.exchange_drops).sum())
        self.stats.exchange_drops = total
        count_drop(self.registry, "exchange_clip",
                   max(0, total - self._exchange_drops_seen))
        self._exchange_drops_seen = max(total, self._exchange_drops_seen)

    # ------------------------------------------------------------------
    # Query side
    # ------------------------------------------------------------------

    def _oversize(self, query: WalkQuery) -> bool:
        return (bucketize(query.num_lanes, self.serve_cfg.lane_buckets)
                is None
                or bucketize(query.max_length, self.serve_cfg.length_buckets)
                is None)

    def submit(self, query: WalkQuery, strict: bool = False) -> Optional[int]:
        """Enqueue a query; returns its ticket, or None when dropped.

        Drops (counted in ``stats``) happen when the fixed-capacity queue
        is full (backpressure) or the query exceeds the largest shape
        bucket. ``strict=True`` raises instead of dropping.

        Table-bias and second-order (node2vec) queries are validated
        against the service's capabilities here — always a raise, never a
        drop: unlike backpressure these can never succeed on retry.
        """
        if query.bias == "table" or query.second_order:
            check_capabilities(
                self.cfg.sampler, self.sched_cfg.path,
                LaneFeatures(table=query.bias == "table",
                             second_order=query.second_order),
                sharded=self.sharded,
                have_tables=(not self.sharded
                             and self.snapshots.current.tables is not None))
        if self._oversize(query):
            if strict or not self.serve_cfg.drop_oversize:
                raise ValueError(
                    f"query needs {query.num_lanes} lanes × "
                    f"{query.max_length} hops; largest bucket is "
                    f"{self.serve_cfg.lane_buckets[-1]} × "
                    f"{self.serve_cfg.length_buckets[-1]}")
            self.stats.dropped_oversize += 1
            count_drop(self.registry, "oversize")
            return None
        if len(self._pending) >= self.serve_cfg.queue_capacity:
            if strict:
                raise QueueFull(
                    f"{len(self._pending)} queries pending "
                    f"(capacity {self.serve_cfg.queue_capacity})")
            self.stats.dropped_backpressure += 1
            count_drop(self.registry, "queue_backpressure")
            return None
        ticket = self._next_ticket
        self._next_ticket += 1
        self._pending.append((ticket, time.perf_counter(), query))
        self.stats.submitted += 1
        self.registry.inc("serve_submitted_total", 1,
                          help="queries accepted into the serving queue")
        self.registry.set_gauge("serve_queue_depth", len(self._pending),
                                help="queries pending in the serving queue")
        return ticket

    def poll(self, ticket: int) -> Optional[QueryResult]:
        """Fetch (and forget) a completed query's result."""
        return self._results.pop(ticket, None)

    @property
    def pending_count(self) -> int:
        return len(self._pending)

    def _group_key(self, query: WalkQuery):
        return (query.start_mode,
                bucketize(query.max_length, self.serve_cfg.length_buckets))

    def _take_batch(self):
        """FIFO head-of-line group: the oldest query fixes the group key;
        fold in same-group queries (arrival order) up to the lane budget."""
        head_key = self._group_key(self._pending[0][2])
        budget = self.serve_cfg.lane_buckets[-1]
        taken, kept, lanes = [], deque(), 0
        for item in self._pending:
            q = item[2]
            if self._group_key(q) == head_key and lanes + q.num_lanes <= budget:
                taken.append(item)
                lanes += q.num_lanes
            else:
                kept.append(item)
        self._pending = kept
        return head_key, taken, lanes

    def _dispatch_lanes(self, params: LaneParams, wcfg: WalkConfig,
                        use_tables: bool = False,
                        second_order: bool = False):
        """Run one packed lane batch to completion; host (nodes, times,
        lengths). Single-device: ``generate_walk_lanes`` against the
        current snapshot. Sharded: ``serve_lanes_sharded`` against the
        (sharded window, ts-view) pair — psum-reassembled leaves are
        replicated, so row 0 is the batch result (DESIGN.md §13).

        ``use_tables`` / ``second_order`` flag whether any lane in the
        batch carries a table bias code / a non-trivial (p, q) pair —
        submit-time validation guarantees both are False on the sharded
        path. Passing tables to a batch with no table lanes (or compiling
        the second-order machinery for an all-first-order batch) would be
        harmless for correctness — the overlay selects per lane — but
        keeping the flags per batch pins the common case to the exact
        historical program."""
        if self.sharded:
            from repro.distributed.streaming_shard import serve_lanes_sharded
            snap = self.snapshots
            outs = serve_lanes_sharded(
                snap.state, snap.view, self.base_key, params,
                mesh=snap.mesh, axis_name=snap.axis_name,
                node_capacity=self.cfg.window.node_capacity, wcfg=wcfg,
                scfg=self.cfg.sampler, shard_cfg=self.cfg.shard,
                placement=snap.placement, with_probes=self.probes)
            if self.probes:
                nodes, times, lengths, drops, claims, sp = outs
            else:
                nodes, times, lengths, drops, claims = outs
            jax.block_until_ready(lengths)
            self.stats.shard_walk_drops += int(np.asarray(drops).sum())
            self._last_shard_claims = np.asarray(claims)
            if self.probes:
                # flushed at the dispatch's existing sync; the exchange
                # refresh keeps both sharded drop counters per-dispatch
                flush_serve_probes(self.registry, np.asarray(sp))
                self._refresh_exchange_drops()
            return (np.asarray(nodes)[0], np.asarray(times)[0],
                    np.asarray(lengths)[0])
        snap = self.snapshots.current
        res = generate_walk_lanes(snap.index, self.base_key, params, wcfg,
                                  self.cfg.sampler, self.sched_cfg,
                                  tables=snap.tables if use_tables else None,
                                  second_order=second_order)
        jax.block_until_ready(res.nodes)
        return result_arrays(res)

    def step(self) -> int:
        """Serve one coalesced batch; returns the number of queries served."""
        if not self._pending:
            return 0
        reg = self.registry
        with span("coalesce", reg):
            (start_mode, len_bucket), taken, lanes = self._take_batch()
            lane_bucket = bucketize(lanes, self.serve_cfg.lane_buckets)
            queries = [q for _, _, q in taken]
            params, slices = pack_queries(queries, lane_bucket, len_bucket)
        wcfg = WalkConfig(num_walks=lane_bucket, max_length=len_bucket,
                          start_mode=start_mode)
        version = self.snapshots.version
        t0 = time.perf_counter()
        with span("dispatch", reg):
            nodes, times, lengths = self._dispatch_lanes(
                params, wcfg,
                use_tables=any(q.bias == "table" for q in queries),
                second_order=any(q.second_order for q in queries))
        elapsed = time.perf_counter() - t0
        self.stats.sample_s.append(elapsed)
        self.stats.busy_s += elapsed
        done_t = time.perf_counter()
        self.stats.batches += 1
        self.stats.lanes_dispatched += lane_bucket
        self.stats.lanes_live += lanes
        reg.inc("serve_batches_total", 1,
                help="coalesced serving dispatches")
        reg.inc("walks_dispatched_total", lane_bucket,
                labels={"path": "serve"},
                help="walk slots dispatched, by sampling path")
        reg.observe("serve_batch_seconds", elapsed,
                    help="wall time per coalesced dispatch")
        reg.set_gauge("serve_lane_occupancy", self.stats.lane_occupancy,
                      help="live fraction of dispatched lanes")
        if self.sharded and self._last_shard_claims is not None:
            # device-side per-shard claim counters (serve_lanes_sharded):
            # unlike the old host-side owner fold this covers edges-mode
            # batches too, whose owners are data-dependent
            for d, n in enumerate(self._last_shard_claims):
                if n:
                    self.stats.lanes_by_shard[int(d)] = \
                        self.stats.lanes_by_shard.get(int(d), 0) + int(n)
        with span("result_slice", reg):
            for (ticket, arrival, q), sl in zip(taken, slices):
                qn, qt, ql = slice_result(nodes, times, lengths, sl, q)
                self._results[ticket] = QueryResult(
                    ticket=ticket, query=q, nodes=qn, times=qt, lengths=ql,
                    latency_s=done_t - arrival, snapshot_version=version)
                self.stats.completed += 1
                self.stats.walks += q.num_lanes
                self.stats.hops += int(np.sum(np.clip(ql - 1, 0, None)))
                self.stats.latencies_s.append(done_t - arrival)
                reg.observe("serve_latency_seconds", done_t - arrival,
                            help="submit -> complete latency per query")
        reg.inc("serve_completed_total", len(taken),
                help="queries completed")
        reg.set_gauge("serve_queue_depth", len(self._pending))
        return len(taken)

    def drain(self) -> List[QueryResult]:
        """Serve until the queue is empty; return all completed results."""
        while self._pending:
            self.step()
        out = list(self._results.values())
        self._results.clear()
        return out

    # ------------------------------------------------------------------
    # Reference path
    # ------------------------------------------------------------------

    def run_query_solo(self, query: WalkQuery):
        """Run one query alone at its exact shape (no coalescing, no
        bucketing) against the current snapshot. The per-lane RNG makes
        this bit-identical to the same query served coalesced — the
        equivalence the tests pin down (and, for a sharded service, also
        bit-identical to the single-device service's solo run).
        """
        params, (sl,) = pack_queries([query], query.num_lanes,
                                     query.max_length)
        wcfg = WalkConfig(num_walks=query.num_lanes,
                          max_length=query.max_length,
                          start_mode=query.start_mode)
        return slice_result(
            *self._dispatch_lanes(params, wcfg,
                                  use_tables=query.bias == "table",
                                  second_order=query.second_order),
            sl, query)
