"""Multi-tenant walk-query service over the streaming engine (DESIGN.md §11).

``WalkService`` is the front door the ROADMAP's "serve heavy traffic"
goal needs: many callers submit small heterogeneous ``WalkQuery``s; the
service queues them (fixed capacity, backpressure by drop + accounting),
coalesces compatible queries into one fixed-shape ``generate_walk_lanes``
dispatch per ``step()``, slices each tenant's rows back out, and tracks
p50/p99 submit→complete latency plus walks/s throughput.

Coalescing policy: head-of-line grouping — the head query (oldest under
FIFO admission, earliest-deadline under EDF) fixes the group key, then
same-group queries fold in along the admission order until the first one
that does not fit the lane budget seals the scan (the *prefix rule*).
Because the scan never skips a non-fitting query to admit a younger one,
**no query is ever overtaken by a younger same-group query** — the
fairness property tests/test_serve.py pins with hypothesis. A lone query
still rides a right-sized (small) bucket instead of the mega-batch shape.

**Async continuous-batching runtime** (DESIGN.md §18): dispatches no
longer block. A sealed batch launches on JAX async dispatch and joins a
bounded ring of in-flight futures, each pinned to the snapshot version it
launched against; ``pump()`` harvests completions (oldest first) at the
caller's pace, and ``tick()`` is the one-call event loop (evict expired →
harvest ready → seal + launch while the ring has room). A
partially-filled batch *lingers* up to ``ServeConfig.linger_s`` so
late-arriving same-group queries are admitted into it before it seals —
safe because the coalescer only decides *where* a lane sits, never *what*
it computes. ``step()`` keeps the historical synchronous semantics
(force-seal one batch, block until every in-flight batch is harvested),
which is also the bit-identity baseline the async path is tested against.

Determinism: results are bit-identical to running each query solo
(``run_query_solo``) because lane RNG folds by (query seed, walk id,
step) and the per-lane bias/length dispatch is pure per lane — the
coalescer only decides *where* a lane sits, never *what* it computes.

**Sharded serving** (DESIGN.md §13): with ``ServeConfig.num_shards > 0``
(or an explicit ``mesh``/``num_shards``), the same service runs against a
node-partitioned window: snapshots double-buffer a
``ShardedWindowState`` + replicated ts-view pair, and each coalesced
batch dispatches through ``serve_lanes_sharded`` — start lanes claimed by
their owner shards, per-hop owner migration, one psum trace reassembly —
with the *same* bit-identity guarantee against single-device solo runs.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.configs.base import EngineConfig, ServeConfig, WalkConfig
from repro.core.alias import spec_from_sampler
from repro.core.edge_store import make_batch
from repro.core.walk_engine import (
    LaneFeatures,
    LaneParams,
    check_capabilities,
    generate_walk_lanes,
)
from repro.core.window import WindowState, init_window
from repro.serve.coalescer import (
    bucketize,
    group_key,
    pack_queries,
    result_arrays,
    slice_result,
)
from repro.obs.probes import flush_serve_probes
from repro.obs.registry import (
    RESERVOIR_SIZE,
    MetricsRegistry,
    Reservoir,
    count_drop,
    get_registry,
)
from repro.obs.tracing import span
from repro.serve.query import QueryResult, WalkQuery
from repro.serve.snapshot import ShardedSnapshotManager, SnapshotManager


class QueueFull(RuntimeError):
    """Raised by ``submit(..., strict=True)`` when the queue is at capacity."""


class OversizeQuery(ValueError):
    """Raised by ``submit`` for a query exceeding the largest shape bucket
    when the service is configured (or asked) not to drop it silently —
    ``strict=True``, or ``ServeConfig.drop_oversize=False``. Unlike
    ``QueueFull`` this can never succeed on retry: the query needs a
    bigger bucket, not a quieter moment."""


@dataclass(frozen=True)
class _Pending:
    """One queued query: ticket, arrival clock, absolute deadline."""

    ticket: int
    arrival: float                   # time.perf_counter() at submit
    query: WalkQuery
    deadline: Optional[float] = None  # absolute perf_counter time, or None


@dataclass
class _InFlight:
    """One dispatched-but-unharvested batch in the async ring.

    ``raw`` holds the un-materialized device outputs (a ``WalkResult`` on
    the single-device path, the ``serve_lanes_sharded`` output tuple on
    the sharded path) — touching them would force a host sync, so only
    ``pump`` does. ``version`` is the snapshot version the batch was
    pinned to at launch; results report it even when ``publish()`` ran
    while the batch was in flight."""

    raw: object
    probe: object                    # one device array to poll readiness on
    taken: List[_Pending]
    slices: List[object]
    lane_bucket: int
    lanes: int
    version: int
    t0: float                        # launch clock


# percentile window: counters are lifetime totals, but the latency/batch
# samples backing p50/p99 are a bounded ring-buffer reservoir (the obs
# histogram backing store, obs/registry.py) so a long-running service
# neither grows without bound nor pays O(history) per stat read
STATS_WINDOW = RESERVOIR_SIZE


@dataclass
class ServeStats:
    """Serving counters + latency/throughput accounting."""

    submitted: int = 0
    completed: int = 0
    dropped_backpressure: int = 0   # queue at capacity
    dropped_oversize: int = 0       # exceeds the largest shape bucket
    #   (counts silent drops AND the typed refusals drop_oversize=False
    #   raises on non-strict submits — both are shed work; strict raises
    #   are the caller's own error handling and are not counted)
    dropped_deadline: int = 0       # queued past deadline_s -> evicted
    batches: int = 0                # coalesced dispatches
    lanes_dispatched: int = 0       # incl. bucket padding
    lanes_live: int = 0             # real query lanes
    walks: int = 0                  # walks returned to callers
    hops: int = 0                   # edges traversed in returned walks
    solo_queries: int = 0           # run_query_solo dispatches (accounted
    #   into walks/hops/busy_s like served traffic, so mixed solo+served
    #   workloads report true throughput)
    busy_s: float = 0.0             # total launch->harvest wall time; with
    #   overlapped dispatch (max_inflight > 1) in-flight intervals overlap
    #   so busy_s can exceed wall time and walks_per_s under-reports the
    #   overlapped rate — wall-clock goodput lives in the SLO harness
    shard_walk_drops: int = 0       # sharded serving: capacity-overflow lanes
    exchange_drops: int = 0         # sharded serving: ingest-exchange drops
    # ^ cumulative over the service lifetime; BOTH refresh per dispatch
    #   (and exchange_drops additionally at publish()), so they advance in
    #   lockstep — the old asymmetry where exchange_drops lagged until the
    #   next snapshot publish is gone. The §13 bit-identity guarantee
    #   needs BOTH at zero: walk drops lose lanes, exchange drops lose
    #   window edges.
    lanes_by_shard: Dict[int, int] = field(default_factory=dict)
    # ^ sharded batches, BOTH start modes: start lanes claimed per owner
    #   shard, counted on device inside ``serve_lanes_sharded`` (the
    #   walk_slots provisioning signal and the placement-imbalance gauge
    #   that ``SkewPlacement.from_loads`` consumes, DESIGN.md §15)
    latencies_s: Reservoir = field(
        default_factory=lambda: Reservoir(STATS_WINDOW))
    sample_s: Reservoir = field(
        default_factory=lambda: Reservoir(STATS_WINDOW))

    @property
    def dropped(self) -> int:
        return (self.dropped_backpressure + self.dropped_oversize
                + self.dropped_deadline)

    def latency_percentile(self, q: float) -> float:
        """q-th percentile of submit→complete latency over the bounded
        reservoir, in seconds. Contract (tested in tests/test_obs.py):
        empty reservoir -> nan for every q; a single sample -> that sample
        for every q; q outside [0, 100] -> ValueError."""
        return self.latencies_s.percentile(q)

    @property
    def p50_ms(self) -> float:
        return 1e3 * self.latency_percentile(50)

    @property
    def p99_ms(self) -> float:
        return 1e3 * self.latency_percentile(99)

    @property
    def walks_per_s(self) -> float:
        return self.walks / self.busy_s if self.busy_s > 0 else 0.0

    @property
    def steps_per_s(self) -> float:
        return self.hops / self.busy_s if self.busy_s > 0 else 0.0

    @property
    def lane_occupancy(self) -> float:
        """Live fraction of dispatched lanes (bucket-padding overhead)."""
        return (self.lanes_live / self.lanes_dispatched
                if self.lanes_dispatched else 0.0)


class WalkService:
    """Walk-query serving over a snapshot double-buffered window.

    The service owns a ``SnapshotManager`` (feed it edges via ``ingest`` /
    ``begin_ingest`` + ``publish``) and a fixed-capacity FIFO of pending
    queries. ``submit`` enqueues (or drops, under backpressure);
    ``step`` serves one coalesced batch; ``drain`` loops until empty.
    """

    def __init__(self, cfg: EngineConfig,
                 serve_cfg: ServeConfig = ServeConfig(),
                 state: Optional[WindowState] = None,
                 batch_capacity: int = 8192, *,
                 mesh=None, num_shards: int = 0, placement=None,
                 registry: Optional[MetricsRegistry] = None,
                 probes: bool = True):
        if list(serve_cfg.lane_buckets) != sorted(serve_cfg.lane_buckets) \
                or list(serve_cfg.length_buckets) != sorted(
                    serve_cfg.length_buckets):
            raise ValueError("ServeConfig buckets must be sorted ascending")
        if serve_cfg.max_inflight < 1:
            raise ValueError("ServeConfig.max_inflight must be >= 1 "
                             f"(got {serve_cfg.max_inflight})")
        if serve_cfg.linger_s < 0:
            raise ValueError("ServeConfig.linger_s must be >= 0 "
                             f"(got {serve_cfg.linger_s})")
        if serve_cfg.admission not in ("fifo", "edf"):
            raise ValueError("ServeConfig.admission must be 'fifo'|'edf' "
                             f"(got {serve_cfg.admission!r})")
        self.cfg = cfg
        self.serve_cfg = serve_cfg
        # the tiled kernel compiles one bias per dispatch; serve on the
        # grouped path instead (same walks — tested path equivalence).
        # The fused kernel dispatches per-lane bias codes, so path="fused"
        # passes through and serves heterogeneous batches in-kernel.
        self.sched_cfg = (dataclasses.replace(cfg.scheduler, path="grouped")
                         if cfg.scheduler.path == "tiled" else cfg.scheduler)
        # bias='table' (or an explicit table_weight) opts the snapshot
        # buffers into alias-table maintenance (core/alias.py, §17)
        self._table = spec_from_sampler(cfg.sampler)
        self._rebuilt_seen = 0
        # every serving dispatch is a per-lane batch, so validate the
        # config against lane capabilities up front — the single
        # chokepoint (walk_engine.check_capabilities) refuses mode !=
        # 'index', config-level node2vec, and sharded table bias here
        # instead of mid-batch
        check_capabilities(
            cfg.sampler, self.sched_cfg.path, LaneFeatures(),
            sharded=mesh is not None or (num_shards
                                         or serve_cfg.num_shards) > 0,
            have_tables=self._table is not None)
        # obs integration (DESIGN.md §16); ``probes=False`` pins the
        # sharded dispatch to the historical uninstrumented program
        self.registry = registry if registry is not None else get_registry()
        self.probes = probes
        ns = num_shards or serve_cfg.num_shards
        self.sharded = mesh is not None or ns > 0
        if self.sharded:
            if state is not None:
                raise ValueError(
                    "sharded serving builds its own node-partitioned "
                    "window; the state= override is single-device only")
            self.snapshots = ShardedSnapshotManager(
                cfg, batch_capacity, mesh=mesh, num_shards=ns,
                placement=placement, registry=self.registry)
            self.batch_capacity = self.snapshots.batch_capacity
            self.num_shards = self.snapshots.num_shards
        else:
            if placement is not None:
                raise ValueError("placement= requires sharded serving "
                                 "(num_shards > 0 or mesh=)")
            self.batch_capacity = batch_capacity
            self.num_shards = 0
            self.snapshots = SnapshotManager(
                state if state is not None else init_window(
                    cfg.window.edge_capacity, cfg.window.node_capacity,
                    int(cfg.window.duration), table=self._table),
                cfg.window.node_capacity, registry=self.registry,
                table=self._table)
        # NOT split per call: lane RNG identity lives in (seed, walk, step)
        # folds, and solo/coalesced bit-equality needs a stable base.
        self.base_key = jax.random.PRNGKey(cfg.seed)
        self.stats = ServeStats()
        # drop-delta baseline: stats.exchange_drops is cumulative and may
        # be reset by callers, the registry needs monotonic deltas
        self._exchange_drops_seen = 0
        self._last_shard_claims: Optional[np.ndarray] = None
        self.placement = (self.snapshots.placement if self.sharded
                          else None)
        self._pending: Deque[_Pending] = deque()
        self._inflight: Deque[_InFlight] = deque()
        self._results: Dict[int, QueryResult] = {}
        self._next_ticket = 0
        # when a drain() is active, tickets harvested during it land here
        # so the drain returns exactly the results it produced
        self._harvest_log: Optional[List[int]] = None

    # ------------------------------------------------------------------
    # Ingest side (snapshot double-buffer)
    # ------------------------------------------------------------------

    def ingest(self, src, dst, ts) -> None:
        """Advance the window synchronously (begin + publish)."""
        self.begin_ingest(src, dst, ts)
        self.publish()

    def begin_ingest(self, src, dst, ts) -> None:
        """Start building the next window; serving continues against the
        current snapshot until ``publish``."""
        batch = make_batch(src, dst, ts, capacity=self.batch_capacity)
        with span("ingest_merge", self.registry):
            self.snapshots.begin_ingest(batch)

    def publish(self) -> None:
        with span("snapshot_publish", self.registry):
            self.snapshots.publish()
        self.registry.set_gauge("snapshot_version", self.snapshots.version,
                                help="published serving snapshot version")
        if self.sharded:
            self._refresh_exchange_drops()
        elif self.snapshots.current.tables is not None:
            # same counter the streaming engine publishes (§17): incremental
            # maintenance work per advance, against a full-rebuild baseline
            rebuilt = int(self.snapshots.current.tables.rebuilt)
            self.registry.inc("alias_nodes_rebuilt_total",
                              max(0, rebuilt - self._rebuilt_seen),
                              help="alias-table node rebuilds performed by "
                                   "incremental window maintenance")
            self._rebuilt_seen = rebuilt

    def _refresh_exchange_drops(self) -> None:
        """Pull the sharded ingest's cumulative exchange-drop counter into
        the stats view + registry. Called per dispatch AND per publish, so
        ``exchange_drops`` advances in lockstep with ``shard_walk_drops``
        (sharded ingest drops edges — not lanes — on exchange overflow;
        they break bit-identity just like walk drops)."""
        total = int(np.asarray(self.snapshots.state.exchange_drops).sum())
        self.stats.exchange_drops = total
        count_drop(self.registry, "exchange_clip",
                   max(0, total - self._exchange_drops_seen))
        self._exchange_drops_seen = max(total, self._exchange_drops_seen)

    # ------------------------------------------------------------------
    # Query side
    # ------------------------------------------------------------------

    def _oversize(self, query: WalkQuery) -> bool:
        return (bucketize(query.num_lanes, self.serve_cfg.lane_buckets)
                is None
                or bucketize(query.max_length, self.serve_cfg.length_buckets)
                is None)

    def submit(self, query: WalkQuery, strict: bool = False) -> Optional[int]:
        """Enqueue a query; returns its ticket, or None when dropped.

        Oversize contract (all four ``strict`` × ``drop_oversize`` cells,
        tested in tests/test_serve.py):

        * ``strict=False, drop_oversize=True`` — silent drop: returns
          None, counted (``stats.dropped_oversize`` + the ``oversize``
          drop kind).
        * ``strict=False, drop_oversize=False`` — typed refusal: raises
          ``OversizeQuery``; still counted as shed work, because the
          service refused traffic mid-stream.
        * ``strict=True`` (either ``drop_oversize``) — raises
          ``OversizeQuery``, NOT counted: like a strict ``QueueFull``,
          the raise is the caller's own error handling, not a drop.

        Backpressure (queue at capacity) drops with ``strict=False`` and
        raises ``QueueFull`` with ``strict=True``. Queued queries whose
        ``deadline_s`` has expired are evicted first (counted as
        ``deadline_expired``), so a full queue of dead queries never
        causes spurious backpressure.

        Table-bias and second-order (node2vec) queries are validated
        against the service's capabilities here — always a raise, never a
        drop: unlike backpressure these can never succeed on retry.
        """
        if query.bias == "table" or query.second_order:
            check_capabilities(
                self.cfg.sampler, self.sched_cfg.path,
                LaneFeatures(table=query.bias == "table",
                             second_order=query.second_order),
                sharded=self.sharded,
                have_tables=(not self.sharded
                             and self.snapshots.current.tables is not None))
        now = time.perf_counter()
        self._evict_expired(now)
        if self._oversize(query):
            msg = (f"query needs {query.num_lanes} lanes × "
                   f"{query.max_length} hops; largest bucket is "
                   f"{self.serve_cfg.lane_buckets[-1]} × "
                   f"{self.serve_cfg.length_buckets[-1]}")
            if strict:
                raise OversizeQuery(msg)
            self.stats.dropped_oversize += 1
            count_drop(self.registry, "oversize")
            if not self.serve_cfg.drop_oversize:
                raise OversizeQuery(
                    msg + " (drop_oversize=False: refusing instead of "
                          "silently dropping)")
            return None
        if len(self._pending) >= self.serve_cfg.queue_capacity:
            if strict:
                raise QueueFull(
                    f"{len(self._pending)} queries pending "
                    f"(capacity {self.serve_cfg.queue_capacity})")
            self.stats.dropped_backpressure += 1
            count_drop(self.registry, "queue_backpressure")
            return None
        ticket = self._next_ticket
        self._next_ticket += 1
        deadline = (now + query.deadline_s
                    if query.deadline_s is not None else None)
        self._pending.append(_Pending(ticket, now, query, deadline))
        self.stats.submitted += 1
        self.registry.inc("serve_submitted_total", 1,
                          help="queries accepted into the serving queue")
        self.registry.set_gauge("serve_queue_depth", len(self._pending),
                                help="queries pending in the serving queue")
        return ticket

    def _evict_expired(self, now: float) -> int:
        """Evict queued queries past their deadline (DESIGN.md §18).

        Only *queued* queries are evicted — once sealed into a batch a
        query always completes (eviction is an admission decision, not a
        cancellation of in-flight device work)."""
        if not any(e.deadline is not None for e in self._pending):
            return 0
        kept: Deque[_Pending] = deque()
        evicted = 0
        for e in self._pending:
            if e.deadline is not None and now > e.deadline:
                evicted += 1
            else:
                kept.append(e)
        if evicted:
            self._pending = kept
            self.stats.dropped_deadline += evicted
            count_drop(self.registry, "deadline_expired", evicted)
            self.registry.set_gauge("serve_queue_depth", len(self._pending))
        return evicted

    def poll(self, ticket: int) -> Optional[QueryResult]:
        """Fetch (and forget) a completed query's result."""
        return self._results.pop(ticket, None)

    @property
    def pending_count(self) -> int:
        return len(self._pending)

    def _group_key(self, query: WalkQuery):
        return group_key(query, self.serve_cfg.length_buckets)

    def _admission_order(self) -> List[_Pending]:
        """Queue view in head-of-line order: arrival order under FIFO,
        (deadline, ticket) under EDF — deadline-free queries sort last and
        keep FIFO order among themselves."""
        if self.serve_cfg.admission == "fifo":
            return list(self._pending)
        return sorted(self._pending,
                      key=lambda e: (e.deadline if e.deadline is not None
                                     else math.inf, e.ticket))

    def _scan_group(self, order: Sequence[_Pending]):
        """The head query fixes the group key; same-group queries fold in
        along the admission order until the first one that does not fit
        the lane budget seals the scan (the prefix rule). Never skipping a
        non-fitting query to admit a later one is what makes the fairness
        claim true: a query can never be overtaken by a younger same-group
        query (property-tested in tests/test_serve.py)."""
        head_key = self._group_key(order[0].query)
        budget = self.serve_cfg.lane_buckets[-1]
        take: List[_Pending] = []
        lanes, sealed = 0, False
        for e in order:
            if self._group_key(e.query) != head_key:
                continue
            if lanes + e.query.num_lanes > budget:
                sealed = True
                break
            take.append(e)
            lanes += e.query.num_lanes
        return head_key, take, lanes, sealed

    def _form_batch(self, now: float, force: bool):
        """Seal one batch if the linger rule allows; returns ``(group
        key, taken, lanes)`` (and removes the taken queries from the
        queue) or None when the head batch should keep lingering.

        Seal rule (DESIGN.md §18): dispatch when the batch cannot grow —
        the scan hit a non-fitting same-group query or filled the lane
        budget exactly — or when the head query has lingered
        ``linger_s`` (0 = seal immediately), or when forced
        (``step``/``drain``)."""
        if not self._pending:
            return None
        order = self._admission_order()
        head_key, take, lanes, sealed = self._scan_group(order)
        budget = self.serve_cfg.lane_buckets[-1]
        if not (force or sealed or lanes >= budget
                or now - take[0].arrival >= self.serve_cfg.linger_s):
            return None
        taken_tickets = {e.ticket for e in take}
        self._pending = deque(e for e in self._pending
                              if e.ticket not in taken_tickets)
        return head_key, take, lanes

    def _take_batch(self):
        """Force-seal one batch now (the synchronous entry point)."""
        head_key, take, lanes = self._form_batch(time.perf_counter(),
                                                 force=True)
        return head_key, take, lanes

    def _launch_lanes(self, params: LaneParams, wcfg: WalkConfig, pin,
                      use_tables: bool = False, second_order: bool = False):
        """Enqueue one packed lane batch on the device WITHOUT waiting;
        returns the raw device outputs (a ``WalkResult`` single-device, the
        ``serve_lanes_sharded`` tuple sharded) against the pinned snapshot.

        ``use_tables`` / ``second_order`` flag whether any lane in the
        batch carries a table bias code / a non-trivial (p, q) pair —
        submit-time validation guarantees both are False on the sharded
        path. Passing tables to a batch with no table lanes (or compiling
        the second-order machinery for an all-first-order batch) would be
        harmless for correctness — the overlay selects per lane — but
        keeping the flags per batch pins the common case to the exact
        historical program."""
        if self.sharded:
            from repro.distributed.streaming_shard import serve_lanes_sharded
            snap = self.snapshots
            return serve_lanes_sharded(
                pin.state, pin.view, self.base_key, params,
                mesh=snap.mesh, axis_name=snap.axis_name,
                node_capacity=self.cfg.window.node_capacity, wcfg=wcfg,
                scfg=self.cfg.sampler, shard_cfg=self.cfg.shard,
                placement=snap.placement, with_probes=self.probes)
        snap = pin.state
        return generate_walk_lanes(snap.index, self.base_key, params, wcfg,
                                   self.cfg.sampler, self.sched_cfg,
                                   tables=snap.tables if use_tables else None,
                                   second_order=second_order)

    def _materialize(self, raw):
        """Block on one launched batch and bring it to host: (nodes,
        times, lengths) arrays, plus the sharded drop/claim/probe
        bookkeeping at this (the batch's only) host sync point.
        Sharded psum-reassembled leaves are replicated, so row 0 is the
        batch result (DESIGN.md §13)."""
        if self.sharded:
            if self.probes:
                nodes, times, lengths, drops, claims, sp = raw
            else:
                nodes, times, lengths, drops, claims = raw
            jax.block_until_ready(lengths)
            self.stats.shard_walk_drops += int(np.asarray(drops).sum())
            self._last_shard_claims = np.asarray(claims)
            if self.probes:
                # flushed at the batch's existing sync; the exchange
                # refresh keeps both sharded drop counters per-harvest
                flush_serve_probes(self.registry, np.asarray(sp))
                self._refresh_exchange_drops()
            # device-side per-shard claim counters (serve_lanes_sharded):
            # unlike the old host-side owner fold this covers edges-mode
            # batches too, whose owners are data-dependent
            for d, n in enumerate(self._last_shard_claims):
                if n:
                    self.stats.lanes_by_shard[int(d)] = \
                        self.stats.lanes_by_shard.get(int(d), 0) + int(n)
            return (np.asarray(nodes)[0], np.asarray(times)[0],
                    np.asarray(lengths)[0])
        jax.block_until_ready(raw.nodes)
        return result_arrays(raw)

    def _dispatch_lanes(self, params: LaneParams, wcfg: WalkConfig,
                        use_tables: bool = False,
                        second_order: bool = False):
        """Blocking convenience (the reference/solo path): launch one lane
        batch against the current snapshot and wait for it."""
        raw = self._launch_lanes(params, wcfg, self.snapshots.acquire(),
                                 use_tables=use_tables,
                                 second_order=second_order)
        return self._materialize(raw)

    # ------------------------------------------------------------------
    # Async runtime: launch ring + pump loop (DESIGN.md §18)
    # ------------------------------------------------------------------

    def _launch(self, batch) -> int:
        """Pack a sealed batch and enqueue it on the device; the batch
        joins the in-flight ring pinned to the current snapshot version.
        Returns the number of queries admitted into it."""
        reg = self.registry
        (start_mode, len_bucket), taken, lanes = batch
        with span("coalesce", reg):
            lane_bucket = bucketize(lanes, self.serve_cfg.lane_buckets)
            queries = [e.query for e in taken]
            params, slices = pack_queries(queries, lane_bucket, len_bucket)
        wcfg = WalkConfig(num_walks=lane_bucket, max_length=len_bucket,
                          start_mode=start_mode)
        pin = self.snapshots.acquire()
        t0 = time.perf_counter()
        with span("dispatch", reg):
            raw = self._launch_lanes(
                params, wcfg, pin,
                use_tables=any(q.bias == "table" for q in queries),
                second_order=any(q.second_order for q in queries))
        probe = raw[2] if self.sharded else raw.lengths
        self._inflight.append(_InFlight(
            raw=raw, probe=probe, taken=list(taken), slices=list(slices),
            lane_bucket=lane_bucket, lanes=lanes, version=pin.version,
            t0=t0))
        self.stats.batches += 1
        self.stats.lanes_dispatched += lane_bucket
        self.stats.lanes_live += lanes
        reg.inc("serve_batches_total", 1,
                help="coalesced serving dispatches")
        reg.inc("walks_dispatched_total", lane_bucket,
                labels={"path": "serve"},
                help="walk slots dispatched, by sampling path")
        reg.set_gauge("serve_lane_occupancy", self.stats.lane_occupancy,
                      help="live fraction of dispatched lanes")
        reg.set_gauge("serve_queue_depth", len(self._pending))
        reg.set_gauge("serve_inflight_depth", len(self._inflight),
                      help="dispatched batches not yet harvested")
        return len(taken)

    @staticmethod
    def _batch_ready(fl: _InFlight) -> bool:
        """Non-blocking readiness probe on one in-flight batch. Older
        runtimes without ``jax.Array.is_ready`` degrade to "always ready"
        — harvest then blocks, which is correct, just overlap-free."""
        is_ready = getattr(fl.probe, "is_ready", None)
        return True if is_ready is None else bool(is_ready())

    def _harvest(self, fl: _InFlight) -> int:
        """Materialize one in-flight batch and deliver its results."""
        reg = self.registry
        nodes, times, lengths = self._materialize(fl.raw)
        done_t = time.perf_counter()
        elapsed = done_t - fl.t0
        self.stats.sample_s.append(elapsed)
        self.stats.busy_s += elapsed
        reg.observe("serve_batch_seconds", elapsed,
                    help="launch -> harvest wall time per coalesced batch")
        with span("result_slice", reg):
            for e, sl in zip(fl.taken, fl.slices):
                qn, qt, ql = slice_result(nodes, times, lengths, sl, e.query)
                self._results[e.ticket] = QueryResult(
                    ticket=e.ticket, query=e.query, nodes=qn, times=qt,
                    lengths=ql, latency_s=done_t - e.arrival,
                    snapshot_version=fl.version)
                if self._harvest_log is not None:
                    self._harvest_log.append(e.ticket)
                self.stats.completed += 1
                self.stats.walks += e.query.num_lanes
                self.stats.hops += int(np.sum(np.clip(ql - 1, 0, None)))
                self.stats.latencies_s.append(done_t - e.arrival)
                reg.observe("serve_latency_seconds", done_t - e.arrival,
                            help="submit -> complete latency per query")
        reg.inc("serve_completed_total", len(fl.taken),
                help="queries completed")
        reg.set_gauge("serve_inflight_depth", len(self._inflight))
        return len(fl.taken)

    @property
    def inflight_count(self) -> int:
        return len(self._inflight)

    def pump(self, block: bool = False) -> int:
        """Harvest completed in-flight batches, oldest first; returns the
        number of queries completed. ``block=False`` stops at the first
        batch whose device work is still running; ``block=True`` waits for
        the whole ring (the sync point ``step``/``drain`` use)."""
        done = 0
        while self._inflight:
            if not block and not self._batch_ready(self._inflight[0]):
                break
            done += self._harvest(self._inflight.popleft())
        return done

    def tick(self, now: Optional[float] = None) -> int:
        """One turn of the async event loop: evict expired queries,
        harvest every ready batch, then seal + launch batches while the
        in-flight ring has room and the linger rule allows. Never blocks.
        Returns the number of queries completed this tick.

        The open-loop caller pattern (benchmarks/serving_load.py)::

            while traffic or svc.pending_count or svc.inflight_count:
                svc.submit(...)     # as arrivals come in
                svc.tick()
            svc.pump(block=True)    # final sync
        """
        if now is None:
            now = time.perf_counter()
        self._evict_expired(now)
        done = self.pump(block=False)
        while (self._pending
               and len(self._inflight) < self.serve_cfg.max_inflight):
            batch = self._form_batch(now, force=False)
            if batch is None:
                break                      # head batch keeps lingering
            self._launch(batch)
        return done

    def step(self) -> int:
        """Serve one coalesced batch synchronously; returns the number of
        queries in it. Force-seals (ignores the linger deadline), then
        blocks until every in-flight batch — including any launched by
        earlier ``tick`` calls — is harvested. With ``max_inflight=1``
        and no ``tick``/``pump`` use this is exactly the historical
        blocking FIFO loop, which is the bit-identity baseline the async
        path is regression-tested against."""
        self._evict_expired(time.perf_counter())
        if not self._pending:
            self.pump(block=True)
            return 0
        if len(self._inflight) >= self.serve_cfg.max_inflight:
            self.pump(block=True)
        n = self._launch(self._take_batch())
        self.pump(block=True)
        return n

    def drain(self) -> List[QueryResult]:
        """Serve until the queue and the in-flight ring are empty; return
        the results of exactly the queries completed during THIS drain.

        Results completed by earlier ``step``/``tick`` calls stay in the
        poll buffer — their tickets remain ``poll``-able after the drain
        (the poll-after-drain contract, regression-tested in
        tests/test_serve.py). The returned results are popped: their
        tickets are delivered, not double-pollable."""
        log: List[int] = []
        outer = self._harvest_log
        self._harvest_log = log
        try:
            while self._pending or self._inflight:
                self._evict_expired(time.perf_counter())
                if (self._pending
                        and len(self._inflight)
                        < self.serve_cfg.max_inflight):
                    batch = self._form_batch(time.perf_counter(),
                                             force=True)
                    if batch is not None:
                        self._launch(batch)
                        continue
                self.pump(block=True)
        finally:
            self._harvest_log = outer
        if outer is not None:
            outer.extend(log)
        return [self._results.pop(t) for t in log if t in self._results]

    # ------------------------------------------------------------------
    # Reference path
    # ------------------------------------------------------------------

    def run_query_solo(self, query: WalkQuery):
        """Run one query alone at its exact shape (no coalescing, no
        bucketing) against the current snapshot. The per-lane RNG makes
        this bit-identical to the same query served coalesced — the
        equivalence the tests pin down (and, for a sharded service, also
        bit-identical to the single-device service's solo run).

        Solo runs ARE accounted: ``stats.solo_queries`` plus the shared
        walks / hops / busy_s totals and the ``path="solo"`` dispatch
        counter, so a mixed solo+served workload reports true throughput
        instead of silently attributing solo device time to nothing.
        They do not touch the queue/latency accounting (nothing was
        queued) or ``completed`` (no ticket is issued).
        """
        params, (sl,) = pack_queries([query], query.num_lanes,
                                     query.max_length)
        wcfg = WalkConfig(num_walks=query.num_lanes,
                          max_length=query.max_length,
                          start_mode=query.start_mode)
        t0 = time.perf_counter()
        out = slice_result(
            *self._dispatch_lanes(params, wcfg,
                                  use_tables=query.bias == "table",
                                  second_order=query.second_order),
            sl, query)
        elapsed = time.perf_counter() - t0
        self.stats.solo_queries += 1
        self.stats.walks += query.num_lanes
        self.stats.hops += int(np.sum(np.clip(out[2] - 1, 0, None)))
        self.stats.busy_s += elapsed
        self.stats.sample_s.append(elapsed)
        self.registry.inc("walks_dispatched_total", query.num_lanes,
                          labels={"path": "solo"},
                          help="walk slots dispatched, by sampling path")
        return out
