"""Walk-query request model for the serving subsystem (DESIGN.md §11).

A ``WalkQuery`` is one tenant's request against the current window
snapshot: its own start nodes (or start-edge bias), hop bias, maximum
length, and RNG seed. The coalescer packs many queries into one
fixed-shape lane batch; because every lane's randomness is a pure function
of (query seed, walk-within-query, step) — see
``walk_engine.LaneParams`` — the answer a query receives is bit-identical
whether it ran solo or packed with arbitrary other traffic.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from repro.core.samplers import BIAS_CODES

START_MODES = ("nodes", "edges")
_INT32_MIN, _INT32_MAX = -(1 << 31), (1 << 31) - 1


@dataclass(frozen=True)
class WalkQuery:
    """One walk request.

    ``start_mode="nodes"``: one lane per entry of ``start_nodes``.
    ``start_mode="edges"``: ``num_walks`` lanes, each starting from an
    edge drawn under ``start_bias`` over the timestamp view.

    ``seed`` is the request's RNG identity: resubmitting the same query
    against the same snapshot reproduces the same walks exactly,
    regardless of what else shares the batch.
    """

    start_nodes: Tuple[int, ...] = ()
    bias: str = "exponential"          # uniform | linear | exponential | table
    max_length: int = 16               # per-walk hop budget (≤ edges emitted)
    seed: int = 0
    start_mode: str = "nodes"          # nodes | edges
    start_bias: str = "uniform"        # edges mode: bias over start edges
    num_walks: int = 0                 # edges mode: lane count
    # second-order node2vec return/in-out parameters (1.0, 1.0 disables;
    # any other pair turns on the rejection-sampled second-order draw for
    # this query's lanes only — co-batched first-order queries are
    # untouched, the solo/coalesced bit-identity holds either way)
    n2v_p: float = 1.0
    n2v_q: float = 1.0
    # SLO deadline (DESIGN.md §18), in seconds from submit; None = none.
    # A query still *queued* past its deadline is evicted (counted as a
    # ``deadline_expired`` drop) instead of wasting a dispatch on an
    # answer nobody will read. Once sealed into a batch it always
    # completes — eviction is an admission decision, not a cancellation.
    # Under ``ServeConfig.admission="edf"`` the deadline also orders the
    # queue (earliest first).
    deadline_s: Optional[float] = None

    def __post_init__(self):
        if self.bias not in BIAS_CODES:
            raise ValueError(f"unknown bias {self.bias!r} "
                             f"(expected one of {sorted(BIAS_CODES)})")
        # "table" is a valid hop bias (the service checks it against the
        # snapshot's tables at submit) but never a start bias: alias
        # tables cover per-node neighborhood regions, not the global
        # timestamp view that start-edge draws sample.
        if self.start_bias == "table" or self.start_bias not in BIAS_CODES:
            raise ValueError(f"unknown start_bias {self.start_bias!r} "
                             "(expected 'uniform'|'linear'|'exponential')")
        if not (self.n2v_p > 0.0 and self.n2v_q > 0.0):
            raise ValueError(
                f"node2vec parameters must be positive, got "
                f"p={self.n2v_p}, q={self.n2v_q}")
        if self.start_mode not in START_MODES:
            raise ValueError(f"unknown start_mode {self.start_mode!r} "
                             f"(expected one of {START_MODES})")
        if self.deadline_s is not None and not self.deadline_s > 0.0:
            raise ValueError(
                f"deadline_s must be positive (got {self.deadline_s}); "
                "omit it (None) for no deadline")
        if self.max_length < 1:
            raise ValueError("max_length must be >= 1")
        # the lane arrays are int32: reject values that cannot round-trip
        # (otherwise pack_queries would throw mid-batch, after innocent
        # co-batched queries were already popped from the pending queue)
        if not _INT32_MIN <= self.seed <= _INT32_MAX:
            raise ValueError(f"seed {self.seed} does not fit int32")
        if self.start_mode == "nodes":
            if not self.start_nodes:
                raise ValueError("start_mode='nodes' requires start_nodes")
            for v in self.start_nodes:
                if not _INT32_MIN <= v <= _INT32_MAX:
                    raise ValueError(f"start node {v} does not fit int32")
        elif self.num_walks < 1:
            raise ValueError("start_mode='edges' requires num_walks >= 1")

    @property
    def num_lanes(self) -> int:
        """Walk lanes this query occupies in a coalesced batch."""
        return (len(self.start_nodes) if self.start_mode == "nodes"
                else self.num_walks)

    @property
    def second_order(self) -> bool:
        """True when this query's lanes draw under node2vec (p, q)."""
        return self.n2v_p != 1.0 or self.n2v_q != 1.0


@dataclass(frozen=True)
class QueryResult:
    """A completed query: per-walk arrays sliced back out of the coalesced
    batch, trimmed to the query's own ``max_length + 1`` columns.

    ``snapshot_version`` is the ``SnapshotManager.version`` the batch ran
    against — the snapshot-consistency handle: every edge in this result
    came from that one window version, never a mix across ``publish()``.
    """

    ticket: int
    query: WalkQuery
    nodes: np.ndarray        # int32[num_lanes, max_length+1], NODE_PAD tail
    times: np.ndarray        # int32[num_lanes, max_length+1]
    lengths: np.ndarray      # int32[num_lanes]
    latency_s: float         # submit -> completion wall time
    snapshot_version: int = -1
