"""Snapshot double-buffer: serve walks against a consistent window while
the next ingest step builds (DESIGN.md §11).

The streaming engine's donating ``ingest`` consumes its input state — the
right call in a pure replay loop, and exactly wrong for serving, where
in-flight queries must keep reading the window they were admitted
against. The ``SnapshotManager`` therefore advances the window through
the **non-donating** merge ingest (``window.ingest_nodonate``, same math,
byte-identical output):

* ``current`` — the front buffer. Immutable from the service's point of
  view; every coalesced batch runs against it.
* ``begin_ingest(batch)`` — dispatches the merge ingest into the back
  buffer and returns immediately (JAX async dispatch): the device builds
  the next window while the host keeps coalescing and dispatching walk
  batches against ``current``.
* ``publish()`` — waits for the back buffer and swaps it in atomically.
  Queries admitted before the swap saw the old window; queries admitted
  after see the new one. No query ever observes a half-ingested state.

Two windows are alive at the swap point — the double-buffer's memory
cost — and the old one is released to the allocator as soon as the last
reference drops.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.core.edge_store import EdgeBatch
from repro.core.window import WindowState, ingest_nodonate


class SnapshotManager:
    """Double-buffered ``WindowState`` for the serving layer."""

    def __init__(self, state: WindowState, node_capacity: int):
        self.current = state
        self.node_capacity = node_capacity
        self.version = 0          # bumped at every publish
        self._next: Optional[WindowState] = None

    @property
    def ingest_in_flight(self) -> bool:
        return self._next is not None

    def begin_ingest(self, batch: EdgeBatch) -> None:
        """Start building the next window; ``current`` stays serveable."""
        if self._next is not None:
            raise RuntimeError("an ingest is already in flight; publish() "
                               "or discard() it first")
        self._next = ingest_nodonate(self.current, batch, self.node_capacity)

    def publish(self) -> WindowState:
        """Wait for the in-flight ingest and swap it in as ``current``."""
        if self._next is None:
            raise RuntimeError("no ingest in flight; call begin_ingest first")
        jax.block_until_ready(self._next.index.ns_order)
        self.current, self._next = self._next, None
        self.version += 1
        return self.current

    def discard(self) -> None:
        """Drop an in-flight ingest without publishing it."""
        self._next = None

    def ingest(self, batch: EdgeBatch) -> WindowState:
        """Synchronous convenience: begin + publish in one call."""
        self.begin_ingest(batch)
        return self.publish()
