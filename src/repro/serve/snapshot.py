"""Snapshot double-buffer: serve walks against a consistent window while
the next ingest step builds (DESIGN.md §11).

The streaming engine's donating ``ingest`` consumes its input state — the
right call in a pure replay loop, and exactly wrong for serving, where
in-flight queries must keep reading the window they were admitted
against. The ``SnapshotManager`` therefore advances the window through
the **non-donating** merge ingest (``window.ingest_nodonate``, same math,
byte-identical output):

* ``current`` — the front buffer. Immutable from the service's point of
  view; every coalesced batch runs against it.
* ``begin_ingest(batch)`` — dispatches the merge ingest into the back
  buffer and returns immediately (JAX async dispatch): the device builds
  the next window while the host keeps coalescing and dispatching walk
  batches against ``current``.
* ``publish()`` — waits for the back buffer and swaps it in atomically.
  Queries admitted before the swap saw the old window; queries admitted
  after see the new one. No query ever observes a half-ingested state.

Two windows are alive at the swap point — the double-buffer's memory
cost — and the old one is released to the allocator as soon as the last
reference drops.

``ShardedSnapshotManager`` is the same protocol one level up (DESIGN.md
§13): the front buffer is a node-partitioned ``ShardedWindowState`` plus
the replicated ``TsView`` start directory, advanced together through the
non-donating sharded ingest (one all_to_all, pmax-agreed eviction
watermark) and the view merge. ``publish()`` swaps both atomically, so a
coalesced sharded batch never sees the per-shard windows and the start
directory at different versions — the cross-shard consistency the
watermark protocol guarantees within one version. Two sharded windows
(plus two 3-column views) are alive at the swap point.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax

from repro.configs.base import EngineConfig
from repro.core.edge_store import EdgeBatch
from repro.core.window import (
    TsView,
    WindowState,
    advance_view,
    ingest_nodonate,
    init_view,
)
from repro.obs.registry import MetricsRegistry, get_registry


class PinnedSnapshot(NamedTuple):
    """A ``(window state, version)`` pair captured at dispatch time.

    The async runtime (DESIGN.md §18) keeps batches in flight across
    ``publish()`` calls: each in-flight batch holds one of these, so the
    device computation it enqueued keeps the exact buffers it launched
    against alive (functional JAX arrays — the swap can't mutate them)
    and its results report the version they were computed at, never the
    version current at harvest time.
    """

    state: WindowState
    version: int


class PinnedShardedSnapshot(NamedTuple):
    """Sharded twin of ``PinnedSnapshot``: the (sharded window, replicated
    ts-view) pair always belongs to ONE published version — pinning them
    together is what keeps an in-flight sharded batch from seeing the
    per-shard windows and the start directory at different versions."""

    state: object
    view: TsView
    version: int


class SnapshotManager:
    """Double-buffered ``WindowState`` for the serving layer.

    ``table`` (a ``core.alias.TableSpec``) opts the buffer into alias-
    table maintenance: every ``begin_ingest`` rebuilds only the nodes
    whose neighborhood region changed (DESIGN.md §17), so the published
    snapshot always carries tables consistent with its window and
    table-bias lane batches can draw O(1) against ``current.tables``.
    The spec must be fixed for the life of the manager — incremental
    maintenance is only valid against tables built under the same spec.
    """

    def __init__(self, state: WindowState, node_capacity: int,
                 registry: Optional[MetricsRegistry] = None,
                 table=None):
        self.current = state
        self.node_capacity = node_capacity
        self.table = table
        self.registry = registry if registry is not None else get_registry()
        self.version = 0          # bumped at every publish
        self._next: Optional[WindowState] = None

    @property
    def ingest_in_flight(self) -> bool:
        return self._next is not None

    def begin_ingest(self, batch: EdgeBatch) -> None:
        """Start building the next window; ``current`` stays serveable."""
        if self._next is not None:
            raise RuntimeError("an ingest is already in flight; publish() "
                               "or discard() it first")
        self._next = ingest_nodonate(self.current, batch, self.node_capacity,
                                     table=self.table)

    def publish(self) -> WindowState:
        """Wait for the in-flight ingest and swap it in as ``current``."""
        if self._next is None:
            raise RuntimeError("no ingest in flight; call begin_ingest first")
        jax.block_until_ready(self._next.index.ns_order)
        self.current, self._next = self._next, None
        self.version += 1
        self.registry.inc("snapshot_publishes_total", 1,
                          help="serving snapshot buffer swaps")
        return self.current

    def discard(self) -> None:
        """Drop an in-flight ingest without publishing it."""
        self._next = None

    def acquire(self) -> PinnedSnapshot:
        """Pin the current (state, version) pair for an async dispatch."""
        return PinnedSnapshot(self.current, self.version)

    def ingest(self, batch: EdgeBatch) -> WindowState:
        """Synchronous convenience: begin + publish in one call."""
        self.begin_ingest(batch)
        return self.publish()


class ShardedSnapshotManager:
    """Double-buffered node-partitioned window + replicated ts-view.

    The serving front end for ``DistributedStreamingEngine``-style state:
    ``state`` (sharded window slices) and ``view`` (replicated global
    start directory) always belong to the same published version. Batches
    are split D-ways on the batch axis exactly like the engine's ingest;
    the next version builds through ``ingest_sharded_nodonate`` (per-shard
    merge against the pmax-agreed watermark) while the current one keeps
    serving coalesced lane batches.
    """

    def __init__(self, cfg: EngineConfig, batch_capacity: int = 8192, *,
                 mesh=None, num_shards: int = 0, placement=None,
                 registry: Optional[MetricsRegistry] = None):
        from repro.distributed.placement import make_placement
        from repro.distributed.streaming_shard import (
            init_sharded_window,
            window_mesh,
        )
        self.cfg = cfg
        self.mesh = mesh if mesh is not None else window_mesh(
            num_shards or cfg.shard.num_shards)
        self.axis_name = self.mesh.axis_names[0]
        D = self.mesh.devices.size
        self.num_shards = D
        # one placement object routes ingest bucketing AND lane claims, so
        # the window layout and the serving claim rule can never diverge
        self.placement = placement if placement is not None else \
            make_placement(cfg.shard.placement, D, cfg.window.node_capacity,
                           hash_buckets=cfg.shard.hash_buckets)
        # per-shard batch slice: round the capacity up to a D multiple
        self.batch_slice = -(-batch_capacity // D)
        self.batch_capacity = self.batch_slice * D
        self.node_capacity = cfg.window.node_capacity
        self.state = init_sharded_window(
            D, cfg.shard.edge_capacity_per_shard, self.node_capacity,
            int(cfg.window.duration), mesh=self.mesh,
            axis_name=self.axis_name)
        self.view = init_view(cfg.window.edge_capacity, self.node_capacity,
                              int(cfg.window.duration))
        self.registry = registry if registry is not None else get_registry()
        self.version = 0          # bumped at every publish
        self._next: Optional[Tuple[object, TsView]] = None

    @property
    def ingest_in_flight(self) -> bool:
        return self._next is not None

    def begin_ingest(self, batch: EdgeBatch) -> None:
        """Start building the next (sharded window, view) pair; the
        current pair stays serveable until ``publish``."""
        from repro.distributed.streaming_shard import ingest_sharded_nodonate
        if self._next is not None:
            raise RuntimeError("an ingest is already in flight; publish() "
                               "or discard() it first")
        if batch.src.shape[0] != self.batch_capacity:
            raise ValueError(
                f"batch capacity {batch.src.shape[0]} != manager capacity "
                f"{self.batch_capacity} (must be the D-rounded capacity)")
        split = lambda a: a.reshape(self.num_shards, self.batch_slice)
        nstate = ingest_sharded_nodonate(
            self.state, split(batch.src), split(batch.dst), split(batch.ts),
            batch.count, mesh=self.mesh, axis_name=self.axis_name,
            node_capacity=self.node_capacity, shard_cfg=self.cfg.shard,
            placement=self.placement)
        nview = advance_view(self.view, batch, self.node_capacity)
        self._next = (nstate, nview)

    def publish(self):
        """Wait for the in-flight ingest and swap both buffers in."""
        if self._next is None:
            raise RuntimeError("no ingest in flight; call begin_ingest first")
        jax.block_until_ready(self._next[0].window.index.ns_order)
        jax.block_until_ready(self._next[1].store.ts)
        self.state, self.view = self._next
        self._next = None
        self.version += 1
        self.registry.inc("snapshot_publishes_total", 1,
                          help="serving snapshot buffer swaps")
        return self.state

    def discard(self) -> None:
        """Drop an in-flight ingest without publishing it."""
        self._next = None

    def acquire(self) -> PinnedShardedSnapshot:
        """Pin the current (state, view, version) triple for an async
        dispatch — both halves from the same published version."""
        return PinnedShardedSnapshot(self.state, self.view, self.version)

    def ingest(self, batch: EdgeBatch):
        """Synchronous convenience: begin + publish in one call."""
        self.begin_ingest(batch)
        return self.publish()
