"""Walk-query serving subsystem (DESIGN.md §11): multi-tenant request
coalescing over the streaming engine.

* ``WalkQuery`` / ``QueryResult`` — the request model (per-request bias,
  max length, seed, start nodes).
* coalescer — shape-bucketed packing of many queries into one
  fixed-shape ``generate_walk_lanes`` dispatch, plus result slicing.
* ``SnapshotManager`` — window double-buffer: serve against a consistent
  snapshot while the next ingest builds.
* ``ShardedSnapshotManager`` — the same protocol over a node-partitioned
  window + replicated ts-view (sharded serving, DESIGN.md §13).
* ``WalkService`` — the service loop: fixed-capacity queue with
  backpressure + drop accounting, FIFO/EDF coalescing, p50/p99 latency
  and walks/s stats; single-device by default, node-partitioned with
  ``num_shards``/``mesh`` (or ``ServeConfig.num_shards``). The async
  continuous-batching runtime (DESIGN.md §18) overlaps dispatch with
  ingest: ``tick``/``pump`` drive a bounded in-flight ring, ``step`` is
  the synchronous baseline.
"""
from repro.serve.coalescer import (
    LaneSlice,
    bucketize,
    group_key,
    lane_owners,
    pack_queries,
    slice_result,
)
from repro.serve.query import QueryResult, WalkQuery
from repro.serve.service import (
    OversizeQuery,
    QueueFull,
    ServeStats,
    WalkService,
)
from repro.serve.snapshot import (
    PinnedShardedSnapshot,
    PinnedSnapshot,
    ShardedSnapshotManager,
    SnapshotManager,
)

__all__ = [
    "LaneSlice", "bucketize", "group_key", "lane_owners", "pack_queries",
    "slice_result", "QueryResult", "WalkQuery", "OversizeQuery", "QueueFull",
    "ServeStats", "WalkService", "PinnedSnapshot", "PinnedShardedSnapshot",
    "SnapshotManager", "ShardedSnapshotManager",
]
