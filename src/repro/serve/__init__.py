"""Walk-query serving subsystem (DESIGN.md §11): multi-tenant request
coalescing over the streaming engine.

* ``WalkQuery`` / ``QueryResult`` — the request model (per-request bias,
  max length, seed, start nodes).
* coalescer — shape-bucketed packing of many queries into one
  fixed-shape ``generate_walk_lanes`` dispatch, plus result slicing.
* ``SnapshotManager`` — window double-buffer: serve against a consistent
  snapshot while the next ingest builds.
* ``WalkService`` — the service loop: fixed-capacity queue with
  backpressure + drop accounting, FIFO coalescing, p50/p99 latency and
  walks/s stats.
"""
from repro.serve.coalescer import (
    LaneSlice,
    bucketize,
    pack_queries,
    slice_result,
)
from repro.serve.query import QueryResult, WalkQuery
from repro.serve.service import QueueFull, ServeStats, WalkService
from repro.serve.snapshot import SnapshotManager

__all__ = [
    "LaneSlice", "bucketize", "pack_queries", "slice_result",
    "QueryResult", "WalkQuery", "QueueFull", "ServeStats", "WalkService",
    "SnapshotManager",
]
