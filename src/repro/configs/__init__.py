"""Config registry: ``get_config(name)`` / ``list_archs()``.

One module per assigned architecture; each exposes ``CONFIG``.
"""
from __future__ import annotations

from importlib import import_module
from typing import Dict

from repro.configs.base import (
    ALL_SHAPES,
    SHAPES_BY_NAME,
    AttentionConfig,
    EngineConfig,
    ModelConfig,
    MoEConfig,
    SamplerConfig,
    SchedulerConfig,
    ShapeConfig,
    SSMConfig,
    WalkConfig,
    WindowConfig,
    reduced,
    shapes_for,
)

ARCH_MODULES = {
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "phi3-medium-14b": "repro.configs.phi3_medium_14b",
    "olmo-1b": "repro.configs.olmo_1b",
    "deepseek-coder-33b": "repro.configs.deepseek_coder_33b",
    "qwen2-0.5b": "repro.configs.qwen2_0_5b",
    "qwen2-vl-72b": "repro.configs.qwen2_vl_72b",
    "xlstm-125m": "repro.configs.xlstm_125m",
    "jamba-v0.1-52b": "repro.configs.jamba_v01_52b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "arctic-480b": "repro.configs.arctic_480b",
}


def list_archs():
    return sorted(ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {list_archs()}")
    return import_module(ARCH_MODULES[name]).CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {n: get_config(n) for n in ARCH_MODULES}


__all__ = [
    "ALL_SHAPES", "SHAPES_BY_NAME", "AttentionConfig", "EngineConfig",
    "ModelConfig", "MoEConfig", "SamplerConfig", "SchedulerConfig",
    "ShapeConfig", "SSMConfig", "WalkConfig", "WindowConfig",
    "reduced", "shapes_for", "get_config", "list_archs", "all_configs",
]
