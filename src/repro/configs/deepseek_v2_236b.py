"""DeepSeek-V2 236B [arXiv:2405.04434; hf].

MoE with Multi-head Latent Attention: 60L, d_model=5120, 128 heads,
kv_lora=512, q_lora=1536, qk_nope=128, qk_rope=64, v_head=128.
MoE: 2 shared + 160 routed experts, top-6, expert d_ff=1536; layer 0 dense.
vocab=102400. MLA is still full attention => skip long_500k.
"""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    d_ff=12288,                     # dense layer-0 FFN width
    vocab_size=102400,
    attention=AttentionConfig(
        kind="mla", n_heads=128, n_kv_heads=128, head_dim=128,
        rope="rope",
        q_lora_rank=1536, kv_lora_rank=512,
        qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=160, top_k=6, expert_d_ff=1536,
        num_shared_experts=2, shared_d_ff=1536,
        first_dense_layers=1, capacity_factor=1.25,
    ),
    layer_pattern=("attn",),
    norm="rmsnorm",
    activation="swiglu",
    supports_long_context=False,
)
