"""Configuration dataclasses for Tempest-JAX.

Two config families:
  * ``ModelConfig`` — the assigned downstream architectures (LM-family).
  * ``EngineConfig`` / ``WalkConfig`` / ``WindowConfig`` — the paper's
    temporal-walk engine (the core contribution).

Everything is a frozen dataclass so configs hash and can key jit caches.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Optional, Tuple

# ---------------------------------------------------------------------------
# Walk-engine configs (the paper's system)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class WindowConfig:
    """Sliding-window semantics (paper §2.6)."""

    duration: float = 3600.0          # Δ, in timestamp units
    edge_capacity: int = 1 << 16      # static capacity of the edge store
    node_capacity: int = 1 << 12      # max node id + 1
    drop_late: bool = True            # drop edges older than t - Δ at merge


@dataclass(frozen=True)
class SamplerConfig:
    """Temporal bias sampling (paper §2.5; DESIGN.md §17 for table bias).

    ``bias="table"`` selects the alias/radix factorization (Bingo-style):
    per-node alias tables over the window's neighborhood regions, built
    from ``table_weight`` and maintained incrementally by ingest. The
    weight callable ``(ts, tbase, tref) -> float32`` must be elementwise,
    non-negative, and node-local (may read only the edge timestamp and
    its source node's min/max timestamp); it may also be one of the
    built-in names "uniform" | "linear" | "exponential" (which reproduce
    the closed-form samplers' laws when timestamps are consecutive
    integers). ``None`` with bias="table" defaults to exponential.
    """

    bias: str = "exponential"         # uniform | linear | exponential | table
    mode: str = "index"               # index (closed-form O(1)) | weight (exact, O(log n))
    start_bias: str = "uniform"       # bias over start edges (timestamp view)
    # Temporal node2vec second-order parameters (rejection sampling); p=q=1.0
    # disables the second-order bias entirely.
    node2vec_p: float = 1.0
    node2vec_q: float = 1.0
    # Alias-table parameters (bias="table"; DESIGN.md §17). table_weight may
    # be a callable or a built-in name; callables hash by identity, so reuse
    # one function object across configs to share jit caches.
    table_weight: Optional[Callable] = None
    table_radix: int = 4096           # M: coin resolution per alias bucket
    table_degree_cap: int = 64        # R: largest region on the O(1) path


@dataclass(frozen=True)
class SchedulerConfig:
    """Hierarchical cooperative scheduling adaptation (paper §2.4).

    The GPU dispatch plane (W x G -> 5 terminal kernels) maps to a 4-path
    plane on TPU; thresholds play the same structural role as the paper's
    W_warp / block-dim hyperparameters and are swept in EXPERIMENTS.md.

    Paths: ``fullwalk`` (per-walk baseline), ``grouped`` (regrouped jnp
    hops), ``tiled`` (Pallas search+sample kernel + jnp gather/fallback),
    ``fused`` (single convergence-tiered Pallas kernel per hop:
    prefix lookup + per-lane branchless draw + gather, DESIGN.md §14).
    """

    path: str = "grouped"             # fullwalk | grouped | tiled | fused
    # per-hop regrouping algorithm for the grouped/tiled/fused paths:
    #   bucket  — O(W) counting regroup with carried permutation (DESIGN.md §10)
    #   lexsort — the seed's per-hop O(W log W) sort + inverse scatter
    #             (kept as the equivalence/benchmark reference)
    regroup: str = "bucket"
    regroup_time: bool = True         # conditional time subsort inside buckets
    solo_threshold: int = 4           # paper W_warp default (Fig. 9)
    tile_walks: int = 256             # paper block-dim analog (Fig. 8): walks per VMEM tile
    tile_edges: int = 1024            # edges staged per VMEM tile (smem panel analog)
    max_task_walks: int = 8192        # mega-hub split threshold (paper §2.4.4)
    compact_threshold: float = 0.5    # re-compact walks when alive fraction drops below


@dataclass(frozen=True)
class WalkConfig:
    """A walk-generation request (paper defaults: L=80, 10 walks/node)."""

    num_walks: int = 1024
    max_length: int = 80
    start_mode: str = "nodes"         # nodes (uniform over active) | edges (bias over time)
    direction: str = "forward"


@dataclass(frozen=True)
class ServeConfig:
    """Walk-query serving layer (repro.serve, DESIGN.md §11).

    Shape buckets bound the jit-cache footprint: a coalesced batch always
    compiles at (lane bucket × length bucket × start mode), never at the
    exact query shape. Buckets must be sorted ascending; the largest lane
    bucket is the lane budget of one dispatch.

    ``num_shards`` switches the service onto the node-partitioned window
    (DESIGN.md §13): 0 serves the single replicated window; N > 0 shards
    the window over the first N devices (lane batches migrate between
    owners per hop; per-shard capacities come from ``ShardConfig``).

    The async continuous-batching runtime (DESIGN.md §18) adds three
    knobs. ``max_inflight`` bounds the ring of dispatched-but-unharvested
    batch futures: 1 degenerates to the synchronous blocking loop, larger
    values let walk batches overlap on JAX async dispatch (and with
    ``begin_ingest``). ``linger_s`` is the continuous-batching seal
    deadline: a partially-filled lane bucket stays open to late-arriving
    same-group queries until the head query has waited that long (0 seals
    at the instant a batch forms — the historical behavior). ``admission``
    picks the head-of-line order: ``"fifo"`` (strict arrival order) or
    ``"edf"`` (earliest ``WalkQuery.deadline_s`` first; deadline-free
    queries sort last, FIFO among themselves).
    """

    queue_capacity: int = 1024        # pending-query slots; beyond -> dropped
    lane_buckets: Tuple[int, ...] = (64, 256, 1024, 4096)
    length_buckets: Tuple[int, ...] = (4, 8, 16, 32, 80)
    drop_oversize: bool = True        # False: oversize submits raise (typed)
    num_shards: int = 0               # 0 = single replicated window
    max_inflight: int = 4             # in-flight dispatch ring depth (>= 1)
    linger_s: float = 0.0             # continuous-batching seal deadline
    admission: str = "fifo"           # fifo | edf (DESIGN.md §18)


@dataclass(frozen=True)
class ShardConfig:
    """Node-partitioned sliding window (repro.distributed.streaming_shard,
    DESIGN.md §12).

    Capacities are per shard and static: overflow at any stage drops rows
    and counts them, never reshapes. ``exchange_capacity`` bounds how many
    batch edges one shard may send to one *destination* shard per ingest
    (provision for owner skew: a hub-owning shard can receive up to
    ``num_shards * exchange_capacity`` edges per batch);
    ``walk_bucket_capacity`` is the walk-migration analog (mirrors
    ``make_distributed_walker``'s bucket knob); ``walk_slots`` bounds the
    walks resident on one shard between hops.

    ``placement`` selects the node-ownership policy
    (repro.distributed.placement, DESIGN.md §15): ``range`` is the
    bit-identity baseline ``owner(v) = v // ceil(nc / D)``; ``hash``
    decorrelates owners from id locality through a multiplicative hash +
    ``hash_buckets``-entry routing table; ``skew`` starts as range and
    grows a measured top-``hot_k`` hub override table via
    ``DistributedStreamingEngine.rebalance``.
    """

    num_shards: int = 0                # 0 = one shard per visible device
    edge_capacity_per_shard: int = 1 << 16
    exchange_capacity: int = 1 << 12   # batch edges per (sender, dest) pair
    walk_slots: int = 1 << 12          # resident walk rows per shard
    walk_bucket_capacity: int = 1 << 10  # migrating walks per (sender, dest)
    placement: str = "range"           # range | hash | skew (DESIGN.md §15)
    hash_buckets: int = 256            # routing-table entries (power of two)
    hot_k: int = 8                     # hub overrides built by rebalance()


@dataclass(frozen=True)
class EngineConfig:
    window: WindowConfig = field(default_factory=WindowConfig)
    sampler: SamplerConfig = field(default_factory=SamplerConfig)
    scheduler: SchedulerConfig = field(default_factory=SchedulerConfig)
    shard: ShardConfig = field(default_factory=ShardConfig)
    timestamp_dtype: str = "int32"
    seed: int = 0


# ---------------------------------------------------------------------------
# Model configs (assigned architectures)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AttentionConfig:
    kind: str = "gqa"                 # gqa | mla
    n_heads: int = 16
    n_kv_heads: int = 16
    head_dim: int = 128
    qkv_bias: bool = False
    rope: str = "rope"                # rope | mrope | none | sinusoidal
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = ()   # M-RoPE (Qwen2-VL): (t, h, w) split of head_dim/2
    # MLA (DeepSeek-V2) parameters
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    # sliding window for long-context decode on hybrid archs (0 = full)
    window: int = 0


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    num_shared_experts: int = 0
    shared_d_ff: int = 0
    dense_residual: bool = False      # Arctic: dense FFN in parallel with MoE
    dense_residual_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.01
    every_k_layers: int = 1           # Jamba: MoE every 2nd layer
    first_dense_layers: int = 0       # DeepSeek-V2: layer 0 dense


@dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba"               # mamba | mlstm | slstm
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    chunk_size: int = 256             # chunked-scan block for training
    chunked: bool = True              # chunkwise-parallel mLSTM (§Perf)
    # xLSTM
    num_heads: int = 4
    proj_factor: float = 2.0


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"             # dense | moe | hybrid | ssm | enc_dec | vlm | audio
    num_layers: int = 12
    d_model: int = 768
    d_ff: int = 3072
    vocab_size: int = 50304
    attention: AttentionConfig = field(default_factory=AttentionConfig)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # per-layer kind pattern, cycled over num_layers. Entries:
    #   "attn" (attention + FFN), "mamba" (mamba + FFN), "mlstm", "slstm"
    layer_pattern: Tuple[str, ...] = ("attn",)
    norm: str = "rmsnorm"             # rmsnorm | layernorm | nonparametric_ln
    activation: str = "swiglu"        # swiglu | gelu | geglu
    tie_embeddings: bool = False
    max_seq_len: int = 131072
    dtype: str = "bfloat16"
    # encoder for enc-dec (seamless): shares d_model/heads, own layer count
    encoder_layers: int = 0
    # modality frontend stub: None | "audio" | "vision"
    frontend: Optional[str] = None
    frontend_dim: int = 0             # dim of precomputed frame/patch embeddings
    # long-context capability: archs with sub-quadratic paths run long_500k
    supports_long_context: bool = False
    # remat policy for train_step
    remat: str = "block"              # none | block | full

    @property
    def head_dim(self) -> int:
        return self.attention.head_dim

    def approx_params(self) -> int:
        """Crude parameter count (used by 6ND roofline term)."""
        from repro.models.model import count_params_analytic

        return count_params_analytic(self)

    def approx_active_params(self) -> int:
        from repro.models.model import count_params_analytic

        return count_params_analytic(self, active_only=True)


# ---------------------------------------------------------------------------
# Input shapes (assigned shape suite)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                          # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def shapes_for(cfg: ModelConfig) -> Tuple[ShapeConfig, ...]:
    """The shape cells an architecture actually runs.

    ``long_500k`` requires a sub-quadratic path (SSM / hybrid); pure
    full-attention archs skip it (recorded in DESIGN.md §5).
    """
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.supports_long_context:
        out.append(LONG_500K)
    return tuple(out)


def reduced(cfg: ModelConfig, layers: int = 2, d_model: int = 64,
            vocab: int = 256, experts: int = 4) -> ModelConfig:
    """Shrink a config for CPU smoke tests, preserving the family topology."""
    att = cfg.attention
    hd = 16
    n_heads = max(2, min(4, att.n_heads))
    n_kv = max(1, min(n_heads, att.n_kv_heads if att.n_kv_heads else n_heads))
    if n_heads % n_kv:
        n_kv = 1
    new_att = dataclasses.replace(
        att,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=hd,
        q_lora_rank=min(att.q_lora_rank, 32) if att.q_lora_rank else 0,
        kv_lora_rank=min(att.kv_lora_rank, 16) if att.kv_lora_rank else 0,
        qk_nope_head_dim=hd if att.qk_nope_head_dim else 0,
        qk_rope_head_dim=8 if att.qk_rope_head_dim else 0,
        v_head_dim=hd if att.v_head_dim else 0,
        mrope_sections=(4, 2, 2) if att.mrope_sections else (),
    )
    new_moe = None
    if cfg.moe is not None:
        m = cfg.moe
        new_moe = dataclasses.replace(
            m,
            num_experts=min(m.num_experts, experts),
            top_k=min(m.top_k, 2),
            expert_d_ff=96 if m.expert_d_ff else 0,
            num_shared_experts=min(m.num_shared_experts, 1),
            shared_d_ff=96 if m.shared_d_ff else 0,
            dense_residual_d_ff=96 if m.dense_residual_d_ff else 0,
        )
    new_ssm = None
    if cfg.ssm is not None:
        new_ssm = dataclasses.replace(
            cfg.ssm, d_state=8, chunk_size=32,
            num_heads=2, expand=2,
        )
    n_layers = max(layers, len(cfg.layer_pattern))
    # keep a full pattern period so every block kind is exercised
    n_layers = min(n_layers, 2 * len(cfg.layer_pattern)) if len(cfg.layer_pattern) > 1 else layers
    return dataclasses.replace(
        cfg,
        num_layers=n_layers,
        d_model=d_model,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=vocab,
        attention=new_att,
        moe=new_moe,
        ssm=new_ssm,
        encoder_layers=min(cfg.encoder_layers, 2),
        frontend_dim=32 if cfg.frontend_dim else 0,
        max_seq_len=512,
        dtype="float32",
        remat="none",
    )
