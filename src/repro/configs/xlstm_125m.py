"""xLSTM-125M [arXiv:2405.04517; unverified].

SSM-family: 12L, d_model=768, 4 heads, vocab=50304, d_ff=0 (xLSTM blocks
carry their own projections). Interleaves sLSTM (scalar memory, recurrent)
and mLSTM (matrix memory, parallelizable) blocks at a 1:7-style ratio —
here a period-4 pattern with one sLSTM per period (xLSTM[7:1] family).
Linear recurrence => sub-quadratic: runs long_500k.
"""
from repro.configs.base import AttentionConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    d_ff=0,
    vocab_size=50304,
    attention=AttentionConfig(kind="gqa", n_heads=4, n_kv_heads=4,
                              head_dim=192, rope="none"),
    ssm=SSMConfig(kind="mlstm", num_heads=4, proj_factor=2.0,
                  chunk_size=256),
    layer_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    norm="layernorm",
    activation="gelu",
    tie_embeddings=True,
    supports_long_context=True,
    max_seq_len=1 << 20,
)
