"""Qwen2-VL-72B backbone [arXiv:2409.12191; hf].

VLM decoder: 80L, d_model=8192, 64H (GQA kv=8), d_ff=29568, vocab=152064.
Distinctive: M-RoPE (multimodal rotary with (t, h, w) sections). The vision
frontend is a STUB — ``input_specs()`` supplies precomputed patch embeddings.
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    d_ff=29568,
    vocab_size=152064,
    attention=AttentionConfig(
        kind="gqa", n_heads=64, n_kv_heads=8, head_dim=128,
        qkv_bias=True, rope="mrope", rope_theta=1000000.0,
        mrope_sections=(16, 24, 24),   # t/h/w split of head_dim/2 = 64
    ),
    layer_pattern=("attn",),
    norm="rmsnorm",
    activation="swiglu",
    frontend="vision",
    frontend_dim=8192,
    supports_long_context=False,
)
