"""OLMo-1B [arXiv:2402.00838; hf].

Dense decoder: 16L, d_model=2048, 16H (kv=16), d_ff=8192, vocab=50304.
Distinctive: non-parametric LayerNorm (no scale/bias).
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    d_ff=8192,
    vocab_size=50304,
    attention=AttentionConfig(
        kind="gqa", n_heads=16, n_kv_heads=16, head_dim=128, rope="rope",
    ),
    layer_pattern=("attn",),
    norm="nonparametric_ln",
    activation="swiglu",
    tie_embeddings=True,
    supports_long_context=False,
)
