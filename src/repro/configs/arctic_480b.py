"""Snowflake Arctic 480B [hf:Snowflake/snowflake-arctic-base; hf].

Dense-MoE hybrid: 35L, d_model=7168, 56H (GQA kv=8), MoE 128 experts top-2
(expert d_ff=4864) with a dense residual FFN (d_ff=4864) in parallel.
vocab=32000. Full attention => skip long_500k.
"""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    d_ff=4864,
    vocab_size=32000,
    attention=AttentionConfig(
        kind="gqa", n_heads=56, n_kv_heads=8, head_dim=128, rope="rope",
    ),
    moe=MoEConfig(
        num_experts=128, top_k=2, expert_d_ff=4864,
        dense_residual=True, dense_residual_d_ff=4864,
        capacity_factor=1.25,
    ),
    layer_pattern=("attn",),
    norm="rmsnorm",
    activation="swiglu",
    supports_long_context=False,
)
