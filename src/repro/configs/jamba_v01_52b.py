"""Jamba-v0.1 52B [arXiv:2403.19887; hf].

Hybrid Mamba+attention 1:7 interleave, 32L, d_model=4096, 32H (GQA kv=8),
d_ff=14336, vocab=65536, MoE 16 experts top-2 every other layer.
Mamba-dominant => sub-quadratic: runs long_500k (the 4 attention layers use
a sliding window in the long-context decode regime).
"""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig, SSMConfig

# period-8 pattern: attention at position 3 (1 attn : 7 mamba, Jamba §2)
_PATTERN = ("mamba", "mamba", "mamba", "attn",
            "mamba", "mamba", "mamba", "mamba")

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=65536,
    attention=AttentionConfig(
        kind="gqa", n_heads=32, n_kv_heads=8, head_dim=128,
        rope="none",                 # Jamba uses no positional encoding
        window=4096,                 # applied only in long-context decode
    ),
    moe=MoEConfig(
        num_experts=16, top_k=2, expert_d_ff=14336,
        every_k_layers=2, capacity_factor=1.25,
    ),
    ssm=SSMConfig(kind="mamba", d_state=16, d_conv=4, expand=2,
                  chunk_size=256),
    layer_pattern=_PATTERN,
    norm="rmsnorm",
    activation="swiglu",
    supports_long_context=True,
    max_seq_len=1 << 20,
)
