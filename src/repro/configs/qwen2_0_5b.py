"""Qwen2-0.5B [arXiv:2407.10671; hf].

Dense decoder: 24L, d_model=896, 14H (GQA kv=2), d_ff=4864, vocab=151936.
Distinctive: QKV bias; tied embeddings.
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    num_layers=24,
    d_model=896,
    d_ff=4864,
    vocab_size=151936,
    attention=AttentionConfig(
        kind="gqa", n_heads=14, n_kv_heads=2, head_dim=64,
        qkv_bias=True, rope="rope", rope_theta=1000000.0,
    ),
    layer_pattern=("attn",),
    norm="rmsnorm",
    activation="swiglu",
    tie_embeddings=True,
    supports_long_context=False,
)
