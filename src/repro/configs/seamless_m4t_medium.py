"""SeamlessM4T-medium backbone [arXiv:2308.11596; hf].

Enc-dec transformer, 12L each side, d_model=1024, 16H (kv=16), d_ff=4096,
vocab=256206. Multimodal: the audio frontend is a STUB — ``input_specs()``
supplies precomputed frame embeddings of dim ``frontend_dim`` (the w2v-BERT
feature dim equals d_model here).
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="enc_dec",
    num_layers=12,               # decoder layers
    encoder_layers=12,
    d_model=1024,
    d_ff=4096,
    vocab_size=256206,
    attention=AttentionConfig(
        kind="gqa", n_heads=16, n_kv_heads=16, head_dim=64,
        rope="sinusoidal",
    ),
    layer_pattern=("attn",),
    norm="layernorm",
    activation="gelu",
    frontend="audio",
    frontend_dim=1024,
    supports_long_context=False,   # full-attention enc-dec: skip long_500k
    max_seq_len=32768,
)
