"""Phi-3-medium 14B [arXiv:2404.14219; unverified].

Dense decoder: 40L, d_model=5120, 40H (GQA kv=10), d_ff=17920,
vocab=100352. RoPE + SwiGLU + RMSNorm.
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    d_ff=17920,
    vocab_size=100352,
    attention=AttentionConfig(
        kind="gqa", n_heads=40, n_kv_heads=10, head_dim=128, rope="rope",
    ),
    layer_pattern=("attn",),
    norm="rmsnorm",
    activation="swiglu",
    supports_long_context=False,
)
