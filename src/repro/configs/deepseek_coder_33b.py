"""DeepSeek-Coder-33B [arXiv:2401.14196; hf].

Llama-arch dense decoder: 62L, d_model=7168, 56H (GQA kv=8), d_ff=19200,
vocab=32256.
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    num_layers=62,
    d_model=7168,
    d_ff=19200,
    vocab_size=32256,
    attention=AttentionConfig(
        kind="gqa", n_heads=56, n_kv_heads=8, head_dim=128, rope="rope",
    ),
    layer_pattern=("attn",),
    norm="rmsnorm",
    activation="swiglu",
    supports_long_context=False,
)
