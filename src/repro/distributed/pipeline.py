"""Pipeline parallelism over the pod axis (GPipe schedule, shard_map).

Mechanism: stages are laid out along a mesh axis; each scheduling tick,
every stage processes the microbatch it holds and ``ppermute``s its
activation to the next stage. With M microbatches and P stages the loop
runs M + P − 1 ticks; stage s is busy for M of them (the usual GPipe
bubble (P−1)/(M+P−1)).

The multi-pod mesh's ``pod`` axis (size 2) hosts stages; within a pod the
usual data/model sharding applies unchanged — PP composes with the
DP/TP/EP/SP schemes of sharding.py. This module provides the schedule for
an arbitrary per-stage apply function plus a reference implementation
used by the correctness test (pipeline == sequential); wiring a specific
architecture's segments onto stages is a config concern
(stage boundary = segments list split).
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def gpipe_forward(mesh: Mesh, axis: str, stage_fn: Callable,
                  stage_params, x_microbatches):
    """Run ``stage_fn`` as a P-stage pipeline over mesh axis ``axis``.

    Args:
      stage_fn: (params_one_stage, x) -> y, applied by every stage.
      stage_params: pytree with leading stage axis (sharded over ``axis``).
      x_microbatches: [M, mb, ...] microbatched input (replicated).

    Returns [M, mb, ...] pipeline output (replicated).
    """
    num_stages = mesh.shape[axis]
    M = x_microbatches.shape[0]
    ticks = M + num_stages - 1

    def per_stage(params_st, xs):
        stage = jax.lax.axis_index(axis)
        params_local = jax.tree.map(lambda a: a[0], params_st)
        mb_shape = xs.shape[1:]
        buf = jnp.zeros(mb_shape, xs.dtype)          # activation in flight
        outs = jnp.zeros((M,) + mb_shape, xs.dtype)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any); others use received
            feed = jnp.where(t < M, t, M - 1)
            x_in = jnp.where(stage == 0,
                             xs[feed],
                             buf)
            y = stage_fn(params_local, x_in)
            # active window for this stage at tick t: stage <= t < stage+M
            active = (t >= stage) & (t < stage + M)
            y = jnp.where(active, y, buf)
            # last stage writes its result for microbatch (t - P + 1)
            out_idx = t - (num_stages - 1)
            is_out = (stage == num_stages - 1) & (out_idx >= 0)
            outs = jax.lax.cond(
                is_out,
                lambda o: o.at[jnp.maximum(out_idx, 0)].set(y),
                lambda o: o, outs)
            # shift activations forward one stage
            perm = [(i, (i + 1) % num_stages) for i in range(num_stages)]
            buf = jax.lax.ppermute(y, axis, perm)
            return (buf, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs),
                                      jnp.arange(ticks))
        # only the last stage holds real outputs; broadcast to all
        outs = jax.lax.psum(
            jnp.where(stage == num_stages - 1, outs, 0.0), axis)
        return outs[None]

    in_specs = (jax.tree.map(lambda _: P(axis), stage_params), P())
    fn = shard_map(per_stage, mesh=mesh, in_specs=in_specs,
                   out_specs=P(axis), check_rep=False)
    out = fn(stage_params, x_microbatches)
    # post-psum every stage holds identical outputs; take one replica
    return out[0]


def sequential_reference(stage_fn, stage_params, x_microbatches):
    """Oracle: apply all stages in order, no pipelining."""
    num_stages = jax.tree.leaves(stage_params)[0].shape[0]

    def one(x):
        for s in range(num_stages):
            p = jax.tree.map(lambda a: a[s], stage_params)
            x = stage_fn(p, x)
        return x

    return jax.vmap(one)(x_microbatches)
