"""Pluggable node-placement layer: who owns node v? (DESIGN.md §15)

Every distributed subsystem in this repo — the node-partitioned sliding
window (DESIGN.md §12), sharded lane serving (§13), and the static walk
migrator (core/distributed.py) — needs one answer to one question: *which
shard owns node v's out-edges?* Until this layer existed the answer was a
constant folded into every call site (``owner(v) = v // range_size``); it
is now a value: a ``Placement`` object threaded through ingest, walk-start
claims, per-hop migration, serving, checkpointing, and resharding.

A placement must satisfy exactly one invariant: **every node id in
[0, node_capacity) maps to exactly one shard in [0, num_shards)** — and
it must answer identically on device (``owner``, traced jnp) and on host
(``owner_np``, the coalescer's routing mirror). Everything else (walk
bit-identity across shard counts, edge locality of Γ_t(v), psum trace
reassembly) follows, because *all* routing decisions — which shard stores
an edge (by owner of its source), which shard claims a start lane, where a
migrating walk lands — consult the same object. The per-(walk, step) RNG
is placement-independent, so replay and serving stay **bit-identical to
the single-device engine under any policy** (tested for all three in
tests/test_reshard_checkpoint.py).

Three policies:

* ``range`` — ``owner(v) = clip(v // ceil(node_capacity / D), 0, D-1)``,
  today's rule kept as the bit-identity baseline vs the PR 4/5 goldens.
* ``hash`` — Knuth multiplicative hash into a small routing table
  (``table[(v * 2654435761) >> (32 - log2(buckets))]``). The table is the
  indirection that makes the policy *operable*: moving a bucket between
  shards is a table edit + ``reshard``, not a formula change.
* ``skew`` — a base policy (range or hash) plus a hot-node override table
  that pins the top-K hubs to explicitly chosen shards.
  ``SkewPlacement.from_loads`` builds the overrides from measured
  per-node load (edge counts from the engine, lane counts from
  ``ServeStats.lanes_by_shard``): hubs are peeled off the base assignment
  and greedily placed on the least-loaded shard (LPT). This *splits* hub
  load off melting shards; replicating a hub onto several shards (read
  scaling for one node) is deliberately out of scope — it would break the
  exactly-one-owner invariant everything else leans on.

Placements are **frozen, hashable dataclasses of ints/tuples** on
purpose: they ride through ``jax.jit`` as static arguments, so the
routing/override tables are baked into the compiled program as constants
(device-resident at run time, zero gather indirection for ``range``) and
a placement change is a recompile — the right cost model, since placement
changes are control-plane events (``reshard``) that already pay an
all_to_all.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Knuth's multiplicative constant (2^32 / phi); uint32 wrap on purpose.
_KNUTH = np.uint32(2654435761)


@dataclass(frozen=True)
class Placement:
    """Base node-placement policy: ``owner(v)`` on device + host mirror.

    Frozen and hashable so concrete placements can key ``jax.jit`` caches
    as static arguments. Subclasses implement ``owner`` (traced jnp,
    int32 in -> int32 shard ids in [0, num_shards)) and ``owner_np`` (the
    bit-identical numpy mirror used by host-side routing/stats, e.g.
    ``serve.coalescer.lane_owners``).
    """

    num_shards: int
    node_capacity: int

    kind = "base"

    def owner(self, v: jax.Array) -> jax.Array:
        raise NotImplementedError

    def owner_np(self, v) -> np.ndarray:
        raise NotImplementedError

    def shard_nodes(self, d: int) -> np.ndarray:
        """Inverse enumeration: the node ids shard ``d`` owns (host-side,
        for provisioning / capacity planning). Generic O(node_capacity)
        scan over the host mirror; subclasses may specialize."""
        all_v = np.arange(self.node_capacity, dtype=np.int32)
        return all_v[self.owner_np(all_v) == d]

    def describe(self) -> dict:
        """JSON-serializable manifest entry (checkpoint placement record).
        Round-trips through ``placement_from_manifest``."""
        raise NotImplementedError


@dataclass(frozen=True)
class RangePlacement(Placement):
    """``owner(v) = clip(v // range_size, 0, D-1)`` — the PR 4/5 rule.

    Kept bit-identical to the formula previously inlined at every call
    site (``core.distributed.owner_range_size``): with this policy the
    sharded ingest/walk/serving paths produce byte-identical states and
    walks to the pre-placement-layer goldens.
    """

    kind = "range"

    @property
    def range_size(self) -> int:
        return math.ceil(self.node_capacity / self.num_shards)

    def owner(self, v: jax.Array) -> jax.Array:
        r = jnp.asarray(v, jnp.int32) // self.range_size
        return jnp.clip(r, 0, self.num_shards - 1)

    def owner_np(self, v) -> np.ndarray:
        r = np.asarray(v).astype(np.int64) // self.range_size
        return np.clip(r, 0, self.num_shards - 1).astype(np.int32)

    def shard_nodes(self, d: int) -> np.ndarray:
        lo = d * self.range_size
        hi = min((d + 1) * self.range_size, self.node_capacity)
        return np.arange(lo, max(lo, hi), dtype=np.int32)

    def describe(self) -> dict:
        return {"kind": self.kind, "num_shards": self.num_shards,
                "node_capacity": self.node_capacity}


@dataclass(frozen=True)
class HashPlacement(Placement):
    """Multiplicative hash + routing table.

    ``bucket(v) = (uint32(v) * 2654435761) >> (32 - log2(len(table)))``;
    ``owner(v) = table[bucket(v)]``. The hash decorrelates owners from id
    locality (hub ids cluster at the low end of Zipf-ranked graphs, which
    melts range placement); the table adds the operable indirection —
    rebalancing is "edit table entries, then reshard". The table is a
    tuple (hashable -> static under jit; small -> baked as constants).
    """

    table: Tuple[int, ...] = ()
    kind = "hash"

    def __post_init__(self):
        b = len(self.table)
        if b == 0 or (b & (b - 1)) != 0:
            raise ValueError(f"routing table size must be a power of two "
                             f"(got {b})")
        if any(not (0 <= t < self.num_shards) for t in self.table):
            raise ValueError("routing table entry out of shard range")

    @classmethod
    def make(cls, num_shards: int, node_capacity: int,
             num_buckets: int = 256) -> "HashPlacement":
        """Round-robin table: bucket i -> shard i % D (uniform in
        expectation over the hashed id space)."""
        table = tuple(i % num_shards for i in range(num_buckets))
        return cls(num_shards=num_shards, node_capacity=node_capacity,
                   table=table)

    @property
    def _shift(self) -> int:
        return 32 - int(math.log2(len(self.table)))

    def owner(self, v: jax.Array) -> jax.Array:
        h = jnp.asarray(v, jnp.int32).astype(jnp.uint32) * _KNUTH
        bucket = (h >> self._shift).astype(jnp.int32)
        return jnp.asarray(self.table, jnp.int32)[bucket]

    def owner_np(self, v) -> np.ndarray:
        h = np.asarray(v).astype(np.uint32) * _KNUTH
        bucket = (h >> np.uint32(self._shift)).astype(np.int64)
        return np.asarray(self.table, np.int32)[bucket]

    def describe(self) -> dict:
        return {"kind": self.kind, "num_shards": self.num_shards,
                "node_capacity": self.node_capacity,
                "table": list(self.table)}


@dataclass(frozen=True)
class SkewPlacement(Placement):
    """A base policy plus a top-K hot-node override table.

    ``owner(v) = hot_owners[i] if v == hot_nodes[i] else base.owner(v)``.
    K stays small (tens), so the override resolves on device as one
    [n, K] compare against baked constants — no gather table of
    node_capacity. Build the overrides from measured load with
    ``from_loads``; an empty table degrades to the base policy exactly.
    """

    base: Placement = None          # type: ignore[assignment]
    hot_nodes: Tuple[int, ...] = ()
    hot_owners: Tuple[int, ...] = ()
    kind = "skew"

    def __post_init__(self):
        if self.base is None:
            raise ValueError("SkewPlacement needs a base placement")
        if (self.base.num_shards != self.num_shards
                or self.base.node_capacity != self.node_capacity):
            raise ValueError("base placement shape mismatch")
        if len(self.hot_nodes) != len(self.hot_owners):
            raise ValueError("hot_nodes / hot_owners length mismatch")
        if len(set(self.hot_nodes)) != len(self.hot_nodes):
            raise ValueError("duplicate hot node override")
        if any(not (0 <= o < self.num_shards) for o in self.hot_owners):
            raise ValueError("hot owner out of shard range")

    @classmethod
    def from_loads(cls, base: Placement, node_loads, k: int = 8
                   ) -> "SkewPlacement":
        """Build hub overrides from measured per-node load.

        ``node_loads`` is host-side [node_capacity] (edge counts from
        ``DistributedStreamingEngine.node_loads()``, or lane counts from
        serving stats). The top-``k`` loaded nodes are peeled off the
        base assignment and greedily placed, heaviest first, on the
        currently least-loaded shard (LPT); ties resolve to the lowest
        shard id so the result is deterministic. A ``SkewPlacement``
        base is unwrapped first (re-deriving overrides, not stacking).
        """
        if isinstance(base, SkewPlacement):
            base = base.base
        loads = np.asarray(node_loads, np.float64)
        if loads.shape[0] != base.node_capacity:
            raise ValueError(
                f"node_loads has {loads.shape[0]} entries; placement "
                f"expects {base.node_capacity}")
        order = np.argsort(-loads, kind="stable")
        hot = [int(v) for v in order[:k] if loads[v] > 0]
        base_owner = base.owner_np(np.arange(base.node_capacity,
                                             dtype=np.int32))
        shard_load = np.zeros(base.num_shards, np.float64)
        np.add.at(shard_load, base_owner, loads)
        shard_load -= np.bincount(base_owner[hot], weights=loads[hot],
                                  minlength=base.num_shards)
        owners = []
        for v in hot:                      # heaviest first (argsort order)
            d = int(np.argmin(shard_load))
            owners.append(d)
            shard_load[d] += loads[v]
        return cls(num_shards=base.num_shards,
                   node_capacity=base.node_capacity, base=base,
                   hot_nodes=tuple(hot), hot_owners=tuple(owners))

    def owner(self, v: jax.Array) -> jax.Array:
        base_o = self.base.owner(v)
        if not self.hot_nodes:
            return base_o
        v32 = jnp.asarray(v, jnp.int32)
        hn = jnp.asarray(self.hot_nodes, jnp.int32)
        ho = jnp.asarray(self.hot_owners, jnp.int32)
        m = v32[..., None] == hn
        return jnp.where(m.any(-1), ho[jnp.argmax(m, -1)], base_o)

    def owner_np(self, v) -> np.ndarray:
        out = self.base.owner_np(v).copy()
        va = np.asarray(v)
        for n, o in zip(self.hot_nodes, self.hot_owners):
            out[va == n] = o
        return out.astype(np.int32)

    def describe(self) -> dict:
        return {"kind": self.kind, "num_shards": self.num_shards,
                "node_capacity": self.node_capacity,
                "base": self.base.describe(),
                "hot_nodes": list(self.hot_nodes),
                "hot_owners": list(self.hot_owners)}


def make_placement(kind: str, num_shards: int, node_capacity: int, *,
                   hash_buckets: int = 256) -> Placement:
    """Factory from a ``ShardConfig.placement`` string.

    ``skew`` starts with an empty override table (== its range base);
    feed it measured loads via ``SkewPlacement.from_loads`` and
    ``reshard`` to activate the rebalance.
    """
    if kind == "range":
        return RangePlacement(num_shards=num_shards,
                              node_capacity=node_capacity)
    if kind == "hash":
        return HashPlacement.make(num_shards, node_capacity,
                                  num_buckets=hash_buckets)
    if kind == "skew":
        return SkewPlacement(num_shards=num_shards,
                             node_capacity=node_capacity,
                             base=RangePlacement(num_shards=num_shards,
                                                 node_capacity=node_capacity))
    raise ValueError(f"unknown placement kind {kind!r} "
                     "(expected range | hash | skew)")


def placement_from_manifest(d: dict) -> Placement:
    """Rebuild a placement from its ``describe()`` manifest entry."""
    kind = d["kind"]
    if kind == "range":
        return RangePlacement(num_shards=d["num_shards"],
                              node_capacity=d["node_capacity"])
    if kind == "hash":
        return HashPlacement(num_shards=d["num_shards"],
                             node_capacity=d["node_capacity"],
                             table=tuple(d["table"]))
    if kind == "skew":
        return SkewPlacement(num_shards=d["num_shards"],
                             node_capacity=d["node_capacity"],
                             base=placement_from_manifest(d["base"]),
                             hot_nodes=tuple(d["hot_nodes"]),
                             hot_owners=tuple(d["hot_owners"]))
    raise ValueError(f"unknown placement manifest kind {kind!r}")
