"""Node-partitioned sliding window: distributed streaming ingest + walks
(DESIGN.md §12).

``core/distributed.py`` shards the *static* edge store across devices and
migrates walks between owners; every streaming path so far (`ingest`,
`replay_scan`, `StreamingEngine`) still lives on one device, and
``sample_walks_sharded`` shards only the walk axis over a *replicated*
index. This module makes the **window itself** sharded, so both ingestion
capacity and walk throughput scale with device count — the regime where an
81B-edge window exceeds one chip's HBM:

* **Ownership** — nodes are partitioned by a pluggable ``Placement``
  policy (repro/distributed/placement.py, DESIGN.md §15; default
  ``range``: ``owner(v) = v // ceil(node_capacity / D)``, the same rule as
  ``core/distributed.py``); shard d holds the merge-sorted window slice
  of edges whose *source* it owns, so Γ_t(v) is always served locally.
  Every owner decision in this module — ingest bucketing, walk start
  claims, per-hop migration, serving lane claims — consults the same
  placement object, so swapping the policy (hash tables, hot-node skew
  overrides) re-routes all of them coherently; ``reshard`` re-buckets a
  *resident* window from one placement to another (or to a different
  shard count) through one all_to_all without dropping edges.
* **Sharded ingest** — each shard takes a 1/D slice of the incoming batch,
  buckets it by edge-source owner, and one ``all_to_all``
  (``exchange_by_owner``) delivers every edge to its owner. The owner
  compacts its received edges to a ts-sorted prefix and runs the
  single-device rank-based two-run merge (``window.ingest_impl``) locally.
* **Watermark agreement** — eviction must be causally consistent: the new
  ``t`` is the max batch timestamp across *all* shards (one ``pmax``
  before the exchange), passed to ``ingest_impl`` through its ``watermark``
  hook so every shard evicts against the same cutoff t − Δ even when its
  local batch slice is old.
* **Sharded walks** — per batch, walks start on their start node's owner
  and migrate every hop (``hop_resident`` + ``exchange_by_owner``) against
  the freshly ingested shard-local dual indexes. Hop draws are the
  streaming engine's own: ``uniform(fold_in(walk_key, step), (W,))``
  indexed by walk id — a pure function of (walk, step), independent of
  placement — so for ``SamplerConfig.mode="index"`` the replay is
  **bit-identical to the single-device ``StreamingEngine.replay_device``**
  for identical keys at any shard count (tested at 1/2/8 in
  tests/test_streaming_shard.py). ``mode="weight"`` runs but is only
  numerically (not bit-) equivalent: its prefix-sum arrays accumulate in a
  different float order per shard.
* **Trace handling** — unlike ``core/distributed.py`` (which migrates each
  walk's full trace every hop), each shard scatters the hops it executes
  into a resident ``[W, L+1]`` walk-order buffer; one ``psum`` at the end
  reassembles the global result (every cell is written by at most one
  shard). Migration payload shrinks from O(L) to 3 ints per walk, at the
  cost of an O(W·L) buffer per shard.

All capacities are static (``ShardConfig``): exchange buckets, resident
walk slots, and walk-migration buckets drop on overflow and count the
event per shard — provisioning knobs exactly like the paper's walk-array
capacity.

**Sharded lane serving** (DESIGN.md §13): ``serve_lanes_sharded`` runs one
coalesced multi-tenant lane batch (``walk_engine.LaneParams``) over the
node-partitioned window. Start lanes are claimed by their owner shard
(nodes mode: owner of the start node; edges mode: owner of the picked
edge's destination, resolved from a replicated ``window.TsView`` of the
global store), then migrate per hop exactly like the replay walker — the
3-int payload carries (lane id, node, time), and the lane's sampler params
(bias code, max length, per-request RNG identity) ride with it *by lane
id* through the replicated ``LaneParams`` arrays, so a lane keeps its own
sampler across owner hops without widening the wire format. Per-lane
draws are ``walk_engine._lane_uniform`` streams — pure functions of
(request seed, walk-within-request, step) — so the coalesced sharded
batch is **bit-identical to each query run solo on the single-device
engine** at any shard count (tested at 1/2/8 in
tests/test_serve_sharded.py). ``ingest_sharded_nodonate`` is the
non-donating ingest twin backing the serving snapshot double-buffer.
"""
from __future__ import annotations

import time
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import (
    EngineConfig,
    SamplerConfig,
    ShardConfig,
    WalkConfig,
)
from repro.core.distributed import (
    exchange_by_owner,
    hop_resident,
    hop_resident_lanes,
)
from repro.core.edge_store import (
    TS_PAD,
    EdgeBatch,
    EdgeStore,
    stack_batches,
)
from repro.core.temporal_index import build_index
from repro.distributed.placement import (
    Placement,
    RangePlacement,
    SkewPlacement,
    make_placement,
)
from repro.core.samplers import index_pick_lanes
from repro.core.streaming import ReplayStats
from repro.obs.probes import (
    RP_WALKS_EMITTED,
    SP_HOPS,
    SP_LANES_CLAIMED,
    SP_WALK_DROPS,
    flush_replay_probes,
    replay_probe_update,
    replay_probe_zeros,
    serve_probe_zeros,
)
from repro.obs.registry import MetricsRegistry, count_drop, get_registry
from repro.core.walk_engine import (
    NODE_PAD,
    LaneFeatures,
    LaneParams,
    WalkResult,
    _lane_keys,
    _lane_uniform,
    check_capabilities,
)
from repro.core.window import TsView, WindowState, ingest_impl, init_window

WINDOW_AXIS = "window_shards"


class ShardedWindowState(NamedTuple):
    """Per-shard window slices, stacked on a leading [D] device axis.

    ``window`` holds one ``WindowState`` per shard (its counters are
    shard-local: summed over shards, ``late_drops``/``overflow_drops``
    equal the single-device window's, and ``ingested`` counts edges
    *delivered* — it lags the global count by ``exchange_drops``).
    """

    window: WindowState          # leaves [D, ...]
    exchange_drops: jax.Array    # int32[D] cumulative ingest-exchange drops


class DistReplayStats(NamedTuple):
    """Distributed replay statistics.

    ``replay`` carries the global per-batch trajectory in the same layout
    as the single-device ``ReplayStats`` — bit-comparable field by field
    when no shard dropped anything. The drop counters are per-batch,
    per-shard [K, D] (senders count their own exchange overflow).
    """

    replay: ReplayStats
    exchange_drops: jax.Array    # int32[K, D] batch-edge exchange overflow
    walk_drops: jax.Array        # int32[K, D] walk migration + slot overflow


def window_mesh(num_shards: int = 0, devices=None,
                axis_name: str = WINDOW_AXIS) -> Mesh:
    """1-D mesh over the first ``num_shards`` (default: all) devices."""
    devs = np.asarray(devices if devices is not None else jax.devices())
    if num_shards:
        if num_shards > devs.size:
            raise ValueError(f"{num_shards} shards > {devs.size} devices")
        devs = devs[:num_shards]
    return Mesh(devs, (axis_name,))


def init_sharded_window(num_shards: int, edge_capacity_per_shard: int,
                        node_capacity: int, window: int,
                        bias_scale: float = 1.0,
                        mesh: Optional[Mesh] = None,
                        axis_name: str = WINDOW_AXIS,
                        table=None) -> ShardedWindowState:
    """D empty per-shard windows; placed onto the mesh when given.

    ``table`` (a ``core.alias.TableSpec``) makes every per-shard window
    carry alias tables over its *resident* regions, maintained
    incrementally by ``ingest_sharded`` (pass the same spec there).
    Sharded *sampling* under bias='table' stays refused — a migrating
    walk's draw would need its owner's table — but the maintenance
    itself shards cleanly because regions are node-local."""
    one = init_window(edge_capacity_per_shard, node_capacity, window,
                      bias_scale, table=table)
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (num_shards,) + x.shape), one)
    state = ShardedWindowState(
        window=stacked,
        exchange_drops=jnp.zeros((num_shards,), jnp.int32))
    if mesh is not None:
        state = jax.device_put(state, NamedSharding(mesh, P(axis_name)))
    return state


# ---------------------------------------------------------------------------
# Per-shard bodies (run under shard_map; all arrays are local views)
# ---------------------------------------------------------------------------


def _shard_ingest(wstate: WindowState, bsrc, bdst, bts, bvalid, *, axis: str,
                  num_shards: int, placement: Placement,
                  exchange_capacity: int,
                  node_capacity: int, bias_scale: float, table=None):
    """One shard's window advance for its slice of the incoming batch.

    batch slice → owner buckets → all_to_all → compact → local merge, with
    the eviction watermark agreed across shards *before* the exchange (so
    it reflects every arriving edge, even one a full bucket drops).
    """
    # (1) watermark agreement: global max batch timestamp
    local_max = jnp.max(jnp.where(bvalid, bts, -TS_PAD))
    watermark = jax.lax.pmax(local_max, axis)

    # (2) bucket by edge-source owner, one all_to_all
    owner = placement.owner(bsrc)
    (r_src, r_dst, r_ts), _, x_drop = exchange_by_owner(
        axis, num_shards, exchange_capacity, owner, bvalid,
        (bsrc, bdst, bts), (0, 0, TS_PAD))

    # (3) compact received edges to a ts-sorted prefix. Empty exchange
    # slots carry TS_PAD, so one stable ts-argsort both drops them to the
    # back and pre-sorts the run; ties keep (sender, sender-position) ==
    # global batch order, matching the single-device stable batch sort.
    order = jnp.argsort(r_ts).astype(jnp.int32)
    cnt = jnp.sum((r_ts != TS_PAD).astype(jnp.int32))
    local_batch = EdgeBatch(src=r_src[order], dst=r_dst[order],
                            ts=r_ts[order], count=cnt)

    # (4) the single-device rank-based two-run merge, shard-locally,
    # evicting against the agreed watermark; with a TableSpec the merge
    # also maintains this shard's alias tables over its resident regions
    new = ingest_impl(wstate, local_batch, node_capacity, bias_scale,
                      watermark=watermark, table=table)
    return new, x_drop


def _shard_walks(idx, walk_key: jax.Array, wcfg: WalkConfig,
                 scfg: SamplerConfig, *, axis: str, num_shards: int,
                 placement: Placement, walk_slots: int,
                 walk_bucket_capacity: int):
    """One batch's walks over the sharded window (start_mode="all_nodes").

    Returns this shard's trace contributions (walk-order [W, L+1] arrays,
    NODE_PAD where this shard executed no hop), its [W] length
    contributions, its drop count, and its start-claim count (the number
    of position-0 cells it wrote — obs probes derive per-shard hop counts
    as ``sum(ln) - claims``; DCE'd when unused). ``psum`` across shards
    reassembles the exact single-device WalkResult.
    """
    W, L = wcfg.num_walks, wcfg.max_length
    nc = idx.node_capacity
    Ws = walk_slots
    shard_id = jax.lax.axis_index(axis)

    # global t_floor: min in-window timestamp across shards, minus one
    # (empty shards report TS_PAD via their padded store)
    any_edges = jax.lax.pmax(idx.num_edges, axis) > 0
    global_min = jax.lax.pmin(idx.store.ts[0], axis)
    t_floor = jnp.where(any_edges, global_min - 1, 0)

    # place walk w (start node w % nc) on its start node's owner
    w_all = jnp.arange(W, dtype=jnp.int32)
    v_all = (w_all % nc).astype(jnp.int32)
    mine = placement.owner(v_all) == shard_id
    rankm = jnp.cumsum(mine.astype(jnp.int32)) - 1
    wid = jnp.full((Ws,), -1, jnp.int32).at[
        jnp.where(mine, rankm, Ws)].set(w_all, mode="drop")
    start_drop = jnp.maximum(jnp.sum(mine.astype(jnp.int32)) - Ws, 0)
    node = jnp.where(wid >= 0, wid % nc, 0).astype(jnp.int32)
    vc = jnp.clip(node, 0, nc - 1)
    deg = idx.node_starts[vc + 1] - idx.node_starts[vc]
    alive = (wid >= 0) & (deg > 0)
    claims = jnp.sum(alive.astype(jnp.int32))
    cur_time = jnp.full((Ws,), 1, jnp.int32) * t_floor

    # walk-order trace contributions; every cell this shard writes is PAD
    # on all other shards, so psum(x - PAD) + PAD reassembles the result
    tn = jnp.full((W, L + 1), NODE_PAD, jnp.int32)
    tt = jnp.full((W, L + 1), NODE_PAD, jnp.int32)
    ln = jnp.zeros((W,), jnp.int32)
    row0 = jnp.where(alive, wid, W)
    tn = tn.at[row0, 0].set(node, mode="drop")
    tt = tt.at[row0, 0].set(cur_time, mode="drop")
    ln = ln.at[row0].add(1, mode="drop")

    def record_hop(wid, node, cur_time, alive, tn, tt, ln, step):
        # the streaming engine's hop draw: one walk-order [W] vector per
        # step, indexed by walk id — placement-independent bits
        u_full = jax.random.uniform(jax.random.fold_in(walk_key, step), (W,))
        u = u_full[jnp.clip(wid, 0, W - 1)]
        nn, nt, has = hop_resident(idx, scfg, node, cur_time, alive, u)
        row = jnp.where(has, wid, W)
        tn = tn.at[row, step + 1].set(nn, mode="drop")
        tt = tt.at[row, step + 1].set(nt, mode="drop")
        ln = ln.at[row].add(1, mode="drop")
        return nn, nt, has, tn, tt, ln

    def hop(carry, step):
        wid, node, cur_time, alive, tn, tt, ln, dropped = carry
        nn, nt, has, tn, tt, ln = record_hop(wid, node, cur_time, alive,
                                             tn, tt, ln, step)

        # migrate surviving walks to their new owner (dead walks just free
        # their slot: the trace already lives in the resident buffers)
        owner = placement.owner(nn)
        (r_wid, r_node, r_time), _, n_drop = exchange_by_owner(
            axis, num_shards, walk_bucket_capacity, owner, has,
            (wid, nn, nt), (-1, 0, 0))

        inc_valid = r_wid >= 0
        dest = jnp.where(inc_valid,
                         jnp.cumsum(inc_valid.astype(jnp.int32)) - 1, Ws)
        recv_drop = jnp.sum(inc_valid & (dest >= Ws))
        wid = jnp.full((Ws,), -1, jnp.int32).at[dest].set(r_wid, mode="drop")
        node = jnp.zeros((Ws,), jnp.int32).at[dest].set(r_node, mode="drop")
        cur_time = jnp.zeros((Ws,), jnp.int32).at[dest].set(r_time,
                                                            mode="drop")
        alive = jnp.zeros((Ws,), bool).at[dest].set(inc_valid, mode="drop")
        return (wid, node, cur_time, alive, tn, tt, ln,
                dropped + n_drop + recv_drop), None

    # L-1 migrating hops under the scan, then one record-only final hop:
    # the last hop's migration would place walks nobody ever advances, so
    # skipping it saves one all_to_all per batch without touching the
    # traces (and therefore the bit-identity guarantee)
    carry0 = (wid, node, cur_time, alive, tn, tt, ln,
              jnp.asarray(0, jnp.int32))
    (wid, node, cur_time, alive, tn, tt, ln, dropped), _ = jax.lax.scan(
        hop, carry0, jnp.arange(max(L - 1, 0), dtype=jnp.int32))
    if L >= 1:
        _, _, _, tn, tt, ln = record_hop(
            wid, node, cur_time, alive, tn, tt, ln,
            jnp.asarray(L - 1, jnp.int32))
    return tn, tt, ln, dropped + start_drop, claims


def _shard_walk_lanes(idx, view: TsView, lanes: LaneParams, lane_keys,
                      wcfg: WalkConfig, *, axis: str, num_shards: int,
                      placement: Placement, walk_slots: int,
                      walk_bucket_capacity: int):
    """One coalesced lane batch's walks over the sharded window.

    The serving twin of ``_shard_walks``: every array-of-lanes input
    (``lanes``, ``lane_keys``, the ``view`` start directory) is replicated,
    so any shard can evaluate any lane's next draw — but each lane is
    *claimed* by exactly one shard per step (its current node's owner), so
    every trace cell is written by at most one shard and one ``psum``
    reassembles the exact single-device ``generate_walk_lanes`` result.

    Start claims: nodes mode places lane i on owner(start_node[i]) when the
    node has in-window out-edges (the owner holds the full degree); edges
    mode computes the global start-edge pick from the replicated ts-view —
    bit-identical to the single-device pick because the view's store is —
    and places the lane on owner(dst). Migration then carries 3 ints
    (lane id, node, time); bias / max_len / RNG identity are recovered from
    the replicated ``LaneParams`` by lane id at every hop.
    """
    S, L = wcfg.num_walks, wcfg.max_length
    nc = idx.node_capacity
    Ws = walk_slots
    shard_id = jax.lax.axis_index(axis)
    edges_mode = wcfg.start_mode == "edges"
    lane_ids = jnp.arange(S, dtype=jnp.int32)
    gstore = view.store

    # lane-order trace contributions (see _shard_walks: psum(x - PAD) + PAD)
    tn = jnp.full((S, L + 1), NODE_PAD, jnp.int32)
    tt = jnp.full((S, L + 1), NODE_PAD, jnp.int32)
    ln = jnp.zeros((S,), jnp.int32)

    if edges_mode:
        # global start-edge draw over the replicated ts-view: same formula,
        # same arrays (bitwise) as the single-device start_walks lane path
        u0 = _lane_uniform(lane_keys, 0)
        n_glob = jnp.broadcast_to(gstore.num_edges, (S,)).astype(jnp.int32)
        e = index_pick_lanes(lanes.start_bias, u0, n_glob)
        e = jnp.clip(e, 0, gstore.capacity - 1)
        s_src = gstore.src[e]
        s_cur = gstore.dst[e]
        s_ts = gstore.ts[e]
        alive0 = lanes.active & (gstore.num_edges > 0)
        owner = placement.owner(s_cur)
        mine = alive0 & (owner == shard_id)
        row0 = jnp.where(mine, lane_ids, S)
        tn = tn.at[row0, 0].set(s_src, mode="drop")
        tt = tt.at[row0, 0].set(s_ts, mode="drop")
        tn = tn.at[row0, 1].set(s_cur, mode="drop")
        tt = tt.at[row0, 1].set(s_ts, mode="drop")
        ln = ln.at[row0].add(2, mode="drop")
        start_node, start_time = s_cur, s_ts
        hops, offset = max(L - 1, 0), 1
    else:
        # explicit per-lane start nodes; the owner holds all of v's
        # out-edges, so its degree test equals the single-device one
        v = lanes.start_node
        vc = jnp.clip(v, 0, nc - 1)
        deg = idx.node_starts[vc + 1] - idx.node_starts[vc]
        owner = placement.owner(vc)
        t_floor = jnp.where(gstore.num_edges > 0, gstore.ts[0] - 1, 0)
        mine = (lanes.active & (v >= 0) & (v < nc) & (deg > 0)
                & (owner == shard_id))
        row0 = jnp.where(mine, lane_ids, S)
        start_node = vc
        start_time = jnp.full((S,), 1, jnp.int32) * t_floor
        tn = tn.at[row0, 0].set(start_node, mode="drop")
        tt = tt.at[row0, 0].set(start_time, mode="drop")
        ln = ln.at[row0].add(1, mode="drop")
        hops, offset = L, 0

    # per-shard start-claim counter (ServeStats.lanes_by_shard): counted on
    # device, so edges-mode claims — whose owners are data-dependent — are
    # observable exactly like nodes-mode ones
    claims = jnp.sum(mine.astype(jnp.int32))

    # place claimed lanes into resident slots
    rankm = jnp.cumsum(mine.astype(jnp.int32)) - 1
    wid = jnp.full((Ws,), -1, jnp.int32).at[
        jnp.where(mine, rankm, Ws)].set(lane_ids, mode="drop")
    start_drop = jnp.maximum(jnp.sum(mine.astype(jnp.int32)) - Ws, 0)
    wc0 = jnp.clip(wid, 0, S - 1)
    node = jnp.where(wid >= 0, start_node[wc0], 0).astype(jnp.int32)
    cur_time = jnp.where(wid >= 0, start_time[wc0], 0).astype(jnp.int32)
    alive = wid >= 0

    def record_hop(wid, node, cur_time, alive, tn, tt, ln, step):
        # per-lane draw stream (tag step+1; tag 0 was the start draw) and
        # per-lane bias/budget, recovered from the replicated arrays by the
        # slot's lane id — placement-independent bits, like the replay's
        u_full = _lane_uniform(lane_keys, step + 1)
        wc = jnp.clip(wid, 0, S - 1)
        nn, nt, has = hop_resident_lanes(idx, lanes.bias[wc], node, cur_time,
                                         alive, u_full[wc])
        write_pos = step + offset
        has = has & ((write_pos + 1) <= lanes.max_len[wc])
        row = jnp.where(has, wid, S)
        tn = tn.at[row, write_pos + 1].set(nn, mode="drop")
        tt = tt.at[row, write_pos + 1].set(nt, mode="drop")
        ln = ln.at[row].add(1, mode="drop")
        return nn, nt, has, tn, tt, ln

    def hop(carry, step):
        wid, node, cur_time, alive, tn, tt, ln, dropped = carry
        nn, nt, has, tn, tt, ln = record_hop(wid, node, cur_time, alive,
                                             tn, tt, ln, step)
        owner = placement.owner(nn)
        (r_wid, r_node, r_time), _, n_drop = exchange_by_owner(
            axis, num_shards, walk_bucket_capacity, owner, has,
            (wid, nn, nt), (-1, 0, 0))

        inc_valid = r_wid >= 0
        dest = jnp.where(inc_valid,
                         jnp.cumsum(inc_valid.astype(jnp.int32)) - 1, Ws)
        recv_drop = jnp.sum(inc_valid & (dest >= Ws))
        wid = jnp.full((Ws,), -1, jnp.int32).at[dest].set(r_wid, mode="drop")
        node = jnp.zeros((Ws,), jnp.int32).at[dest].set(r_node, mode="drop")
        cur_time = jnp.zeros((Ws,), jnp.int32).at[dest].set(r_time,
                                                            mode="drop")
        alive = jnp.zeros((Ws,), bool).at[dest].set(inc_valid, mode="drop")
        return (wid, node, cur_time, alive, tn, tt, ln,
                dropped + n_drop + recv_drop), None

    # L-1 migrating hops + one record-only final hop, as in _shard_walks
    carry0 = (wid, node, cur_time, alive, tn, tt, ln,
              jnp.asarray(0, jnp.int32))
    (wid, node, cur_time, alive, tn, tt, ln, dropped), _ = jax.lax.scan(
        hop, carry0, jnp.arange(max(hops - 1, 0), dtype=jnp.int32))
    if hops >= 1:
        _, _, _, tn, tt, ln = record_hop(
            wid, node, cur_time, alive, tn, tt, ln,
            jnp.asarray(hops - 1, jnp.int32))
    return tn, tt, ln, dropped + start_drop, claims


# ---------------------------------------------------------------------------
# Standalone sharded ingest: advance the window by one batch (no walks)
# ---------------------------------------------------------------------------


def _ingest_sharded_impl(state: ShardedWindowState, bsrc, bdst, bts, count, *,
                         mesh: Mesh, axis_name: str, node_capacity: int,
                         shard_cfg: ShardConfig, bias_scale: float = 1.0,
                         placement: Optional[Placement] = None,
                         table=None) -> ShardedWindowState:
    """Advance the sharded window by one batch (``bsrc/bdst/bts`` are
    [D, Bd], the batch axis pre-split per shard; ``count`` the global valid
    prefix length). The shard_map'd single-batch twin of the replay's
    ingest stage; see ``ingest_sharded`` / ``ingest_sharded_nodonate``."""
    D = mesh.devices.size
    if placement is None:
        placement = RangePlacement(num_shards=D, node_capacity=node_capacity)

    def shard_fn(state, bsrc, bdst, bts, count):
        wstate = jax.tree.map(lambda a: a[0], state.window)
        Bd = bsrc.shape[-1]
        gpos = jax.lax.axis_index(axis_name) * Bd + jnp.arange(
            Bd, dtype=jnp.int32)
        new, x_drop = _shard_ingest(
            wstate, bsrc[0], bdst[0], bts[0], gpos < count, axis=axis_name,
            num_shards=D, placement=placement,
            exchange_capacity=shard_cfg.exchange_capacity,
            node_capacity=node_capacity, bias_scale=bias_scale, table=table)
        return ShardedWindowState(
            window=jax.tree.map(lambda a: a[None], new),
            exchange_drops=(state.exchange_drops[0] + x_drop)[None])

    sharded = P(axis_name)
    state_spec = ShardedWindowState(
        window=jax.tree.map(lambda _: sharded, state.window),
        exchange_drops=sharded)
    fn = shard_map(shard_fn, mesh=mesh,
                   in_specs=(state_spec, sharded, sharded, sharded, P()),
                   out_specs=state_spec, check_rep=False)
    return fn(state, bsrc, bdst, bts, count)


# Donating entry point: the replay-style in-place window advance.
ingest_sharded = partial(
    jax.jit,
    static_argnames=("mesh", "axis_name", "node_capacity", "shard_cfg",
                     "bias_scale", "placement", "table"),
    donate_argnums=(0,))(_ingest_sharded_impl)

# Non-donating twin for the sharded serving snapshot double-buffer
# (serve/snapshot.py, DESIGN.md §13): the old ShardedWindowState must stay
# serveable while the next one builds, so the input cannot be donated —
# exactly the ``window.ingest_nodonate`` trade, one sharded window level
# up. Same shard_map'd body, pmax-agreed watermark included.
ingest_sharded_nodonate = partial(
    jax.jit,
    static_argnames=("mesh", "axis_name", "node_capacity", "shard_cfg",
                     "bias_scale", "placement", "table"))(_ingest_sharded_impl)


# ---------------------------------------------------------------------------
# Fused sharded replay: one shard_map'd lax.scan over all batches
# ---------------------------------------------------------------------------


def _check_supported(wcfg: WalkConfig, scfg: SamplerConfig, *,
                     lanes: bool = False) -> None:
    """Static validation of a sharded walk dispatch.

    ``lanes=False`` is the replay walker (all_nodes placement only);
    ``lanes=True`` is the serving lane walker, where start placement is
    owner-computable per lane: explicit start nodes, or start edges
    resolved from the replicated ts-view (DESIGN.md §13).

    The start-mode checks are sharding-specific and live here; every
    sampler-capability refusal (mode, node2vec, bias='table') delegates
    to the engine's single chokepoint, ``walk_engine.check_capabilities``
    with ``sharded=True`` — one matrix, one set of messages.
    """
    if lanes:
        if wcfg.start_mode not in ("nodes", "edges"):
            raise ValueError(
                "sharded lane serving supports start_mode 'nodes'|'edges' "
                f"(got {wcfg.start_mode!r})")
    elif wcfg.start_mode != "all_nodes":
        raise ValueError(
            "sharded streaming walks require start_mode='all_nodes' (start "
            "placement must be owner-computable without global state; got "
            f"{wcfg.start_mode!r})")
    check_capabilities(scfg, "grouped",
                       LaneFeatures() if lanes else None, sharded=True)


@partial(jax.jit,
         static_argnames=("mesh", "axis_name", "node_capacity", "wcfg",
                          "scfg", "shard_cfg", "placement", "with_probes"))
def serve_lanes_sharded(state: ShardedWindowState, view: TsView,
                        key: jax.Array, lanes: LaneParams, *, mesh: Mesh,
                        axis_name: str, node_capacity: int,
                        wcfg: WalkConfig, scfg: SamplerConfig,
                        shard_cfg: ShardConfig,
                        placement: Optional[Placement] = None,
                        with_probes: bool = False):
    """One coalesced lane batch over the node-partitioned window.

    ``state`` is the sharded window (NOT donated: the serving snapshot
    keeps it readable across dispatches), ``view`` the replicated ts-view
    of the same window version, ``key`` the service's stable base key and
    ``lanes`` the packed per-lane params. Returns (nodes, times, lengths,
    drops, claims): walk leaves with a leading [D] replicated axis
    (callers read row 0) shaped like the single-device
    ``generate_walk_lanes`` result, plus two per-shard [D] counters —
    ``drops`` (start-slot + migration overflow — 0 under healthy
    provisioning, and required for the bit-identity guarantee) and
    ``claims`` (start lanes claimed by each shard, the device-side source
    of ``ServeStats.lanes_by_shard`` for both start modes).
    ``with_probes=True`` appends a sixth output — an obs serve-probe
    matrix int32[D, NUM_SERVE_PROBES] (claims / drops / per-shard hop
    cells) for ``obs.flush_serve_probes`` — computed from values the
    dispatch already produces, so walks stay bit-identical (pinned by
    tests/test_obs_probes.py).
    """
    _check_supported(wcfg, scfg, lanes=True)
    D = mesh.devices.size
    if placement is None:
        placement = RangePlacement(num_shards=D, node_capacity=node_capacity)

    def shard_fn(state, view, key, lanes):
        wstate = jax.tree.map(lambda a: a[0], state.window)
        # lane RNG identity: fold (request seed, walk-within-request) into
        # the base key — replicated math, identical on every shard
        lane_keys = _lane_keys(key, lanes)
        tn, tt, ln, drop, claims = _shard_walk_lanes(
            wstate.index, view, lanes, lane_keys, wcfg, axis=axis_name,
            num_shards=D, placement=placement,
            walk_slots=shard_cfg.walk_slots,
            walk_bucket_capacity=shard_cfg.walk_bucket_capacity)
        nodes = NODE_PAD + jax.lax.psum(tn - NODE_PAD, axis_name)
        times = NODE_PAD + jax.lax.psum(tt - NODE_PAD, axis_name)
        lengths = jax.lax.psum(ln, axis_name)
        outs = (nodes[None], times[None], lengths[None], drop[None],
                claims[None])
        if with_probes:
            # start cells are written only by the claiming shard (2 per
            # lane in edges mode: src + first dst), so this shard's hop
            # cells are its length contributions minus its start cells
            start_cells = claims * (2 if wcfg.start_mode == "edges" else 1)
            sp = serve_probe_zeros()
            sp = sp.at[SP_LANES_CLAIMED].add(claims)
            sp = sp.at[SP_WALK_DROPS].add(drop)
            sp = sp.at[SP_HOPS].add(jnp.sum(ln) - start_cells)
            outs = outs + (sp[None],)
        return outs

    sharded = P(axis_name)
    state_spec = ShardedWindowState(
        window=jax.tree.map(lambda _: sharded, state.window),
        exchange_drops=sharded)
    view_spec = jax.tree.map(lambda _: P(), view)
    lane_spec = LaneParams(*([P()] * len(LaneParams._fields)))
    out_specs = (sharded,) * (6 if with_probes else 5)
    fn = shard_map(shard_fn, mesh=mesh,
                   in_specs=(state_spec, view_spec, P(), lane_spec),
                   out_specs=out_specs, check_rep=False)
    return fn(state, view, key, lanes)


@partial(jax.jit,
         static_argnames=("axis_name", "node_capacity", "wcfg", "scfg",
                          "shard_cfg", "bias_scale", "mesh", "placement",
                          "with_probes"),
         donate_argnums=(0,))
def _replay_scan_sharded(state: ShardedWindowState, bsrc, bdst, bts, bcount,
                         key, *, mesh: Mesh, axis_name: str,
                         node_capacity: int, wcfg: WalkConfig,
                         scfg: SamplerConfig, shard_cfg: ShardConfig,
                         bias_scale: float = 1.0,
                         placement: Optional[Placement] = None,
                         with_probes: bool = False):
    """Replay K stacked batches over the sharded window, fully on device.

    ``bsrc/bdst/bts`` are [K, D, Bd] (the batch axis pre-split per shard),
    ``bcount`` [K]. Returns (new state, per-batch stat leaves, final-batch
    walk leaves); everything carries a leading [D] axis — psum'd leaves are
    replicated so callers read row 0. ``with_probes=True`` appends one
    obs probe matrix int32[D, NUM_REPLAY_PROBES] (shard-local counters
    accumulated across batches in the scan carry — pure arithmetic on
    values the replay already computes, RNG chain untouched).
    """
    D = mesh.devices.size
    if placement is None:
        placement = RangePlacement(num_shards=D, node_capacity=node_capacity)

    def shard_fn(state, bsrc, bdst, bts, bcount, key):
        wstate = jax.tree.map(lambda a: a[0], state.window)
        xdrops = state.exchange_drops[0]
        lsrc, ldst, lts = bsrc[:, 0], bdst[:, 0], bts[:, 0]   # [K, Bd]
        Bd = lsrc.shape[-1]
        shard_id = jax.lax.axis_index(axis_name)
        # local slice covers global batch positions [shard_id*Bd, ...+Bd)
        gpos = shard_id * Bd + jnp.arange(Bd, dtype=jnp.int32)

        def batch_step(carry, xs):
            if with_probes:
                wstate, xdrops, k, pv = carry
            else:
                wstate, xdrops, k = carry
            w0 = wstate
            src, dst, ts, cnt = xs
            k, sub = jax.random.split(k)
            wstate, x_drop = _shard_ingest(
                wstate, src, dst, ts, gpos < cnt, axis=axis_name,
                num_shards=D, placement=placement,
                exchange_capacity=shard_cfg.exchange_capacity,
                node_capacity=node_capacity, bias_scale=bias_scale)

            # same key chain as the single-device replay_scan
            _, walk_key = jax.random.split(sub)
            tn, tt, ln, w_drop, claims = _shard_walks(
                wstate.index, walk_key, wcfg, scfg, axis=axis_name,
                num_shards=D, placement=placement,
                walk_slots=shard_cfg.walk_slots,
                walk_bucket_capacity=shard_cfg.walk_bucket_capacity)

            lengths = jax.lax.psum(ln, axis_name)
            stats = ReplayStats(
                edges_active=jax.lax.psum(wstate.index.num_edges, axis_name),
                t_now=wstate.t_now,      # watermark-agreed: replicated
                ingested=jax.lax.psum(wstate.ingested, axis_name),
                late_drops=jax.lax.psum(wstate.late_drops, axis_name),
                overflow_drops=jax.lax.psum(wstate.overflow_drops,
                                            axis_name),
                mean_len=jnp.mean(lengths.astype(jnp.float32)),
            )
            if with_probes:
                # shard-local deltas (the flush sums label series); the
                # emitted-walk count is global, so only shard 0 records it
                pv = replay_probe_update(
                    pv,
                    ingested_delta=wstate.ingested - w0.ingested,
                    late_delta=wstate.late_drops - w0.late_drops,
                    overflow_delta=wstate.overflow_drops - w0.overflow_drops,
                    exchange_drops=x_drop,
                    walk_drops=w_drop,
                    hops=jnp.sum(ln) - claims)
                emitted = jnp.sum((lengths >= 2).astype(jnp.int32))
                pv = pv.at[RP_WALKS_EMITTED].add(
                    jnp.where(shard_id == 0, emitted, 0))
                return ((wstate, xdrops + x_drop, k, pv),
                        (stats, x_drop, w_drop, tn, tt, ln))
            return ((wstate, xdrops + x_drop, k),
                    (stats, x_drop, w_drop, tn, tt, ln))

        carry0 = [wstate, xdrops, key]
        if with_probes:
            carry0.append(replay_probe_zeros())
        carry, (stats, x_drops, w_drops, tns, tts, lns) = \
            jax.lax.scan(batch_step, tuple(carry0),
                         (lsrc, ldst, lts, bcount))
        wstate, xdrops = carry[0], carry[1]

        # reassemble the final batch's walks (each cell written by ≤ 1
        # shard; contributions are PAD elsewhere)
        tn, tt, ln = tns[-1], tts[-1], lns[-1]
        nodes = NODE_PAD + jax.lax.psum(tn - NODE_PAD, axis_name)
        times = NODE_PAD + jax.lax.psum(tt - NODE_PAD, axis_name)
        lengths = jax.lax.psum(ln, axis_name)

        new_state = ShardedWindowState(
            window=jax.tree.map(lambda a: a[None], wstate),
            exchange_drops=xdrops[None])
        expand = lambda a: a[None]
        outs = (new_state, jax.tree.map(expand, stats), x_drops[None],
                w_drops[None], expand(nodes), expand(times), expand(lengths))
        if with_probes:
            outs = outs + (expand(carry[3]),)
        return outs

    sharded = P(axis_name)
    state_spec = ShardedWindowState(
        window=jax.tree.map(lambda _: sharded, state.window),
        exchange_drops=sharded)
    stats_spec = ReplayStats(*([sharded] * len(ReplayStats._fields)))
    out_specs = (state_spec, stats_spec, sharded, sharded, sharded,
                 sharded, sharded)
    if with_probes:
        out_specs = out_specs + (sharded,)
    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(state_spec, P(None, axis_name), P(None, axis_name),
                  P(None, axis_name), P(), P()),
        out_specs=out_specs,
        check_rep=False)
    return fn(state, bsrc, bdst, bts, bcount, key)


class DistributedStreamingEngine:
    """Streaming ingest → rebuild → walk over a node-partitioned window.

    The distributed counterpart of ``StreamingEngine.replay_device``: the
    window lives sharded across ``mesh`` (per-shard capacity
    ``cfg.shard.edge_capacity_per_shard``, so total window capacity scales
    with device count), batches ingest through one all_to_all per batch,
    and walks migrate between owners per hop. For
    ``SamplerConfig.mode="index"`` the replay is bit-identical to the
    single-device engine for identical keys (any shard count, provided no
    capacity drops — check ``DistReplayStats``); per-hop grouping does not
    apply (the migration layout is its own schedule), which changes nothing
    observable since every scheduler path emits identical walks.
    """

    def __init__(self, cfg: EngineConfig, batch_capacity: int, *,
                 mesh: Optional[Mesh] = None, num_shards: int = 0,
                 placement: Optional[Placement] = None,
                 registry: Optional[MetricsRegistry] = None,
                 probes: bool = True):
        self.cfg = cfg
        # obs integration (DESIGN.md §16); ``probes=False`` pins
        # replay_device to the historical uninstrumented program
        self.registry = registry if registry is not None else get_registry()
        self.probes = probes
        self.mesh = mesh if mesh is not None else window_mesh(
            num_shards or cfg.shard.num_shards)
        self.axis_name = self.mesh.axis_names[0]
        D = self.mesh.devices.size
        self.num_shards = D
        if placement is None:
            placement = make_placement(
                cfg.shard.placement, D, cfg.window.node_capacity,
                hash_buckets=cfg.shard.hash_buckets)
        if placement.num_shards != D:
            raise ValueError(
                f"placement covers {placement.num_shards} shards; mesh has "
                f"{D} devices")
        if placement.node_capacity != cfg.window.node_capacity:
            raise ValueError(
                f"placement node_capacity {placement.node_capacity} != "
                f"window node_capacity {cfg.window.node_capacity}")
        self.placement = placement
        # per-shard batch slice: round the capacity up to a D multiple
        self._requested_batch_capacity = batch_capacity
        self.batch_slice = -(-batch_capacity // D)
        self.batch_capacity = self.batch_slice * D
        self.state = init_sharded_window(
            D, cfg.shard.edge_capacity_per_shard, cfg.window.node_capacity,
            int(cfg.window.duration), mesh=self.mesh,
            axis_name=self.axis_name)
        self.key = jax.random.PRNGKey(cfg.seed)

    def ingest_batch(self, src, dst, ts) -> None:
        """Advance the sharded window by one batch (no walks) — the
        distributed twin of ``StreamingEngine.ingest_batch``."""
        from repro.core.edge_store import make_batch
        batch = make_batch(src, dst, ts, capacity=self.batch_capacity)
        split = lambda a: a.reshape(self.num_shards, self.batch_slice)
        self.state = ingest_sharded(
            self.state, split(batch.src), split(batch.dst), split(batch.ts),
            batch.count, mesh=self.mesh, axis_name=self.axis_name,
            node_capacity=self.cfg.window.node_capacity,
            shard_cfg=self.cfg.shard, placement=self.placement)

    def replay_device(self, batches, wcfg: WalkConfig):
        """One shard_map'd ``lax.scan`` over all batches; a single host
        sync at the end. Returns (DistReplayStats, final-batch WalkResult,
        wall seconds)."""
        _check_supported(wcfg, self.cfg.sampler)
        stacked = stack_batches(batches, self.batch_capacity)
        K = stacked.src.shape[0]
        split = lambda a: a.reshape(K, self.num_shards, self.batch_slice)
        self.key, sub = jax.random.split(self.key)
        t0 = time.perf_counter()
        outs = _replay_scan_sharded(
            self.state, split(stacked.src), split(stacked.dst),
            split(stacked.ts), stacked.count, sub, mesh=self.mesh,
            axis_name=self.axis_name,
            node_capacity=self.cfg.window.node_capacity, wcfg=wcfg,
            scfg=self.cfg.sampler, shard_cfg=self.cfg.shard,
            placement=self.placement, with_probes=self.probes)
        if self.probes:
            (self.state, stats, x_drops, w_drops, nodes, times, lengths,
             pv) = outs
            # the single sync point — probes ride the same materialization
            jax.block_until_ready((lengths, pv))
        else:
            (self.state, stats, x_drops, w_drops, nodes, times,
             lengths) = outs
            jax.block_until_ready(lengths)      # the single sync point
        elapsed = time.perf_counter() - t0
        replay = ReplayStats(*(np.asarray(a)[0] for a in stats))
        if self.probes:
            self._publish_replay(pv, replay, elapsed)
        dstats = DistReplayStats(
            replay=replay,
            exchange_drops=np.asarray(x_drops).T,     # [D, K] -> [K, D]
            walk_drops=np.asarray(w_drops).T,
        )
        walks = WalkResult(nodes=np.asarray(nodes)[0],
                           times=np.asarray(times)[0],
                           lengths=np.asarray(lengths)[0], stats=None)
        return dstats, walks, elapsed

    def _publish_replay(self, pv, replay: ReplayStats, elapsed: float
                        ) -> None:
        """Flush the per-shard probe matrix + window gauges after a
        replay's single host sync (the arrays are already materialized)."""
        reg = self.registry
        mat = np.asarray(pv)                     # [D, NUM_REPLAY_PROBES]
        for d in range(mat.shape[0]):
            flush_replay_probes(reg, mat[d], driver="sharded", shard=d)
        loads = self.shard_loads()
        for d, v in enumerate(loads):
            reg.set_gauge("shard_edges_active", int(v),
                          labels={"shard": str(d)},
                          help="resident window edges per shard")
        cap = self.cfg.shard.edge_capacity_per_shard * self.num_shards
        edges = int(replay.edges_active[-1]) if replay.edges_active.size \
            else 0
        reg.set_gauge("window_edges_active", edges,
                      help="edges resident in the temporal window")
        reg.set_gauge("window_t_now",
                      int(replay.t_now[-1]) if replay.t_now.size else 0,
                      help="watermark timestamp of the window")
        reg.set_gauge("window_occupancy", edges / cap,
                      help="window fill fraction (edges_active / capacity)")
        reg.observe("replay_seconds", elapsed, labels={"driver": "sharded"},
                    help="wall time per replay_device call")

    # ------------------------------------------------------------------
    # Placement control plane: measured load -> new placement -> reshard
    # ------------------------------------------------------------------

    def node_loads(self) -> np.ndarray:
        """Per-node in-window out-degree [node_capacity] (host-side).

        The skew signal: under a power-law stream, range placement piles
        the hub nodes' edges onto few shards; feeding these loads to
        ``SkewPlacement.from_loads`` builds the hot-node override table
        that ``rebalance`` reshards onto.
        """
        # node_starts spans nc real nodes + the virtual padding node; the
        # per-node degree diff is trimmed to the real ids
        ns = np.asarray(self.state.window.index.node_starts)
        nc = self.cfg.window.node_capacity
        return (ns[:, 1:] - ns[:, :-1]).sum(axis=0)[:nc]

    def shard_loads(self) -> np.ndarray:
        """Resident window edges per shard [D] (the imbalance metric)."""
        return np.asarray(self.state.window.index.num_edges)

    def reshard_to(self, new_placement: Placement) -> None:
        """Live reshard: re-bucket the resident window onto
        ``new_placement`` (different policy and/or shard count) through
        one all_to_all; ingest/replay continue against the new layout.
        The walk RNG chain is untouched — replay stays bit-identical to
        the single-device engine across the reshard (absent drops)."""
        before = int(np.asarray(self.state.exchange_drops).sum())
        self.state, self.mesh = reshard(
            self.state, self.placement, new_placement,
            axis_name=self.axis_name)
        # exchange_drops is cumulative; the reshard's own contribution is
        # the per-shard capacity clip — published under its canonical kind
        after = int(np.asarray(self.state.exchange_drops).sum())
        count_drop(self.registry, "reshard_clip", max(0, after - before))
        self.registry.inc("reshards_total", 1,
                          help="live placement reshards executed")
        self.placement = new_placement
        D = new_placement.num_shards
        self.num_shards = D
        self.batch_slice = -(-self._requested_batch_capacity // D)
        self.batch_capacity = self.batch_slice * D

    def rebalance(self, k: Optional[int] = None) -> Placement:
        """Measure per-node load, build a top-K hub override placement on
        the current base policy, and reshard onto it. Returns the new
        placement."""
        base = (self.placement.base
                if isinstance(self.placement, SkewPlacement)
                else self.placement)
        new = SkewPlacement.from_loads(
            base, self.node_loads(),
            k=k if k is not None else self.cfg.shard.hot_k)
        self.reshard_to(new)
        return new


# ---------------------------------------------------------------------------
# Live resharding: re-bucket a resident window under a new placement
# ---------------------------------------------------------------------------


def _pad_shards(state: ShardedWindowState, num: int) -> ShardedWindowState:
    """Append ``num`` empty shard slices (same Δ, zeroed clock/counters).

    Host-side prep for a shard-count-increasing reshard: the exchange mesh
    spans max(D_old, D_new) devices, so a growing window first gains empty
    slices. Their t_now starts at 0 and is pmax-repaired on device.
    """
    w = state.window
    E = int(w.index.store.src.shape[1])
    nc = int(w.index.node_starts.shape[1]) - 1
    delta = int(np.asarray(w.window)[0])
    empty = init_window(E, nc, delta)
    pad = jax.tree.map(lambda x: jnp.broadcast_to(x, (num,) + x.shape),
                       empty)
    window = jax.tree.map(lambda a, p: jnp.concatenate([a, p]), w, pad)
    return ShardedWindowState(
        window=window,
        exchange_drops=jnp.concatenate(
            [state.exchange_drops, jnp.zeros((num,), jnp.int32)]))


@partial(jax.jit,
         static_argnames=("mesh", "axis_name", "placement", "bias_scale"))
def _reshard_impl(state: ShardedWindowState, *, mesh: Mesh, axis_name: str,
                  placement: Placement, bias_scale: float = 1.0
                  ) -> ShardedWindowState:
    """shard_map'd reshard body over a max(D_old, D_new)-device mesh.

    Each shard sends every resident edge to ``placement.owner(src)`` with
    per-(sender, dest) bucket capacity E — a sender holds at most E edges
    total, so the exchange itself can NEVER drop. The receiver re-merges
    by the canonical rule: received runs concatenated in old-shard-id
    order with sender-position preserved (``exchange_by_owner``'s order
    guarantee), one stable ts-argsort (ties therefore break by (old
    shard, position) — for edges of one source node that is their
    original relative order, which is all walk bit-identity needs), then
    an overflow clip keeping the NEWEST E edges (``_clip_to_capacity``'s
    rule) with the loss counted in ``exchange_drops``.

    Counters: per-shard ``ingested``/``late_drops``/``overflow_drops``/
    ``exchange_drops`` are psum'd onto shard 0 (zeros elsewhere), so their
    shard-sums — the quantities the identity tests compare against the
    single-device engine — survive any shard-count change.
    """
    Dm = mesh.devices.size
    nc = placement.node_capacity

    def shard_fn(state):
        wstate = jax.tree.map(lambda a: a[0], state.window)
        store = wstate.index.store
        E = store.capacity
        valid = jnp.arange(E, dtype=jnp.int32) < store.num_edges
        owner = placement.owner(store.src)
        (r_src, r_dst, r_ts), _, x_drop = exchange_by_owner(
            axis_name, Dm, E, owner, valid,
            (store.src, store.dst, store.ts), (nc, 0, TS_PAD))

        # canonical merge: stable ts sort over the [Dm*E] receive buffer
        # (TS_PAD rows sink to the back), then clip keeping the newest E
        order = jnp.argsort(r_ts).astype(jnp.int32)
        msrc, mdst, mts = r_src[order], r_dst[order], r_ts[order]
        cnt = jnp.sum((r_ts != TS_PAD).astype(jnp.int32))
        overflow = jnp.maximum(cnt - E, 0)
        idx2 = jnp.arange(E, dtype=jnp.int32) + overflow
        live2 = jnp.arange(E, dtype=jnp.int32) < jnp.minimum(cnt, E)
        gidx = jnp.clip(idx2, 0, Dm * E - 1)
        new_store = EdgeStore(
            src=jnp.where(live2, msrc[gidx], nc),
            dst=jnp.where(live2, mdst[gidx], 0),
            ts=jnp.where(live2, mts[gidx], TS_PAD),
            num_edges=jnp.minimum(cnt, E).astype(jnp.int32))
        index = build_index(new_store, nc, bias_scale)

        # clock: pmax repairs padded shards' zero t_now / Δ
        t_now = jax.lax.pmax(wstate.t_now, axis_name)
        delta = jax.lax.pmax(wstate.window, axis_name)

        # counters: global sums live on shard 0 after a reshard
        sid = jax.lax.axis_index(axis_name)
        on0 = lambda x: jnp.where(sid == 0, jax.lax.psum(x, axis_name), 0)
        new_w = WindowState(
            index=index, t_now=t_now, window=delta,
            ingested=on0(wstate.ingested),
            late_drops=on0(wstate.late_drops),
            overflow_drops=on0(wstate.overflow_drops))
        xd = on0(state.exchange_drops[0] + x_drop) + overflow
        return ShardedWindowState(
            window=jax.tree.map(lambda a: a[None], new_w),
            exchange_drops=xd[None])

    sharded = P(axis_name)
    state_spec = ShardedWindowState(
        window=jax.tree.map(lambda _: sharded, state.window),
        exchange_drops=sharded)
    fn = shard_map(shard_fn, mesh=mesh, in_specs=(state_spec,),
                   out_specs=state_spec, check_rep=False)
    return fn(state)


def reshard(state: ShardedWindowState, old_placement: Placement,
            new_placement: Placement, *, mesh: Optional[Mesh] = None,
            axis_name: str = WINDOW_AXIS, bias_scale: float = 1.0):
    """Re-bucket a resident sharded window from one placement to another.

    One all_to_all + per-shard canonical re-merge (see ``_reshard_impl``);
    handles shard-count changes in both directions by running the
    exchange over max(D_old, D_new) devices (growing windows are padded
    with empty slices first; shrinking ones are truncated after — shards
    ≥ D_new receive nothing by construction since owners are < D_new).
    Edge-preserving except for the counted per-shard capacity clip (a
    shard asked to own more than its E-capacity drops the oldest).

    Returns ``(new_state, new_mesh)`` with the state placed on a
    D_new-device mesh. This is the control-plane path behind
    ``DistributedStreamingEngine.reshard_to`` and the elastic checkpoint
    restore; a placement change recompiles downstream programs — the
    expected cost of a topology event.
    """
    D_old = int(state.exchange_drops.shape[0])
    D_new = new_placement.num_shards
    if old_placement.num_shards != D_old:
        raise ValueError(
            f"old placement covers {old_placement.num_shards} shards; "
            f"state has {D_old}")
    if old_placement.node_capacity != new_placement.node_capacity:
        raise ValueError("placements disagree on node_capacity")
    Dm = max(D_old, D_new)
    if mesh is None:
        mesh = window_mesh(Dm, axis_name=axis_name)
    elif mesh.devices.size != Dm:
        raise ValueError(
            f"reshard mesh must span max(D_old, D_new) = {Dm} devices "
            f"(got {mesh.devices.size})")
    if D_old < Dm:
        state = _pad_shards(state, Dm - D_old)
    state = jax.device_put(
        state, NamedSharding(mesh, P(axis_name)))
    new_state = _reshard_impl(state, mesh=mesh, axis_name=axis_name,
                              placement=new_placement,
                              bias_scale=bias_scale)
    if D_new < Dm:
        new_state = jax.device_get(new_state)
        new_state = jax.tree.map(lambda a: jnp.asarray(a[:D_new]), new_state)
    new_mesh = mesh if Dm == D_new else window_mesh(D_new,
                                                    axis_name=axis_name)
    new_state = jax.device_put(
        new_state, NamedSharding(new_mesh, P(axis_name)))
    return new_state, new_mesh


def reshard_host(state: ShardedWindowState, new_placement: Placement,
                 bias_scale: float = 1.0) -> ShardedWindowState:
    """Numpy mirror of ``reshard``'s canonical merge (no device mesh).

    The elastic checkpoint restore path (train/checkpoint.py): a window
    saved at 8 shards must restore on a 2-device host, where the
    max(D_old, D_new)-device exchange cannot run. Per new shard: old
    shards' owned edges concatenated in old-shard-id order (position
    preserved), one stable ts sort, clip keeping the newest E — the exact
    receiver rule of ``_reshard_impl``, so device and host reshards agree
    bitwise (tested in tests/test_reshard_checkpoint.py).
    """
    w = state.window
    src = np.asarray(w.index.store.src)      # [D_old, E]
    dst = np.asarray(w.index.store.dst)
    ts = np.asarray(w.index.store.ts)
    n = np.asarray(w.index.store.num_edges)  # [D_old]
    D_old, E = src.shape
    D_new = new_placement.num_shards
    nc = new_placement.node_capacity

    owners = [new_placement.owner_np(src[s][:n[s]]) for s in range(D_old)]
    windows, xdrops = [], np.zeros(D_new, np.int64)
    for d in range(D_new):
        parts = [(src[s][:n[s]][owners[s] == d],
                  dst[s][:n[s]][owners[s] == d],
                  ts[s][:n[s]][owners[s] == d]) for s in range(D_old)]
        csrc = np.concatenate([p[0] for p in parts])
        cdst = np.concatenate([p[1] for p in parts])
        cts = np.concatenate([p[2] for p in parts])
        order = np.argsort(cts, kind="stable")
        csrc, cdst, cts = csrc[order], cdst[order], cts[order]
        overflow = max(len(cts) - E, 0)
        xdrops[d] = overflow
        csrc, cdst, cts = csrc[overflow:], cdst[overflow:], cts[overflow:]
        cnt = len(cts)
        store = EdgeStore(
            src=jnp.asarray(np.pad(csrc, (0, E - cnt),
                                   constant_values=nc), jnp.int32),
            dst=jnp.asarray(np.pad(cdst, (0, E - cnt)), jnp.int32),
            ts=jnp.asarray(np.pad(cts, (0, E - cnt),
                                  constant_values=TS_PAD), jnp.int32),
            num_edges=jnp.asarray(cnt, jnp.int32))
        index = build_index(store, nc, bias_scale)
        t_now = jnp.asarray(int(np.asarray(w.t_now).max()), jnp.int32)
        delta = jnp.asarray(int(np.asarray(w.window).max()), jnp.int32)
        z = lambda v: jnp.asarray(v, jnp.int32)
        windows.append(WindowState(
            index=index, t_now=t_now, window=delta,
            ingested=z(int(np.asarray(w.ingested).sum()) if d == 0 else 0),
            late_drops=z(int(np.asarray(w.late_drops).sum())
                         if d == 0 else 0),
            overflow_drops=z(int(np.asarray(w.overflow_drops).sum())
                             if d == 0 else 0)))
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *windows)
    old_x = int(np.asarray(state.exchange_drops).sum())
    xd = xdrops.astype(np.int64)
    xd[0] += old_x
    return ShardedWindowState(window=stacked,
                              exchange_drops=jnp.asarray(xd, jnp.int32))
