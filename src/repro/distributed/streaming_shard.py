"""Node-partitioned sliding window: distributed streaming ingest + walks
(DESIGN.md §12).

``core/distributed.py`` shards the *static* edge store across devices and
migrates walks between owners; every streaming path so far (`ingest`,
`replay_scan`, `StreamingEngine`) still lives on one device, and
``sample_walks_sharded`` shards only the walk axis over a *replicated*
index. This module makes the **window itself** sharded, so both ingestion
capacity and walk throughput scale with device count — the regime where an
81B-edge window exceeds one chip's HBM:

* **Ownership** — nodes are range-partitioned, ``owner(v) = v //
  range_size`` with ``range_size = ceil(node_capacity / D)`` (the same rule
  as ``core/distributed.py``); shard d holds the merge-sorted window slice
  of edges whose *source* it owns, so Γ_t(v) is always served locally.
* **Sharded ingest** — each shard takes a 1/D slice of the incoming batch,
  buckets it by edge-source owner, and one ``all_to_all``
  (``exchange_by_owner``) delivers every edge to its owner. The owner
  compacts its received edges to a ts-sorted prefix and runs the
  single-device rank-based two-run merge (``window.ingest_impl``) locally.
* **Watermark agreement** — eviction must be causally consistent: the new
  ``t`` is the max batch timestamp across *all* shards (one ``pmax``
  before the exchange), passed to ``ingest_impl`` through its ``watermark``
  hook so every shard evicts against the same cutoff t − Δ even when its
  local batch slice is old.
* **Sharded walks** — per batch, walks start on their start node's owner
  and migrate every hop (``hop_resident`` + ``exchange_by_owner``) against
  the freshly ingested shard-local dual indexes. Hop draws are the
  streaming engine's own: ``uniform(fold_in(walk_key, step), (W,))``
  indexed by walk id — a pure function of (walk, step), independent of
  placement — so for ``SamplerConfig.mode="index"`` the replay is
  **bit-identical to the single-device ``StreamingEngine.replay_device``**
  for identical keys at any shard count (tested at 1/2/8 in
  tests/test_streaming_shard.py). ``mode="weight"`` runs but is only
  numerically (not bit-) equivalent: its prefix-sum arrays accumulate in a
  different float order per shard.
* **Trace handling** — unlike ``core/distributed.py`` (which migrates each
  walk's full trace every hop), each shard scatters the hops it executes
  into a resident ``[W, L+1]`` walk-order buffer; one ``psum`` at the end
  reassembles the global result (every cell is written by at most one
  shard). Migration payload shrinks from O(L) to 3 ints per walk, at the
  cost of an O(W·L) buffer per shard.

All capacities are static (``ShardConfig``): exchange buckets, resident
walk slots, and walk-migration buckets drop on overflow and count the
event per shard — provisioning knobs exactly like the paper's walk-array
capacity.
"""
from __future__ import annotations

import time
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import (
    EngineConfig,
    SamplerConfig,
    ShardConfig,
    WalkConfig,
)
from repro.core.distributed import (
    exchange_by_owner,
    hop_resident,
    owner_range_size,
)
from repro.core.edge_store import TS_PAD, EdgeBatch, stack_batches
from repro.core.streaming import ReplayStats
from repro.core.walk_engine import NODE_PAD, WalkResult
from repro.core.window import WindowState, ingest_impl, init_window

WINDOW_AXIS = "window_shards"


class ShardedWindowState(NamedTuple):
    """Per-shard window slices, stacked on a leading [D] device axis.

    ``window`` holds one ``WindowState`` per shard (its counters are
    shard-local: summed over shards, ``late_drops``/``overflow_drops``
    equal the single-device window's, and ``ingested`` counts edges
    *delivered* — it lags the global count by ``exchange_drops``).
    """

    window: WindowState          # leaves [D, ...]
    exchange_drops: jax.Array    # int32[D] cumulative ingest-exchange drops


class DistReplayStats(NamedTuple):
    """Distributed replay statistics.

    ``replay`` carries the global per-batch trajectory in the same layout
    as the single-device ``ReplayStats`` — bit-comparable field by field
    when no shard dropped anything. The drop counters are per-batch,
    per-shard [K, D] (senders count their own exchange overflow).
    """

    replay: ReplayStats
    exchange_drops: jax.Array    # int32[K, D] batch-edge exchange overflow
    walk_drops: jax.Array        # int32[K, D] walk migration + slot overflow


def window_mesh(num_shards: int = 0, devices=None,
                axis_name: str = WINDOW_AXIS) -> Mesh:
    """1-D mesh over the first ``num_shards`` (default: all) devices."""
    devs = np.asarray(devices if devices is not None else jax.devices())
    if num_shards:
        if num_shards > devs.size:
            raise ValueError(f"{num_shards} shards > {devs.size} devices")
        devs = devs[:num_shards]
    return Mesh(devs, (axis_name,))


def init_sharded_window(num_shards: int, edge_capacity_per_shard: int,
                        node_capacity: int, window: int,
                        bias_scale: float = 1.0,
                        mesh: Optional[Mesh] = None,
                        axis_name: str = WINDOW_AXIS) -> ShardedWindowState:
    """D empty per-shard windows; placed onto the mesh when given."""
    one = init_window(edge_capacity_per_shard, node_capacity, window,
                      bias_scale)
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (num_shards,) + x.shape), one)
    state = ShardedWindowState(
        window=stacked,
        exchange_drops=jnp.zeros((num_shards,), jnp.int32))
    if mesh is not None:
        state = jax.device_put(state, NamedSharding(mesh, P(axis_name)))
    return state


# ---------------------------------------------------------------------------
# Per-shard bodies (run under shard_map; all arrays are local views)
# ---------------------------------------------------------------------------


def _shard_ingest(wstate: WindowState, bsrc, bdst, bts, bvalid, *, axis: str,
                  num_shards: int, range_size: int, exchange_capacity: int,
                  node_capacity: int, bias_scale: float):
    """One shard's window advance for its slice of the incoming batch.

    batch slice → owner buckets → all_to_all → compact → local merge, with
    the eviction watermark agreed across shards *before* the exchange (so
    it reflects every arriving edge, even one a full bucket drops).
    """
    # (1) watermark agreement: global max batch timestamp
    local_max = jnp.max(jnp.where(bvalid, bts, -TS_PAD))
    watermark = jax.lax.pmax(local_max, axis)

    # (2) bucket by edge-source owner, one all_to_all
    owner = jnp.clip(bsrc // range_size, 0, num_shards - 1)
    (r_src, r_dst, r_ts), _, x_drop = exchange_by_owner(
        axis, num_shards, exchange_capacity, owner, bvalid,
        (bsrc, bdst, bts), (0, 0, TS_PAD))

    # (3) compact received edges to a ts-sorted prefix. Empty exchange
    # slots carry TS_PAD, so one stable ts-argsort both drops them to the
    # back and pre-sorts the run; ties keep (sender, sender-position) ==
    # global batch order, matching the single-device stable batch sort.
    order = jnp.argsort(r_ts).astype(jnp.int32)
    cnt = jnp.sum((r_ts != TS_PAD).astype(jnp.int32))
    local_batch = EdgeBatch(src=r_src[order], dst=r_dst[order],
                            ts=r_ts[order], count=cnt)

    # (4) the single-device rank-based two-run merge, shard-locally,
    # evicting against the agreed watermark
    new = ingest_impl(wstate, local_batch, node_capacity, bias_scale,
                      watermark=watermark)
    return new, x_drop


def _shard_walks(idx, walk_key: jax.Array, wcfg: WalkConfig,
                 scfg: SamplerConfig, *, axis: str, num_shards: int,
                 range_size: int, walk_slots: int,
                 walk_bucket_capacity: int):
    """One batch's walks over the sharded window (start_mode="all_nodes").

    Returns this shard's trace contributions (walk-order [W, L+1] arrays,
    NODE_PAD where this shard executed no hop), its [W] length
    contributions, and its drop count. ``psum`` across shards reassembles
    the exact single-device WalkResult.
    """
    W, L = wcfg.num_walks, wcfg.max_length
    nc = idx.node_capacity
    Ws = walk_slots
    shard_id = jax.lax.axis_index(axis)

    # global t_floor: min in-window timestamp across shards, minus one
    # (empty shards report TS_PAD via their padded store)
    any_edges = jax.lax.pmax(idx.num_edges, axis) > 0
    global_min = jax.lax.pmin(idx.store.ts[0], axis)
    t_floor = jnp.where(any_edges, global_min - 1, 0)

    # place walk w (start node w % nc) on its start node's owner
    w_all = jnp.arange(W, dtype=jnp.int32)
    v_all = (w_all % nc).astype(jnp.int32)
    mine = (v_all // range_size) == shard_id
    rankm = jnp.cumsum(mine.astype(jnp.int32)) - 1
    wid = jnp.full((Ws,), -1, jnp.int32).at[
        jnp.where(mine, rankm, Ws)].set(w_all, mode="drop")
    start_drop = jnp.maximum(jnp.sum(mine.astype(jnp.int32)) - Ws, 0)
    node = jnp.where(wid >= 0, wid % nc, 0).astype(jnp.int32)
    vc = jnp.clip(node, 0, nc - 1)
    deg = idx.node_starts[vc + 1] - idx.node_starts[vc]
    alive = (wid >= 0) & (deg > 0)
    cur_time = jnp.full((Ws,), 1, jnp.int32) * t_floor

    # walk-order trace contributions; every cell this shard writes is PAD
    # on all other shards, so psum(x - PAD) + PAD reassembles the result
    tn = jnp.full((W, L + 1), NODE_PAD, jnp.int32)
    tt = jnp.full((W, L + 1), NODE_PAD, jnp.int32)
    ln = jnp.zeros((W,), jnp.int32)
    row0 = jnp.where(alive, wid, W)
    tn = tn.at[row0, 0].set(node, mode="drop")
    tt = tt.at[row0, 0].set(cur_time, mode="drop")
    ln = ln.at[row0].add(1, mode="drop")

    def record_hop(wid, node, cur_time, alive, tn, tt, ln, step):
        # the streaming engine's hop draw: one walk-order [W] vector per
        # step, indexed by walk id — placement-independent bits
        u_full = jax.random.uniform(jax.random.fold_in(walk_key, step), (W,))
        u = u_full[jnp.clip(wid, 0, W - 1)]
        nn, nt, has = hop_resident(idx, scfg, node, cur_time, alive, u)
        row = jnp.where(has, wid, W)
        tn = tn.at[row, step + 1].set(nn, mode="drop")
        tt = tt.at[row, step + 1].set(nt, mode="drop")
        ln = ln.at[row].add(1, mode="drop")
        return nn, nt, has, tn, tt, ln

    def hop(carry, step):
        wid, node, cur_time, alive, tn, tt, ln, dropped = carry
        nn, nt, has, tn, tt, ln = record_hop(wid, node, cur_time, alive,
                                             tn, tt, ln, step)

        # migrate surviving walks to their new owner (dead walks just free
        # their slot: the trace already lives in the resident buffers)
        owner = jnp.clip(nn // range_size, 0, num_shards - 1)
        (r_wid, r_node, r_time), _, n_drop = exchange_by_owner(
            axis, num_shards, walk_bucket_capacity, owner, has,
            (wid, nn, nt), (-1, 0, 0))

        inc_valid = r_wid >= 0
        dest = jnp.where(inc_valid,
                         jnp.cumsum(inc_valid.astype(jnp.int32)) - 1, Ws)
        recv_drop = jnp.sum(inc_valid & (dest >= Ws))
        wid = jnp.full((Ws,), -1, jnp.int32).at[dest].set(r_wid, mode="drop")
        node = jnp.zeros((Ws,), jnp.int32).at[dest].set(r_node, mode="drop")
        cur_time = jnp.zeros((Ws,), jnp.int32).at[dest].set(r_time,
                                                            mode="drop")
        alive = jnp.zeros((Ws,), bool).at[dest].set(inc_valid, mode="drop")
        return (wid, node, cur_time, alive, tn, tt, ln,
                dropped + n_drop + recv_drop), None

    # L-1 migrating hops under the scan, then one record-only final hop:
    # the last hop's migration would place walks nobody ever advances, so
    # skipping it saves one all_to_all per batch without touching the
    # traces (and therefore the bit-identity guarantee)
    carry0 = (wid, node, cur_time, alive, tn, tt, ln,
              jnp.asarray(0, jnp.int32))
    (wid, node, cur_time, alive, tn, tt, ln, dropped), _ = jax.lax.scan(
        hop, carry0, jnp.arange(max(L - 1, 0), dtype=jnp.int32))
    if L >= 1:
        _, _, _, tn, tt, ln = record_hop(
            wid, node, cur_time, alive, tn, tt, ln,
            jnp.asarray(L - 1, jnp.int32))
    return tn, tt, ln, dropped + start_drop


# ---------------------------------------------------------------------------
# Standalone sharded ingest: advance the window by one batch (no walks)
# ---------------------------------------------------------------------------


@partial(jax.jit,
         static_argnames=("mesh", "axis_name", "node_capacity", "shard_cfg",
                          "bias_scale"),
         donate_argnums=(0,))
def ingest_sharded(state: ShardedWindowState, bsrc, bdst, bts, count, *,
                   mesh: Mesh, axis_name: str, node_capacity: int,
                   shard_cfg: ShardConfig, bias_scale: float = 1.0
                   ) -> ShardedWindowState:
    """Advance the sharded window by one batch (``bsrc/bdst/bts`` are
    [D, Bd], the batch axis pre-split per shard; ``count`` the global valid
    prefix length). The shard_map'd single-batch twin of the replay's
    ingest stage, donating the old state."""
    D = mesh.devices.size
    range_size = owner_range_size(node_capacity, D)

    def shard_fn(state, bsrc, bdst, bts, count):
        wstate = jax.tree.map(lambda a: a[0], state.window)
        Bd = bsrc.shape[-1]
        gpos = jax.lax.axis_index(axis_name) * Bd + jnp.arange(
            Bd, dtype=jnp.int32)
        new, x_drop = _shard_ingest(
            wstate, bsrc[0], bdst[0], bts[0], gpos < count, axis=axis_name,
            num_shards=D, range_size=range_size,
            exchange_capacity=shard_cfg.exchange_capacity,
            node_capacity=node_capacity, bias_scale=bias_scale)
        return ShardedWindowState(
            window=jax.tree.map(lambda a: a[None], new),
            exchange_drops=(state.exchange_drops[0] + x_drop)[None])

    sharded = P(axis_name)
    state_spec = ShardedWindowState(
        window=jax.tree.map(lambda _: sharded, state.window),
        exchange_drops=sharded)
    fn = shard_map(shard_fn, mesh=mesh,
                   in_specs=(state_spec, sharded, sharded, sharded, P()),
                   out_specs=state_spec, check_rep=False)
    return fn(state, bsrc, bdst, bts, count)


# ---------------------------------------------------------------------------
# Fused sharded replay: one shard_map'd lax.scan over all batches
# ---------------------------------------------------------------------------


def _check_supported(wcfg: WalkConfig, scfg: SamplerConfig) -> None:
    if wcfg.start_mode != "all_nodes":
        raise ValueError(
            "sharded streaming walks require start_mode='all_nodes' (start "
            "placement must be owner-computable without global state; got "
            f"{wcfg.start_mode!r})")
    if scfg.node2vec_p != 1.0 or scfg.node2vec_q != 1.0:
        raise ValueError(
            "sharded streaming walks do not support node2vec second-order "
            "bias (the β probe needs the previous node's adjacency, which "
            "lives on a different shard)")


@partial(jax.jit,
         static_argnames=("axis_name", "node_capacity", "wcfg", "scfg",
                          "shard_cfg", "bias_scale", "mesh"),
         donate_argnums=(0,))
def _replay_scan_sharded(state: ShardedWindowState, bsrc, bdst, bts, bcount,
                         key, *, mesh: Mesh, axis_name: str,
                         node_capacity: int, wcfg: WalkConfig,
                         scfg: SamplerConfig, shard_cfg: ShardConfig,
                         bias_scale: float = 1.0):
    """Replay K stacked batches over the sharded window, fully on device.

    ``bsrc/bdst/bts`` are [K, D, Bd] (the batch axis pre-split per shard),
    ``bcount`` [K]. Returns (new state, per-batch stat leaves, final-batch
    walk leaves); everything carries a leading [D] axis — psum'd leaves are
    replicated so callers read row 0.
    """
    D = mesh.devices.size
    range_size = owner_range_size(node_capacity, D)

    def shard_fn(state, bsrc, bdst, bts, bcount, key):
        wstate = jax.tree.map(lambda a: a[0], state.window)
        xdrops = state.exchange_drops[0]
        lsrc, ldst, lts = bsrc[:, 0], bdst[:, 0], bts[:, 0]   # [K, Bd]
        Bd = lsrc.shape[-1]
        shard_id = jax.lax.axis_index(axis_name)
        # local slice covers global batch positions [shard_id*Bd, ...+Bd)
        gpos = shard_id * Bd + jnp.arange(Bd, dtype=jnp.int32)

        def batch_step(carry, xs):
            wstate, xdrops, k = carry
            src, dst, ts, cnt = xs
            k, sub = jax.random.split(k)
            wstate, x_drop = _shard_ingest(
                wstate, src, dst, ts, gpos < cnt, axis=axis_name,
                num_shards=D, range_size=range_size,
                exchange_capacity=shard_cfg.exchange_capacity,
                node_capacity=node_capacity, bias_scale=bias_scale)

            # same key chain as the single-device replay_scan
            _, walk_key = jax.random.split(sub)
            tn, tt, ln, w_drop = _shard_walks(
                wstate.index, walk_key, wcfg, scfg, axis=axis_name,
                num_shards=D, range_size=range_size,
                walk_slots=shard_cfg.walk_slots,
                walk_bucket_capacity=shard_cfg.walk_bucket_capacity)

            lengths = jax.lax.psum(ln, axis_name)
            stats = ReplayStats(
                edges_active=jax.lax.psum(wstate.index.num_edges, axis_name),
                t_now=wstate.t_now,      # watermark-agreed: replicated
                ingested=jax.lax.psum(wstate.ingested, axis_name),
                late_drops=jax.lax.psum(wstate.late_drops, axis_name),
                overflow_drops=jax.lax.psum(wstate.overflow_drops,
                                            axis_name),
                mean_len=jnp.mean(lengths.astype(jnp.float32)),
            )
            return ((wstate, xdrops + x_drop, k),
                    (stats, x_drop, w_drop, tn, tt, ln))

        (wstate, xdrops, _), (stats, x_drops, w_drops, tns, tts, lns) = \
            jax.lax.scan(batch_step, (wstate, xdrops, key),
                         (lsrc, ldst, lts, bcount))

        # reassemble the final batch's walks (each cell written by ≤ 1
        # shard; contributions are PAD elsewhere)
        tn, tt, ln = tns[-1], tts[-1], lns[-1]
        nodes = NODE_PAD + jax.lax.psum(tn - NODE_PAD, axis_name)
        times = NODE_PAD + jax.lax.psum(tt - NODE_PAD, axis_name)
        lengths = jax.lax.psum(ln, axis_name)

        new_state = ShardedWindowState(
            window=jax.tree.map(lambda a: a[None], wstate),
            exchange_drops=xdrops[None])
        expand = lambda a: a[None]
        return (new_state, jax.tree.map(expand, stats), x_drops[None],
                w_drops[None], expand(nodes), expand(times), expand(lengths))

    sharded = P(axis_name)
    state_spec = ShardedWindowState(
        window=jax.tree.map(lambda _: sharded, state.window),
        exchange_drops=sharded)
    stats_spec = ReplayStats(*([sharded] * len(ReplayStats._fields)))
    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(state_spec, P(None, axis_name), P(None, axis_name),
                  P(None, axis_name), P(), P()),
        out_specs=(state_spec, stats_spec, sharded, sharded, sharded,
                   sharded, sharded),
        check_rep=False)
    return fn(state, bsrc, bdst, bts, bcount, key)


class DistributedStreamingEngine:
    """Streaming ingest → rebuild → walk over a node-partitioned window.

    The distributed counterpart of ``StreamingEngine.replay_device``: the
    window lives sharded across ``mesh`` (per-shard capacity
    ``cfg.shard.edge_capacity_per_shard``, so total window capacity scales
    with device count), batches ingest through one all_to_all per batch,
    and walks migrate between owners per hop. For
    ``SamplerConfig.mode="index"`` the replay is bit-identical to the
    single-device engine for identical keys (any shard count, provided no
    capacity drops — check ``DistReplayStats``); per-hop grouping does not
    apply (the migration layout is its own schedule), which changes nothing
    observable since every scheduler path emits identical walks.
    """

    def __init__(self, cfg: EngineConfig, batch_capacity: int, *,
                 mesh: Optional[Mesh] = None, num_shards: int = 0):
        self.cfg = cfg
        self.mesh = mesh if mesh is not None else window_mesh(
            num_shards or cfg.shard.num_shards)
        self.axis_name = self.mesh.axis_names[0]
        D = self.mesh.devices.size
        self.num_shards = D
        # per-shard batch slice: round the capacity up to a D multiple
        self.batch_slice = -(-batch_capacity // D)
        self.batch_capacity = self.batch_slice * D
        self.state = init_sharded_window(
            D, cfg.shard.edge_capacity_per_shard, cfg.window.node_capacity,
            int(cfg.window.duration), mesh=self.mesh,
            axis_name=self.axis_name)
        self.key = jax.random.PRNGKey(cfg.seed)

    def ingest_batch(self, src, dst, ts) -> None:
        """Advance the sharded window by one batch (no walks) — the
        distributed twin of ``StreamingEngine.ingest_batch``."""
        from repro.core.edge_store import make_batch
        batch = make_batch(src, dst, ts, capacity=self.batch_capacity)
        split = lambda a: a.reshape(self.num_shards, self.batch_slice)
        self.state = ingest_sharded(
            self.state, split(batch.src), split(batch.dst), split(batch.ts),
            batch.count, mesh=self.mesh, axis_name=self.axis_name,
            node_capacity=self.cfg.window.node_capacity,
            shard_cfg=self.cfg.shard)

    def replay_device(self, batches, wcfg: WalkConfig):
        """One shard_map'd ``lax.scan`` over all batches; a single host
        sync at the end. Returns (DistReplayStats, final-batch WalkResult,
        wall seconds)."""
        _check_supported(wcfg, self.cfg.sampler)
        stacked = stack_batches(batches, self.batch_capacity)
        K = stacked.src.shape[0]
        split = lambda a: a.reshape(K, self.num_shards, self.batch_slice)
        self.key, sub = jax.random.split(self.key)
        t0 = time.perf_counter()
        (self.state, stats, x_drops, w_drops, nodes, times, lengths) = \
            _replay_scan_sharded(
                self.state, split(stacked.src), split(stacked.dst),
                split(stacked.ts), stacked.count, sub, mesh=self.mesh,
                axis_name=self.axis_name,
                node_capacity=self.cfg.window.node_capacity, wcfg=wcfg,
                scfg=self.cfg.sampler, shard_cfg=self.cfg.shard)
        jax.block_until_ready(lengths)          # the single sync point
        elapsed = time.perf_counter() - t0
        replay = ReplayStats(*(np.asarray(a)[0] for a in stats))
        dstats = DistReplayStats(
            replay=replay,
            exchange_drops=np.asarray(x_drops).T,     # [D, K] -> [K, D]
            walk_drops=np.asarray(w_drops).T,
        )
        walks = WalkResult(nodes=np.asarray(nodes)[0],
                           times=np.asarray(times)[0],
                           lengths=np.asarray(lengths)[0], stats=None)
        return dstats, walks, elapsed
