"""Sharding rules: parameter/activation PartitionSpecs per architecture.

Scheme (DESIGN.md §6):
* TP over ``model``: attention heads, FFN hidden, MoE experts (EP), vocab;
* FSDP over ``data`` (+``pod`` when present): the d_model axis of every
  large matrix — ZeRO-3-style, XLA inserts the per-layer all-gathers;
* activations: batch over (pod, data); decode KV caches shard their
  *sequence* axis over ``model`` (flash-decoding-style split) because kv
  heads (2..10) rarely divide the model axis;
* anything small (norms, biases, routers) replicates.

Rules key on parameter-path substrings — param trees are nested dicts with
stable names, so the rules stay readable and auditable.
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (path-regex, spec-builder) — first match wins. Builders receive the
# param shape and the mesh axis names, returning a PartitionSpec.
_RULES = [
    # embeddings / unembeddings: vocab x d_model
    (r"(embed|unembed)/table$", lambda s, ax: P(ax.model, ax.fsdp)),
    # attention projections [d, H, hd] / [H, hd, d]
    (r"attn/wq$|attn/wk$|attn/wv$|cross/wq$|cross/wk$|cross/wv$",
     lambda s, ax: P(ax.fsdp, ax.model, None)),
    (r"attn/wo$|cross/wo$", lambda s, ax: P(ax.model, None, ax.fsdp)),
    (r"attn/bq$|attn/bk$|attn/bv$|cross/b[qkv]$",
     lambda s, ax: P(ax.model, None)),
    # MLA latents
    (r"attn/wq_a$|attn/wkv_a$", lambda s, ax: P(ax.fsdp, None)),
    (r"attn/wq_b$|attn/wk_b$|attn/wv_b$",
     lambda s, ax: P(None, ax.model, None)),
    # dense MLP [d, ff] / [ff, d]
    (r"(mlp|shared|dense)/w_gate$|(mlp|shared|dense)/w_up$",
     lambda s, ax: P(ax.fsdp, ax.model)),
    (r"(mlp|shared|dense)/w_down$", lambda s, ax: P(ax.model, ax.fsdp)),
    # MoE experts [E, d, f] / [E, f, d]  (EP over model)
    (r"moe/w_gate$|moe/w_up$", lambda s, ax: P(ax.model, ax.fsdp, None)),
    (r"moe/w_down$", lambda s, ax: P(ax.model, None, ax.fsdp)),
    (r"moe/router$", lambda s, ax: P(ax.fsdp, None)),
    # mamba
    (r"mamba/w_in$", lambda s, ax: P(ax.fsdp, ax.model)),
    (r"mamba/w_out$", lambda s, ax: P(ax.model, ax.fsdp)),
    (r"mamba/w_x$", lambda s, ax: P(ax.model, None)),
    (r"mamba/w_dt$", lambda s, ax: P(None, ax.model)),
    (r"mamba/(conv_w|conv_b|dt_bias|A_log|D)$",
     lambda s, ax: _last_axis_model(s, ax)),
    # xLSTM
    (r"(mlstm|slstm)/w_up$|slstm/w_gates$|slstm/w_ff1$",
     lambda s, ax: P(ax.fsdp, ax.model)),
    (r"(mlstm|slstm)/w_down$|slstm/w_ff2$", lambda s, ax: P(ax.model, ax.fsdp)),
    (r"mlstm/w(q|k|v)$", lambda s, ax: P(ax.model, None, None)),
    (r"mlstm/w_if$", lambda s, ax: P(ax.model, None)),
]


import os


class AxisNames:
    """Resolved mesh-axis names; fsdp composes pod+data when present.

    Sharding modes (env ``REPRO_SHARDING_MODE``, also a §Perf knob):
      hybrid (default) — batch over (pod, data); TP/EP over model.
      fsdp             — batch over ALL axes (pure data-parallel/ZeRO);
                         for archs whose head counts don't divide the
                         model axis this removes attention replication.
    """

    def __init__(self, mesh: Mesh):
        names = mesh.axis_names
        mode = os.environ.get("REPRO_SHARDING_MODE", "hybrid")
        self.model = "model" if "model" in names else None
        if "pod" in names and "data" in names:
            self.fsdp = ("pod", "data")
        elif "data" in names:
            self.fsdp = "data"
        else:
            self.fsdp = None
        if mode == "fsdp" and self.model is not None:
            parts = self.fsdp if isinstance(self.fsdp, tuple) \
                else ((self.fsdp,) if self.fsdp else ())
            self.batch = parts + (self.model,)
        else:
            self.batch = self.fsdp

    def sizes(self, mesh: Mesh):
        return dict(zip(mesh.axis_names, mesh.devices.shape))


def _last_axis_model(shape, ax):
    spec = [None] * (len(shape) - 1) + [ax.model]
    return P(*spec)


def _divisible(shape, spec: P, mesh: Mesh) -> P:
    """Drop sharding on axes the mesh doesn't divide (e.g. kv=10 over 16)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for dim, s in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if s is None:
            out.append(None)
            continue
        parts = s if isinstance(s, tuple) else (s,)
        total = int(np.prod([sizes[p] for p in parts]))
        out.append(s if dim % total == 0 else None)
    return P(*out)


def param_pspec(path: str, shape, mesh: Mesh) -> P:
    ax = AxisNames(mesh)
    for pattern, builder in _RULES:
        if re.search(pattern, path):
            return _divisible(shape, builder(shape, ax), mesh)
    return P()   # norms, small biases: replicated


def tree_pspecs(tree, mesh: Mesh):
    """Pytree of PartitionSpecs mirroring ``tree`` (params or opt state)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    specs = []
    for pathkeys, leaf in flat:
        path = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in pathkeys)
        if hasattr(leaf, "shape"):
            # strip optimizer-state prefixes (mu/nu/error shard like params)
            specs.append(param_pspec(path, leaf.shape, mesh))
        else:
            specs.append(P())
    return jax.tree_util.tree_unflatten(treedef, specs)


def tree_shardings(tree, mesh: Mesh):
    return jax.tree.map(lambda spec: NamedSharding(mesh, spec),
                        tree_pspecs(tree, mesh))


# ---------------------------------------------------------------------------
# Activation / batch / cache shardings
# ---------------------------------------------------------------------------


def current_mesh() -> Optional[Mesh]:
    """The ambient mesh installed by ``with mesh:`` (legacy context)."""
    try:
        from jax.interpreters import pxla
        m = pxla.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None


def hint(x, *logical):
    """with_sharding_constraint using logical axes, no-op without a mesh.

    Logical names: "batch" -> (pod, data); "model" -> model; None.
    Model code calls this so activation layouts are pinned where XLA's
    propagation would otherwise pick pathological ones (e.g. all-reducing
    full logits over the fsdp axis).
    """
    m = current_mesh()
    if m is None:
        return x
    ax = AxisNames(m)
    sizes = dict(zip(m.axis_names, m.devices.shape))
    spec = []
    used = set()
    for l, dim in zip(logical, x.shape):
        if l == "batch" and ax.batch is not None:
            parts = ax.batch if isinstance(ax.batch, tuple) else (ax.batch,)
            parts = tuple(p for p in parts if p not in used)
            total = int(np.prod([sizes[p] for p in parts])) if parts else 0
            if parts and dim % total == 0:
                spec.append(parts if len(parts) > 1 else parts[0])
                used.update(parts)
            else:
                spec.append(None)
        elif l == "model" and ax.model is not None and ax.model not in used:
            ok = dim % sizes[ax.model] == 0
            spec.append(ax.model if ok else None)
            if ok:
                used.add(ax.model)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(m, P(*spec)))


def batch_pspec(mesh: Mesh, batch_size: int) -> P:
    """tokens/labels [B, S]: B over (pod, data) when divisible, else S."""
    ax = AxisNames(mesh)
    if ax.batch is None:
        return P()
    parts = ax.batch if isinstance(ax.batch, tuple) else (ax.batch,)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    total = int(np.prod([sizes[p] for p in parts]))
    if batch_size % total == 0:
        return P(ax.batch, None)
    # sequence sharding (SP) fallback for tiny batches (long-context decode)
    return P(None, None)


def batch_shardings(cfg, mesh: Mesh, batch: dict):
    """Shardings for a train/prefill batch dict."""
    out = {}
    for k, v in batch.items():
        if k in ("tokens", "labels"):
            out[k] = NamedSharding(mesh, batch_pspec(mesh, v.shape[0]))
        else:  # frames/patches [B, S, d]
            bspec = batch_pspec(mesh, v.shape[0])
            out[k] = NamedSharding(
                mesh, P(bspec[0] if len(bspec) else None, None, None))
    return out


def cache_pspec(mesh: Mesh, shape, batch_size: int) -> P:
    """Decode caches: batch over (pod,data) when divisible; the sequence
    axis over model (split-KV decode). Works for [B,S,Hkv,D] (GQA),
    [B,S,R] (MLA latent), and recurrent states [B, ...]."""
    ax = AxisNames(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    parts = ax.batch if isinstance(ax.batch, tuple) else (ax.batch,)
    btotal = int(np.prod([sizes[p] for p in parts])) if ax.batch else 1
    b_ax = ax.batch if (ax.batch and batch_size % btotal == 0) else None
    spec = [None, b_ax]   # leading stack axis (scan periods), then batch
    m = sizes.get("model", 1)
    for dim in shape[2:]:
        if ("model" not in [x for x in spec if x] and dim >= m
                and dim % m == 0 and dim > 8):
            spec.append("model")
        else:
            spec.append(None)
    return P(*spec[:len(shape) + 0])


def state_shardings(mesh: Mesh, state, batch_size: int):
    def one(leaf):
        if not hasattr(leaf, "shape") or leaf.ndim < 2:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, cache_pspec(mesh, leaf.shape, batch_size))
    return jax.tree.map(one, state)
