"""Fault tolerance for long-running training AND streaming: checkpoint/
restart drivers, straggler detection, heartbeat bookkeeping.

Design for 1000+ nodes (DESIGN.md §6, §15): the entire training state is
(params, opt_state, data cursor, rng) — all checkpointable
(``TrainSupervisor``); the walk engine's state is (window edges + rng),
which ``WindowCheckpointer`` persists directly — the sharded window, its
placement manifest and the walk key — so a restart resumes the replay
mid-stream instead of re-ingesting from the cursor, and the **elastic**
restore retargets a different shard count or placement policy by
re-bucketing the saved window (``checkpoint.restore_sharded_window`` →
``reshard_host``). ``StreamSupervisor`` drives a
``DistributedStreamingEngine`` replay with the same checkpoint-every-N +
straggler-watchdog semantics ``TrainSupervisor`` gives training; its
``remesh`` verdict is the trigger for exactly that elastic path.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import numpy as np

from repro.obs.registry import MetricsRegistry, get_registry
from repro.train import checkpoint as ckpt


@dataclass
class StragglerPolicy:
    """Per-step wall-time watchdog.

    At pod scale a straggling host shows up as a slow collective; the
    runner cannot see *which* host, but it can see the step-time
    distribution. Policy: flag when a step exceeds ``threshold`` x the
    running median; after ``max_flags`` consecutive flags, recommend a
    checkpoint-and-remesh (the elastic path) instead of waiting.
    """

    threshold: float = 3.0
    window: int = 32
    max_flags: int = 3

    _times: List[float] = field(default_factory=list)
    _flags: int = 0

    def observe(self, step_s: float) -> str:
        """Returns 'ok' | 'straggler' | 'remesh'."""
        self._times.append(step_s)
        hist = self._times[-self.window:]
        if len(hist) < 5:
            return "ok"
        med = float(np.median(hist[:-1]))
        if step_s > self.threshold * med:
            self._flags += 1
            if self._flags >= self.max_flags:
                self._flags = 0
                return "remesh"
            return "straggler"
        self._flags = 0
        return "ok"


@dataclass
class TrainSupervisor:
    """Checkpoint-every-N supervisor with crash-resume semantics."""

    ckpt_dir: str
    save_every: int = 100
    straggler: StragglerPolicy = field(default_factory=StragglerPolicy)
    registry: Optional[MetricsRegistry] = None

    @property
    def _reg(self) -> MetricsRegistry:
        return self.registry if self.registry is not None else get_registry()

    def resume_step(self) -> int:
        s = ckpt.latest_step(os.path.join(self.ckpt_dir, "params"))
        return int(s) if s is not None else 0

    def restore(self, params_like, opt_like, shardings=None):
        p = ckpt.restore(os.path.join(self.ckpt_dir, "params"), params_like,
                         shardings)
        o = ckpt.restore(os.path.join(self.ckpt_dir, "opt"), opt_like,
                         shardings=None)
        return p, o

    def run(self, step_fn: Callable, params, opt_state, batches,
            start_step: int = 0, max_steps: int = 10**9,
            on_event: Optional[Callable] = None):
        """Drives training; checkpoints; reports straggler events.

        ``step_fn(params, opt_state, batch) -> (params, opt_state, metrics)``
        """
        step = start_step
        for batch in batches:
            if step >= max_steps:
                break
            t0 = time.perf_counter()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            verdict = self.straggler.observe(time.perf_counter() - t0)
            if verdict != "ok":
                self._reg.inc("straggler_events_total", 1,
                              labels={"verdict": verdict},
                              help="straggler watchdog flags, by verdict")
                if on_event:
                    on_event(step, verdict)
            step += 1
            if step % self.save_every == 0:
                self.save(params, opt_state, step)
        return params, opt_state, step

    def save(self, params, opt_state, step: int):
        ckpt.save(os.path.join(self.ckpt_dir, "params"), params, step)
        ckpt.save(os.path.join(self.ckpt_dir, "opt"), opt_state, step)
        self._reg.inc("checkpoints_total", 1, labels={"kind": "train"},
                      help="checkpoints written, by kind")


@dataclass
class WindowCheckpointer:
    """Save/restore a ``DistributedStreamingEngine``'s full replay state.

    The streaming counterpart of params checkpoints: (sharded window,
    placement, walk key) under ``<ckpt_dir>/window``. ``restore_engine``
    is the elastic restart — pass ``num_shards`` or ``placement`` to come
    back up on a different topology; the saved window re-buckets through
    the host reshard mirror and the walk key resumes the exact RNG chain,
    so a restored replay of the remaining batches is bit-identical to the
    uninterrupted run (tested in tests/test_reshard_checkpoint.py).
    """

    ckpt_dir: str

    @property
    def window_dir(self) -> str:
        return os.path.join(self.ckpt_dir, "window")

    def save(self, engine, step: int) -> None:
        ckpt.save_sharded_window(self.window_dir, engine.state,
                                 engine.placement, step,
                                 walk_key=engine.key)

    def latest_step(self) -> Optional[int]:
        return ckpt.latest_step(self.window_dir)

    def restore_engine(self, cfg, batch_capacity: int, *,
                       num_shards: Optional[int] = None,
                       placement=None, mesh=None):
        """Rebuild a ``DistributedStreamingEngine`` from the checkpoint."""
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.distributed.streaming_shard import (
            DistributedStreamingEngine,
        )

        state, plc, walk_key = ckpt.restore_sharded_window(
            self.window_dir, placement=placement, num_shards=num_shards)
        eng = DistributedStreamingEngine(
            cfg, batch_capacity, mesh=mesh, num_shards=plc.num_shards,
            placement=plc)
        eng.state = jax.device_put(
            state, NamedSharding(eng.mesh, P(eng.axis_name)))
        if walk_key is not None:
            eng.key = walk_key
        return eng


@dataclass
class StreamSupervisor:
    """Checkpoint-every-N driver for a distributed streaming replay.

    Feeds batches through ``engine.replay_device`` one at a time (so the
    walk-key chain advances exactly as a per-batch caller's would),
    watches the per-batch wall time with the same ``StragglerPolicy`` as
    training, and checkpoints the full (window, placement, key) state
    every ``save_every`` batches. ``on_event(batch_idx, verdict)`` fires
    on 'straggler'/'remesh' verdicts; a 'remesh' caller typically
    restores the latest checkpoint at a new shard count via
    ``WindowCheckpointer.restore_engine``.

    **Health telemetry** (DESIGN.md §16): with ``health_every > 0`` the
    supervisor writes a validated ``tempest-health/v1`` snapshot
    (``obs.dump_health`` — ingest progress, window occupancy, per-shard
    load/drift, drop taxonomy) to ``health_dir`` (default
    ``<ckpt_dir>/health``) every ``health_every`` batches and once at the
    end of the run — the periodic streaming-health dump a dashboard or
    the rebalance policy tails.
    """

    ckpt_dir: str
    save_every: int = 8
    straggler: StragglerPolicy = field(default_factory=StragglerPolicy)
    registry: Optional[MetricsRegistry] = None
    health_every: int = 0
    health_dir: Optional[str] = None

    def __post_init__(self):
        self.checkpointer = WindowCheckpointer(self.ckpt_dir)
        if self.registry is None:
            self.registry = get_registry()
        if self.health_dir is None:
            self.health_dir = os.path.join(self.ckpt_dir, "health")

    def resume_batch(self) -> int:
        s = self.checkpointer.latest_step()
        return int(s) if s is not None else 0

    def dump_health(self, engine, step: int) -> str:
        """Write one health snapshot for ``step``; returns its path."""
        from repro.obs.export import dump_health
        os.makedirs(self.health_dir, exist_ok=True)
        path = os.path.join(self.health_dir, f"health_{step:06d}.json")
        dump_health(path, self.registry, engine=engine)
        return path

    def run(self, engine, batches, wcfg, start_batch: int = 0,
            on_event: Optional[Callable] = None):
        """Replay ``batches[start_batch:]``; returns (stats list, batches
        completed). Each entry is the batch's ``DistReplayStats``."""
        out = []
        step = start_batch
        for batch in batches[start_batch:]:
            t0 = time.perf_counter()
            stats, _walks, _ = engine.replay_device([batch], wcfg)
            verdict = self.straggler.observe(time.perf_counter() - t0)
            if verdict != "ok":
                self.registry.inc("straggler_events_total", 1,
                                  labels={"verdict": verdict},
                                  help="straggler watchdog flags, by "
                                       "verdict")
                if on_event:
                    on_event(step, verdict)
            out.append(stats)
            step += 1
            if step % self.save_every == 0:
                self.checkpointer.save(engine, step)
                self.registry.inc("checkpoints_total", 1,
                                  labels={"kind": "window"},
                                  help="checkpoints written, by kind")
            if self.health_every and step % self.health_every == 0:
                self.dump_health(engine, step)
        if self.health_every and out:
            self.dump_health(engine, step)
        return out, step
