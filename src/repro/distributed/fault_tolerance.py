"""Fault tolerance for long-running training: checkpoint/restart driver,
straggler detection, heartbeat bookkeeping.

Design for 1000+ nodes (DESIGN.md §6): the entire training state is
(params, opt_state, data cursor, rng) — all checkpointable; the walk
engine's state is (window edges + rng), rebuilt from the stream cursor.
Restart is therefore a pure function of the last checkpoint, and the
elastic restore path (train/checkpoint.py) retargets a different mesh.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import numpy as np

from repro.train import checkpoint as ckpt


@dataclass
class StragglerPolicy:
    """Per-step wall-time watchdog.

    At pod scale a straggling host shows up as a slow collective; the
    runner cannot see *which* host, but it can see the step-time
    distribution. Policy: flag when a step exceeds ``threshold`` x the
    running median; after ``max_flags`` consecutive flags, recommend a
    checkpoint-and-remesh (the elastic path) instead of waiting.
    """

    threshold: float = 3.0
    window: int = 32
    max_flags: int = 3

    _times: List[float] = field(default_factory=list)
    _flags: int = 0

    def observe(self, step_s: float) -> str:
        """Returns 'ok' | 'straggler' | 'remesh'."""
        self._times.append(step_s)
        hist = self._times[-self.window:]
        if len(hist) < 5:
            return "ok"
        med = float(np.median(hist[:-1]))
        if step_s > self.threshold * med:
            self._flags += 1
            if self._flags >= self.max_flags:
                self._flags = 0
                return "remesh"
            return "straggler"
        self._flags = 0
        return "ok"


@dataclass
class TrainSupervisor:
    """Checkpoint-every-N supervisor with crash-resume semantics."""

    ckpt_dir: str
    save_every: int = 100
    straggler: StragglerPolicy = field(default_factory=StragglerPolicy)

    def resume_step(self) -> int:
        s = ckpt.latest_step(os.path.join(self.ckpt_dir, "params"))
        return int(s) if s is not None else 0

    def restore(self, params_like, opt_like, shardings=None):
        p = ckpt.restore(os.path.join(self.ckpt_dir, "params"), params_like,
                         shardings)
        o = ckpt.restore(os.path.join(self.ckpt_dir, "opt"), opt_like,
                         shardings=None)
        return p, o

    def run(self, step_fn: Callable, params, opt_state, batches,
            start_step: int = 0, max_steps: int = 10**9,
            on_event: Optional[Callable] = None):
        """Drives training; checkpoints; reports straggler events.

        ``step_fn(params, opt_state, batch) -> (params, opt_state, metrics)``
        """
        step = start_step
        for batch in batches:
            if step >= max_steps:
                break
            t0 = time.perf_counter()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            verdict = self.straggler.observe(time.perf_counter() - t0)
            if verdict != "ok" and on_event:
                on_event(step, verdict)
            step += 1
            if step % self.save_every == 0:
                self.save(params, opt_state, step)
        return params, opt_state, step

    def save(self, params, opt_state, step: int):
        ckpt.save(os.path.join(self.ckpt_dir, "params"), params, step)
        ckpt.save(os.path.join(self.ckpt_dir, "opt"), opt_state, step)
