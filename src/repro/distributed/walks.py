"""Walk-axis sharding: device-parallel walk generation over a replicated
index (DESIGN.md §10).

Complements ``core/distributed.py``: that module range-partitions the *edge
store* across devices and migrates walks between owners every hop — the
mechanism for windows that exceed one chip's HBM. This module is the other
regime: the window fits on-chip, throughput is the constraint, so the
dual index is **replicated** and the *walk axis* is sharded with
``shard_map`` — walks are embarrassingly parallel, so a hop involves zero
cross-device communication and scaling is linear in devices.

RNG: shard ``s`` folds ``s`` into the key and generates its walks exactly
like a single-device ``generate_walks`` over ``W/D`` walks. Results are
deterministic for a fixed (key, device count); a D-device run is not
bit-identical to a 1-device run (``core/distributed.py`` pays a per-walk
``fold_in`` every hop for that stronger property). ``all_nodes`` starts
keep their global assignment via ``walk_offset``: shard s's walk w starts
where global walk ``s·W/D + w`` would.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import SamplerConfig, SchedulerConfig, WalkConfig
from repro.core.walk_engine import WalkResult, _generate_walks_impl

WALK_AXIS = "walks"


def walk_mesh(devices=None, axis_name: str = WALK_AXIS) -> Mesh:
    """1-D mesh over all (or the given) devices for walk-axis sharding."""
    devs = np.asarray(devices if devices is not None else jax.devices())
    return Mesh(devs, (axis_name,))


@functools.lru_cache(maxsize=None)
def _sharded_walk_fn(mesh: Mesh, axis_name: str, wcfg: WalkConfig,
                     scfg: SamplerConfig, sched_cfg: SchedulerConfig):
    D = mesh.shape[axis_name]
    if wcfg.num_walks % D:
        raise ValueError(f"num_walks {wcfg.num_walks} not divisible by "
                         f"{D} devices on axis {axis_name!r}")
    wd = dataclasses.replace(wcfg, num_walks=wcfg.num_walks // D)

    def shard_fn(index, key):
        s = jax.lax.axis_index(axis_name)
        res = _generate_walks_impl(
            index, jax.random.fold_in(key, s), wd, scfg, sched_cfg,
            walk_offset=s * wd.num_walks)
        return res.nodes, res.times, res.lengths

    fn = shard_map(shard_fn, mesh=mesh,
                   in_specs=(P(), P()),              # index + key replicated
                   out_specs=(P(axis_name), P(axis_name), P(axis_name)),
                   check_rep=False)
    return jax.jit(fn)


def generate_walks_sharded(index, key: jax.Array, wcfg: WalkConfig,
                           scfg: SamplerConfig, sched_cfg: SchedulerConfig,
                           *, mesh: Optional[Mesh] = None,
                           axis_name: str = WALK_AXIS) -> WalkResult:
    """Generate ``wcfg.num_walks`` walks sharded over the mesh's devices.

    Drop-in for ``generate_walks`` (stats collection excepted): each device
    runs the full scheduler path (fullwalk/grouped/tiled, bucket or lexsort
    regroup) on its ``W/D`` walk slice against the replicated index; the
    result arrays come back sharded along the walk axis. Defaults to a
    fresh 1-D mesh over every visible device.
    """
    if mesh is None:
        mesh = walk_mesh(axis_name=axis_name)
    fn = _sharded_walk_fn(mesh, axis_name, wcfg, scfg, sched_cfg)
    nodes, times, lengths = fn(index, key)
    return WalkResult(nodes=nodes, times=times, lengths=lengths, stats=None)
