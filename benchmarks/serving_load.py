"""Serving-load benchmark: open-loop Poisson arrivals, mixed-bias traffic.

No direct paper counterpart — this measures the serving subsystem
(DESIGN.md §11) the ROADMAP's "heavy traffic" north star needs: many
tenants submitting small heterogeneous ``WalkQuery``s, coalesced into
fixed-shape batches.

**Open-loop** means arrivals follow a Poisson process at the offered rate
regardless of completions (a closed loop would throttle arrivals to the
service's pace and hide queueing delay — the coordinated-omission trap).
Per offered load this reports p50/p99 submit→complete latency, walks/s,
drop counts (backpressure + oversize), and lane occupancy (coalescing
efficiency: live lanes over dispatched lanes).

CPU wall-clock caveats of DESIGN.md §9 apply; the relative shape —
latency flat until the knee, then queueing blow-up and backpressure
drops — is the claim, not the absolute numbers.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.configs.base import (
    EngineConfig,
    SamplerConfig,
    SchedulerConfig,
    ServeConfig,
    WindowConfig,
)
from repro.data.synthetic import chronological_batches, powerlaw_temporal_graph
from repro.serve import ServeStats, WalkQuery, WalkService

BIASES = ("uniform", "linear", "exponential")


def _mixed_workload(rng: np.random.Generator, n: int, nc: int):
    """Heterogeneous tenants: all three biases, both start modes, varied
    fan-out and length — nothing here shares a compile-time config."""
    out = []
    for i in range(n):
        bias = BIASES[int(rng.integers(3))]
        max_length = int(rng.integers(2, 17))
        lanes = int(rng.integers(1, 9))
        seed = int(rng.integers(1 << 20))
        if rng.random() < 0.3:
            out.append(WalkQuery(num_walks=lanes, start_mode="edges",
                                 bias=bias,
                                 start_bias=BIASES[int(rng.integers(3))],
                                 max_length=max_length, seed=seed))
        else:
            starts = tuple(int(s) for s in rng.integers(0, nc, lanes))
            out.append(WalkQuery(start_nodes=starts, bias=bias,
                                 max_length=max_length, seed=seed))
    return out


def _drive_open_loop(svc: WalkService, queries, arrivals_s):
    """Submit each query at its Poisson arrival time; serve in between."""
    n = len(queries)
    i = 0
    t0 = time.perf_counter()
    while i < n or svc.pending_count:
        now = time.perf_counter() - t0
        while i < n and arrivals_s[i] <= now:
            svc.submit(queries[i])
            i += 1
        if svc.pending_count:
            svc.step()
        elif i < n:
            time.sleep(min(max(arrivals_s[i] - now, 0.0), 5e-4))
    return time.perf_counter() - t0


def run(offered_loads_qps=(100, 400, 1600), n_queries=150,
        num_nodes=1024, num_edges=60_000, seed=17):
    g = powerlaw_temporal_graph(num_nodes, num_edges, seed=seed)
    cfg = EngineConfig(
        window=WindowConfig(duration=6000, edge_capacity=1 << 16,
                            node_capacity=num_nodes),
        sampler=SamplerConfig(mode="index"),
        scheduler=SchedulerConfig(path="grouped"))
    serve_cfg = ServeConfig(queue_capacity=64,
                            lane_buckets=(64, 256, 1024),
                            length_buckets=(4, 8, 16))
    svc = WalkService(cfg, serve_cfg,
                      batch_capacity=num_edges // 4 + 64)
    for bs, bd, bt in chronological_batches(g, 4):
        svc.ingest(bs, bd, bt)

    rng = np.random.default_rng(seed)
    # warm the jit cache across the FULL bucket grid (lane bucket × length
    # bucket × start mode), one batch per shape, so the measured loads see
    # steady-state dispatch, not compilation
    for lanes in serve_cfg.lane_buckets:
        for length in serve_cfg.length_buckets:
            starts = tuple(int(s) for s in rng.integers(0, num_nodes, lanes))
            svc.submit(WalkQuery(start_nodes=starts, max_length=length,
                                 seed=1))
            svc.step()
            svc.submit(WalkQuery(num_walks=lanes, start_mode="edges",
                                 max_length=length, seed=2))
            svc.step()
    svc.drain()

    for qps in offered_loads_qps:
        svc.stats = ServeStats()      # fresh counters per offered load
        queries = _mixed_workload(rng, n_queries, num_nodes)
        arrivals = np.cumsum(rng.exponential(1.0 / qps, n_queries))
        wall = _drive_open_loop(svc, queries, arrivals)
        svc.drain()
        s = svc.stats
        emit(f"serving/load_{qps}qps",
             1e6 * (np.mean(s.latencies_s) if s.latencies_s else float("nan")),
             f"p50_ms={s.p50_ms:.2f};p99_ms={s.p99_ms:.2f};"
             f"walks_per_s={s.walks_per_s:.0f};steps_per_s={s.steps_per_s:.0f};"
             f"served={s.completed};dropped={s.dropped};"
             f"batches={s.batches};occupancy={s.lane_occupancy:.2f};"
             f"wall_s={wall:.2f}")


if __name__ == "__main__":
    run()
