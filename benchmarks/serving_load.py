"""Serving-load benchmark: open-loop Poisson arrivals, mixed-bias traffic.

No direct paper counterpart — this measures the serving subsystem
(DESIGN.md §11) the ROADMAP's "heavy traffic" north star needs: many
tenants submitting small heterogeneous ``WalkQuery``s, coalesced into
fixed-shape batches.

**Open-loop** means arrivals follow a Poisson process at the offered rate
regardless of completions (a closed loop would throttle arrivals to the
service's pace and hide queueing delay — the coordinated-omission trap).
Per offered load this reports p50/p99 submit→complete latency, walks/s,
drop counts (backpressure + oversize), and lane occupancy (coalescing
efficiency: live lanes over dispatched lanes).

A second sweep (``run_sharded`` / ``--shards``) drives the same mixed
workload through the node-partitioned service (DESIGN.md §13) at every
shard count the host exposes — drain throughput, latency, and overflow
drops per shard count. On a CPU-only host, fake devices first:
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

CPU wall-clock caveats of DESIGN.md §9 apply; the relative shape —
latency flat until the knee, then queueing blow-up and backpressure
drops — is the claim, not the absolute numbers.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.configs.base import (
    EngineConfig,
    SamplerConfig,
    SchedulerConfig,
    ServeConfig,
    ShardConfig,
    WindowConfig,
)
from repro.data.synthetic import chronological_batches, powerlaw_temporal_graph
from repro.serve import ServeStats, WalkQuery, WalkService

BIASES = ("uniform", "linear", "exponential")


def _mixed_workload(rng: np.random.Generator, n: int, nc: int):
    """Heterogeneous tenants: all three biases, both start modes, varied
    fan-out and length — nothing here shares a compile-time config."""
    out = []
    for i in range(n):
        bias = BIASES[int(rng.integers(3))]
        max_length = int(rng.integers(2, 17))
        lanes = int(rng.integers(1, 9))
        seed = int(rng.integers(1 << 20))
        if rng.random() < 0.3:
            out.append(WalkQuery(num_walks=lanes, start_mode="edges",
                                 bias=bias,
                                 start_bias=BIASES[int(rng.integers(3))],
                                 max_length=max_length, seed=seed))
        else:
            starts = tuple(int(s) for s in rng.integers(0, nc, lanes))
            out.append(WalkQuery(start_nodes=starts, bias=bias,
                                 max_length=max_length, seed=seed))
    return out


def _drive_open_loop(svc: WalkService, queries, arrivals_s):
    """Submit each query at its Poisson arrival time; serve in between."""
    n = len(queries)
    i = 0
    t0 = time.perf_counter()
    while i < n or svc.pending_count:
        now = time.perf_counter() - t0
        while i < n and arrivals_s[i] <= now:
            svc.submit(queries[i])
            i += 1
        if svc.pending_count:
            svc.step()
        elif i < n:
            time.sleep(min(max(arrivals_s[i] - now, 0.0), 5e-4))
    return time.perf_counter() - t0


def run_sharded(shard_counts=None, n_queries=120, num_nodes=1024,
                num_edges=40_000, seed=29):
    """Drain throughput of the sharded service vs shard count.

    Closed-loop on purpose (submit everything, then drain): this sweep
    measures the sharded dispatch path itself — owner-claimed starts,
    per-hop migration, psum reassembly — not queueing, which the
    open-loop sweep above already characterizes.
    """
    import jax
    devs = len(jax.devices())
    counts = shard_counts or [d for d in (1, 2, 4, 8) if d <= devs]
    g = powerlaw_temporal_graph(num_nodes, num_edges, seed=seed)
    cfg = EngineConfig(
        window=WindowConfig(duration=6000, edge_capacity=1 << 16,
                            node_capacity=num_nodes),
        sampler=SamplerConfig(mode="index"),
        scheduler=SchedulerConfig(path="grouped"),
        # exchange provisioning mirrors fig7 (DESIGN.md §12): at D=1 one
        # sender may route its whole batch slice to one owner
        shard=ShardConfig(edge_capacity_per_shard=1 << 16,
                          exchange_capacity=1 << 14,
                          walk_slots=1 << 11,
                          walk_bucket_capacity=1 << 10))
    serve_cfg = ServeConfig(queue_capacity=n_queries + 8,
                            lane_buckets=(64, 256),
                            length_buckets=(4, 8, 16))
    rng = np.random.default_rng(seed)
    queries = _mixed_workload(rng, n_queries, num_nodes)

    for D in counts:
        svc = WalkService(cfg, serve_cfg, batch_capacity=num_edges // 4 + 64,
                          num_shards=D)
        for bs, bd, bt in chronological_batches(g, 4):
            svc.ingest(bs, bd, bt)
        for q in queries:                    # warm the jit cache per shape
            svc.submit(q)
        svc.drain()
        svc.stats = ServeStats()
        for q in queries:
            svc.submit(q)
        t0 = time.perf_counter()
        while svc.pending_count:
            svc.step()
        wall = time.perf_counter() - t0
        s = svc.stats
        emit(f"serving/shards={D}", 1e6 * wall / max(s.batches, 1),
             f"walks_per_s={s.walks / wall:.0f};served={s.completed};"
             f"batches={s.batches};occupancy={s.lane_occupancy:.2f};"
             f"shard_walk_drops={s.shard_walk_drops};wall_s={wall:.2f}")


def run(offered_loads_qps=(100, 400, 1600), n_queries=150,
        num_nodes=1024, num_edges=60_000, seed=17):
    g = powerlaw_temporal_graph(num_nodes, num_edges, seed=seed)
    cfg = EngineConfig(
        window=WindowConfig(duration=6000, edge_capacity=1 << 16,
                            node_capacity=num_nodes),
        sampler=SamplerConfig(mode="index"),
        scheduler=SchedulerConfig(path="grouped"))
    serve_cfg = ServeConfig(queue_capacity=64,
                            lane_buckets=(64, 256, 1024),
                            length_buckets=(4, 8, 16))
    svc = WalkService(cfg, serve_cfg,
                      batch_capacity=num_edges // 4 + 64)
    for bs, bd, bt in chronological_batches(g, 4):
        svc.ingest(bs, bd, bt)

    rng = np.random.default_rng(seed)
    # warm the jit cache across the FULL bucket grid (lane bucket × length
    # bucket × start mode), one batch per shape, so the measured loads see
    # steady-state dispatch, not compilation
    for lanes in serve_cfg.lane_buckets:
        for length in serve_cfg.length_buckets:
            starts = tuple(int(s) for s in rng.integers(0, num_nodes, lanes))
            svc.submit(WalkQuery(start_nodes=starts, max_length=length,
                                 seed=1))
            svc.step()
            svc.submit(WalkQuery(num_walks=lanes, start_mode="edges",
                                 max_length=length, seed=2))
            svc.step()
    svc.drain()

    for qps in offered_loads_qps:
        svc.stats = ServeStats()      # fresh counters per offered load
        queries = _mixed_workload(rng, n_queries, num_nodes)
        arrivals = np.cumsum(rng.exponential(1.0 / qps, n_queries))
        wall = _drive_open_loop(svc, queries, arrivals)
        svc.drain()
        s = svc.stats
        emit(f"serving/load_{qps}qps",
             1e6 * (np.mean(s.latencies_s) if s.latencies_s else float("nan")),
             f"p50_ms={s.p50_ms:.2f};p99_ms={s.p99_ms:.2f};"
             f"walks_per_s={s.walks_per_s:.0f};steps_per_s={s.steps_per_s:.0f};"
             f"served={s.completed};dropped={s.dropped};"
             f"batches={s.batches};occupancy={s.lane_occupancy:.2f};"
             f"wall_s={wall:.2f}")

    run_sharded()


if __name__ == "__main__":
    import sys
    if "--shards" in sys.argv[1:]:
        # e.g. XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        #        python -m benchmarks.serving_load --shards [1,2,8]
        i = sys.argv.index("--shards")
        arg = sys.argv[i + 1] if len(sys.argv) > i + 1 else ""
        counts = ([int(x) for x in arg.strip("[]").split(",") if x]
                  if arg and not arg.startswith("-") else None)
        run_sharded(shard_counts=counts)
    else:
        run()
