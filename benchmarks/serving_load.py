"""Serving SLO harness: open- and closed-loop load curves, blocking vs
overlapped runtime, goodput under deadlines (DESIGN.md §11/§18).

No direct paper counterpart — this measures the serving subsystem the
ROADMAP's "heavy traffic" north star needs: many tenants submitting
small heterogeneous ``WalkQuery``s, coalesced into fixed-shape batches.

Three sweeps, all emitted as CSV rows and (with ``--emit-json``) folded
into a schema-validated ``BENCH_serving.json``:

* **Open-loop** load curve — arrivals follow a Poisson process at the
  offered rate regardless of completions (a closed loop would throttle
  arrivals to the service's pace and hide queueing delay — the
  coordinated-omission trap). Every query carries a ``deadline_s``; the
  curve reports p50/p99 submit→complete latency AND **goodput** (queries
  completed within deadline, per second) per offered load, for both
  runtimes: the historical blocking baseline (``step()``,
  ``max_inflight=1``, synchronous ingest) and the overlapped async
  runtime (``tick()``/``pump()``, in-flight ring, continuous-batching
  linger, ingest building while walk batches dispatch). Mid-run window
  advances are part of the load: both modes ingest the same edge batches
  at the same offered times.
* **Closed-loop** drain — submit everything, then drain: pure service
  throughput without queueing, blocking vs overlapped.
* **Sharded** drain (``run_sharded`` / ``--shards``) — the same mixed
  workload through the node-partitioned service (DESIGN.md §13) at every
  shard count the host exposes. On a CPU-only host, fake devices first:
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

CPU wall-clock caveats of DESIGN.md §9 apply; the relative shape —
latency flat until the knee, then queueing blow-up, deadline evictions,
and the overlapped runtime sustaining goodput past the blocking knee —
is the claim, not the absolute numbers.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from benchmarks.common import emit, write_json
from repro.configs.base import (
    EngineConfig,
    SamplerConfig,
    SchedulerConfig,
    ServeConfig,
    ShardConfig,
    WindowConfig,
)
from repro.data.synthetic import chronological_batches, powerlaw_temporal_graph
from repro.serve import ServeStats, WalkQuery, WalkService

BIASES = ("uniform", "linear", "exponential")


def _mixed_workload(rng: np.random.Generator, n: int, nc: int,
                    deadline_s=None):
    """Heterogeneous tenants: all three biases, both start modes, varied
    fan-out and length — nothing here shares a compile-time config."""
    out = []
    for i in range(n):
        bias = BIASES[int(rng.integers(3))]
        max_length = int(rng.integers(2, 17))
        lanes = int(rng.integers(1, 9))
        seed = int(rng.integers(1 << 20))
        if rng.random() < 0.3:
            out.append(WalkQuery(num_walks=lanes, start_mode="edges",
                                 bias=bias,
                                 start_bias=BIASES[int(rng.integers(3))],
                                 max_length=max_length, seed=seed,
                                 deadline_s=deadline_s))
        else:
            starts = tuple(int(s) for s in rng.integers(0, nc, lanes))
            out.append(WalkQuery(start_nodes=starts, bias=bias,
                                 max_length=max_length, seed=seed,
                                 deadline_s=deadline_s))
    return out


def _drive_open_loop(svc: WalkService, queries, arrivals_s, overlapped,
                     ingests=(), publish_lag=3):
    """Submit each query at its Poisson arrival time; serve in between.

    ``overlapped=False`` is the blocking baseline: ``step()`` per batch,
    window advances synchronously (begin + publish back-to-back).
    ``overlapped=True`` drives the async runtime: ``tick()`` keeps the
    in-flight ring full while an ingest builds in the back buffer, and
    ``publish()`` lands ``publish_lag`` loop turns later — walk batches
    launched in between overlap with the device-side ingest.

    ``ingests`` is a list of ``(offered_time_s, (src, dst, ts))`` window
    advances; both modes get the same schedule. Returns (wall_s,
    tickets).
    """
    n = len(queries)
    tickets = []
    i = j = 0
    publish_in = None              # loop turns until the pending publish
    t0 = time.perf_counter()
    while i < n or svc.pending_count or svc.inflight_count:
        now = time.perf_counter() - t0
        while i < n and arrivals_s[i] <= now:
            tickets.append(svc.submit(queries[i]))
            i += 1
        if (j < len(ingests) and ingests[j][0] <= now
                and not svc.snapshots.ingest_in_flight):
            svc.begin_ingest(*ingests[j][1])
            j += 1
            if overlapped:
                publish_in = publish_lag
            else:
                svc.publish()
        if overlapped:
            before = svc.inflight_count
            harvested = svc.tick()
            if publish_in is not None:
                publish_in -= 1
                if publish_in <= 0:
                    svc.publish()
                    publish_in = None
            if not harvested and svc.inflight_count == before:
                # nothing moved: yield the core instead of hot-spinning
                # tick() — on a CPU host the XLA compute threads need it
                time.sleep(2e-4)
        elif svc.pending_count:
            svc.step()
        if not svc.pending_count and not svc.inflight_count and i < n:
            time.sleep(min(max(arrivals_s[i] - now, 0.0), 5e-4))
    if svc.snapshots.ingest_in_flight:
        svc.publish()
    svc.pump(block=True)
    return time.perf_counter() - t0, tickets


def _goodput(svc, queries, tickets, wall_s):
    """Fraction-of-deadline accounting: completed-in-time per second."""
    good = 0
    for t, q in zip(tickets, queries):
        if t is None:
            continue
        r = svc.poll(t)
        if r is None:                  # evicted past deadline: not good
            continue
        if q.deadline_s is None or r.latency_s <= q.deadline_s:
            good += 1
    return good / wall_s if wall_s > 0 else 0.0


def _base_cfg(num_nodes):
    return EngineConfig(
        window=WindowConfig(duration=6000, edge_capacity=1 << 16,
                            node_capacity=num_nodes),
        sampler=SamplerConfig(mode="index"),
        scheduler=SchedulerConfig(path="grouped"))


def _serve_cfg(overlapped, queue_capacity=64):
    # the overlapped runtime: 4-deep in-flight ring + a short linger so
    # late same-group arrivals ride partially-filled batches; the blocking
    # baseline is the exact historical configuration
    if overlapped:
        return ServeConfig(queue_capacity=queue_capacity,
                           lane_buckets=(64, 256, 1024),
                           length_buckets=(4, 8, 16),
                           max_inflight=4, linger_s=0.002)
    return ServeConfig(queue_capacity=queue_capacity,
                       lane_buckets=(64, 256, 1024),
                       length_buckets=(4, 8, 16), max_inflight=1)


def _fresh_service(cfg, serve_cfg, base_batches, batch_capacity):
    svc = WalkService(cfg, serve_cfg, batch_capacity=batch_capacity)
    for bs, bd, bt in base_batches:
        svc.ingest(bs, bd, bt)
    return svc


def _warm_buckets(svc, serve_cfg, rng, num_nodes):
    """Compile the FULL bucket grid (lane bucket × length bucket × start
    mode) once, so measured loads see steady-state dispatch. The jit
    cache is process-global: later services with the same shapes reuse
    these programs."""
    for lanes in serve_cfg.lane_buckets:
        for length in serve_cfg.length_buckets:
            starts = tuple(int(s) for s in rng.integers(0, num_nodes, lanes))
            svc.submit(WalkQuery(start_nodes=starts, max_length=length,
                                 seed=1))
            svc.step()
            svc.submit(WalkQuery(num_walks=lanes, start_mode="edges",
                                 max_length=length, seed=2))
            svc.step()
    svc.drain()


def run_sharded(shard_counts=None, n_queries=120, num_nodes=1024,
                num_edges=40_000, seed=29):
    """Drain throughput of the sharded service vs shard count.

    Closed-loop on purpose (submit everything, then drain): this sweep
    measures the sharded dispatch path itself — owner-claimed starts,
    per-hop migration, psum reassembly — not queueing, which the
    open-loop sweep above already characterizes.
    """
    import jax
    devs = len(jax.devices())
    counts = shard_counts or [d for d in (1, 2, 4, 8) if d <= devs]
    if common.SMALL:
        n_queries, num_edges = 40, 20_000
    g = powerlaw_temporal_graph(num_nodes, num_edges, seed=seed)
    cfg = EngineConfig(
        window=WindowConfig(duration=6000, edge_capacity=1 << 16,
                            node_capacity=num_nodes),
        sampler=SamplerConfig(mode="index"),
        scheduler=SchedulerConfig(path="grouped"),
        # exchange provisioning mirrors fig7 (DESIGN.md §12): at D=1 one
        # sender may route its whole batch slice to one owner
        shard=ShardConfig(edge_capacity_per_shard=1 << 16,
                          exchange_capacity=1 << 14,
                          walk_slots=1 << 11,
                          walk_bucket_capacity=1 << 10))
    serve_cfg = ServeConfig(queue_capacity=n_queries + 8,
                            lane_buckets=(64, 256),
                            length_buckets=(4, 8, 16))
    rng = np.random.default_rng(seed)
    queries = _mixed_workload(rng, n_queries, num_nodes)

    rows = []
    for D in counts:
        svc = WalkService(cfg, serve_cfg, batch_capacity=num_edges // 4 + 64,
                          num_shards=D)
        for bs, bd, bt in chronological_batches(g, 4):
            svc.ingest(bs, bd, bt)
        for q in queries:                    # warm the jit cache per shape
            svc.submit(q)
        svc.drain()
        svc.stats = ServeStats()
        for q in queries:
            svc.submit(q)
        t0 = time.perf_counter()
        while svc.pending_count:
            svc.step()
        wall = time.perf_counter() - t0
        s = svc.stats
        emit(f"serving/shards={D}", 1e6 * wall / max(s.batches, 1),
             f"walks_per_s={s.walks / wall:.0f};served={s.completed};"
             f"batches={s.batches};occupancy={s.lane_occupancy:.2f};"
             f"shard_walk_drops={s.shard_walk_drops};wall_s={wall:.2f}")
        rows.append({"shards": D, "walks_per_s": s.walks / wall,
                     "served": s.completed, "batches": s.batches,
                     "shard_walk_drops": s.shard_walk_drops,
                     "wall_s": wall})
    return rows


def run(offered_loads_qps=(100, 800, 6400), n_queries=150,
        num_nodes=1024, num_edges=60_000, seed=17, deadline_s=0.25):
    if common.SMALL:
        offered_loads_qps, n_queries, num_edges = (100, 4000), 80, 30_000
    g = powerlaw_temporal_graph(num_nodes, num_edges, seed=seed)
    cfg = _base_cfg(num_nodes)
    batch_capacity = num_edges // 8 + 64
    batches = list(chronological_batches(g, 8))
    base, live = batches[:4], batches[4:]

    rng = np.random.default_rng(seed)
    _warm_buckets(_fresh_service(cfg, _serve_cfg(False), base,
                                 batch_capacity),
                  _serve_cfg(False), rng, num_nodes)

    open_rows = []
    for qps in offered_loads_qps:
        queries = _mixed_workload(rng, n_queries, num_nodes,
                                  deadline_s=deadline_s)
        arrivals = np.cumsum(rng.exponential(1.0 / qps, n_queries))
        # live window advances at fixed offered times, same for both modes
        span = float(arrivals[-1])
        ingests = [(span * (k + 1) / (len(live) + 1), b)
                   for k, b in enumerate(live)]
        for overlapped in (False, True):
            svc = _fresh_service(cfg, _serve_cfg(overlapped), base,
                                 batch_capacity)
            wall, tickets = _drive_open_loop(svc, queries, arrivals,
                                             overlapped, ingests)
            s = svc.stats
            goodput = _goodput(svc, queries, tickets, wall)
            mode = "overlapped" if overlapped else "blocking"
            emit(f"serving/load_{qps}qps/{mode}",
                 1e6 * (np.mean(s.latencies_s) if len(s.latencies_s)
                        else float("nan")),
                 f"p50_ms={s.p50_ms:.2f};p99_ms={s.p99_ms:.2f};"
                 f"goodput_qps={goodput:.0f};served={s.completed};"
                 f"dropped_deadline={s.dropped_deadline};"
                 f"dropped_backpressure={s.dropped_backpressure};"
                 f"batches={s.batches};occupancy={s.lane_occupancy:.2f};"
                 f"wall_s={wall:.2f}")
            open_rows.append({
                "offered_qps": qps, "mode": mode, "wall_s": wall,
                "p50_ms": float(s.p50_ms), "p99_ms": float(s.p99_ms),
                "goodput_qps": goodput, "served": s.completed,
                "dropped_deadline": s.dropped_deadline,
                "dropped_backpressure": s.dropped_backpressure,
                "batches": s.batches,
                "occupancy": float(s.lane_occupancy)})

    closed_rows = []
    queries = _mixed_workload(rng, n_queries, num_nodes)
    for overlapped in (False, True):
        svc = _fresh_service(cfg, _serve_cfg(overlapped,
                                             queue_capacity=n_queries + 8),
                             base, batch_capacity)
        for q in queries:
            svc.submit(q, strict=True)
        t0 = time.perf_counter()
        svc.drain()
        wall = time.perf_counter() - t0
        s = svc.stats
        mode = "overlapped" if overlapped else "blocking"
        emit(f"serving/closed_loop/{mode}", 1e6 * wall / max(s.batches, 1),
             f"walks_per_s={s.walks / wall:.0f};served={s.completed};"
             f"batches={s.batches};wall_s={wall:.2f}")
        closed_rows.append({"mode": mode, "walks_per_s": s.walks / wall,
                            "served": s.completed, "batches": s.batches,
                            "wall_s": wall})

    sharded_rows = run_sharded()

    # the acceptance comparison: at the heaviest offered load, overlapped
    # ingest+dispatch vs the blocking baseline, goodput under deadlines
    top = max(offered_loads_qps)
    by_mode = {r["mode"]: r for r in open_rows if r["offered_qps"] == top}
    blocking_g = by_mode["blocking"]["goodput_qps"]
    overlapped_g = by_mode["overlapped"]["goodput_qps"]
    write_json("serving", {
        "deadline_s": deadline_s,
        "offered_loads_qps": list(offered_loads_qps),
        "n_queries_per_load": n_queries,
        "open_loop": open_rows,
        "closed_loop": closed_rows,
        "sharded": sharded_rows,
        "overlap_vs_blocking": {
            "offered_qps": top,
            "blocking_goodput_qps": blocking_g,
            "overlapped_goodput_qps": overlapped_g,
            "goodput_gain": (overlapped_g / blocking_g
                             if blocking_g > 0 else float("inf")),
        },
    })


if __name__ == "__main__":
    import sys
    argv = sys.argv[1:]
    if "--small" in argv:
        common.SMALL = True
    if "--emit-json" in argv:
        common.EMIT_JSON = True
        common.begin_suite("serving_load")
    if "--shards" in argv:
        # e.g. XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        #        python -m benchmarks.serving_load --shards [1,2,8]
        i = argv.index("--shards")
        arg = argv[i + 1] if len(argv) > i + 1 else ""
        counts = ([int(x) for x in arg.strip("[]").split(",") if x]
                  if arg and not arg.startswith("-") else None)
        run_sharded(shard_counts=counts)
    else:
        run()
    common.end_suite()
