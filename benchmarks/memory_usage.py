"""Paper Fig. 11: memory usage.

(a) bulk-mode scaling: engine device bytes vs edge count (linear);
(b) streaming: bytes flat across batches (bounded by the window, exactly
    constant here thanks to static shapes).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core.edge_store import make_batch, store_from_arrays, store_nbytes
from repro.core.temporal_index import build_index
from repro.core.window import ingest, init_window
from repro.data.synthetic import chronological_batches, powerlaw_temporal_graph


def index_nbytes(idx) -> int:
    import jax
    return sum(a.size * a.dtype.itemsize for a in jax.tree.leaves(idx))


def run():
    # (a) bulk scaling
    for E in (1 << 10, 1 << 14, 1 << 17, 1 << 19):
        nn = max(256, E // 64)
        g = powerlaw_temporal_graph(nn, E, seed=15)
        store = store_from_arrays(g.src, g.dst, g.ts, edge_capacity=E,
                                  node_capacity=nn)
        idx = build_index(store, nn)
        total = index_nbytes(idx)
        emit(f"fig11a/E={E}", 0.0,
             f"bytes={total};bytes_per_edge={total/E:.1f}")

    # (b) streaming flatness
    g = powerlaw_temporal_graph(1024, 100_000, seed=16)
    st = init_window(edge_capacity=1 << 16, node_capacity=1024, window=2000)
    sizes = []
    for bs, bd, bt in chronological_batches(g, 20):
        st = ingest(st, make_batch(bs, bd, bt, capacity=8192), 1024)
        sizes.append(index_nbytes(st.index))
    emit("fig11b/streaming", 0.0,
         f"min={min(sizes)};max={max(sizes)};flat={min(sizes)==max(sizes)}")
    return sizes


if __name__ == "__main__":
    run()
