"""Fused-path walk throughput (paper Tables 2-3, DESIGN.md §14).

Times ``generate_walks`` over all five walk paths — fullwalk,
grouped-lexsort, grouped-bucket, tiled, fused — on one skewed graph and
reports walks/s and M-steps/s per path, plus the fused kernel's
per-tier launch counts (tier-S lanes, tier-L lanes, swept edge blocks)
alongside the classic dispatch tiers. With ``--emit-json`` the full
record is persisted as ``BENCH_fused.json`` for trend tracking.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.common import (
    emit,
    make_bench_index,
    steps_per_sec,
    timeit,
    write_json,
)
from repro.configs.base import SamplerConfig, SchedulerConfig, WalkConfig
from repro.core import scheduler as sched
from repro.core.alias import alias_pick, build_tables, spec_from_sampler
from repro.core.samplers import weighted_pick_exp
from repro.core.temporal_index import node_range
from repro.core.walk_engine import generate_walks

PATHS = [
    ("fullwalk", dict(path="fullwalk")),
    ("grouped-lexsort", dict(path="grouped", regroup="lexsort")),
    ("grouped-bucket", dict(path="grouped", regroup="bucket")),
    ("tiled", dict(path="tiled")),
    ("fused", dict(path="fused", regroup="bucket")),
]

TIER_STATS = {
    "solo": "STAT_SOLO",
    "group_smem": "STAT_GROUP_SMEM",
    "group_global": "STAT_GROUP_GLOBAL",
    "mega": "STAT_MEGA",
    "fused_small": "STAT_FUSED_SMALL",
    "fused_big": "STAT_FUSED_BIG",
    "fused_blocks": "STAT_FUSED_BLOCKS",
}


def run():
    small = common.SMALL
    num_walks = 512 if small else 2048
    max_length = 6 if small else 10
    num_edges = 6000 if small else 14000
    edge_capacity = 8192 if small else 16384
    repeats = 1 if small else 3
    wcfg = WalkConfig(num_walks=num_walks, max_length=max_length,
                      start_mode="nodes")
    scfg = SamplerConfig(bias="exponential", mode="weight")
    tiles = dict(tile_walks=128 if small else 256, tile_edges=1024)
    _, idx = make_bench_index(num_nodes=256 if small else 1024,
                              num_edges=num_edges,
                              skew=2.0 if small else 1.4,
                              edge_capacity=edge_capacity)
    key = jax.random.PRNGKey(0)

    payload = {
        "suite": "fused_walk_paths",
        "config": dict(num_walks=num_walks, max_length=max_length,
                       num_edges=num_edges, edge_capacity=edge_capacity,
                       small=small, **tiles),
        "paths": {},
        "tiers": {},
    }
    for name, kw in PATHS:
        cfg = SchedulerConfig(**kw, **tiles)
        mean_s, std_s, res = timeit(generate_walks, idx, key, wcfg, scfg,
                                    cfg, repeats=repeats)
        walks_s = num_walks / mean_s
        msteps = steps_per_sec(res, mean_s)
        emit(f"fused_walks/{name}", mean_s * 1e6,
             f"walks/s={walks_s:.0f};Msteps/s={msteps:.3f}")
        payload["paths"][name] = dict(mean_s=float(mean_s),
                                      std_s=float(std_s),
                                      walks_per_s=float(walks_s),
                                      msteps_per_s=float(msteps))

    # per-tier dispatch counts for the fused run (paper Table 3 analog)
    res = generate_walks(idx, key, wcfg, scfg,
                         SchedulerConfig(path="fused", regroup="bucket",
                                         **tiles), collect_stats=True)
    st = np.asarray(res.stats)
    for tier, const in TIER_STATS.items():
        payload["tiers"][tier] = int(st[:, getattr(sched, const)].sum())
    emit("fused_walks/tiers", 0.0,
         ";".join(f"{k}={v}" for k, v in payload["tiers"].items()))

    # ---- alias tables vs binary-search weighted picks (DESIGN.md §17) ----
    # walk-level: the same exponential-recency law sampled through O(1)
    # alias draws (bias="table") vs the O(log n) weighted inverse CDF.
    table_scfg = SamplerConfig(mode="index", bias="table",
                               table_weight="exponential")
    spec = spec_from_sampler(table_scfg)
    tables = build_tables(idx, spec)
    grouped = SchedulerConfig(path="grouped", regroup="bucket", **tiles)
    payload["table_bias"] = {}
    for name, s, tb in (("walks-weight-binsearch", scfg, None),
                        ("walks-table-alias", table_scfg, tables)):
        mean_s, std_s, res = timeit(generate_walks, idx, key, wcfg, s,
                                    grouped, tables=tb, repeats=repeats)
        emit(f"fused_walks/{name}", mean_s * 1e6,
             f"walks/s={num_walks / mean_s:.0f}")
        payload["table_bias"][name] = dict(
            mean_s=float(mean_s), std_s=float(std_s),
            walks_per_s=float(num_walks / mean_s))

    # draw-level micro: one biased pick per lane over full node regions
    W = 50_000 if small else 200_000
    rng = np.random.default_rng(0)
    nodes = jnp.asarray(rng.integers(0, idx.node_capacity, W), jnp.int32)
    a, b = node_range(idx, nodes)
    u = jnp.asarray(rng.uniform(size=W), jnp.float32)
    draw_alias = jax.jit(lambda aa, bb, uu: alias_pick(
        tables, aa, aa, bb, uu, radix=spec.radix,
        degree_cap=spec.degree_cap))
    draw_bin = jax.jit(lambda aa, bb, uu: weighted_pick_exp(
        idx.pexp, aa, bb, uu))
    for name, fn in (("draws-table-alias", draw_alias),
                     ("draws-weight-binsearch", draw_bin)):
        mean_s, std_s, _ = timeit(fn, a, b, u, repeats=repeats)
        emit(f"fused_walks/{name}", mean_s * 1e6,
             f"Mdraws/s={W / mean_s / 1e6:.2f}")
        payload["table_bias"][name] = dict(
            mean_s=float(mean_s), std_s=float(std_s),
            mdraws_per_s=float(W / mean_s / 1e6))

    write_json("fused", payload)
    return payload


if __name__ == "__main__":
    run()
