"""Paper Table 2: cooperative scheduler ablation + per-hop regroup
old-vs-new (Fig. 8 analog).

Variants (DESIGN.md mapping):
  fullwalk        <-> Full-Walk   (one lane per walk, no grouping)
  grouped-lexsort <-> Coop-Global with the seed's per-hop O(W log W)
                      lexsort + inverse-scatter regrouping
  grouped-bucket  <-> Coop-Global with the O(W) counting regroup and
                      carried permutation (DESIGN.md §10)
  tiled-lexsort / tiled-bucket <-> Coop (VMEM-staged metadata kernel) over
                      either regrouping

Reported: walks/s and M-steps/s wall-clock (CPU, relative — the
grouped-lexsort vs grouped-bucket delta is the regroup win), plus the
modeled per-step HBM bytes for fullwalk vs grouped — the structural metric
that the launch count plays in the paper (DESIGN.md §9: launch counts are
not a TPU quantity).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, make_bench_index, steps_per_sec, timeit
from repro.configs.base import SamplerConfig, SchedulerConfig, WalkConfig
from repro.core import scheduler as sched
from repro.core.walk_engine import generate_walks

DATASETS = {
    "lowskew": dict(num_nodes=2048, num_edges=60000, skew=0.8),
    "hubskew": dict(num_nodes=2048, num_edges=60000, skew=1.6),
    "megahub": dict(num_nodes=256, num_edges=60000, skew=2.2),
}

# (label, path, regroup) — the old-vs-new regroup benchmark rides the
# same grid: grouped-lexsort is the seed behavior, grouped-bucket the
# production path
VARIANTS = [
    ("fullwalk", "fullwalk", "bucket"),
    ("grouped-lexsort", "grouped", "lexsort"),
    ("grouped-bucket", "grouped", "bucket"),
    ("tiled-lexsort", "tiled", "lexsort"),
    ("tiled-bucket", "tiled", "bucket"),
]


def run(repeats: int = 3):
    wcfg = WalkConfig(num_walks=4096, max_length=40, start_mode="nodes")
    scfg = SamplerConfig(bias="exponential", mode="weight")
    rows = []
    for dname, kw in DATASETS.items():
        g, idx = make_bench_index(**kw)
        for label, path, regroup in VARIANTS:
            cfg = SchedulerConfig(path=path, regroup=regroup,
                                  tile_walks=256, tile_edges=1024)
            mean, std, res = timeit(
                generate_walks, idx, jax.random.PRNGKey(0), wcfg, scfg, cfg,
                repeats=repeats)
            msps = steps_per_sec(res, mean)
            walks_s = wcfg.num_walks / mean
            derived = (f"walks_per_s={walks_s:.3g};Msteps/s={msps:.2f};"
                       f"std_us={std*1e6:.0f}")
            if label in ("fullwalk", "grouped-bucket", "tiled-bucket"):
                # modeled bytes from dispatch stats (layout-level metric:
                # identical across regroup flavors, so sampled once each)
                res2 = generate_walks(idx, jax.random.PRNGKey(0), wcfg,
                                      scfg, cfg, collect_stats=True)
                st = np.asarray(res2.stats)
                b_full = st[:, sched.STAT_BYTES_FULLWALK].sum()
                b_grp = st[:, sched.STAT_BYTES_GROUPED].sum()
                derived += f";bytes_full={b_full:.3g};bytes_grouped={b_grp:.3g}"
            emit(f"table2/{dname}/{label}", mean * 1e6, derived)
            rows.append((dname, label, walks_s, msps))
    return rows


if __name__ == "__main__":
    run()
