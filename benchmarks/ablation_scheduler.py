"""Paper Table 2: cooperative scheduler ablation.

Variants (DESIGN.md mapping):
  fullwalk    <-> Full-Walk   (one lane per walk, no grouping)
  grouped     <-> Coop-Global (per-step regrouping, metadata from "global")
  tiled       <-> Coop        (regrouping + VMEM-staged metadata kernel)

Reported: M-steps/s wall-clock (CPU, relative), plus the modeled per-step
HBM bytes for fullwalk vs grouped — the structural metric that the launch
count plays in the paper (DESIGN.md §9: launch counts are not a TPU
quantity).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, make_bench_index, steps_per_sec, timeit
from repro.configs.base import SamplerConfig, SchedulerConfig, WalkConfig
from repro.core import scheduler as sched
from repro.core.walk_engine import generate_walks

DATASETS = {
    "lowskew": dict(num_nodes=2048, num_edges=60000, skew=0.8),
    "hubskew": dict(num_nodes=2048, num_edges=60000, skew=1.6),
    "megahub": dict(num_nodes=256, num_edges=60000, skew=2.2),
}


def run(repeats: int = 3):
    wcfg = WalkConfig(num_walks=4096, max_length=40, start_mode="nodes")
    scfg = SamplerConfig(bias="exponential", mode="weight")
    rows = []
    for dname, kw in DATASETS.items():
        g, idx = make_bench_index(**kw)
        for path in ("fullwalk", "grouped", "tiled"):
            cfg = SchedulerConfig(path=path, tile_walks=256, tile_edges=1024)
            mean, std, res = timeit(
                generate_walks, idx, jax.random.PRNGKey(0), wcfg, scfg, cfg,
                repeats=repeats)
            msps = steps_per_sec(res, mean)
            # modeled bytes from dispatch stats
            res2 = generate_walks(idx, jax.random.PRNGKey(0), wcfg, scfg,
                                  cfg, collect_stats=True)
            st = np.asarray(res2.stats)
            b_full = st[:, sched.STAT_BYTES_FULLWALK].sum()
            b_grp = st[:, sched.STAT_BYTES_GROUPED].sum()
            emit(f"table2/{dname}/{path}", mean * 1e6,
                 f"Msteps/s={msps:.2f};bytes_full={b_full:.3g};"
                 f"bytes_grouped={b_grp:.3g};std_us={std*1e6:.0f}")
            rows.append((dname, path, msps, b_full, b_grp))
    return rows


if __name__ == "__main__":
    run()
