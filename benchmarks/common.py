"""Shared benchmark utilities. All timings are CPU wall-clock (relative
claims only; TPU projections come from the roofline model — DESIGN.md §9).

Every suite's ``emit()`` rows are also accumulated into a per-suite
record (``begin_suite``/``end_suite``, driven by ``benchmarks.run``);
with ``--emit-json`` each suite writes a schema-validated
``BENCH_<suite>.json`` in the shared ``tempest-bench/v1`` layout
(obs/export.py, DESIGN.md §16) — one schema for every artifact instead
of per-suite ad-hoc payloads.
"""
from __future__ import annotations

import json
import math
import os
import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.obs.export import bench_doc

from repro.configs.base import (
    EngineConfig,
    SamplerConfig,
    SchedulerConfig,
    WalkConfig,
    WindowConfig,
)
from repro.core.edge_store import store_from_arrays
from repro.core.temporal_index import build_index
from repro.data.synthetic import powerlaw_temporal_graph


# Toggled by ``benchmarks.run`` flags: --emit-json persists machine-readable
# BENCH_*.json artifacts next to the CSV stream; --small shrinks suite
# configs to nightly-CI scale.
EMIT_JSON = False
SMALL = False

# Active suite record (one per ``begin_suite``/``end_suite`` bracket):
# emit() rows + any write_json() detail payloads land here.
_SUITE: Optional[str] = None
_SUITE_ROWS: List[dict] = []
_SUITE_EXTRAS: Dict[str, dict] = {}


def begin_suite(name: str) -> None:
    """Open a suite record; subsequent ``emit``/``write_json`` calls
    accumulate into it until ``end_suite``."""
    global _SUITE, _SUITE_ROWS, _SUITE_EXTRAS
    _SUITE = name
    _SUITE_ROWS = []
    _SUITE_EXTRAS = {}


def end_suite() -> str | None:
    """Close the active suite; with --emit-json write its accumulated
    rows (+ detail payloads) as a schema-validated ``BENCH_<suite>.json``
    in the shared ``tempest-bench/v1`` layout."""
    global _SUITE, _SUITE_ROWS, _SUITE_EXTRAS
    if _SUITE is None:
        return None
    name, rows, extras = _SUITE, _SUITE_ROWS, _SUITE_EXTRAS
    _SUITE, _SUITE_ROWS, _SUITE_EXTRAS = None, [], {}
    if not EMIT_JSON:
        return None
    doc = bench_doc(name, rows, results=extras or None)
    return _dump_json(name, doc)


def _dump_json(name: str, doc: dict) -> str:
    path = os.path.join(os.getcwd(), f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path}", flush=True)
    return path


def write_json(name: str, payload: dict) -> str | None:
    """Persist a suite's detail payload when --emit-json is active.

    The payload is folded into the active suite record (so the suite's
    ``BENCH_<suite>.json`` carries it under ``results``) and, for
    backwards compatibility with existing artifact names, also written
    standalone as ``BENCH_<name>.json`` — wrapped in the same
    ``tempest-bench/v1`` schema with the rows emitted so far.
    """
    if _SUITE is not None:
        _SUITE_EXTRAS[name] = payload
    if not EMIT_JSON:
        return None
    doc = bench_doc(name, list(_SUITE_ROWS), results={name: payload})
    return _dump_json(name, doc)


def timeit(fn: Callable, *args, repeats: int = 5, warmup: int = 1,
           **kwargs) -> tuple:
    for _ in range(warmup):
        out = fn(*args, **kwargs)
        jax.block_until_ready(jax.tree.leaves(out)[0]) if jax.tree.leaves(out) else None
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        leaves = jax.tree.leaves(out)
        if leaves:
            jax.block_until_ready(leaves[0])
        times.append(time.perf_counter() - t0)
    return np.mean(times), np.std(times), out


def emit(name: str, us_per_call: float, derived: str = ""):
    us = float(us_per_call)
    if not math.isfinite(us):
        us = -1.0          # schema wants finite numbers; -1 marks "n/a"
    if _SUITE is not None:
        _SUITE_ROWS.append(
            {"name": name, "us_per_call": us, "derived": derived})
    print(f"{name},{us_per_call:.1f},{derived}")


def make_bench_index(num_nodes=2048, num_edges=60000, skew=1.2, seed=0,
                     edge_capacity=65536, ts_groups=None):
    g = powerlaw_temporal_graph(num_nodes, num_edges, skew=skew, seed=seed,
                                ts_groups=ts_groups)
    store = store_from_arrays(g.src, g.dst, g.ts,
                              edge_capacity=edge_capacity,
                              node_capacity=num_nodes)
    return g, build_index(store, num_nodes)


def steps_per_sec(result, elapsed_s: float) -> float:
    """M-steps/s from walk lengths (paper Table 2 metric)."""
    hops = float(np.sum(np.asarray(result.lengths) - 1).clip(min=0))
    return hops / elapsed_s / 1e6
