"""Shared benchmark utilities. All timings are CPU wall-clock (relative
claims only; TPU projections come from the roofline model — DESIGN.md §9)."""
from __future__ import annotations

import json
import os
import time
from typing import Callable

import jax
import numpy as np

from repro.configs.base import (
    EngineConfig,
    SamplerConfig,
    SchedulerConfig,
    WalkConfig,
    WindowConfig,
)
from repro.core.edge_store import store_from_arrays
from repro.core.temporal_index import build_index
from repro.data.synthetic import powerlaw_temporal_graph


# Toggled by ``benchmarks.run`` flags: --emit-json persists machine-readable
# BENCH_*.json artifacts next to the CSV stream; --small shrinks suite
# configs to nightly-CI scale.
EMIT_JSON = False
SMALL = False


def write_json(name: str, payload: dict) -> str | None:
    """Write ``BENCH_<name>.json`` in the cwd when --emit-json is active."""
    if not EMIT_JSON:
        return None
    path = os.path.join(os.getcwd(), f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path}", flush=True)
    return path


def timeit(fn: Callable, *args, repeats: int = 5, warmup: int = 1,
           **kwargs) -> tuple:
    for _ in range(warmup):
        out = fn(*args, **kwargs)
        jax.block_until_ready(jax.tree.leaves(out)[0]) if jax.tree.leaves(out) else None
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        leaves = jax.tree.leaves(out)
        if leaves:
            jax.block_until_ready(leaves[0])
        times.append(time.perf_counter() - t0)
    return np.mean(times), np.std(times), out


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")


def make_bench_index(num_nodes=2048, num_edges=60000, skew=1.2, seed=0,
                     edge_capacity=65536, ts_groups=None):
    g = powerlaw_temporal_graph(num_nodes, num_edges, skew=skew, seed=seed,
                                ts_groups=ts_groups)
    store = store_from_arrays(g.src, g.dst, g.ts,
                              edge_capacity=edge_capacity,
                              node_capacity=num_nodes)
    return g, build_index(store, num_nodes)


def steps_per_sec(result, elapsed_s: float) -> float:
    """M-steps/s from walk lengths (paper Table 2 metric)."""
    hops = float(np.sum(np.asarray(result.lengths) - 1).clip(min=0))
    return hops / elapsed_s / 1e6
