"""Paper Table 6: causal validity vs non-temporal engines.

The static walker (FlowWalker/ThunderRW abstraction: timestamps
discarded) produces ~0% temporally valid walks; Tempest produces 100%.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, make_bench_index, steps_per_sec, timeit
from repro.configs.base import SamplerConfig, SchedulerConfig, WalkConfig
from repro.core.baselines import StaticWalker, temporal_validity
from repro.core.validation import validate_walks
from repro.core.walk_engine import generate_walks


def run(num_nodes=1024, num_edges=40000, n_walks=2048, L=40):
    g, idx = make_bench_index(num_nodes=num_nodes, num_edges=num_edges)

    # --- static walker ---
    sw = StaticWalker(g.src, g.dst, g.ts, num_nodes)
    rng = np.random.default_rng(0)
    starts = rng.integers(0, num_nodes, n_walks)
    t0 = time.perf_counter()
    vh = th = vw = tw = 0
    for s in starts:
        nodes, times = sw.walk(int(s), L, rng)
        v, t, ok = temporal_validity(nodes, times)
        vh += v; th += t; vw += ok; tw += 1
    t_static = time.perf_counter() - t0
    static_hop = 100.0 * vh / max(th, 1)
    static_walk = 100.0 * vw / max(tw, 1)

    # --- tempest ---
    wcfg = WalkConfig(num_walks=n_walks, max_length=L, start_mode="nodes")
    mean, _, res = timeit(generate_walks, idx, jax.random.PRNGKey(0), wcfg,
                          SamplerConfig(), SchedulerConfig(), repeats=3)
    rep = validate_walks(idx, res)
    emit("table6/static", t_static * 1e6,
         f"valid_hops={static_hop:.1f}%;valid_walks={static_walk:.1f}%")
    emit("table6/tempest", mean * 1e6,
         f"valid_hops={100*float(rep.hop_valid_frac):.1f}%;"
         f"valid_walks={100*float(rep.walk_valid_frac):.1f}%;"
         f"Msteps/s={steps_per_sec(res, mean):.2f}")
    return static_hop, static_walk, float(rep.walk_valid_frac)


if __name__ == "__main__":
    run()
