"""Paper Fig. 7: scaling with active graph size (1K -> 512K edges here).

(a) ingestion-from-scratch time per edge count;
(b) per-walk sampling time across edge counts for the three pickers
    (paper: essentially flat — per-walk time varies <5%).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, timeit
from repro.configs.base import SamplerConfig, SchedulerConfig, WalkConfig
from repro.core.edge_store import make_batch, store_from_arrays
from repro.core.temporal_index import build_index
from repro.core.window import ingest, init_window
from repro.core.walk_engine import generate_walks
from repro.data.synthetic import powerlaw_temporal_graph

EDGE_COUNTS = (1024, 8192, 65536, 262144, 524288)


def run():
    rows = []
    for E in EDGE_COUNTS:
        nn = max(256, E // 64)
        g = powerlaw_temporal_graph(nn, E, seed=11)
        # (a) ingestion from scratch (batch pad + sort + index build)
        cap = 1 << (E - 1).bit_length()
        t0 = time.perf_counter()
        store = store_from_arrays(g.src, g.dst, g.ts, edge_capacity=cap,
                                  node_capacity=nn)
        idx = build_index(store, nn)
        jax.block_until_ready(idx.ns_order)
        t_ing = time.perf_counter() - t0

        # (b) per-walk time, three pickers
        wcfg = WalkConfig(num_walks=4096, max_length=40, start_mode="nodes")
        per_walk = {}
        for bias, mode, p, q in [("exponential", "index", 1.0, 1.0),
                                 ("exponential", "weight", 1.0, 1.0),
                                 ("exponential", "weight", 0.5, 2.0)]:
            name = "node2vec" if p != 1.0 else f"{mode}"
            scfg = SamplerConfig(bias=bias, mode=mode, node2vec_p=p,
                                 node2vec_q=q)
            mean, _, _ = timeit(generate_walks, idx, jax.random.PRNGKey(0),
                                wcfg, scfg, SchedulerConfig(), repeats=3)
            per_walk[name] = mean / wcfg.num_walks * 1e6
        emit(f"fig7/E={E}", t_ing * 1e6,
             f"ingest_s={t_ing:.3f};" +
             ";".join(f"walk_us_{k}={v:.1f}" for k, v in per_walk.items()))
        rows.append((E, t_ing, per_walk))
    # flatness check across edge counts
    for k in rows[0][2]:
        vals = [r[2][k] for r in rows[1:]]   # skip smallest (fixed costs)
        spread = (max(vals) - min(vals)) / max(np.mean(vals), 1e-9)
        emit(f"fig7/flatness/{k}", 0.0, f"spread={100*spread:.1f}%")
    return rows


if __name__ == "__main__":
    run()
