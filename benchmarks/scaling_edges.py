"""Paper Fig. 7: scaling with active graph size (1K -> 512K edges here).

(a) ingestion-from-scratch time per edge count;
(b) per-walk sampling time across edge counts for the three pickers
    (paper: essentially flat — per-walk time varies <5%);
(c) beyond-paper: node-partitioned window (DESIGN.md §12) — streaming
    ingest + walk throughput per shard count, absolute and per device.
    Shard counts sweep the divisors of the visible device count; fake an
    8-device host with XLA_FLAGS=--xla_force_host_platform_device_count=8
    (see benchmarks/README.md) to get the full curve on CPU.
(d) beyond-paper: placement-policy load balance (DESIGN.md §15) — a Zipf
    skew sweep comparing per-shard edge loads under range / hash / skew
    node placement. Pure host math (``owner_np`` over the edge stream),
    so it needs no devices; ``--emit-json`` writes BENCH_shard.json.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks import common
from benchmarks.common import emit, timeit, write_json
from repro.configs.base import (
    EngineConfig,
    SamplerConfig,
    SchedulerConfig,
    ShardConfig,
    WalkConfig,
    WindowConfig,
)
from repro.core.edge_store import make_batch, store_from_arrays
from repro.core.streaming import StreamingEngine
from repro.core.temporal_index import build_index
from repro.core.window import ingest, init_window
from repro.core.walk_engine import generate_walks
from repro.data.synthetic import chronological_batches, powerlaw_temporal_graph
from repro.distributed.streaming_shard import DistributedStreamingEngine

EDGE_COUNTS = (1024, 8192, 65536, 262144, 524288)

# sharded-window replay workload (c): modest sizes so the CPU path stays
# quick; the structural claim is the per-shard scaling, not absolute us
SHARD_NODES = 4096
SHARD_EDGES = 200_000
SHARD_BATCHES = 10
SHARD_WALKS = 2048


def run_sharded():
    """(c) streaming replay throughput vs shard count."""
    devs = len(jax.devices())
    counts = [d for d in (1, 2, 4, 8) if d <= devs]
    nodes = 512 if common.SMALL else SHARD_NODES
    n_edges = 20_000 if common.SMALL else SHARD_EDGES
    n_walks = 512 if common.SMALL else SHARD_WALKS
    g = powerlaw_temporal_graph(nodes, n_edges, seed=23)
    wcfg = WalkConfig(num_walks=n_walks, max_length=16,
                      start_mode="all_nodes")
    batch_cap = n_edges // SHARD_BATCHES + 8
    cfg = EngineConfig(
        window=WindowConfig(duration=5000, edge_capacity=1 << 17,
                            node_capacity=nodes),
        sampler=SamplerConfig(bias="exponential", mode="index"),
        scheduler=SchedulerConfig(path="grouped", regroup="bucket"),
        # exchange buckets must cover the worst case of one sender routing
        # its whole batch slice to one owner (DESIGN.md §12 provisioning):
        # at D=1 that is the full batch
        shard=ShardConfig(edge_capacity_per_shard=1 << 17,
                          exchange_capacity=1 << 15,
                          walk_slots=1 << 13,
                          walk_bucket_capacity=1 << 12),
    )

    def timed_replay(make_engine):
        # warm-up on a throwaway engine (pays the jit compile), then time a
        # FRESH engine so the measured replay ingests a fresh stream, not a
        # re-ingest against an already-advanced window (the
        # streaming_replay.py convention)
        make_engine().replay_device(chronological_batches(g, SHARD_BATCHES),
                                    wcfg)
        return make_engine().replay_device(
            chronological_batches(g, SHARD_BATCHES), wcfg)

    # single-device reference: the fused replay_scan driver, its own row —
    # the shards=1 row below runs the shard_map'd engine, so the 1->D
    # deltas isolate shard scaling and the ref->1 delta isolates the
    # collective/migration machinery itself
    out = timed_replay(
        lambda: StreamingEngine(cfg, batch_capacity=batch_cap))
    secs = out[-1]
    emit("fig7/single_device_ref", secs * 1e6,
         f"ingest_edges_s={n_edges / secs:.0f};"
         f"walks_s={SHARD_BATCHES * n_walks / secs:.0f}")

    rows = []
    for D in counts:
        stats, _, secs = timed_replay(
            lambda: DistributedStreamingEngine(cfg, batch_capacity=batch_cap,
                                               num_shards=D))
        drops = int(stats.exchange_drops.sum() + stats.walk_drops.sum())
        edges_s = n_edges / secs
        walks_s = SHARD_BATCHES * n_walks / secs
        emit(f"fig7/shards={D}", secs * 1e6,
             f"ingest_edges_s={edges_s:.0f};walks_s={walks_s:.0f};"
             f"edges_s_per_dev={edges_s / D:.0f};"
             f"walks_s_per_dev={walks_s / D:.0f};drops={drops}")
        rows.append((D, edges_s, walks_s))
    return rows


def run_placement_sweep():
    """(d) per-shard edge load under Zipf skew: range vs hash vs skew.

    Host-side placement math only (``owner_np`` over the stream's source
    nodes — the same rule the sharded ingest applies on device), so the
    sweep runs at full size regardless of the visible device count. The
    headline number per (zipf, policy) cell is max/mean per-shard edge
    load: 1.0 is a perfectly balanced window, range placement melts as
    hubs concentrate in one node-id range, and the measured-load skew
    overrides (SkewPlacement.from_loads, DESIGN.md §15) pull it back.
    """
    from repro.distributed.placement import (
        HashPlacement,
        RangePlacement,
        SkewPlacement,
    )

    D = 8
    nn = 1024 if common.SMALL else 8192
    ne = 20_000 if common.SMALL else 200_000
    payload = {"num_shards": D, "num_nodes": nn, "num_edges": ne,
               "hot_k": 16, "zipf": {}}
    for zipf in (0.8, 1.2, 1.6):
        g = powerlaw_temporal_graph(nn, ne, skew=zipf, seed=31)
        loads = np.bincount(g.src, minlength=nn).astype(np.float64)
        rp = RangePlacement(num_shards=D, node_capacity=nn)
        policies = (rp, HashPlacement.make(D, nn),
                    SkewPlacement.from_loads(rp, loads, k=16))
        cell = {}
        for plc in policies:
            per = np.bincount(plc.owner_np(g.src), minlength=D
                              ).astype(np.float64)
            imb = float(per.max() / max(per.mean(), 1e-9))
            cell[plc.kind] = {"per_shard_edges": per.tolist(),
                              "max_edges": float(per.max()),
                              "mean_edges": float(per.mean()),
                              "max_over_mean": imb}
            emit(f"fig7/placement/zipf={zipf}/{plc.kind}", 0.0,
                 f"max_edges={per.max():.0f};mean_edges={per.mean():.1f};"
                 f"max_over_mean={imb:.3f}")
        assert cell["skew"]["max_over_mean"] <= \
            cell["range"]["max_over_mean"] + 1e-9, \
            "skew overrides must not worsen range imbalance"
        payload["zipf"][str(zipf)] = cell
    write_json("shard", payload)
    return payload


def run():
    rows = []
    # --small (nightly CI): cap the edge sweep so the suite stays quick
    counts = EDGE_COUNTS[:3] if common.SMALL else EDGE_COUNTS
    for E in counts:
        nn = max(256, E // 64)
        g = powerlaw_temporal_graph(nn, E, seed=11)
        # (a) ingestion from scratch (batch pad + sort + index build)
        cap = 1 << (E - 1).bit_length()
        t0 = time.perf_counter()
        store = store_from_arrays(g.src, g.dst, g.ts, edge_capacity=cap,
                                  node_capacity=nn)
        idx = build_index(store, nn)
        jax.block_until_ready(idx.ns_order)
        t_ing = time.perf_counter() - t0

        # (b) per-walk time, three pickers
        wcfg = WalkConfig(num_walks=4096, max_length=40, start_mode="nodes")
        per_walk = {}
        for bias, mode, p, q in [("exponential", "index", 1.0, 1.0),
                                 ("exponential", "weight", 1.0, 1.0),
                                 ("exponential", "weight", 0.5, 2.0)]:
            name = "node2vec" if p != 1.0 else f"{mode}"
            scfg = SamplerConfig(bias=bias, mode=mode, node2vec_p=p,
                                 node2vec_q=q)
            mean, _, _ = timeit(generate_walks, idx, jax.random.PRNGKey(0),
                                wcfg, scfg, SchedulerConfig(), repeats=3)
            per_walk[name] = mean / wcfg.num_walks * 1e6
        emit(f"fig7/E={E}", t_ing * 1e6,
             f"ingest_s={t_ing:.3f};" +
             ";".join(f"walk_us_{k}={v:.1f}" for k, v in per_walk.items()))
        rows.append((E, t_ing, per_walk))
    # flatness check across edge counts
    for k in rows[0][2]:
        vals = [r[2][k] for r in rows[1:]]   # skip smallest (fixed costs)
        spread = (max(vals) - min(vals)) / max(np.mean(vals), 1e-9)
        emit(f"fig7/flatness/{k}", 0.0, f"spread={100*spread:.1f}%")
    rows.append(("sharded", run_sharded()))
    rows.append(("placement", run_placement_sweep()))
    return rows


if __name__ == "__main__":
    run()
