"""Paper Table 3: dispatch-plane tier distribution (% of tasks per tier)."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, make_bench_index
from repro.configs.base import SamplerConfig, SchedulerConfig, WalkConfig
from repro.core import scheduler as sched
from repro.core.walk_engine import generate_walks

DATASETS = {
    "lowskew": dict(num_nodes=2048, num_edges=60000, skew=0.8),
    "hubskew": dict(num_nodes=2048, num_edges=60000, skew=1.6),
    "megahub": dict(num_nodes=256, num_edges=60000, skew=2.2,
                    ts_groups=64),
}


def run():
    wcfg = WalkConfig(num_walks=8192, max_length=20, start_mode="nodes")
    cfg = SchedulerConfig(solo_threshold=4, max_task_walks=512,
                          tile_edges=1024)
    rows = []
    for dname, kw in DATASETS.items():
        g, idx = make_bench_index(**kw)
        res = generate_walks(idx, jax.random.PRNGKey(0), wcfg,
                             SamplerConfig(), cfg, collect_stats=True)
        st = np.asarray(res.stats)
        tiers = {
            "solo": st[:, sched.STAT_SOLO].sum(),
            "group_smem": st[:, sched.STAT_GROUP_SMEM].sum(),
            "group_global": st[:, sched.STAT_GROUP_GLOBAL].sum(),
            "mega": st[:, sched.STAT_MEGA].sum(),
        }
        total = max(sum(tiers.values()), 1)
        pct = {k: 100.0 * v / total for k, v in tiers.items()}
        emit(f"table3/{dname}", 0.0,
             ";".join(f"{k}={v:.1f}%" for k, v in pct.items()))
        rows.append((dname, pct))
    return rows


if __name__ == "__main__":
    run()
