"""Paper Table 3: dispatch-plane tier distribution (% of tasks per tier).

Also exposes ``tier_counts``/``golden_counts`` so the fast-lane golden
test (tests/test_tier_golden.py) can assert exact tier counts on a fixed
seeded graph — any change to ``dispatch_stats``'s tier rules shows up as
an integer diff there rather than a silent drift in this table.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, make_bench_index
from repro.configs.base import SamplerConfig, SchedulerConfig, WalkConfig
from repro.core import scheduler as sched
from repro.core.walk_engine import generate_walks

DATASETS = {
    "lowskew": dict(num_nodes=2048, num_edges=60000, skew=0.8),
    "hubskew": dict(num_nodes=2048, num_edges=60000, skew=1.6),
    "megahub": dict(num_nodes=256, num_edges=60000, skew=2.2,
                    ts_groups=64),
}

TIER_STATS = {
    "solo": sched.STAT_SOLO,
    "group_smem": sched.STAT_GROUP_SMEM,
    "group_global": sched.STAT_GROUP_GLOBAL,
    "mega": sched.STAT_MEGA,
    "fused_small": sched.STAT_FUSED_SMALL,
    "fused_big": sched.STAT_FUSED_BIG,
    "fused_blocks": sched.STAT_FUSED_BLOCKS,
}

# Fixed seeded config for the golden test: small enough for the fast
# lane, skewed enough that every tier (incl. fused tier-L) is populated.
GOLDEN_DATASET = dict(num_nodes=256, num_edges=6000, skew=1.6, seed=0,
                      edge_capacity=8192)
GOLDEN_WALKS = WalkConfig(num_walks=1024, max_length=8, start_mode="nodes")
GOLDEN_SCHED = SchedulerConfig(solo_threshold=4, max_task_walks=512,
                               tile_edges=1024)


def tier_counts(idx, wcfg, cfg) -> dict:
    """Summed dispatch_stats tier counts over a full walk generation."""
    res = generate_walks(idx, jax.random.PRNGKey(0), wcfg,
                         SamplerConfig(), cfg, collect_stats=True)
    st = np.asarray(res.stats)
    return {k: int(st[:, col].sum()) for k, col in TIER_STATS.items()}


def golden_counts() -> dict:
    _, idx = make_bench_index(**GOLDEN_DATASET)
    return tier_counts(idx, GOLDEN_WALKS, GOLDEN_SCHED)


def run():
    wcfg = WalkConfig(num_walks=8192, max_length=20, start_mode="nodes")
    cfg = SchedulerConfig(solo_threshold=4, max_task_walks=512,
                          tile_edges=1024)
    rows = []
    for dname, kw in DATASETS.items():
        _, idx = make_bench_index(**kw)
        tiers = tier_counts(idx, wcfg, cfg)
        classic = {k: tiers[k] for k in ("solo", "group_smem",
                                         "group_global", "mega")}
        total = max(sum(classic.values()), 1)
        pct = {k: 100.0 * v / total for k, v in classic.items()}
        emit(f"table3/{dname}", 0.0,
             ";".join(f"{k}={v:.1f}%" for k, v in pct.items()))
        emit(f"table3/{dname}/fused", 0.0,
             ";".join(f"{k}={tiers[k]}" for k in ("fused_small",
                                                  "fused_big",
                                                  "fused_blocks")))
        rows.append((dname, pct))
    return rows


if __name__ == "__main__":
    run()
