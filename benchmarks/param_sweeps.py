"""Paper Figs. 8-9: tuning sweeps.

Fig 8 analog: VMEM tile size (tile_edges x tile_walks) — the structural
equivalent of the CUDA block dimension (DESIGN.md §2).
Fig 9 analog: solo/group threshold W_warp sweep across skew levels.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, make_bench_index, steps_per_sec, timeit
from repro.configs.base import SamplerConfig, SchedulerConfig, WalkConfig
from repro.core.walk_engine import generate_walks


def run_tile_sweep():
    g, idx = make_bench_index(num_nodes=2048, num_edges=60000, skew=1.4)
    wcfg = WalkConfig(num_walks=4096, max_length=20, start_mode="nodes")
    scfg = SamplerConfig(bias="exponential", mode="weight")
    results = {}
    for tw, te in [(64, 256), (128, 512), (256, 1024), (512, 2048)]:
        cfg = SchedulerConfig(path="tiled", tile_walks=tw, tile_edges=te)
        mean, _, res = timeit(generate_walks, idx, jax.random.PRNGKey(0),
                              wcfg, scfg, cfg, repeats=3)
        msps = steps_per_sec(res, mean)
        results[(tw, te)] = msps
        emit(f"fig8/tile={tw}x{te}", mean * 1e6, f"Msteps/s={msps:.2f}")
    return results


def run_wwarp_sweep():
    wcfg = WalkConfig(num_walks=4096, max_length=20, start_mode="nodes")
    scfg = SamplerConfig()
    all_norm = {}
    for skew in (0.8, 1.4, 2.0):
        g, idx = make_bench_index(num_nodes=1024, num_edges=40000, skew=skew)
        vals = {}
        for w in (1, 2, 4, 8, 16, 32, 64):
            cfg = SchedulerConfig(path="grouped", solo_threshold=w)
            mean, _, res = timeit(generate_walks, idx,
                                  jax.random.PRNGKey(0), wcfg, scfg, cfg,
                                  repeats=3)
            vals[w] = steps_per_sec(res, mean)
        peak = max(vals.values())
        norm = {w: v / peak for w, v in vals.items()}
        all_norm[skew] = norm
        emit(f"fig9/skew={skew}", 0.0,
             ";".join(f"W{w}={v:.3f}" for w, v in norm.items()))
    # cross-dataset mean (paper defaults to its argmax)
    ws = list(next(iter(all_norm.values())).keys())
    mean_curve = {w: np.mean([all_norm[s][w] for s in all_norm]) for w in ws}
    best = max(mean_curve, key=mean_curve.get)
    emit("fig9/mean", 0.0,
         ";".join(f"W{w}={v:.3f}" for w, v in mean_curve.items())
         + f";argmax=W{best}")
    return all_norm


def run():
    return run_tile_sweep(), run_wwarp_sweep()


if __name__ == "__main__":
    run()
