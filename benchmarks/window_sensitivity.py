"""Paper Fig. 10: window-duration sensitivity.

Sweeps Δ (in batch units); reports walk-sampling latency (monotone rise
with window size) and downstream link-prediction AUC from incrementally
trained skipgram embeddings (peaks at small Δ, paper §3.9).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.base import (
    EngineConfig,
    SamplerConfig,
    SchedulerConfig,
    WalkConfig,
    WindowConfig,
)
from repro.core.streaming import StreamingEngine
from repro.data.synthetic import chronological_batches, powerlaw_temporal_graph
from repro.train.embeddings import (
    init_skipgram,
    link_prediction_auc,
    train_on_walks,
)


def run(num_nodes=512, num_edges=40_000, batches=20, dim=32):
    g = powerlaw_temporal_graph(num_nodes, num_edges, seed=13)
    t_span = int(g.ts.max()) + 1
    batch_dur = t_span / batches
    # chronological 70/15/15 split; eval on the test slice
    n_train = int(0.7 * num_edges)
    n_val = int(0.85 * num_edges)
    test_src, test_dst = g.src[n_val:], g.dst[n_val:]

    rows = []
    for delta_batches in (1, 2, 4, 8):
        cfg = EngineConfig(
            window=WindowConfig(duration=batch_dur * delta_batches,
                                edge_capacity=1 << 16,
                                node_capacity=num_nodes),
            sampler=SamplerConfig(bias="exponential", mode="index"),
            scheduler=SchedulerConfig(),
        )
        eng = StreamingEngine(cfg, batch_capacity=num_edges // batches + 64)
        wcfg = WalkConfig(num_walks=2048, max_length=12, start_mode="nodes")
        state = init_skipgram(num_nodes, dim, jax.random.PRNGKey(7))
        key = jax.random.PRNGKey(8)
        sample_times = []
        for bi, (bs, bd, bt) in enumerate(
                chronological_batches(g, batches)):
            if bs.size and bs[0] >= 0 and (bi / batches) > 0.7:
                break                       # train partition only
            eng.ingest_batch(bs, bd, bt)
            t0 = time.perf_counter()
            res = eng.sample_walks(wcfg)
            sample_times.append(time.perf_counter() - t0)
            key, sub = jax.random.split(key)
            state, _ = train_on_walks(state, res.nodes, res.lengths, sub,
                                      epochs=1)
        auc = link_prediction_auc(state, test_src, test_dst, num_nodes)
        lat = float(np.mean(sample_times[1:])) if len(sample_times) > 1 \
            else float(np.mean(sample_times))
        emit(f"fig10/delta={delta_batches}", lat * 1e6,
             f"auc={auc:.3f};sample_ms={1e3*lat:.1f}")
        rows.append((delta_batches, lat, auc))
    return rows


if __name__ == "__main__":
    run()
